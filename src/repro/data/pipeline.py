"""Deterministic synthetic LM data pipeline.

Produces (tokens, labels) batches from a seeded Zipfian token source with
a Markov bigram structure, so training loss has real signal to descend
(the quickstart example shows monotone loss decrease). Batches are
generated per-host for the host's addressable shard and assembled with
``jax.make_array_from_process_local_data`` on multi-host systems; on the
single-host CI we build the global batch directly.

Straggler mitigation (large-scale runnability): the pipeline tracks a
per-host EWMA of batch production latency; hosts flagged as stragglers
get their *local* batch thinned (the trainer rescales the loss by the
actual token count) rather than stalling the step — the deterministic
skip-and-rebalance pattern. On one host this is exercised by the unit
tests through the public accounting API.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    #: EWMA factor for straggler detection
    ewma: float = 0.9
    #: a host is a straggler when its latency exceeds median * threshold
    straggler_threshold: float = 3.0


class LMDataPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0
        self._latency_ewma: dict[int, float] = {}
        # Markov bigram table: token t -> preferred successor band.
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size, dtype=np.int32)

    def _tokens_for(self, step: int, batch: int) -> np.ndarray:
        """Deterministic (batch, seq+1) token block for a step."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        # Zipfian unigram draws, then a bigram walk mixes in structure.
        z = rng.zipf(1.3, size=(batch, cfg.seq_len + 1)).astype(np.int64)
        toks = (z % cfg.vocab_size).astype(np.int32)
        follow = rng.random((batch, cfg.seq_len)) < 0.5
        nxt = self._succ[toks[:, :-1]]
        toks[:, 1:] = np.where(follow, nxt, toks[:, 1:])
        return toks

    def next_batch(self) -> dict:
        """Global (tokens, labels) batch for the current step."""
        t0 = time.perf_counter()
        cfg = self.cfg
        toks = self._tokens_for(self.step, cfg.global_batch)
        self.step += 1
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        self.record_host_latency(0, time.perf_counter() - t0)
        return batch

    # -- straggler accounting -------------------------------------------------

    def record_host_latency(self, host: int, latency_s: float) -> None:
        prev = self._latency_ewma.get(host, latency_s)
        self._latency_ewma[host] = (
            self.cfg.ewma * prev + (1 - self.cfg.ewma) * latency_s
        )

    def straggler_hosts(self) -> list[int]:
        if len(self._latency_ewma) < 2:
            return []
        vals = sorted(self._latency_ewma.values())
        med = vals[len(vals) // 2]
        return [
            h
            for h, v in self._latency_ewma.items()
            if v > self.cfg.straggler_threshold * max(med, 1e-9)
        ]

    def plan_host_batches(self, hosts: list[int], per_host: int) -> dict[int, int]:
        """Thin straggler hosts' local batches; rebalance onto healthy hosts
        (total preserved when possible)."""
        stragglers = set(self.straggler_hosts())
        plan = {h: per_host for h in hosts}
        deficit = 0
        for h in hosts:
            if h in stragglers:
                cut = per_host // 2
                plan[h] = per_host - cut
                deficit += cut
        healthy = [h for h in hosts if h not in stragglers]
        for i in range(deficit):
            if not healthy:
                break
            plan[healthy[i % len(healthy)]] += 1
        return plan
