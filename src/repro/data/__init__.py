"""Data pipeline: deterministic synthetic LM batches, host-sharded, with
straggler-mitigation accounting."""

from .pipeline import DataConfig, LMDataPipeline

__all__ = ["DataConfig", "LMDataPipeline"]
