"""D-Rex-protected distributed checkpointing (the paper's technique as a
first-class framework feature).

Every checkpoint is cut into ~item_mb groups; each group is a D-Rex
"data item": the configured scheduler picks (K, P, M) per group against
the live heterogeneous fabric (reliability target + retention window are
checkpoint policy), the Cauchy-RS kernel encodes, and chunks land on the
chosen nodes. Restore tolerates up to P node losses per group; `repair`
proactively re-encodes degraded groups after failures (§2
failure-recovery techniques layer on the paper's placement model
unchanged).

The manifest is mesh-agnostic (leaf shapes/dtypes + tree structure), so
restore composes with elastic rescale: `restore_latest` returns host
arrays that `repro.train.step.reshard_state` lays out on any mesh.
"""

from __future__ import annotations

import dataclasses
import io
import json
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

from repro.core import BatchContext, DataItem, Placement, PlacementEngine, Scheduler
from repro.ec import ECCodec
from repro.train.step import TrainState

from .fabric import StorageFabric

__all__ = ["CheckpointPolicy", "DRexCheckpointer"]


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    reliability_target: float = 0.999
    retention_days: float = 30.0
    item_mb: float = 64.0            # max group payload size
    use_kernel: bool = True          # Pallas bit-matrix codec vs ref
    keep_last: int = 2               # garbage-collect older checkpoints


@dataclasses.dataclass
class _Group:
    key: str
    k: int
    p: int
    node_ids: list
    orig_nbytes: int


def _pad_to_bucket(payload: bytes) -> bytes:
    """Pad to power-of-two bucket sizes so the codec sees a bounded set of
    chunk shapes (one jit compile per (K, P, bucket) instead of one per
    group) — steady-state encode throughput, <=2x padding on the tail
    group only.  Every (re-)encode of a group MUST go through this so
    repaired chunks keep the shape of the surviving ones."""
    bucket = 4096
    while bucket < len(payload):
        bucket <<= 1
    return payload + b"\x00" * (bucket - len(payload))


class DRexCheckpointer:
    def __init__(
        self,
        fabric: StorageFabric,
        scheduler: Scheduler | str = "drex_sc",
        policy: CheckpointPolicy | None = None,
    ):
        self.fabric = fabric
        # auto_commit=False: the fabric is the byte-accounting authority —
        # occupancy updates when chunks actually land (fabric.put), not at
        # decision time.
        self.engine = PlacementEngine(fabric.cluster, scheduler, auto_commit=False)
        self.scheduler = self.engine.scheduler
        self.policy = policy or CheckpointPolicy()
        self._manifests: dict[int, dict] = {}
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._item_counter = 0
        self.stats: dict[str, float] = {
            "bytes_raw": 0.0, "bytes_stored": 0.0, "encode_s": 0.0, "place_s": 0.0,
        }

    # -- save -------------------------------------------------------------------

    def save(self, state: TrainState, step: int) -> dict:
        leaves, treedef = jax.tree.flatten(state)
        # The tree structure is reconstructed from a like-state at restore
        # (shapes/dtypes per leaf live in the manifest).
        manifest: dict[str, Any] = {"step": step, "leaves": []}
        policy = self.policy
        # One checkpoint = one placement batch: groups share retention and
        # reliability target, so the engine's batch context amortizes the
        # scheduler's reliability DP across all groups of this save.
        ctx = BatchContext()
        for li, leaf in enumerate(leaves):
            if leaf is None:
                manifest["leaves"].append(None)
                continue
            arr = np.asarray(jax.device_get(leaf))
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": str(arr.dtype), "groups": []}
            )
            raw = arr.tobytes()
            self.stats["bytes_raw"] += len(raw)
            max_bytes = int(policy.item_mb * 1e6)
            for off in range(0, max(len(raw), 1), max_bytes):
                payload = raw[off : off + max_bytes]
                g = self._store_group(payload, step, li, off // max_bytes, ctx)
                manifest["leaves"][li]["groups"].append(dataclasses.asdict(g))
        self._manifests[step] = manifest
        self._gc(step)
        return manifest

    def save_async(self, state: TrainState, step: int) -> Future:
        # device_get on the caller thread (consistent snapshot), encode+put
        # in the background — the async checkpointing pattern of [29, 30].
        leaves, _ = jax.tree.flatten(state)
        host_leaves = [
            None if l is None else np.asarray(jax.device_get(l)) for l in leaves
        ]

        def work():
            fake_state = jax.tree.unflatten(jax.tree.structure(state), host_leaves)
            return self.save(fake_state, step)

        return self._pool.submit(work)

    def _store_group(
        self,
        payload: bytes,
        step: int,
        leaf_i: int,
        part: int,
        ctx: BatchContext | None = None,
    ) -> _Group:
        policy = self.policy
        orig_len = len(payload)
        payload = _pad_to_bucket(payload)
        size_mb = max(len(payload) / 1e6, 1e-6)
        self._item_counter += 1
        item = DataItem(
            item_id=self._item_counter,
            size_mb=size_mb,
            arrival_time=float(step),
            delta_t_days=policy.retention_days,
            reliability_target=policy.reliability_target,
        )
        record = self.engine.place(item, ctx=ctx)
        self.stats["place_s"] += record.overhead_s
        if record.placement is None:
            raise IOError(
                f"D-Rex could not place checkpoint group ({size_mb:.1f} MB, "
                f"RT={policy.reliability_target}): {record.reason}"
            )
        pl = record.placement
        codec = ECCodec(pl.k, pl.p, use_kernel=policy.use_kernel)
        t0 = time.perf_counter()
        chunks = codec.encode(payload)
        self.stats["encode_s"] += time.perf_counter() - t0
        key = f"ck{step}_l{leaf_i}_p{part}"
        for row, node in enumerate(pl.node_ids):
            self.fabric.put(node, f"{key}_r{row}", chunks[row].tobytes())
            self.stats["bytes_stored"] += chunks.shape[1]
        return _Group(key=key, k=pl.k, p=pl.p, node_ids=list(pl.node_ids), orig_nbytes=orig_len)

    # -- restore ----------------------------------------------------------------

    def restore_latest(self, like_state_or_cfg) -> Optional[tuple[TrainState, int]]:
        if not self._manifests:
            return None
        step = max(self._manifests)
        return self.restore(step, like_state_or_cfg), step

    def restore(self, step: int, like_state) -> TrainState:
        """Rebuild the state pytree. ``like_state`` provides the tree
        structure (a TrainState of matching config — e.g. freshly
        initialized with `jax.eval_shape` or real arrays)."""
        manifest = self._manifests[step]
        leaves_meta = manifest["leaves"]
        like_leaves, treedef = jax.tree.flatten(like_state)
        assert len(like_leaves) == len(
            [m for m in leaves_meta]
        ), "state structure mismatch"
        out_leaves = []
        for meta in leaves_meta:
            if meta is None:
                out_leaves.append(None)
                continue
            buf = io.BytesIO()
            for g in meta["groups"]:
                buf.write(self._load_group(_Group(**g)))
            arr = np.frombuffer(buf.getvalue(), dtype=np.dtype(meta["dtype"]))
            out_leaves.append(arr.reshape(meta["shape"]))
        return jax.tree.unflatten(treedef, out_leaves)

    def _load_group(self, g: _Group) -> bytes:
        rows, chunks = [], []
        for row, node in enumerate(g.node_ids):
            blob = self.fabric.get(node, f"{g.key}_r{row}")
            if blob is not None:
                rows.append(row)
                chunks.append(np.frombuffer(blob, dtype=np.uint8))
            if len(rows) == g.k:
                break
        if len(rows) < g.k:
            raise IOError(
                f"checkpoint group {g.key} unrecoverable: "
                f"{len(rows)}/{g.k} chunks available (P={g.p} exceeded)"
            )
        codec = ECCodec(g.k, g.p, use_kernel=self.policy.use_kernel)
        return codec.decode(np.stack(chunks), np.array(rows), g.orig_nbytes)

    # -- failure handling ---------------------------------------------------------

    def on_node_failure(self, node_id: int) -> None:
        self.fabric.fail_node(node_id)

    def repair(self, step: Optional[int] = None, *, strict: bool = True) -> int:
        """Proactive repair: re-encode any group that lost chunks and place
        the replacements through ``PlacementEngine.plan_repair`` (keeps
        (K,P), re-maps; best-effort mode — group health is reported by
        :meth:`group_reliability`).  Returns the number of chunks rebuilt.

        A group whose missing chunks cannot *all* be re-placed (not enough
        live nodes with capacity) is left untouched and reported: with
        ``strict=True`` (default) an :class:`IOError` lists every such
        group after the repairable ones were fixed.  The old code silently
        under-repaired here — ``zip(missing, live)`` truncated when live
        candidates ran out, leaving groups degraded with no error.
        """
        step = step if step is not None else max(self._manifests)
        manifest = self._manifests[step]
        rebuilt = 0
        unplaced: list[tuple[str, int, str]] = []
        for meta in manifest["leaves"]:
            if meta is None:
                continue
            for gd in meta["groups"]:
                g = _Group(**gd)
                missing = [
                    (row, node)
                    for row, node in enumerate(g.node_ids)
                    if self.fabric.get(node, f"{g.key}_r{row}") is None
                ]
                if not missing:
                    continue
                payload = self._load_group(g)  # raises if > P lost
                codec = ECCodec(g.k, g.p, use_kernel=self.policy.use_kernel)
                # Re-pad exactly as the original encode did: replacement
                # chunks must match the surviving chunks' shape.
                chunks = codec.encode(_pad_to_bucket(payload))
                chunk_mb = chunks.shape[1] / 1e6
                missing_rows = {row for row, _ in missing}
                survivors = [
                    node
                    for row, node in enumerate(g.node_ids)
                    if row not in missing_rows
                ]
                self._item_counter += 1
                item = DataItem(
                    item_id=self._item_counter,
                    size_mb=chunk_mb * g.k,
                    arrival_time=float(step),
                    delta_t_days=self.policy.retention_days,
                    reliability_target=self.policy.reliability_target,
                )
                # require_target=False: the code is fixed at (K, P), so
                # repair is best-effort re-mapping (no reliability DP to
                # amortize — group health is group_reliability()'s job);
                # commit=False because the fabric accounts bytes as
                # chunks land (fabric.put).
                plan = self.engine.plan_repair(
                    item,
                    Placement(k=g.k, p=g.p, node_ids=tuple(g.node_ids)),
                    chunk_mb=chunk_mb,
                    survivors=survivors,
                    allow_parity_growth=False,
                    require_target=False,
                    commit=False,
                )
                if not plan.ok:
                    unplaced.append((g.key, len(missing), plan.reason))
                    continue
                for (row, _), new_node in zip(missing, plan.new_nodes):
                    self.fabric.put(new_node, f"{g.key}_r{row}", chunks[row].tobytes())
                    g.node_ids[row] = new_node
                    rebuilt += 1
                gd["node_ids"] = g.node_ids
        if unplaced and strict:
            detail = "; ".join(
                f"{key}: {n} missing chunk(s) ({reason})"
                for key, n, reason in unplaced
            )
            raise IOError(
                f"repair left {len(unplaced)} group(s) degraded: {detail}"
            )
        return rebuilt

    def group_reliability(self, step: Optional[int] = None) -> list[float]:
        """Current Pr_avail of every group (post-failure health metric)."""
        step = step if step is not None else max(self._manifests)
        out = []
        for meta in self._manifests[step]["leaves"]:
            if meta is None:
                continue
            for gd in meta["groups"]:
                alive = [n for n in gd["node_ids"] if self.fabric.cluster.alive[n]]
                lost = len(gd["node_ids"]) - len(alive)
                if lost > gd["p"]:
                    out.append(0.0)
                    continue
                fp = self.fabric.cluster.fail_probs(self.policy.retention_days)[alive]
                from repro.core.reliability import poisson_binomial_cdf

                out.append(poisson_binomial_cdf(fp, gd["p"] - lost))
        return out

    # -- gc -------------------------------------------------------------------------

    def _gc(self, newest_step: int) -> None:
        steps = sorted(self._manifests)
        while len(steps) > self.policy.keep_last:
            victim = steps.pop(0)
            man = self._manifests.pop(victim)
            for meta in man["leaves"]:
                if meta is None:
                    continue
                for gd in meta["groups"]:
                    for row, node in enumerate(gd["node_ids"]):
                        self.fabric.delete(node, f"{gd['key']}_r{row}")
