"""D-Rex-protected distributed checkpointing (the paper's technique as a
first-class framework feature).

Every checkpoint is cut into ~item_mb groups; each group is a D-Rex
"data item": the configured scheduler picks (K, P, M) per group against
the live heterogeneous fabric (reliability target + retention window are
checkpoint policy), the Cauchy-RS kernel encodes, and chunks land on the
chosen nodes.  Restore tolerates up to P node losses per group; `repair`
proactively re-encodes degraded groups after failures (§2
failure-recovery techniques layer on the paper's placement model
unchanged).

``save`` is a streaming encode→place→write pipeline: all groups of a
checkpoint are placed in ONE ``place_many`` batch (one shared
``BatchContext``, so the reliability DP amortizes across every group),
encoded in per-(K, P) cohort waves through ``ECCodec.encode_many`` (one
kernel launch per wave), and each wave's fabric ``put`` overlaps the
*next* wave's encode through a multi-worker I/O pool (double-buffered —
at most two waves of chunks are in flight, bounding peak memory).
``pipeline_workers=0`` recovers the legacy serial path (per-group encode
then put), which benchmarks/fig13 uses as the upload baseline.

The manifest is mesh-agnostic (leaf shapes/dtypes + tree structure), so
restore composes with elastic rescale: `restore_latest` returns host
arrays that `repro.train.step.reshard_state` lays out on any mesh.
"""

from __future__ import annotations

import dataclasses
import io
import json
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

from repro.core import BatchContext, DataItem, Placement, PlacementEngine, Scheduler
from repro.ec import ECCodec, plan_cohorts
from repro.train.step import TrainState

from .fabric import StorageFabric

__all__ = ["CheckpointPolicy", "DRexCheckpointer"]


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    reliability_target: float = 0.999
    retention_days: float = 30.0
    item_mb: float = 64.0            # max group payload size
    use_kernel: bool = True          # Pallas/XLA bit-matrix codec vs ref
    keep_last: int = 2               # garbage-collect older checkpoints
    #: fabric-write workers for the save pipeline; 0 = legacy serial
    #: (per-group encode then put, no overlap — the fig13 baseline).
    pipeline_workers: int = 2
    #: max groups fused into one encode launch; also the wave size the
    #: pipeline double-buffers (bounds peak chunk memory to ~2 waves).
    encode_wave_groups: int = 16


@dataclasses.dataclass
class _Group:
    key: str
    k: int
    p: int
    node_ids: list
    orig_nbytes: int


def _pad_to_bucket(payload: bytes) -> bytes:
    """Pad to power-of-two bucket sizes so the codec sees a bounded set of
    chunk shapes (one jit compile per (K, P, bucket) instead of one per
    group) — steady-state encode throughput, <=2x padding on the tail
    group only.  Every (re-)encode of a group MUST go through this so
    repaired chunks keep the shape of the surviving ones."""
    bucket = 4096
    while bucket < len(payload):
        bucket <<= 1
    return payload + b"\x00" * (bucket - len(payload))


class DRexCheckpointer:
    def __init__(
        self,
        fabric: StorageFabric,
        scheduler: Scheduler | str = "drex_sc",
        policy: CheckpointPolicy | None = None,
    ):
        self.fabric = fabric
        # auto_commit=False: the fabric is the byte-accounting authority —
        # occupancy updates when chunks actually land (fabric.put), not at
        # decision time.
        self.engine = PlacementEngine(fabric.cluster, scheduler, auto_commit=False)
        self.scheduler = self.engine.scheduler
        self.policy = policy or CheckpointPolicy()
        self._manifests: dict[int, dict] = {}
        # Two pools, no cross-wait cycle: save drivers (async snapshots)
        # wait only on I/O futures, never on other drivers — so two
        # overlapping save_async calls cannot deadlock and no longer
        # serialize behind a single worker.
        self._save_pool = ThreadPoolExecutor(max_workers=2)
        self._io_pool = ThreadPoolExecutor(
            max_workers=max(1, self.policy.pipeline_workers)
        )
        #: serializes the placement phase (engine + item-id counter) so
        #: concurrent saves see consistent cluster snapshots.
        self._place_lock = threading.Lock()
        self._meta_lock = threading.Lock()
        self._item_counter = 0
        self.stats: dict[str, float] = {
            "bytes_raw": 0.0, "bytes_stored": 0.0, "encode_s": 0.0, "place_s": 0.0,
        }

    # -- save -------------------------------------------------------------------

    def save(self, state: TrainState, step: int) -> dict:
        """Encode→place→write one checkpoint through the batched pipeline.

        Placement decisions for all groups are made against the cluster
        view at the start of the save (one ``place_many`` batch) — the
        fabric's byte accounting still updates as chunks land."""
        leaves, treedef = jax.tree.flatten(state)
        # The tree structure is reconstructed from a like-state at restore
        # (shapes/dtypes per leaf live in the manifest).
        manifest: dict[str, Any] = {"step": step, "leaves": []}
        policy = self.policy
        max_bytes = int(policy.item_mb * 1e6)
        # 1. Split every leaf into group payloads (bucket-padded).
        payloads: list[bytes] = []
        orig_lens: list[int] = []
        slots: list[tuple[int, int]] = []  # (leaf_i, part)
        for li, leaf in enumerate(leaves):
            if leaf is None:
                manifest["leaves"].append(None)
                continue
            arr = np.asarray(jax.device_get(leaf))
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": str(arr.dtype), "groups": []}
            )
            raw = arr.tobytes()
            with self._meta_lock:
                self.stats["bytes_raw"] += len(raw)
            for off in range(0, max(len(raw), 1), max_bytes):
                payload = raw[off : off + max_bytes]
                payloads.append(_pad_to_bucket(payload))
                orig_lens.append(len(payload))
                slots.append((li, off // max_bytes))
        # 2. One placement batch: groups share retention and reliability
        # target, so the engine's batch context amortizes the scheduler's
        # reliability DP across all groups of this save.
        with self._place_lock:
            items = []
            for payload in payloads:
                self._item_counter += 1
                items.append(DataItem(
                    item_id=self._item_counter,
                    size_mb=max(len(payload) / 1e6, 1e-6),
                    arrival_time=float(step),
                    delta_t_days=policy.retention_days,
                    reliability_target=policy.reliability_target,
                ))
            records = self.engine.place_many(items, ctx=BatchContext())
        placements: list[Placement] = []
        for item, record in zip(items, records):
            with self._meta_lock:
                self.stats["place_s"] += record.overhead_s
            if record.placement is None:
                raise IOError(
                    f"D-Rex could not place checkpoint group "
                    f"({item.size_mb:.1f} MB, "
                    f"RT={policy.reliability_target}): {record.reason}"
                )
            placements.append(record.placement)
        # 3. Cohort waves: encode cohort i+1 while cohort i's chunks land.
        groups: list[Optional[_Group]] = [None] * len(payloads)
        wave_size = 1 if policy.pipeline_workers == 0 else max(
            1, policy.encode_wave_groups
        )
        waves: list[list[int]] = []
        for (_kp, idxs) in plan_cohorts([(pl.k, pl.p) for pl in placements]):
            for w in range(0, len(idxs), wave_size):
                waves.append(idxs[w : w + wave_size])
        pending: deque[Future] = deque()
        try:
            self._encode_waves(
                waves, payloads, placements, slots, orig_lens, groups,
                step, pending,
            )
        except BaseException:
            while pending:  # no orphaned background puts behind an error
                try:
                    pending.popleft().result()
                except Exception:
                    pass
            raise
        while pending:
            pending.popleft().result()
        # 4. Manifest in original (leaf, part) order.
        for g, (li, _part) in zip(groups, slots):
            manifest["leaves"][li]["groups"].append(dataclasses.asdict(g))
        with self._meta_lock:
            self._manifests[step] = manifest
        self._gc(step)
        return manifest

    def _encode_waves(
        self, waves, payloads, placements, slots, orig_lens, groups,
        step, pending,
    ) -> None:
        """Encode each wave and hand its chunks to the I/O pool."""
        policy = self.policy
        for wave in waves:
            k, p = placements[wave[0]].k, placements[wave[0]].p
            codec = ECCodec(k, p, use_kernel=policy.use_kernel)
            t0 = time.perf_counter()
            chunk_mats = codec.encode_many([payloads[i] for i in wave])
            with self._meta_lock:
                self.stats["encode_s"] += time.perf_counter() - t0
            entries = []
            for i, chunks in zip(wave, chunk_mats):
                li, part = slots[i]
                g = _Group(
                    key=f"ck{step}_l{li}_p{part}", k=k, p=p,
                    node_ids=list(placements[i].node_ids),
                    orig_nbytes=orig_lens[i],
                )
                groups[i] = g
                entries.append((g, chunks))
            if policy.pipeline_workers == 0:
                self._put_wave(entries)
            else:
                pending.append(self._io_pool.submit(self._put_wave, entries))
                # double buffer: at most 2 waves of chunks in flight
                while len(pending) > 2:
                    pending.popleft().result()

    def _put_wave(self, entries: list[tuple[_Group, np.ndarray]]) -> None:
        """Land one wave's chunks on the fabric (runs on the I/O pool)."""
        stored = 0.0
        for g, chunks in entries:
            for row, node in enumerate(g.node_ids):
                self.fabric.put(node, f"{g.key}_r{row}", chunks[row].tobytes())
                stored += chunks.shape[1]
        with self._meta_lock:
            self.stats["bytes_stored"] += stored

    def save_async(self, state: TrainState, step: int) -> Future:
        # device_get on the caller thread (consistent snapshot), encode+put
        # in the background — the async checkpointing pattern of [29, 30].
        leaves, _ = jax.tree.flatten(state)
        host_leaves = [
            None if l is None else np.asarray(jax.device_get(l)) for l in leaves
        ]

        def work():
            fake_state = jax.tree.unflatten(jax.tree.structure(state), host_leaves)
            return self.save(fake_state, step)

        return self._save_pool.submit(work)

    # -- restore ----------------------------------------------------------------

    def restore_latest(self, like_state_or_cfg) -> Optional[tuple[TrainState, int]]:
        if not self._manifests:
            return None
        step = max(self._manifests)
        return self.restore(step, like_state_or_cfg), step

    def restore(self, step: int, like_state) -> TrainState:
        """Rebuild the state pytree. ``like_state`` provides the tree
        structure (a TrainState of matching config — e.g. freshly
        initialized with `jax.eval_shape` or real arrays)."""
        manifest = self._manifests[step]
        leaves_meta = manifest["leaves"]
        like_leaves, treedef = jax.tree.flatten(like_state)
        assert len(like_leaves) == len(
            [m for m in leaves_meta]
        ), "state structure mismatch"
        out_leaves = []
        for meta in leaves_meta:
            if meta is None:
                out_leaves.append(None)
                continue
            buf = io.BytesIO()
            # All groups of a leaf decode in cohort launches (per (K, P)
            # and erasure pattern) instead of one kernel call per group.
            for raw in self._load_groups([_Group(**g) for g in meta["groups"]]):
                buf.write(raw)
            arr = np.frombuffer(buf.getvalue(), dtype=np.dtype(meta["dtype"]))
            out_leaves.append(arr.reshape(meta["shape"]))
        return jax.tree.unflatten(treedef, out_leaves)

    def _load_groups(self, groups: list[_Group]) -> list[bytes]:
        """Fetch + decode many groups, batching decodes by (K, P)."""
        gathered: list[tuple[np.ndarray, np.ndarray, int]] = []
        for g in groups:
            rows, chunks = [], []
            for row, node in enumerate(g.node_ids):
                blob = self.fabric.get(node, f"{g.key}_r{row}")
                if blob is not None:
                    rows.append(row)
                    chunks.append(np.frombuffer(blob, dtype=np.uint8))
                if len(rows) == g.k:
                    break
            if len(rows) < g.k:
                raise IOError(
                    f"checkpoint group {g.key} unrecoverable: "
                    f"{len(rows)}/{g.k} chunks available (P={g.p} exceeded)"
                )
            gathered.append((np.stack(chunks), np.array(rows), g.orig_nbytes))
        outs: list = [None] * len(groups)
        for (k, p), idxs in plan_cohorts([(g.k, g.p) for g in groups]):
            codec = ECCodec(k, p, use_kernel=self.policy.use_kernel)
            for i, raw in zip(idxs, codec.decode_many([gathered[i] for i in idxs])):
                outs[i] = raw
        return outs

    def _load_group(self, g: _Group) -> bytes:
        return self._load_groups([g])[0]

    # -- failure handling ---------------------------------------------------------

    def on_node_failure(self, node_id: int) -> None:
        self.fabric.fail_node(node_id)

    def repair(self, step: Optional[int] = None, *, strict: bool = True) -> int:
        """Proactive repair: re-encode any group that lost chunks and place
        the replacements through ``PlacementEngine.plan_repair`` (keeps
        (K,P), re-maps; best-effort mode — group health is reported by
        :meth:`group_reliability`).  Returns the number of chunks rebuilt.

        Re-encodes run through the same cached-matrix cohort path as
        ``save`` (one launch per (K, P) cohort of degraded groups); the
        coding matrices themselves come from the process-wide cache, so
        steady-state repair rebuilds no matrices at all.

        A group whose missing chunks cannot *all* be re-placed (not enough
        live nodes with capacity) is left untouched and reported: with
        ``strict=True`` (default) an :class:`IOError` lists every such
        group after the repairable ones were fixed.  The old code silently
        under-repaired here — ``zip(missing, live)`` truncated when live
        candidates ran out, leaving groups degraded with no error.
        """
        step = step if step is not None else max(self._manifests)
        manifest = self._manifests[step]
        rebuilt = 0
        unplaced: list[tuple[str, int, str]] = []
        # 1. Collect every degraded group (reads only; no mutation yet).
        degraded: list[tuple[dict, _Group, list[tuple[int, int]]]] = []
        for meta in manifest["leaves"]:
            if meta is None:
                continue
            for gd in meta["groups"]:
                g = _Group(**gd)
                missing = [
                    (row, node)
                    for row, node in enumerate(g.node_ids)
                    if self.fabric.get(node, f"{g.key}_r{row}") is None
                ]
                if missing:
                    degraded.append((gd, g, missing))
        if not degraded:
            return 0
        # 2. Cohort re-encode: decode the survivors (raises if > P lost),
        # re-pad exactly as the original encode did (replacement chunks
        # must match the surviving chunks' shape), one launch per (K, P).
        payloads = self._load_groups([g for _, g, _ in degraded])
        specs = [(g.k, g.p) for _, g, _ in degraded]
        all_chunks: list = [None] * len(degraded)
        for (k, p), idxs in plan_cohorts(specs):
            codec = ECCodec(k, p, use_kernel=self.policy.use_kernel)
            for i, chunks in zip(
                idxs,
                codec.encode_many([_pad_to_bucket(payloads[i]) for i in idxs]),
            ):
                all_chunks[i] = chunks
        # 3. Re-place + land replacements, group by group (plans see the
        # fabric bytes earlier repairs already landed).
        for (gd, g, missing), chunks in zip(degraded, all_chunks):
            chunk_mb = chunks.shape[1] / 1e6
            missing_rows = {row for row, _ in missing}
            survivors = [
                node
                for row, node in enumerate(g.node_ids)
                if row not in missing_rows
            ]
            with self._place_lock:
                self._item_counter += 1
                item = DataItem(
                    item_id=self._item_counter,
                    size_mb=chunk_mb * g.k,
                    arrival_time=float(step),
                    delta_t_days=self.policy.retention_days,
                    reliability_target=self.policy.reliability_target,
                )
                # require_target=False: the code is fixed at (K, P), so
                # repair is best-effort re-mapping (no reliability DP to
                # amortize — group health is group_reliability()'s job);
                # commit=False because the fabric accounts bytes as
                # chunks land (fabric.put).
                plan = self.engine.plan_repair(
                    item,
                    Placement(k=g.k, p=g.p, node_ids=tuple(g.node_ids)),
                    chunk_mb=chunk_mb,
                    survivors=survivors,
                    allow_parity_growth=False,
                    require_target=False,
                    commit=False,
                )
            if not plan.ok:
                unplaced.append((g.key, len(missing), plan.reason))
                continue
            for (row, _), new_node in zip(missing, plan.new_nodes):
                self.fabric.put(new_node, f"{g.key}_r{row}", chunks[row].tobytes())
                g.node_ids[row] = new_node
                rebuilt += 1
            gd["node_ids"] = g.node_ids
        if unplaced and strict:
            detail = "; ".join(
                f"{key}: {n} missing chunk(s) ({reason})"
                for key, n, reason in unplaced
            )
            raise IOError(
                f"repair left {len(unplaced)} group(s) degraded: {detail}"
            )
        return rebuilt

    def group_reliability(self, step: Optional[int] = None) -> list[float]:
        """Current Pr_avail of every group (post-failure health metric)."""
        step = step if step is not None else max(self._manifests)
        out = []
        for meta in self._manifests[step]["leaves"]:
            if meta is None:
                continue
            for gd in meta["groups"]:
                alive = [n for n in gd["node_ids"] if self.fabric.cluster.alive[n]]
                lost = len(gd["node_ids"]) - len(alive)
                if lost > gd["p"]:
                    out.append(0.0)
                    continue
                fp = self.fabric.cluster.fail_probs(self.policy.retention_days)[alive]
                from repro.core.reliability import poisson_binomial_cdf

                out.append(poisson_binomial_cdf(fp, gd["p"] - lost))
        return out

    # -- gc -------------------------------------------------------------------------

    def _gc(self, newest_step: int) -> None:
        with self._meta_lock:
            steps = sorted(self._manifests)
            victims = []
            while len(steps) > self.policy.keep_last:
                victim = steps.pop(0)
                victims.append(self._manifests.pop(victim))
        for man in victims:
            for meta in man["leaves"]:
                if meta is None:
                    continue
                for gd in meta["groups"]:
                    for row, node in enumerate(gd["node_ids"]):
                        self.fabric.delete(node, f"{gd['key']}_r{row}")
