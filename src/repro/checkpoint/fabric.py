"""Simulated heterogeneous storage fabric (the DynoStore-style data
containers of paper §6).

Each storage node holds chunk blobs up to its capacity; nodes can
fail-stop (dropping everything they held). The fabric exposes the same
``ClusterView`` the D-Rex schedulers consume, so placement decisions made
for checkpoints use the identical code path as the paper's simulator.
Optionally persists chunks to a directory per node (restart across
processes).
"""

from __future__ import annotations

import pathlib
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.types import ClusterView, StorageNode

__all__ = ["StorageFabric"]


class StorageFabric:
    def __init__(
        self,
        nodes: Sequence[StorageNode],
        persist_dir: Optional[str] = None,
        link_mbps: Optional[float] = None,
    ):
        self.nodes = list(nodes)
        self.cluster = ClusterView.from_nodes(self.nodes)
        self._blobs: list[dict[str, bytes]] = [{} for _ in self.nodes]
        self._lock = threading.Lock()
        #: simulated per-put link bandwidth (MB/s): each ``put`` blocks
        #: its calling thread for blob_mb / link_mbps *outside* the
        #: fabric lock, so concurrent writers overlap like independent
        #: network links.  ``None`` = in-memory speed (tests, simulator);
        #: benchmarks/fig13 uses this to make upload pipelining
        #: measurable against a realistic write cost.
        self.link_mbps = link_mbps
        self.persist_dir = pathlib.Path(persist_dir) if persist_dir else None
        if self.persist_dir:
            for i in range(len(self.nodes)):
                (self.persist_dir / f"node_{i}").mkdir(parents=True, exist_ok=True)
            self._reload()

    # -- data plane -----------------------------------------------------------

    def put(self, node_id: int, key: str, blob: bytes) -> None:
        if self.link_mbps:
            time.sleep(len(blob) / 1e6 / self.link_mbps)
        with self._lock:
            if not self.cluster.alive[node_id]:
                raise IOError(f"node {node_id} is down")
            size_mb = len(blob) / 1e6
            if self.cluster.free_mb[node_id] < size_mb:
                raise IOError(f"node {node_id} out of capacity")
            old = self._blobs[node_id].pop(key, None)
            used = self.cluster.writable("used_mb")
            if old is not None:
                used[node_id] -= len(old) / 1e6
            self._blobs[node_id][key] = blob
            used[node_id] += size_mb
        if self.persist_dir:
            (self.persist_dir / f"node_{node_id}" / key).write_bytes(blob)

    def get(self, node_id: int, key: str) -> Optional[bytes]:
        with self._lock:
            if not self.cluster.alive[node_id]:
                return None
            return self._blobs[node_id].get(key)

    def delete(self, node_id: int, key: str) -> None:
        with self._lock:
            blob = self._blobs[node_id].pop(key, None)
            if blob is not None:
                self.cluster.writable("used_mb")[node_id] -= len(blob) / 1e6
        if self.persist_dir:
            p = self.persist_dir / f"node_{node_id}" / key
            if p.exists():
                p.unlink()

    # -- failure injection ------------------------------------------------------

    def fail_node(self, node_id: int) -> None:
        """Fail-stop: all chunks on the node are permanently lost."""
        with self._lock:
            self.cluster.fail_node(node_id)
            self._blobs[node_id].clear()
            self.cluster.writable("used_mb")[node_id] = 0.0
        if self.persist_dir:
            d = self.persist_dir / f"node_{node_id}"
            for f in d.glob("*"):
                f.unlink()

    def live_nodes(self) -> list[int]:
        return [int(i) for i in self.cluster.live_ids()]

    def _reload(self) -> None:
        for i in range(len(self.nodes)):
            d = self.persist_dir / f"node_{i}"
            for f in d.glob("*"):
                blob = f.read_bytes()
                self._blobs[i][f.name] = blob
                self.cluster.writable("used_mb")[i] += len(blob) / 1e6
