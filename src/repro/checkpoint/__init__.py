"""EC-protected checkpointing with D-Rex placement (paper integration)."""

from .fabric import StorageFabric
from .manager import CheckpointPolicy, DRexCheckpointer

__all__ = ["StorageFabric", "CheckpointPolicy", "DRexCheckpointer"]
