"""Post-SPMD HLO text analysis: per-device memory traffic, collective
bytes, and dot FLOPs — with while-loop bodies scaled by their trip
counts (which ``compiled.cost_analysis()`` does not do).

The compiled module is the per-device program, so every byte count here
is already per-chip. Computations are parsed into symbol tables
(instruction -> shape) so collective/dot operand shapes resolve even
though HLO text prints operand names only.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[\d,]*\})?))")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples sum their elements."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",") if d]))
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[list[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    body: str          # full RHS text

    @property
    def out_bytes(self) -> int:
        return _shape_bytes(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    params: dict          # name -> type str
    instructions: list

    def symbols(self) -> dict:
        sym = dict(self.params)
        for ins in self.instructions:
            sym[ins.name] = ins.type_str
        return sym


_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|\w+\[[\d,]*\](?:\{[\d,:TS()]*\})?|tuple|token)\s+)?([\w\-]+)\(")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        hdr = _COMP_HDR_RE.match(s)
        if hdr and s.endswith("{"):
            params = {}
            for pname, ptype in _PARAM_RE.findall(hdr.group(2)):
                params[pname] = ptype
            cur = Computation(
                name=hdr.group(1),
                is_entry=s.startswith("ENTRY"),
                params=params,
                instructions=[],
            )
            comps[cur.name] = cur
            continue
        if s == "}" or s == "})":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "TYPE opcode(...)..." ; find the opcode
        om = re.match(r"^((?:\([^)]*\))|(?:[\w]+\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\(", rhs)
        if om:
            type_str, opcode = om.group(1), om.group(2)
        else:
            om2 = re.match(r"^([\w]+\[[\d,]*\](?:\{[^}]*\})?)\s+(\S+)", rhs)
            if om2:
                type_str, opcode = om2.group(1), om2.group(2).split("(")[0]
            else:
                type_str, opcode = rhs, "unknown"
        cur.instructions.append(Instruction(name, type_str, opcode, rhs))
    return comps


def _while_links(comps: dict[str, Computation]) -> list[tuple[str, str, str]]:
    """(computation containing the while, body comp, condition comp)."""
    out = []
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.opcode == "while":
                b = re.search(r"body=%?([\w.\-]+)", ins.body)
                c = re.search(r"condition=%?([\w.\-]+)", ins.body)
                if b and c:
                    out.append((comp.name, b.group(1), c.group(1)))
    return out


def _trip_count(cond: Computation) -> int:
    """Heuristic trip count: largest s32 constant in the while condition
    (scan lowers to `iter < length`). Falls back to 1."""
    best = 1
    for ins in cond.instructions:
        m = re.search(r"s32\[\]\s+constant\((\d+)\)", ins.body) or re.search(
            r"constant\((\d+)\)", ins.body
        )
        if m and ins.type_str.startswith("s32"):
            best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution multiplier per computation (nested whiles multiply)."""
    mult: dict[str, float] = {}
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {c: 1.0 for c in comps}
    links = _while_links(comps)
    children: dict[str, list[tuple[str, int]]] = {}
    for host, body, cond in links:
        trips = _trip_count(comps[cond]) if cond in comps else 1
        children.setdefault(host, []).append((body, trips))
        children.setdefault(host, []).append((cond, trips + 1))

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for child, trips in children.get(name, []):
            visit(child, m * trips)

    visit(entry.name, 1.0)
    return mult


_SKIP_MEMORY_OPS = {
    "tuple",
    "get-tuple-element",
    "parameter",
    "constant",
    "bitcast",
    "after-all",
    "partition-id",
    "replica-id",
    "while",       # body counted separately
    "conditional",
}


@dataclasses.dataclass
class HloStats:
    memory_bytes: float = 0.0          # raw per-op traffic (upper bound)
    memory_bytes_ideal: float = 0.0    # TPU-fusion-idealized traffic
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES}
    )
    dot_flops: float = 0.0
    n_collectives: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# Ops that still materialize HBM traffic under TPU-grade fusion. The CPU
# backend (whose optimized HLO we analyze) fuses far less than the TPU
# backend, so counting every op's operands/outputs double-counts
# score-sized attention tensors many times over. `memory_bytes` keeps
# that raw upper bound; `memory_bytes_ideal` counts only materializing
# ops — bare elementwise/layout ops are assumed fused into producers.
_IDEAL_COUNTED = {
    "dot",
    "fusion",
    "reduce",
    "reduce-window",
    "sort",
    "concatenate",
    "custom-call",
    "select-and-scatter",
    "convolution",
    "cholesky",
    "triangular-solve",
}


def _operand_names(ins: Instruction) -> list[str]:
    """Operand instruction names (first parenthesized group only, so
    attributes like body=%x / to_apply=%y are excluded)."""
    m = re.search(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)", ins.body)
    if not m:
        return []
    return _OPERAND_RE.findall(m.group(1))


def _sliced_read_bytes(comps, comp_name: str) -> Optional[dict[int, float]]:
    """For a fusion computation: bytes actually read per parameter index,
    for parameters consumed ONLY through dynamic-slice/gather (a scan
    body slicing one layer out of stacked weights reads the slice, not
    the stack). Returns {param_idx: bytes} for such params, or None if
    the computation is unknown."""
    comp = comps.get(comp_name)
    if comp is None:
        return None
    out: dict[int, float] = {}
    # map parameter instruction name -> param index
    pnames: dict[str, int] = {}
    for ins in comp.instructions:
        pm = re.match(r"parameter\((\d+)\)", ins.body.split(" ", 1)[-1]) or re.search(
            r"parameter\((\d+)\)", ins.body
        )
        if pm:
            pnames[ins.name] = int(pm.group(1))
    sym = comp.symbols()
    for pname, pidx in pnames.items():
        consumers = [
            i
            for i in comp.instructions
            if i.name != pname and re.search(rf"%{re.escape(pname)}\b", i.body)
        ]
        if not consumers:
            continue
        if all(c.opcode in ("dynamic-slice", "gather") for c in consumers):
            out[pidx] = float(sum(c.out_bytes for c in consumers))
        elif all(c.opcode == "dynamic-update-slice" for c in consumers) and all(
            _operand_names(c) and _operand_names(c)[0] == pname for c in consumers
        ):
            # param is the in-place update target: traffic ~ update size
            upd = 0.0
            for c in consumers:
                ops = _operand_names(c)
                if len(ops) > 1 and ops[1] in sym:
                    upd += _shape_bytes(sym[ops[1]])
            out[pidx] = upd
    return out


def fusion_traffic(comps, ins: Instruction, operands: list[str]) -> float:
    """HBM traffic of one fusion call (unmultiplied).

    Two special patterns matter enormously inside scan bodies:
      * slice-read: a parameter consumed only via dynamic-slice/gather
        (layer weights sliced from the stacked scan array) reads the
        slice, not the stack;
      * in-place accumulation: a fusion containing a dynamic-update-slice
        whose output aliases a same-shaped operand (scan residual
        stacking, KV-cache writes) writes the update region, not the
        whole buffer.
    """
    cm = re.search(r"calls=%?([\w.\-]+)", ins.body)
    callee = cm.group(1) if cm else None
    callee_comp = comps.get(callee) if callee else None
    has_dus = bool(callee_comp) and any(
        i.opcode == "dynamic-update-slice" for i in callee_comp.instructions
    )
    if has_dus and any(
        _shape_bytes(t) == ins.out_bytes and ins.out_bytes > 0 for t in operands
    ):
        others = [t for t in operands if _shape_bytes(t) != ins.out_bytes]
        upd = sum(_shape_bytes(t) for t in others)
        biggest = max((_shape_bytes(t) for t in others), default=0)
        return float(upd + biggest)  # read sources + write update region
    sliced = _sliced_read_bytes(comps, callee) if callee else None
    in_bytes = 0.0
    for idx, t in enumerate(operands):
        if sliced is not None and idx in sliced:
            in_bytes += sliced[idx]
        else:
            in_bytes += _shape_bytes(t)
    return float(in_bytes + ins.out_bytes)


def analyze_hlo(text: str) -> HloStats:
    comps = parse_hlo(text)
    mult = _multipliers(comps)
    stats = HloStats()
    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None:
            # not reachable from entry through whiles: a fusion body or
            # reduction lambda — its cost is accounted at the call site.
            continue
        sym = comp.symbols()
        for ins in comp.instructions:
            op_names = [o for o in _operand_names(ins) if o in sym and o != ins.name]
            operands = [sym[o] for o in op_names]
            if ins.opcode in COLLECTIVES:
                ob = sum(_shape_bytes(t) for t in operands) or ins.out_bytes
                stats.collective_bytes[ins.opcode] += m * ob
                stats.n_collectives += 1
                stats.memory_bytes += m * (ins.out_bytes + ob)
                stats.memory_bytes_ideal += m * (ins.out_bytes + ob)
                continue
            if ins.opcode in _SKIP_MEMORY_OPS:
                continue
            if ins.opcode in ("dynamic-slice", "gather"):
                # reads only the slice it produces (plus indices ~ 0)
                stats.memory_bytes += m * 2 * ins.out_bytes
                stats.memory_bytes_ideal += m * 2 * ins.out_bytes
                continue
            if ins.opcode in ("dynamic-update-slice", "scatter"):
                # in-place update: traffic ~ 2x the update operand
                upd = _shape_bytes(operands[1]) if len(operands) > 1 else ins.out_bytes
                stats.memory_bytes += m * 2 * upd
                stats.memory_bytes_ideal += m * 2 * upd
                continue
            if ins.opcode == "fusion":
                bytes_ = m * fusion_traffic(comps, ins, operands)
                stats.memory_bytes += bytes_
                stats.memory_bytes_ideal += bytes_
                continue
            in_bytes = sum(_shape_bytes(t) for t in operands)
            stats.memory_bytes += m * (in_bytes + ins.out_bytes)
            if ins.opcode in _IDEAL_COUNTED:
                stats.memory_bytes_ideal += m * (in_bytes + ins.out_bytes)
            if ins.opcode == "dot":
                out_dims = _shape_dims(ins.type_str) or []
                lhs_t = operands[0] if operands else None
                lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.body)
                if lhs_t and lc:
                    lhs_dims = _shape_dims(lhs_t) or []
                    contract = int(
                        np.prod([lhs_dims[int(i)] for i in lc.group(1).split(",") if i], initial=1)
                    )
                    out_n = int(np.prod(out_dims, initial=1))
                    stats.dot_flops += m * 2.0 * out_n * contract
    return stats
