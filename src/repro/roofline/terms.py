"""Roofline terms for TPU v5e (the TARGET hardware; this host only
compiles).

    compute term    = global_FLOPs / (chips * peak_FLOP/s)
    memory term     = per_device_HBM_bytes / HBM_bw
    collective term = per_device_collective_bytes / link_bw

Sources: global FLOPs from the jaxpr walker (scan-aware; see
jaxpr_flops.py for why cost_analysis() is not usable), per-device bytes
from the post-SPMD compiled HLO (hlo_analysis.py). MODEL_FLOPS = 6*N*D
(dense) or 6*N_active*D (MoE) gives the useful-compute ratio.
"""

from __future__ import annotations

import dataclasses

PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (effective, one link)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    global_flops: float
    per_device_hbm_bytes: float            # fusion-idealized (headline)
    per_device_collective_bytes: float
    collective_breakdown: dict
    model_flops: float
    hlo_dot_flops_per_device: float = 0.0
    per_device_hbm_bytes_raw: float = 0.0  # unfused upper bound

    @property
    def compute_s(self) -> float:
        return self.global_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.per_device_hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.per_device_collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: no overlap (upper bound on the dominant)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.global_flops if self.global_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the dominant-term-limited step achieves on
        *useful* model FLOPs — the headline score."""
        if self.step_time_s <= 0:
            return 0.0
        achieved = self.model_flops / self.step_time_s
        return achieved / (self.chips * PEAK_FLOPS_BF16)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "global_flops": self.global_flops,
            "model_flops": self.model_flops,
            "per_device_hbm_bytes": self.per_device_hbm_bytes,
            "per_device_hbm_bytes_raw": self.per_device_hbm_bytes_raw,
            "memory_s_raw": self.per_device_hbm_bytes_raw / HBM_BW,
            "per_device_collective_bytes": self.per_device_collective_bytes,
            "collective_breakdown": self.collective_breakdown,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "hlo_dot_flops_per_device": self.hlo_dot_flops_per_device,
        }


def model_flops_for(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """6*N*D for training, 2*N*D for inference forward (D = tokens)."""
    n = cfg.n_active_params() if cfg.moe else cfg.n_params()
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * global_batch
