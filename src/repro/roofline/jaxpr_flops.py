"""Analytic FLOP counting by walking the jaxpr.

Why not ``compiled.cost_analysis()``: XLA's cost analysis counts a
``while`` body ONCE regardless of trip count, so any scan-over-layers or
scan-over-time model is undercounted by ~n_layers x (verified in
EXPERIMENTS.md §Roofline methodology). The jaxpr walker recurses into
``scan`` with its static ``length``, into ``pjit``/``remat`` calls, and
counts ``dot_general`` exactly — including the remat-induced recompute
visible in the backward jaxpr.

Matmul FLOPs are the standard 2*M*N*K; elementwise ops are tallied
separately (1 flop/output element) so the dot count stays clean.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax._src import core as jcore


@dataclasses.dataclass
class FlopCount:
    dot_flops: float = 0.0
    elementwise_flops: float = 0.0

    @property
    def total(self) -> float:
        return self.dot_flops + self.elementwise_flops

    def scaled(self, m: float) -> "FlopCount":
        return FlopCount(self.dot_flops * m, self.elementwise_flops * m)

    def __iadd__(self, o: "FlopCount"):
        self.dot_flops += o.dot_flops
        self.elementwise_flops += o.elementwise_flops
        return self


def _dot_flops(eqn) -> float:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs = eqn.invars[0].aval
    batch = float(np.prod([lhs.shape[i] for i in lb], initial=1.0))
    contract = float(np.prod([lhs.shape[i] for i in lc], initial=1.0))
    m = float(
        np.prod(
            [s for i, s in enumerate(lhs.shape) if i not in lc and i not in lb],
            initial=1.0,
        )
    )
    rhs = eqn.invars[1].aval
    n = float(
        np.prod(
            [s for i, s in enumerate(rhs.shape) if i not in rc and i not in rb],
            initial=1.0,
        )
    )
    return 2.0 * batch * m * n * contract


_CALL_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")
_ZERO_COST = {
    "broadcast_in_dim",
    "reshape",
    "transpose",
    "squeeze",
    "slice",
    "concatenate",
    "convert_element_type",
    "dynamic_slice",
    "dynamic_update_slice",
    "gather",
    "scatter",
    "scatter-add",
    "iota",
    "pad",
    "rev",
    "copy",
    "stop_gradient",
    "device_put",
    "sharding_constraint",
    "split",
}


def _out_elems(eqn) -> float:
    return float(
        sum(np.prod(v.aval.shape, initial=1.0) for v in eqn.outvars if hasattr(v.aval, "shape"))
    )


def count_jaxpr(jaxpr: jcore.Jaxpr) -> FlopCount:
    fc = FlopCount()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            fc.dot_flops += _dot_flops(eqn)
        elif name == "scan":
            inner = count_jaxpr(eqn.params["jaxpr"].jaxpr)
            fc += inner.scaled(float(eqn.params["length"]))
        elif name == "while":
            # only used for unbounded loops we never emit; count body once
            fc += count_jaxpr(eqn.params["body_jaxpr"].jaxpr)
        elif name == "cond":
            branches = eqn.params["branches"]
            counts = [count_jaxpr(b.jaxpr) for b in branches]
            best = max(counts, key=lambda c: c.total)
            fc += best
        elif name in ("custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr"):
            for pname in _CALL_JAXPR_PARAMS:
                if pname in eqn.params:
                    inner_j = eqn.params[pname]
                    fc += count_jaxpr(getattr(inner_j, "jaxpr", inner_j))
                    break
        elif any(p in eqn.params for p in _CALL_JAXPR_PARAMS):
            for pname in _CALL_JAXPR_PARAMS:
                if pname in eqn.params:
                    inner_j = eqn.params[pname]
                    fc += count_jaxpr(getattr(inner_j, "jaxpr", inner_j))
                    break
        elif name in _ZERO_COST:
            continue
        else:
            fc.elementwise_flops += _out_elems(eqn)
    return fc


def count_fn_flops(fn, *args, **kwargs) -> FlopCount:
    """FLOPs of ``fn(*args)`` (args may be ShapeDtypeStructs)."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    return count_jaxpr(jaxpr.jaxpr)
