"""Roofline analysis: jaxpr FLOP walker + post-SPMD HLO byte/collective
analysis + v5e roofline terms."""

from .jaxpr_flops import FlopCount, count_fn_flops, count_jaxpr
from .hlo_analysis import HloStats, analyze_hlo, parse_hlo
from .terms import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, RooflineTerms, model_flops_for

__all__ = [
    "FlopCount", "count_fn_flops", "count_jaxpr",
    "HloStats", "analyze_hlo", "parse_hlo",
    "RooflineTerms", "model_flops_for",
    "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW",
]
