"""Traffic attribution: where do the HBM bytes / collective bytes go?

Used by the §Perf hillclimb to find the dominant contributors before
forming a hypothesis. Reuses the hlo_analysis parser; reports per-opcode
totals and the top individual instructions (with their execution
multipliers) under the fusion-idealized model.
"""

from __future__ import annotations

import re
from collections import defaultdict

from .hlo_analysis import (
    COLLECTIVES,
    _IDEAL_COUNTED,
    _SKIP_MEMORY_OPS,
    _multipliers,
    _operand_names,
    _shape_bytes,
    fusion_traffic,
    parse_hlo,
)


def memory_breakdown(text: str, top_n: int = 15) -> dict:
    comps = parse_hlo(text)
    mult = _multipliers(comps)
    by_opcode: dict[str, float] = defaultdict(float)
    items: list[tuple[float, str]] = []
    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None:
            continue
        sym = comp.symbols()
        for ins in comp.instructions:
            op_names = [o for o in _operand_names(ins) if o in sym and o != ins.name]
            operands = [sym[o] for o in op_names]
            bytes_ = 0.0
            if ins.opcode in COLLECTIVES:
                ob = sum(_shape_bytes(t) for t in operands) or ins.out_bytes
                bytes_ = m * (ins.out_bytes + ob)
            elif ins.opcode in _SKIP_MEMORY_OPS:
                continue
            elif ins.opcode in ("dynamic-slice", "gather"):
                bytes_ = m * 2 * ins.out_bytes
            elif ins.opcode in ("dynamic-update-slice", "scatter"):
                upd = _shape_bytes(operands[1]) if len(operands) > 1 else ins.out_bytes
                bytes_ = m * 2 * upd
            elif ins.opcode == "fusion":
                bytes_ = m * fusion_traffic(comps, ins, operands)
            elif ins.opcode in _IDEAL_COUNTED:
                bytes_ = m * (sum(_shape_bytes(t) for t in operands) + ins.out_bytes)
            else:
                continue
            by_opcode[ins.opcode] += bytes_
            items.append((bytes_, f"{comp.name}/{ins.name} x{m:.0f} {ins.opcode} {ins.type_str[:60]}"))
    items.sort(reverse=True)
    return {
        "by_opcode": dict(sorted(by_opcode.items(), key=lambda kv: -kv[1])),
        "top": items[:top_n],
        "total": sum(by_opcode.values()),
    }


def collective_breakdown(text: str, top_n: int = 12) -> dict:
    comps = parse_hlo(text)
    mult = _multipliers(comps)
    items: list[tuple[float, str]] = []
    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None:
            continue
        sym = comp.symbols()
        for ins in comp.instructions:
            if ins.opcode not in COLLECTIVES:
                continue
            op_names = [o for o in _operand_names(ins) if o in sym and o != ins.name]
            ob = sum(_shape_bytes(sym[o]) for o in op_names) or ins.out_bytes
            meta = re.search(r'op_name="([^"]+)"', ins.body)
            items.append(
                (m * ob, f"{ins.opcode} x{m:.0f} {ins.type_str[:40]} :: {(meta.group(1) if meta else '')[:80]}")
            )
    items.sort(reverse=True)
    return {"top": items[:top_n], "total": sum(b for b, _ in items)}
