"""Reliability model of D-Rex (paper §3.1).

Implements:
  * ``pr_failure`` — Eq. (1): probability of a node failing at least once
    over ``delta_t`` (a fraction of a year), given a constant annual
    failure rate ``lambda_rate`` (homogeneous Poisson process).
  * ``poisson_binomial_cdf`` — Eq. (2): probability that at most ``P`` of
    the nodes in a mapping fail, i.e. the Poisson-binomial CDF at ``P``.
    Exact O(N*(P+1)) dynamic-programming convolution plus the refined
    normal approximation (RNA) of Hong (2013), which is what the paper's
    implementation approximates with.
  * ``pr_avail`` — availability of an item with ``P`` parity chunks on a
    mapping, and the reliability constraint check of Eq. (3).

All scalar entry points are numpy/float64 (the online scheduler is
sequential control-plane code); ``batch_pr_avail_exact`` is a vectorized
jnp variant used when scoring many candidate mappings at once.
"""

from __future__ import annotations

import math
from typing import Iterable, Literal, Sequence

import numpy as np

__all__ = [
    "pr_failure",
    "poisson_binomial_cdf",
    "pr_avail",
    "meets_target",
    "batch_pr_avail_exact",
    "max_parity_needed",
    "min_parity_for_target",
    "parity_frontier",
    "rna_parity_frontier",
    "ParityFrontier",
]

_SQRT2PI = math.sqrt(2.0 * math.pi)

# Exact DP is used below this mapping size under method="auto"; RNA above.
_AUTO_EXACT_LIMIT = 64

Method = Literal["exact", "rna", "auto"]


def pr_failure(annual_failure_rate, delta_t_years):
    """Eq. (1): ``1 - exp(-lambda * dt)`` — elementwise on numpy arrays.

    ``annual_failure_rate`` is the Poisson rate per year (the Backblaze
    AFR is treated as this rate, per the paper); ``delta_t_years`` is the
    retention window expressed as a fraction of a year.
    """
    lam = np.asarray(annual_failure_rate, dtype=np.float64)
    dt = np.asarray(delta_t_years, dtype=np.float64)
    if np.any(lam < 0.0):
        raise ValueError("annual failure rate must be >= 0")
    if np.any(dt < 0.0):
        raise ValueError("delta_t must be >= 0")
    return -np.expm1(-lam * dt)


def _exact_cdf(p: np.ndarray, k: int) -> float:
    """Exact Poisson-binomial ``Pr(X <= k)`` via DP over failure probs.

    ``dp[j]`` holds ``Pr(X == j)`` over the prefix of trials processed so
    far, truncated at ``j <= k`` (probability mass above k is not needed
    for the CDF at k). O(N*(k+1)) time, O(k+1) space, stable in float64
    (all terms are nonnegative; no cancellation).
    """
    dp = np.zeros(k + 1, dtype=np.float64)
    dp[0] = 1.0
    for pi in p:
        q = 1.0 - pi
        # dp_new[j] = dp[j]*q + dp[j-1]*pi ; done in-place right-to-left.
        upper = k
        dp[1 : upper + 1] = dp[1 : upper + 1] * q + dp[:upper] * pi
        dp[0] *= q
    return float(min(1.0, dp.sum()))


def _rna_cdf_from_moments(mu: float, sigma: float, gamma: float, k: int) -> float:
    """Hong (2013) eq. 10 probe with the distribution moments precomputed
    — the one place the RNA formula lives (callers: :func:`_rna_cdf` per
    mapping, :func:`rna_parity_frontier` per prefix)."""
    x = (k + 0.5 - mu) / sigma
    phi = math.exp(-0.5 * x * x) / _SQRT2PI
    big_phi = 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))
    val = big_phi + gamma * (1.0 - x * x) * phi / 6.0
    return float(min(1.0, max(0.0, val)))


def _rna_cdf(p: np.ndarray, k: int) -> float:
    """Refined normal approximation (Hong 2013, eq. 10) to Pr(X <= k).

    Adds a skewness correction to the plain CLT approximation; accurate to
    ~1e-3 absolute for the N >= 10 regimes the paper's scheduler explores,
    and monotone enough for threshold checks. Falls back to exact for
    degenerate spreads (sigma == 0).
    """
    mu = float(p.sum())
    var = float((p * (1.0 - p)).sum())
    if var <= 0.0:
        # All-deterministic trials: X == mu exactly.
        return 1.0 if k >= round(mu) else 0.0
    sigma = math.sqrt(var)
    gamma = float((p * (1.0 - p) * (1.0 - 2.0 * p)).sum()) / (sigma**3)
    return _rna_cdf_from_moments(mu, sigma, gamma, k)


def poisson_binomial_cdf(
    fail_probs: Iterable[float], k: int, method: Method = "auto"
) -> float:
    """``Pr(X <= k)`` where ``X = sum Bernoulli(fail_probs_i)`` (Eq. 2)."""
    p = np.asarray(list(fail_probs) if not isinstance(fail_probs, np.ndarray) else fail_probs, dtype=np.float64)
    if p.ndim != 1:
        raise ValueError("fail_probs must be one-dimensional")
    if np.any((p < 0.0) | (p > 1.0)):
        raise ValueError("fail probabilities must lie in [0, 1]")
    n = p.shape[0]
    if k < 0:
        return 0.0
    if k >= n:
        return 1.0
    if method == "exact" or (method == "auto" and n <= _AUTO_EXACT_LIMIT):
        return _exact_cdf(p, k)
    if method in ("rna", "auto"):
        return _rna_cdf(p, k)
    raise ValueError(f"unknown method {method!r}")


def pr_avail(
    node_fail_probs: Iterable[float], parity: int, method: Method = "auto"
) -> float:
    """Availability of an item with ``parity`` parity chunks on a mapping.

    ``node_fail_probs[i]`` is ``pr_failure`` of the i-th node in the
    mapping over the item's retention window. The item survives iff at
    most ``parity`` of the mapped nodes fail.
    """
    return poisson_binomial_cdf(node_fail_probs, parity, method=method)


def meets_target(
    node_fail_probs: Iterable[float],
    parity: int,
    target: float,
    method: Method = "auto",
) -> bool:
    """Reliability constraint (Eq. 3): ``pr_avail >= RT(d)``."""
    return pr_avail(node_fail_probs, parity, method=method) >= target


class ParityFrontier:
    """Incremental Poisson-binomial frontier over a *prefix-structured*
    node sequence: for every prefix length ``n`` of ``fail_probs``, the
    smallest parity ``P`` (in ``[0, n-1]``) whose availability CDF meets
    ``target``, or ``-1`` if no such P exists.

    This is the one DP the prefix-greedy schedulers (GreedyLeastUsed,
    D-Rex LB, D-Rex SC windows) all need: they sort the live nodes once
    and ask "what is the minimum parity for the first ``n`` nodes?" for
    growing ``n``.  The DP state is shared across all prefixes and
    extended lazily, so a scheduler that stops at ``n = 3`` pays
    ``O(3^2)``, not ``O(L^2)`` — and a batch of items with an unchanged
    sort order pays for the DP once (see
    :meth:`repro.core.engine.BatchContext.frontier`).
    """

    __slots__ = ("probs", "target", "_dp", "_n", "_j", "_out")

    def __init__(self, fail_probs, target: float):
        self.probs = np.asarray(fail_probs, dtype=np.float64)
        self.target = float(target)
        self._dp = np.zeros(self.probs.shape[0] + 1, dtype=np.float64)
        self._dp[0] = 1.0
        self._n = 0
        self._j = 0  # unbounded min parity of the current prefix
        self._out = np.full(self.probs.shape[0], -1, dtype=np.int64)

    def __len__(self) -> int:
        return int(self.probs.shape[0])

    def upto(self, n: int) -> np.ndarray:
        """Extend the DP through prefix length ``n``; returns the frontier
        array (entries past ``n`` are only valid once computed)."""
        dp, out, probs, target = self._dp, self._out, self.probs, self.target
        while self._n < n:
            i = self._n
            pi = probs[i]
            dp[1 : i + 2] = dp[1 : i + 2] * (1.0 - pi) + dp[: i + 1] * pi
            dp[0] *= 1.0 - pi
            self._n = i + 1
            # Adding a node can only lower the CDF at fixed P, so the min
            # parity is weakly increasing in the prefix length: resume the
            # scan from the previous prefix's value instead of a cumsum.
            j = self._j
            cdf = float(dp[: j + 1].sum())
            while cdf < target and j <= i:
                j += 1
                cdf += float(dp[j])
            self._j = j
            if j <= i:  # P is capped at n-1 (at least one data chunk)
                out[i] = j
        return out

    def min_parity(self, n: int) -> int:
        """Min parity for the first ``n`` nodes; ``-1`` if infeasible."""
        if n < 1 or n > len(self):
            return -1
        return int(self.upto(n)[n - 1])

    def upto_many(
        self, n_starts: int | None = None, nmax: int | None = None
    ) -> np.ndarray:
        """Batch variant of :meth:`upto` over *suffix starts*.

        ``out[s, m]`` is the smallest parity meeting ``target`` for the
        window ``probs[s : s + m + 1]`` (the length-``m+1`` prefix of the
        suffix starting at ``s``), or ``-1`` when infeasible or out of
        range.  One masked Poisson-binomial DP advances every suffix's
        distribution in lockstep, answering every ``(start,
        window-length)`` pair in ``O(n_starts * L^2)`` instead of one
        fresh DP per start.  This is the numpy reference twin of the
        in-jit DP in :mod:`repro.core.sc_kernel` (D-Rex SC's window
        enumeration): the property tests cross-check it against
        brute-force enumeration and against :meth:`upto`, pinning both
        implementations of the suffix-frontier recurrence.

        ``n_starts`` bounds the suffix starts (default: every start);
        ``nmax`` bounds the window length (default: unbounded).
        """
        L = len(self)
        S = L if n_starts is None else max(0, min(int(n_starts), L))
        W = L if nmax is None else max(0, min(int(nmax), L))
        out = np.full((S, W), -1, dtype=np.int64)
        if S == 0 or W == 0:
            return out
        starts = np.arange(S)
        dp = np.zeros((S, L + 1), dtype=np.float64)
        dp[:, 0] = 1.0
        rows = np.arange(S)
        for i in range(min(L, S - 1 + W)):
            pi = self.probs[i]
            # Window [s..i] exists once i >= s and stays within nmax.
            active = (starts <= i) & (i - starts < W)
            nd = dp * (1.0 - pi)
            nd[:, 1:] += dp[:, :-1] * pi
            dp = np.where(active[:, None], nd, dp)
            cdf = np.cumsum(dp, axis=1)
            feas = cdf >= self.target
            j = np.argmax(feas, axis=1)
            n_len = i - starts + 1
            ok = active & feas.any(axis=1) & (j <= n_len - 1)
            out[rows[ok], (i - starts)[ok]] = j[ok]
        return out


def parity_frontier(sorted_fail_probs, target: float) -> np.ndarray:
    """Vectorized one-pass frontier: ``out[n-1]`` is the min parity for
    the length-``n`` prefix of ``sorted_fail_probs`` (``-1`` infeasible).

    One exact Poisson-binomial DP over the whole sequence answers the
    feasibility question for *every* prefix — the primitive previously
    re-derived inline by GreedyLeastUsed, D-Rex LB and D-Rex SC.
    """
    fr = ParityFrontier(sorted_fail_probs, target)
    return fr.upto(len(fr)).copy()


def min_parity_for_target(
    node_fail_probs: Sequence[float], target: float, method: Method = "auto"
) -> int | None:
    """Smallest ``P`` such that the mapping meets ``target``; None if even
    P = N-1 (i.e. only one chunk must survive) is insufficient.

    Computes the DP once and reads off all CDF values, instead of one DP
    per candidate P — O(N^2) total instead of O(N^3).  (This is the
    whole-sequence special case of :func:`parity_frontier`, kept one-shot
    because non-prefix-structured callers never reuse intermediate
    prefixes.)
    """
    p = np.asarray(node_fail_probs, dtype=np.float64)
    n = p.shape[0]
    if n == 0:
        return None
    if method == "exact" or (method == "auto" and n <= _AUTO_EXACT_LIMIT):
        dp = np.zeros(n + 1, dtype=np.float64)
        dp[0] = 1.0
        for pi in p:
            dp[1:] = dp[1:] * (1.0 - pi) + dp[:-1] * pi
            dp[0] *= 1.0 - pi
        cdf = np.cumsum(dp)
        feas = np.nonzero(cdf[:n] >= target)[0]  # P can be at most n-1
        return int(feas[0]) if feas.size else None
    for parity in range(n):
        if _rna_cdf(p, parity) >= target:
            return parity
    return None


def rna_parity_frontier(
    sorted_fail_probs, target: float, n_lo: int, n_hi: int
) -> np.ndarray:
    """Min parity per prefix length under the RNA regime, moments hoisted.

    ``out[i]`` is the smallest parity whose refined-normal-approximation
    CDF meets ``target`` for the length-``n_lo + i`` prefix of
    ``sorted_fail_probs`` (``-1`` infeasible) — bit-for-bit identical to
    calling :func:`min_parity_for_target` per prefix in its ``auto``
    regime above ``_AUTO_EXACT_LIMIT`` (same elementwise products, same
    pairwise prefix summations, the shared :func:`_rna_cdf_from_moments`
    probe in the same scan order), but the O(n) moment sums are computed
    once per prefix instead of once per parity probe.  This is the
    host-side half of the GreedyMinStorage kernel
    (:mod:`repro.core.greedy_kernel`): XLA transcendentals differ from
    libm in ulps, so the approximation regime stays on the CPU.
    """
    p = np.asarray(sorted_fail_probs, dtype=np.float64)
    w = p * (1.0 - p)
    g = w * (1.0 - 2.0 * p)
    n_lo = max(1, n_lo)
    out = np.full(max(0, n_hi - n_lo + 1), -1, dtype=np.int64)
    for i, n in enumerate(range(n_lo, n_hi + 1)):
        mu = float(p[:n].sum())
        var = float(w[:n].sum())
        if var <= 0.0:
            # All-deterministic trials: X == mu exactly (cf. _rna_cdf).
            for k in range(n):
                if (1.0 if k >= round(mu) else 0.0) >= target:
                    out[i] = k
                    break
            continue
        sigma = math.sqrt(var)
        gamma = float(g[:n].sum()) / (sigma**3)
        for k in range(n):
            if _rna_cdf_from_moments(mu, sigma, gamma, k) >= target:
                out[i] = k
                break
    return out


def max_parity_needed(target: float, worst_fail_prob: float) -> int:
    """Upper bound on parity ever useful: with i.i.d. ``worst_fail_prob``
    nodes, the number of failures concentrates at ``N*p``; beyond
    ``ceil(log(1-target)/log(p))`` extra parity the marginal availability
    gain is below float precision. Used to bound scheduler loops."""
    if worst_fail_prob <= 0.0:
        return 0
    if worst_fail_prob >= 1.0:
        return 10**9
    return max(1, math.ceil(math.log(max(1e-300, 1.0 - target)) / math.log(worst_fail_prob)))


def batch_pr_avail_exact(fail_probs_matrix, parity: int):
    """Vectorized exact Poisson-binomial CDF at ``parity`` for a batch of
    mappings, each row one mapping (rows may be padded with 0.0 — a
    never-failing pseudo-node does not change the distribution's CDF at
    any k since it contributes a deterministic 0).

    Implemented with jnp so callers can jit/vmap it when scoring many
    candidate mappings (D-Rex SC explores up to 2^10).
    """
    import jax.numpy as jnp
    from jax import lax

    pm = jnp.asarray(fail_probs_matrix, dtype=jnp.float64 if _x64() else jnp.float32)
    b, n = pm.shape
    k = min(parity, n)

    def step(dp, p_col):
        # dp: (b, k+1). dp'[j] = dp[j]*(1-p) + dp[j-1]*p
        shifted = jnp.concatenate([jnp.zeros((b, 1), dp.dtype), dp[:, :-1]], axis=1)
        return dp * (1.0 - p_col)[:, None] + shifted * p_col[:, None], None

    dp0 = jnp.zeros((b, k + 1), pm.dtype).at[:, 0].set(1.0)
    dp, _ = lax.scan(step, dp0, pm.T)
    return jnp.minimum(dp.sum(axis=1), 1.0)


def _x64() -> bool:
    import jax

    return bool(jax.config.read("jax_enable_x64"))
