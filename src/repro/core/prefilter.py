"""Top-M candidate pre-filter for the batched placement kernels.

At 10k+ nodes the kernels' cost is dominated by padding and scoring over
*all* N live nodes even though every scheduler's choice rule only ever
reads a short freest-first prefix.  This module centralizes the
pre-filter contract: per batch, the top-M live nodes by the scheduler's
own sort key (free space, descending — the order ``_live_sorted``
already produces) are handed to the kernel and the remaining N-M nodes
are never materialized into kernel inputs, so decision cost scales with
M, not N.

Losslessness is *per scheduler*, proved from the choice rule plus the
parity-frontier monotonicity lemma (min feasible parity is weakly
increasing in freest-first prefix length — ``reliability.ParityFrontier``):

* **D-Rex SC** (``sc_cap``): window enumeration is start-major under a
  fixed candidate budget; whenever L-1 >= budget only windows inside the
  first ``budget + 1`` sorted nodes are enumerated at all, so slicing to
  M >= budget + 1 is *always* exact.  The only full-L dependence —
  the ``1/L`` / ``log L`` saturation scale — is threaded through as the
  true live count (``score_windows_batch(..., n_live=L)``).
* **D-Rex LB**: the (K, P) grid over the top-M prefix finds the same
  smallest feasible P and min-penalty K as the full grid whenever
  ``mp_eff(M) > P_found``, where ``mp_eff(M)`` is the min parity of the
  full M-prefix (the frontier's ``-1`` sentinel means "more parity than
  nodes", i.e. ``mp_eff = M``): monotonicity then makes every window
  wider than M infeasible at P <= P_found, so nothing outside the prefix
  could have been chosen.  Rows failing the test fall back to the
  unfiltered kernel — exactness is unconditional, the filter is purely
  a fast path.
* **GreedyLeastUsed**: the rule takes the *first* feasible N of a
  freest-first scan, so its existing ``SCAN_CAP`` prefix IS the
  pre-filter; a capped scan that finds nothing falls back to the scalar
  oracle over full L.
* **GreedyMinStorage** is *not* prefix-filterable: its objective
  ``(size/K) * N`` can keep improving as N grows (K grows with N), so a
  top-M slice can change the argmin.  It is counted ``bypassed`` and
  always scores unfiltered.

Caps are :mod:`repro.core.shapes` rungs so filtered kernel shapes land
on the same bucketed pads as everything else (no new compile churn).

Process-wide hit-rate telemetry (``stats()``) feeds the ``scale``
benchmark lane's pre-filter columns and is thread-safe, mirroring
``shapes.ShapeBucketer``'s locking discipline.
"""

from __future__ import annotations

import threading

import numpy as np

from . import shapes

__all__ = [
    "sc_cap",
    "lb_cap",
    "domain_slice",
    "record",
    "stats",
    "reset_stats",
    "LB_CAP_DEFAULT",
]

#: Default top-M target for D-Rex LB's filtered grid, rounded up to a
#: shapes rung by :func:`lb_cap`; at or below that many live nodes the
#: filter never engages.
LB_CAP_DEFAULT = 256

_EVENTS = ("engaged", "accepted", "fallback", "bypassed", "promoted")

_lock = threading.Lock()
_counters: dict[str, dict[str, int]] = {}


def sc_cap(budget: int) -> int:
    """Top-M cap sufficient for D-Rex SC's start-major window enumeration
    under ``budget`` candidate mappings (see module docstring): any
    M >= budget + 1 is exact, rounded up to a shapes rung for pad reuse."""
    return shapes.rung(budget + 1)


def lb_cap() -> int:
    """Default top-M cap for D-Rex LB (``LB_CAP_DEFAULT`` rounded up to
    a shapes rung so the filtered grid lands on a bucketed pad)."""
    return shapes.rung(LB_CAP_DEFAULT)


def domain_slice(
    order: np.ndarray,
    rack: np.ndarray,
    zone: np.ndarray,
    m: int,
    constraints,
    scheduler: str | None = None,
) -> np.ndarray:
    """Top-``m`` slice of a sorted candidate order with per-domain
    representatives: the slice keeps at least one node from enough
    distinct racks/zones to meet the spread width of ``constraints``
    (when the full order can), so the top-M pre-filter cannot starve a
    spread constraint into the engine's swap post-pass.

    Greedy and deterministic: first pick the earliest occurrence of each
    of the first ``min(min_racks, m)`` distinct racks (then zones, while
    slots remain), then fill with the earliest unpicked nodes.  The
    result is sorted by original position — a *subsequence* of ``order``,
    so a free-descending input stays free-descending and window/prefix
    capacity logic downstream stays valid.  When the plain ``order[:m]``
    slice already spans enough domains, the result is exactly that slice
    (bit-identical fast path); promotions are counted under the
    ``promoted`` telemetry event.
    """
    order = np.asarray(order)
    length = order.shape[0]
    if length <= m or constraints is None:
        return order
    need_r = min(int(constraints.min_racks), m)
    need_z = min(int(constraints.min_zones), m)
    if need_r <= 1 and need_z <= 1:
        return order[:m]
    picked: list[int] = []          # positions in `order`
    picked_set: set[int] = set()
    for axis, need in ((rack, need_r), (zone, need_z)):
        seen: set[int] = {int(axis[order[pos]]) for pos in picked}
        pos = 0
        while len(seen) < need and pos < length and len(picked) < m:
            d = int(axis[order[pos]])
            if d not in seen:
                seen.add(d)
                if pos not in picked_set:
                    picked.append(pos)
                    picked_set.add(pos)
            pos += 1
    pos = 0
    while len(picked) < m:
        if pos not in picked_set:
            picked.append(pos)
            picked_set.add(pos)
        pos += 1
    picked.sort()
    n_promoted = sum(1 for pos in picked if pos >= m)
    if n_promoted and scheduler is not None:
        record(scheduler, "promoted", n_promoted)
    if not n_promoted:
        return order[:m]
    return order[np.asarray(picked, dtype=np.int64)]


def record(scheduler: str, event: str, n: int = 1) -> None:
    """Count ``n`` items against ``event`` for ``scheduler``.

    Events: ``engaged`` (item scored through the filtered path),
    ``accepted`` (filtered decision provably exact), ``fallback`` (item
    re-scored unfiltered after the sufficiency test failed), ``bypassed``
    (scheduler's rule is not prefix-filterable, or too few nodes)."""
    if event not in _EVENTS:
        raise ValueError(f"unknown prefilter event {event!r}")
    if n <= 0:
        return
    with _lock:
        per = _counters.setdefault(scheduler, dict.fromkeys(_EVENTS, 0))
        per[event] += int(n)


def stats() -> dict[str, dict[str, int]]:
    """Snapshot of per-scheduler counters (copies; safe to mutate)."""
    with _lock:
        return {name: dict(per) for name, per in _counters.items()}


def reset_stats() -> None:
    with _lock:
        _counters.clear()
