"""Repair planning: the one policy for re-placing degraded items.

Before this module existed the repo had two divergent hand-rolled repair
paths (the simulator's chunk rescheduling and the checkpoint manager's
proactive re-encode) that bypassed the placement engine entirely — no
telemetry, no shared reliability-DP kernel, no capability gating.  The
:class:`RepairPlanner` answers the one question both ask: *given an item
whose placement lost chunks, where do the replacements go?*

The policy (matching §5.7 of the paper):

* fewer than K surviving chunks ⇒ the item is unrecoverable;
* replacement targets are the freest live nodes not already involved
  with the item (the dynamic algorithms' house style);
* when the caller requires the reliability target to hold, the new
  mapping must satisfy Eq. 3 — schedulers whose registry entry declares
  ``supports_parity_growth`` may buy extra parity chunks to get there
  (gated by :class:`~repro.core.engine.PlacementEngine`, which combines
  the caller's flag with the scheduler's declared capability);
* feasibility is answered through the shared reliability-DP kernel —
  an optional :class:`~repro.core.engine.BatchContext` memoizes failure
  probabilities and min-parity queries across the repairs of one
  failure event.

The planner is *pure*: it never mutates the cluster view.  Commit (and
rollback of in-flight repairs) is the engine's job, so repair decisions
get the same commit/rollback + telemetry treatment as placements.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .reliability import min_parity_for_target
from .types import ClusterView, DataItem, Placement, PlacementConstraints

__all__ = ["RepairPlan", "RepairPlanner"]


@dataclasses.dataclass(frozen=True)
class RepairPlan:
    """Structured telemetry for one repair decision (the repair-side
    analogue of :class:`~repro.core.engine.PlacementRecord`)."""

    item_id: int
    placement: Optional[Placement]     # full post-repair placement; None => infeasible
    survivors: tuple[int, ...]         # nodes still holding valid chunks
    new_nodes: tuple[int, ...]         # replacement targets, one chunk each
    added_parity: int                  # parity chunks bought on top of the old P
    chunk_mb: float
    candidates_considered: int
    reason: str                        # "" on success
    overhead_s: float = 0.0            # planner wall time (engine fills this in)
    committed: bool = False            # True iff replacement bytes were reserved

    @property
    def ok(self) -> bool:
        return self.placement is not None

    @property
    def repair_mb(self) -> float:
        """Replacement *write* bytes (kept under the historical name —
        the engine's ``repair_mb_committed`` gauge and the simulator's
        ``repaired_mb`` both account committed write bytes)."""
        return self.chunk_mb * len(self.new_nodes)

    @property
    def write_mb(self) -> float:
        """Bytes written onto replacement targets (alias of repair_mb)."""
        return self.repair_mb

    @property
    def read_mb(self) -> float:
        """Reconstruction *read* bytes: decoding the lost chunks streams
        one chunk from each of K survivors.  Zero when nothing needs
        rebuilding (no replacement targets)."""
        if not self.new_nodes or self.placement is None:
            return 0.0
        return self.chunk_mb * self.placement.k

    @property
    def total_traffic_mb(self) -> float:
        """Total repair traffic (survivor reads + replacement writes) —
        the quantity a shared cluster-wide repair budget throttles."""
        return self.read_mb + self.write_mb


class RepairPlanner:
    """Plans degraded-item re-placement against one :class:`ClusterView`."""

    def __init__(self, cluster: ClusterView):
        self.cluster = cluster

    def plan(
        self,
        item: DataItem,
        placement: Placement,
        *,
        chunk_mb: float | None = None,
        survivors: Sequence[int] | None = None,
        allow_parity_growth: bool = False,
        require_target: bool = True,
        ctx=None,
        constraints: Optional[PlacementConstraints] = None,
    ) -> RepairPlan:
        """Plan replacements for ``placement``'s lost chunks.

        ``survivors`` is the set of nodes still holding valid chunks; when
        omitted it is derived from the view's liveness (correct while the
        only invalid chunks are those on currently-dead nodes — callers
        tracking chunk state out of band, e.g. the checkpoint manager or
        in-flight repairs, pass it explicitly).  ``require_target=False``
        skips the reliability-feasibility loop (best-effort repair with
        the old (K, P) kept — the checkpoint plane's mode, where group
        health is reported separately).

        ``constraints`` (failure-domain caps + spread) shape replacement
        selection: a candidate is only taken while it keeps every capped
        domain within its cap *given the surviving chunks*, and while a
        spread width is unmet candidates from unrepresented domains are
        preferred.  Survivors hold data and are never moved, so a
        pre-constraint mapping that already violates a cap keeps its
        violation (repair never makes it worse) — cap-conforming inputs
        stay cap-conforming, which is what the invariant harness pins.
        """
        cluster = self.cluster
        chunk = (
            placement.chunk_size_mb(item.size_mb)
            if chunk_mb is None
            else float(chunk_mb)
        )
        if survivors is None:
            surv = [int(i) for i in placement.node_ids if cluster.alive[i]]
        else:
            surv = [int(i) for i in survivors]
        lost = placement.n - len(surv)

        def infeasible(reason: str, considered: int = 0) -> RepairPlan:
            return RepairPlan(
                item.item_id, None, tuple(surv), (), 0, chunk, considered, reason
            )

        if lost == 0:
            return RepairPlan(
                item.item_id, placement, tuple(surv), (), 0, chunk, 0, ""
            )
        if len(surv) < placement.k:
            return infeasible(
                f"unrecoverable: {len(surv)}/{placement.k} chunks survive"
            )
        # Freest-first replacement candidates; every node of the old
        # mapping is excluded (survivors must not double up, dead nodes
        # are gone, and a node that lost its chunk while staying alive —
        # the checkpoint heal case — held this item once already).
        exclude = set(surv) | {int(i) for i in placement.node_ids}
        candidates = [
            int(i)
            for i in cluster.live_ids()
            if int(i) not in exclude and cluster.free_mb[i] >= chunk
        ]
        candidates.sort(key=lambda i: -cluster.free_mb[i])
        considered = len(candidates)
        if len(candidates) < lost:
            return infeasible(
                f"not enough replacement capacity: need {lost} nodes, "
                f"{len(candidates)} fit",
                considered,
            )
        if constraints is not None and not constraints.unconstrained:
            new_map, remaining = self._select_constrained(
                surv, candidates, lost, placement.n, constraints
            )
            if new_map is None:
                return infeasible(
                    "no replacement satisfies failure-domain constraints",
                    considered,
                )
        else:
            new_map = surv + candidates[:lost]
            remaining = candidates[lost:]
        added = 0
        if require_target:
            # Min-parity feasibility over the candidate mapping; dynamic
            # schedulers may keep buying parity nodes until Eq. 3 holds.
            # The full-N probability table is computed once; growth steps
            # append the single new entry instead of re-slicing O(N).
            fail_probs = self._fail_probs(item.delta_t_days, ctx)
            probs = fail_probs[new_map]
            while True:
                mp = self._min_parity(probs, item.reliability_target, ctx)
                if 0 <= mp <= placement.p + added:
                    break
                if not allow_parity_growth or not remaining:
                    return infeasible(
                        "reliability target unreachable after failure",
                        considered,
                    )
                if constraints is not None and not constraints.unconstrained:
                    nxt = self._pop_admissible(new_map, remaining, constraints)
                    if nxt is None:
                        return infeasible(
                            "reliability target unreachable within "
                            "failure-domain constraints",
                            considered,
                        )
                else:
                    nxt = remaining.pop(0)
                new_map.append(nxt)
                probs = np.append(probs, fail_probs[nxt])
                added += 1
        new_nodes = tuple(n for n in new_map if n not in surv)
        return RepairPlan(
            item.item_id,
            Placement(
                k=placement.k, p=placement.p + added, node_ids=tuple(new_map)
            ),
            tuple(surv),
            new_nodes,
            added,
            chunk,
            considered,
            "",
        )

    # -- failure-domain constraint selection ----------------------------------

    def _admissible(
        self, node: int, chosen: list[int], c: PlacementConstraints
    ) -> bool:
        """Would adding ``node`` keep every capped domain within its cap?"""
        cluster = self.cluster
        for axis, cap in (
            (cluster.rack, c.max_per_rack),
            (cluster.zone, c.max_per_zone),
        ):
            if cap is None:
                continue
            d = int(axis[node])
            if sum(1 for i in chosen if int(axis[i]) == d) + 1 > cap:
                return False
        return True

    def _pop_admissible(
        self, chosen: list[int], remaining: list[int], c: PlacementConstraints
    ) -> Optional[int]:
        for idx, cand in enumerate(remaining):
            if self._admissible(cand, chosen, c):
                return remaining.pop(idx)
        return None

    def _select_constrained(
        self,
        surv: list[int],
        candidates: list[int],
        lost: int,
        n_final: int,
        c: PlacementConstraints,
    ) -> tuple[Optional[list[int]], list[int]]:
        """Freest-first replacement selection under caps, preferring
        unrepresented domains while a spread width is unmet (racks
        first — they nest in zones, so widening racks usually widens
        zones for free)."""
        cluster = self.cluster
        chosen = list(surv)
        pool = list(candidates)
        need_r = min(c.min_racks, n_final)
        need_z = min(c.min_zones, n_final)
        for _ in range(lost):
            racks = {int(cluster.rack[i]) for i in chosen}
            zones = {int(cluster.zone[i]) for i in chosen}
            pick = None
            if len(racks) < need_r:
                pick = next(
                    (
                        cand
                        for cand in pool
                        if int(cluster.rack[cand]) not in racks
                        and self._admissible(cand, chosen, c)
                    ),
                    None,
                )
            if pick is None and len(zones) < need_z:
                pick = next(
                    (
                        cand
                        for cand in pool
                        if int(cluster.zone[cand]) not in zones
                        and self._admissible(cand, chosen, c)
                    ),
                    None,
                )
            if pick is None:
                pick = next(
                    (
                        cand
                        for cand in pool
                        if self._admissible(cand, chosen, c)
                    ),
                    None,
                )
            if pick is None:
                return None, pool
            chosen.append(pick)
            pool.remove(pick)
        return chosen, pool

    # -- shared-kernel shims (context-optional) -------------------------------

    def _fail_probs(self, delta_t_days: float, ctx) -> np.ndarray:
        if ctx is not None:
            return ctx.fail_probs(self.cluster, delta_t_days)
        return self.cluster.fail_probs(delta_t_days)

    @staticmethod
    def _min_parity(probs: np.ndarray, target: float, ctx) -> int:
        if ctx is not None:
            return ctx.min_parity(probs, target)
        mp = min_parity_for_target(probs, target)
        return -1 if mp is None else int(mp)
