"""Jitted jax kernels for the greedy baseline schedulers (paper §4.1-4.2).

``GreedyMinStorage`` and ``GreedyLeastUsed`` are the paper's cheap
baselines, yet after the D-Rex SC kernel landed their scalar loops were
the slowest decision paths at scale (GreedyMinStorage's fixed-point
search: ~180 ms/item at 500 nodes).  Both algorithms score *prefixes of
one sorted node order*, so the same masked-DP tensorization as
:mod:`repro.core.sc_kernel` applies: the per-prefix Poisson-binomial
parity frontier becomes one scan (the jax twin of
:meth:`ParityFrontier.upto_many` restricted to the ``start == 0`` row),
capacity checks become prefix-min tensors, and the whole program is
vmapped over a batch of items sharing a cluster snapshot — which is what
lets ``PlacementEngine.place_many`` drive both schedulers through
``place_batch`` with no engine special-casing.

Two scheduler-specific wrinkles keep the kernels bit-for-bit equivalent
to the scalar numpy oracles (``place_scalar``), which remain the
reference:

* **GreedyMinStorage's RNA regime.**  The scalar path asks
  :func:`min_parity_for_target` with ``method="auto"``: exact DP for
  mappings of at most ``_AUTO_EXACT_LIMIT`` (64) nodes, Hong's refined
  normal approximation above.  The RNA uses libm ``erf``/``exp`` whose
  jnp counterparts differ in ulps, so the kernel computes the exact-DP
  region in-jit and takes the RNA frontiers as a *host-computed input
  tensor* (:func:`rna_frontier_row`, which calls the very same scalar
  code path) — equivalence by construction instead of by reimplementation.

* **GreedyMinStorage's capacity filter.**  The fixed point over K maps
  chunks onto the fastest nodes *among those with room*
  (``free >= size/K``).  While every node of the bw-sorted prefix fits
  (the overwhelmingly common case — checked exactly via a prefix-min),
  the filtered mapping IS the prefix and the fixed point collapses to a
  closed form the kernel evaluates for every N at once.  Rows where the
  filter actually engages (capacity-tight clusters) are flagged ``slow``
  and finished on the host by the same per-N fixed point the scalar
  oracle runs (``GreedyMinStorage._fixed_point_row``); the final
  min-cost selection then merges both row kinds in scalar order.

``GreedyLeastUsed`` needs neither: its frontier is always the exact DP
(:class:`ParityFrontier`) and its mapping is always the free-desc prefix,
so the whole first-feasible-N scan runs in-jit.

D-Rex LB (§4.3) has its own kernel in :mod:`repro.core.lb_kernel`,
which resolves the balance penalty's summation-order problem by fixing
both paths to prefix-sum order and takes its parity frontiers as host
inputs from the oracle's own :class:`ParityFrontier` (the same
equivalence-by-construction move as this module's RNA rows; see that
module's docstring).

Everything runs in float64 under a scoped ``jax.experimental.enable_x64``
(availability targets with many nines need the full mantissa); when jax
is unavailable the callers fall back to the scalar oracles.  Pad
planning goes through :mod:`repro.core.shapes` (shared hysteresis-banded
buckets + compile-cache census).

**Failure-domain constraints.**  Under ``PlacementConstraints`` both
greedy schedulers hand these kernels the cap-admitted subsequence of
their own sorted orders (``core.constraints.constrained_order``;
GreedyLeastUsed's ``SCAN_CAP`` slice additionally keeps per-domain
representatives via ``prefilter.domain_slice``).  Prefixes of an
admitted order are subsets of a cap-conforming set, so the in-kernel
scans are unchanged and greedy admission is WLOG for prefix-greedy
rules: any excluded node is dominated, under the scheduler's sort key,
by the cap's worth of same-domain nodes before it.  Unconstrained calls
pass identical arrays (bit-identical decisions).
"""

from __future__ import annotations

import functools

import numpy as np

from . import shapes
from .reliability import _AUTO_EXACT_LIMIT, rna_parity_frontier

try:  # pragma: no cover - exercised implicitly by every greedy-kernel test
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64

    _JAX_OK = True
except Exception:  # jax is an optional accelerator dependency
    _JAX_OK = False

__all__ = [
    "kernel_available",
    "least_used_batch",
    "min_storage_batch",
    "rna_frontier_row",
]


def kernel_available() -> bool:
    """True when the jitted scoring paths can run (jax importable)."""
    return _JAX_OK


def rna_frontier_row(fail_sorted: np.ndarray, target: float, L: int) -> np.ndarray:
    """Host-side min-parity frontier for prefixes beyond the exact-DP limit.

    ``out[n]`` is the minimum parity for the length-``n`` prefix of
    ``fail_sorted`` (``-1`` infeasible), computed for
    ``n in (_AUTO_EXACT_LIMIT, L]`` exactly as the scalar oracle's
    :func:`min_parity_for_target` would under ``method="auto"`` (Hong's
    RNA with libm transcendentals; see
    :func:`repro.core.reliability.rna_parity_frontier`) — the kernel
    consumes this row verbatim for the approximation regime, keeping
    decisions bit-for-bit equal without reimplementing libm in XLA.
    ``BatchContext.rna_frontier`` memoizes rows across the items and
    commit groups of a batch.
    """
    out = np.full(L + 1, -1, dtype=np.int64)
    if L > _AUTO_EXACT_LIMIT:
        out[_AUTO_EXACT_LIMIT + 1 :] = rna_parity_frontier(
            fail_sorted, target, _AUTO_EXACT_LIMIT + 1, L
        )
    return out


if _JAX_OK:

    def _prefix_frontier(probs, target, L, width, n_steps):
        """Min parity of every prefix of ``probs`` (one masked DP scan).

        Jax twin of ``ParityFrontier.upto_many(n_starts=1)`` — and of the
        exact branch of ``min_parity_for_target`` (full-width DP, cumsum
        CDF, first feasible index): ``out[i]`` is the min parity of the
        length-``i+1`` prefix, ``-1`` where infeasible, valid for steps
        ``i < n_steps``.  ``width`` bounds the tracked parity count (the
        full ``n_steps + 1`` for exactness).
        """

        def step(dp, i):
            p_i = probs[i]
            shifted = jnp.concatenate([jnp.zeros(1, dp.dtype), dp[:-1]])
            new_dp = dp * (1.0 - p_i) + shifted * p_i
            dp = jnp.where(i < L, new_dp, dp)
            cdf = jnp.cumsum(dp)
            feas = cdf >= target
            j = jnp.argmax(feas)
            ok = jnp.any(feas) & (j <= i) & (i < L)
            return dp, jnp.where(ok, j, -1).astype(jnp.int64)

        dp0 = jnp.zeros(width).at[0].set(1.0)
        _, mp = lax.scan(step, dp0, jnp.arange(n_steps))
        return mp  # (n_steps,) indexed by prefix length - 1

    @functools.partial(jax.jit, static_argnums=(0,))
    def _least_used_scores(
        L_pad,
        probs_b,     # (B, L_pad) per-item fail probs in free-desc order
        size_b,      # (B,)
        target_b,    # (B,)
        free,        # (L_pad,) free MB, free-desc order (pad -1)
        L,           # live-node count (traced; padding masked via L)
    ):
        """GreedyLeastUsed (Eq. 5): first N whose exact frontier admits
        ``K = N - max(1, P*) >= 2`` with the chunk fitting the prefix."""
        i_idx = jnp.arange(L_pad)
        n_arr = i_idx + 1

        def one(probs, size, target):
            mp = _prefix_frontier(probs, target, L, L_pad + 1, L_pad)
            p_star = jnp.maximum(1, mp)
            k = n_arr - p_star
            k_safe = jnp.maximum(k, 1)
            chunk = size / k_safe
            feasible = (
                (n_arr >= 2)
                & (n_arr <= L)
                & (mp >= 0)
                & (k >= 2)
                & (free >= chunk)  # free-desc prefix: min free is node N-1
            )
            idx = jnp.argmax(feasible)
            found = jnp.any(feasible)
            return (
                found,
                jnp.where(found, n_arr[idx], 0),
                jnp.where(found, k[idx], 0),
                jnp.where(found, p_star[idx], 0),
            )

        return jax.vmap(one)(probs_b, size_b, target_b)

    @functools.partial(jax.jit, static_argnums=(0, 1))
    def _min_storage_scores(
        L_pad,
        EXACT,       # _AUTO_EXACT_LIMIT (static; mapping-size DP/RNA split)
        probs_b,     # (B, L_pad) per-item fail probs in write-bw-desc order
        size_b,      # (B,)
        target_b,    # (B,)
        rna_b,       # (B, L_pad + 1): host RNA frontier, indexed by N
        free_bw,     # (L_pad,) free MB, write-bw-desc order (pad -1)
        L,
    ):
        """GreedyMinStorage (Eq. 4): evaluate the per-N fixed point over K
        in closed form wherever the bw-sorted prefix fits the chunk.

        Returns per-(item, N) rows — ``valid``/``k``/``p``/``cost`` plus a
        ``slow`` flag for rows whose capacity filter engages (finished on
        the host; see module docstring).  Rows are indexed by ``N - 1``.
        """
        i_idx = jnp.arange(L_pad)
        n_arr = i_idx + 1
        fmin = lax.cummin(jnp.where(i_idx < L, free_bw, jnp.inf))

        def one(probs, size, target, rna):
            mp_exact = _prefix_frontier(
                probs, target, L, min(L_pad, EXACT) + 1, min(L_pad, EXACT)
            )
            mp_exact = jnp.concatenate(
                [mp_exact, jnp.full(L_pad - mp_exact.shape[0], -1, jnp.int64)]
            )
            # Frontier per prefix length N: exact DP for N <= EXACT, the
            # host-computed RNA row above (min_parity_for_target "auto").
            m_hat = jnp.where(n_arr <= EXACT, mp_exact, rna[1:])

            in_range = (n_arr >= 2) & (n_arr <= L)
            chunk0 = size / (n_arr - 1.0)        # first probe: K = N - 1
            fitcnt0 = jnp.sum(
                (free_bw[None, :] >= chunk0[:, None]) & (i_idx[None, :] < L),
                axis=1,
            )
            pfit0 = fmin >= chunk0               # whole prefix fits probe 1
            k1 = n_arr - m_hat                   # second probe: K = N - m_hat
            pfit1 = fmin >= size / jnp.maximum(k1, 1).astype(jnp.float64)

            # Probe 1 accepts immediately when min parity is already <= 1;
            # otherwise the fixed point re-probes at K = N - m_hat, where an
            # unchanged (still-prefix) mapping reproduces m_hat and accepts.
            acc1 = pfit0 & (m_hat >= 0) & (m_hat <= 1)
            deeper = pfit0 & (m_hat >= 2) & (k1 >= 1)
            acc2 = deeper & pfit1
            valid = in_range & (fitcnt0 >= n_arr) & (acc1 | acc2)
            slow = in_range & (fitcnt0 >= n_arr) & (
                (~pfit0) | (deeper & ~pfit1)
            )
            k = jnp.where(acc1, n_arr - 1, k1)
            p = jnp.where(acc1, 1, m_hat)
            cost = jnp.where(
                valid,
                (size / k.astype(jnp.float64)) * n_arr.astype(jnp.float64),
                jnp.inf,
            )
            return valid, slow, k, p, cost

        return jax.vmap(one)(probs_b, size_b, target_b, rna_b)


def _pad_batch(B: int, L: int):
    """Shared hysteresis-banded pads (see :mod:`repro.core.shapes`)."""
    return shapes.batch_pad(B), shapes.node_pad(L)


def _pad_to(a: np.ndarray, size: int, fill: float) -> np.ndarray:
    """``a`` extended to ``size`` with a neutral ``fill`` (shared padding
    idiom of both batch entry points)."""
    out = np.full(size, fill, dtype=np.float64)
    out[: a.shape[0]] = a
    return out


def least_used_batch(
    probs_mat: np.ndarray,   # (B, L) per-item fail probs, free-desc order
    sizes: np.ndarray,       # (B,)
    targets: np.ndarray,     # (B,)
    free_s: np.ndarray,      # (L,) free MB in the same order
):
    """GreedyLeastUsed decisions for a batch sharing one cluster snapshot.

    Returns ``(ok, n, k, p)`` length-B arrays: the first feasible prefix
    length and EC parameters per item (zeros where ``ok`` is False).
    Pure function of its arguments.
    """
    if not _JAX_OK:  # callers are expected to gate on kernel_available()
        raise RuntimeError("jax unavailable; use the scalar oracle path")
    B, L = probs_mat.shape
    if L < 2 or B == 0:
        z = np.zeros(B, dtype=np.int64)
        return z.astype(bool), z, z, z
    B_pad, L_pad = _pad_batch(B, L)
    shapes.record_compile("least_used_kernel", (B_pad, L_pad))
    pm = np.zeros((B_pad, L_pad), dtype=np.float64)
    pm[:B, :L] = probs_mat
    with enable_x64():
        ok, n, k, p = _least_used_scores(
            L_pad,
            jnp.asarray(pm),
            jnp.asarray(_pad_to(sizes, B_pad, 1.0)),
            jnp.asarray(_pad_to(targets, B_pad, 0.5)),
            jnp.asarray(_pad_to(free_s, L_pad, -1.0)),
            np.int64(L),
        )
    return (
        np.asarray(ok)[:B],
        np.asarray(n, dtype=np.int64)[:B],
        np.asarray(k, dtype=np.int64)[:B],
        np.asarray(p, dtype=np.int64)[:B],
    )


def min_storage_batch(
    probs_mat: np.ndarray,   # (B, L) per-item fail probs, write-bw-desc order
    sizes: np.ndarray,       # (B,)
    targets: np.ndarray,     # (B,)
    rna_rows: np.ndarray,    # (B, L + 1) host RNA frontier rows (by N)
    free_bw: np.ndarray,     # (L,) free MB in the same order
):
    """Per-(item, N) GreedyMinStorage scores for a batch sharing one
    cluster snapshot.

    Returns ``(valid, slow, k, p, cost)`` arrays of shape ``(B, L)`` with
    rows indexed by ``N - 1``; the caller finishes ``slow`` rows with the
    scalar fixed point and takes the min-cost row in ascending-N order
    (matching the oracle's strict-less tie-breaking).  Pure function.
    """
    if not _JAX_OK:
        raise RuntimeError("jax unavailable; use the scalar oracle path")
    B, L = probs_mat.shape
    if L < 2 or B == 0:
        shape = (B, max(L, 0))
        return (
            np.zeros(shape, dtype=bool),
            np.zeros(shape, dtype=bool),
            np.zeros(shape, dtype=np.int64),
            np.zeros(shape, dtype=np.int64),
            np.full(shape, np.inf),
        )
    B_pad, L_pad = _pad_batch(B, L)
    shapes.record_compile("min_storage_kernel", (B_pad, L_pad))
    pm = np.zeros((B_pad, L_pad), dtype=np.float64)
    pm[:B, :L] = probs_mat
    rna = np.full((B_pad, L_pad + 1), -1, dtype=np.int64)
    rna[:B, : L + 1] = rna_rows
    with enable_x64():
        valid, slow, k, p, cost = _min_storage_scores(
            L_pad,
            int(_AUTO_EXACT_LIMIT),
            jnp.asarray(pm),
            jnp.asarray(_pad_to(sizes, B_pad, 1.0)),
            jnp.asarray(_pad_to(targets, B_pad, 0.5)),
            jnp.asarray(rna),
            jnp.asarray(_pad_to(free_bw, L_pad, -1.0)),
            np.int64(L),
        )
    return (
        np.asarray(valid)[:B, :L],
        np.asarray(slow)[:B, :L],
        np.asarray(k, dtype=np.int64)[:B, :L],
        np.asarray(p, dtype=np.int64)[:B, :L],
        np.asarray(cost, dtype=np.float64)[:B, :L],
    )
