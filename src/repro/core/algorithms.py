"""The four D-Rex schedulers (paper §4) and the SOTA baselines (§5.2).

Every scheduler answers, for one item ``d`` arriving online, the question
of Problem 1: choose ``(K_d, P_d, M_d)`` subject to the reliability
constraint (Eq. 3) and per-node capacity, optimizing storage and I/O.

All schedulers see the cluster through :class:`repro.core.types.ClusterView`
and are purely functional over it (the caller commits the placement).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Optional, Sequence

import numpy as np

from .reliability import min_parity_for_target
from .types import ClusterView, DataItem, Decision, ECTimeModel, Placement

__all__ = [
    "Scheduler",
    "GreedyMinStorage",
    "GreedyLeastUsed",
    "DRexLB",
    "DRexSC",
    "StaticEC",
    "DAOSAdaptive",
    "RandomSpread",
    "make_scheduler",
    "SCHEDULER_NAMES",
]


class Scheduler:
    """Base interface. ``place`` must not mutate ``cluster``."""

    name: str = "base"
    #: smallest item size seen so far (MB); simulator keeps this fresh.
    smin_mb: float = 1.0

    def place(self, item: DataItem, cluster: ClusterView) -> Decision:
        raise NotImplementedError

    def observe_item(self, item: DataItem) -> None:
        """Track the smallest item size (used by the SC saturation curve)."""
        if item.size_mb > 0:
            self.smin_mb = min(self.smin_mb, item.size_mb)

    # -- shared helpers -----------------------------------------------------

    @staticmethod
    def _live_sorted(cluster: ClusterView, key: np.ndarray, descending=True):
        """Live node ids sorted by ``key`` (stable, deterministic)."""
        ids = cluster.live_ids()
        order = np.argsort(-key[ids] if descending else key[ids], kind="stable")
        return ids[order]

    @staticmethod
    def _fits(cluster: ClusterView, node_ids, chunk_mb: float) -> bool:
        free = cluster.free_mb[np.asarray(node_ids)]
        return bool(np.all(free >= chunk_mb))


# ---------------------------------------------------------------------------
# §4.1 GreedyMinStorage
# ---------------------------------------------------------------------------


class GreedyMinStorage(Scheduler):
    """Minimize per-item storage footprint ``(size/K) * N`` s.t. reliability
    (Eq. 4); mapping favors the fastest (write-bandwidth) nodes *among
    those with room for the chunk* — once the fast nodes saturate the
    selection slides to slower ones instead of failing (the paper's §5.4
    observation that GreedyMinStorage keeps utilizing all nodes)."""

    name = "greedy_min_storage"

    def place(self, item: DataItem, cluster: ClusterView) -> Decision:
        self.observe_item(item)
        by_bw = self._live_sorted(cluster, cluster.write_bw)
        L = len(by_bw)
        if L < 2:
            return Decision(None, 0, "fewer than 2 live nodes")
        fail_all = cluster.fail_probs(item.delta_t_days)
        free = cluster.free_mb

        best: Optional[Placement] = None
        best_cost = math.inf
        considered = 0
        for n in range(2, L + 1):
            considered += 1
            # Fixed point over K: the chunk size determines which nodes
            # qualify (free >= chunk), which determines the mapping, which
            # determines the min parity, which determines K. K only ever
            # decreases, so this terminates in <= N steps (typically 1-2).
            k = n - 1
            placement = None
            while k >= 1:
                chunk = item.size_mb / k
                fitting = by_bw[free[by_bw] >= chunk]
                if len(fitting) < n:
                    break
                mapping = fitting[:n]
                mp = min_parity_for_target(
                    fail_all[mapping], item.reliability_target
                )
                if mp is None:
                    break
                p_star = max(1, mp)  # the repository always keeps parity
                k_new = n - p_star
                if k_new < 1:
                    break
                if k_new >= k:
                    placement = Placement(
                        k=k, p=n - k, node_ids=tuple(int(x) for x in mapping)
                    )
                    break
                k = k_new
            if placement is None:
                continue
            cost = (item.size_mb / placement.k) * n
            if cost < best_cost:
                best_cost = cost
                best = placement
        if best is None:
            return Decision(None, considered, "no (N,K) satisfies reliability+capacity")
        return Decision(best, considered, "")


# ---------------------------------------------------------------------------
# §4.2 GreedyLeastUsed
# ---------------------------------------------------------------------------


class GreedyLeastUsed(Scheduler):
    """Minimize ``K+P`` s.t. reliability (Eq. 5); nodes with the highest
    free space get the chunks (then minimal parity among feasible).
    ``K >= 2`` as in Alg. 1 — the paper's erasure-coding schedulers do not
    degenerate to replication (only DAOS's explicit replication configs do).
    """

    name = "greedy_least_used"

    def place(self, item: DataItem, cluster: ClusterView) -> Decision:
        self.observe_item(item)
        by_free = self._live_sorted(cluster, cluster.free_mb)
        L = len(by_free)
        if L < 2:
            return Decision(None, 0, "fewer than 2 live nodes")
        fail_all = cluster.fail_probs(item.delta_t_days)

        considered = 0
        dp = np.zeros(L + 1, dtype=np.float64)
        dp[0] = 1.0
        for n_idx in range(L):
            pi = fail_all[by_free[n_idx]]
            dp[1 : n_idx + 2] = dp[1 : n_idx + 2] * (1.0 - pi) + dp[: n_idx + 1] * pi
            dp[0] *= 1.0 - pi
            n = n_idx + 1
            if n < 2:
                continue
            considered += 1
            cdf = np.cumsum(dp[: n + 1])
            feas = np.nonzero(cdf[:n] >= item.reliability_target)[0]
            if feas.size == 0:
                continue
            p_star = max(1, int(feas[0]))  # the repository always keeps parity
            k = n - p_star
            if k < 2:
                continue
            chunk = item.size_mb / k
            mapping = by_free[:n]
            if not self._fits(cluster, mapping, chunk):
                continue
            return Decision(
                Placement(k=k, p=p_star, node_ids=tuple(int(x) for x in mapping)),
                considered,
                "",
            )
        return Decision(None, considered, "no N satisfies reliability+capacity")


# ---------------------------------------------------------------------------
# §4.3 D-Rex LB (Algorithm 1)
# ---------------------------------------------------------------------------


class DRexLB(Scheduler):
    """Balance-penalty minimization; smallest feasible parity (Alg. 1)."""

    name = "drex_lb"

    def place(self, item: DataItem, cluster: ClusterView) -> Decision:
        self.observe_item(item)
        by_free = self._live_sorted(cluster, cluster.free_mb)
        L = len(by_free)
        if L < 3:  # Alg. 1 needs K>=2 and P>=1
            return Decision(None, 0, "fewer than 3 live nodes")
        fail_all = cluster.fail_probs(item.delta_t_days)
        free = cluster.free_mb
        f_avg = float(free[by_free].mean())  # line 1
        # |F(S_j) - F_avg| for every node once; penalties for out-of-mapping
        # nodes are suffix sums over the sorted order (mapping is a prefix).
        dev = np.abs(free[by_free] - f_avg)
        suffix = np.concatenate([np.cumsum(dev[::-1])[::-1], [0.0]])

        considered = 0
        for p in range(1, L):  # line 5
            min_bp = math.inf
            min_k = -1
            # Incremental DP over the prefix (mapping = first K+P nodes).
            dp = np.zeros(L + 1, dtype=np.float64)
            dp[0] = 1.0
            # preload first (2 + p - 1) nodes minus one; we advance as K grows
            n_loaded = 0
            for k in range(2, L - p + 1):  # line 6
                n = k + p
                while n_loaded < n:
                    pi = fail_all[by_free[n_loaded]]
                    dp[1 : n_loaded + 2] = (
                        dp[1 : n_loaded + 2] * (1.0 - pi) + dp[: n_loaded + 1] * pi
                    )
                    dp[0] *= 1.0 - pi
                    n_loaded += 1
                considered += 1
                avail = float(np.minimum(np.cumsum(dp[: n + 1]), 1.0)[p])
                if avail < item.reliability_target:
                    continue
                chunk = item.size_mb / k
                mapping = by_free[:n]
                if not self._fits(cluster, mapping, chunk):
                    continue
                # lines 10-15: balance penalty
                bp = float(np.abs(free[mapping] - chunk - f_avg).sum()) + float(
                    suffix[n]
                )
                if bp < min_bp:
                    min_bp = bp
                    min_k = k
            if min_k != -1:  # line 22: stop at the smallest feasible P
                n = min_k + p
                return Decision(
                    Placement(
                        k=min_k, p=p, node_ids=tuple(int(x) for x in by_free[:n])
                    ),
                    considered,
                    "",
                )
        return Decision(None, considered, "no (K,P) satisfies reliability+capacity")


# ---------------------------------------------------------------------------
# §4.4 D-Rex SC (Algorithm 2)
# ---------------------------------------------------------------------------


def saturation_score(projected_used_mb, capacity_mb, smin_mb, n_nodes: int = 10):
    """Exponential saturation score (paper Fig. 3 / Alg. 2 line 11).

    The curve is the exponential through the two anchors the paper's
    formula names: ``(smallest known data item size, 1/L)`` and
    ``(total storage capacity, 1)``, evaluated at the projected *used*
    bytes ``x``:

        f(x) = (1/L) * exp( ln(L) * (x - s_min) / (cap - s_min) )

    i.e. an empty node scores ~1/L and a full node scores 1, rising
    exponentially as the node approaches its limit ("penalize nodes
    approaching their limit", §4.4). Elementwise on numpy arrays; clipped
    to [0, 1].
    """
    cap = np.asarray(capacity_mb, dtype=np.float64)
    x = np.asarray(projected_used_mb, dtype=np.float64)
    span = np.maximum(cap - smin_mb, 1e-9)
    u = np.clip((x - smin_mb) / span, 0.0, 1.0)
    inv_l = 1.0 / max(2, n_nodes)
    return np.clip(inv_l * np.exp(math.log(max(2, n_nodes)) * u), 0.0, 1.0)


@dataclasses.dataclass
class _Candidate:
    k: int
    p: int
    node_ids: tuple
    duration: float
    storage: float
    saturation: float


class DRexSC(Scheduler):
    """System-capacity-aware scheduler (Alg. 2): Pareto front over
    {duration, storage, saturation} with saturation-weighted scoring."""

    name = "drex_sc"
    MAX_MAPPINGS = 2**10

    def __init__(self, time_model: ECTimeModel | None = None):
        self.time_model = time_model or ECTimeModel()

    def place(self, item: DataItem, cluster: ClusterView) -> Decision:
        self.observe_item(item)
        by_free = self._live_sorted(cluster, cluster.free_mb)  # line 1
        L = len(by_free)
        if L < 2:
            return Decision(None, 0, "fewer than 2 live nodes")
        fail_all = cluster.fail_probs(item.delta_t_days)
        free = cluster.free_mb
        cap = cluster.capacity_mb
        used = cluster.used_mb
        smin = self.smin_mb
        live = cluster.live_ids()
        # Saturation baseline over every live node; candidates add only the
        # delta of their mapped nodes (+chunk), so — like D-Rex LB's
        # balance penalty — unmapped nodes still participate and wide,
        # shallow placements are rewarded for not pushing any node toward
        # its limit.
        f_base = saturation_score(used[live], cap[live], smin, L)
        f_base_sum = float(f_base.sum())

        candidates: list[_Candidate] = []
        considered = 0
        # line 2: first 2^10 contiguous windows of the sorted order, windows
        # expanding from each start: [0:2],[0:3],...,[0:L],[1:3],...
        n_windows = 0
        for s in range(L - 1):
            if n_windows >= self.MAX_MAPPINGS:
                break
            dp = np.zeros(L + 1, dtype=np.float64)
            dp[0] = 1.0
            n_loaded = 0
            for e in range(s + 2, L + 1):
                if n_windows >= self.MAX_MAPPINGS:
                    break
                n_windows += 1
                while n_loaded < e - s:
                    pi = fail_all[by_free[s + n_loaded]]
                    dp[1 : n_loaded + 2] = (
                        dp[1 : n_loaded + 2] * (1.0 - pi) + dp[: n_loaded + 1] * pi
                    )
                    dp[0] *= 1.0 - pi
                    n_loaded += 1
                n = e - s
                considered += 1
                cdf = np.minimum(np.cumsum(dp[: n + 1]), 1.0)
                feas = np.nonzero(cdf[:n] >= item.reliability_target)[0]
                if feas.size == 0:
                    continue
                p_star = max(1, int(feas[0]))  # line 4: min storage == max K
                k = n - p_star
                if k < 1:
                    continue
                chunk = item.size_mb / k
                mapping = by_free[s:e]
                if not self._fits(cluster, mapping, chunk):
                    continue
                tm = self.time_model
                duration = (
                    chunk / float(cluster.write_bw[mapping].min())
                    + chunk / float(cluster.read_bw[mapping].min())
                    + tm.t_encode(n, k, item.size_mb)
                    + tm.t_decode(k, item.size_mb)
                )  # line 6
                storage = chunk * n  # line 7
                sat = f_base_sum + float(
                    (
                        saturation_score(used[mapping] + chunk, cap[mapping], smin, L)
                        - saturation_score(used[mapping], cap[mapping], smin, L)
                    ).sum()
                )  # line 8
                candidates.append(
                    _Candidate(k, p_star, tuple(int(x) for x in mapping), duration, storage, sat)
                )
        if not candidates:
            return Decision(None, considered, "no mapping satisfies reliability+capacity")

        # line 11: system saturation over the whole repository.
        sys_sat = float(
            saturation_score(
                np.array([used[live].sum()]), np.array([cap[live].sum()]), smin, L
            )[0]
        )

        front = _pareto_front(candidates)
        d = np.array([c.duration for c in front])
        st = np.array([c.storage for c in front])
        sa = np.array([c.saturation for c in front])
        dur_prog = _progress(d)
        sto_prog = _progress(st)
        sat_prog = _progress(sa)
        score = (1.0 - sys_sat) * dur_prog + (sto_prog + sat_prog) / 2.0  # line 17
        best = front[int(np.argmax(score))]
        return Decision(
            Placement(k=best.k, p=best.p, node_ids=best.node_ids), considered, ""
        )


def _progress(vals: np.ndarray) -> np.ndarray:
    """Relative progress (line 16): 1 at the min, 0 at the max; all-equal
    candidates make no progress relative to each other."""
    lo, hi = float(vals.min()), float(vals.max())
    if hi - lo <= 1e-12:
        return np.zeros_like(vals)
    return (hi - vals) / (hi - lo)


def _pareto_front(cands: Sequence[_Candidate]) -> list[_Candidate]:
    """Minimizing front over (duration, storage, saturation); O(n^2) with
    n <= 1024 candidate mappings."""
    arr = np.array([[c.duration, c.storage, c.saturation] for c in cands])
    n = arr.shape[0]
    keep = np.ones(n, dtype=bool)
    for i in range(n):
        # i is dominated iff some j is <= on every objective and < on one.
        dominates_i = np.all(arr <= arr[i], axis=1) & np.any(arr < arr[i], axis=1)
        if np.any(dominates_i):
            keep[i] = False
    front = [c for c, k in zip(cands, keep) if k]
    return front if front else list(cands)


# ---------------------------------------------------------------------------
# §5.2.1 Static erasure coding (HDFS EC(3,2)/EC(6,3), Gluster EC(4,2))
# ---------------------------------------------------------------------------


class StaticEC(Scheduler):
    """Algorithm 3: fixed (K, P); first K+P fitting nodes by write BW."""

    def __init__(self, k: int, p: int):
        self.k = k
        self.p = p
        self.name = f"ec({k},{p})"

    def place(self, item: DataItem, cluster: ClusterView) -> Decision:
        self.observe_item(item)
        by_bw = self._live_sorted(cluster, cluster.write_bw)  # line 2
        n = self.k + self.p
        chunk = item.size_mb / self.k
        fitting = [int(i) for i in by_bw if cluster.free_mb[i] >= chunk]
        if len(fitting) < n:
            return Decision(None, 1, "not enough nodes with capacity")
        mapping = tuple(fitting[:n])
        fail = cluster.fail_probs(item.delta_t_days)[list(mapping)]
        mp = min_parity_for_target(fail, item.reliability_target)
        if mp is None or mp > self.p:
            return Decision(None, 1, "fixed (K,P) cannot meet reliability target")
        return Decision(Placement(k=self.k, p=self.p, node_ids=mapping), 1, "")


# ---------------------------------------------------------------------------
# §5.2.2 DAOS: EC configs + replication, least storage overhead meeting RT
# ---------------------------------------------------------------------------


class DAOSAdaptive(Scheduler):
    """Pick, among DAOS's predefined configs, the one meeting the
    reliability target with the lowest storage overhead (paper §5.2.2).

    Replication 2x/4x/6x is modeled in the erasure-coded representation as
    K=1 with P = copies-1 (paper §3.1)."""

    name = "daos"
    # (K, P), ordered by storage overhead N/K ascending:
    CONFIGS = [(8, 1), (8, 2), (4, 1), (4, 2), (1, 1), (1, 3), (1, 5)]

    def place(self, item: DataItem, cluster: ClusterView) -> Decision:
        self.observe_item(item)
        by_bw = self._live_sorted(cluster, cluster.write_bw)
        fail_all = cluster.fail_probs(item.delta_t_days)
        considered = 0
        for k, p in sorted(self.CONFIGS, key=lambda kp: (kp[0] + kp[1]) / kp[0]):
            considered += 1
            n = k + p
            chunk = item.size_mb / k
            fitting = [int(i) for i in by_bw if cluster.free_mb[i] >= chunk]
            if len(fitting) < n:
                continue
            mapping = tuple(fitting[:n])
            mp = min_parity_for_target(fail_all[list(mapping)], item.reliability_target)
            if mp is None or mp > p:
                continue
            return Decision(Placement(k=k, p=p, node_ids=mapping), considered, "")
        return Decision(None, considered, "no DAOS config meets target")


# ---------------------------------------------------------------------------
# Extra baseline (ours): uniform random spread — ablation control
# ---------------------------------------------------------------------------


class RandomSpread(Scheduler):
    """Uniformly random feasible mapping with HDFS-style EC(6,3); control
    baseline for ablations (not in the paper)."""

    name = "random_spread"

    def __init__(self, k: int = 6, p: int = 3, seed: int = 0):
        self.k, self.p = k, p
        self.rng = np.random.default_rng(seed)

    def place(self, item: DataItem, cluster: ClusterView) -> Decision:
        self.observe_item(item)
        n = self.k + self.p
        chunk = item.size_mb / self.k
        ids = [int(i) for i in cluster.live_ids() if cluster.free_mb[i] >= chunk]
        if len(ids) < n:
            return Decision(None, 1, "not enough nodes with capacity")
        mapping = tuple(int(x) for x in self.rng.choice(ids, size=n, replace=False))
        fail = cluster.fail_probs(item.delta_t_days)[list(mapping)]
        mp = min_parity_for_target(fail, item.reliability_target)
        if mp is None or mp > self.p:
            return Decision(None, 1, "fixed (K,P) cannot meet reliability target")
        return Decision(Placement(k=self.k, p=self.p, node_ids=mapping), 1, "")


# ---------------------------------------------------------------------------


SCHEDULER_NAMES = [
    "drex_sc",
    "drex_lb",
    "greedy_min_storage",
    "greedy_least_used",
    "ec(3,2)",
    "ec(4,2)",
    "ec(6,3)",
    "daos",
    "random_spread",
]


def make_scheduler(name: str, **kwargs) -> Scheduler:
    """Factory over every algorithm in the paper (+ controls)."""
    name = name.lower()
    if name == "greedy_min_storage":
        return GreedyMinStorage()
    if name == "greedy_least_used":
        return GreedyLeastUsed()
    if name == "drex_lb":
        return DRexLB()
    if name == "drex_sc":
        return DRexSC(**kwargs)
    if name.startswith("ec(") and name.endswith(")"):
        k, p = (int(x) for x in name[3:-1].split(","))
        return StaticEC(k, p)
    if name == "daos":
        return DAOSAdaptive()
    if name == "random_spread":
        return RandomSpread(**kwargs)
    raise ValueError(f"unknown scheduler {name!r}; known: {SCHEDULER_NAMES}")
