"""The four D-Rex schedulers (paper §4) and the SOTA baselines (§5.2).

Every scheduler answers, for one item ``d`` arriving online, the question
of Problem 1: choose ``(K_d, P_d, M_d)`` subject to the reliability
constraint (Eq. 3) and per-node capacity, optimizing storage and I/O.

All schedulers see the cluster through :class:`repro.core.types.ClusterView`
and are purely functional over it (the caller — normally a
:class:`repro.core.engine.PlacementEngine` — commits the placement).
Each algorithm registers itself with :mod:`repro.core.registry`, declaring
its capabilities (adaptive (K,P)?, may grow parity on reschedule?) so the
simulator and checkpoint plane never match on name strings.

The reliability feasibility question every prefix-greedy algorithm asks
("min parity for the first n nodes of my sorted order?") is answered by
one shared :class:`repro.core.reliability.ParityFrontier` DP; under
batched placement (``PlacementEngine.place_many``) the optional ``ctx``
argument memoizes frontiers across items so the DP cost amortizes.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from . import constraints as constraints_mod
from . import greedy_kernel, lb_kernel, prefilter, sc_kernel
from .incremental import FreeOrderTracker, SaturationTracker
from .registry import (
    get_spec,
    register_scheduler,
    register_scheduler_family,
    SchedulerCapabilities,
)
from .reliability import _AUTO_EXACT_LIMIT, min_parity_for_target, ParityFrontier
from .types import (
    ClusterView,
    DataItem,
    Decision,
    ECTimeModel,
    Placement,
    PlacementConstraints,
)

__all__ = [
    "Scheduler",
    "GreedyMinStorage",
    "GreedyLeastUsed",
    "DRexLB",
    "DRexSC",
    "StaticEC",
    "DAOSAdaptive",
    "RandomSpread",
    "SCHEDULER_NAMES",
]


class Scheduler:
    """Base interface. ``place`` must not mutate ``cluster``.

    ``ctx`` is an optional :class:`repro.core.engine.BatchContext`; when
    provided, pure derived quantities (failure probabilities per
    retention window, parity frontiers per sorted node sequence) are
    memoized across the items of a batch.  Results are bit-identical with
    and without a context — the cache keys on the exact inputs of each
    computation.
    """

    name: str = "base"
    #: capability record; overwritten by the registry decorator.
    capabilities: SchedulerCapabilities = SchedulerCapabilities()
    #: smallest item size seen so far (MB); None until the first item is
    #: observed.  Seeded from the first item rather than a fixed 1 MB
    #: prior: traces whose smallest item exceeds 1 MB would otherwise
    #: never move the anchor, skewing the SC saturation curve's
    #: (s_min, 1/L) endpoint (§4.4).
    smin_mb: Optional[float] = None

    def place(
        self, item: DataItem, cluster: ClusterView, ctx=None
    ) -> Decision:
        raise NotImplementedError

    def observe_item(self, item: DataItem) -> None:
        """Track the smallest item size (used by the SC saturation curve)."""
        if item.size_mb > 0:
            smin = self.smin_mb
            self.smin_mb = (
                item.size_mb if smin is None else min(smin, item.size_mb)
            )

    # -- shared helpers -----------------------------------------------------

    @staticmethod
    def _live_sorted(cluster: ClusterView, key: np.ndarray, descending=True):
        """Live node ids sorted by ``key`` (stable, deterministic)."""
        ids = cluster.live_ids()
        order = np.argsort(-key[ids] if descending else key[ids], kind="stable")
        return ids[order]

    @staticmethod
    def _apply_constraints(
        order: np.ndarray,
        cluster: ClusterView,
        constraints: Optional[PlacementConstraints],
    ) -> np.ndarray:
        """Cap-admitted subsequence of a sorted candidate order (see
        ``core.constraints.constrained_order``).  Identity — same array
        object — when no constraints are given, so the unconstrained
        path stays bit-identical.  ``topology_aware`` schedulers call
        this on their own order before any slicing: every mapping they
        emit is then a subset of a cap-conforming set, so the per-domain
        caps hold by construction and only spread width is left to the
        engine's swap post-pass."""
        if constraints is None or constraints.unconstrained:
            return order
        return constraints_mod.constrained_order(
            order, cluster.rack, cluster.zone, constraints
        )

    @staticmethod
    def _fits(cluster: ClusterView, node_ids, chunk_mb: float) -> bool:
        free = cluster.free_mb[np.asarray(node_ids)]
        return bool(np.all(free >= chunk_mb))

    @staticmethod
    def _fail_probs(cluster: ClusterView, item: DataItem, ctx) -> np.ndarray:
        if ctx is not None:
            return ctx.fail_probs(cluster, item.delta_t_days)
        return cluster.fail_probs(item.delta_t_days)

    @staticmethod
    def _frontier(probs: np.ndarray, target: float, ctx) -> ParityFrontier:
        if ctx is not None:
            return ctx.frontier(probs, target)
        return ParityFrontier(probs, target)

    @staticmethod
    def _min_parity(probs: np.ndarray, target: float, ctx) -> int:
        """Min parity for an arbitrary (non-prefix) mapping; -1 infeasible."""
        if ctx is not None:
            return ctx.min_parity(probs, target)
        mp = min_parity_for_target(probs, target)
        return -1 if mp is None else mp


def _kernel_dispatch(
    scheduler, kernel_ok: bool, cluster: ClusterView, batch: int
) -> bool:
    """The one kernel/scalar dispatch rule for kernel-backed schedulers:
    a single item needs at least ``KERNEL_MIN_NODES`` live nodes for the
    kernel to beat numpy dispatch; batches of >= 4 items amortize
    dispatch and need only ``KERNEL_MIN_NODES_BATCH`` (0 for most
    schedulers — GreedyLeastUsed's scalar scan is so cheap its kernel
    only wins batched on large clusters).  Setting both to 0 forces the
    kernel everywhere (the equivalence tests do).  Boundary pinned by
    tests/test_kernel_dispatch_boundary.py."""
    if not (scheduler.use_kernel and kernel_ok):
        return False
    live = int(np.count_nonzero(cluster.alive))
    if batch >= 4:
        return live >= scheduler.KERNEL_MIN_NODES_BATCH
    return live >= scheduler.KERNEL_MIN_NODES


class _KernelSchedulerMixin:
    """Kernel/scalar dispatch shared by the kernel-backed prefix
    schedulers (the greedys on :mod:`repro.core.greedy_kernel`, D-Rex LB
    on :mod:`repro.core.lb_kernel`).  Concrete classes set
    ``KERNEL_MODULE`` and provide the scalar oracle (``_place_scalar``),
    the batched kernel path (``_place_kernel``) and the
    ``KERNEL_MIN_NODES`` crossover."""

    #: set to False to force the scalar numpy oracle even when jax is
    #: present.
    use_kernel = True
    #: live-node crossover for batched (>= 4 item) dispatch; 0 = batches
    #: always use the kernel (see :func:`_kernel_dispatch`).
    KERNEL_MIN_NODES_BATCH = 0
    #: module providing ``kernel_available()`` for this scheduler's
    #: vectorized path; set by concrete classes.
    KERNEL_MODULE = None

    def _kernel_wins(self, cluster: ClusterView, batch: int) -> bool:
        return _kernel_dispatch(
            self, self.KERNEL_MODULE.kernel_available(), cluster, batch
        )

    def place(
        self, item: DataItem, cluster: ClusterView, ctx=None, constraints=None
    ) -> Decision:
        self.observe_item(item)
        if self._kernel_wins(cluster, 1):
            return self._place_kernel([item], cluster, ctx, constraints)[0]
        return self._place_scalar(item, cluster, ctx, constraints)

    def place_batch(
        self,
        items: Sequence[DataItem],
        cluster: ClusterView,
        ctx=None,
        constraints=None,
    ) -> list[Decision]:
        """Score ``items`` against the *current* cluster snapshot in one
        vmapped kernel call (pure; consumed by the engine's batched
        ``place_many``, which re-scores items invalidated by a commit).
        ``constraints`` (a :class:`PlacementConstraints`) restricts the
        candidate order to the cap-admitted subsequence — only the
        engine passes it, and only to ``topology_aware`` schedulers."""
        if self._kernel_wins(cluster, len(items)):
            return self._place_kernel(list(items), cluster, ctx, constraints)
        return [self._place_scalar(it, cluster, ctx, constraints) for it in items]

    def place_scalar(
        self, item: DataItem, cluster: ClusterView, ctx=None, constraints=None
    ) -> Decision:
        """Reference numpy oracle (kept for equivalence tests/benchmarks)."""
        self.observe_item(item)
        return self._place_scalar(item, cluster, ctx, constraints)


# ---------------------------------------------------------------------------
# §4.1 GreedyMinStorage
# ---------------------------------------------------------------------------


@register_scheduler(
    "greedy_min_storage",
    adaptive=True,
    supports_parity_growth=True,
    batch_scoring=True,
    topology_aware=True,
)
class GreedyMinStorage(_KernelSchedulerMixin, Scheduler):
    """Minimize per-item storage footprint ``(size/K) * N`` s.t. reliability
    (Eq. 4); mapping favors the fastest (write-bandwidth) nodes *among
    those with room for the chunk* — once the fast nodes saturate the
    selection slides to slower ones instead of failing (the paper's §5.4
    observation that GreedyMinStorage keeps utilizing all nodes).

    Two implementations of the same decision function: the scalar numpy
    oracle (:meth:`place_scalar` — the Python fixed-point loop over K per
    candidate N) and the jitted jax kernel
    (:mod:`repro.core.greedy_kernel`), which evaluates the fixed point in
    closed form for every N at once wherever the bw-sorted prefix fits
    the chunk, finishing capacity-tight rows with the same
    :meth:`_fixed_point_row` the oracle runs.  ``place`` uses the kernel
    when jax is importable and the cluster clears ``KERNEL_MIN_NODES``
    (batches of >= 4 items always do); ``place_batch`` vmaps it over many
    items sharing a snapshot.  Decisions are bit-for-bit equivalent and
    pinned by tests/test_greedy_vectorized.py.
    """

    name = "greedy_min_storage"
    KERNEL_MODULE = greedy_kernel
    #: below this many live nodes a single-item kernel call is dispatch-
    #: bound and the scalar oracle wins; batches of >= 4 items amortize
    #: dispatch and use the kernel regardless (measured crossover,
    #: benchmarks/table2).  Set to 0 to force the kernel (tests do).
    KERNEL_MIN_NODES = 24

    def _fixed_point_row(
        self, n, by_bw, free, fail_all, size, target, ctx
    ) -> Optional[Placement]:
        # Fixed point over K for one N: the chunk size determines which
        # nodes qualify (free >= chunk), which determines the mapping,
        # which determines the min parity, which determines K. K only
        # ever decreases, so this terminates in <= N steps (typically
        # 1-2).  Shared verbatim by the scalar oracle's N-loop and the
        # kernel's slow-row fallback.
        k = n - 1
        while k >= 1:
            chunk = size / k
            fitting = by_bw[free[by_bw] >= chunk]
            if len(fitting) < n:
                return None
            mapping = fitting[:n]
            mp = self._min_parity(fail_all[mapping], target, ctx)
            if mp < 0:
                return None
            p_star = max(1, mp)  # the repository always keeps parity
            k_new = n - p_star
            if k_new < 1:
                return None
            if k_new >= k:
                return Placement(
                    k=k, p=n - k, node_ids=tuple(int(x) for x in mapping)
                )
            k = k_new
        return None

    # -- scalar oracle ------------------------------------------------------

    def _place_scalar(
        self, item: DataItem, cluster: ClusterView, ctx=None, constraints=None
    ) -> Decision:
        by_bw = self._apply_constraints(
            self._live_sorted(cluster, cluster.write_bw), cluster, constraints
        )
        L = len(by_bw)
        if L < 2:
            return Decision(None, 0, "fewer than 2 live nodes")
        fail_all = self._fail_probs(cluster, item, ctx)
        free = cluster.free_mb

        best: Optional[Placement] = None
        best_cost = math.inf
        considered = 0
        for n in range(2, L + 1):
            considered += 1
            placement = self._fixed_point_row(
                n, by_bw, free, fail_all, item.size_mb,
                item.reliability_target, ctx,
            )
            if placement is None:
                continue
            cost = (item.size_mb / placement.k) * n
            if cost < best_cost:
                best_cost = cost
                best = placement
        if best is None:
            return Decision(None, considered, "no (N,K) satisfies reliability+capacity")
        return Decision(best, considered, "")

    # -- vectorized path ----------------------------------------------------

    def _place_kernel(
        self, items: list[DataItem], cluster: ClusterView, ctx, constraints=None
    ) -> list[Decision]:
        by_bw = self._apply_constraints(
            self._live_sorted(cluster, cluster.write_bw), cluster, constraints
        )
        L = len(by_bw)
        if L < 2:
            return [Decision(None, 0, "fewer than 2 live nodes") for _ in items]
        # No top-M pre-filter: the (size/K)*N objective keeps improving as
        # N grows (K grows with N), so a bw-sorted prefix slice can change
        # the argmin — MinStorage always scores the full grid (counted so
        # the scale lane's hit-rate columns show the bypass).
        prefilter.record(self.name, "bypassed", len(items))
        free = cluster.free_mb
        free_bw = free[by_bw]
        B = len(items)
        fail_rows: list[np.ndarray] = []
        probs_mat = np.empty((B, L), dtype=np.float64)
        for row, item in enumerate(items):
            fa = self._fail_probs(cluster, item, ctx)
            fail_rows.append(fa)
            probs_mat[row] = fa[by_bw]
        # Host-side RNA frontier rows for mappings beyond the exact-DP
        # limit (the oracle's min_parity auto-method switch); items
        # sharing (fail probs, target) pay for a row once per batch.
        rna_rows = np.full((B, L + 1), -1, dtype=np.int64)
        if L > _AUTO_EXACT_LIMIT:
            memo: dict[tuple[bytes, float], np.ndarray] = {}
            for row, item in enumerate(items):
                if ctx is not None:
                    rna_rows[row] = ctx.rna_frontier(
                        probs_mat[row], item.reliability_target, L
                    )
                    continue
                key = (probs_mat[row].tobytes(), item.reliability_target)
                got = memo.get(key)
                if got is None:
                    got = greedy_kernel.rna_frontier_row(
                        probs_mat[row], item.reliability_target, L
                    )
                    memo[key] = got
                rna_rows[row] = got
        valid, slow, ks, ps, cost = greedy_kernel.min_storage_batch(
            probs_mat,
            np.array([it.size_mb for it in items], dtype=np.float64),
            np.array([it.reliability_target for it in items], dtype=np.float64),
            rna_rows,
            free_bw,
        )
        decisions = []
        considered = L - 1  # the N-loop always runs 2..L
        for row, item in enumerate(items):
            c = cost[row]
            slow_pl: dict[int, Placement] = {}
            if slow[row].any():
                # Capacity filter engaged: finish these N with the same
                # fixed point the scalar oracle runs, then merge.
                c = c.copy()
                for i in np.nonzero(slow[row])[0]:
                    n = int(i) + 1
                    pl = self._fixed_point_row(
                        n, by_bw, free, fail_rows[row], item.size_mb,
                        item.reliability_target, ctx,
                    )
                    if pl is not None:
                        slow_pl[n] = pl
                        c[i] = (item.size_mb / pl.k) * n
            best_i = int(np.argmin(c))
            if not np.isfinite(c[best_i]):
                decisions.append(
                    Decision(
                        None, considered, "no (N,K) satisfies reliability+capacity"
                    )
                )
                continue
            n = best_i + 1
            if n in slow_pl:
                decisions.append(Decision(slow_pl[n], considered, ""))
            else:
                decisions.append(
                    Decision(
                        Placement(
                            k=int(ks[row, best_i]),
                            p=int(ps[row, best_i]),
                            node_ids=tuple(int(x) for x in by_bw[:n]),
                        ),
                        considered,
                        "",
                    )
                )
        return decisions


# ---------------------------------------------------------------------------
# §4.2 GreedyLeastUsed
# ---------------------------------------------------------------------------


@register_scheduler(
    "greedy_least_used",
    adaptive=True,
    supports_parity_growth=True,
    batch_scoring=True,
    windowed_scoring=True,
    topology_aware=True,
)
class GreedyLeastUsed(_KernelSchedulerMixin, Scheduler):
    """Minimize ``K+P`` s.t. reliability (Eq. 5); nodes with the highest
    free space get the chunks (then minimal parity among feasible).
    ``K >= 2`` as in Alg. 1 — the paper's erasure-coding schedulers do not
    degenerate to replication (only DAOS's explicit replication configs do).

    The scalar numpy oracle (:meth:`place_scalar`) scans N upward with a
    lazily-extended :class:`ParityFrontier`; the jitted jax kernel
    (:mod:`repro.core.greedy_kernel`) evaluates the whole first-feasible-N
    scan as one masked DP, vmapped across items in :meth:`place_batch`.
    Equivalence is pinned by tests/test_greedy_vectorized.py.

    Declares ``windowed_scoring``: a successful decision is a pure
    function of the free-desc order, the item, the failure probabilities
    and the free space of the *scanned prefix* — which is exactly the
    chosen mapping, since every probed N < N_chosen maps a sub-prefix of
    it.  Decisions therefore carry ``window = node_ids``, and the
    engine's dependency-aware rescoring may keep them across a commit
    that neither touches the window nor perturbs the free-desc order
    (see ``PlacementEngine._place_many_batched``).  Rejections scanned
    every live node and carry no window (always re-scored).
    """

    name = "greedy_least_used"
    KERNEL_MODULE = greedy_kernel
    #: the scalar scan stops at the first feasible N (typically < 10), so
    #: a single-item kernel call is dispatch-bound at any realistic
    #: cluster size (measured: the scalar oracle wins even at 500 nodes);
    #: only batches of >= 4 items amortize dispatch into a win.  The
    #: constant still defines the dispatch boundary for forced-kernel
    #: tests (set it to 0 to force the kernel everywhere).
    KERNEL_MIN_NODES = 4096
    #: batched calls beat the scalar loop only on large clusters (the
    #: capped DP wins ~1.8x at 500 nodes but loses ~1.4x at 100, where
    #: the whole queue costs under a millisecond either way).
    KERNEL_MIN_NODES_BATCH = 192
    #: prefix length the kernel scans: the first feasible N within the
    #: cap is globally first-feasible, and items with none fall back to
    #: the scalar oracle (bit-identical, just recomputed) — keeping the
    #: vmapped DP O(batch * SCAN_CAP^2) instead of O(batch * L^2).
    SCAN_CAP = 32

    def __init__(self):
        #: incremental free-desc order across commit deltas (see
        #: core/candidates); None forces the from-scratch argsort.
        self._order_tracker: Optional[FreeOrderTracker] = FreeOrderTracker()

    def observe_commit(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Engine commit hook (see ``PlacementEngine._finalize``)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_commit(node_ids, chunk_mb, cluster)

    def observe_release(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Engine release hook (release / abort_repair)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_release(node_ids, chunk_mb, cluster)

    def observe_churn(self, kind: str, node_ids, cluster: ClusterView) -> None:
        """Membership-churn hook (fail / heal / join)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_churn(kind, node_ids, cluster)

    def _by_free(self, cluster: ClusterView) -> np.ndarray:
        if self._order_tracker is None:
            return self._live_sorted(cluster, cluster.free_mb)
        return self._order_tracker.order(cluster)

    def _place_scalar(
        self, item: DataItem, cluster: ClusterView, ctx=None, constraints=None
    ) -> Decision:
        by_free = self._apply_constraints(
            self._by_free(cluster), cluster, constraints
        )
        L = len(by_free)
        if L < 2:
            return Decision(None, 0, "fewer than 2 live nodes")
        fail_all = self._fail_probs(cluster, item, ctx)
        frontier = self._frontier(
            fail_all[by_free], item.reliability_target, ctx
        )

        considered = 0
        for n in range(2, L + 1):
            considered += 1
            mp = frontier.min_parity(n)
            if mp < 0:
                continue
            p_star = max(1, mp)  # the repository always keeps parity
            k = n - p_star
            if k < 2:
                continue
            chunk = item.size_mb / k
            mapping = by_free[:n]
            if not self._fits(cluster, mapping, chunk):
                continue
            ids = tuple(int(x) for x in mapping)
            return Decision(
                Placement(k=k, p=p_star, node_ids=ids),
                considered,
                "",
                window=ids,
            )
        return Decision(None, considered, "no N satisfies reliability+capacity")

    def _place_kernel(
        self, items: list[DataItem], cluster: ClusterView, ctx, constraints=None
    ) -> list[Decision]:
        by_free = self._apply_constraints(
            self._by_free(cluster), cluster, constraints
        )
        L = len(by_free)
        if L < 2:
            return [Decision(None, 0, "fewer than 2 live nodes") for _ in items]
        # The first-feasible-N rule makes SCAN_CAP a lossless top-M
        # pre-filter (see core/prefilter): any N found within the prefix
        # is the global answer, so kernel inputs are materialized over the
        # cap slice only — decision cost scales with the cap, not L.
        # Under constraints the slice keeps per-domain representatives
        # (prefilter.domain_slice) so a spread width cannot be starved by
        # the cap; it stays a free-descending subsequence, so the
        # first-feasible scan and capacity logic are unchanged.
        cap = min(L, self.SCAN_CAP)
        if constraints is not None and not constraints.unconstrained:
            by_free_c = prefilter.domain_slice(
                by_free, cluster.rack, cluster.zone, cap, constraints, self.name
            )
            cap = len(by_free_c)
        else:
            by_free_c = by_free[:cap]
        if cap < L:
            prefilter.record(self.name, "engaged", len(items))
        probs_mat = np.empty((len(items), cap), dtype=np.float64)
        for row, item in enumerate(items):
            probs_mat[row] = self._fail_probs(cluster, item, ctx)[by_free_c]
        ok, ns, ks, ps = greedy_kernel.least_used_batch(
            probs_mat,
            np.array([it.size_mb for it in items], dtype=np.float64),
            np.array([it.reliability_target for it in items], dtype=np.float64),
            # free space of the cap slice only: index-then-subtract is
            # bitwise free_mb[by_free_c] without the O(N) materialize
            cluster.capacity_mb[by_free_c] - cluster.used_mb[by_free_c],
        )
        decisions = []
        for row, item in enumerate(items):
            if not ok[row]:
                if cap < L:
                    # No feasible N within the scanned prefix: finish with
                    # the scalar oracle (rare; bit-identical decision).
                    prefilter.record(self.name, "fallback")
                    decisions.append(
                        self._place_scalar(item, cluster, ctx, constraints)
                    )
                else:
                    decisions.append(
                        Decision(None, L - 1, "no N satisfies reliability+capacity")
                    )
                continue
            n = int(ns[row])
            ids = tuple(int(x) for x in by_free_c[:n])
            decisions.append(
                Decision(
                    Placement(k=int(ks[row]), p=int(ps[row]), node_ids=ids),
                    n - 1,  # the scalar scan increments considered per N
                    "",
                    window=ids,
                )
            )
        if cap < L:
            prefilter.record(self.name, "accepted", int(np.count_nonzero(ok)))
        return decisions


# ---------------------------------------------------------------------------
# §4.3 D-Rex LB (Algorithm 1)
# ---------------------------------------------------------------------------


@register_scheduler(
    "drex_lb",
    adaptive=True,
    supports_parity_growth=True,
    batch_scoring=True,
    topology_aware=True,
)
class DRexLB(_KernelSchedulerMixin, Scheduler):
    """Balance-penalty minimization; smallest feasible parity (Alg. 1).

    Two implementations of the same decision function: the scalar numpy
    oracle (:meth:`place_scalar` — the per-P scan below, penalties
    vectorized over K) and the jitted jax kernel
    (:mod:`repro.core.lb_kernel`), which evaluates the full (K, P) grid
    in one shot and is vmapped over items in :meth:`place_batch`.

    **Exactness policy** (see the lb_kernel module docstring): the
    balance penalty's in-mapping sum is accumulated in plain
    left-to-right prefix-sum order on both paths (``np.cumsum`` here, an
    explicit ``lax.scan`` carry in the kernel), and every other
    order-sensitive quantity — ``f_avg``, the out-of-mapping suffix
    sums, and the :class:`ParityFrontier` rows themselves — is a
    host-computed numpy value the kernel consumes as an input, so kernel
    decisions are bit-for-bit equal to this oracle with no fallback
    regimes (pinned by tests/test_lb_vectorized.py).

    No ``windowed_scoring``: every score depends on ``f_avg`` — the mean
    free space over *all* live nodes — so any commit anywhere shifts
    every pending penalty and batched scores can never outlive a commit
    (the engine's dependency-aware rescoring correctly invalidates them).

    **Incremental rescoring under commit-heavy load**: the exactness
    policy pins ``f_avg`` to numpy's pairwise mean over the free-desc
    order, so the mean itself must be re-reduced after every commit —
    but the *order* usually survives (a commit moves a few nodes down a
    little), and with the order the O(L log L) argsort, the frontier
    cache keys and the DP reuse all survive too.  A
    :class:`~repro.core.incremental.FreeOrderTracker` fed by the
    engine's ``observe_commit`` hook keeps the order across commit
    deltas with an O(p) adjacency check, leaving ``f_avg``/dev/suffix as
    O(L) re-reductions over the same element order (bitwise identical to
    the from-scratch path).
    """

    name = "drex_lb"
    KERNEL_MODULE = lb_kernel
    #: below this many live nodes a single-item kernel call is dispatch-
    #: bound and the (vectorized-numpy) scalar oracle wins — LB's oracle
    #: is grid-shaped too, so the single-item crossover sits much higher
    #: than SC's (~0.6x at 200 nodes, ~2x at 500; measured,
    #: benchmarks/table2).  Batches of >= 4 items amortize dispatch and
    #: use the kernel regardless (6-10x at 100-500 nodes).  Set to 0 to
    #: force the kernel (tests do).
    KERNEL_MIN_NODES = 256
    #: top-M candidate pre-filter (core/prefilter): above this many live
    #: nodes the (K, P) grid runs over the freest-PREFILTER_CAP prefix
    #: with a per-row exactness test and unfiltered fallback.  A shapes
    #: rung so filtered pads land on shared buckets; False disables.
    use_prefilter = True
    PREFILTER_CAP = prefilter.lb_cap()

    def __init__(self):
        #: incremental free-desc order across commit deltas; set to None
        #: to force the from-scratch argsort (the exactness tests compare
        #: both).
        self._order_tracker: Optional[FreeOrderTracker] = FreeOrderTracker()

    def observe_commit(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Engine commit hook (see ``PlacementEngine._finalize``)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_commit(node_ids, chunk_mb, cluster)

    def observe_release(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Engine release hook (release / abort_repair)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_release(node_ids, chunk_mb, cluster)

    def observe_churn(self, kind: str, node_ids, cluster: ClusterView) -> None:
        """Membership-churn hook (fail / heal / join)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_churn(kind, node_ids, cluster)

    def _by_free(self, cluster: ClusterView) -> np.ndarray:
        if self._order_tracker is None:
            return self._live_sorted(cluster, cluster.free_mb)
        return self._order_tracker.order(cluster)

    @staticmethod
    def _considered(L: int, p_found: int | None) -> int:
        """Candidates the scalar per-(P, K) loop enumerates: for each
        probed P it scans K = 2..L-P (``L - 1 - p`` candidates), stopping
        after the first feasible P (or exhausting P = 1..L-1)."""
        p_last = L - 1 if p_found is None else p_found
        return p_last * (L - 1) - p_last * (p_last + 1) // 2

    # -- scalar oracle ------------------------------------------------------

    def _place_scalar(
        self, item: DataItem, cluster: ClusterView, ctx=None, constraints=None
    ) -> Decision:
        by_free = self._apply_constraints(
            self._by_free(cluster), cluster, constraints
        )
        L = len(by_free)
        if L < 3:  # Alg. 1 needs K>=2 and P>=1
            return Decision(None, 0, "fewer than 3 live nodes")
        fail_all = self._fail_probs(cluster, item, ctx)
        free_sorted = cluster.free_mb[by_free]
        f_avg = float(free_sorted.mean())  # line 1
        # |F(S_j) - F_avg| for every node once; penalties for out-of-mapping
        # nodes are suffix sums over the sorted order (mapping is a prefix).
        dev = np.abs(free_sorted - f_avg)
        suffix = np.concatenate([np.cumsum(dev[::-1])[::-1], [0.0]])
        # One frontier answers the (prefix, parity) feasibility question for
        # every (K, P) pair: CDF_n(p) >= RT  <=>  min_parity(n) <= p.
        frontier = self._frontier(
            fail_all[by_free], item.reliability_target, ctx
        )
        mp_all = frontier.upto(L)

        # lines 10-15 for every K at once: the in-mapping penalty of the
        # (K, P) pair is the length-(K+P) prefix sum of the chunk-adjusted
        # deviations, accumulated left-to-right (np.cumsum — the fixed
        # summation order the kernel reproduces; see class docstring).
        ks = np.arange(2, L)                       # K = 2..L-1
        chunk_k = item.size_mb / ks.astype(np.float64)
        pen = np.cumsum(
            np.abs(free_sorted[None, :] - chunk_k[:, None] - f_avg), axis=1
        )

        for p in range(1, L):  # line 5
            k_arr = ks[: L - p - 1]                # K = 2..L-P
            if k_arr.size == 0:
                continue
            n_arr = k_arr + p
            mp = mp_all[n_arr - 1]
            feas = (
                (mp >= 0)
                & (mp <= p)
                & (free_sorted[n_arr - 1] >= chunk_k[: k_arr.size])
            )
            if not np.any(feas):
                continue
            # line 22: stop at the smallest feasible P; best (strictly
            # smallest penalty, earliest K on ties) K within it.
            bp = np.where(
                feas, pen[np.arange(k_arr.size), n_arr - 1] + suffix[n_arr],
                np.inf,
            )
            k = int(k_arr[int(np.argmin(bp))])
            n = k + p
            return Decision(
                Placement(
                    k=k, p=p, node_ids=tuple(int(x) for x in by_free[:n])
                ),
                self._considered(L, p),
                "",
            )
        return Decision(
            None, self._considered(L, None),
            "no (K,P) satisfies reliability+capacity",
        )

    # -- vectorized path ----------------------------------------------------

    def _place_kernel(
        self, items: list[DataItem], cluster: ClusterView, ctx, constraints=None
    ) -> list[Decision]:
        by_free = self._apply_constraints(
            self._by_free(cluster), cluster, constraints
        )
        L = len(by_free)
        if L < 3:
            return [Decision(None, 0, "fewer than 3 live nodes") for _ in items]
        cap = self.PREFILTER_CAP if self.use_prefilter else 0
        if constraints is not None and 3 <= cap < L:
            # LB's filtered grid consumes parity-frontier *prefix* rows,
            # so the slice must stay a plain prefix (no representative
            # promotion).  When the top-cap prefix of the admitted order
            # cannot span the required width, run the grid unfiltered
            # instead of starving the spread constraint.
            sl = by_free[:cap]
            if (
                np.unique(cluster.rack[sl]).shape[0]
                < min(constraints.min_racks, cap)
                or np.unique(cluster.zone[sl]).shape[0]
                < min(constraints.min_zones, cap)
            ):
                prefilter.record(self.name, "fallback", len(items))
                cap = 0
        if cap < 3 or cap >= L:  # lb_batch needs K>=2, P>=1 => m >= 3
            return self._kernel_decisions(items, cluster, ctx, by_free, L, {})
        # Top-M pre-filter (core/prefilter): run the (K, P) grid over the
        # freest-M prefix; a row's answer is provably the full-grid answer
        # iff the min parity of the whole M-prefix exceeds the P it found
        # (frontier monotonicity makes every wider window infeasible at
        # that P).  Rows failing the test re-run unfiltered — the lazily
        # extended ParityFrontier makes that an incremental DP, not a
        # restart.
        prefilter.record(self.name, "engaged", len(items))
        memo: dict[tuple[bytes, float], ParityFrontier] = {}
        decisions = self._kernel_decisions(items, cluster, ctx, by_free, cap, memo)
        fb = [i for i, d in enumerate(decisions) if d is None]
        prefilter.record(self.name, "accepted", len(items) - len(fb))
        if fb:
            prefilter.record(self.name, "fallback", len(fb))
            full = self._kernel_decisions(
                [items[i] for i in fb], cluster, ctx, by_free, L, memo
            )
            for j, i in enumerate(fb):
                decisions[i] = full[j]
        return decisions

    def _kernel_decisions(
        self,
        items: list[DataItem],
        cluster: ClusterView,
        ctx,
        by_free: np.ndarray,
        m: int,
        memo: dict,
    ) -> list[Optional[Decision]]:
        """Grid-evaluate ``items`` over the freest-``m`` prefix of
        ``by_free``.  When ``m < L`` (pre-filtered call) a row whose
        sufficiency test fails yields ``None`` — the caller re-runs it
        with ``m = L``."""
        L = len(by_free)
        filtered = m < L
        free_sorted = cluster.free_mb[by_free]
        # Order-sensitive global terms, host-computed exactly as the
        # scalar oracle computes them (numpy pairwise mean / reversed
        # cumsum); the kernel consumes them as inputs.  f_avg and the
        # suffix sums are cluster-global (all L nodes) even on the
        # pre-filtered path — only the scanned grid shrinks to m.
        f_avg = float(free_sorted.mean())
        dev = np.abs(free_sorted - f_avg)
        suffix = np.concatenate([np.cumsum(dev[::-1])[::-1], [0.0]])
        # Host parity-frontier rows — the very DP the oracle consults
        # (equivalence by construction; see the lb_kernel docstring).
        # Items sharing (fail probs, target) pay for one frontier per
        # batch; the BatchContext extends that across commit groups.
        mp_rows = np.empty((len(items), m), dtype=np.int64)
        for row, item in enumerate(items):
            probs = self._fail_probs(cluster, item, ctx)[by_free]
            if ctx is not None:
                fr = ctx.frontier(probs, item.reliability_target)
            else:
                key = (probs.tobytes(), item.reliability_target)
                fr = memo.get(key)
                if fr is None:
                    fr = ParityFrontier(probs, item.reliability_target)
                    memo[key] = fr
            mp_rows[row] = fr.upto(m)[:m]
        ok, ks, ps = lb_kernel.lb_batch(
            mp_rows,
            np.array([it.size_mb for it in items], dtype=np.float64),
            free_sorted[:m],
            f_avg,
            suffix[: m + 1],
        )
        decisions: list[Optional[Decision]] = []
        for row in range(len(items)):
            if not ok[row]:
                if filtered:
                    # A wider-than-m window might still be feasible.
                    decisions.append(None)
                    continue
                decisions.append(
                    Decision(
                        None, self._considered(L, None),
                        "no (K,P) satisfies reliability+capacity",
                    )
                )
                continue
            k, p = int(ks[row]), int(ps[row])
            if filtered:
                # Sufficiency test: min parity of the full m-prefix (-1
                # sentinel => > m-1, i.e. at least m) must strictly exceed
                # the found P, else a wider window could be feasible at a
                # P <= found (same P, lower penalty) and the slice is not
                # provably exact.
                mp_m = int(mp_rows[row, m - 1])
                if (m if mp_m < 0 else mp_m) <= p:
                    decisions.append(None)
                    continue
            decisions.append(
                Decision(
                    Placement(
                        k=k, p=p,
                        node_ids=tuple(int(x) for x in by_free[: k + p]),
                    ),
                    self._considered(L, p),
                    "",
                )
            )
        return decisions


# ---------------------------------------------------------------------------
# §4.4 D-Rex SC (Algorithm 2)
# ---------------------------------------------------------------------------


def saturation_score(projected_used_mb, capacity_mb, smin_mb, n_nodes: int = 10):
    """Exponential saturation score (paper Fig. 3 / Alg. 2 line 11).

    The curve is the exponential through the two anchors the paper's
    formula names: ``(smallest known data item size, 1/L)`` and
    ``(total storage capacity, 1)``, evaluated at the projected *used*
    bytes ``x``:

        f(x) = (1/L) * exp( ln(L) * (x - s_min) / (cap - s_min) )

    i.e. an empty node scores ~1/L and a full node scores 1, rising
    exponentially as the node approaches its limit ("penalize nodes
    approaching their limit", §4.4). Elementwise on numpy arrays; clipped
    to [0, 1].
    """
    cap = np.asarray(capacity_mb, dtype=np.float64)
    x = np.asarray(projected_used_mb, dtype=np.float64)
    span = np.maximum(cap - smin_mb, 1e-9)
    u = np.clip((x - smin_mb) / span, 0.0, 1.0)
    inv_l = 1.0 / max(2, n_nodes)
    return np.clip(inv_l * np.exp(math.log(max(2, n_nodes)) * u), 0.0, 1.0)


@register_scheduler(
    "drex_sc",
    adaptive=True,
    supports_parity_growth=True,
    batch_scoring=True,
    topology_aware=True,
)
class DRexSC(Scheduler):
    """System-capacity-aware scheduler (Alg. 2): Pareto front over
    {duration, storage, saturation} with saturation-weighted scoring.

    Two implementations of the same decision function:

    * :meth:`place_scalar` — the reference numpy oracle: a Python loop
      over window starts, one lazily-extended :class:`ParityFrontier`
      per start.
    * the jitted jax kernel (:mod:`repro.core.sc_kernel`) — the whole
      (starts x window-lengths) grid scored as one tensor program, and
      :meth:`place_batch` vmaps it over many items sharing a cluster
      snapshot (consumed by ``PlacementEngine.place_many``).

    ``place`` uses the kernel when jax is importable and the cluster is
    large enough for the kernel to win over numpy dispatch
    (``KERNEL_MIN_NODES``; batches of >= 4 items always use it); set
    ``use_kernel = False`` to force the oracle.  Decisions are
    equivalent by construction and pinned by tests/test_sc_vectorized.py.

    **Partial rescoring after commits**: the saturation *baseline*
    (Alg. 2 line 11's sum over every live node) changes after a commit
    only at the committed nodes, so a
    :class:`~repro.core.incremental.SaturationTracker` fed by the
    engine's ``observe_commit`` hook refreshes just those entries
    instead of re-evaluating the exponential over the whole cluster;
    a :class:`~repro.core.incremental.FreeOrderTracker` likewise keeps
    the free-desc order (and with it the per-start frontier cache keys)
    across commits.  Both reproduce the from-scratch values bitwise (see
    the incremental module docstring); the per-candidate window grid is
    always scored fresh.
    """

    name = "drex_sc"
    MAX_MAPPINGS = 2**10
    #: top-M candidate pre-filter (core/prefilter.sc_cap): above
    #: sc_cap(MAX_MAPPINGS) live nodes, kernel inputs slice to the
    #: freest-M prefix — exact by the start-major enumeration order.
    #: False disables (the scale benchmark times both paths).
    use_prefilter = True
    #: set to False to force the scalar numpy oracle even when jax is
    #: present.
    use_kernel = True
    #: below this many live nodes a single-item kernel call is dispatch-
    #: bound and the numpy oracle wins; batches amortize dispatch and use
    #: the kernel regardless (measured crossover, benchmarks/table2).
    #: Set to 0 to force the kernel everywhere (equivalence tests do).
    KERNEL_MIN_NODES = 16
    #: batches of >= 4 items always use the kernel (see _kernel_dispatch).
    KERNEL_MIN_NODES_BATCH = 0

    def __init__(self, time_model: ECTimeModel | None = None):
        self.time_model = time_model or ECTimeModel()
        #: incremental rescoring state (None disables; exactness tests
        #: compare both paths).
        self._order_tracker: Optional[FreeOrderTracker] = FreeOrderTracker()
        self._sat_tracker: Optional[SaturationTracker] = SaturationTracker()

    def observe_commit(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Engine commit hook (see ``PlacementEngine._finalize``)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_commit(node_ids, chunk_mb, cluster)
        if self._sat_tracker is not None:
            self._sat_tracker.observe_commit(node_ids, chunk_mb, cluster)

    def observe_release(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Engine release hook.  The saturation tracker's per-entry
        scores are commit-shaped only; a release invalidates it (the
        mirror would catch the mismatch anyway — this skips the failed
        validation)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_release(node_ids, chunk_mb, cluster)
        if self._sat_tracker is not None:
            self._sat_tracker.invalidate()

    def observe_churn(self, kind: str, node_ids, cluster: ClusterView) -> None:
        """Membership-churn hook (fail / heal / join)."""
        if self._order_tracker is not None:
            self._order_tracker.observe_churn(kind, node_ids, cluster)
        if self._sat_tracker is not None:
            self._sat_tracker.invalidate()  # live set changed

    def _by_free(self, cluster: ClusterView) -> np.ndarray:
        if self._order_tracker is None:
            return self._live_sorted(cluster, cluster.free_mb)
        return self._order_tracker.order(cluster)

    def _f_base_sum(
        self, cluster: ClusterView, smin: float, live: np.ndarray, L: int
    ) -> float:
        """Alg. 2 line 11's baseline sum; tracker-served when possible."""
        if self._sat_tracker is None:
            return float(
                saturation_score(
                    cluster.used_mb[live], cluster.capacity_mb[live], smin, L
                ).sum()
            )
        return self._sat_tracker.f_base_sum(cluster, smin)

    def _kernel_wins(self, cluster: ClusterView, batch: int) -> bool:
        return _kernel_dispatch(
            self, sc_kernel.kernel_available(), cluster, batch
        )

    def place(
        self, item: DataItem, cluster: ClusterView, ctx=None, constraints=None
    ) -> Decision:
        self.observe_item(item)
        if self._kernel_wins(cluster, 1):
            smin = self.smin_mb if self.smin_mb is not None else 1.0
            return self._place_kernel([item], [smin], cluster, ctx, constraints)[0]
        return self._place_scalar(item, cluster, ctx, constraints)

    def place_batch(
        self,
        items: Sequence[DataItem],
        cluster: ClusterView,
        ctx=None,
        constraints=None,
    ) -> list[Decision]:
        """Score ``items`` against the *current* cluster snapshot in one
        vmapped kernel call.

        Pure: scheduler state (``smin_mb``) is not mutated — each item is
        scored with the running smallest-size anchor it would see under
        sequential ``place`` calls, and the consumer (the engine's
        batched ``place_many``) calls :meth:`observe_item` as it commits
        to a decision.  Decisions are valid only while the cluster is
        unchanged: any commit invalidates the remaining items of the
        batch, which must be re-scored against the post-commit state.
        """
        run = self.smin_mb
        smins: list[float] = []
        for it in items:
            if it.size_mb > 0:
                run = it.size_mb if run is None else min(run, it.size_mb)
            smins.append(run if run is not None else 1.0)
        if self._kernel_wins(cluster, len(items)):
            return self._place_kernel(list(items), smins, cluster, ctx, constraints)
        saved = self.smin_mb
        try:
            out = []
            for it, sm in zip(items, smins):
                self.smin_mb = sm
                out.append(self._place_scalar(it, cluster, ctx, constraints))
            return out
        finally:
            self.smin_mb = saved

    def place_scalar(
        self, item: DataItem, cluster: ClusterView, ctx=None, constraints=None
    ) -> Decision:
        """Reference numpy oracle (kept for equivalence tests/benchmarks)."""
        self.observe_item(item)
        return self._place_scalar(item, cluster, ctx, constraints)

    # -- vectorized path ----------------------------------------------------

    def _place_kernel(
        self,
        items: list[DataItem],
        smins: Sequence[float],
        cluster: ClusterView,
        ctx,
        constraints=None,
    ) -> list[Decision]:
        by_free = self._apply_constraints(
            self._by_free(cluster), cluster, constraints
        )  # line 1
        L = len(by_free)
        if L < 2:
            return [Decision(None, 0, "fewer than 2 live nodes") for _ in items]
        live = cluster.live_ids()
        # Saturation terms stay cluster-global under constraints: the
        # 1/L anchor and the baseline sum describe the repository, not
        # the admissible candidate set (L_live == L when unconstrained,
        # keeping that path bit-identical).
        L_live = len(live)
        used, cap = cluster.used_mb, cluster.capacity_mb
        # Top-M pre-filter (core/prefilter): window enumeration under the
        # candidate budget is start-major, so whenever it engages
        # (L > sc_cap >= budget + 1) no enumerated window ever reaches
        # past the first budget+1 sorted nodes — slicing kernel inputs to
        # M is exact with no per-row test.  Cluster-global terms (the
        # saturation baseline/system saturation below and the 1/L scale,
        # threaded through as n_live) still use the true L.
        M = prefilter.sc_cap(self.MAX_MAPPINGS) if self.use_prefilter else 0
        if 0 < M < L:
            prefilter.record(self.name, "engaged", len(items))
            prefilter.record(self.name, "accepted", len(items))
            if constraints is not None and not constraints.unconstrained:
                # Keep per-domain representatives in the slice (still a
                # free-descending subsequence, so the start-major window
                # logic below is unchanged).
                by_free_k = prefilter.domain_slice(
                    by_free, cluster.rack, cluster.zone, M, constraints,
                    self.name,
                )
            else:
                by_free_k = by_free[:M]
        else:
            by_free_k = by_free
        Lk = len(by_free_k)
        probs_mat = np.empty((len(items), Lk), dtype=np.float64)
        for row, item in enumerate(items):
            probs_mat[row] = self._fail_probs(cluster, item, ctx)[by_free_k]
        # The saturation baseline and system saturation depend only on the
        # item's smin anchor; batches rarely move the running min, so
        # compute once per distinct value (numpy, bit-matching the oracle).
        base_cache: dict[float, tuple[float, float]] = {}
        fbase = np.empty(len(items))
        ssat = np.empty(len(items))
        for row, smin in enumerate(smins):
            got = base_cache.get(smin)
            if got is None:
                f_base_sum = self._f_base_sum(cluster, smin, live, L_live)
                sys_sat = float(
                    saturation_score(
                        np.array([used[live].sum()]),
                        np.array([cap[live].sum()]),
                        smin,
                        L_live,
                    )[0]
                )
                got = (f_base_sum, sys_sat)
                base_cache[smin] = got
            fbase[row], ssat[row] = got
        tm = self.time_model
        ok, s, n, k, p = sc_kernel.score_windows_batch(
            probs_mat,
            np.array([it.size_mb for it in items], dtype=np.float64),
            np.array([it.reliability_target for it in items], dtype=np.float64),
            np.asarray(smins, dtype=np.float64),
            fbase,
            ssat,
            cluster.free_mb[by_free_k],
            cluster.write_bw[by_free_k],
            cluster.read_bw[by_free_k],
            used[by_free_k],
            cap[by_free_k],
            self.MAX_MAPPINGS,
            (tm.e0, tm.e_byte, tm.e_mult, tm.d0, tm.d_byte, tm.d_mult),
            n_live=L_live,
        )
        considered = min(L * (L - 1) // 2, self.MAX_MAPPINGS)
        decisions = []
        for row in range(len(items)):
            if not ok[row]:
                decisions.append(
                    Decision(
                        None, considered, "no mapping satisfies reliability+capacity"
                    )
                )
                continue
            s_r, n_r = int(s[row]), int(n[row])
            decisions.append(
                Decision(
                    Placement(
                        k=int(k[row]),
                        p=int(p[row]),
                        node_ids=tuple(int(x) for x in by_free_k[s_r : s_r + n_r]),
                    ),
                    considered,
                    "",
                )
            )
        return decisions

    # -- scalar oracle ------------------------------------------------------

    def _place_scalar(
        self, item: DataItem, cluster: ClusterView, ctx=None, constraints=None
    ) -> Decision:
        by_free = self._apply_constraints(
            self._by_free(cluster), cluster, constraints
        )  # line 1
        L = len(by_free)
        if L < 2:
            return Decision(None, 0, "fewer than 2 live nodes")
        fail_all = self._fail_probs(cluster, item, ctx)
        fail_sorted = fail_all[by_free]
        free_sorted = cluster.free_mb[by_free]
        wb_sorted = cluster.write_bw[by_free]
        rb_sorted = cluster.read_bw[by_free]
        used_sorted = cluster.used_mb[by_free]
        cap_sorted = cluster.capacity_mb[by_free]
        used = cluster.used_mb
        cap = cluster.capacity_mb
        # observe_item just ran, so smin_mb is only None for degenerate
        # zero-size items; fall back to the old 1 MB prior there.
        smin = self.smin_mb if self.smin_mb is not None else 1.0
        size = item.size_mb
        live = cluster.live_ids()
        # Saturation baseline over every live node; candidates add only the
        # delta of their mapped nodes (+chunk), so — like D-Rex LB's
        # balance penalty — unmapped nodes still participate and wide,
        # shallow placements are rewarded for not pushing any node toward
        # its limit.  The 1/L anchor is the true live count (== L unless
        # a constraint shortened the candidate order).
        L_live = len(live)
        f_base_sum = self._f_base_sum(cluster, smin, live, L_live)
        tm = self.time_model

        # Candidate windows as parallel arrays ((s, n) identifies the
        # mapping; only the winner's node tuple is ever materialized).
        cand_cols: list[np.ndarray] = []
        considered = 0
        budget = self.MAX_MAPPINGS
        # line 2: first 2^10 contiguous windows of the sorted order, windows
        # expanding from each start: [0:2],[0:3],...,[0:L],[1:3],...
        # The window [s:e] is a prefix of the suffix starting at s, so one
        # lazily-extended ParityFrontier per start answers every window;
        # all windows sharing a start are then scored vectorized.
        for s in range(L - 1):
            if budget <= 0:
                break
            n_wins = min(L - s - 1, budget)   # windows e in [s+2, s+2+n_wins)
            budget -= n_wins
            considered += n_wins
            nmax = n_wins + 1                 # largest prefix length probed
            frontier = self._frontier(
                fail_sorted[s:], item.reliability_target, ctx
            )
            fr = frontier.upto(nmax)
            n_arr = np.arange(2, nmax + 1)
            mp = fr[1:nmax]                   # min parity for n = 2..nmax
            p_star = np.maximum(1, mp)        # line 4: min storage == max K
            k = n_arr - p_star
            valid = (mp >= 0) & (k >= 1)
            if not np.any(valid):
                continue
            k_safe = np.where(valid, k, 1)
            chunk = size / k_safe
            # Capacity: mapping is sorted by free desc, so the window min
            # is its last node.
            valid &= free_sorted[s + n_arr - 1] >= chunk
            if not np.any(valid):
                continue
            wb_min = np.minimum.accumulate(wb_sorted[s : s + nmax])[n_arr - 1]
            rb_min = np.minimum.accumulate(rb_sorted[s : s + nmax])[n_arr - 1]
            enc = tm.t_encode_many(n_arr, k_safe, size)
            dec = tm.t_decode_many(k_safe, size)
            duration = chunk / wb_min + chunk / rb_min + enc + dec  # line 6
            storage = chunk * n_arr  # line 7
            # line 8: per-window saturation delta of the mapped prefix.
            u = used_sorted[s : s + nmax]
            c = cap_sorted[s : s + nmax]
            delta = saturation_score(
                u[None, :] + chunk[:, None], c[None, :], smin, L_live
            ) - saturation_score(u, c, smin, L_live)[None, :]
            in_window = np.arange(nmax)[None, :] < n_arr[:, None]
            sat = f_base_sum + (delta * in_window).sum(axis=1)
            cand_cols.append(
                np.stack(
                    [
                        np.full(int(valid.sum()), float(s)),
                        n_arr[valid].astype(np.float64),
                        k[valid].astype(np.float64),
                        p_star[valid].astype(np.float64),
                        duration[valid],
                        storage[valid],
                        sat[valid],
                    ],
                    axis=1,
                )
            )
        if not cand_cols:
            return Decision(None, considered, "no mapping satisfies reliability+capacity")
        cands = np.concatenate(cand_cols, axis=0)  # (m, 7); every block non-empty

        # line 11: system saturation over the whole repository.
        sys_sat = float(
            saturation_score(
                np.array([used[live].sum()]), np.array([cap[live].sum()]), smin,
                L_live,
            )[0]
        )

        objectives = cands[:, 4:7]  # (duration, storage, saturation)
        front = cands[_pareto_front(objectives)]
        dur_prog = _progress(front[:, 4])
        sto_prog = _progress(front[:, 5])
        sat_prog = _progress(front[:, 6])
        score = (1.0 - sys_sat) * dur_prog + (sto_prog + sat_prog) / 2.0  # line 17
        best = front[int(np.argmax(score))]
        s_best, n_best = int(best[0]), int(best[1])
        return Decision(
            Placement(
                k=int(best[2]),
                p=int(best[3]),
                node_ids=tuple(int(x) for x in by_free[s_best : s_best + n_best]),
            ),
            considered,
            "",
        )


def _progress(vals: np.ndarray) -> np.ndarray:
    """Relative progress (line 16): 1 at the min, 0 at the max; all-equal
    candidates make no progress relative to each other."""
    lo, hi = float(vals.min()), float(vals.max())
    if hi - lo <= 1e-12:
        return np.zeros_like(vals)
    return (hi - vals) / (hi - lo)


def _pareto_front(objectives: np.ndarray) -> np.ndarray:
    """Keep-mask of the minimizing front over an (m, d) objective matrix;
    one broadcasted pairwise comparison with m <= 1024 candidates."""
    # i is dominated iff some j is <= on every objective and < on one:
    # le[i, j] = all_k arr[j, k] <= arr[i, k]; lt[i, j] = any_k <.
    # Built per objective in 2-D (m x m) to avoid the (m, m, d) temporary.
    m, d = objectives.shape
    le = np.ones((m, m), dtype=bool)
    lt = np.zeros((m, m), dtype=bool)
    for col in range(d):
        c = objectives[:, col]
        le &= c[None, :] <= c[:, None]
        lt |= c[None, :] < c[:, None]
    keep = ~np.any(le & lt, axis=1)
    if not np.any(keep):  # defensive — exact ties are never "dominated"
        keep[:] = True
    return keep


# ---------------------------------------------------------------------------
# §5.2.1 Static erasure coding (HDFS EC(3,2)/EC(6,3), Gluster EC(4,2))
# ---------------------------------------------------------------------------


@register_scheduler_family(r"ec\(\s*(\d+)\s*,\s*(\d+)\s*\)")
class StaticEC(Scheduler):
    """Algorithm 3: fixed (K, P); first K+P fitting nodes by write BW."""

    def __init__(self, k: int, p: int):
        self.k = k
        self.p = p
        self.name = f"ec({k},{p})"

    def place(self, item: DataItem, cluster: ClusterView, ctx=None) -> Decision:
        self.observe_item(item)
        by_bw = self._live_sorted(cluster, cluster.write_bw)  # line 2
        n = self.k + self.p
        chunk = item.size_mb / self.k
        fitting = [int(i) for i in by_bw if cluster.free_mb[i] >= chunk]
        if len(fitting) < n:
            return Decision(None, 1, "not enough nodes with capacity")
        mapping = tuple(fitting[:n])
        fail_all = self._fail_probs(cluster, item, ctx)
        mp = self._min_parity(
            fail_all[list(mapping)], item.reliability_target, ctx
        )
        if mp < 0 or mp > self.p:
            return Decision(None, 1, "fixed (K,P) cannot meet reliability target")
        return Decision(Placement(k=self.k, p=self.p, node_ids=mapping), 1, "")


# ---------------------------------------------------------------------------
# §5.2.2 DAOS: EC configs + replication, least storage overhead meeting RT
# ---------------------------------------------------------------------------


@register_scheduler("daos", adaptive=True)
class DAOSAdaptive(Scheduler):
    """Pick, among DAOS's predefined configs, the one meeting the
    reliability target with the lowest storage overhead (paper §5.2.2).

    Replication 2x/4x/6x is modeled in the erasure-coded representation as
    K=1 with P = copies-1 (paper §3.1)."""

    name = "daos"
    # (K, P), ordered by storage overhead N/K ascending:
    CONFIGS = [(8, 1), (8, 2), (4, 1), (4, 2), (1, 1), (1, 3), (1, 5)]

    def place(self, item: DataItem, cluster: ClusterView, ctx=None) -> Decision:
        self.observe_item(item)
        by_bw = self._live_sorted(cluster, cluster.write_bw)
        fail_all = self._fail_probs(cluster, item, ctx)
        considered = 0
        for k, p in sorted(self.CONFIGS, key=lambda kp: (kp[0] + kp[1]) / kp[0]):
            considered += 1
            n = k + p
            chunk = item.size_mb / k
            fitting = [int(i) for i in by_bw if cluster.free_mb[i] >= chunk]
            if len(fitting) < n:
                continue
            mapping = tuple(fitting[:n])
            mp = self._min_parity(
                fail_all[list(mapping)], item.reliability_target, ctx
            )
            if mp < 0 or mp > p:
                continue
            return Decision(Placement(k=k, p=p, node_ids=mapping), considered, "")
        return Decision(None, considered, "no DAOS config meets target")


# ---------------------------------------------------------------------------
# Extra baseline (ours): uniform random spread — ablation control
# ---------------------------------------------------------------------------


@register_scheduler("random_spread", randomized=True)
class RandomSpread(Scheduler):
    """Uniformly random feasible mapping with HDFS-style EC(6,3); control
    baseline for ablations (not in the paper).

    RNG state: the mapping for an item is drawn from a generator seeded
    with ``(seed, item_id)``, so ``place`` is a pure function of
    ``(seed, item, cluster)`` — repeated calls for the same item return
    the same mapping, and batched ``place_many`` matches sequential
    ``place`` exactly (no generator state threaded between calls).
    """

    name = "random_spread"

    def __init__(self, k: int = 6, p: int = 3, seed: int = 0):
        self.k, self.p = k, p
        self.seed = seed

    def place(self, item: DataItem, cluster: ClusterView, ctx=None) -> Decision:
        self.observe_item(item)
        n = self.k + self.p
        chunk = item.size_mb / self.k
        ids = [int(i) for i in cluster.live_ids() if cluster.free_mb[i] >= chunk]
        if len(ids) < n:
            return Decision(None, 1, "not enough nodes with capacity")
        # Mask to non-negative 64-bit words: default_rng rejects negative
        # entropy, and DataItem does not forbid sentinel/negative ids.
        mask = (1 << 64) - 1
        rng = np.random.default_rng((self.seed & mask, item.item_id & mask))
        mapping = tuple(int(x) for x in rng.choice(ids, size=n, replace=False))
        fail_all = self._fail_probs(cluster, item, ctx)
        mp = self._min_parity(
            fail_all[list(mapping)], item.reliability_target, ctx
        )
        if mp < 0 or mp > self.p:
            return Decision(None, 1, "fixed (K,P) cannot meet reliability target")
        return Decision(Placement(k=self.k, p=self.p, node_ids=mapping), 1, "")


# ---------------------------------------------------------------------------


#: Canonical paper ordering (the 9 algorithms every benchmark sweeps).
SCHEDULER_NAMES = [
    "drex_sc",
    "drex_lb",
    "greedy_min_storage",
    "greedy_least_used",
    "ec(3,2)",
    "ec(4,2)",
    "ec(6,3)",
    "daos",
    "random_spread",
]

# Materialize the paper's static-EC configs in the registry so
# ``scheduler_names()`` lists all nine out of the box.
for _name in SCHEDULER_NAMES:
    get_spec(_name)

