"""Core datatypes shared by the D-Rex algorithms, simulator and checkpointer.

Sizes are in MB (the paper's unit); times in seconds; bandwidths in MB/s;
``delta_t`` retention windows in days (converted to year-fractions at the
reliability boundary, matching Eq. 1's convention).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

DAYS_PER_YEAR = 365.25


@dataclasses.dataclass
class StorageNode:
    """A heterogeneous storage node (paper Table 1 'known' quantities)."""

    node_id: int
    capacity_mb: float                 # size(S_i)
    write_bw: float                    # B_w(S_i), MB/s
    read_bw: float                     # B_r(S_i), MB/s
    annual_failure_rate: float         # lambda_rate of Eq. (1)
    name: str = ""
    used_mb: float = 0.0
    failed: bool = False
    rack: int = 0                      # failure domain: rack id
    zone: int = 0                      # failure domain: zone id (racks nest in zones)

    @property
    def free_mb(self) -> float:        # F(S_i, t)
        return self.capacity_mb - self.used_mb

    def pr_failure(self, delta_t_days: float) -> float:
        from .reliability import pr_failure

        return float(pr_failure(self.annual_failure_rate, delta_t_days / DAYS_PER_YEAR))

    def can_fit(self, chunk_mb: float) -> bool:
        return not self.failed and self.free_mb >= chunk_mb


@dataclasses.dataclass(frozen=True)
class DataItem:
    """A store request (paper Table 1, per-item knowns)."""

    item_id: int
    size_mb: float                     # size(d)
    arrival_time: float                # submission timestamp (seconds)
    delta_t_days: float                # retention Delta t_d
    reliability_target: float          # RT(d) in (0, 1)


@dataclasses.dataclass(frozen=True)
class Placement:
    """An algorithm's decision for one item: (K, P, M) of Problem 1."""

    k: int                             # data chunks K_d
    p: int                             # parity chunks P_d
    node_ids: tuple[int, ...]          # mapping M_d, |M| == k + p

    @property
    def n(self) -> int:
        return self.k + self.p

    def chunk_size_mb(self, size_mb: float) -> float:
        # ceil at MB-fraction granularity is not meaningful for floats;
        # the paper's ceil(size/K) is over MB — we keep exact division,
        # consistent for all algorithms being compared.
        return size_mb / self.k

    def __post_init__(self):
        if self.k < 1 or self.p < 0:
            raise ValueError(f"invalid EC parameters K={self.k} P={self.p}")
        if len(self.node_ids) != self.k + self.p:
            raise ValueError(
                f"mapping has {len(self.node_ids)} nodes, need K+P={self.k + self.p}"
            )
        if len(set(self.node_ids)) != len(self.node_ids):
            raise ValueError("mapping nodes must be distinct")


@dataclasses.dataclass
class ClusterView:
    """Mutable view of the cluster the scheduler sees at decision time.

    Thin wrapper over parallel numpy arrays so the algorithms can operate
    vectorized; kept in sync by the simulator/checkpoint manager.
    """

    capacity_mb: np.ndarray
    used_mb: np.ndarray
    write_bw: np.ndarray
    read_bw: np.ndarray
    afr: np.ndarray
    alive: np.ndarray                  # bool mask
    #: failure-domain topology: rack/zone id per node.  Optional at
    #: construction (older call sites build the view positionally from
    #: the six flat arrays); normalized to int64 zeros in __post_init__
    #: so a topology-free cluster is "one rack in one zone".
    rack: Optional[np.ndarray] = None
    zone: Optional[np.ndarray] = None

    def __post_init__(self):
        n = int(self.capacity_mb.shape[0])
        if self.rack is None:
            self.rack = np.zeros(n, dtype=np.int64)
        else:
            self.rack = np.asarray(self.rack, dtype=np.int64)
        if self.zone is None:
            self.zone = np.zeros(n, dtype=np.int64)
        else:
            self.zone = np.asarray(self.zone, dtype=np.int64)

    @classmethod
    def from_nodes(cls, nodes: Sequence[StorageNode]) -> "ClusterView":
        return cls(
            capacity_mb=np.array([n.capacity_mb for n in nodes], dtype=np.float64),
            used_mb=np.array([n.used_mb for n in nodes], dtype=np.float64),
            write_bw=np.array([n.write_bw for n in nodes], dtype=np.float64),
            read_bw=np.array([n.read_bw for n in nodes], dtype=np.float64),
            afr=np.array([n.annual_failure_rate for n in nodes], dtype=np.float64),
            alive=np.array([not n.failed for n in nodes], dtype=bool),
            rack=np.array([getattr(n, "rack", 0) for n in nodes], dtype=np.int64),
            zone=np.array([getattr(n, "zone", 0) for n in nodes], dtype=np.int64),
        )

    #: fields shared (and write-protected) by :meth:`share_snapshot`.
    _ARRAY_FIELDS = (
        "capacity_mb", "used_mb", "write_bw", "read_bw",
        "afr", "alive", "rack", "zone",
    )

    #: bound on cached ``fail_probs`` retention windows per view.
    _MAX_FP_ANCHORS = 16

    @property
    def n_nodes(self) -> int:
        return int(self.capacity_mb.shape[0])

    @property
    def free_mb(self) -> np.ndarray:
        return self.capacity_mb - self.used_mb

    def live_ids(self) -> np.ndarray:
        return np.nonzero(self.alive)[0]

    def fail_probs(self, delta_t_days: float) -> np.ndarray:
        """Per-node failure probabilities for one retention window.

        Cached per ``delta_t`` against an AFR-content mirror, so repeated
        decisions stop re-exponentiating all N rates: when the AFRs are
        untouched the cached vector is returned; when some entries
        changed (or the view grew by a join) only the touched tail/
        entries are recomputed — ``pr_failure`` is elementwise, so the
        sliced recompute is bit-equal to the full-array one.  Returned
        arrays are write-protected shared state; callers must copy
        before mutating."""
        from .reliability import pr_failure

        key = float(delta_t_days)
        cache: dict = self.__dict__.setdefault("_fp_cache", {})
        mirror: Optional[np.ndarray] = self.__dict__.get("_fp_afr")
        afr = self.afr
        if mirror is None or not (
            mirror.shape == afr.shape and np.array_equal(mirror, afr)
        ):
            self._fp_refresh(mirror, cache)
        fp = cache.get(key)
        if fp is None:
            if len(cache) >= self._MAX_FP_ANCHORS:
                cache.clear()
            fp = pr_failure(afr, key / DAYS_PER_YEAR)
            fp = np.asarray(fp, dtype=np.float64)
            fp.setflags(write=False)
            cache[key] = fp
        return fp

    def _fp_refresh(self, mirror: Optional[np.ndarray], cache: dict) -> None:
        """Touched-entry refresh of every cached fail-prob vector after
        an AFR content change (edit or elastic join)."""
        from .reliability import pr_failure

        afr = self.afr
        n = afr.shape[0]
        if mirror is None or n < mirror.shape[0]:
            cache.clear()  # shrink or first use: no prefix to reuse
        else:
            old = mirror.shape[0]
            changed = np.nonzero(mirror != afr[:old])[0]
            for key in list(cache):
                vec = cache[key]
                new = np.empty(n, dtype=np.float64)
                new[:old] = vec
                if changed.size:
                    new[changed] = pr_failure(afr[changed], key / DAYS_PER_YEAR)
                if n > old:
                    new[old:] = pr_failure(afr[old:], key / DAYS_PER_YEAR)
                new.setflags(write=False)
                cache[key] = new
        self.__dict__["_fp_afr"] = afr.copy()

    # -- copy-on-write mutation plumbing ------------------------------------

    def writable(self, name: str) -> np.ndarray:
        """The named field array, un-shared for writing.

        After :meth:`share_snapshot` the view's arrays are write-
        protected (they are shared with the published snapshot); the
        first mutation of a field copies it — the snapshot keeps the
        original — and every mutator below routes through here.  Cost is
        one flag check per mutation and one O(N) copy per field per
        snapshot *only if the field actually changes*."""
        arr = getattr(self, name)
        if not arr.flags.writeable:
            arr = arr.copy()
            setattr(self, name, arr)
            bufs = self.__dict__.get("_growth_bufs")
            if bufs:  # the old growth buffer now backs the snapshot
                bufs.pop(name, None)
        return arr

    def share_snapshot(self) -> "ClusterView":
        """Read-only snapshot sharing this view's buffers (copy-on-write).

        O(1): no array is copied at publish time.  Both the snapshot and
        the live view's arrays become write-protected; the live view
        un-shares a field lazily on its next mutation (see
        :meth:`writable`), so a snapshot costs one copy per field that
        actually changes afterwards — not eight O(N) copies per window.
        Direct out-of-band writes to a shared array raise ``ValueError``
        (loud, instead of silently corrupting a published epoch)."""
        for name in self._ARRAY_FIELDS:
            getattr(self, name).setflags(write=False)
        return ClusterView(
            self.capacity_mb, self.used_mb, self.write_bw, self.read_bw,
            self.afr, self.alive, self.rack, self.zone,
        )

    # -- mutators ------------------------------------------------------------

    def commit(self, placement: Placement, chunk_mb: float) -> None:
        ids = np.asarray(placement.node_ids)
        self.writable("used_mb")[ids] += chunk_mb

    def charge(self, node_ids: Sequence[int], chunk_mb: float) -> None:
        """Reserve ``chunk_mb`` on each node (repair reservations) —
        the exact array op :meth:`commit` performs."""
        self.writable("used_mb")[np.asarray(list(node_ids))] += chunk_mb

    def release(self, node_ids: Sequence[int], chunk_mb: float) -> None:
        ids = np.asarray(list(node_ids))
        used = self.writable("used_mb")
        used[ids] -= chunk_mb
        np.maximum(used, 0.0, out=used)

    def fail_node(self, node_id: int) -> None:
        self.writable("alive")[node_id] = False

    def fail_stop(self, node_id: int) -> None:
        """Fail-stop: the node dies and its bytes are permanently lost
        (the churn paths' canonical failure op)."""
        self.writable("alive")[node_id] = False
        self.writable("used_mb")[node_id] = 0.0

    def heal_node(self, node_id: int) -> None:
        """Fail-stop recovery: the node returns alive and *empty* (its
        chunks were permanently lost when it failed)."""
        self.writable("alive")[node_id] = True
        self.writable("used_mb")[node_id] = 0.0

    def restore(self, used_mb: np.ndarray, alive: np.ndarray) -> None:
        """Overwrite occupancy/liveness from a snapshot (rollback)."""
        self.writable("used_mb")[:] = used_mb
        self.writable("alive")[:] = alive

    def nodes_in_rack(self, rack_id: int) -> np.ndarray:
        return np.nonzero(self.rack == rack_id)[0]

    def nodes_in_zone(self, zone_id: int) -> np.ndarray:
        return np.nonzero(self.zone == zone_id)[0]

    def add_node(self, node: StorageNode) -> int:
        """Append a node to the view (elastic join) and return its id.

        Views index nodes by position, so a joining node's id is always
        the previous ``n_nodes`` regardless of the ``node_id`` recorded
        on the :class:`StorageNode`.

        Growth is amortized O(1): each per-node field is a length-n view
        over a geometrically doubled backing buffer, so long
        ``node_join_schedule``s don't pay np.append's O(n) copy per join.
        External semantics are unchanged — shape, dtype and values of the
        exposed arrays match the old append-per-call implementation
        exactly, and any rebinding invalidates stale mirrors by shape
        (see ``core.incremental``'s trackers)."""
        nid = self.n_nodes
        bufs = self.__dict__.get("_growth_bufs")
        if bufs is None:
            bufs = {}
            self.__dict__["_growth_bufs"] = bufs
        for name, value in (
            ("capacity_mb", float(node.capacity_mb)),
            ("used_mb", float(node.used_mb)),
            ("write_bw", float(node.write_bw)),
            ("read_bw", float(node.read_bw)),
            ("afr", float(node.annual_failure_rate)),
            ("alive", not node.failed),
            ("rack", int(getattr(node, "rack", 0))),
            ("zone", int(getattr(node, "zone", 0))),
        ):
            arr = getattr(self, name)
            buf = bufs.get(name)
            # Only reuse a buffer the current field array is a prefix view
            # of — anything else (fresh view, external rebinding, buffer
            # full, or an array shared read-only with a snapshot, whose
            # backing buffer must not be written through) reallocates
            # with doubled headroom.
            if (
                buf is None
                or arr.base is not buf
                or buf.shape[0] <= nid
                or not arr.flags.writeable
            ):
                buf = np.empty(max(4, 2 * (nid + 1)), dtype=arr.dtype)
                buf[:nid] = arr
                bufs[name] = buf
            buf[nid] = value
            setattr(self, name, buf[: nid + 1])
        return nid

    def copy(self) -> "ClusterView":
        return ClusterView(
            self.capacity_mb.copy(), self.used_mb.copy(), self.write_bw.copy(),
            self.read_bw.copy(), self.afr.copy(), self.alive.copy(),
            self.rack.copy(), self.zone.copy(),
        )


@dataclasses.dataclass(frozen=True)
class ECTimeModel:
    """Linear encode/decode cost model (paper §4.4: linear regression over
    measurements across sizes and (K, P); functional form follows the IDA
    complexity analysis the paper's Fig. 1 is based on [28]).

    Reed-Solomon work: each of the P parity chunks is a K-term GF dot
    product over chunk bytes -> encode work = P * size multiply-adds; a
    worst-case decode re-applies a KxK matrix -> K * size multiply-adds.
    Hence (matching Fig. 1: encode ~flat in K at fixed P, decode linear
    in K):

        T_encode(N, K, size) = e0 + e_byte*size + e_mult*(N-K)*size
        T_decode(K, size)    = d0 + d_byte*size + d_mult*K*size

    Replication (K == 1) has no coding math (paper §3.1:
    T_encode = T_decode = 0); only the constant dispatch cost remains.

    Defaults are calibrated against our own GF(2^8) codec measurements
    (benchmarks/fig1_encode_breakdown.py recalibrates; see EXPERIMENTS.md).
    """

    e0: float = 1e-3
    e_byte: float = 2.0e-4             # s per MB striped (memcpy-level)
    e_mult: float = 1.2e-3             # s per parity-MB GF dot-product
    d0: float = 1e-3
    d_byte: float = 2.0e-4
    d_mult: float = 1.2e-3             # s per (K * MB) GF dot-product

    def t_encode(self, n: int, k: int, size_mb: float) -> float:
        if k == 1:
            return self.e0
        return self.e0 + self.e_byte * size_mb + self.e_mult * (n - k) * size_mb

    def t_decode(self, k: int, size_mb: float) -> float:
        if k == 1:
            return self.d0
        return self.d0 + self.d_byte * size_mb + self.d_mult * k * size_mb

    # Elementwise variants over parallel (n, k) arrays — the ONLY other
    # place the cost model's functional form lives; keep in lockstep with
    # the scalar methods above (D-Rex SC scores all candidate windows
    # through these).

    def t_encode_many(self, n, k, size_mb: float):
        n = np.asarray(n)
        k = np.asarray(k)
        return np.where(
            k == 1,
            self.e0,
            self.e0 + self.e_byte * size_mb + self.e_mult * (n - k) * size_mb,
        )

    def t_decode_many(self, k, size_mb: float):
        k = np.asarray(k)
        return np.where(
            k == 1,
            self.d0,
            self.d0 + self.d_byte * size_mb + self.d_mult * k * size_mb,
        )


@dataclasses.dataclass(frozen=True)
class PlacementConstraints:
    """Failure-domain constraints on a mapping (rack/zone topology).

    A mapping satisfies the constraints when no more than ``max_per_rack``
    of its chunks share a rack, no more than ``max_per_zone`` share a
    zone, and the mapping spans at least ``min(min_racks, n)`` distinct
    racks and ``min(min_zones, n)`` distinct zones (the ``min`` keeps
    small mappings satisfiable: a 2-chunk mapping cannot span 3 racks).

    ``None`` caps are unlimited; the all-default instance is
    :attr:`unconstrained` and must behave exactly like passing no
    constraints at all.  With ``max_per_rack <= P`` a single rack event
    destroys at most P chunks of any conforming item, which keeps the
    item decodable — the durability contract the invariant harness pins.
    """

    max_per_rack: Optional[int] = None
    max_per_zone: Optional[int] = None
    min_racks: int = 1
    min_zones: int = 1

    def __post_init__(self):
        for label, v in (("max_per_rack", self.max_per_rack),
                         ("max_per_zone", self.max_per_zone)):
            if v is not None and v < 1:
                raise ValueError(f"{label} must be >= 1 or None, got {v}")
        if self.min_racks < 1 or self.min_zones < 1:
            raise ValueError("min_racks/min_zones must be >= 1")

    @property
    def unconstrained(self) -> bool:
        return (
            self.max_per_rack is None
            and self.max_per_zone is None
            and self.min_racks <= 1
            and self.min_zones <= 1
        )

    def satisfied_by(
        self, node_ids: Sequence[int], rack: np.ndarray, zone: np.ndarray
    ) -> bool:
        """Whether a mapping meets caps and spread under this topology."""
        ids = np.asarray(list(node_ids), dtype=np.int64)
        n = ids.shape[0]
        if n == 0:
            return True
        racks = rack[ids]
        zones = zone[ids]
        if self.max_per_rack is not None:
            if np.bincount(racks - racks.min()).max() > self.max_per_rack:
                return False
        if self.max_per_zone is not None:
            if np.bincount(zones - zones.min()).max() > self.max_per_zone:
                return False
        if np.unique(racks).shape[0] < min(self.min_racks, n):
            return False
        if np.unique(zones).shape[0] < min(self.min_zones, n):
            return False
        return True


@dataclasses.dataclass(frozen=True)
class Decision:
    """Result of one scheduling call."""

    placement: Optional[Placement]     # None => write failed
    # Diagnostics for benchmarks / EXPERIMENTS.md:
    candidates_considered: int = 0
    reason: str = ""
    #: dependency window for batched rescoring: the node ids whose free
    #: space this decision's score depended on, or ``None`` when the
    #: score depends on cluster-global state (the conservative default —
    #: any commit invalidates it).  Only meaningful from schedulers that
    #: declare the ``windowed_scoring`` capability; consumed by
    #: ``PlacementEngine._place_many_batched``.
    window: Optional[tuple[int, ...]] = None
