"""D-Rex core: reliability model (§3.1) + placement algorithms (§4, §5.2)."""

from .reliability import (
    batch_pr_avail_exact,
    meets_target,
    min_parity_for_target,
    poisson_binomial_cdf,
    pr_avail,
    pr_failure,
)
from .types import (
    ClusterView,
    DataItem,
    Decision,
    ECTimeModel,
    Placement,
    StorageNode,
)
from .algorithms import (
    DAOSAdaptive,
    DRexLB,
    DRexSC,
    GreedyLeastUsed,
    GreedyMinStorage,
    RandomSpread,
    SCHEDULER_NAMES,
    Scheduler,
    StaticEC,
    make_scheduler,
    saturation_score,
)

__all__ = [
    "pr_failure",
    "pr_avail",
    "poisson_binomial_cdf",
    "meets_target",
    "min_parity_for_target",
    "batch_pr_avail_exact",
    "StorageNode",
    "DataItem",
    "Placement",
    "ClusterView",
    "ECTimeModel",
    "Decision",
    "Scheduler",
    "GreedyMinStorage",
    "GreedyLeastUsed",
    "DRexLB",
    "DRexSC",
    "StaticEC",
    "DAOSAdaptive",
    "RandomSpread",
    "make_scheduler",
    "saturation_score",
    "SCHEDULER_NAMES",
]
