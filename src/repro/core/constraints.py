"""Failure-domain constraint mechanics shared by schedulers and the engine.

:class:`~repro.core.types.PlacementConstraints` is the declarative side
(caps + spread); this module is the operational side, built around one
observation that keeps the jitted kernels untouched:

* :func:`constrained_order` greedily admits nodes from a scheduler's own
  sorted candidate order while no failure domain exceeds its cap.  The
  admitted *set* as a whole satisfies the caps, therefore **every subset
  of it does** (domain counts only shrink under subsetting).  D-Rex SC's
  contiguous windows, D-Rex LB's prefix grid and both greedy rules all
  select subsets of the order they are handed — so feeding them the
  admitted order makes every decision cap-conforming by construction,
  with zero kernel changes.  An admitted order is a subsequence of the
  input, so a free-descending input stays free-descending (the kernels'
  sortedness assumptions hold).
* :func:`repair_mapping` is the swap-based post-pass: the registry-wide
  fallback for schedulers that do not declare ``topology_aware``, and
  the spread-width enforcer for those that do (caps are handled by the
  admitted order; spread needs a whole-mapping view).  It swaps the
  cheapest over-cap chunk (least free space in an over-cap domain) to
  the best out-of-domain candidate, then fixes spread the same way, and
  finally re-checks Eq. 3 feasibility so a swap can never silently trade
  durability for topology.

Greedily admitting under caps is WLOG for prefix-greedy choice rules:
any excluded node is dominated, under the scheduler's own sort key, by
the cap's worth of same-domain nodes admitted before it.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from .types import ClusterView, Placement, PlacementConstraints

__all__ = ["constrained_order", "repair_mapping", "has_caps"]


def has_caps(constraints: Optional[PlacementConstraints]) -> bool:
    """Whether the constraints include per-domain caps (the part the
    admitted candidate order enforces; spread is the post-pass's job)."""
    return constraints is not None and (
        constraints.max_per_rack is not None
        or constraints.max_per_zone is not None
    )


def _occurrence_rank(values: np.ndarray) -> np.ndarray:
    """For each element, how many earlier elements share its value.

    Stable argsort groups equal values in original order, so the offset
    from each group's start is exactly the prior-occurrence count."""
    n = values.shape[0]
    idx = np.argsort(values, kind="stable")
    sorted_vals = values[idx]
    starts = np.nonzero(np.r_[True, np.diff(sorted_vals) != 0])[0]
    group_start = np.repeat(starts, np.diff(np.r_[starts, n]))
    ranks = np.empty(n, dtype=np.int64)
    ranks[idx] = np.arange(n) - group_start
    return ranks


def constrained_order(
    order: np.ndarray,
    rack: np.ndarray,
    zone: np.ndarray,
    constraints: Optional[PlacementConstraints],
) -> np.ndarray:
    """Greedy cap-admitted subsequence of a sorted candidate order.

    Walks ``order`` admitting each node while its rack/zone counts among
    already-admitted nodes stay below the caps; over-cap nodes are
    dropped.  Returns ``order`` unchanged (same object) when there are
    no caps, so the unconstrained path is bit-identical to before this
    module existed.
    """
    if not has_caps(constraints):
        return order
    order = np.asarray(order)
    cap_r = constraints.max_per_rack
    cap_z = constraints.max_per_zone
    if cap_r is not None and cap_z is None:
        return order[_occurrence_rank(rack[order]) < cap_r]
    if cap_z is not None and cap_r is None:
        return order[_occurrence_rank(zone[order]) < cap_z]
    # Both axes capped: sequential admission (a rack-rejected node must
    # not consume a zone slot, so the two ranks are not independent).
    r_cnt: dict[int, int] = {}
    z_cnt: dict[int, int] = {}
    keep = np.zeros(order.shape[0], dtype=bool)
    r_arr = rack[order]
    z_arr = zone[order]
    for i in range(order.shape[0]):
        r = int(r_arr[i])
        z = int(z_arr[i])
        if r_cnt.get(r, 0) < cap_r and z_cnt.get(z, 0) < cap_z:
            keep[i] = True
            r_cnt[r] = r_cnt.get(r, 0) + 1
            z_cnt[z] = z_cnt.get(z, 0) + 1
    return order[keep]


def _counts(ids: Sequence[int], axis: np.ndarray) -> dict[int, int]:
    out: dict[int, int] = {}
    for i in ids:
        d = int(axis[i])
        out[d] = out.get(d, 0) + 1
    return out


def _admissible(
    node: int,
    ids: list[int],
    skip: int,
    rack: np.ndarray,
    zone: np.ndarray,
    c: PlacementConstraints,
) -> bool:
    """Would swapping ``skip`` -> ``node`` keep every capped axis within
    its cap?  (Counts are over the post-swap mapping; a pre-existing
    violation elsewhere is allowed to persist — it gets its own swap.)"""
    for axis, cap in ((rack, c.max_per_rack), (zone, c.max_per_zone)):
        if cap is None:
            continue
        d = int(axis[node])
        cnt = sum(1 for i in ids if i != skip and int(axis[i]) == d)
        if cnt + 1 > cap:
            return False
    return True


def repair_mapping(
    placement: Placement,
    cluster: ClusterView,
    constraints: PlacementConstraints,
    chunk_mb: float,
    *,
    min_parity: Optional[Callable[[np.ndarray], int]] = None,
    fail_probs: Optional[np.ndarray] = None,
) -> Optional[tuple[Placement, int]]:
    """Swap chunks until ``placement`` satisfies ``constraints``.

    Returns ``(new_placement, n_swaps)`` or ``None`` when the constraints
    cannot be met (no admissible candidate, or the swapped mapping no
    longer meets the reliability target).  Pure: the view is only read.
    Deterministic: victims are the least-free member of the worst domain
    (ties on node id), replacements the freest admissible candidate.

    When ``min_parity`` and ``fail_probs`` are provided, the repaired
    mapping must still satisfy Eq. 3 at the original parity count
    (``min_parity(fail_probs[mapping]) <= placement.p``).
    """
    ids = list(int(i) for i in placement.node_ids)
    n = len(ids)
    rack, zone = cluster.rack, cluster.zone
    free = cluster.free_mb
    in_map = set(ids)
    pool = [
        int(i)
        for i in cluster.live_ids()
        if int(i) not in in_map and free[i] >= chunk_mb
    ]
    pool.sort(key=lambda i: (-free[i], i))
    swaps = 0

    def swap(victim: int, repl: int) -> None:
        nonlocal swaps
        ids[ids.index(victim)] = repl
        pool.remove(repl)
        swaps += 1

    # Phase 1 — caps: evict the cheapest chunk of each over-cap domain.
    for axis, cap in ((rack, constraints.max_per_rack),
                      (zone, constraints.max_per_zone)):
        if cap is None:
            continue
        for _ in range(2 * n):
            counts = _counts(ids, axis)
            over = {d for d, cnt in counts.items() if cnt > cap}
            if not over:
                break
            victim = min(
                (i for i in ids if int(axis[i]) in over),
                key=lambda i: (free[i], -i),
            )
            repl = next(
                (
                    cand
                    for cand in pool
                    if _admissible(cand, ids, victim, rack, zone, constraints)
                ),
                None,
            )
            if repl is None:
                return None
            swap(victim, repl)

    # Phase 2 — spread: promote a candidate from an unrepresented domain,
    # evicting from the most-populated one.  Bounded alternation because
    # a zone swap may narrow rack spread and vice versa.
    need_r = min(constraints.min_racks, n)
    need_z = min(constraints.min_zones, n)
    for _ in range(2 * n):
        fixed = True
        for axis, other, need in ((rack, zone, need_r), (zone, rack, need_z)):
            counts = _counts(ids, axis)
            if len(counts) >= need:
                continue
            fixed = False
            repl = next(
                (
                    cand
                    for cand in pool
                    if int(axis[cand]) not in counts
                    and _admissible(cand, ids, -1, rack, zone, constraints)
                ),
                None,
            )
            if repl is None:
                return None
            # Evict from the most-populated domain of this axis, preferring
            # victims whose *other*-axis domain keeps >= 2 members so the
            # swap cannot undo the other axis's spread.
            crowd = max(counts.items(), key=lambda kv: (kv[1], -kv[0]))[0]
            other_counts = _counts(ids, other)
            members = [i for i in ids if int(axis[i]) == crowd]
            safe = [i for i in members if other_counts[int(other[i])] >= 2]
            victim = min(safe or members, key=lambda i: (free[i], -i))
            swap(victim, repl)
        if fixed:
            break
    if not constraints.satisfied_by(ids, rack, zone):
        return None

    if min_parity is not None and fail_probs is not None:
        mp = min_parity(fail_probs[np.asarray(ids)])
        if not (0 <= mp <= placement.p):
            return None
    if swaps == 0:
        return placement, 0
    return (
        Placement(k=placement.k, p=placement.p, node_ids=tuple(ids)),
        swaps,
    )
