"""Jitted jax kernel for D-Rex SC's (starts x window-lengths) scoring.

``DRexSC`` enumerates up to ``MAX_MAPPINGS`` contiguous windows of the
free-space-sorted live nodes and scores each on (duration, storage,
saturation) before a Pareto-front selection (Alg. 2).  The scalar numpy
path (:meth:`DRexSC.place_scalar`) remains the reference oracle; this
module computes the same decision as one jitted kernel over a padded
(starts x window-lengths) tensor:

* the per-start Poisson-binomial parity frontiers become one masked DP
  over *all* suffixes at once (a ``(starts, prefix-length)`` tensor, the
  jax twin of :meth:`ParityFrontier.upto_many`);
* capacity checks and bandwidth bottlenecks are prefix-min tensors;
* the enumerated windows (at most ``budget`` of them, in the scalar
  path's start-major order) are compacted to a fixed-width candidate
  axis, scored, and Pareto-masked in-kernel;
* the whole thing is vmapped over a batch of items sharing one cluster
  snapshot, which is what lets ``PlacementEngine.place_many`` score a
  queue of items in a single call.

Everything runs in float64 under a scoped ``jax.experimental.enable_x64``
(the DP discriminates seven-nines availability targets, which float32
cannot represent), so the kernel is decision-equivalent to the numpy
oracle; tests/test_sc_vectorized.py enforces this bit-for-bit on pinned
traces.  When jax is unavailable the callers fall back to the oracle.

**Failure-domain constraints.**  Under ``PlacementConstraints`` the
candidate-node axis arrives already masked: ``DRexSC`` feeds the kernel
the cap-admitted subsequence of its free-descending order
(``core.constraints.constrained_order``, with per-domain
representatives kept by ``prefilter.domain_slice``), so every
enumerated window is a subset of a cap-conforming set and the in-kernel
math — including the saturation scale, which stays anchored to the
*cluster-wide* live count via ``n_live`` — is unchanged.  Unconstrained
calls pass the identical arrays as before, keeping decisions
bit-identical to the pinned goldens.
"""

from __future__ import annotations

import functools
import math

import numpy as np

from . import shapes

try:  # pragma: no cover - exercised implicitly by every SC test
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64

    _JAX_OK = True
except Exception:  # jax is an optional accelerator dependency
    _JAX_OK = False

__all__ = ["kernel_available", "score_windows_batch"]


def kernel_available() -> bool:
    """True when the jitted scoring path can run (jax importable)."""
    return _JAX_OK


if _JAX_OK:

    @functools.partial(jax.jit, static_argnums=(0, 1, 2))
    def _score_windows(
        S_pad,
        L_pad,
        budget,
        probs_b,     # (B, L_pad) per-item fail probs in free-desc order
        size_b,      # (B,)
        target_b,    # (B,)
        smin_b,      # (B,) running smallest-item anchor per item
        fbase_b,     # (B,) sum of per-node saturation over live nodes
        ssat_b,      # (B,) system saturation scalar
        free,        # (L_pad,) shared sorted cluster snapshot ------------
        wb,
        rb,
        used,
        cap,
        L,           # live-node count (traced; padding is masked via L)
        inv_l,       # 1 / max(2, L)
        log_l,       # log(max(2, L))
        tm,          # (6,) ECTimeModel params e0,e_byte,e_mult,d0,d_byte,d_mult
    ):
        K_c = min(budget, S_pad * L_pad)  # enumerated windows <= budget
        s_idx = jnp.arange(S_pad)
        i_idx = jnp.arange(L_pad)
        act2 = i_idx[None, :] >= s_idx[:, None]  # (S, L): end >= start

        # Bottleneck bandwidth of window [s..i] is a running min over the
        # suffix starting at s (exact: min has no rounding).
        wb_min = lax.cummin(jnp.where(act2, wb[None, :], jnp.inf), axis=1)
        rb_min = lax.cummin(jnp.where(act2, rb[None, :], jnp.inf), axis=1)

        # Scalar enumeration order and budget: start s contributes
        # min(L-1-s, remaining budget) windows, starts in ascending order.
        w_full = jnp.clip(L - 1 - s_idx, 0, None)
        cum_before = jnp.concatenate(
            [jnp.zeros(1, w_full.dtype), jnp.cumsum(w_full)[:-1]]
        )
        allowed = jnp.clip(budget - cum_before, 0, w_full)
        win_idx = i_idx[None, :] - s_idx[:, None] - 1  # 0 <=> window n=2
        in_budget = (win_idx >= 0) & (win_idx < allowed[:, None])
        in_budget &= i_idx[None, :] <= L - 1

        # Compact the (S, L) window grid to a fixed candidate axis in the
        # scalar path's (start-major, length-minor) order: a stable sort
        # moves the <= budget enumerated windows to the front unpermuted.
        flat_order = jnp.argsort(
            jnp.where(in_budget.ravel(), 0, 1).astype(jnp.int32)
        )[:K_c]
        s_w = flat_order // L_pad
        i_w = flat_order % L_pad
        enumerated = in_budget.ravel()[flat_order]
        n_w = i_w - s_w + 1

        e0, e_byte, e_mult, d0, d_byte, d_mult = (
            tm[0], tm[1], tm[2], tm[3], tm[4], tm[5]
        )

        def saturation(x, c, smin):
            # Mirror of algorithms.saturation_score (elementwise, f64).
            span = jnp.maximum(c - smin, 1e-9)
            u = jnp.clip((x - smin) / span, 0.0, 1.0)
            return jnp.clip(inv_l * jnp.exp(log_l * u), 0.0, 1.0)

        def one(probs, size, target, smin, f_base_sum, sys_sat):
            # ---- parity frontier of every suffix, one masked DP --------
            def step(dp, i):
                p_i = probs[i]
                shifted = jnp.concatenate(
                    [jnp.zeros((S_pad, 1), dp.dtype), dp[:, :-1]], axis=1
                )
                new_dp = dp * (1.0 - p_i) + shifted * p_i
                dp = jnp.where((i >= s_idx)[:, None], new_dp, dp)
                cdf = jnp.cumsum(dp, axis=1)
                feas = cdf >= target
                j = jnp.argmax(feas, axis=1)
                n_len = i - s_idx + 1
                ok = jnp.any(feas, axis=1) & (j <= n_len - 1)
                return dp, jnp.where(ok, j, -1)

            dp0 = jnp.zeros((S_pad, L_pad + 1)).at[:, 0].set(1.0)
            _, cols = lax.scan(step, dp0, i_idx)
            mp = cols.T[s_w, i_w]  # (K_c,) min parity per window

            p_star = jnp.maximum(1, mp)
            k = n_w - p_star
            valid = enumerated & (mp >= 0) & (k >= 1)
            k_safe = jnp.where(valid, k, 1)
            chunk = size / k_safe
            # Mapping is free-desc sorted: the window min free is its
            # last node (index i).
            valid &= free[i_w] >= chunk

            enc = jnp.where(
                k_safe == 1,
                e0,
                e0 + e_byte * size + e_mult * (n_w - k_safe) * size,
            )
            dec = jnp.where(
                k_safe == 1, d0, d0 + d_byte * size + d_mult * k_safe * size
            )
            duration = (
                chunk / wb_min[s_w, i_w] + chunk / rb_min[s_w, i_w] + enc + dec
            )
            storage = chunk * n_w

            # Saturation objective: base sum over all live nodes plus the
            # delta of the window's nodes at projected occupancy.
            in_win = (i_idx[None, :] >= s_w[:, None]) & (
                i_idx[None, :] <= i_w[:, None]
            )
            delta = (
                (
                    saturation(used[None, :] + chunk[:, None], cap[None, :], smin)
                    - saturation(used, cap, smin)[None, :]
                )
                * in_win
            ).sum(axis=1)
            sat_obj = f_base_sum + delta

            # ---- Pareto front + relative-progress scoring (lines 11-17)
            dur_f = jnp.where(valid, duration, jnp.inf)
            sto_f = jnp.where(valid, storage, jnp.inf)
            sat_f = jnp.where(valid, sat_obj, jnp.inf)
            le = jnp.ones((K_c, K_c), bool)
            lt = jnp.zeros((K_c, K_c), bool)
            for c in (dur_f, sto_f, sat_f):
                le &= c[None, :] <= c[:, None]
                lt |= c[None, :] < c[:, None]
            front = ~jnp.any(le & lt, axis=1) & valid

            def progress(v):
                lo = jnp.min(jnp.where(front, v, jnp.inf))
                hi = jnp.max(jnp.where(front, v, -jnp.inf))
                return jnp.where(hi - lo <= 1e-12, 0.0, (hi - v) / (hi - lo))

            score = (1.0 - sys_sat) * progress(dur_f) + (
                progress(sto_f) + progress(sat_f)
            ) / 2.0
            best = jnp.argmax(jnp.where(front, score, -jnp.inf))
            bp = jnp.maximum(1, mp[best])
            return (
                jnp.any(valid),
                s_w[best],
                n_w[best],
                n_w[best] - bp,
                bp,
            )

        return jax.vmap(one)(
            probs_b, size_b, target_b, smin_b, fbase_b, ssat_b
        )


def _shape_plan(L: int, budget: int) -> tuple[int, int]:
    """Static (S_pad, L_pad) for a live-node count: L padded through the
    shared hysteresis-banded buckets (:mod:`repro.core.shapes`), starts
    covering every budgeted window."""
    L_pad = shapes.node_pad(L)
    if L_pad <= 64:
        return L_pad - 1, L_pad  # every start can matter; keep stable
    w = L - 1 - np.arange(L - 1)
    consider = min(int(w.sum()), budget)
    s_real = int(np.searchsorted(np.cumsum(w), consider) + 1)
    return min(L_pad - 1, shapes.start_pad(s_real)), L_pad


def score_windows_batch(
    probs_mat: np.ndarray,   # (B, L) per-item fail probs, free-desc order
    sizes: np.ndarray,       # (B,)
    targets: np.ndarray,     # (B,)
    smins: np.ndarray,       # (B,)
    fbase: np.ndarray,       # (B,)
    ssat: np.ndarray,        # (B,)
    free_s: np.ndarray,      # (L,) shared sorted cluster snapshot
    wb_s: np.ndarray,
    rb_s: np.ndarray,
    used_s: np.ndarray,
    cap_s: np.ndarray,
    budget: int,
    tm_params: tuple,        # (e0, e_byte, e_mult, d0, d_byte, d_mult)
    n_live: int | None = None,
):
    """Score every item's candidate windows against one shared snapshot.

    Returns ``(ok, s, n, k, p)`` int64 arrays of length B: the winning
    window start/length and EC parameters per item (undefined where
    ``ok`` is False).  Pure function of its arguments — callers own all
    cluster/scheduler state.

    ``n_live`` is the true live-node count when the node arrays are a
    top-M pre-filtered slice (see :mod:`repro.core.prefilter`): the
    ``1/L`` / ``log L`` saturation scale is an Alg. 2 property of the
    *cluster*, not of the slice handed to the kernel, so it must come
    from the caller.  Defaults to the array length (unfiltered call).
    """
    if not _JAX_OK:  # callers are expected to gate on kernel_available()
        raise RuntimeError("jax unavailable; use the scalar oracle path")
    B, L = probs_mat.shape
    if L < 2 or B == 0:
        z = np.zeros(B, dtype=np.int64)
        return z.astype(bool), z, z, z, z
    S_pad, L_pad = _shape_plan(L, budget)
    B_pad = shapes.batch_pad(B)
    shapes.record_compile("sc_kernel", (B_pad, S_pad, L_pad, int(budget)))

    def pad_nodes(a, fill):
        out = np.full(L_pad, fill, dtype=np.float64)
        out[:L] = a
        return out

    pm = np.zeros((B_pad, L_pad), dtype=np.float64)
    pm[:B, :L] = probs_mat

    def pad_items(a, fill):
        out = np.full(B_pad, fill, dtype=np.float64)
        out[:B] = a
        return out

    l_eff = max(2, L if n_live is None else int(n_live))
    with enable_x64():
        ok, s, n, k, p = _score_windows(
            S_pad,
            L_pad,
            int(budget),
            jnp.asarray(pm),
            jnp.asarray(pad_items(sizes, 1.0)),
            jnp.asarray(pad_items(targets, 0.5)),
            jnp.asarray(pad_items(smins, 1.0)),
            jnp.asarray(pad_items(fbase, 0.0)),
            jnp.asarray(pad_items(ssat, 0.0)),
            jnp.asarray(pad_nodes(free_s, -1.0)),
            jnp.asarray(pad_nodes(wb_s, 1.0)),
            jnp.asarray(pad_nodes(rb_s, 1.0)),
            jnp.asarray(pad_nodes(used_s, 0.0)),
            jnp.asarray(pad_nodes(cap_s, 1.0)),
            np.int64(L),
            np.float64(1.0 / l_eff),
            np.float64(math.log(l_eff)),
            jnp.asarray(np.asarray(tm_params, dtype=np.float64)),
        )
    return (
        np.asarray(ok)[:B],
        np.asarray(s, dtype=np.int64)[:B],
        np.asarray(n, dtype=np.int64)[:B],
        np.asarray(k, dtype=np.int64)[:B],
        np.asarray(p, dtype=np.int64)[:B],
    )
