"""Opt-in persistent (cross-process) XLA compilation cache.

The shape-bucket planner (:mod:`repro.core.shapes`) bounds how many
distinct static signatures a process compiles — geometric rungs plus a
hysteresis band keep the census small.  This module makes those few
compiles survive the process: with ``REPRO_JIT_CACHE=1`` in the
environment, jax's persistent compilation cache is pointed at a
directory (``REPRO_JIT_CACHE_DIR``, default ``results/.jax_cache/``) so
a benchmark or CI job's first decision pays a disk read instead of an
XLA compile when a previous run already compiled the same signature.
The bucketer is what makes the disk cache effective: stable pads mean
stable signatures mean cache hits.

Strictly opt-in and failure-proof: with the flag unset this module never
imports jax; with it set, every config knob is applied best-effort (a
jax build without the persistent-cache knobs just runs uncached).  The
cold-vs-warm first-decision latency the cache buys is stamped into the
``table2`` benchmark telemetry (``first_decision`` section) via
:func:`repro.telemetry.snapshot`.
"""

from __future__ import annotations

import os
import pathlib
from typing import Any, Optional

__all__ = [
    "DEFAULT_DIR",
    "ENV_DIR",
    "ENV_FLAG",
    "cache_dir",
    "configure",
    "enabled",
    "status",
]

#: set non-empty (and not 0/false/no) to activate the persistent cache.
ENV_FLAG = "REPRO_JIT_CACHE"
#: overrides the cache directory (default: results/.jax_cache).
ENV_DIR = "REPRO_JIT_CACHE_DIR"
DEFAULT_DIR = pathlib.Path("results") / ".jax_cache"

_state: dict[str, Any] = {
    "configured": False,
    "active": False,
    "dir": None,
    "error": None,
}


def enabled() -> bool:
    """True when the opt-in env flag is set."""
    return os.environ.get(ENV_FLAG, "").strip().lower() not in ("", "0", "false", "no")


def cache_dir() -> pathlib.Path:
    return pathlib.Path(os.environ.get(ENV_DIR) or DEFAULT_DIR)


def configure() -> bool:
    """Idempotently point jax's persistent compilation cache at
    :func:`cache_dir` when the env flag is set.

    Called on :mod:`repro.core.shapes` import, i.e. before any kernel
    module traces its first jit — the config must precede the first
    compile for that compile to be written to (or served from) disk.
    Returns True when the cache is active.  Never raises: a missing or
    knobless jax leaves the process running with in-memory jit only,
    with the failure recorded in :func:`status`.
    """
    if _state["configured"]:
        return _state["active"]
    _state["configured"] = True
    if not enabled():
        return False
    try:
        import jax

        d = cache_dir()
        d.mkdir(parents=True, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(d))
        # cache every compile however small — the placement kernels are
        # individually fast to compile but numerous across lanes
        for knob, val in (
            ("jax_persistent_cache_min_compile_time_secs", 0.0),
            ("jax_persistent_cache_min_entry_size_bytes", -1),
        ):
            try:
                jax.config.update(knob, val)
            except Exception:
                pass  # knob absent on this jax version: still cached, just gated
        _state["active"] = True
        _state["dir"] = str(d)
    except Exception as exc:  # jax missing/unimportable: stay opt-out
        _state["error"] = f"{type(exc).__name__}: {exc}"
    return _state["active"]


def status() -> dict[str, Any]:
    """Telemetry view: flag state, active dir, entry count, any error."""
    out = {
        "enabled": enabled(),
        "active": bool(_state["active"]),
        "dir": _state["dir"],
        "error": _state["error"],
    }
    if _state["active"] and _state["dir"]:
        try:
            out["entries"] = sum(1 for _ in pathlib.Path(_state["dir"]).iterdir())
        except OSError:
            out["entries"] = 0
    return out
