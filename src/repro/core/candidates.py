"""Incrementally maintained live-node candidate order (free desc, id asc).

Every prefix-greedy decision consumes ``Scheduler._live_sorted(cluster,
cluster.free_mb)`` — live node ids sorted free-space-descending with
ascending-id tie-break.  That is a *strict total order* over live nodes,
so there is exactly one sorted arrangement; any structure that maintains
it is bit-identical to the from-scratch stable argsort by construction.
This module maintains it across the cluster's mutation vocabulary —
commit / release / fail / heal / join — repositioning only the touched
nodes instead of re-sorting all N per decision:

* **O(p) fast path** — a commit (or release) changes the free space of
  its p mapped nodes only.  Each touched node's new key is written in
  place and verified against its cached neighbours under the total
  order; when every adjacency holds the arrangement is still *the*
  sorted one and the query returns the cached arrays untouched.
* **O(p log N) splice** — when a touched node actually moved past a
  neighbour (or a node died / was healed / joined), the touched set is
  deleted from the cached order in one vectorized pass and re-inserted
  at ``searchsorted`` positions (binary search on the key array, with an
  ascending-id bisect inside equal-key runs).  The surviving elements
  keep their relative order — they were sorted and their keys did not
  change — so the spliced arrangement is again the unique sorted one.
  No argsort runs; the O(N) terms are C-speed ``np.delete``/``np.insert``
  memmoves.
* **Self-healing** — the tracker mirrors ``(used_mb, alive)`` and
  validates the mirror against the live view on every query (vectorized
  array compares).  Any out-of-band mutation — a direct array write, a
  rollback, a mutation whose observe hook was not called — fails
  validation and triggers a from-scratch rebuild.  The observe hooks are
  an optimization, never a soundness requirement.

Exactness is pinned by tests/test_candidates.py (property suite over
random op interleavings, including equal-free tie churn and dead-node
resurrection) and tests/test_incremental_rescore.py (engine-level
bit-identity).  :class:`~repro.core.incremental.FreeOrderTracker` is an
alias of :class:`CandidateTracker`; both D-Rex trackers share the one
:class:`_UsedMirror` defined here.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

from .types import ClusterView

__all__ = ["CandidateTracker"]


class _UsedMirror:
    """Mirror of ``(used_mb, alive)`` that replays mutation deltas with
    the exact array ops :class:`ClusterView` performs, so a mirror that
    matched before a mutation matches (bitwise) after it."""

    def __init__(self):
        self.used: np.ndarray | None = None
        self.alive: np.ndarray | None = None

    def capture(self, cluster: ClusterView) -> None:
        self.used = cluster.used_mb.copy()
        self.alive = cluster.alive.copy()

    def matches(self, cluster: ClusterView) -> bool:
        return (
            self.used is not None
            and self.used.shape == cluster.used_mb.shape
            and np.array_equal(self.used, cluster.used_mb)
            and np.array_equal(self.alive, cluster.alive)
        )

    def apply_commit(self, node_ids, chunk_mb: float) -> bool:
        """Replay one commit; False when the mirror cannot absorb it."""
        if self.used is None:
            return False
        ids = np.asarray(node_ids)
        if ids.size == 0 or int(ids.max()) >= len(self.used):
            return False
        self.used[ids] += chunk_mb  # ClusterView.commit's exact op
        return True

    def apply_release(self, node_ids, chunk_mb: float) -> bool:
        """Replay :meth:`ClusterView.release`; False when the clamp would
        touch entries outside ``node_ids`` (a view that already held
        negative occupancy — pathological; the caller rebuilds)."""
        if self.used is None:
            return False
        ids = np.asarray(list(node_ids))
        if ids.size == 0 or int(ids.max()) >= len(self.used):
            return False
        neg_before = int(np.count_nonzero(self.used < 0.0))
        if neg_before:
            return False
        self.used[ids] -= chunk_mb
        np.maximum(self.used, 0.0, out=self.used)  # release's exact clamp
        return True

    def apply_fail_stop(self, node_ids) -> bool:
        """Replay :meth:`ClusterView.fail_stop`: dead and empty."""
        if self.used is None:
            return False
        ids = np.asarray(list(node_ids))
        if ids.size == 0 or int(ids.max()) >= len(self.used):
            return False
        self.alive[ids] = False
        self.used[ids] = 0.0
        return True

    def apply_heal(self, node_ids) -> bool:
        """Replay :meth:`ClusterView.heal_node`: alive and empty."""
        if self.used is None:
            return False
        ids = np.asarray(list(node_ids))
        if ids.size == 0 or int(ids.max()) >= len(self.used):
            return False
        self.alive[ids] = True
        self.used[ids] = 0.0
        return True

    def grow_to(self, cluster: ClusterView) -> bool:
        """Absorb an elastic join: extend the mirror with the live view's
        tail values (``add_node`` appends, never rewrites the prefix)."""
        if self.used is None:
            return False
        old = len(self.used)
        n = cluster.n_nodes
        if n < old:
            return False
        if n > old:
            used = np.empty(n, dtype=self.used.dtype)
            used[:old] = self.used
            used[old:] = cluster.used_mb[old:]
            alive = np.empty(n, dtype=self.alive.dtype)
            alive[:old] = self.alive
            alive[old:] = cluster.alive[old:]
            self.used, self.alive = used, alive
        return True


class CandidateTracker:
    """Maintains the free-desc live-node order across mutation deltas.

    :meth:`order` returns exactly what
    ``Scheduler._live_sorted(cluster, cluster.free_mb)`` would; the
    returned array is shared state — callers must not mutate it.
    :meth:`topm` slices the lazily-maintained top-M prefix for the
    candidate pre-filter.

    Counters: ``hits`` — queries served from the maintained order (fast
    path or splice); ``rebuilds`` — from-scratch argsorts (first query
    and out-of-band self-heals); ``splices`` — queries that repositioned
    a pending touched set.
    """

    def __init__(self):
        self._mirror = _UsedMirror()
        self._order: np.ndarray | None = None  # ids, free desc / id asc
        self._neg: np.ndarray | None = None    # -(free) per slot, ascending
        self._pos: np.ndarray | None = None    # node id -> slot, -1 absent
        self._touched: set[int] = set()        # ids pending reposition
        self.hits = 0
        self.rebuilds = 0
        self.splices = 0

    # -- queries ------------------------------------------------------------

    def invalidate(self) -> None:
        self._order = None
        self._neg = None
        self._pos = None
        self._touched.clear()
        self._mirror.used = None

    def order(self, cluster: ClusterView) -> np.ndarray:
        """The full maintained order (== fresh ``_live_sorted``)."""
        if self._order is None or not self._mirror.matches(cluster):
            return self._rebuild(cluster)
        if self._touched:
            self._splice(cluster)
        self.hits += 1
        return self._order

    def topm(self, cluster: ClusterView, m: int) -> np.ndarray:
        """Lazily-extracted top-M prefix of the maintained order."""
        return self.order(cluster)[:m]

    def hit_rate(self) -> float:
        total = self.hits + self.rebuilds
        return self.hits / total if total else 0.0

    # -- observe hooks ------------------------------------------------------

    def observe_commit(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Fold one committed placement (``used[ids] += chunk``) in."""
        if self._order is None:
            return
        if not self._mirror.apply_commit(node_ids, chunk_mb):
            self.invalidate()
            return
        self._reposition(node_ids, cluster)

    def observe_release(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Fold one release (``used[ids] -= chunk`` + clamp) in."""
        if self._order is None:
            return
        if not self._mirror.apply_release(node_ids, chunk_mb):
            self.invalidate()
            return
        self._reposition(node_ids, cluster)

    def observe_churn(self, kind: str, node_ids, cluster: ClusterView) -> None:
        """Fold a membership event in: ``fail`` (fail-stop: dead+empty),
        ``heal`` (alive+empty) or ``join`` (appended nodes).  Unknown
        kinds invalidate — the mirror then self-heals on the next query."""
        if self._order is None:
            return
        if kind == "fail":
            ok = self._mirror.apply_fail_stop(node_ids)
        elif kind == "heal":
            ok = self._mirror.apply_heal(node_ids)
        elif kind == "join":
            ok = self._mirror.grow_to(cluster)
        else:
            ok = False
        if not ok:
            self.invalidate()
            return
        self._mark(node_ids)

    # -- internals ----------------------------------------------------------

    def _rebuild(self, cluster: ClusterView) -> np.ndarray:
        self.rebuilds += 1
        ids = cluster.live_ids()
        neg = -cluster.free_mb[ids]
        perm = np.argsort(neg, kind="stable")  # key asc == free desc, ids asc in ties
        self._order = ids[perm]
        self._neg = neg[perm]
        pos = np.full(cluster.n_nodes, -1, dtype=np.int64)
        pos[self._order] = np.arange(len(self._order))
        self._pos = pos
        self._touched.clear()
        self._mirror.capture(cluster)
        return self._order

    def _mark(self, node_ids: Iterable[int]) -> None:
        """Queue ids for the next query's splice (no adjacency check)."""
        alive, pos = self._mirror.alive, self._pos
        for i in node_ids:
            i = int(i)
            if i >= len(alive):
                self.invalidate()
                return
            if alive[i] or (i < len(pos) and pos[i] >= 0):
                self._touched.add(i)

    def _reposition(self, node_ids, cluster: ClusterView) -> None:
        """O(p) fast path: write the touched keys in place and verify
        each against its neighbours under the strict total order
        ``(-free asc, id asc)``.  Sortedness of every adjacent pair under
        a strict total order implies the unique sorted arrangement, so a
        passing check leaves the cached order *the* answer.  On any
        violation the writes are reverted and the whole touched set is
        queued for the next query's splice (all-or-nothing: partial
        in-place moves cannot be verified pairwise)."""
        if self._touched:
            self._mark(node_ids)  # order already pending; skip the check
            return
        by, neg, pos = self._order, self._neg, self._pos
        used, alive = self._mirror.used, self._mirror.alive
        slots: list[tuple[int, int]] = []
        olds: list[float] = []
        for i in dict.fromkeys(int(x) for x in node_ids):
            if i >= len(alive):
                self.invalidate()
                return
            k = int(pos[i]) if i < len(pos) else -1
            if k < 0:
                if alive[i]:  # alive but absent from the order: stale
                    self.invalidate()
                continue  # delta on a dead node: order unaffected
            slots.append((i, k))
            olds.append(float(neg[k]))
        if self._order is None:  # invalidated above
            return

        def before(ka: float, ia: int, kb: float, ib: int) -> bool:
            return ka < kb or (ka == kb and ia < ib)

        # keys: -(free) computed exactly as the rebuild does
        cap = cluster.capacity_mb
        for i, k in slots:
            neg[k] = -(cap[i] - used[i])
        ok = True
        for i, k in slots:
            if k > 0 and not before(float(neg[k - 1]), int(by[k - 1]), float(neg[k]), i):
                ok = False
                break
            if k + 1 < len(by) and not before(
                float(neg[k]), i, float(neg[k + 1]), int(by[k + 1])
            ):
                ok = False
                break
        if not ok:
            for (i, k), old in zip(slots, olds):
                neg[k] = old
            self._mark(node_ids)

    def _splice(self, cluster: ClusterView) -> None:
        """Batch-reposition the pending touched set.

        Common case (every touched node alive and present — commits and
        releases, the per-decision traffic): a **windowed re-sort**.
        All stale slots sit inside ``[min slot, max slot]``, so the key
        array outside that span is clean and sorted; two binary searches
        extend the span to where the new keys could land, and only that
        window is re-sorted (``lexsort`` on (key, id) — exactly the
        strict total order) and its ``_pos`` entries rewritten.  Cost is
        O(w log w + log N) for window w — per-decision cost does not
        scale with N (the 100k gate in benchmarks/scale_cluster.py).

        Membership changes (fail / heal / join — rare events) take the
        general path: vectorized delete of the touched-present slots,
        then binary-search inserts (key bisect + ascending-id bisect
        inside the equal-key run) and an O(N) ``_pos`` rebuild."""
        touched = np.fromiter(self._touched, dtype=np.int64, count=len(self._touched))
        pos = self._pos
        if (
            int(touched.max()) < len(pos)
            and bool(np.all(pos[touched] >= 0))
            and bool(np.all(self._mirror.alive[touched]))
        ):
            self._splice_window(touched, cluster)
            return
        at = pos[touched[touched < len(pos)]]
        at = at[at >= 0]
        order, neg = self._order, self._neg
        if at.size:
            at = np.sort(at)
            order = np.delete(order, at)
            neg = np.delete(neg, at)
        alive, used = self._mirror.alive, self._mirror.used
        ins = touched[alive[touched]]
        if ins.size:
            ins = np.sort(ins)  # ascending ids
            keys = -(cluster.capacity_mb[ins] - used[ins])
            srt = np.argsort(keys, kind="stable")  # keeps id asc within ties
            ins, keys = ins[srt], keys[srt]
            where = np.empty(len(ins), dtype=np.int64)
            for j in range(len(ins)):
                lo = int(np.searchsorted(neg, keys[j], side="left"))
                hi = int(np.searchsorted(neg, keys[j], side="right"))
                where[j] = lo + int(np.searchsorted(order[lo:hi], ins[j]))
            order = np.insert(order, where, ins)
            neg = np.insert(neg, where, keys)
        self._order, self._neg = order, neg
        n = cluster.n_nodes
        if self._pos is None or len(self._pos) != n:
            self._pos = np.empty(n, dtype=np.int64)
        self._pos.fill(-1)
        self._pos[self._order] = np.arange(len(self._order))
        self._touched.clear()
        self.splices += 1

    def _splice_window(self, touched: np.ndarray, cluster: ClusterView) -> None:
        """Pure reposition (no membership change): re-sort only the span
        the moved keys can affect.  Entries before ``lo`` are strictly
        below every new key and entries from ``hi`` on strictly above
        (ties land inside the window), and untouched survivors inside
        the window were already ordered against both sides — so sorted
        prefix + sorted window + sorted suffix is *the* unique sorted
        arrangement."""
        order, neg, pos = self._order, self._neg, self._pos
        used = self._mirror.used
        keys = -(cluster.capacity_mb[touched] - used[touched])
        slots = pos[touched]
        lo0, hi0 = int(slots.min()), int(slots.max()) + 1
        lo = int(np.searchsorted(neg[:lo0], float(keys.min()), side="left"))
        hi = hi0 + int(np.searchsorted(neg[hi0:], float(keys.max()), side="right"))
        neg[slots] = keys  # stale slots are inside [lo0, hi0) ⊆ window
        sub_ids, sub_neg = order[lo:hi], neg[lo:hi]
        perm = np.lexsort((sub_ids, sub_neg))  # key asc, id asc in ties
        order[lo:hi] = sub_ids[perm]
        neg[lo:hi] = sub_neg[perm]
        pos[order[lo:hi]] = np.arange(lo, hi, dtype=np.int64)
        self._touched.clear()
        self.splices += 1
