"""Shared shape-bucket planning + compile-cache accounting for the jitted
placement kernels (:mod:`sc_kernel`, :mod:`greedy_kernel`,
:mod:`lb_kernel`).

Every jitted kernel is compiled once per *static shape signature* —
padded node count, padded batch size, candidate-axis width.  Before this
module each kernel planned its own pads (``_round_up(L, 8)`` ladders,
power-of-two batch pads), which meant an elastic cluster churning
through ``node_join``/``node_heal`` events triggered a fresh ~100 ms-1 s
XLA compile every time the live-node count crossed an 8-boundary — and
three kernels crossed three boundaries independently.  This module is
the one place pad planning lives:

* **Geometric rungs.**  Node-axis pads are exact multiples of 8 up to
  ``GEOMETRIC_FROM`` (the exact-DP regime, where compiles are cheap and
  sizes small), then grow by ``GROWTH`` per rung — so a cluster scaling
  from 100 to 200 nodes one join at a time recompiles O(log) times, not
  once per 8 joins.  Batch pads stay powers of two (already geometric).
* **Hysteresis band.**  A :class:`ShapeBucketer` remembers the last pad
  it issued per axis kind and keeps issuing it while the requested size
  stays within the band (``n <= held`` and ``held <= SHRINK_BAND x``
  the natural rung) — so join/heal oscillation around a rung boundary
  reuses one compiled shape instead of flapping between two, and a
  briefly-shrunk cluster does not recompile on the way back up.
* **Compile-cache counter.**  Kernels report the exact static signature
  of every batch call through :func:`record_compile`; distinct
  signatures are what XLA compiles, so :func:`compile_cache_stats`
  is a true recompile census.  Exposed as benchmark telemetry
  (``benchmarks/table2_overhead.py`` stamps it into the ``batched_lb``
  section) and pinned by the churn-budget regression test in
  tests/test_shapes.py.

Pads only ever *enlarge* the masked region of a kernel's tensors; the
kernels mask every padded lane via the traced live-node count, so
decisions are invariant to which bucket a call lands in (the
golden-equivalence suites run under arbitrary bucket histories).
"""

from __future__ import annotations

import threading

from . import jitcache

# Shapes is imported by every kernel module before its first jit trace,
# so this is the one spot early enough to point jax's persistent
# compilation cache at disk (opt-in via REPRO_JIT_CACHE=1; no-op — and
# no jax import — otherwise).  Stable bucketed pads => stable static
# signatures => the disk cache actually hits across processes.
jitcache.configure()

__all__ = [
    "ShapeBucketer",
    "batch_pad",
    "compile_cache_stats",
    "ec_block_pad",
    "issued_shapes",
    "node_pad",
    "record_compile",
    "reset",
    "start_pad",
]

#: pads are always multiples of this (vector-lane friendly; matches the
#: pre-bucketing ladders so small-cluster shapes are unchanged).
ALIGN = 8

#: largest exact-multiple-of-ALIGN rung; geometric growth above.  Chosen
#: to coincide with ``reliability._AUTO_EXACT_LIMIT`` — below it shapes
#: are small enough that per-8 compiles are cheap and memory is noise.
GEOMETRIC_FROM = 64

#: per-rung growth factor above GEOMETRIC_FROM (each rung costs at most
#: ~25% padding waste, and a cluster doubling in size crosses ~3 rungs).
GROWTH = 1.25

#: a held pad is kept while it is at most this factor above the natural
#: rung for the requested size — i.e. a cluster must shrink below about
#: half the held pad before the bucketer lets the shape shrink.
SHRINK_BAND = 2.0


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def rung(n: int) -> int:
    """Smallest ladder pad >= ``n`` (multiples of 8, then geometric)."""
    n = max(1, int(n))
    if n <= GEOMETRIC_FROM:
        return max(ALIGN, _round_up(n, ALIGN))
    r = GEOMETRIC_FROM
    while r < n:
        r = _round_up(int(r * GROWTH), ALIGN)
    return r


def pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (batch axes; inherently geometric)."""
    return 1 << max(0, int(n) - 1).bit_length()


class ShapeBucketer:
    """Hysteresis-banded pad planner with a compile-cache census.

    One instance (the module-level default) is shared by every kernel in
    the process so that e.g. the SC and LB kernels agree on the node pad
    for the same cluster.  Thread-safe: the simulator and checkpoint
    plane may place from different threads.
    """

    def __init__(self):
        self._lock = threading.Lock()
        #: kind -> last pad issued (the hysteresis memory).
        self._held: dict[str, int] = {}
        #: kernel name -> set of static signatures seen (== XLA compiles).
        self._compiled: dict[str, set[tuple]] = {}
        #: kernel name -> total batch calls recorded.
        self._calls: dict[str, int] = {}
        self.queries = 0
        self.band_hits = 0

    # -- pad planning -------------------------------------------------------

    def bucket(self, kind: str, n: int) -> int:
        """Banded pad for axis ``kind``: the natural rung, unless the
        previously issued pad still covers ``n`` within the band."""
        natural = rung(n)
        with self._lock:
            self.queries += 1
            held = self._held.get(kind)
            if held is not None and n <= held and held <= natural * SHRINK_BAND:
                self.band_hits += 1
                return held
            self._held[kind] = natural
            return natural

    # -- compile census -----------------------------------------------------

    def record_compile(self, kernel: str, signature: tuple) -> bool:
        """Note one batch call's static signature; True if it is new
        (i.e. this call pays an XLA compile)."""
        with self._lock:
            seen = self._compiled.setdefault(kernel, set())
            self._calls[kernel] = self._calls.get(kernel, 0) + 1
            if signature in seen:
                return False
            seen.add(signature)
            return True

    def issued_shapes(self, kernel: str) -> frozenset:
        with self._lock:
            return frozenset(self._compiled.get(kernel, ()))

    def stats(self) -> dict:
        """Telemetry snapshot: per-kernel compile/call counts plus the
        bucketer's own query/band counters."""
        with self._lock:
            return {
                "queries": self.queries,
                "band_hits": self.band_hits,
                "kernels": {
                    k: {"compiles": len(v), "calls": self._calls.get(k, 0)}
                    for k, v in sorted(self._compiled.items())
                },
            }

    def reset(self) -> None:
        """Forget held pads and the census (tests; the jit caches of the
        kernels themselves are unaffected)."""
        with self._lock:
            self._held.clear()
            self._compiled.clear()
            self._calls.clear()
            self.queries = 0
            self.band_hits = 0


#: process-wide default bucketer shared by all kernels.
DEFAULT = ShapeBucketer()


def node_pad(L: int) -> int:
    """Padded node-axis length for ``L`` live nodes (shared by every
    kernel so one cluster size maps to one compiled extent)."""
    return DEFAULT.bucket("nodes", L)


def batch_pad(B: int) -> int:
    """Padded batch-axis length (power of two; at most 2x waste and at
    most log2(MAX_SCORING_GROUP) distinct shapes)."""
    return pow2(B)


def start_pad(s: int) -> int:
    """Padded start-axis length for the SC kernel's window starts."""
    return DEFAULT.bucket("sc_starts", s)


def ec_block_pad(n_blocks: int) -> int:
    """Padded byte-block count for the EC coding kernels' byte axis.

    The bit-matmul kernels are compiled per (bit-matrix shape, byte-block
    count); bucketing the block count through the shared rungs means a
    checkpoint whose cohort sizes churn step to step reuses one compiled
    extent per (K, P, bucket) instead of recompiling per distinct byte
    length (the padded tail columns are zeros and are sliced off).

    Below ALIGN blocks the ladder is powers of two instead of the
    multiple-of-8 floor: a small group's chunks are often 1-4 blocks
    wide, and padding them all to 8 would waste up to 8x compute on the
    per-item path for no compile-count benefit (1/2/4/8 is still only
    four shapes)."""
    n = max(1, int(n_blocks))
    if n < ALIGN:
        return pow2(n)
    return DEFAULT.bucket("ec_blocks", n)


def record_compile(kernel: str, signature: tuple) -> bool:
    return DEFAULT.record_compile(kernel, signature)


def issued_shapes(kernel: str) -> frozenset:
    return DEFAULT.issued_shapes(kernel)


def compile_cache_stats() -> dict:
    return DEFAULT.stats()


def reset() -> None:
    DEFAULT.reset()
