"""Jitted jax kernel for D-Rex LB's (K, P) balance-penalty grid (Alg. 1).

D-Rex LB was the last hot-path scheduler still running a scalar numpy
loop: for each parity count P (ascending) it scores every data-chunk
count K by the balance penalty of mapping the item onto the
free-space-sorted prefix of K+P nodes, and stops at the smallest
feasible P (taking the best K there).  The kernel evaluates the full
(K, P) grid in two phases, neither of which materializes a (K, N)
float tensor:

1. **Smallest feasible P, O(L).**  At prefix length N the feasible K
   form the contiguous range ``[2, hi(N)]`` with
   ``hi(N) = N - max(1, mp(N))`` (the parity frontier bounds P from
   below), and since the chunk ``size/K`` shrinks as K grows, the range
   is nonempty iff its largest K fits — one exact float capacity
   compare per column.  The smallest feasible P at a valid column is
   ``max(1, mp(N))``; P* is a masked min over columns.
2. **Penalties on the P* diagonal, O(L) memory.**  The scalar loop
   evaluates the penalty ``sum_i |free_i - chunk - f_avg|`` for every K
   at the winning P (``N = K + P*``), so the kernel accumulates the
   per-K prefix sums with one O(K)-carry scan over node index,
   snapshotting each K row exactly at its own diagonal column.
   "Strictly smallest penalty, earliest K on ties" is a min plus an
   exact-equality masked min over K.

The whole program is vmapped over a batch of items sharing one cluster
snapshot (consumed by ``PlacementEngine.place_many`` through
``DRexLB.place_batch``).

**Exactness policy.**  Decisions are bit-for-bit equal to the scalar
oracle (``DRexLB.place_scalar``), with no fallback regimes, by keeping
every order-sensitive computation on the host:

* **Parity frontiers are a host input.**  ``mp_rows`` comes from the
  very :class:`reliability.ParityFrontier` the oracle consults (one DP
  per distinct ``(fail-probs, target)`` pair — batches overwhelmingly
  share it, and ``BatchContext.frontier`` memoizes across commit
  groups), the same equivalence-by-construction move the
  GreedyMinStorage kernel makes for its RNA rows.  Reimplementing the
  DP in XLA was both slower (a serial ``lax.scan`` per item dominated
  the kernel's runtime) and riskier (XLA's ``cumsum`` lowering
  re-associates, which can flip a threshold compare in ulp-tight
  cases — measurably: ``jnp.cumsum`` != ``np.cumsum`` bitwise on CPU).
* **Summation order is fixed on both paths.**  A float sum depends on
  its grouping, and numpy's pairwise ``.sum()`` cannot be cheaply
  reproduced in XLA, so the penalty sums are defined — on *both*
  paths — in plain left-to-right prefix-sum order: the oracle
  accumulates with ``np.cumsum`` (sequential by construction), the
  kernel with an explicit ``lax.scan`` carry (never ``jnp.cumsum``).
  The remaining order-sensitive global terms — ``f_avg`` (a numpy
  pairwise mean) and the out-of-mapping suffix penalties (a reversed
  ``np.cumsum``) — are host inputs too.

Equivalence across normal, capacity-tight and low-reliability regimes
is pinned by tests/test_lb_vectorized.py.

Everything runs in float64 under a scoped ``jax.experimental.enable_x64``
(many-nines availability targets need the full mantissa); when jax is
unavailable the callers fall back to the scalar oracle.  Pad planning
goes through :mod:`repro.core.shapes` (shared hysteresis-banded buckets
+ compile-cache census).

**Failure-domain constraints.**  Under ``PlacementConstraints`` the
free-descending node order handed to the grid is the cap-admitted
subsequence (``core.constraints.constrained_order``): every (K, P)
prefix of it is a subset of a cap-conforming set, so the grid math is
untouched.  Because the frontier's prefix rows must remain *plain*
prefixes of the scored order, the top-M pre-filter is bypassed (not
domain-sliced) whenever its prefix cannot span a required spread width
— correctness first, the filter is only ever a fast path.
Unconstrained calls pass the identical arrays as before (bit-identical
decisions).
"""

from __future__ import annotations

import functools

import numpy as np

from . import shapes

try:  # pragma: no cover - exercised implicitly by every LB-kernel test
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64

    _JAX_OK = True
except Exception:  # jax is an optional accelerator dependency
    _JAX_OK = False

__all__ = ["kernel_available", "lb_batch"]


def kernel_available() -> bool:
    """True when the jitted scoring path can run (jax importable)."""
    return _JAX_OK


if _JAX_OK:

    @functools.partial(jax.jit, static_argnums=(0,))
    def _lb_scores(
        L_pad,
        mp_b,        # (B, L_pad) host frontier: min parity per prefix length
        size_b,      # (B,)
        free,        # (L_pad,) free MB, free-desc order (pad -1)
        suffix,      # (L_pad + 1,) host suffix penalties by n (pad 0)
        f_avg,       # scalar: mean free over live nodes (host-computed)
        L,           # live-node count (traced; padding masked via L)
    ):
        """D-Rex LB (Alg. 1) for a batch: per item, the winning (K, P).

        See the module docstring for the two-phase structure and the
        exactness policy.  ``mp_b[row, n-1]`` is the min parity of the
        length-``n`` free-desc prefix (``-1`` infeasible), straight from
        the oracle's :class:`ParityFrontier`.
        """
        k_arr = jnp.arange(L_pad) + 2
        n_row = jnp.arange(L_pad) + 1
        i_idx = jnp.arange(L_pad)
        big = jnp.int64(L_pad + 2)

        def one(mp, size):
            chunk = size / k_arr.astype(jnp.float64)
            # ---- phase 1: smallest feasible P (line 22), O(L)
            hi = jnp.where(mp >= 0, n_row - jnp.maximum(1, mp), 0)
            col_ok = (
                (n_row <= L)
                & (hi >= 2)
                # same float predicate the oracle tests: free[n-1] >= size/K
                & (free >= size / jnp.maximum(hi, 1).astype(jnp.float64))
            )
            p_star = jnp.min(jnp.where(col_ok, jnp.maximum(1, mp), big))
            ok = p_star < big
            # ---- phase 2: penalties on the N = K + P* diagonal
            n_diag = jnp.clip(k_arr + p_star, 2, L_pad)
            mp_d = mp[n_diag - 1]
            feas_d = (
                ok
                & (k_arr + p_star <= L)
                & (mp_d >= 0)
                & (mp_d <= p_star)
                & (free[n_diag - 1] >= chunk)
            )

            def body(carry, x):
                run, acc = carry
                i, f_i = x
                run = run + jnp.abs(f_i - chunk - f_avg)
                acc = jnp.where(i == n_diag - 1, run, acc)
                return (run, acc), None

            (_, acc), _ = lax.scan(
                body,
                (jnp.zeros(L_pad), jnp.zeros(L_pad)),
                (i_idx, free),
            )
            # lines 10-15: in-mapping prefix sum + precomputed suffix term.
            bp = jnp.where(feas_d, acc + suffix[n_diag], jnp.inf)
            bv = jnp.min(bp)
            k_star = jnp.min(jnp.where(feas_d & (bp == bv), k_arr, big))
            return (
                ok,
                jnp.where(ok, k_star, 0),
                jnp.where(ok, p_star, 0),
            )

        return jax.vmap(one)(mp_b, size_b)


def _pad_to(a: np.ndarray, size: int, fill: float) -> np.ndarray:
    out = np.full(size, fill, dtype=np.float64)
    out[: a.shape[0]] = a
    return out


def lb_batch(
    mp_rows: np.ndarray,     # (B, L) host ParityFrontier rows, by n - 1
    sizes: np.ndarray,       # (B,)
    free_s: np.ndarray,      # (L,) free MB, free-desc order
    f_avg: float,            # host-computed mean free over live nodes
    suffix: np.ndarray,      # (L + 1,) host-computed suffix penalties
):
    """D-Rex LB decisions for a batch sharing one cluster snapshot.

    Returns ``(ok, k, p)`` length-B arrays: the winning EC parameters
    per item (zeros where ``ok`` is False — genuinely infeasible, since
    the host frontier rows are exact at every width; the mapping is
    always the free-desc prefix of ``k + p`` nodes).  Pure function of
    its arguments.
    """
    if not _JAX_OK:  # callers are expected to gate on kernel_available()
        raise RuntimeError("jax unavailable; use the scalar oracle path")
    B, L = mp_rows.shape
    if L < 3 or B == 0:
        z = np.zeros(B, dtype=np.int64)
        return z.astype(bool), z, z
    L_pad = shapes.node_pad(L)
    B_pad = shapes.batch_pad(B)
    shapes.record_compile("lb_kernel", (B_pad, L_pad))
    mp = np.full((B_pad, L_pad), -1, dtype=np.int64)
    mp[:B, :L] = mp_rows
    suf = np.zeros(L_pad + 1, dtype=np.float64)
    suf[: L + 1] = suffix
    with enable_x64():
        ok, k, p = _lb_scores(
            L_pad,
            jnp.asarray(mp),
            jnp.asarray(_pad_to(sizes, B_pad, 1.0)),
            jnp.asarray(_pad_to(free_s, L_pad, -1.0)),
            jnp.asarray(suf),
            jnp.asarray(np.float64(f_avg)),
            np.int64(L),
        )
    return (
        np.asarray(ok)[:B],
        np.asarray(k, dtype=np.int64)[:B],
        np.asarray(p, dtype=np.int64)[:B],
    )
