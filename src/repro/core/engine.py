"""Placement engine: the one entry point through which items get placed.

The engine owns a :class:`ClusterView`, runs a registered scheduler over
it, commits accepted placements, and emits structured per-decision
telemetry (:class:`PlacementRecord`).  It adds the two things the bare
``Scheduler.place`` call sites (simulator, checkpoint manager,
benchmarks) each reimplemented ad hoc:

* **commit/rollback** — ``place`` commits the chunk bytes to the view
  (optional); :meth:`PlacementEngine.snapshot` /
  :meth:`PlacementEngine.rollback` restore the view exactly, and
  ``place_many(..., atomic=True)`` rolls the whole batch back if any
  item is rejected.
* **batched placement** — :meth:`PlacementEngine.place_many` threads a
  shared :class:`BatchContext` through the scheduler so pure derived
  quantities (failure probabilities per retention window, Poisson-
  binomial parity frontiers per sorted node sequence) are computed once
  per batch instead of once per item.  Caches key on the *exact inputs*
  of each computation, so batched placements are bit-identical to
  sequential ``place`` calls — the DP cost of D-Rex SC simply amortizes
  whenever consecutive items see an unchanged sort order.  Rescoring
  after a commit is *dependency-aware*: schedulers declaring the
  ``windowed_scoring`` capability keep pending scores whose
  ``Decision.window`` is provably untouched (see
  :meth:`PlacementEngine._place_many_batched`).
* **repair planning** — :meth:`PlacementEngine.plan_repair` routes
  degraded-item re-placement through the shared
  :class:`~repro.core.repair.RepairPlanner` (capability-gated parity
  growth, reliability feasibility via the same DP kernel), with the same
  commit/telemetry treatment as placements; the simulator's failure path
  and the checkpoint manager's proactive repair both delegate here.
"""

from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Optional, Sequence

import numpy as np

from . import constraints as constraints_mod
from . import greedy_kernel
from .registry import create_scheduler, scheduler_capabilities
from .reliability import min_parity_for_target, ParityFrontier
from .repair import RepairPlan, RepairPlanner
from .types import (
    ClusterView,
    DataItem,
    Placement,
    PlacementConstraints,
    StorageNode,
)

__all__ = [
    "BatchContext",
    "PlacementRecord",
    "PlacementEngine",
    "RepairPlan",
    "batch_stats",
]


class BatchContext:
    """Memoization scope shared by the items of one batch.

    All caches key on the exact content of their inputs (byte-hashed
    arrays + scalars), never on cluster identity or time, so a cache hit
    returns precisely what recomputation would — schedulers may consult
    the context freely without changing their decisions.  The context
    assumes node failure *rates* are constant while it lives (occupancy
    and liveness may change freely); discard it if AFRs are edited.

    **Commit staleness.** Content keying is what makes the context safe
    across the commits of a batch: a committed placement changes free
    space, which changes the free-desc node ordering the prefix-greedy
    schedulers sort by, which changes the permuted failure-probability
    sequence that *is* the frontier cache key — so the Nth item of a
    batch can never be served a frontier computed against pre-commit
    free space unless the orderings (and hence the DPs) are genuinely
    identical, in which case reuse is exact.  Quantities that depend on
    occupancy itself (capacity fits, saturation, balance penalties) are
    never cached here; schedulers always read them fresh from the view.
    Pinned by ``TestBatchStaleness`` in tests/test_engine.py.
    """

    #: default bound on cached entries per cache; content keys churn with
    #: cluster occupancy, so a long-lived context (e.g. the simulator's
    #: run-long one) would otherwise grow without bound over large traces.
    MAX_ENTRIES = 4096

    def __init__(self, max_entries: int | None = None):
        self.max_entries = self.MAX_ENTRIES if max_entries is None else max_entries
        self._fp_seen: set[tuple[float, int]] = set()
        self._frontiers: dict[tuple[bytes, float], ParityFrontier] = {}
        self._min_parity: dict[tuple[bytes, float], int] = {}
        self._rna_rows: dict[tuple[bytes, float, int], np.ndarray] = {}
        self.hits = 0
        self.misses = 0

    def _bound(self, cache) -> None:
        # Plain clear-on-full: memoization is pure, so dropping entries
        # only costs recomputation, never correctness.
        if len(cache) >= self.max_entries:
            cache.clear()

    def fail_probs(self, cluster: ClusterView, delta_t_days: float) -> np.ndarray:
        """Per-node failure probabilities for one retention window.

        Delegates to :meth:`ClusterView.fail_probs`, which caches per
        ``delta_t`` against an AFR-content mirror with touched-entry
        refresh — correct across AFR edits, joins and accidental sharing
        of a context across engines/clusters, without hashing all N AFR
        bytes per decision the way the old ``(delta_t, afr.tobytes())``
        key did.  Hit/miss telemetry counts per (window, view)."""
        fp = cluster.fail_probs(delta_t_days)
        token = (float(delta_t_days), id(cluster))
        if token in self._fp_seen:
            self.hits += 1
        else:
            self.misses += 1
            self._bound(self._fp_seen)
            self._fp_seen.add(token)
        return fp

    def frontier(self, sorted_fail_probs: np.ndarray, target: float) -> ParityFrontier:
        """Shared lazily-extended parity frontier for one node sequence."""
        key = (sorted_fail_probs.tobytes(), float(target))
        fr = self._frontiers.get(key)
        if fr is None:
            self.misses += 1
            fr = ParityFrontier(sorted_fail_probs, target)
            self._bound(self._frontiers)
            self._frontiers[key] = fr
        else:
            self.hits += 1
        return fr

    def rna_frontier(
        self, sorted_fail_probs: np.ndarray, target: float, L: int
    ) -> np.ndarray:
        """Shared RNA min-parity frontier row for one sorted node sequence
        (the approximation-regime half of the GreedyMinStorage kernel;
        see :func:`repro.core.greedy_kernel.rna_frontier_row`).  The
        write-bandwidth sort order is insensitive to occupancy, so this
        row survives the commits of a batch and amortizes across the
        per-commit rescoring groups of ``place_many``."""
        key = (np.ascontiguousarray(sorted_fail_probs).tobytes(), float(target), int(L))
        row = self._rna_rows.get(key)
        if row is None:
            self.misses += 1
            row = greedy_kernel.rna_frontier_row(sorted_fail_probs, target, L)
            self._bound(self._rna_rows)
            self._rna_rows[key] = row
        else:
            self.hits += 1
        return row

    def min_parity(self, fail_probs: np.ndarray, target: float) -> int:
        """Min parity for an arbitrary mapping; -1 if infeasible."""
        key = (np.ascontiguousarray(fail_probs).tobytes(), float(target))
        mp = self._min_parity.get(key)
        if mp is None:
            self.misses += 1
            got = min_parity_for_target(fail_probs, target)
            mp = -1 if got is None else int(got)
            self._bound(self._min_parity)
            self._min_parity[key] = mp
        else:
            self.hits += 1
        return mp


@dataclasses.dataclass(frozen=True)
class PlacementRecord:
    """Structured telemetry for one scheduling decision."""

    item_id: int
    placement: Optional[Placement]     # None => rejected
    chunk_mb: float                    # 0.0 when rejected
    candidates_considered: int
    reason: str                        # "" on success
    overhead_s: float                  # scheduler wall time for this item
    committed: bool                    # True iff bytes were committed

    @property
    def ok(self) -> bool:
        return self.placement is not None


class PlacementEngine:
    """Runs one scheduler against one :class:`ClusterView`.

    ``scheduler`` may be a registered name (resolved through the
    registry) or an instance; ``cluster`` may be a view or a node list.
    With ``auto_commit=True`` (default) accepted placements are committed
    to the view; the checkpoint plane runs with ``auto_commit=False``
    because its fabric accounts for the bytes as chunks actually land.
    """

    def __init__(
        self,
        cluster: ClusterView | Sequence[StorageNode],
        scheduler,
        *,
        auto_commit: bool = True,
        constraints: Optional[PlacementConstraints] = None,
        **scheduler_kwargs,
    ):
        if isinstance(scheduler, str):
            scheduler = create_scheduler(scheduler, **scheduler_kwargs)
        elif scheduler_kwargs:
            raise TypeError("scheduler kwargs only apply to name resolution")
        if not isinstance(cluster, ClusterView):
            cluster = ClusterView.from_nodes(list(cluster))
        self.cluster = cluster
        self.scheduler = scheduler
        self.auto_commit = auto_commit
        # Engine-wide failure-domain constraints (normalized: the
        # all-default record means "no constraints" and takes the exact
        # unconstrained code path).  Per-call ``constraints=`` overrides.
        if constraints is not None and constraints.unconstrained:
            constraints = None
        self.constraints = constraints
        self.capabilities = scheduler_capabilities(scheduler)
        # Legacy third-party schedulers may still implement the two-arg
        # ``place(item, cluster)``; detect once and call accordingly.
        try:
            sig = inspect.signature(scheduler.place)
            self._pass_ctx = "ctx" in sig.parameters or any(
                p.kind is inspect.Parameter.VAR_KEYWORD
                for p in sig.parameters.values()
            )
        except (TypeError, ValueError):  # builtins / C callables
            self._pass_ctx = False
        # Commit-delta hook: schedulers maintaining incremental rescoring
        # state (see repro.core.incremental) get every commit replayed;
        # out-of-band mutations are caught by the trackers' own mirror
        # validation, so the hook is an optimization, never a soundness
        # requirement.
        self._observe_commit = getattr(scheduler, "observe_commit", None)
        self._observe_release = getattr(scheduler, "observe_release", None)
        self._observe_churn = getattr(scheduler, "observe_churn", None)
        #: monotonic counter of state mutations made *through the engine*
        #: (commits, repairs, releases, rollbacks); snapshot epochs stamp
        #: it so readers can order views without comparing arrays.
        self.mutation_seq = 0
        self._repair_planner = RepairPlanner(self.cluster)
        self.stats = {
            "n_placed": 0,
            "n_rejected": 0,
            "mb_committed": 0.0,
            "overhead_s": 0.0,
            "n_repairs_planned": 0,
            "n_repairs_failed": 0,
            "repair_mb_committed": 0.0,
            # Constraint post-pass telemetry: chunks swapped to satisfy
            # failure-domain constraints, and decisions rejected because
            # no conforming mapping existed.
            "n_constraint_swaps": 0,
            "n_constraint_rejects": 0,
        }

    # -- placement ----------------------------------------------------------

    def place(
        self,
        item: DataItem,
        *,
        ctx: BatchContext | None = None,
        constraints: Optional[PlacementConstraints] = None,
    ) -> PlacementRecord:
        """Schedule (and, with ``auto_commit``, commit) one item.

        ``constraints`` overrides the engine-wide
        :class:`PlacementConstraints` for this call.  ``topology_aware``
        schedulers receive them directly and build cap-conforming
        mappings by construction; for every other scheduler the swap
        post-pass in :meth:`_finalize` enforces the invariant, so it
        holds registry-wide."""
        c = self._effective_constraints(constraints)
        t0 = time.perf_counter()
        if c is not None and self.capabilities.topology_aware:
            decision = self.scheduler.place(
                item, self.cluster, ctx=ctx, constraints=c
            )
        elif self._pass_ctx:
            decision = self.scheduler.place(item, self.cluster, ctx=ctx)
        else:
            decision = self.scheduler.place(item, self.cluster)
        return self._finalize(
            item, decision, time.perf_counter() - t0, constraints=c, ctx=ctx
        )

    def _effective_constraints(
        self, constraints: Optional[PlacementConstraints]
    ) -> Optional[PlacementConstraints]:
        if constraints is None:
            return self.constraints
        return None if constraints.unconstrained else constraints

    def _finalize(
        self,
        item: DataItem,
        decision,
        overhead: float,
        constraints: Optional[PlacementConstraints] = None,
        ctx: BatchContext | None = None,
    ) -> PlacementRecord:
        """Turn a scheduler decision into a committed record + telemetry."""
        self.stats["overhead_s"] += overhead
        if decision.placement is not None and constraints is not None:
            decision = self._enforce_constraints(item, decision, constraints, ctx)
        if decision.placement is None:
            self.stats["n_rejected"] += 1
            return PlacementRecord(
                item_id=item.item_id,
                placement=None,
                chunk_mb=0.0,
                candidates_considered=decision.candidates_considered,
                reason=decision.reason or "rejected",
                overhead_s=overhead,
                committed=False,
            )
        pl = decision.placement
        chunk = pl.chunk_size_mb(item.size_mb)
        self._validate(pl, chunk, constraints)
        committed = False
        if self.auto_commit:
            self.cluster.commit(pl, chunk)
            self.mutation_seq += 1
            if self._observe_commit is not None:
                self._observe_commit(pl.node_ids, chunk, self.cluster)
            self.stats["mb_committed"] += chunk * pl.n
            committed = True
        self.stats["n_placed"] += 1
        return PlacementRecord(
            item_id=item.item_id,
            placement=pl,
            chunk_mb=chunk,
            candidates_considered=decision.candidates_considered,
            reason="",
            overhead_s=overhead,
            committed=committed,
        )

    def _enforce_constraints(
        self,
        item: DataItem,
        decision,
        constraints: PlacementConstraints,
        ctx: BatchContext | None,
    ):
        """Constraint-repair post-pass (see ``core.constraints``).

        ``topology_aware`` schedulers arrive here already cap-conforming
        (their candidate orders are cap-admitted), so the swap pass only
        ever fires for spread width — and, for non-declaring schedulers,
        for everything.  A mapping that cannot be repaired (no admissible
        swap, or the swapped mapping would miss Eq. 3 at the original
        parity) becomes a rejection rather than a constraint violation.
        A swap invalidates ``Decision.window`` (the score's provenance no
        longer matches the mapping), so rescoring stays sound."""
        pl = decision.placement
        if constraints.satisfied_by(pl.node_ids, self.cluster.rack, self.cluster.zone):
            return decision
        chunk = pl.chunk_size_mb(item.size_mb)
        if ctx is not None:
            fail_probs = ctx.fail_probs(self.cluster, item.delta_t_days)

            def mp(probs: np.ndarray) -> int:
                return ctx.min_parity(probs, item.reliability_target)

        else:
            fail_probs = self.cluster.fail_probs(item.delta_t_days)

            def mp(probs: np.ndarray) -> int:
                got = min_parity_for_target(probs, item.reliability_target)
                return -1 if got is None else int(got)

        repaired = constraints_mod.repair_mapping(
            pl, self.cluster, constraints, chunk,
            min_parity=mp, fail_probs=fail_probs,
        )
        if repaired is None:
            self.stats["n_constraint_rejects"] += 1
            return dataclasses.replace(
                decision,
                placement=None,
                window=None,
                reason="failure-domain constraints unsatisfiable for this item",
            )
        new_pl, swaps = repaired
        if swaps == 0:
            return decision
        self.stats["n_constraint_swaps"] += swaps
        return dataclasses.replace(
            decision, placement=new_pl, window=None
        )

    def place_many(
        self,
        items: Sequence[DataItem],
        *,
        atomic: bool = False,
        ctx: BatchContext | None = None,
        constraints: Optional[PlacementConstraints] = None,
    ) -> list[PlacementRecord]:
        """Place a batch in arrival order under one shared context.

        Decisions are identical to calling :meth:`place` per item, but
        the batch amortizes two ways:

        * the shared :class:`BatchContext` memoizes pure derived
          quantities (failure probabilities, parity frontiers) across
          items, and
        * schedulers declaring the ``batch_scoring`` capability are
          driven through :meth:`Scheduler.place_batch`, which scores many
          queued items against one cluster snapshot in a single
          vectorized call.  A committed placement changes the snapshot,
          so pending decisions are re-scored against the post-commit
          state — except decisions a ``windowed_scoring`` scheduler has
          *proven* independent of the commit (disjoint
          ``Decision.window``, unchanged free-desc order), which are
          exactly what rescoring would return (see
          :meth:`_place_many_batched`).  Batched placement never
          consumes a score the commit could have affected.

        With ``atomic=True`` the whole batch is rolled back if any item
        is rejected (records then carry ``committed=False``).
        """
        c = self._effective_constraints(constraints)
        ctx = ctx or BatchContext()
        snap = self.snapshot()
        records: list[PlacementRecord] = []
        batched = self.capabilities.batch_scoring and hasattr(
            self.scheduler, "place_batch"
        )
        try:
            if batched:
                records = self._place_many_batched(list(items), ctx, c)
            else:
                for item in items:
                    records.append(self.place(item, ctx=ctx, constraints=c))
        except Exception:
            self.rollback(snap)
            raise
        if atomic and not all(r.ok for r in records):
            self.rollback(snap)
            records = [dataclasses.replace(r, committed=False) for r in records]
        return records

    #: upper bound on items scored per place_batch call: beyond this a
    #: vectorized scorer's per-item working set (e.g. the SC kernel's
    #: pairwise Pareto matrices) dominates memory, and a single commit
    #: would discard the whole group's scores anyway.
    MAX_SCORING_GROUP = 64

    def _place_many_batched(
        self,
        items: list[DataItem],
        ctx: BatchContext,
        constraints: Optional[PlacementConstraints] = None,
    ) -> list[PlacementRecord]:
        """Batch placement via ``Scheduler.place_batch``.

        The scheduler scores a group of items against the current
        cluster snapshot in one vectorized call; decisions are consumed
        in arrival order.  A committed placement mutates the cluster, so
        not-yet-consumed scores are *stale* by default and the remainder
        of the group is re-scored against the post-commit snapshot.

        **Dependency-aware rescoring.**  Schedulers declaring the
        ``windowed_scoring`` capability emit decisions whose scores are
        pure functions of the free-desc node order plus the free space
        of their ``Decision.window`` nodes.  For those, a commit only
        invalidates the pending scores it can actually affect: a pending
        decision survives while (a) its window is disjoint from every
        node committed since the group was scored and (b) the free-desc
        order of live nodes is unchanged — both checked here, so a kept
        score is *provably* equal to what rescoring would return, and a
        score whose window intersects a committed mapping is never
        reused.  Decisions without a window (rejections, conservative
        schedulers) always trigger the rescore.  Pinned by
        ``TestBatchStaleness`` in tests/test_engine.py.

        Group size adapts: commit-heavy workloads without windowed
        scoring degrade to per-item kernel calls (still vectorized over
        candidates), while non-committing engines (``auto_commit=False``,
        the Table-2 protocol) and windowed schedulers with disjoint
        traffic score the whole queue in ~one call.  Results are
        bit-identical to sequential :meth:`place`.
        """
        records: list[PlacementRecord] = []
        i, n = 0, len(items)
        windowed = self.capabilities.windowed_scoring
        if not self.auto_commit or windowed:
            chunk = min(n, self.MAX_SCORING_GROUP)
        else:
            chunk = 1
        while i < n:
            group = items[i : i + chunk]
            order0 = (
                self._free_desc_order()
                if windowed and self.auto_commit and len(group) > 1
                else None
            )
            t0 = time.perf_counter()
            if constraints is not None and self.capabilities.topology_aware:
                decisions = self.scheduler.place_batch(
                    group, self.cluster, ctx=ctx, constraints=constraints
                )
            else:
                decisions = self.scheduler.place_batch(group, self.cluster, ctx=ctx)
            elapsed = time.perf_counter() - t0
            if len(decisions) != len(group):
                raise RuntimeError(
                    f"{self.scheduler.name}.place_batch returned "
                    f"{len(decisions)} decisions for {len(group)} items"
                )
            per_item = elapsed / len(group)
            used = 0
            committed_nodes: set[int] = set()
            order_unchanged = True
            stale = False
            reused = False
            for item, decision in zip(group, decisions):
                if committed_nodes:
                    if not (
                        order_unchanged
                        and decision.window is not None
                        and committed_nodes.isdisjoint(decision.window)
                    ):
                        stale = True
                        break  # this score saw pre-commit state: rescore
                    reused = True
                # place_batch is pure; the scheduler observes the item
                # only as its decision is consumed (matching sequential
                # place, where observation precedes the item's scoring).
                self.scheduler.observe_item(item)
                records.append(
                    self._finalize(
                        item, decision, per_item,
                        constraints=constraints, ctx=ctx,
                    )
                )
                used += 1
                if records[-1].committed:
                    committed_nodes.update(records[-1].placement.node_ids)
                    if order0 is not None and order_unchanged:
                        order_unchanged = np.array_equal(
                            order0, self._free_desc_order()
                        )
                    elif order0 is None:
                        # Conservative schedulers never reuse across a
                        # commit; skip the order bookkeeping entirely.
                        order_unchanged = False
            i += used
            # Per-record overhead is the amortized share of the scoring
            # call; scores discarded by a mid-group commit still cost
            # wall time, so charge the unconsumed share to the aggregate
            # gauge (stats['overhead_s'] tracks real scheduling time).
            self.stats["overhead_s"] += elapsed - used * per_item
            # Grow the scoring group only while scores are being consumed
            # wholesale: a stale break — or a commit no score survived
            # (non-windowed schedulers always; windowed ones whose
            # windows happened to collide) — degrades to per-item calls
            # rather than oscillating and re-wasting scores.
            if stale or (committed_nodes and not reused):
                chunk = 1
            elif used == len(group) and i < n:
                chunk = min(chunk * 2, self.MAX_SCORING_GROUP, n - i)
        return records

    def _free_desc_order(self) -> np.ndarray:
        """Live node ids in free-space-descending order — the sort every
        windowed-scoring scheduler's decisions are relative to.  Served
        from the scheduler's own candidate tracker when it keeps one
        (same maintained array the scheduler sorts by, so the
        reuse-soundness check and the scheduler can never disagree on
        key or tie-breaking — and the per-commit check stops paying an
        argsort); falls back to the from-scratch ``_live_sorted``."""
        tracker = getattr(self.scheduler, "_order_tracker", None)
        if tracker is not None:
            return tracker.order(self.cluster)
        from .algorithms import Scheduler  # deferred: no import cycle

        return Scheduler._live_sorted(self.cluster, self.cluster.free_mb)

    def observe_churn(self, kind: str, node_ids: Sequence[int]) -> None:
        """Notify the scheduler's incremental trackers of a membership
        event (``fail`` / ``heal`` / ``join``) applied to the cluster
        through the owning plane (serve frontier, simulator).  Purely an
        optimization: trackers self-heal via mirror validation if this
        is never called."""
        if self._observe_churn is not None:
            self._observe_churn(kind, node_ids, self.cluster)

    def observe_external_release(
        self, node_ids: Sequence[int], chunk_mb: float
    ) -> None:
        """Notify the trackers of a release applied to the cluster
        directly by the owning plane (e.g. the frontier's drop path).
        Optimization only — trackers self-heal without it."""
        if self._observe_release is not None:
            self._observe_release(node_ids, chunk_mb, self.cluster)

    # -- repair ---------------------------------------------------------------

    def plan_repair(
        self,
        item: DataItem,
        placement: Placement,
        *,
        chunk_mb: float | None = None,
        survivors: Sequence[int] | None = None,
        allow_parity_growth: bool = True,
        require_target: bool = True,
        commit: bool | None = None,
        ctx: BatchContext | None = None,
        constraints: Optional[PlacementConstraints] = None,
    ) -> RepairPlan:
        """Plan (and, with ``commit``, reserve) re-placement of an item's
        lost chunks — the one repair policy in the codebase (§5.7).

        Parity growth happens only when *both* the caller allows it and
        the scheduler's registry entry declares ``supports_parity_growth``
        (capability gating, never name matching).  ``commit`` defaults to
        the engine's ``auto_commit``; committing reserves one chunk on
        each replacement node so concurrent placements see the capacity
        as taken while the repair transfer is in flight.  Use
        :meth:`abort_repair` to return the reservation if the repair is
        voided (e.g. a reconstruction source dies mid-transfer).
        """
        t0 = time.perf_counter()
        grow = bool(allow_parity_growth) and self.capabilities.supports_parity_growth
        plan = self._repair_planner.plan(
            item,
            placement,
            chunk_mb=chunk_mb,
            survivors=survivors,
            allow_parity_growth=grow,
            require_target=require_target,
            ctx=ctx,
            constraints=self._effective_constraints(constraints),
        )
        plan = dataclasses.replace(
            plan, overhead_s=time.perf_counter() - t0
        )
        self.stats["overhead_s"] += plan.overhead_s
        if not plan.ok:
            self.stats["n_repairs_failed"] += 1
            return plan
        self.stats["n_repairs_planned"] += 1
        commit = self.auto_commit if commit is None else commit
        if commit and plan.new_nodes:
            self.cluster.charge(plan.new_nodes, plan.chunk_mb)
            self.mutation_seq += 1
            if self._observe_commit is not None:
                # same array op as a placement commit: replayable
                self._observe_commit(plan.new_nodes, plan.chunk_mb, self.cluster)
            self.stats["repair_mb_committed"] += plan.repair_mb
            plan = dataclasses.replace(plan, committed=True)
        return plan

    def abort_repair(self, plan: RepairPlan) -> None:
        """Release a committed repair's reserved replacement bytes.

        Occupancy is returned only on still-alive replacement nodes —
        fail-stop already zeroed any that died (which is exactly why the
        repair is being aborted) — but the ``repair_mb_committed`` gauge
        drops by the full reservation: after an abort no replacement
        bytes remain reserved anywhere."""
        if plan.committed and plan.new_nodes:
            alive = [n for n in plan.new_nodes if self.cluster.alive[n]]
            if alive:
                self.cluster.release(alive, plan.chunk_mb)
                if self._observe_release is not None:
                    self._observe_release(alive, plan.chunk_mb, self.cluster)
            self.mutation_seq += 1
            self.stats["repair_mb_committed"] -= plan.repair_mb

    # -- commit / rollback ----------------------------------------------------

    def view_snapshot(self) -> ClusterView:
        """Read-only copy-on-write snapshot of the current cluster state.

        This is the mechanism behind the placement frontier's snapshot
        epochs (:mod:`repro.serve.placement.epochs`): readers hold a
        consistent view while placements keep mutating the live one.
        Publishing is O(1) — the snapshot *shares* the live arrays and
        both sides are write-protected; the live view copies a field
        lazily on its next mutation of that field (see
        :meth:`ClusterView.share_snapshot`), so an epoch costs one copy
        per field that actually changes instead of eight O(N) copies per
        window.  Snapshot arrays stay write-protected forever, so a
        reader bug cannot corrupt a published epoch — and a direct
        out-of-band write to the *live* arrays while they are shared
        raises ``ValueError`` instead of silently mutating the epoch."""
        return self.cluster.share_snapshot()

    def snapshot(self) -> tuple[np.ndarray, np.ndarray, dict, Optional[float]]:
        """Capture the mutable engine state (occupancy, liveness, stats,
        and the scheduler's observed min item size)."""
        return (
            self.cluster.used_mb.copy(),
            self.cluster.alive.copy(),
            dict(self.stats),
            getattr(self.scheduler, "smin_mb", None),
        )

    def rollback(self, snapshot: tuple[np.ndarray, np.ndarray, dict, Optional[float]]) -> None:
        """Restore a :meth:`snapshot` exactly (bitwise, not arithmetically).
        A rolled-back batch leaves no trace: telemetry counters and the
        scheduler's ``smin_mb`` observation (which feeds D-Rex SC's
        saturation curve) are restored along with the cluster."""
        used, alive, stats, smin = snapshot
        self.cluster.restore(used, alive)
        self.mutation_seq += 1
        self.stats = dict(stats)
        if hasattr(self.scheduler, "smin_mb"):
            self.scheduler.smin_mb = smin

    def release(self, record: PlacementRecord) -> None:
        """Return one committed placement's bytes to the cluster (and to
        ``stats['mb_committed']``).

        ``stats['mb_committed']`` counts bytes committed *through this
        engine* (net of release/rollback); it is not a live occupancy
        gauge — callers that mutate the view directly (e.g. the
        simulator's failure/drop paths) should read ``cluster.used_mb``
        for current occupancy."""
        if record.committed and record.placement is not None:
            self.cluster.release(record.placement.node_ids, record.chunk_mb)
            if self._observe_release is not None:
                self._observe_release(
                    record.placement.node_ids, record.chunk_mb, self.cluster
                )
            self.mutation_seq += 1
            self.stats["mb_committed"] -= record.chunk_mb * record.placement.n

    # -- internal -------------------------------------------------------------

    def _validate(
        self,
        pl: Placement,
        chunk: float,
        constraints: Optional[PlacementConstraints] = None,
    ) -> None:
        ids = np.asarray(pl.node_ids)
        if not np.all(self.cluster.alive[ids]):
            raise RuntimeError(
                f"{self.scheduler.name} placed on a dead node: {pl.node_ids}"
            )
        # index-then-subtract == free_mb[ids] bitwise, without the O(N)
        # full-array materialize on every commit
        free = self.cluster.capacity_mb[ids] - self.cluster.used_mb[ids]
        if not np.all(free >= chunk - 1e-6):
            raise RuntimeError(
                f"{self.scheduler.name} violated capacity ({chunk:.3f} MB chunk)"
            )
        if constraints is not None and not constraints.satisfied_by(
            pl.node_ids, self.cluster.rack, self.cluster.zone
        ):
            # Post-pass guarantees conformance before commit; reaching
            # here means a scheduler/post-pass bug, not user input.
            raise RuntimeError(
                f"{self.scheduler.name} violated failure-domain constraints: "
                f"{pl.node_ids}"
            )


def batch_stats(records: Sequence[PlacementRecord]) -> dict:
    """Aggregate a batch of records into the summary benchmarks report."""
    ok = [r for r in records if r.ok]
    rejected = [r for r in records if not r.ok]
    reasons: dict[str, int] = {}
    for r in rejected:
        reasons[r.reason] = reasons.get(r.reason, 0) + 1
    return {
        "n_items": len(records),
        "n_placed": len(ok),
        "n_rejected": len(rejected),
        "mb_placed": float(sum(r.chunk_mb * r.placement.n for r in ok)),
        "mb_committed": float(
            sum(r.chunk_mb * r.placement.n for r in ok if r.committed)
        ),
        "overhead_s": float(sum(r.overhead_s for r in records)),
        "overhead_per_item_ms": (
            1e3 * sum(r.overhead_s for r in records) / len(records)
            if records
            else 0.0
        ),
        "reject_reasons": reasons,
    }
