"""Incremental commit-delta rescoring for cluster-global D-Rex terms.

Both dynamic D-Rex schedulers rescore *cluster-global* quantities on
every placement: LB re-sorts all live nodes by free space and re-averages
them for the balance penalty's ``f_avg``; SC re-evaluates the exponential
saturation baseline over every live node.  Under commit-heavy streaming
load those recomputations dominate the per-decision cost, yet a commit's
effect on the cluster is known exactly — ``used_mb[node_ids] += chunk``
— so this module keeps per-scheduler trackers that fold commit deltas in
instead of recomputing from scratch.

**Exactness contract.**  Decisions must stay bit-identical to the
from-scratch path (the simulator's legacy goldens and the fig12 equality
gates pin absolute placements), which rules out changing any summation
order.  The trackers therefore never maintain floating-point *sums*
incrementally:

* :class:`FreeOrderTracker` maintains the free-desc *sort order*.  A
  commit only changes the free space of the touched nodes, so the cached
  order stays valid iff each touched node is still correctly ordered
  against its cached neighbours — an O(p) adjacency check under the same
  total order ``Scheduler._live_sorted`` realizes (free desc, ties by
  ascending id; sortedness of every adjacent pair under a strict total
  order implies the unique sorted arrangement, hence equality with what
  a fresh stable argsort would return).  When valid, the O(L log L)
  argsort is skipped; ``f_avg`` and the deviation terms are then
  recomputed in O(L) over the *same* element order, which is bitwise
  what the argsort path yields.  An unchanged order also keeps the
  permuted fail-prob sequence identical, so :class:`BatchContext`
  frontier hits survive the commit.
* :class:`SaturationTracker` caches D-Rex SC's per-node saturation
  scores in live-id order and refreshes only the touched entries after a
  commit (``saturation_score`` is elementwise, so a sliced recompute is
  bit-equal to the full-array one); the baseline ``f_base_sum`` is then
  the same left-to-right pairwise ``.sum()`` over the same value
  sequence the from-scratch path reduces.

**Self-healing.**  Trackers mirror ``(used_mb, alive)`` and validate the
mirror against the live view on every query (two vectorized array
compares); any out-of-band mutation — failures, heals, joins, repairs,
rollbacks, ``release`` — fails validation and triggers a from-scratch
rebuild.  The engine feeds commits through ``Scheduler.observe_commit``
(see ``PlacementEngine._finalize``); everything else is caught by
validation.  Exactness and reuse are pinned by
tests/test_incremental_rescore.py.
"""

from __future__ import annotations

import numpy as np

from .types import ClusterView

__all__ = ["FreeOrderTracker", "SaturationTracker"]


class _UsedMirror:
    """Mirror of ``(used_mb, alive)`` that replays commit deltas with the
    exact array op :meth:`ClusterView.commit` performs, so a mirror that
    matched before a commit matches (bitwise) after it."""

    def __init__(self):
        self.used: np.ndarray | None = None
        self.alive: np.ndarray | None = None

    def capture(self, cluster: ClusterView) -> None:
        self.used = cluster.used_mb.copy()
        self.alive = cluster.alive.copy()

    def matches(self, cluster: ClusterView) -> bool:
        return (
            self.used is not None
            and self.used.shape == cluster.used_mb.shape
            and np.array_equal(self.used, cluster.used_mb)
            and np.array_equal(self.alive, cluster.alive)
        )

    def apply_commit(self, node_ids, chunk_mb: float) -> bool:
        """Replay one commit; False when the mirror cannot absorb it."""
        if self.used is None:
            return False
        ids = np.asarray(node_ids)
        if ids.size == 0 or int(ids.max()) >= len(self.used):
            return False
        self.used[ids] += chunk_mb  # ClusterView.commit's exact op
        return True


class FreeOrderTracker:
    """Maintains the free-desc live-node order across commit deltas.

    :meth:`order` returns exactly what
    ``Scheduler._live_sorted(cluster, cluster.free_mb)`` would; when the
    cached order is provably still valid the argsort is skipped.  The
    returned array is shared state — callers must not mutate it.
    """

    def __init__(self):
        self._mirror = _UsedMirror()
        self._by_free: np.ndarray | None = None
        self._pos: np.ndarray | None = None  # node id -> position, -1 dead
        self.hits = 0
        self.rebuilds = 0

    def invalidate(self) -> None:
        self._by_free = None
        self._pos = None
        self._mirror.used = None

    def order(self, cluster: ClusterView) -> np.ndarray:
        if self._by_free is not None and self._mirror.matches(cluster):
            self.hits += 1
            return self._by_free
        self.rebuilds += 1
        ids = cluster.live_ids()
        perm = np.argsort(-cluster.free_mb[ids], kind="stable")
        self._by_free = ids[perm]
        pos = np.full(cluster.n_nodes, -1, dtype=np.int64)
        pos[self._by_free] = np.arange(len(self._by_free))
        self._pos = pos
        self._mirror.capture(cluster)
        return self._by_free

    def observe_commit(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Fold one committed placement into the cached order.

        The touched nodes' free space shrank; the order survives iff each
        touched node still sorts correctly against its cached neighbours.
        Any violation (or a commit the mirror cannot absorb) drops the
        cache — the next query rebuilds from scratch.
        """
        if self._by_free is None:
            return
        if not self._mirror.apply_commit(node_ids, chunk_mb):
            self.invalidate()
            return
        by, pos = self._by_free, self._pos
        cap, used = cluster.capacity_mb, self._mirror.used

        def before(a: int, b: int) -> bool:
            # the _live_sorted total order: free desc, ties ascending id
            fa, fb = cap[a] - used[a], cap[b] - used[b]
            return fa > fb or (fa == fb and a < b)

        for nid in node_ids:
            nid = int(nid)
            k = int(pos[nid]) if nid < len(pos) else -1
            if (
                k < 0
                or (k > 0 and not before(int(by[k - 1]), nid))
                or (k + 1 < len(by) and not before(nid, int(by[k + 1])))
            ):
                self.invalidate()
                return


class SaturationTracker:
    """Caches D-Rex SC's per-node saturation baseline across commits.

    Scores are kept per smin anchor in live-id order; a commit refreshes
    only the touched entries (elementwise recompute over the touched
    slice — bit-equal to the full-array evaluation), and
    :meth:`f_base_sum` is the same ``.sum()`` over the same value
    sequence the from-scratch path reduces.
    """

    #: distinct smin anchors kept; the anchor is a running minimum, so
    #: more than a couple of live values means the trace is degenerate.
    MAX_ANCHORS = 8

    def __init__(self):
        self._mirror = _UsedMirror()
        self._live: np.ndarray | None = None
        self._pos: np.ndarray | None = None
        self._scores: dict[float, np.ndarray] = {}
        self.hits = 0
        self.rebuilds = 0

    def invalidate(self) -> None:
        self._scores.clear()
        self._live = None
        self._pos = None
        self._mirror.used = None

    def f_base_sum(self, cluster: ClusterView, smin: float) -> float:
        """Saturation baseline over every live node for one smin anchor —
        bit-equal to
        ``float(saturation_score(used[live], cap[live], smin, L).sum())``."""
        from .algorithms import saturation_score  # deferred: no cycle

        smin = float(smin)
        if self._live is None or not self._mirror.matches(cluster):
            self.invalidate()
            self._live = cluster.live_ids()
            pos = np.full(cluster.n_nodes, -1, dtype=np.int64)
            pos[self._live] = np.arange(len(self._live))
            self._pos = pos
            self._mirror.capture(cluster)
        scores = self._scores.get(smin)
        if scores is None:
            self.rebuilds += 1
            scores = saturation_score(
                cluster.used_mb[self._live],
                cluster.capacity_mb[self._live],
                smin,
                len(self._live),
            )
            if len(self._scores) >= self.MAX_ANCHORS:
                self._scores.clear()
            self._scores[smin] = scores
        else:
            self.hits += 1
        return float(scores.sum())

    def observe_commit(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Refresh only the committed nodes' cached saturation scores."""
        from .algorithms import saturation_score

        if self._live is None:
            return
        if not self._mirror.apply_commit(node_ids, chunk_mb):
            self.invalidate()
            return
        ids = np.asarray(node_ids)
        if int(ids.max()) >= len(self._pos):
            self.invalidate()
            return
        at = self._pos[ids]
        if np.any(at < 0):  # committed to a node outside the cached live set
            self.invalidate()
            return
        used = self._mirror.used[ids]
        cap = cluster.capacity_mb[ids]
        L = len(self._live)
        for smin, scores in self._scores.items():
            scores[at] = saturation_score(used, cap, smin, L)
