"""Incremental commit-delta rescoring for cluster-global D-Rex terms.

Both dynamic D-Rex schedulers rescore *cluster-global* quantities on
every placement: LB re-sorts all live nodes by free space and re-averages
them for the balance penalty's ``f_avg``; SC re-evaluates the exponential
saturation baseline over every live node.  Under commit-heavy streaming
load those recomputations dominate the per-decision cost, yet a commit's
effect on the cluster is known exactly — ``used_mb[node_ids] += chunk``
— so this module keeps per-scheduler trackers that fold commit deltas in
instead of recomputing from scratch.

**Exactness contract.**  Decisions must stay bit-identical to the
from-scratch path (the simulator's legacy goldens and the fig12 equality
gates pin absolute placements), which rules out changing any summation
order.  The trackers therefore never maintain floating-point *sums*
incrementally:

* :class:`FreeOrderTracker` maintains the free-desc *sort order*.  It is
  an alias of :class:`repro.core.candidates.CandidateTracker`, which
  generalizes the original O(p) adjacency fast path (sortedness of every
  adjacent pair under the strict ``(free desc, id asc)`` total order
  implies the unique sorted arrangement, hence equality with a fresh
  stable argsort) with an O(p log N) *splice* that repositions only the
  touched nodes when they actually moved — instead of dropping the cache
  and re-argsorting all N.  When the order is served from cache,
  ``f_avg`` and the deviation terms are recomputed in O(L) over the
  *same* element order, which is bitwise what the argsort path yields;
  an unchanged order also keeps the permuted fail-prob sequence
  identical, so :class:`BatchContext` frontier hits survive the commit.
* :class:`SaturationTracker` caches D-Rex SC's per-node saturation
  scores in live-id order and refreshes only the touched entries after a
  commit (``saturation_score`` is elementwise, so a sliced recompute is
  bit-equal to the full-array one); the baseline ``f_base_sum`` is then
  the same left-to-right pairwise ``.sum()`` over the same value
  sequence the from-scratch path reduces.

**Self-healing.**  Trackers mirror ``(used_mb, alive)`` (one shared
mirror implementation, ``repro.core.candidates._UsedMirror``) and
validate the mirror against the live view on every query (two vectorized
array compares); any out-of-band mutation — a direct array write, a
rollback, a mutation whose observe hook was not called — fails
validation and triggers a from-scratch rebuild.  The engine feeds
commits through ``Scheduler.observe_commit`` (see
``PlacementEngine._finalize``), releases through ``observe_release`` and
membership churn through ``observe_churn``; everything else is caught by
validation.  Exactness and reuse are pinned by
tests/test_incremental_rescore.py and tests/test_candidates.py.
"""

from __future__ import annotations

import numpy as np

from .candidates import CandidateTracker, _UsedMirror
from .types import ClusterView

__all__ = ["FreeOrderTracker", "SaturationTracker"]

#: Backward-compatible name: the free-desc order tracker was absorbed
#: into the generalized candidate-order structure (see candidates.py).
FreeOrderTracker = CandidateTracker


class SaturationTracker:
    """Caches D-Rex SC's per-node saturation baseline across commits.

    Scores are kept per smin anchor in live-id order; a commit refreshes
    only the touched entries (elementwise recompute over the touched
    slice — bit-equal to the full-array evaluation), and
    :meth:`f_base_sum` is the same ``.sum()`` over the same value
    sequence the from-scratch path reduces.
    """

    #: distinct smin anchors kept; the anchor is a running minimum, so
    #: more than a couple of live values means the trace is degenerate.
    MAX_ANCHORS = 8

    def __init__(self):
        self._mirror = _UsedMirror()
        self._live: np.ndarray | None = None
        self._pos: np.ndarray | None = None
        self._scores: dict[float, np.ndarray] = {}
        self.hits = 0
        self.rebuilds = 0

    def invalidate(self) -> None:
        self._scores.clear()
        self._live = None
        self._pos = None
        self._mirror.used = None

    def f_base_sum(self, cluster: ClusterView, smin: float) -> float:
        """Saturation baseline over every live node for one smin anchor —
        bit-equal to
        ``float(saturation_score(used[live], cap[live], smin, L).sum())``."""
        from .algorithms import saturation_score  # deferred: no cycle

        smin = float(smin)
        if self._live is None or not self._mirror.matches(cluster):
            self.invalidate()
            self._live = cluster.live_ids()
            pos = np.full(cluster.n_nodes, -1, dtype=np.int64)
            pos[self._live] = np.arange(len(self._live))
            self._pos = pos
            self._mirror.capture(cluster)
        scores = self._scores.get(smin)
        if scores is None:
            self.rebuilds += 1
            scores = saturation_score(
                cluster.used_mb[self._live],
                cluster.capacity_mb[self._live],
                smin,
                len(self._live),
            )
            if len(self._scores) >= self.MAX_ANCHORS:
                self._scores.clear()
            self._scores[smin] = scores
        else:
            self.hits += 1
        return float(scores.sum())

    def observe_commit(self, node_ids, chunk_mb: float, cluster: ClusterView) -> None:
        """Refresh only the committed nodes' cached saturation scores."""
        from .algorithms import saturation_score

        if self._live is None:
            return
        if not self._mirror.apply_commit(node_ids, chunk_mb):
            self.invalidate()
            return
        ids = np.asarray(node_ids)
        if int(ids.max()) >= len(self._pos):
            self.invalidate()
            return
        at = self._pos[ids]
        if np.any(at < 0):  # committed to a node outside the cached live set
            self.invalidate()
            return
        used = self._mirror.used[ids]
        cap = cluster.capacity_mb[ids]
        L = len(self._live)
        for smin, scores in self._scores.items():
            scores[at] = saturation_score(used, cap, smin, L)
