"""Scheduler registry: declarative registration + capability flags.

Every placement algorithm registers itself under its paper name with a
:class:`SchedulerCapabilities` declaration, replacing the old
``make_scheduler`` if-chain and the name-string matching the simulator
used to decide which schedulers may grow parity on reschedule
(``Simulator._dynamic()``).  Callers resolve algorithms through
:func:`create_scheduler` / :func:`get_spec`; parameterized families
(``ec(K,P)``) register a regex pattern once and any concrete
instantiation resolves on demand.

Usage::

    @register_scheduler("drex_lb", adaptive=True, supports_parity_growth=True)
    class DRexLB(Scheduler): ...

    @register_scheduler_family(r"ec\\((\\d+),(\\d+)\\)")
    class StaticEC(Scheduler):
        def __init__(self, k: int, p: int): ...

    sched = create_scheduler("ec(6,3)")
    get_spec("drex_lb").capabilities.supports_parity_growth  # True
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
import re
from typing import Callable, Optional

__all__ = [
    "SchedulerCapabilities",
    "SchedulerSpec",
    "register_scheduler",
    "register_scheduler_family",
    "create_scheduler",
    "get_spec",
    "scheduler_names",
    "scheduler_capabilities",
    "find",
]


@dataclasses.dataclass(frozen=True)
class SchedulerCapabilities:
    """What a scheduler declares about itself (consumed by the simulator,
    the checkpoint manager and the benchmarks instead of name matching)."""

    #: chooses (K, P) per item instead of a fixed code.
    adaptive: bool = False
    #: may add parity chunks when repairing after node failures (§5.7).
    #: Consumed by ``PlacementEngine.plan_repair``: parity growth happens
    #: only when the caller allows it AND this flag is declared.
    supports_parity_growth: bool = False
    #: placement depends on an RNG seed (mapping not a pure function of
    #: the cluster state alone).
    randomized: bool = False
    #: provides ``place_batch(items, cluster, ctx)``: scores a whole batch
    #: against one cluster snapshot in a single vectorized call, returning
    #: decisions identical to sequential ``place`` while the cluster is
    #: unchanged.  Consumed by ``PlacementEngine.place_many`` (which
    #: re-scores items invalidated by a commit); never match on names.
    #: Declared by D-Rex SC (core/sc_kernel), both greedy baselines
    #: (core/greedy_kernel) and D-Rex LB (core/lb_kernel); the scalar
    #: paths survive as the equivalence oracles (``place_scalar``).
    batch_scoring: bool = False
    #: consumes :class:`~repro.core.types.PlacementConstraints`: ``place``
    #: / ``place_batch`` accept a ``constraints=`` keyword and build their
    #: candidate orders through ``core.constraints.constrained_order`` (and
    #: ``prefilter.domain_slice``), so per-domain caps hold by construction
    #: and the engine's swap post-pass only ever has to enforce spread.
    #: Non-declaring schedulers never receive the keyword; the engine
    #: repairs their mappings with the post-pass instead.
    topology_aware: bool = False
    #: ``place_batch`` decisions carry a ``Decision.window`` naming the
    #: node ids their score depends on, and the decision is a pure
    #: function of (item, failure probs, the free-desc order of live
    #: nodes, free space of the window nodes) — nothing else.  Lets the
    #: engine's dependency-aware rescoring keep a pending score across a
    #: commit that is disjoint from its window and leaves the free-desc
    #: order unchanged.  Schedulers whose scores depend on cluster-global
    #: terms (D-Rex LB's ``f_avg``, D-Rex SC's saturation baseline,
    #: GreedyMinStorage's cluster-wide capacity filter) must NOT declare
    #: this; only GreedyLeastUsed qualifies among the built-ins.
    windowed_scoring: bool = False


@dataclasses.dataclass(frozen=True)
class SchedulerSpec:
    name: str
    factory: Callable
    capabilities: SchedulerCapabilities
    doc: str = ""


_REGISTRY: dict[str, SchedulerSpec] = {}
_FAMILIES: list[tuple[re.Pattern, Callable, SchedulerCapabilities, str]] = []


def register_scheduler(
    name: str,
    *,
    adaptive: bool = False,
    supports_parity_growth: bool = False,
    randomized: bool = False,
    batch_scoring: bool = False,
    windowed_scoring: bool = False,
    topology_aware: bool = False,
    doc: str = "",
):
    """Class/factory decorator adding one named algorithm to the registry.

    The capability record is also attached to the factory as
    ``.capabilities`` so instances can be interrogated directly
    (``scheduler.capabilities.supports_parity_growth``).
    """
    caps = SchedulerCapabilities(
        adaptive=adaptive,
        supports_parity_growth=supports_parity_growth,
        randomized=randomized,
        batch_scoring=batch_scoring,
        windowed_scoring=windowed_scoring,
        topology_aware=topology_aware,
    )

    def deco(factory):
        key = name.lower()
        # Latest registration wins: re-decorating the same name (module
        # reload, test fixtures) stays idempotent instead of raising.
        _REGISTRY[key] = SchedulerSpec(
            key, factory, caps, doc or inspect.getdoc(factory) or ""
        )
        try:
            factory.capabilities = caps
        except (AttributeError, TypeError):  # e.g. functools.partial
            pass
        return factory

    return deco


def register_scheduler_family(
    pattern: str,
    *,
    adaptive: bool = False,
    supports_parity_growth: bool = False,
    randomized: bool = False,
    batch_scoring: bool = False,
    windowed_scoring: bool = False,
    topology_aware: bool = False,
    doc: str = "",
):
    """Register a parameterized family, e.g. ``ec(K,P)``.

    ``pattern`` is a regex whose groups are passed to the factory as int
    positional arguments; any name fully matching it resolves (and is
    memoized into the registry so it appears in :func:`scheduler_names`).
    """
    caps = SchedulerCapabilities(
        adaptive=adaptive,
        supports_parity_growth=supports_parity_growth,
        randomized=randomized,
        batch_scoring=batch_scoring,
        windowed_scoring=windowed_scoring,
        topology_aware=topology_aware,
    )

    def deco(factory):
        _FAMILIES.append(
            (re.compile(pattern), factory, caps, doc or inspect.getdoc(factory) or "")
        )
        try:
            factory.capabilities = caps
        except (AttributeError, TypeError):
            pass
        return factory

    return deco


def _resolve_family(name: str) -> Optional[SchedulerSpec]:
    for rx, factory, caps, doc in _FAMILIES:
        m = rx.fullmatch(name)
        if m is None:
            continue
        args = tuple(int(g) for g in m.groups())
        spec = SchedulerSpec(name, functools.partial(factory, *args), caps, doc)
        _REGISTRY[name] = spec
        return spec
    return None


def get_spec(name: str) -> SchedulerSpec:
    """Look up a registered scheduler (or instantiate a family match).

    Names are case- and whitespace-insensitive (``"EC(6, 3)"`` resolves
    to ``ec(6,3)``, matching the old factory's tolerance)."""
    key = "".join(name.lower().split())
    spec = _REGISTRY.get(key) or _resolve_family(key)
    if spec is None:
        raise ValueError(
            f"unknown scheduler {name!r}; registered: {scheduler_names()}"
        )
    return spec


def create_scheduler(name: str, **kwargs):
    """Instantiate a scheduler by registered name (the factory behind the
    old ``make_scheduler``)."""
    return get_spec(name).factory(**kwargs)


def scheduler_names() -> list[str]:
    """All names registered so far (family members appear once resolved)."""
    return sorted(_REGISTRY)


def find(
    capabilities: Optional[dict] = None, **flags: bool
) -> list[SchedulerSpec]:
    """Query the registry by capability flags instead of poking classes.

    Each given flag must match the spec's declared value exactly; flags
    left out do not filter.  ``capabilities`` may be passed as a dict
    (``find(capabilities={"topology_aware": True})``) or as keyword
    flags (``find(topology_aware=True, batch_scoring=True)``).  Only
    concrete registrations are searched — family patterns (``ec(K,P)``)
    appear once a member has been resolved.  Results are name-sorted for
    deterministic sweeps (the invariant harness iterates this).
    """
    wanted = dict(capabilities or {})
    wanted.update(flags)
    valid = {f.name for f in dataclasses.fields(SchedulerCapabilities)}
    unknown = set(wanted) - valid
    if unknown:
        raise ValueError(
            f"unknown capability flags {sorted(unknown)}; valid: {sorted(valid)}"
        )
    return [
        spec
        for _, spec in sorted(_REGISTRY.items())
        if all(
            getattr(spec.capabilities, flag) == want
            for flag, want in wanted.items()
        )
    ]


def scheduler_capabilities(scheduler) -> SchedulerCapabilities:
    """Capabilities of a scheduler *instance*; permissive default for
    unregistered third-party schedulers."""
    caps = getattr(scheduler, "capabilities", None)
    if isinstance(caps, SchedulerCapabilities):
        return caps
    return SchedulerCapabilities()
