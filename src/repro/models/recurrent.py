"""Recurrent blocks: RWKV6 time/channel mix (Finch) and Griffin RG-LRU.

Both are linear-time in sequence length with O(1) decode state — these
are the two assigned architectures that run the ``long_500k`` shape.

Implementation notes (TPU-minded):
  * RWKV6: projections and data-dependent decay are computed for the full
    sequence in parallel (dense matmuls on the MXU); only the rank-1
    state recurrence S_t = diag(w_t) S_{t-1} + k_t^T v_t runs in a
    ``lax.scan`` over time.
  * RG-LRU: the diagonal recurrence h_t = a_t h_{t-1} + b_t is evaluated
    with ``lax.associative_scan`` (log-depth, parallel) for train/prefill
    and a single fused step for decode.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, rms_norm

# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------

_TM_LORA = 32   # token-mix lora rank
_TD_LORA = 64   # decay lora rank


def init_rwkv6_tmix(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_size
    hd = cfg.rwkv_head_size
    ks = jax.random.split(key, 12)
    return {
        "x_maa": jnp.zeros((d,), cfg.dt),
        "maa": jnp.zeros((5, d), cfg.dt),           # w,k,v,r,g base mixes
        "tm_w1": dense_init(ks[0], (d, 5 * _TM_LORA), cfg.dt),
        "tm_w2": dense_init(ks[1], (5, _TM_LORA, d), cfg.dt, in_axis=1),
        "td_w1": dense_init(ks[2], (d, _TD_LORA), cfg.dt),
        "td_w2": dense_init(ks[3], (_TD_LORA, d), cfg.dt),
        "decay_bias": jnp.full((d,), -6.0, cfg.dt),
        "bonus_u": dense_init(ks[4], (h, hd), cfg.dt),
        "wr": dense_init(ks[5], (d, d), cfg.dt),
        "wk": dense_init(ks[6], (d, d), cfg.dt),
        "wv": dense_init(ks[7], (d, d), cfg.dt),
        "wg": dense_init(ks[8], (d, d), cfg.dt),
        "wo": dense_init(ks[9], (d, d), cfg.dt),
        "ln_scale": jnp.ones((d,), cfg.dt),
    }


def rwkv6_tmix_axes() -> dict:
    return {
        "x_maa": (None,),
        "maa": (None, None),
        "tm_w1": ("embed", None),
        "tm_w2": (None, None, "embed"),
        "td_w1": ("embed", None),
        "td_w2": (None, "embed"),
        "decay_bias": (None,),
        "bonus_u": ("heads", None),
        "wr": ("embed", "mlp"),
        "wk": ("embed", "mlp"),
        "wv": ("embed", "mlp"),
        "wg": ("embed", "mlp"),
        "wo": ("mlp", "embed"),
        "ln_scale": (None,),
    }


def _ddlerp(p, x, sx):
    """Data-dependent token-shift mixing (RWKV6's ddlerp)."""
    base = x + sx * p["x_maa"]
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", base, p["tm_w1"]))
    lora = lora.reshape(*lora.shape[:-1], 5, _TM_LORA)
    offs = jnp.einsum("btsr,srd->sbtd", lora, p["tm_w2"])  # (5,B,T,D)
    mixed = x[None] + sx[None] * (p["maa"][:, None, None, :] + offs)
    return mixed  # order: w,k,v,r,g


def _rwkv_core_scan(r, k, v, w, u, s0, chunk: int = 1):
    """The WKV recurrence over time.

    r,k,v,w: (B,T,H,hd); u: (H,hd); s0: (B,H,hd,hd). Returns y (B,T,H,hd)
    and final state.

    ``chunk > 1`` runs the scan over T/chunk super-steps with the inner
    ``chunk`` recurrence steps unrolled (beyond-paper §Perf optimization):
    the math is bit-identical to the step scan, but per-step state
    round-trips to HBM and per-step backward residual stacking amortize
    over the chunk — the dominant memory term of the rwkv6 train/prefill
    cells drops by ~the chunk factor.
    """

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, y

    t = r.shape[1]
    rs, ks_, vs, ws = (jnp.moveaxis(x, 1, 0) for x in (r, k, v, w))
    if chunk <= 1 or t % chunk != 0:
        s_final, ys = jax.lax.scan(step, s0, (rs, ks_, vs, ws))
        return jnp.moveaxis(ys, 0, 1), s_final

    nc = t // chunk
    rs, ks_, vs, ws = (
        x.reshape(nc, chunk, *x.shape[1:]) for x in (rs, ks_, vs, ws)
    )

    def chunk_step(s, inp):
        rc, kc, vc, wc = inp
        ys = []
        for i in range(chunk):  # unrolled: state stays on-chip
            s, y = step(s, (rc[i], kc[i], vc[i], wc[i]))
            ys.append(y)
        return s, jnp.stack(ys)

    s_final, ys = jax.lax.scan(
        jax.checkpoint(chunk_step), s0, (rs, ks_, vs, ws)
    )
    return jnp.moveaxis(ys.reshape(t, *ys.shape[2:]), 0, 1), s_final


def rwkv6_tmix(p, x, cfg: ModelConfig, state=None):
    """Full-sequence RWKV6 time-mix. state: None (zeros) or
    {"s": (B,H,hd,hd), "x_prev": (B,D)}. Returns (out, new_state)."""
    b, t, d = x.shape
    h = d // cfg.rwkv_head_size
    hd = cfg.rwkv_head_size
    x_prev = jnp.zeros((b, d), x.dtype) if state is None else state["x_prev"]
    s0 = (
        jnp.zeros((b, h, hd, hd), jnp.float32)
        if state is None
        else state["s"]
    )
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    sx = shifted - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)

    r = jnp.einsum("btd,de->bte", xr, p["wr"]).reshape(b, t, h, hd)
    k = jnp.einsum("btd,de->bte", xk, p["wk"]).reshape(b, t, h, hd)
    v = jnp.einsum("btd,de->bte", xv, p["wv"]).reshape(b, t, h, hd)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"]))
    decay = p["decay_bias"].astype(jnp.float32) + jnp.einsum(
        "btd,dr,re->bte", xw.astype(jnp.float32), p["td_w1"].astype(jnp.float32),
        p["td_w2"].astype(jnp.float32),
    )
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, h, hd)  # data-dependent decay

    y, s_final = _rwkv_core_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), w,
        p["bonus_u"].astype(jnp.float32), s0, chunk=cfg.rwkv_chunk
    )
    y = y.reshape(b, t, d).astype(x.dtype)
    # per-head group norm
    y = rms_norm(
        y.reshape(b, t, h, hd), jnp.ones((hd,), x.dtype), cfg.norm_eps
    ).reshape(b, t, d) * p["ln_scale"]
    out = jnp.einsum("btd,de->bte", y * g, p["wo"])
    new_state = {"s": s_final, "x_prev": x[:, -1, :]}
    return out, new_state


def init_rwkv6_cmix(cfg: ModelConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), cfg.dt),
        "mu_r": jnp.zeros((d,), cfg.dt),
        "wk": dense_init(ks[0], (d, f), cfg.dt),
        "wv": dense_init(ks[1], (f, d), cfg.dt),
        "wr": dense_init(ks[2], (d, d), cfg.dt),
    }


def rwkv6_cmix_axes() -> dict:
    return {
        "mu_k": (None,),
        "mu_r": (None,),
        "wk": ("embed", "mlp"),
        "wv": ("mlp", "embed"),
        "wr": ("embed", "mlp"),
    }


def rwkv6_cmix(p, x, cfg: ModelConfig, state=None):
    b, _, d = x.shape
    x_prev = jnp.zeros((b, d), x.dtype) if state is None else state["x_prev"]
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    sx = shifted - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["wk"])))
    kv = jnp.einsum("btf,fd->btd", k, p["wv"])
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"])) * kv
    return out, {"x_prev": x[:, -1, :]}


# ---------------------------------------------------------------------------
# Griffin RG-LRU recurrent block (RecurrentGemma)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru_block(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model            # lru width == d_model for recurrentgemma-9b
    ks = jax.random.split(key, 6)
    return {
        "wx": dense_init(ks[0], (d, d), cfg.dt),
        "wy": dense_init(ks[1], (d, d), cfg.dt),
        "conv_w": dense_init(ks[2], (cfg.conv1d_width, d), cfg.dt),
        "conv_b": jnp.zeros((d,), cfg.dt),
        "wa": dense_init(ks[3], (d, d), cfg.dt),
        "wi": dense_init(ks[4], (d, d), cfg.dt),
        "a_param": jnp.full((d,), 0.7, jnp.float32),
        "wo": dense_init(ks[5], (d, d), cfg.dt),
    }


def rglru_block_axes() -> dict:
    return {
        "wx": ("embed", "mlp"),
        "wy": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "wa": ("embed", "mlp"),
        "wi": ("embed", "mlp"),
        "a_param": ("mlp",),
        "wo": ("mlp", "embed"),
    }


def _temporal_conv(x, w, b, state=None):
    """Depthwise causal conv1d of width W. x: (B,T,D); state: (B,W-1,D)."""
    width = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
        if state is None
        else state
    )
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_state = xp[:, -(width - 1) :, :] if width > 1 else None
    return out + b, new_state


def _rglru(a_gate, i_gate, x, a_param, h0):
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t), via associative scan."""
    log_a = -_RGLRU_C * jax.nn.softplus(a_param) * jax.nn.sigmoid(a_gate)
    a = jnp.exp(log_a)                               # (B,T,D) f32
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 0.0)) * (i_gate * x)
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(u, v):
        au, bu = u
        av, bv = v
        return au * av, bu * av + bv

    a_all, h_all = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h_all, h_all[:, -1, :]


def rglru_block(p, x, cfg: ModelConfig, state=None):
    """Griffin recurrent block. state: {"h": (B,D), "conv": (B,W-1,D)}."""
    gate = jax.nn.gelu(jnp.einsum("btd,de->bte", x, p["wy"]))
    xb = jnp.einsum("btd,de->bte", x, p["wx"])
    conv_state = None if state is None else state["conv"]
    xb, new_conv = _temporal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    a_gate = jnp.einsum("btd,de->bte", xb, p["wa"]).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xb, p["wi"])).astype(
        jnp.float32
    )
    h0 = None if state is None else state["h"]
    h, h_last = _rglru(a_gate, i_gate, xb.astype(jnp.float32), p["a_param"], h0)
    out = jnp.einsum("btd,de->bte", (h.astype(x.dtype) * gate), p["wo"])
    return out, {"h": h_last, "conv": new_conv}
