"""Model configuration for the ten assigned architectures.

One dataclass drives every family; ``block_pattern`` selects the layer
algebra (full attention, RWKV6 time-mix, Griffin RG-LRU/local-attn mix,
encoder-decoder)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    expert_d_ff: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    norm_topk: bool = True
    #: pad the expert dimension to this size (0 = no padding) so expert
    #: parallelism shards evenly on meshes the true count doesn't divide
    #: (GShard-style padding; padded experts are masked out of routing).
    pad_experts_to: int = 0

    @property
    def n_experts_padded(self) -> int:
        return max(self.n_experts, self.pad_experts_to)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style audio encoder; the conv frontend is a stub — inputs
    are precomputed frame embeddings (B, n_frames, d_model)."""

    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    activation: str = "silu"       # silu (gated) | gelu (gated) | squared_relu
    use_qk_norm: bool = False
    rope_theta: float = 10_000.0
    block_pattern: str = "attn"    # attn | rwkv6 | griffin | encdec
    attn_window: int = 0           # 0 = global causal; >0 local window
    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None
    rwkv_head_size: int = 64
    #: WKV recurrence chunk (1 = per-step scan; >1 = chunked, §Perf)
    rwkv_chunk: int = 1
    conv1d_width: int = 4          # griffin temporal conv
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"        # compute/param dtype
    tie_embeddings: bool = False
    #: remat policy for scan-over-layers: none|minimal|full
    remat: str = "full"
    #: Megatron-style sequence parallelism: residual stream + norms run
    #: T-sharded over the model axis; gathers/reduce-scatters bracket the
    #: attention and MLP blocks (beyond-paper §Perf optimization).
    seq_parallel: bool = False
    #: all-reduce TP partial sums in bf16 instead of f32 (halves the TP
    #: collective bytes; bf16 accumulation on the reduced dots)
    tp_reduce_bf16: bool = False
    #: MoE dispatch: "scatter" (global-view GSPMD) | "shard_map" (explicit
    #: per-shard dispatch: one combine-psum per layer instead of GSPMD's
    #: dispatch-buffer all-reduces; beyond-paper §Perf optimization)
    moe_dispatch: str = "scatter"
    #: RMSNorm: keep only the variance statistic in f32 and normalize in
    #: the compute dtype — halves the d_model-wide f32 elementwise chains
    #: the norm backward otherwise creates (beyond-paper §Perf)
    norm_stats_only_f32: bool = False
    #: cast the loss cotangent to bf16 before it backpropagates through
    #: the layer stack: activation gradients (and their TP all-reduces)
    #: run in bf16 instead of promoted f32 (beyond-paper §Perf; weight
    #: gradients still accumulate in f32 inside the dots / optimizer)
    bwd_bf16: bool = False
    #: attention implementation: dense | blockwise (flash-style streaming)
    attn_impl: str = "dense"
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    #: max decode positions a KV cache supports (set by the serve shape)
    max_cache_len: int = 4096

    @property
    def dhead(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def dt(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def sub_quadratic(self) -> bool:
        """True if a 500k-token context is served without a full-attention
        KV cache (SSM state and/or bounded-window attention)."""
        return self.block_pattern in ("rwkv6", "griffin")

    def griffin_pattern(self) -> list[str]:
        """Layer types for block_pattern='griffin': (R, R, A) repeating,
        trailing remainder recurrent (DESIGN.md §5)."""
        kinds = []
        for i in range(self.n_layers):
            kinds.append("attn" if i % 3 == 2 else "rec")
        return kinds

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        e, h = self.d_model, self.dhead
        att = e * self.n_heads * h + 2 * e * self.n_kv_heads * h + self.n_heads * h * e
        if self.activation == "squared_relu":
            mlp = 2 * e * self.d_ff
        else:
            mlp = 3 * e * self.d_ff
        if self.moe:
            m = self.moe
            emlp = 3 * e * m.expert_d_ff
            mlp = m.n_experts * emlp + e * m.n_experts
            if m.n_shared_experts:
                mlp += 3 * e * (m.n_shared_experts * m.expert_d_ff)
        if self.block_pattern == "rwkv6":
            # r,k,v,g,o + decay/mix loras + channel mix
            blk = 5 * e * e + 2 * e * self.d_ff + e * self.d_ff
        elif self.block_pattern == "griffin":
            kinds = self.griffin_pattern()
            n_rec = sum(1 for k in kinds if k == "rec")
            n_att = len(kinds) - n_rec
            rec = 3 * e * e + self.conv1d_width * e
            per_att = att
            blk_total = n_rec * (rec + mlp) + n_att * (per_att + mlp)
            emb = self.vocab_size * e * (1 if self.tie_embeddings else 2)
            return blk_total + emb
        else:
            blk = att + mlp
        total = self.n_layers * blk
        if self.is_encdec:
            total += self.encoder.n_layers * (att + mlp)
            total += self.n_layers * (att)  # cross-attention
        emb = self.vocab_size * e * (1 if self.tie_embeddings else 2)
        return total + emb

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed-in experts)."""
        if not self.moe:
            return self.n_params()
        m = self.moe
        e = self.d_model
        emlp = 3 * e * m.expert_d_ff
        dense_like = self.n_params() - self.n_layers * (m.n_experts * emlp)
        return dense_like + self.n_layers * (m.experts_per_token * emlp)
