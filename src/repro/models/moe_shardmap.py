"""shard_map MoE dispatch (beyond-paper §Perf optimization, opt-in via
``moe_dispatch="shard_map"``).

Why: under the global-view scatter formulation, GSPMD reduces the FULL
(E, C, D) dispatch buffers across the mesh (the qwen-MoE cells' dominant
collective). With explicit per-shard control the data plane becomes:

  * x is replicated across the model axis within each data shard, so
    "dispatch to the model shard owning expert e" is a local slice — no
    cross-device dispatch traffic at all;
  * each model shard runs its E/n_model experts over the local tokens;
  * the only collective is one psum of the combined token outputs
    (B_loc, T, D) over the model axis per layer — the same volume as a
    single TP all-reduce, orders of magnitude below the buffer reduce.

Capacity semantics: per-(data-shard, expert) queues (local capacity),
the standard large-scale variant of GShard capacity. FSDP'd expert
weights are all-gathered over the data axis explicitly inside the shard
(the gather GSPMD previously inserted implicitly).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig

# jax >= 0.5 exposes shard_map at top level with `check_vma`; jax <= 0.4.x
# has the experimental module with `check_rep` — same semantics here.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KWARGS = {"check_vma": False}
else:  # pragma: no cover - exercised on jax <= 0.4.x images
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KWARGS = {"check_rep": False}


def _local_moe(xt, router, wg, wi, wo, *, cfg: ModelConfig, n_model: int,
               fsdp_axes):
    """Per-shard body. xt: (S_loc, D); router: (D, Ep) replicated;
    wg/wi/wo: (Ep/n_model, D[/fsdp], F) local expert slices."""
    m = cfg.moe
    ep = m.n_experts_padded
    s_loc, d = xt.shape

    # FSDP: expert weights arrive sharded over the data axes on the embed
    # dim; gather them for local compute (explicitly, once per layer).
    for ax in fsdp_axes:
        wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
        wi = jax.lax.all_gather(wi, ax, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, ax, axis=2, tiled=True)

    logits = (xt.astype(jnp.float32) @ router).astype(jnp.float32)
    if ep != m.n_experts:
        logits = jnp.where(jnp.arange(ep)[None, :] >= m.n_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, m.experts_per_token)
    if m.norm_topk:
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    cap = int(math.ceil(s_loc * m.experts_per_token / ep * m.capacity_factor))
    flat_ids = top_ids.reshape(-1)
    flat_w = top_w.reshape(-1)
    one_hot = jax.nn.one_hot(flat_ids, ep, dtype=jnp.int32)
    pos = jnp.cumsum(one_hot, axis=0) * one_hot - one_hot
    slot = jnp.sum(pos, axis=1)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, 0)

    xe = jnp.repeat(xt, m.experts_per_token, axis=0)
    dispatched = jnp.zeros((ep, cap, d), xt.dtype)
    dispatched = dispatched.at[flat_ids, slot_c].add(
        jnp.where(keep[:, None], xe, 0).astype(xt.dtype)
    )

    # keep only this model shard's experts (x is replicated over 'model',
    # so this is a free slice, not a communication)
    e_loc = ep // n_model
    shard = jax.lax.axis_index("model")
    local = jax.lax.dynamic_slice_in_dim(dispatched, shard * e_loc, e_loc, axis=0)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", local, wg))
    h = g * jnp.einsum("ecd,edf->ecf", local, wi)
    out_e = jnp.einsum("ecf,efd->ecd", h, wo)

    # scatter back into the full-Ep layout (zeros elsewhere), gather the
    # per-token results, weight, and psum the partial outputs over model.
    full = jnp.zeros((ep, cap, d), out_e.dtype)
    full = jax.lax.dynamic_update_slice_in_dim(full, out_e, shard * e_loc, axis=0)
    gathered = full[flat_ids, slot_c]
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = (
        (gathered * flat_w[:, None].astype(gathered.dtype))
        .reshape(s_loc, m.experts_per_token, d)
        .sum(axis=1)
    )
    return jax.lax.psum(combined, "model")


def moe_apply_shardmap(p, x, cfg: ModelConfig, mesh):
    """Drop-in for the expert part of moe_apply (shared experts and the
    aux loss stay in the global-view caller). x: (B, T, D) global."""
    from .sharding import logical_to_spec, rules_for

    b, t, d = x.shape
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_model = mesh.shape["model"]
    rules = rules_for(mesh)
    # FSDP axes actually used for the experts' embed dim under the rules
    # (must match the parameters' resident sharding — no silent reshard).
    wg_spec = logical_to_spec(("experts", "embed", None), p["wg"].shape, mesh, rules)
    fsdp = wg_spec[1]
    fsdp_axes = () if fsdp is None else ((fsdp,) if isinstance(fsdp, str) else tuple(fsdp))

    x2 = x.reshape(b * t, d)
    fn = partial(_local_moe, cfg=cfg, n_model=n_model, fsdp_axes=fsdp_axes)
    out = _shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            P(dp_axes or None, None),           # tokens: batch-sharded
            P(None, None),                       # router: replicated
            P("model", fsdp, None),              # wg
            P("model", fsdp, None),              # wi
            P("model", None, fsdp),              # wo
        ),
        out_specs=P(dp_axes or None, None),
        **_CHECK_KWARGS,
    )(x2, p["router"], p["wg"], p["wi"], p["wo"])
    return out.reshape(b, t, d)
