"""Core neural layers: norms, RoPE, GQA attention, MLP variants, MoE.

Pure-functional: ``init_*`` builds param dicts (leaves: jnp arrays),
``*_axes`` builds the parallel tree of logical-axis tuples used by the
sharding rules, and apply functions are jit-safe with static shapes.
Compute dtype follows ``cfg.dt`` (bf16 by default); softmax/logits run in
f32.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .sharding import constrain

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis])
    )
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6, stats_only_f32: bool = False):
    dt = x.dtype
    if stats_only_f32:
        # f32 statistic, compute-dtype normalization: the (B,T,E) tensor
        # ops (and their backward) stay bf16; only the (B,T,1) statistic
        # is f32.
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(dt)
        return x * inv * scale.astype(dt)
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (half-rotation)
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., T, H, D); positions: (..., T) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm / local window / cross-attention)
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key, cross: bool = False) -> dict:
    e, h, hd = cfg.d_model, cfg.dhead, cfg.dhead
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (e, nh, hd), cfg.dt),
        "wk": dense_init(ks[1], (e, nkv, hd), cfg.dt),
        "wv": dense_init(ks[2], (e, nkv, hd), cfg.dt),
        "wo": dense_init(ks[3], (nh, hd, e), cfg.dt, in_axis=(0, 1)),
    }
    if cfg.use_qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), cfg.dt)
        p["k_norm"] = jnp.ones((hd,), cfg.dt)
    return p


def attention_axes(cfg: ModelConfig, cross: bool = False) -> dict:
    a = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.use_qk_norm and not cross:
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return a


def _qkv(p, x, x_kv, cfg: ModelConfig, positions, kv_positions, use_rope=True):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p["wv"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """Grouped scaled-dot-product attention.

    q: (B,T,Hq,D); k/v: (B,S,Hkv,D); mask: (T,S) bool or None.
    """
    b, t, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q = q.reshape(b, t, hkv, g, d)
    scores = jnp.einsum("bthgd,bshd->bhgts", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    if mask is not None:
        scores = jnp.where(mask[None, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v)
    return out.reshape(b, t, hq, d)


def blockwise_sdpa(q, k, v, cfg: ModelConfig, window: int = 0, q_offset: int = 0):
    """Flash-style streaming attention (beyond-paper §Perf optimization).

    Scans query blocks; per query block an inner scan over KV blocks keeps
    the online-softmax state (m, l, acc) — the (T, S) score/prob tensors
    are never materialized, so HBM traffic drops from O(T*S) per layer to
    O(T*bk + S). The per-q-block body is rematerialized in the backward
    pass (jax.checkpoint), keeping residuals at O(T*D) like the rest of
    the layer.

    Causal and local-window masks are generated from block indices (no
    materialized mask). Cross-/bidirectional attention keeps the dense
    path (encoder sequences are short).
    """
    b, t, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(cfg.attn_block_q, t)
    bk = min(cfg.attn_block_kv, s)
    assert t % bq == 0 and s % bk == 0, (t, s, bq, bk)
    nq, nk = t // bq, s // bk
    scale = 1.0 / math.sqrt(d)

    qb = q.reshape(b, nq, bq, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(b, nk, bk, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, bk, hkv, d).transpose(1, 0, 2, 3, 4)

    def one_q_block(qi, q_blk):
        qpos = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp
            kpos = kj * bk + jnp.arange(bk)
            sc = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            valid = kpos[None, :] <= qpos[:, None]
            if window > 0:
                valid &= kpos[None, :] > qpos[:, None] - window
            sc = jnp.where(valid[None, None, None, :, :], sc, -1e30)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (b,bq,hkv,g,d)

    out_blocks = jax.lax.scan(
        lambda _, inp: (None, jax.checkpoint(one_q_block)(inp[0], inp[1])),
        None,
        (jnp.arange(nq), qb),
    )[1]                                                     # (nq,b,bq,hkv,g,d)
    out = out_blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, hq, d)
    return out


def self_attention(q, k, v, cfg: ModelConfig, window: int = 0, q_offset: int = 0):
    """Causal self-attention dispatch: dense vs blockwise per config."""
    t, s = q.shape[1], k.shape[1]
    if (
        cfg.attn_impl == "blockwise"
        and t % min(cfg.attn_block_q, t) == 0
        and s % min(cfg.attn_block_kv, s) == 0
        and t > 1
    ):
        return blockwise_sdpa(q, k, v, cfg, window=window, q_offset=q_offset)
    return _sdpa(q, k, v, causal_mask(t, s, window, offset=q_offset), cfg)


def causal_mask(t: int, s: int, window: int = 0, offset: int = 0):
    """(T, S) bool where query i attends key j iff j <= i+offset and, for a
    local window w, j > i+offset-w."""
    qi = jnp.arange(t)[:, None] + offset
    kj = jnp.arange(s)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m


def attention_full(p, x, cfg: ModelConfig, positions, window: int = 0):
    """Full-sequence causal self-attention (train / prefill)."""
    q, k, v = _qkv(p, x, x, cfg, positions, positions)
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    out = self_attention(q, k, v, cfg, window=window)
    return jnp.einsum(
        "bthd,hde->bte", out, p["wo"], preferred_element_type=_tp_out_dtype(cfg)
    )


def attention_decode(p, x, cache, pos, cfg: ModelConfig, window: int = 0, ring: bool = False):
    """One-token decode against a pre-allocated KV cache.

    x: (B,1,E); cache: {"k","v"}: (B,S,Hkv,D); pos: scalar int32 — the
    *true* sequence position of the new token (RoPE uses this).

    ``ring=False``: the cache holds absolute positions 0..S-1 and ``pos``
    is also the write index (optionally with a local ``window`` mask).

    ``ring=True``: the cache is a rolling window of the last S positions;
    the write index is ``pos % S`` and every slot written so far is valid
    (RoPE rotations are absolute per token, so relative offsets survive
    the wrap). Used by the griffin local-attention blocks.

    Returns (out (B,1,E), new_cache).
    """
    s = cache["k"].shape[1]
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q, k1, v1 = _qkv(p, x, x, cfg, positions, positions)
    widx = jnp.mod(pos, s) if ring else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k1.astype(cache["k"].dtype), (0, widx, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v1.astype(cache["v"].dtype), (0, widx, 0, 0))
    kj = jnp.arange(s)[None, :]
    if ring:
        valid = (kj <= pos) | jnp.full((1, s), pos >= s)
    else:
        valid = kj <= pos
        if window > 0:
            valid = valid & (kj > pos - window)
    out = _sdpa(q, ck, cv, valid, cfg)
    return jnp.einsum("bthd,hde->bte", out, p["wo"]), {"k": ck, "v": cv}


def attention_cross(p, x, enc_kv, cfg: ModelConfig):
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    out = _sdpa(q, enc_kv["k"], enc_kv["v"], None, cfg)
    return jnp.einsum("bthd,hde->bte", out, p["wo"])


def encode_cross_kv(p, enc_out, cfg: ModelConfig) -> dict:
    return {
        "k": jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"]),
        "v": jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"]),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> dict:
    e = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "squared_relu":
        return {
            "wi": dense_init(ks[0], (e, f), cfg.dt),
            "wo": dense_init(ks[1], (f, e), cfg.dt),
        }
    return {
        "wg": dense_init(ks[0], (e, f), cfg.dt),
        "wi": dense_init(ks[1], (e, f), cfg.dt),
        "wo": dense_init(ks[2], (f, e), cfg.dt),
    }


def mlp_axes(cfg: ModelConfig) -> dict:
    if cfg.activation == "squared_relu":
        return {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return {
        "wg": ("embed", "mlp"),
        "wi": ("embed", "mlp"),
        "wo": ("mlp", "embed"),
    }


def _tp_out_dtype(cfg: ModelConfig):
    return cfg.dt if cfg.tp_reduce_bf16 else None


def mlp_apply(p, x, cfg: ModelConfig):
    pet = _tp_out_dtype(cfg)
    if cfg.activation == "squared_relu":
        h = jnp.einsum("btd,df->btf", x, p["wi"])
        h = jnp.square(jax.nn.relu(h))
        return jnp.einsum("btf,fd->btd", h, p["wo"], preferred_element_type=pet)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    g = act(jnp.einsum("btd,df->btf", x, p["wg"]))
    h = g * jnp.einsum("btd,df->btf", x, p["wi"])
    h = constrain(h, ("batch", None, "mlp"))
    return jnp.einsum("btf,fd->btd", h, p["wo"], preferred_element_type=pet)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-bounded scatter dispatch)
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> dict:
    m = cfg.moe
    e, f = cfg.d_model, m.expert_d_ff
    ep = m.n_experts_padded   # GShard-style padding for even EP sharding
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (e, ep), jnp.float32),
        "wg": dense_init(ks[1], (ep, e, f), cfg.dt, in_axis=1),
        "wi": dense_init(ks[2], (ep, e, f), cfg.dt, in_axis=1),
        "wo": dense_init(ks[3], (ep, f, e), cfg.dt, in_axis=1),
    }
    if m.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=m.n_shared_experts * f)
    return p


def moe_axes(cfg: ModelConfig) -> dict:
    a = {
        "router": ("embed", "experts"),
        "wg": ("experts", "embed", "expert_mlp"),
        "wi": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }
    if cfg.moe.n_shared_experts:
        a["shared"] = mlp_axes(cfg)
    return a


def moe_apply(p, x, cfg: ModelConfig):
    """Top-k MoE with capacity-bounded scatter dispatch.

    Tokens route to their top-k experts; each expert processes at most
    C = ceil(S*k/E * capacity_factor) tokens (overflow dropped, standard
    GShard semantics). Returns (out, aux_loss).
    """
    m = cfg.moe
    ep = m.n_experts_padded
    b, t, e = x.shape
    s = b * t
    xt = x.reshape(s, e)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (S, Ep)
    if ep != m.n_experts:   # padded experts never win routing
        pad_mask = jnp.arange(ep) >= m.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, m.experts_per_token)  # (S, k)
    if m.norm_topk:
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch/GShard form)
    density = jnp.mean(
        jax.nn.one_hot(top_ids[:, 0], ep, dtype=jnp.float32), axis=0
    )
    density_prob = jnp.mean(probs, axis=0)
    aux = m.router_aux_coef * m.n_experts * jnp.sum(density * density_prob)

    if cfg.moe_dispatch == "shard_map":
        from .sharding import _ACTIVE_MESH
        from .moe_shardmap import moe_apply_shardmap

        mesh = _ACTIVE_MESH[0]
        if mesh is not None and not mesh.empty and "model" in mesh.axis_names \
                and ep % mesh.shape["model"] == 0:
            out = moe_apply_shardmap(p, x, cfg, mesh)
            if "shared" in p:
                out = out + mlp_apply(p["shared"], x, cfg)
            return out, aux

    cap = int(math.ceil(s * m.experts_per_token / m.n_experts * m.capacity_factor))
    flat_ids = top_ids.reshape(-1)                              # (S*k,)
    flat_w = top_w.reshape(-1)
    # position of each (token, slot) within its expert queue
    one_hot = jax.nn.one_hot(flat_ids, ep, dtype=jnp.int32)
    pos = jnp.cumsum(one_hot, axis=0) * one_hot - one_hot        # (S*k, E)
    slot = jnp.sum(pos, axis=1)                                  # (S*k,)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, 0)

    xe = jnp.repeat(xt, m.experts_per_token, axis=0)             # (S*k, D)
    dispatched = jnp.zeros((ep, cap, e), x.dtype)
    dispatched = dispatched.at[flat_ids, slot_c].add(
        jnp.where(keep[:, None], xe, 0).astype(x.dtype)
    )
    dispatched = constrain(dispatched, ("experts", None, None))

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", dispatched, p["wg"]))
    h = g * jnp.einsum("ecd,edf->ecf", dispatched, p["wi"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out_e = constrain(out_e, ("experts", None, None))

    gathered = out_e[flat_ids, slot_c]                           # (S*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = (gathered * flat_w[:, None].astype(gathered.dtype)).reshape(
        s, m.experts_per_token, e
    ).sum(axis=1)
    out = combined.reshape(b, t, e)
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, cfg)
    return out, aux
