"""Model assembly: init / forward / loss / prefill / decode for all ten
assigned architectures.

Layer stacks are scanned (``lax.scan`` over stacked params) with
configurable remat, keeping HLO size ~constant in depth (96-layer
nemotron-340b lowers as fast as 4-layer whisper-tiny). Heterogeneous
stacks (griffin's R,R,A pattern; whisper's enc/dec) scan over homogeneous
sub-stacks.

Three entry points per architecture (the dry-run lowers each):
  * ``loss_fn``      — full-seq training objective (train_4k)
  * ``prefill``      — full forward returning serve state (prefill_32k)
  * ``decode_step``  — one token against the serve state (decode_32k,
                       long_500k for the sub-quadratic families)
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    attention_axes,
    attention_cross,
    attention_decode,
    attention_full,
    causal_mask,
    dense_init,
    encode_cross_kv,
    init_attention,
    init_mlp,
    init_moe,
    mlp_apply,
    mlp_axes,
    moe_apply,
    moe_axes,
    rms_norm,
)
from .recurrent import (
    init_rglru_block,
    init_rwkv6_cmix,
    init_rwkv6_tmix,
    rglru_block,
    rglru_block_axes,
    rwkv6_cmix,
    rwkv6_cmix_axes,
    rwkv6_tmix,
    rwkv6_tmix_axes,
)
from .sharding import constrain

def rms_norm_cfg(x, scale, cfg):
    return rms_norm(x, scale, cfg.norm_eps, stats_only_f32=cfg.norm_stats_only_f32)


# ---------------------------------------------------------------------------
# per-layer init / axes
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, key) -> dict:
    """One decoder block's params (unstacked)."""
    ks = jax.random.split(key, 8)
    if cfg.block_pattern == "rwkv6":
        return {
            "norm1": jnp.ones((cfg.d_model,), cfg.dt),
            "tmix": init_rwkv6_tmix(cfg, ks[0]),
            "norm2": jnp.ones((cfg.d_model,), cfg.dt),
            "cmix": init_rwkv6_cmix(cfg, ks[1]),
        }
    p = {
        "norm1": jnp.ones((cfg.d_model,), cfg.dt),
        "attn": init_attention(cfg, ks[0]),
        "norm2": jnp.ones((cfg.d_model,), cfg.dt),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(cfg, ks[1])
    else:
        p["mlp"] = init_mlp(cfg, ks[1])
    if cfg.is_encdec:
        p["norm_x"] = jnp.ones((cfg.d_model,), cfg.dt)
        p["xattn"] = init_attention(cfg, ks[2], cross=True)
    return p


def _block_axes(cfg: ModelConfig) -> dict:
    if cfg.block_pattern == "rwkv6":
        return {
            "norm1": (None,),
            "tmix": rwkv6_tmix_axes(),
            "norm2": (None,),
            "cmix": rwkv6_cmix_axes(),
        }
    a = {
        "norm1": (None,),
        "attn": attention_axes(cfg),
        "norm2": (None,),
    }
    if cfg.moe is not None:
        a["moe"] = moe_axes(cfg)
    else:
        a["mlp"] = mlp_axes(cfg)
    if cfg.is_encdec:
        a["norm_x"] = (None,)
        a["xattn"] = attention_axes(cfg, cross=True)
    return a


def _init_griffin_group(cfg: ModelConfig, key) -> dict:
    """One (rec, rec, attn) griffin super-block."""
    ks = jax.random.split(key, 6)
    return {
        "rec": [
            {
                "norm1": jnp.ones((cfg.d_model,), cfg.dt),
                "rg": init_rglru_block(cfg, ks[i]),
                "norm2": jnp.ones((cfg.d_model,), cfg.dt),
                "mlp": init_mlp(cfg, ks[i + 2]),
            }
            for i in range(2)
        ],
        "attn": {
            "norm1": jnp.ones((cfg.d_model,), cfg.dt),
            "attn": init_attention(cfg, ks[4]),
            "norm2": jnp.ones((cfg.d_model,), cfg.dt),
            "mlp": init_mlp(cfg, ks[5]),
        },
    }


def _griffin_group_axes(cfg: ModelConfig) -> dict:
    rec = {
        "norm1": (None,),
        "rg": rglru_block_axes(),
        "norm2": (None,),
        "mlp": mlp_axes(cfg),
    }
    return {
        "rec": [rec, rec],
        "attn": {
            "norm1": (None,),
            "attn": attention_axes(cfg),
            "norm2": (None,),
            "mlp": mlp_axes(cfg),
        },
    }


def _rec_tail_axes(cfg: ModelConfig) -> dict:
    return {
        "norm1": (None,),
        "rg": rglru_block_axes(),
        "norm2": (None,),
        "mlp": mlp_axes(cfg),
    }


def _stacked(init_fn, key, n: int):
    """vmap an init over layer keys -> stacked (n, ...) leaves."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _stack_axes(axes_tree):
    """Prepend the 'layers' logical axis to every leaf's axes tuple."""
    return jax.tree.map(
        lambda ax: ("layers", *ax),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def init_params(cfg: ModelConfig, key) -> dict:
    k_emb, k_layers, k_head, k_enc, k_tail = jax.random.split(key, 5)
    params: dict[str, Any] = {
        "embed": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), cfg.dt, in_axis=1),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size), cfg.dt)

    if cfg.block_pattern == "griffin":
        n_groups = cfg.n_layers // 3
        n_tail = cfg.n_layers - 3 * n_groups
        params["groups"] = _stacked(lambda k: _init_griffin_group(cfg, k), k_layers, n_groups)
        if n_tail:
            params["tail"] = _stacked(
                lambda k: {
                    "norm1": jnp.ones((cfg.d_model,), cfg.dt),
                    "rg": init_rglru_block(cfg, jax.random.split(k, 2)[0]),
                    "norm2": jnp.ones((cfg.d_model,), cfg.dt),
                    "mlp": init_mlp(cfg, jax.random.split(k, 2)[1]),
                },
                k_tail,
                n_tail,
            )
    else:
        params["layers"] = _stacked(lambda k: _init_block(cfg, k), k_layers, cfg.n_layers)

    if cfg.is_encdec:
        enc_cfg = cfg.with_(use_qk_norm=False)
        params["enc_layers"] = _stacked(
            lambda k: {
                "norm1": jnp.ones((cfg.d_model,), cfg.dt),
                "attn": init_attention(enc_cfg, k),
                "norm2": jnp.ones((cfg.d_model,), cfg.dt),
                "mlp": init_mlp(enc_cfg, jax.random.fold_in(k, 1)),
            },
            k_enc,
            cfg.encoder.n_layers,
        )
        params["enc_norm"] = jnp.ones((cfg.d_model,), cfg.dt)
    return params


def param_axes(cfg: ModelConfig) -> dict:
    axes: dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    if cfg.block_pattern == "griffin":
        axes["groups"] = _stack_axes(_griffin_group_axes(cfg))
        if cfg.n_layers % 3:
            axes["tail"] = _stack_axes(_rec_tail_axes(cfg))
    else:
        axes["layers"] = _stack_axes(_block_axes(cfg))
    if cfg.is_encdec:
        axes["enc_layers"] = _stack_axes(
            {
                "norm1": (None,),
                "attn": attention_axes(cfg),
                "norm2": (None,),
                "mlp": mlp_axes(cfg),
            }
        )
        axes["enc_norm"] = (None,)
    return axes


# ---------------------------------------------------------------------------
# backward-dtype barrier
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _grad_to_bf16(x):
    """Identity whose cotangent is cast to bf16 — stops the f32 loss
    cotangent from promoting the whole backward pass to f32."""
    return x


def _gb_fwd(x):
    return x, None


def _gb_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_grad_to_bf16.defvjp(_gb_fwd, _gb_bwd)


# ---------------------------------------------------------------------------
# remat policy
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "minimal":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_apply_full(cfg, p, x, positions, enc_out=None):
    """One block, full sequence. Returns (x, aux_loss, serve_state)."""
    aux = 0.0
    state: dict[str, Any] = {}
    if cfg.block_pattern == "rwkv6":
        h, tm_state = rwkv6_tmix(p["tmix"], rms_norm_cfg(x, p["norm1"], cfg), cfg)
        x = x + h
        h, cm_state = rwkv6_cmix(p["cmix"], rms_norm_cfg(x, p["norm2"], cfg), cfg)
        x = x + h
        state = {"tmix": tm_state, "cmix": cm_state}
        return x, aux, state
    # attention block. Under sequence parallelism the residual stream and
    # the norms live T-sharded over the model axis; the all-gather /
    # reduce-scatter pairs that bracket attention and MLP are inserted by
    # GSPMD from the sharding constraints (identity ops mathematically).
    sp = cfg.seq_parallel
    if sp:
        x = constrain(x, ("batch", "seq_sp", None))
    h_in = rms_norm_cfg(x, p["norm1"], cfg)
    if sp:
        h_in = constrain(h_in, ("batch", None, None))     # gather T
    att = attention_full(p["attn"], h_in, cfg, positions, window=cfg.attn_window)
    if sp:
        att = constrain(att, ("batch", "seq_sp", None))   # reduce-scatter
    x = x + att
    if cfg.is_encdec and enc_out is not None:
        xh = rms_norm_cfg(x, p["norm_x"], cfg)
        if sp:
            xh = constrain(xh, ("batch", None, None))
        kv = encode_cross_kv(p["xattn"], enc_out, cfg)
        xo = attention_cross(p["xattn"], xh, kv, cfg)
        x = x + (constrain(xo, ("batch", "seq_sp", None)) if sp else xo)
    h2 = rms_norm_cfg(x, p["norm2"], cfg)
    if sp:
        h2 = constrain(h2, ("batch", None, None))
    if cfg.moe is not None:
        mo, aux = moe_apply(p["moe"], h2, cfg)
        if sp:
            mo = constrain(mo, ("batch", "seq_sp", None))
        x = x + mo
    else:
        mo = mlp_apply(p["mlp"], h2, cfg)
        if sp:
            mo = constrain(mo, ("batch", "seq_sp", None))
        x = x + mo
    return x, aux, state


def _encode(params, frames, cfg: ModelConfig):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = frames.astype(cfg.dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    def body(carry, lp):
        h = carry
        hin = rms_norm_cfg(h, lp["norm1"], cfg)
        q, k, v = None, None, None
        # bidirectional self-attention (no mask)
        from .layers import _qkv, _sdpa

        qq, kk, vv = _qkv(lp["attn"], hin, hin, cfg, positions, positions)
        att = _sdpa(qq, kk, vv, None, cfg)
        h = h + jnp.einsum("bthd,hde->bte", att, lp["attn"]["wo"])
        h = h + mlp_apply(lp["mlp"], rms_norm_cfg(h, lp["norm2"], cfg), cfg)
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return rms_norm_cfg(x, params["enc_norm"], cfg)


def forward(params, tokens, cfg: ModelConfig, frames=None):
    """Full-sequence causal forward -> logits (B, T, V) in f32."""
    b, t = tokens.shape
    x = params["embed"][tokens].astype(cfg.dt)
    x = constrain(x, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None, :], (b, t))
    enc_out = _encode(params, frames, cfg) if cfg.is_encdec else None

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.block_pattern == "griffin":
        def gbody(carry, gp):
            h, aux = carry
            for rp in gp["rec"]:
                r, _ = rglru_block(rp["rg"], rms_norm_cfg(h, rp["norm1"], cfg), cfg)
                h = h + r
                h = h + mlp_apply(rp["mlp"], rms_norm_cfg(h, rp["norm2"], cfg), cfg)
            ap = gp["attn"]
            h = h + attention_full(
                ap["attn"], rms_norm_cfg(h, ap["norm1"], cfg), cfg, positions,
                window=cfg.attn_window,
            )
            h = h + mlp_apply(ap["mlp"], rms_norm_cfg(h, ap["norm2"], cfg), cfg)
            return (h, aux), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(gbody, cfg), (x, aux_total), params["groups"]
        )
        if "tail" in params:
            def tbody(carry, rp):
                h = carry
                r, _ = rglru_block(rp["rg"], rms_norm_cfg(h, rp["norm1"], cfg), cfg)
                h = h + r
                h = h + mlp_apply(rp["mlp"], rms_norm_cfg(h, rp["norm2"], cfg), cfg)
                return h, None

            x, _ = jax.lax.scan(_maybe_remat(tbody, cfg), x, params["tail"])
    else:
        def body(carry, lp):
            h, aux = carry
            h, a, _ = _block_apply_full(cfg, lp, h, positions, enc_out)
            return (h, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(body, cfg), (x, aux_total), params["layers"]
        )

    if cfg.seq_parallel:
        x = constrain(x, ("batch", None, None))
    if cfg.bwd_bf16:
        x = _grad_to_bf16(x)
    x = rms_norm_cfg(x, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head).astype(jnp.float32)
    return constrain(logits, ("batch", None, "vocab")), aux_total


def loss_fn(params, batch, cfg: ModelConfig):
    """Cross-entropy LM loss. batch: {"tokens","labels"[, "frames"]}."""
    logits, aux = forward(params, batch["tokens"], cfg, batch.get("frames"))
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: state init / prefill / decode
# ---------------------------------------------------------------------------


def init_serve_state(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """Zero-initialized decode state (shapes define the dry-run specs)."""
    hd, nkv = cfg.dhead, cfg.n_kv_heads
    d = cfg.d_model
    h = d // cfg.rwkv_head_size

    def kv(length):
        return {
            "k": jnp.zeros((batch, length, nkv, hd), cfg.dt),
            "v": jnp.zeros((batch, length, nkv, hd), cfg.dt),
        }

    if cfg.block_pattern == "rwkv6":
        return {
            "layers": {
                "tmix": {
                    "s": jnp.zeros((cfg.n_layers, batch, h, cfg.rwkv_head_size, cfg.rwkv_head_size), jnp.float32),
                    "x_prev": jnp.zeros((cfg.n_layers, batch, d), cfg.dt),
                },
                "cmix": {"x_prev": jnp.zeros((cfg.n_layers, batch, d), cfg.dt)},
            }
        }
    if cfg.block_pattern == "griffin":
        n_groups = cfg.n_layers // 3
        n_tail = cfg.n_layers - 3 * n_groups
        win = min(cfg.attn_window or cache_len, cache_len)
        st = {
            "groups": {
                "rec": [
                    {
                        "h": jnp.zeros((n_groups, batch, d), jnp.float32),
                        "conv": jnp.zeros((n_groups, batch, cfg.conv1d_width - 1, d), cfg.dt),
                    }
                    for _ in range(2)
                ],
                "attn": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n_groups, *x.shape)), kv(win)
                ),
            }
        }
        if n_tail:
            st["tail"] = {
                "h": jnp.zeros((n_tail, batch, d), jnp.float32),
                "conv": jnp.zeros((n_tail, batch, cfg.conv1d_width - 1, d), cfg.dt),
            }
        return st
    state = {
        "layers": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers, *x.shape)), kv(cache_len)
        )
    }
    if cfg.is_encdec:
        state["cross_kv"] = {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder.n_frames, nkv, hd), cfg.dt),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder.n_frames, nkv, hd), cfg.dt),
        }
    return state


def serve_state_axes(cfg: ModelConfig, state) -> Any:
    """Logical axes for every serve-state leaf: (layers, batch, ...) with
    kv-head sharding where present."""

    def leaf_axes(path_leaf):
        x = path_leaf
        if x.ndim == 5:  # (L, B, S, kv, hd) or rwkv s (L,B,H,hd,hd)
            if x.shape[-1] == x.shape[-2]:
                return ("layers", "batch", "heads", None, None)
            return ("layers", "batch", None, "kv_heads", None)
        if x.ndim == 4:
            return ("layers", "batch", None, None)
        if x.ndim == 3:
            return ("layers", "batch", None)
        return tuple([None] * x.ndim)

    return jax.tree.map(leaf_axes, state)


def decode_step(params, token, pos, state, cfg: ModelConfig):
    """One-token decode. token: (B, 1) int32; pos: scalar int32 (current
    position = number of tokens already in the state).

    Returns (logits (B, V) f32, new_state)."""
    x = params["embed"][token].astype(cfg.dt)

    if cfg.block_pattern == "rwkv6":
        ls = state["layers"]

        def body(h, xs):
            lp, tm, cm = xs
            o, tm2 = rwkv6_tmix(lp["tmix"], rms_norm_cfg(h, lp["norm1"], cfg), cfg, tm)
            h = h + o
            o, cm2 = rwkv6_cmix(lp["cmix"], rms_norm_cfg(h, lp["norm2"], cfg), cfg, cm)
            return h + o, (tm2, cm2)

        x, (tm_new, cm_new) = jax.lax.scan(
            body, x, (params["layers"], ls["tmix"], ls["cmix"])
        )
        new_state = {"layers": {"tmix": tm_new, "cmix": cm_new}}
    elif cfg.block_pattern == "griffin":
        gs = state["groups"]

        def gbody(h, xs):
            gp, st = xs
            new_rec = []
            for i in range(2):
                rp, rst = gp["rec"][i], st["rec"][i]
                o, rst2 = rglru_block(rp["rg"], rms_norm_cfg(h, rp["norm1"], cfg), cfg, rst)
                h = h + o
                h = h + mlp_apply(rp["mlp"], rms_norm_cfg(h, rp["norm2"], cfg), cfg)
                new_rec.append(rst2)
            ap = gp["attn"]
            # local attention against the rolling window cache
            o, new_kv = attention_decode(
                ap["attn"], rms_norm_cfg(h, ap["norm1"], cfg),
                st["attn"], pos, cfg, ring=True,
            )
            h = h + o
            h = h + mlp_apply(ap["mlp"], rms_norm_cfg(h, ap["norm2"], cfg), cfg)
            return h, {"rec": new_rec, "attn": new_kv}

        x, gs_new = jax.lax.scan(gbody, x, (params["groups"], gs))
        new_state = {"groups": gs_new}
        if "tail" in params:
            def tbody(h, xs):
                rp, rst = xs
                o, rst2 = rglru_block(rp["rg"], rms_norm_cfg(h, rp["norm1"], cfg), cfg, rst)
                h = h + o
                h = h + mlp_apply(rp["mlp"], rms_norm_cfg(h, rp["norm2"], cfg), cfg)
                return h, rst2

            x, tail_new = jax.lax.scan(tbody, x, (params["tail"], state["tail"]))
            new_state["tail"] = tail_new
    else:
        def body(h, xs):
            if cfg.is_encdec:
                lp, kv, xkv = xs
            else:
                lp, kv = xs
                xkv = None
            o, kv2 = attention_decode(
                lp["attn"], rms_norm_cfg(h, lp["norm1"], cfg), kv, pos, cfg,
                window=cfg.attn_window,
            )
            h = h + o
            if cfg.is_encdec:
                h = h + attention_cross(
                    lp["xattn"], rms_norm_cfg(h, lp["norm_x"], cfg), xkv, cfg
                )
            h2 = rms_norm_cfg(h, lp["norm2"], cfg)
            if cfg.moe is not None:
                mo, _ = moe_apply(lp["moe"], h2, cfg)
                h = h + mo
            else:
                h = h + mlp_apply(lp["mlp"], h2, cfg)
            return h, kv2

        xs = (
            (params["layers"], state["layers"], state["cross_kv"])
            if cfg.is_encdec
            else (params["layers"], state["layers"])
        )
        x, kv_new = jax.lax.scan(body, x, xs)
        new_state = dict(state)
        new_state["layers"] = kv_new

    x = rms_norm_cfg(x, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head).astype(jnp.float32)[:, 0, :]
    return logits, new_state


def prefill(params, tokens, cfg: ModelConfig, frames=None):
    """Full forward that also materializes the serve state.

    Returns (last-token logits (B, V), state). For attention families the
    KV cache length equals the prompt length (the serve loop reallocates
    or pre-pads as needed)."""
    b, t = tokens.shape
    x = params["embed"][tokens].astype(cfg.dt)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None, :], (b, t))
    enc_out = _encode(params, frames, cfg) if cfg.is_encdec else None

    if cfg.block_pattern == "rwkv6":
        def body(h, lp):
            o, tm = rwkv6_tmix(lp["tmix"], rms_norm_cfg(h, lp["norm1"], cfg), cfg)
            h = h + o
            o, cm = rwkv6_cmix(lp["cmix"], rms_norm_cfg(h, lp["norm2"], cfg), cfg)
            return h + o, (tm, cm)

        x, (tm, cm) = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        state = {"layers": {"tmix": tm, "cmix": cm}}
    elif cfg.block_pattern == "griffin":
        win = cfg.attn_window or t

        def gbody(h, gp):
            sts = {"rec": [], "attn": None}
            for i in range(2):
                rp = gp["rec"][i]
                o, rst = rglru_block(rp["rg"], rms_norm_cfg(h, rp["norm1"], cfg), cfg)
                h = h + o
                h = h + mlp_apply(rp["mlp"], rms_norm_cfg(h, rp["norm2"], cfg), cfg)
                sts["rec"].append(rst)
            ap = gp["attn"]
            hin = rms_norm_cfg(h, ap["norm1"], cfg)
            from .layers import _qkv

            q, k, v = _qkv(ap["attn"], hin, hin, cfg, positions, positions)
            from .layers import self_attention

            att = self_attention(q, k, v, cfg, window=cfg.attn_window)
            h = h + jnp.einsum("bthd,hde->bte", att, ap["attn"]["wo"])
            h = h + mlp_apply(ap["mlp"], rms_norm_cfg(h, ap["norm2"], cfg), cfg)
            # Ring layout: slot j holds position p with p % win == j, so the
            # decode path (write index pos % win) continues seamlessly.
            if t >= win:
                shift = t % win
                sts["attn"] = {
                    "k": jnp.roll(k[:, -win:], shift, axis=1),
                    "v": jnp.roll(v[:, -win:], shift, axis=1),
                }
            else:  # short prompt: positions 0..t-1 live at slots 0..t-1
                pad = ((0, 0), (0, win - t), (0, 0), (0, 0))
                sts["attn"] = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
            return h, sts

        x, gstates = jax.lax.scan(_maybe_remat(gbody, cfg), x, params["groups"])
        state = {"groups": gstates}
        if "tail" in params:
            def tbody(h, rp):
                o, rst = rglru_block(rp["rg"], rms_norm_cfg(h, rp["norm1"], cfg), cfg)
                h = h + o
                h = h + mlp_apply(rp["mlp"], rms_norm_cfg(h, rp["norm2"], cfg), cfg)
                return h, rst

            x, tstates = jax.lax.scan(_maybe_remat(tbody, cfg), x, params["tail"])
            state["tail"] = tstates
    else:
        def body(h, lp):
            hin = rms_norm_cfg(h, lp["norm1"], cfg)
            from .layers import _qkv, self_attention

            q, k, v = _qkv(lp["attn"], hin, hin, cfg, positions, positions)
            att = self_attention(q, k, v, cfg, window=cfg.attn_window)
            h = h + jnp.einsum("bthd,hde->bte", att, lp["attn"]["wo"])
            xkv = None
            if cfg.is_encdec:
                xh = rms_norm_cfg(h, lp["norm_x"], cfg)
                xkv = encode_cross_kv(lp["xattn"], enc_out, cfg)
                h = h + attention_cross(lp["xattn"], xh, xkv, cfg)
            h2 = rms_norm_cfg(h, lp["norm2"], cfg)
            if cfg.moe is not None:
                mo, _ = moe_apply(lp["moe"], h2, cfg)
                h = h + mo
            else:
                h = h + mlp_apply(lp["mlp"], h2, cfg)
            out_state = {"k": k, "v": v}
            if cfg.is_encdec:
                return h, (out_state, xkv)
            return h, out_state

        x, scanned = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        if cfg.is_encdec:
            kv, xkv = scanned
            state = {"layers": kv, "cross_kv": xkv}
        else:
            state = {"layers": scanned}

    x = rms_norm_cfg(x, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1, :], head).astype(jnp.float32)
    return logits, state
