"""Model zoo: composable decoder stacks covering all assigned families."""

from .config import EncoderConfig, ModelConfig, MoEConfig
from .model import (
    decode_step,
    forward,
    init_params,
    init_serve_state,
    loss_fn,
    param_axes,
    prefill,
    serve_state_axes,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "EncoderConfig",
    "init_params",
    "param_axes",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_serve_state",
    "serve_state_axes",
]
