"""Logical-axis sharding rules (MaxText-style) for single- and multi-pod
meshes.

Parameters and activations are annotated with tuples of *logical* axis
names; ``logical_to_spec`` resolves them to ``PartitionSpec`` against a
rule table, dropping mesh axes that do not divide the concrete dimension
(e.g. whisper-tiny's 6 heads on a 16-way model axis fall back to
replication instead of failing to lower).
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (in priority order), per mesh flavor
RULES_SINGLE_POD: dict[str, tuple[str, ...]] = {
    "batch": ("data",),
    "seq": (),
    "embed": ("data",),          # FSDP: params+optimizer sharded over data
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_mlp": (),
    "expert_cap": (),
    "layers": (),
    "conv": (),
    "frames": (),
    "state": ("model",),
    "seq_sp": ("model",),   # Megatron-style sequence parallelism
}

RULES_MULTI_POD: dict[str, tuple[str, ...]] = {
    **RULES_SINGLE_POD,
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),    # FSDP over the full DP extent
}


def rules_for(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    return RULES_MULTI_POD if "pod" in mesh.axis_names else RULES_SINGLE_POD


def logical_to_spec(
    logical: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Optional[Mapping[str, tuple[str, ...]]] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec, checking divisibility."""
    rules = rules or rules_for(mesh)
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, logical):
        if name is None:
            out.append(None)
            continue
        axes = []
        extent = 1
        for mesh_axis in rules.get(name, ()):
            if mesh_axis in used:
                continue
            size = mesh.shape[mesh_axis]
            if dim % (extent * size) == 0:
                axes.append(mesh_axis)
                extent *= size
        for a in axes:
            used.add(a)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def named_sharding(
    logical: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical, shape, mesh))


def tree_shardings(logical_tree, shape_tree, mesh: Mesh):
    """Map parallel pytrees of logical-axis tuples and shapes to
    NamedShardings."""
    return jax.tree.map(
        lambda log, shp: named_sharding(log, shp, mesh),
        logical_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


_ACTIVE_MESH: list[Optional[Mesh]] = [None]


class activate_mesh:
    """Explicit ambient-mesh scope for ``constrain`` (no reliance on
    deprecated thread-resource introspection). The train/serve builders
    activate the production mesh around tracing; tests that never
    activate a mesh get no-op constraints."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        self.prev = _ACTIVE_MESH[0]
        _ACTIVE_MESH[0] = self.mesh
        return self.mesh

    def __exit__(self, *exc):
        _ACTIVE_MESH[0] = self.prev
        return False


def constrain(x, logical: Sequence[Optional[str]], mesh: Optional[Mesh] = None):
    """with_sharding_constraint via logical names under the active mesh."""
    mesh = mesh or _ACTIVE_MESH[0]
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
