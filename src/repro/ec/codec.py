"""Item-level erasure codec: bytes -> N chunks -> bytes (with erasures).

Wraps the chunk-matrix kernels with the split/pad/join bookkeeping the
checkpoint manager and benchmarks need.  A ``ECCodec(k, p)`` is the data
plane counterpart of a :class:`repro.core.types.Placement`.

Batch API: :meth:`ECCodec.encode_many` / :meth:`ECCodec.decode_many`
drive whole cohorts of payloads through one kernel launch per coding
matrix (see ``repro.kernels.ops``), and the module-level planner
(:func:`plan_cohorts` / :func:`encode_batch`) partitions a mixed list of
``(k, p)`` codings into those cohorts.  The per-item :meth:`ECCodec.
encode` / :meth:`ECCodec.decode` path is the bit-for-bit oracle the
batched paths are pinned against (tests/test_ec_batched.py).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.kernels import ops as kops

__all__ = [
    "ECCodec",
    "encode_item",
    "decode_item",
    "plan_cohorts",
    "encode_batch",
]


def _as_bytes_array(payload) -> np.ndarray:
    if isinstance(payload, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(payload), dtype=np.uint8)
    return np.asarray(payload, dtype=np.uint8).ravel()


@dataclasses.dataclass(frozen=True)
class ECCodec:
    k: int
    p: int
    use_kernel: bool = True

    @property
    def n(self) -> int:
        return self.k + self.p

    def chunk_len(self, nbytes: int) -> int:
        return -(-nbytes // self.k)  # ceil(size / K), paper Table 1

    def _data_matrix(self, payload) -> np.ndarray:
        """(K, chunk_len) zero-padded data rows for one payload."""
        buf = _as_bytes_array(payload)
        clen = self.chunk_len(buf.size)
        padded = np.zeros(self.k * clen, dtype=np.uint8)
        padded[: buf.size] = buf
        return padded.reshape(self.k, clen)

    def encode(self, payload: bytes | np.ndarray) -> np.ndarray:
        """bytes -> (N, chunk_len) uint8: K data rows then P parity rows.

        An empty payload yields a well-defined empty manifest — shape
        (N, 0), no kernel call (the kernels require block-aligned widths
        and an empty matrix has none)."""
        data = self._data_matrix(payload)
        if data.shape[1] == 0:
            return np.zeros((self.n, 0), dtype=np.uint8)
        parity = np.asarray(
            kops.encode_chunks(data, self.p, use_kernel=self.use_kernel)
        )
        return np.concatenate([data, parity], axis=0)

    def encode_many(self, payloads: Sequence) -> list[np.ndarray]:
        """Encode a cohort of payloads in ONE kernel launch.

        Payload lengths may differ (the code is columnwise; the kernel
        sees the cohort concatenated along the byte axis).  Returns the
        (N, chunk_len_i) chunk matrices in input order, bit-identical to
        per-item :meth:`encode`."""
        datas = [self._data_matrix(p) for p in payloads]
        parities = kops.encode_chunks_many(
            datas, self.p, use_kernel=self.use_kernel
        )
        return [
            np.concatenate([d, np.asarray(par)], axis=0)
            for d, par in zip(datas, parities)
        ]

    def _select_rows(
        self, chunks: np.ndarray, rows: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Deterministic choice of K rows (sorted; systematic first)."""
        chunks = np.asarray(chunks, dtype=np.uint8)
        rows = np.asarray(rows)
        if chunks.shape[0] < self.k:
            raise ValueError(
                f"need at least K={self.k} chunks, got {chunks.shape[0]}"
            )
        sel = np.argsort(rows)[: self.k]
        return chunks[sel], rows[sel]

    def decode(
        self,
        chunks: np.ndarray,
        rows: np.ndarray,
        orig_nbytes: int,
    ) -> bytes:
        """Any K chunk rows (+ their row indices) -> original payload."""
        use_chunks, use_rows = self._select_rows(chunks, rows)
        if orig_nbytes == 0 or use_chunks.shape[1] == 0:
            return b""
        if np.array_equal(use_rows, np.arange(self.k)):
            data = use_chunks  # all-systematic fast path: no math
        else:
            data = np.asarray(
                kops.decode_chunks(
                    use_chunks, use_rows, self.k, self.p,
                    use_kernel=self.use_kernel,
                )
            )
        return data.reshape(-1)[:orig_nbytes].tobytes()

    def decode_many(
        self, parts: Sequence[tuple[np.ndarray, np.ndarray, int]]
    ) -> list[bytes]:
        """Decode a cohort of ``(chunks, rows, orig_nbytes)`` triples.

        All-systematic items take the no-math fast path; the rest run
        one kernel launch per distinct erasure pattern.  Bit-identical
        to per-item :meth:`decode`."""
        outs: list = [None] * len(parts)
        pend_idx: list[int] = []
        pend_chunks: list[np.ndarray] = []
        pend_rows: list[np.ndarray] = []
        systematic = np.arange(self.k)
        for i, (chunks, rows, orig_nbytes) in enumerate(parts):
            use_chunks, use_rows = self._select_rows(chunks, rows)
            if orig_nbytes == 0 or use_chunks.shape[1] == 0:
                outs[i] = b""
            elif np.array_equal(use_rows, systematic):
                outs[i] = use_chunks.reshape(-1)[:orig_nbytes].tobytes()
            else:
                pend_idx.append(i)
                pend_chunks.append(use_chunks)
                pend_rows.append(use_rows)
        if pend_idx:
            datas = kops.decode_chunks_many(
                pend_chunks, pend_rows, self.k, self.p,
                use_kernel=self.use_kernel,
            )
            for i, data in zip(pend_idx, datas):
                nbytes = parts[i][2]
                outs[i] = np.asarray(data).reshape(-1)[:nbytes].tobytes()
        return outs


def plan_cohorts(specs: Sequence[tuple[int, int]]) -> list[tuple[tuple[int, int], list[int]]]:
    """Partition payload indices by codec shape.

    ``specs[i] = (k, p)`` for payload i; returns ``[((k, p), indices),
    ...]`` in first-appearance order — each cohort shares one coding
    matrix and therefore one kernel launch."""
    order: dict[tuple[int, int], list[int]] = {}
    for i, (k, p) in enumerate(specs):
        order.setdefault((int(k), int(p)), []).append(i)
    return list(order.items())


def encode_batch(
    specs: Sequence[tuple[int, int]],
    payloads: Sequence,
    *,
    use_kernel: bool = True,
) -> list[np.ndarray]:
    """Encode a mixed-(K, P) batch: one launch per (K, P) cohort.

    Returns the (N_i, chunk_len_i) chunk matrices in input order."""
    if len(specs) != len(payloads):
        raise ValueError("specs/payloads length mismatch")
    outs: list = [None] * len(payloads)
    for (k, p), idxs in plan_cohorts(specs):
        codec = ECCodec(k, p, use_kernel=use_kernel)
        for i, chunks in zip(idxs, codec.encode_many([payloads[i] for i in idxs])):
            outs[i] = chunks
    return outs


def encode_item(payload: bytes, k: int, p: int, use_kernel: bool = True) -> np.ndarray:
    return ECCodec(k, p, use_kernel).encode(payload)


def decode_item(
    chunks: np.ndarray,
    rows: np.ndarray,
    k: int,
    p: int,
    orig_nbytes: int,
    use_kernel: bool = True,
) -> bytes:
    return ECCodec(k, p, use_kernel).decode(chunks, rows, orig_nbytes)
