"""Item-level erasure codec: bytes -> N chunks -> bytes (with erasures).

Wraps the chunk-matrix kernels with the split/pad/join bookkeeping the
checkpoint manager and benchmarks need. A ``ECCodec(k, p)`` is the data
plane counterpart of a :class:`repro.core.types.Placement`.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.kernels import ops as kops

__all__ = ["ECCodec", "encode_item", "decode_item"]


@dataclasses.dataclass(frozen=True)
class ECCodec:
    k: int
    p: int
    use_kernel: bool = True

    @property
    def n(self) -> int:
        return self.k + self.p

    def chunk_len(self, nbytes: int) -> int:
        return -(-nbytes // self.k)  # ceil(size / K), paper Table 1

    def encode(self, payload: bytes | np.ndarray) -> np.ndarray:
        """bytes -> (N, chunk_len) uint8: K data rows then P parity rows."""
        buf = np.frombuffer(bytes(payload), dtype=np.uint8) if isinstance(
            payload, (bytes, bytearray)
        ) else np.asarray(payload, dtype=np.uint8).ravel()
        clen = self.chunk_len(buf.size)
        padded = np.zeros(self.k * clen, dtype=np.uint8)
        padded[: buf.size] = buf
        data = padded.reshape(self.k, clen)
        parity = np.asarray(
            kops.encode_chunks(data, self.p, use_kernel=self.use_kernel)
        )
        return np.concatenate([data, parity], axis=0)

    def decode(
        self,
        chunks: np.ndarray,
        rows: np.ndarray,
        orig_nbytes: int,
    ) -> bytes:
        """Any K chunk rows (+ their row indices) -> original payload."""
        chunks = np.asarray(chunks, dtype=np.uint8)
        rows = np.asarray(rows)
        if chunks.shape[0] < self.k:
            raise ValueError(
                f"need at least K={self.k} chunks, got {chunks.shape[0]}"
            )
        sel = np.argsort(rows)[: self.k]  # deterministic choice of K rows
        use_rows = rows[sel]
        use_chunks = chunks[sel]
        if np.array_equal(use_rows, np.arange(self.k)):
            data = use_chunks  # all-systematic fast path: no math
        else:
            data = np.asarray(
                kops.decode_chunks(
                    use_chunks, use_rows, self.k, self.p, use_kernel=self.use_kernel
                )
            )
        return data.reshape(-1)[:orig_nbytes].tobytes()


def encode_item(payload: bytes, k: int, p: int, use_kernel: bool = True) -> np.ndarray:
    return ECCodec(k, p, use_kernel).encode(payload)


def decode_item(
    chunks: np.ndarray,
    rows: np.ndarray,
    k: int,
    p: int,
    orig_nbytes: int,
    use_kernel: bool = True,
) -> bytes:
    return ECCodec(k, p, use_kernel).decode(chunks, rows, orig_nbytes)
