"""GF(2^8) arithmetic and Cauchy Reed-Solomon matrix machinery (host side).

The code is the classic systematic Cauchy-RS construction (Jerasure
lineage): generator G = [I_K ; C] with C a P x K Cauchy matrix over
GF(2^8). Every square submatrix of a Cauchy matrix is nonsingular, so any
K of the K+P chunk rows of G are invertible -> any K chunks recover the
item. Field polynomial 0x11d (x^8+x^4+x^3+x^2+1), generator alpha = 2.

Everything here is control-plane numpy (tiny matrices); the data plane is
in repro/kernels (Pallas bit-matrix kernel) with repro/kernels/ref.py as
the pure-jnp oracle.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "GF_EXP",
    "GF_LOG",
    "gf_mul",
    "gf_inv",
    "gf_matmul",
    "gf_mat_inv",
    "cauchy_matrix",
    "generator_matrix",
    "decode_matrix",
    "gf_to_bitmatrix",
]

_POLY = 0x11D


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= _POLY
    exp[255:510] = exp[:255]  # doubled so exp[a+b] needs no mod
    return exp, log


GF_EXP, GF_LOG = _build_tables()


def gf_mul(a, b):
    """Elementwise GF(2^8) product (numpy uint8 arrays or scalars)."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    out = GF_EXP[GF_LOG[a].astype(np.int64) + GF_LOG[b].astype(np.int64)]
    return np.where((a == 0) | (b == 0), np.uint8(0), out)


def gf_inv(a):
    a = np.asarray(a, dtype=np.uint8)
    if np.any(a == 0):
        raise ZeroDivisionError("0 has no inverse in GF(2^8)")
    return GF_EXP[255 - GF_LOG[a].astype(np.int64)]


def gf_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(m,n) @ (n,p) over GF(2^8): XOR-accumulated products."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    # products[m, n, p] then XOR-reduce over n
    prod = gf_mul(a[:, :, None], b[None, :, :])
    return np.bitwise_xor.reduce(prod, axis=1)


def gf_mat_inv(m: np.ndarray) -> np.ndarray:
    """Gauss-Jordan inverse over GF(2^8); raises if singular."""
    m = np.asarray(m, dtype=np.uint8)
    n = m.shape[0]
    assert m.shape == (n, n)
    a = m.astype(np.uint8).copy()
    inv = np.eye(n, dtype=np.uint8)
    for col in range(n):
        pivot = None
        for row in range(col, n):
            if a[row, col] != 0:
                pivot = row
                break
        if pivot is None:
            raise np.linalg.LinAlgError("singular matrix over GF(2^8)")
        if pivot != col:
            a[[col, pivot]] = a[[pivot, col]]
            inv[[col, pivot]] = inv[[pivot, col]]
        pinv = gf_inv(a[col, col])
        a[col] = gf_mul(a[col], pinv)
        inv[col] = gf_mul(inv[col], pinv)
        for row in range(n):
            if row != col and a[row, col] != 0:
                factor = a[row, col]
                a[row] ^= gf_mul(factor, a[col])
                inv[row] ^= gf_mul(factor, inv[col])
    return inv


def cauchy_matrix(p: int, k: int) -> np.ndarray:
    """P x K Cauchy matrix C[i,j] = 1/(x_i ^ y_j), x_i = i, y_j = p + j.

    Requires p + k <= 256 (distinct field points)."""
    if p + k > 256:
        raise ValueError(f"Cauchy construction needs P+K <= 256, got {p + k}")
    xs = np.arange(p, dtype=np.uint8)[:, None]
    ys = np.arange(p, p + k, dtype=np.uint8)[None, :]
    return gf_inv(xs ^ ys)


def generator_matrix(k: int, p: int) -> np.ndarray:
    """Systematic generator [I_K ; C] of shape (K+P, K)."""
    return np.concatenate([np.eye(k, dtype=np.uint8), cauchy_matrix(p, k)], axis=0)


def decode_matrix(k: int, p: int, surviving_rows: np.ndarray) -> np.ndarray:
    """(K,K) matrix mapping K surviving chunks -> K data chunks.

    ``surviving_rows``: indices (into the N=K+P chunk rows) of the K
    chunks being used for reconstruction."""
    rows = np.asarray(surviving_rows, dtype=np.int64)
    if rows.shape != (k,):
        raise ValueError(f"need exactly K={k} surviving rows, got {rows.shape}")
    if np.unique(rows).size != k or rows.min() < 0 or rows.max() >= k + p:
        raise ValueError("surviving rows must be distinct indices in [0, K+P)")
    g = generator_matrix(k, p)
    return gf_mat_inv(g[rows, :])


# -- bit-matrix construction (the TPU adaptation, DESIGN.md §4) -------------

_BIT_BASIS = np.array([1 << j for j in range(8)], dtype=np.uint8)


def gf_to_bitmatrix(m: np.ndarray) -> np.ndarray:
    """Expand a GF(2^8) matrix (R, K) into its GF(2) bit-matrix (8R, 8K).

    Multiplication by a constant g is linear over GF(2); column j of the
    8x8 block for g holds the bits of g * x^j. With LSB-first bit order:

        out_bits[r*8 + i, k*8 + j] = bit i of gf_mul(m[r, k], 1 << j)

    so that ``parity_bits = bitmatrix @ data_bits (mod 2)`` computes the
    same code as ``parity = m @ data`` over GF(2^8).
    """
    m = np.asarray(m, dtype=np.uint8)
    r, k = m.shape
    # prods[r, k, j] = m[r,k] * 2^j  -> bits[r, k, i, j]
    prods = gf_mul(m[:, :, None], _BIT_BASIS[None, None, :])
    bits = (prods[:, :, None, :] >> np.arange(8)[None, None, :, None]) & 1
    # lay out as (r, i) x (k, j)
    return bits.transpose(0, 2, 1, 3).reshape(8 * r, 8 * k).astype(np.uint8)
