"""Erasure-coding layer: GF(2^8) Cauchy Reed-Solomon (host matrices) +
item-level codec built on the Pallas/ref kernels."""

from .gf256 import (
    cauchy_matrix,
    decode_matrix,
    generator_matrix,
    gf_inv,
    gf_mat_inv,
    gf_matmul,
    gf_mul,
    gf_to_bitmatrix,
)
from .codec import ECCodec, encode_item, decode_item, encode_batch, plan_cohorts

__all__ = [
    "gf_mul",
    "gf_inv",
    "gf_matmul",
    "gf_mat_inv",
    "cauchy_matrix",
    "generator_matrix",
    "decode_matrix",
    "gf_to_bitmatrix",
    "ECCodec",
    "encode_item",
    "decode_item",
    "encode_batch",
    "plan_cohorts",
]
