"""Error-feedback int8 gradient compression (distributed-optimization
trick for the DP all-reduce path).

Gradients are quantized to int8 with a per-tensor scale before the
data-parallel reduction and dequantized after; the quantization residual
is carried in an error-feedback buffer so the compression bias vanishes
over steps (Seide et al. / EF-SGD lineage). 4x reduction of DP all-reduce
bytes at the cost of one extra buffer per parameter.

Honest scope note: under XLA SPMD the gradient reductions happen as
partial-sum all-reduces *inside* the backward dots, before this hook
sees the gradients — quantizing here compresses what a parameter-server
or explicit shard_map/psum reduction path would move, not GSPMD's
fused wgrad all-reduces. Wiring EF-int8 into the actual reduction
requires a shard_map custom all-reduce (documented follow-up in
EXPERIMENTS.md §Perf); the optimizer-side machinery (error feedback,
bounded quantization error, convergence) is implemented and tested
here.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # per-parameter f32 residual buffers


def compression_init(params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    )


def _quantize(x):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, state: CompressionState):
    """Apply EF-int8 to every gradient leaf. Returns (grads', new_state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    pairs = jax.tree.map(one, grads, state.error)
    new_grads = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, CompressionState(error=new_err)
