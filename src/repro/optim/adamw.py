"""AdamW with f32 master weights over bf16 compute params.

Functional (no optax dependency): ``adamw_init`` builds the state pytree
(sharded like the params via the same logical axes — FSDP shards the
optimizer moments too), ``adamw_update`` applies one step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    #: cosine decay horizon; 0 disables scheduling (constant lr after warmup)
    decay_steps: int = 10_000


class OptState(NamedTuple):
    step: jax.Array          # scalar int32
    mu: Any                  # first moment (f32, like params)
    nu: Any                  # second moment (f32)
    master: Any              # f32 master copy of params


def adamw_init(params) -> OptState:
    # The eager add forces distinct buffers: jnp.zeros of identical
    # shape/dtype can return a shared cached constant, and two aliased
    # leaves inside one donated TrainState trip XLA's double-donation check.
    f32 = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32) + 0.0, t)
    master = jax.tree.map(lambda x: x.astype(jnp.float32) + 0.0, params)
    return OptState(jnp.zeros((), jnp.int32), f32(params), f32(params), master)


def opt_state_axes(axes_tree) -> OptState:
    """Logical axes for the optimizer state (moments/master mirror params)."""
    return OptState(step=(), mu=axes_tree, nu=axes_tree, master=axes_tree)


def _schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    if cfg.decay_steps > 0:
        frac = jnp.clip(step / cfg.decay_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return cfg.lr * warm * (0.1 + 0.9 * cos)
    return cfg.lr * warm


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(cfg: AdamWConfig, grads, state: OptState, params):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(w, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w)

    master = jax.tree.map(upd, state.master, mu, nu)
    new_params = jax.tree.map(
        lambda w, p: w.astype(p.dtype), master, params
    )
    return new_params, OptState(step, mu, nu, master), {
        "grad_norm": gnorm,
        "lr": lr,
    }
