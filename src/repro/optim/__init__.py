"""Optimizers: AdamW with f32 master weights, global-norm clipping, and
optional error-feedback int8 gradient compression."""

from .adamw import AdamWConfig, OptState, adamw_init, adamw_update, clip_by_global_norm
from .compression import CompressionState, compress_decompress, compression_init

__all__ = [
    "AdamWConfig",
    "OptState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "CompressionState",
    "compression_init",
    "compress_decompress",
]
