"""Pallas TPU kernels for the paper's compute hot-spot (EC coding)."""

from . import ops, ref
from .rs_bitmatmul import gf_bitmatmul, DEFAULT_BLOCK_BYTES

__all__ = ["ops", "ref", "gf_bitmatmul", "DEFAULT_BLOCK_BYTES"]
