"""Jit'd public wrappers over the coding kernels.

``encode_chunks`` / ``decode_chunks`` operate on (K, B) byte matrices and
handle padding to the kernel's block size; ``repro.ec.codec`` builds the
item-level API (split/join, chunk manifests) on top of these.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.ec import gf256
from .rs_bitmatmul import DEFAULT_BLOCK_BYTES, gf_bitmatmul
from . import ref as _ref

__all__ = ["encode_chunks", "decode_chunks", "encode_chunks_ref", "decode_chunks_ref"]


def _bitmatrix_for(m: np.ndarray) -> jnp.ndarray:
    return jnp.asarray(gf256.gf_to_bitmatrix(m), dtype=jnp.float32)


def _pad_to_block(data: jax.Array, block: int) -> tuple[jax.Array, int]:
    k, b = data.shape
    rem = (-b) % block
    if rem:
        data = jnp.pad(data, ((0, 0), (0, rem)))
    return data, b


def encode_chunks(
    data_chunks,
    p: int,
    *,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    use_kernel: bool = True,
) -> jax.Array:
    """Parity chunks (P, B) for systematic Cauchy-RS over (K, B) data."""
    data = jnp.asarray(data_chunks, dtype=jnp.uint8)
    k = data.shape[0]
    cauchy = gf256.cauchy_matrix(p, k)
    if not use_kernel:
        return _ref.encode_ref(data, jnp.asarray(cauchy))
    padded, b = _pad_to_block(data, block_bytes)
    out = gf_bitmatmul(_bitmatrix_for(cauchy), padded, block_bytes=block_bytes)
    return out[:, :b]


def decode_chunks(
    surviving_chunks,
    surviving_rows,
    k: int,
    p: int,
    *,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    use_kernel: bool = True,
) -> jax.Array:
    """Reconstruct the K data chunks from any K surviving chunk rows.

    ``surviving_rows``: indices into the N=K+P rows matching the order of
    ``surviving_chunks`` (K, B)."""
    surv = jnp.asarray(surviving_chunks, dtype=jnp.uint8)
    dec = gf256.decode_matrix(k, p, np.asarray(surviving_rows))
    if not use_kernel:
        return _ref.decode_ref(surv, jnp.asarray(dec))
    padded, b = _pad_to_block(surv, block_bytes)
    out = gf_bitmatmul(_bitmatrix_for(dec), padded, block_bytes=block_bytes)
    return out[:, :b]


def encode_chunks_ref(data_chunks, p: int) -> jax.Array:
    """Oracle path (pure jnp log/exp tables)."""
    return encode_chunks(data_chunks, p, use_kernel=False)


def decode_chunks_ref(surviving_chunks, surviving_rows, k: int, p: int) -> jax.Array:
    return decode_chunks(surviving_chunks, surviving_rows, k, p, use_kernel=False)
