"""Jit'd public wrappers over the coding kernels.

``encode_chunks`` / ``decode_chunks`` operate on (K, B) byte matrices;
``encode_chunks_many`` / ``decode_chunks_many`` batch whole cohorts of
same-shape codings into ONE kernel launch; ``repro.ec.codec`` builds the
item-level API (split/pad/join, chunk manifests) on top of these.

Three data-plane optimizations live here (everything above sees only
bytes in, bytes out, bit-identical to the per-item oracle):

* **Cached coding matrices.**  The host-side Cauchy / decode matrices
  and their GF(2) bit-matrix expansions are pure functions of ``(k, p)``
  (encode) and ``(k, p, surviving_rows)`` (decode) — memoized in
  process-wide LRU caches so steady-state encode/repair stops rebuilding
  the same tiny matrices (``gf_mat_inv`` is Python-loop pivoting) on
  every call.  ``matrix_cache_stats`` exposes build/hit counters.

* **Multi-item launches.**  The coding kernels are linear per byte
  column: ``M @ [D1 | D2 | ...] == [M@D1 | M@D2 | ...]``, so a cohort of
  groups sharing a bit matrix concatenates along the byte axis into one
  launch — one dispatch instead of one per group, and the f32
  bit-accumulation stays exact (sums <= 8K <= 2048), so batched output
  is *bit-identical* to the per-item path by construction.

* **Shape buckets.**  The byte axis is padded to a bucketed block count
  (:func:`repro.core.shapes.ec_block_pad` — the same rung/hysteresis
  planner the placement kernels share) so churn in cohort sizes does not
  churn XLA compiles; every launch records its static signature through
  the shared compile census (``compile_cache_stats``).

Backend dispatch: the Pallas bit-matmul targets the TPU MXU; off-TPU the
kernel path runs the jitted XLA bit-matmul (``ref.bitmatmul_ref`` under
``jax.jit``) — the same unpack/matmul/pack algorithm, so CPU CI both
tests and *times* the kernel path instead of interpreting Pallas.
``pallas=True`` forces the Pallas kernel (interpret mode off-TPU; the
correctness harness in tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import threading as _threading

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import shapes as _shapes
from repro.ec import gf256
from .rs_bitmatmul import DEFAULT_BLOCK_BYTES, gf_bitmatmul
from . import ref as _ref

__all__ = [
    "encode_chunks",
    "decode_chunks",
    "encode_chunks_many",
    "decode_chunks_many",
    "encode_chunks_ref",
    "decode_chunks_ref",
    "matrix_cache_stats",
    "reset_matrix_caches",
    "MATRIX_CACHE_SIZE",
]

#: LRU bound on the decode-matrix cache: (k, p, surviving_rows) patterns
#: are combinatorial, so unlike the (k, p) encode cache the decode cache
#: must evict.  256 distinct erasure patterns covers steady-state repair
#: of any realistic failure mix; eviction just means a rebuild.
MATRIX_CACHE_SIZE = 256

#: kernel name under which every coding launch records its static
#: signature in the shared compile census (repro.core.shapes).
CENSUS_KERNEL = "rs_bitmatmul"

#: build counters behind the LRU caches (the counter hook the cache
#: tests pin "built exactly once" against).  ``lru_cache`` does NOT hold
#: its lock while the wrapped builder runs, so two threads missing the
#: same key concurrently (the serve frontier's worker threads do) both
#: execute the builder — a bare ``+= 1`` here is a read-modify-write
#: race that loses increments.  All counter updates go through
#: :func:`_note_build` under ``_builds_lock``; regression:
#: tests/test_threaded_counters.py.
_MATRIX_BUILDS = {"encode": 0, "decode": 0}
_builds_lock = _threading.Lock()


def _note_build(kind: str) -> None:
    with _builds_lock:
        _MATRIX_BUILDS[kind] += 1


@functools.lru_cache(maxsize=MATRIX_CACHE_SIZE)
def _encode_matrices(k: int, p: int):
    """(Cauchy GF matrix, (8P, 8K) f32 bit matrix) for encode — cached.

    The numpy matrix is returned read-only: cached arrays are shared."""
    _note_build("encode")
    cauchy = gf256.cauchy_matrix(p, k)
    cauchy.setflags(write=False)
    bitm = jnp.asarray(gf256.gf_to_bitmatrix(cauchy), dtype=jnp.float32)
    return cauchy, bitm


@functools.lru_cache(maxsize=MATRIX_CACHE_SIZE)
def _decode_matrices(k: int, p: int, rows: tuple):
    """(decode GF matrix, (8K, 8K) f32 bit matrix) for one erasure
    pattern — cached so repeated decodes of the same pattern pay the
    Gauss-Jordan inversion exactly once."""
    _note_build("decode")
    dec = gf256.decode_matrix(k, p, np.asarray(rows, dtype=np.int64))
    dec.setflags(write=False)
    bitm = jnp.asarray(gf256.gf_to_bitmatrix(dec), dtype=jnp.float32)
    return dec, bitm


def matrix_cache_stats() -> dict:
    """Telemetry: matrix builds vs cache hits (see MATRIX_CACHE_SIZE)."""
    enc, dec = _encode_matrices.cache_info(), _decode_matrices.cache_info()
    with _builds_lock:
        encode_builds = _MATRIX_BUILDS["encode"]
        decode_builds = _MATRIX_BUILDS["decode"]
    return {
        "encode_builds": encode_builds,
        "decode_builds": decode_builds,
        "encode_cache": {"hits": enc.hits, "misses": enc.misses,
                         "size": enc.currsize, "maxsize": enc.maxsize},
        "decode_cache": {"hits": dec.hits, "misses": dec.misses,
                         "size": dec.currsize, "maxsize": dec.maxsize},
    }


def reset_matrix_caches() -> None:
    """Clear the matrix caches and build counters (tests)."""
    _encode_matrices.cache_clear()
    _decode_matrices.cache_clear()
    with _builds_lock:
        _MATRIX_BUILDS["encode"] = 0
        _MATRIX_BUILDS["decode"] = 0


def _rows_key(surviving_rows) -> tuple:
    return tuple(int(r) for r in np.asarray(surviving_rows).reshape(-1))


# -- one launch: pad -> census -> matmul -------------------------------------

#: column tile (in byte blocks) for the XLA twin of the Pallas kernel.
#: ``lax.map`` over cache-sized tiles keeps each tile's unpacked f32 bit
#: planes resident while it is consumed; a monolithic launch at
#: checkpoint-cohort widths materializes tens of MB of intermediates and
#: runs ~4x slower (measured in benchmarks/fig1's batched lane).  The
#: Pallas kernel needs no analogue — its grid over ``block_bytes``
#: blocks IS the tiling.
EC_TILE_BLOCKS = 2


@functools.partial(jax.jit, static_argnames=("block_bytes",))
def _bitmatmul_xla(bitm, data, *, block_bytes: int = DEFAULT_BLOCK_BYTES):
    k, b = data.shape
    tile = EC_TILE_BLOCKS * block_bytes
    # Bucketed widths are powers of two below 8 blocks and multiples of
    # 8 blocks above (shapes.ec_block_pad), so any width > tile divides
    # evenly; the guard keeps the function total for direct callers.
    if b <= tile or b % tile:
        return _ref.bitmatmul_ref(bitm, data)
    n_tiles = b // tile
    tiles = data.reshape(k, n_tiles, tile).transpose(1, 0, 2)
    out = jax.lax.map(lambda t: _ref.bitmatmul_ref(bitm, t), tiles)
    return out.transpose(1, 0, 2).reshape(out.shape[1], b)


def _pad_to_bucket(data: jax.Array, block: int) -> tuple[jax.Array, int]:
    """Pad the byte axis to a *bucketed* multiple of ``block`` (zeros)."""
    k, b = data.shape
    blocks = -(-b // block)  # ceil; at least 1 block so grids are nonempty
    target = _shapes.ec_block_pad(max(1, blocks)) * block
    if target != b:
        data = jnp.pad(data, ((0, 0), (0, target - b)))
    return data, b


def _bitmatmul(
    bitm: jax.Array,
    data: jax.Array,
    *,
    block_bytes: int,
    pallas: bool | None,
) -> jax.Array:
    """One coding launch on a block-aligned (K, B) byte matrix."""
    if pallas is None:
        pallas = jax.default_backend() == "tpu"
    r8, k8 = bitm.shape
    _shapes.record_compile(
        CENSUS_KERNEL,
        (r8, k8, data.shape[1] // block_bytes, block_bytes,
         "pallas" if pallas else "xla"),
    )
    if pallas:
        return gf_bitmatmul(bitm, data, block_bytes=block_bytes)
    return _bitmatmul_xla(bitm, data, block_bytes=block_bytes)


# -- per-item API (the bit-for-bit oracle for the _many paths) ---------------

def encode_chunks(
    data_chunks,
    p: int,
    *,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    use_kernel: bool = True,
    pallas: bool | None = None,
) -> jax.Array:
    """Parity chunks (P, B) for systematic Cauchy-RS over (K, B) data."""
    data = jnp.asarray(data_chunks, dtype=jnp.uint8)
    k, b = data.shape
    if b == 0:  # empty item: a well-defined empty parity, no kernel call
        return jnp.zeros((p, 0), dtype=jnp.uint8)
    cauchy, bitm = _encode_matrices(k, p)
    if not use_kernel:
        return _ref.encode_ref(data, jnp.asarray(cauchy))
    padded, b = _pad_to_bucket(data, block_bytes)
    out = _bitmatmul(bitm, padded, block_bytes=block_bytes, pallas=pallas)
    return out[:, :b]


def decode_chunks(
    surviving_chunks,
    surviving_rows,
    k: int,
    p: int,
    *,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    use_kernel: bool = True,
    pallas: bool | None = None,
) -> jax.Array:
    """Reconstruct the K data chunks from any K surviving chunk rows.

    ``surviving_rows``: indices into the N=K+P rows matching the order of
    ``surviving_chunks`` (K, B)."""
    surv = jnp.asarray(surviving_chunks, dtype=jnp.uint8)
    dec, bitm = _decode_matrices(k, p, _rows_key(surviving_rows))
    if surv.shape[1] == 0:
        return jnp.zeros((k, 0), dtype=jnp.uint8)
    if not use_kernel:
        return _ref.decode_ref(surv, jnp.asarray(dec))
    padded, b = _pad_to_bucket(surv, block_bytes)
    out = _bitmatmul(bitm, padded, block_bytes=block_bytes, pallas=pallas)
    return out[:, :b]


# -- multi-item API: one launch per cohort -----------------------------------

def _matmul_concat(
    mats: list[np.ndarray],
    gf_matrix: np.ndarray,
    bitm,
    out_rows: int,
    *,
    block_bytes: int,
    use_kernel: bool,
    pallas: bool | None,
) -> list[np.ndarray]:
    """Apply one coding matrix to many (K, B_i) matrices in one launch."""
    widths = [m.shape[1] for m in mats]
    outs: list = [None] * len(mats)
    live = [i for i, w in enumerate(widths) if w > 0]
    for i, w in enumerate(widths):
        if w == 0:
            outs[i] = np.zeros((out_rows, 0), dtype=np.uint8)
    if live:
        cat = jnp.asarray(
            np.concatenate([mats[i] for i in live], axis=1), dtype=jnp.uint8
        )
        total = cat.shape[1]
        if use_kernel:
            padded, _ = _pad_to_bucket(cat, block_bytes)
            out = _bitmatmul(
                bitm, padded, block_bytes=block_bytes, pallas=pallas
            )[:, :total]
        else:
            out = _ref.gf_matmul_ref(jnp.asarray(gf_matrix), cat)
        out = np.asarray(out)
        off = 0
        for i in live:
            outs[i] = out[:, off : off + widths[i]]
            off += widths[i]
    return outs


def encode_chunks_many(
    data_chunks_list,
    p: int,
    *,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    use_kernel: bool = True,
    pallas: bool | None = None,
) -> list[np.ndarray]:
    """Parity for a cohort of (K, B_i) data matrices sharing K and P.

    The cohort is stacked along the byte axis into ONE kernel launch
    (byte lengths may differ — the code is columnwise); results are
    bit-identical to per-item :func:`encode_chunks`.  Returns a list of
    (P, B_i) numpy arrays in input order."""
    mats = [np.asarray(d, dtype=np.uint8) for d in data_chunks_list]
    if not mats:
        return []
    k = mats[0].shape[0]
    for m in mats:
        if m.shape[0] != k:
            raise ValueError(
                f"cohort mixes K: {m.shape[0]} vs {k} (partition by (K, P) "
                "first — see repro.ec.codec.plan_cohorts)"
            )
    cauchy, bitm = _encode_matrices(k, p)
    return _matmul_concat(
        mats, cauchy, bitm, p,
        block_bytes=block_bytes, use_kernel=use_kernel, pallas=pallas,
    )


def decode_chunks_many(
    surviving_chunks_list,
    surviving_rows_list,
    k: int,
    p: int,
    *,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    use_kernel: bool = True,
    pallas: bool | None = None,
) -> list[np.ndarray]:
    """Reconstruct many items sharing (K, P): one launch per distinct
    erasure pattern (the decode matrix depends on the surviving rows).

    Returns a list of (K, B_i) numpy arrays in input order."""
    mats = [np.asarray(c, dtype=np.uint8) for c in surviving_chunks_list]
    if len(mats) != len(surviving_rows_list):
        raise ValueError("chunks/rows length mismatch")
    by_pattern: dict[tuple, list[int]] = {}
    for i, rows in enumerate(surviving_rows_list):
        by_pattern.setdefault(_rows_key(rows), []).append(i)
    outs: list = [None] * len(mats)
    for rows_key, idxs in by_pattern.items():
        dec, bitm = _decode_matrices(k, p, rows_key)
        got = _matmul_concat(
            [mats[i] for i in idxs], dec, bitm, k,
            block_bytes=block_bytes, use_kernel=use_kernel, pallas=pallas,
        )
        for i, out in zip(idxs, got):
            outs[i] = out
    return outs


def encode_chunks_ref(data_chunks, p: int) -> jax.Array:
    """Oracle path (pure jnp log/exp tables)."""
    return encode_chunks(data_chunks, p, use_kernel=False)


def decode_chunks_ref(surviving_chunks, surviving_rows, k: int, p: int) -> jax.Array:
    return decode_chunks(surviving_chunks, surviving_rows, k, p, use_kernel=False)
