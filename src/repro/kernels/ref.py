"""Pure-jnp oracle for the GF(2^8) coding kernels.

This is the "CPU algorithm" the paper's encode/decode hot-spot uses:
log/exp-table multiplication with XOR accumulation. It defines the
semantics the Pallas bit-matrix kernel must match bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.ec.gf256 import GF_EXP, GF_LOG

_EXP = jnp.asarray(GF_EXP)          # (512,) uint8, doubled
_LOG = jnp.asarray(GF_LOG)          # (256,) int32


def gf_mul_ref(a, b):
    """Elementwise GF(2^8) multiply via log/exp tables (jnp)."""
    a = jnp.asarray(a, dtype=jnp.uint8)
    b = jnp.asarray(b, dtype=jnp.uint8)
    la = _LOG[a.astype(jnp.int32)]
    lb = _LOG[b.astype(jnp.int32)]
    out = _EXP[la + lb]
    return jnp.where((a == 0) | (b == 0), jnp.uint8(0), out)


def gf_matmul_ref(m, data):
    """(R, K) GF matrix times (K, B) byte matrix -> (R, B) bytes.

    products[r, k, b] XOR-reduced over k; this is exactly the dot product
    structure the paper's Fig. 1 measures (R*K*B multiply-XOR ops).
    """
    m = jnp.asarray(m, dtype=jnp.uint8)
    data = jnp.asarray(data, dtype=jnp.uint8)
    r, k = m.shape
    k2, b = data.shape
    assert k == k2, (m.shape, data.shape)

    def body(i, acc):
        prod = gf_mul_ref(m[:, i][:, None], data[i][None, :])
        return acc ^ prod

    return jax.lax.fori_loop(0, k, body, jnp.zeros((r, b), dtype=jnp.uint8))


def encode_ref(data_chunks, cauchy):
    """Systematic encode: parity (P, B) = C (P, K) x data (K, B)."""
    return gf_matmul_ref(cauchy, data_chunks)


def decode_ref(surviving_chunks, dec_matrix):
    """Reconstruct data (K, B) from K surviving chunks via the inverted
    generator submatrix (K, K)."""
    return gf_matmul_ref(dec_matrix, surviving_chunks)


def bitmatmul_ref(bit_matrix, data_chunks):
    """Mod-2 bit-matrix product with explicit unpack/pack — the semantic
    spec of the Pallas kernel, in plain jnp (no pallas).

    bit_matrix: (8R, 8K) in {0,1}; data_chunks: (K, B) uint8.
    Returns (R, B) uint8. Must equal gf_matmul_ref(m, data) when
    bit_matrix = gf_to_bitmatrix(m).
    """
    bm = jnp.asarray(bit_matrix, dtype=jnp.float32)
    d = jnp.asarray(data_chunks, dtype=jnp.uint8)
    k, b = d.shape
    r8 = bm.shape[0]
    assert bm.shape[1] == 8 * k
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (d.astype(jnp.int32)[:, None, :] >> shifts[None, :, None]) & 1  # (K,8,B)
    bits = bits.reshape(8 * k, b).astype(jnp.float32)
    acc = bm @ bits                                  # exact integers in f32
    par_bits = acc.astype(jnp.int32) & 1             # mod 2
    par_bits = par_bits.reshape(r8 // 8, 8, b)
    weights = (1 << shifts).astype(jnp.int32)
    out = (par_bits * weights[None, :, None]).sum(axis=1)
    return out.astype(jnp.uint8)
