"""Pallas TPU kernel: Cauchy-RS coding as a mod-2 bit-matrix MXU matmul.

TPU adaptation of the paper's GF(2^8) hot-spot (DESIGN.md §4): instead of
the CPU's 256-entry lookup-table gathers (hostile to the MXU), the code's
GF(2)-linearity turns encode/decode into one dense {0,1} matmul

    out_bits (8R, B) = bit_matrix (8R, 8K) @ data_bits (8K, B)   (mod 2)

evaluated in f32 on the systolic array (sums <= 8K <= 2048 are exact in
f32). HBM traffic stays at byte granularity: the 8x bit inflation happens
in VMEM after the tile load, and parity bits are re-packed to bytes
before the store.

Grid: 1-D over byte columns. Per-program VMEM working set for block size
``bb`` and K data chunks: K*bb (input bytes) + 8K*bb*4 (bits f32) +
8R*bb*4 (acc) + R*bb (output) bytes — for K=16, R=16, bb=2048 that is
~2.3 MB, comfortably inside a v5e's ~16 MB VMEM with double-buffering.

The byte dimension block (lane dimension) is kept a multiple of 128; the
bit dimensions (8K, 8R) are multiples of 8 and are padded by Mosaic to
the MXU's 128 where needed — for the small K of storage codes the MXU is
underutilized in one dimension, which is intrinsic to the problem shape
(see EXPERIMENTS.md §Roofline for the kernel's arithmetic-intensity
analysis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_BYTES = 2048


def _coding_kernel(bitm_ref, data_ref, out_ref, *, r: int, k: int):
    """One byte-tile: unpack -> f32 MXU matmul -> mod 2 -> pack."""
    d = data_ref[...].astype(jnp.int32)                       # (K, bb)
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (d[:, None, :] >> shifts[None, :, None]) & 1       # (K, 8, bb)
    bits = bits.reshape(8 * k, d.shape[-1]).astype(jnp.float32)
    bm = bitm_ref[...]                                        # (8R, 8K) f32
    acc = jnp.dot(bm, bits, preferred_element_type=jnp.float32)
    par_bits = acc.astype(jnp.int32) & 1                      # exact mod-2
    par_bits = par_bits.reshape(r, 8, d.shape[-1])
    weights = (jnp.int32(1) << shifts).astype(jnp.int32)
    packed = (par_bits * weights[None, :, None]).sum(axis=1)
    out_ref[...] = packed.astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("block_bytes", "interpret")
)
def gf_bitmatmul(
    bit_matrix: jax.Array,
    data_chunks: jax.Array,
    *,
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    interpret: bool | None = None,
) -> jax.Array:
    """out (R, B) u8 = GF(2^8) matrix-product via bit-matmul.

    ``bit_matrix``: (8R, 8K) f32 in {0,1} (from gf_to_bitmatrix).
    ``data_chunks``: (K, B) uint8, B a multiple of ``block_bytes``
    (ops.py pads).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    r8, k8 = bit_matrix.shape
    assert r8 % 8 == 0 and k8 % 8 == 0, bit_matrix.shape
    r, k = r8 // 8, k8 // 8
    kk, b = data_chunks.shape
    assert kk == k, (data_chunks.shape, bit_matrix.shape)
    assert b % block_bytes == 0, (b, block_bytes)
    grid = (b // block_bytes,)

    return pl.pallas_call(
        functools.partial(_coding_kernel, r=r, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r8, k8), lambda i: (0, 0)),          # whole matrix
            pl.BlockSpec((k, block_bytes), lambda i: (0, i)),  # byte tile
        ],
        out_specs=pl.BlockSpec((r, block_bytes), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, b), jnp.uint8),
        interpret=interpret,
    )(bit_matrix.astype(jnp.float32), data_chunks)
