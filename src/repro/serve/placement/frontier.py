"""The streaming placement frontier: a long-lived service over
:class:`~repro.core.engine.PlacementEngine`.

Open-loop arrivals are admitted into a bounded queue
(:mod:`.admission`), coalesced into micro-batched ``place_many``
*windows* (flush when the window fills or its oldest item has waited
``max_wait_s``), and decided against the live cluster; every flush and
every churn event publishes a snapshot :class:`~.epochs.Epoch` so reads
see a consistent view without ever blocking placements.

**Determinism.**  The service runs on a *virtual clock*: arrivals and
churn events carry virtual timestamps, and a deterministic service-time
model (``service_base_s + B * service_per_item_s`` virtual seconds per
window of B items) governs when the frontier is busy — which fixes
window composition, queue depths, and admission rejects as pure
functions of the trace and configuration.  Replaying the same trace +
seed therefore yields byte-identical placements on any machine (pinned
by golden-trace tests and the serve_load equality gates), while the real
wall-clock cost of each ``place_many`` call is measured separately as
telemetry (p50/p99 decision latency) that never feeds back into
decisions.  Single-threaded by construction: "concurrency" between
readers and placements is the epoch snapshot discipline, not threads.

**Correctness under churn.**  ``place_many`` is bit-identical to
sequential ``place`` per item in arrival order, so placements are
invariant to how arrivals are partitioned into windows; the only thing
window boundaries decide is *which cluster state* an item is scored
against when failures/joins interleave with arrivals — exactly the
mid-window churn the service must absorb.  Failures route every affected
stored item through ``engine.plan_repair`` (the instantaneous
placement-plane model, matching ``Simulator._repair_or_drop`` with
infinite repair bandwidth), most-degraded first by
surviving-chunks-minus-K margin (the simulator's health priority);
unrecoverable items release their surviving chunks and are counted
lost — never silently.

Failure-domain awareness comes for free from the engine: construct the
``PlacementEngine`` with :class:`~repro.core.types.PlacementConstraints`
and every placement and repair the frontier makes — including the
post-failure replans — honors the rack/zone caps and spread width.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Iterable, Optional, Sequence

from repro.core.engine import BatchContext, PlacementEngine
from repro.core.types import DataItem, Placement, StorageNode

from .admission import AdmissionQueue
from .epochs import Epoch, EpochJournal
from .metrics import ServiceMetrics

__all__ = [
    "FrontierConfig",
    "PlacementFrontier",
    "ServiceEvent",
    "ServiceOutcome",
    "ServiceReport",
    "arrival_events",
    "churn_events",
    "placements_digest",
]

SECONDS_PER_DAY = 86400.0

#: outcome statuses (never a fourth: every offered item ends in one)
PLACED = "placed"
REJECTED = "rejected"               # scheduler found no feasible mapping
ADMISSION_REJECT = "admission_reject"  # queue was full (backpressure)

# event priorities at equal virtual time: cluster membership changes
# apply before arrivals, mirroring the simulator's event ordering.
_P_JOIN, _P_HEAL, _P_FAIL, _P_ARRIVAL = 0, 1, 2, 3
_PRIO = {"join": _P_JOIN, "heal": _P_HEAL, "fail": _P_FAIL, "arrival": _P_ARRIVAL}


@dataclasses.dataclass(frozen=True)
class FrontierConfig:
    """Tuning knobs for one frontier instance."""

    #: window flushes as soon as this many items are queued ...
    max_batch: int = 32
    #: ... or once its oldest item has waited this long (virtual s).
    max_wait_s: float = 0.05
    #: admission-queue bound; offers beyond it are rejected explicitly.
    queue_capacity: int = 256
    #: deterministic service-time model: a window of B items occupies
    #: the frontier for ``service_base_s + B * service_per_item_s``
    #: virtual seconds.  Fixed constants — never measured — so queue
    #: dynamics and admission decisions replay identically everywhere.
    service_base_s: float = 2e-3
    service_per_item_s: float = 1e-3
    #: snapshot epochs retained for history diffing.
    epoch_history: int = 8

    def service_s(self, batch: int) -> float:
        return self.service_base_s + batch * self.service_per_item_s


@dataclasses.dataclass(frozen=True)
class ServiceEvent:
    """One virtual-time event: ``kind`` in {arrival, fail, join, heal}."""

    t: float
    kind: str
    payload: object  # DataItem | node id | StorageNode


def arrival_events(items: Iterable[DataItem]) -> list[ServiceEvent]:
    """Arrival events from a trace (``DataItem.arrival_time`` seconds)."""
    return [ServiceEvent(float(it.arrival_time), "arrival", it) for it in items]


def churn_events(
    failure_schedule: Sequence[tuple[float, int]] = (),
    node_join_schedule: Sequence[tuple[float, StorageNode]] = (),
    node_heal_schedule: Sequence[tuple[float, int]] = (),
    *,
    unit: str = "days",
) -> list[ServiceEvent]:
    """Churn events from SimConfig-style ``(when, what)`` schedules.

    ``unit`` is ``"days"`` (the simulator's convention) or ``"seconds"``
    (the frontier's native clock).
    """
    scale = SECONDS_PER_DAY if unit == "days" else 1.0
    if unit not in ("days", "seconds"):
        raise ValueError(f"unknown time unit {unit!r}")
    out = [ServiceEvent(t * scale, "fail", int(n)) for t, n in failure_schedule]
    out += [ServiceEvent(t * scale, "join", node) for t, node in node_join_schedule]
    out += [ServiceEvent(t * scale, "heal", int(n)) for t, n in node_heal_schedule]
    return out


@dataclasses.dataclass(frozen=True)
class ServiceOutcome:
    """Per-item service result — one per offered item, no silent drops."""

    item_id: int
    status: str                      # PLACED | REJECTED | ADMISSION_REJECT
    placement: Optional[Placement]   # None unless PLACED
    reason: str                      # "" on success
    submit_t: float                  # virtual arrival time
    decide_t: float                  # virtual decision time (flush end)
    epoch_id: int                    # epoch published with this decision

    @property
    def ok(self) -> bool:
        return self.status == PLACED


@dataclasses.dataclass
class _StoredItem:
    """A placed item the frontier still tracks (the repair plane's unit)."""

    item: DataItem
    placement: Placement
    chunk_mb: float


@dataclasses.dataclass(frozen=True)
class ServiceReport:
    """Everything one :meth:`PlacementFrontier.run` produced."""

    outcomes: list[ServiceOutcome]
    summary: dict
    makespan_virtual_s: float

    def digest(self) -> int:
        return placements_digest(self.outcomes)


def placements_digest(outcomes: Sequence[ServiceOutcome]) -> int:
    """Order-sensitive digest of every outcome's placement bits, as an
    int so the benchmark gate can equality-check it (the gate skips
    non-numeric leaves)."""
    h = hashlib.sha256()
    for o in outcomes:
        if o.placement is None:
            h.update(f"{o.item_id}|{o.status}|-\n".encode())
        else:
            p = o.placement
            h.update(
                f"{o.item_id}|{o.status}|{p.k},{p.p},{p.node_ids}\n".encode()
            )
    return int(h.hexdigest()[:12], 16)


class PlacementFrontier:
    """Single-threaded streaming placement service (see module docstring).

    Drive it with :meth:`run` over a merged event stream, or feed it
    piecemeal with :meth:`submit`/:meth:`advance` for interactive use.
    :meth:`read` returns the latest snapshot epoch at any point and
    never touches the live view.
    """

    def __init__(self, engine: PlacementEngine, config: FrontierConfig | None = None):
        if not engine.auto_commit:
            raise ValueError("the placement frontier requires auto_commit engines")
        self.engine = engine
        self.config = config or FrontierConfig()
        self.queue = AdmissionQueue(self.config.queue_capacity)
        self.ctx = BatchContext()
        self.metrics = ServiceMetrics()
        self.epochs = EpochJournal(keep=self.config.epoch_history)
        self.outcomes: list[ServiceOutcome] = []
        self.stored: dict[int, _StoredItem] = {}
        self.clock = 0.0        # virtual now
        self.busy_until = 0.0   # virtual time the current window ends
        self.epochs.publish(self.engine, 0.0)  # epoch 0: initial state

    # -- reads ---------------------------------------------------------------

    def read(self) -> Epoch:
        """Latest consistent snapshot; O(1), never blocks placements."""
        return self.epochs.latest()

    # -- event loop ----------------------------------------------------------

    def run(self, events: Iterable[ServiceEvent]) -> ServiceReport:
        """Process an event stream to completion (drains the queue)."""
        ordered = sorted(
            enumerate(events), key=lambda iv: (iv[1].t, _PRIO[iv[1].kind], iv[0])
        )
        for _, ev in ordered:
            if ev.t < self.clock:
                raise ValueError(
                    f"event at t={ev.t} is in the past (clock={self.clock})"
                )
            self.advance(ev.t)
            if ev.kind == "arrival":
                self.submit(ev.payload, ev.t)
            elif ev.kind == "fail":
                self._on_fail(ev.t, ev.payload)
            elif ev.kind == "join":
                self._on_join(ev.t, ev.payload)
            elif ev.kind == "heal":
                self._on_heal(ev.t, ev.payload)
            else:  # pragma: no cover - guarded by _PRIO lookup in sort
                raise ValueError(f"unknown event kind {ev.kind!r}")
        self.drain()
        makespan = max(self.clock, self.busy_until)
        epoch = self.epochs.publish(self.engine, makespan)
        summary = self.metrics.summary(makespan)
        summary["final_epoch_id"] = epoch.epoch_id
        summary["n_stored"] = len(self.stored)
        summary["ctx"] = {"hits": self.ctx.hits, "misses": self.ctx.misses}
        summary.update(self.queue.counters())
        return ServiceReport(
            outcomes=list(self.outcomes),
            summary=summary,
            makespan_virtual_s=makespan,
        )

    def submit(self, item: DataItem, t: float) -> None:
        """Offer one arrival at virtual time ``t`` (advance first)."""
        if not self.queue.offer(item, t):
            # Backpressure: explicit per-item reject, counted and
            # reported — the caller sees exactly which items bounced.
            self.metrics.n_rejected_admission += 1
            self.outcomes.append(
                ServiceOutcome(
                    item_id=item.item_id,
                    status=ADMISSION_REJECT,
                    placement=None,
                    reason=f"admission queue full ({self.queue.capacity})",
                    submit_t=t,
                    decide_t=t,
                    epoch_id=self.epochs.latest().epoch_id,
                )
            )
        self.metrics.record_depth(self.queue.depth)

    def advance(self, until: float) -> None:
        """Run every window flush due strictly before virtual ``until``."""
        while True:
            trigger = self._next_trigger()
            if trigger is None:
                break
            flush_t = max(trigger, self.busy_until)
            if flush_t >= until:
                break
            self._flush(flush_t)
        self.clock = max(self.clock, until)

    def drain(self) -> None:
        """Flush until the queue is empty (end of stream)."""
        while self.queue.depth:
            trigger = self._next_trigger()
            self._flush(max(trigger, self.busy_until))

    # -- internals -----------------------------------------------------------

    def _next_trigger(self) -> float | None:
        """Virtual time the next window becomes due: the moment it
        filled to ``max_batch``, or its oldest item's deadline."""
        oldest = self.queue.oldest_t()
        if oldest is None:
            return None
        deadline = oldest + self.config.max_wait_s
        if self.queue.depth >= self.config.max_batch:
            return min(deadline, self.queue.peek_t(self.config.max_batch - 1))
        return deadline

    def _flush(self, flush_t: float) -> None:
        """Decide one micro-batch window at virtual time ``flush_t``."""
        batch = self.queue.take(self.config.max_batch)
        items = [qi.item for qi in batch]
        w0 = time.perf_counter()
        records = self.engine.place_many(items, ctx=self.ctx)
        wall = time.perf_counter() - w0
        # busy_until, not clock, carries the window's completion: events
        # with t < done_t arrive while the window is in flight and are
        # processed after its commits (which applied at the flush).
        done_t = flush_t + self.config.service_s(len(batch))
        self.busy_until = done_t
        epoch = self.epochs.publish(self.engine, done_t)
        for qi, rec in zip(batch, records):
            if rec.ok:
                self.metrics.n_placed += 1
                self.stored[rec.item_id] = _StoredItem(
                    qi.item, rec.placement, rec.chunk_mb
                )
            else:
                self.metrics.n_rejected_placement += 1
            self.metrics.sojourn_virtual.record(done_t - qi.enqueued_t)
            self.outcomes.append(
                ServiceOutcome(
                    item_id=rec.item_id,
                    status=PLACED if rec.ok else REJECTED,
                    placement=rec.placement,
                    reason=rec.reason,
                    submit_t=qi.enqueued_t,
                    decide_t=done_t,
                    epoch_id=epoch.epoch_id,
                )
            )
        self.metrics.record_flush(len(batch), wall)

    # -- churn ---------------------------------------------------------------

    def _on_fail(self, t: float, node_id: int) -> None:
        """Fail-stop a node between windows; queued arrivals older than
        the failure are decided after it (churn lands mid-window)."""
        cluster = self.engine.cluster
        if node_id >= cluster.n_nodes or not cluster.alive[node_id]:
            return
        cluster.fail_stop(node_id)
        self.engine.observe_churn("fail", [node_id])
        self.metrics.n_failures += 1
        affected = [
            si for si in self.stored.values() if node_id in si.placement.node_ids
        ]
        # Health-prioritized replanning (same policy as the simulator's
        # repair queue): most-degraded first by surviving-chunks-minus-K
        # margin, deterministic item-id tie-break — replacement capacity
        # goes to the items nearest data loss.
        affected.sort(
            key=lambda si: (
                sum(1 for n in si.placement.node_ids if cluster.alive[n])
                - si.placement.k,
                si.item.item_id,
            )
        )
        for si in affected:
            self._repair_or_drop(si)
        self.epochs.publish(self.engine, t)

    def _repair_or_drop(self, si: _StoredItem) -> None:
        """Instantaneous placement-plane repair (the simulator's
        infinite-bandwidth model): replacements land immediately or the
        item is lost and its surviving chunks released."""
        plan = self.engine.plan_repair(
            si.item, si.placement, chunk_mb=si.chunk_mb, commit=True, ctx=self.ctx
        )
        if plan.ok:
            si.placement = plan.placement
            self.metrics.n_repairs += 1
            return
        cluster = self.engine.cluster
        alive_survivors = [n for n in plan.survivors if cluster.alive[n]]
        if alive_survivors:
            # release == per-entry subtract + clamp-at-zero, bitwise what
            # the old per-node max(0, used - chunk) loop computed
            cluster.release(alive_survivors, si.chunk_mb)
            self.engine.observe_external_release(alive_survivors, si.chunk_mb)
        self.metrics.n_items_lost += 1
        self.metrics.mb_lost += si.item.size_mb
        del self.stored[si.item.item_id]

    def _on_join(self, t: float, node: StorageNode) -> None:
        nid = self.engine.cluster.add_node(node)
        self.engine.observe_churn("join", [nid])
        self.metrics.n_joins += 1
        self.epochs.publish(self.engine, t)

    def _on_heal(self, t: float, node_id: int) -> None:
        cluster = self.engine.cluster
        if node_id >= cluster.n_nodes or cluster.alive[node_id]:
            return
        cluster.heal_node(node_id)
        self.engine.observe_churn("heal", [node_id])
        self.metrics.n_heals += 1
        self.epochs.publish(self.engine, t)
