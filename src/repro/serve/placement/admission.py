"""Bounded admission queue for the streaming placement frontier.

Open-loop arrivals are offered to the queue; when it is full the offer
is *rejected explicitly* — the caller receives ``False`` and must emit a
per-item rejected outcome (the frontier turns it into a
``ServiceOutcome`` with status ``"admission_reject"``).  Nothing is ever
dropped silently: ``n_offered == n_admitted + n_rejected`` is a class
invariant, pinned by tests/test_serve_placement.py.
"""

from __future__ import annotations

import collections
import dataclasses

from repro.core.types import DataItem

__all__ = ["QueuedItem", "AdmissionQueue"]


@dataclasses.dataclass(frozen=True)
class QueuedItem:
    """One admitted arrival waiting for a window flush."""

    item: DataItem
    enqueued_t: float  # virtual seconds


class AdmissionQueue:
    """FIFO queue with a hard depth bound (the backpressure knob)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"queue capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._q: collections.deque[QueuedItem] = collections.deque()
        self.n_offered = 0
        self.n_admitted = 0
        self.n_rejected = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def depth(self) -> int:
        return len(self._q)

    def offer(self, item: DataItem, t: float) -> bool:
        """Admit ``item`` at virtual time ``t``; False == admission reject."""
        self.n_offered += 1
        if len(self._q) >= self.capacity:
            self.n_rejected += 1
            return False
        self._q.append(QueuedItem(item, t))
        self.n_admitted += 1
        return True

    def oldest_t(self) -> float | None:
        """Enqueue time of the head item (drives the max-wait trigger)."""
        return self._q[0].enqueued_t if self._q else None

    def peek_t(self, i: int) -> float:
        """Enqueue time of the i-th queued item (drives the max-batch
        trigger: the next window is full the moment its last member
        arrived)."""
        return self._q[i].enqueued_t

    def take(self, n: int) -> list[QueuedItem]:
        """Dequeue up to ``n`` items FIFO — one micro-batch window."""
        out = [self._q.popleft() for _ in range(min(n, len(self._q)))]
        return out

    def counters(self) -> dict:
        return {
            "offered": self.n_offered,
            "admitted": self.n_admitted,
            "admission_rejected": self.n_rejected,
        }
