"""Service telemetry for the streaming placement frontier.

Two clocks, deliberately separated:

* **virtual** quantities (sojourn, queue depth, goodput over the virtual
  makespan, reject counts) are functions of the deterministic service
  model and therefore byte-stable across runs and machines — the
  benchmark gate pins them with equality;
* **wall** quantities (p50/p99 decision latency, flush wall time) are
  measured ``time.perf_counter`` costs of the actual ``place_many``
  calls — they never influence decisions, and the gate treats them as
  ratios with a noise budget, like every other timing metric.
"""

from __future__ import annotations

import numpy as np

__all__ = ["LatencyStats", "ServiceMetrics"]


class LatencyStats:
    """Reservoir of latency samples (seconds) with percentile summary."""

    def __init__(self):
        self._vals: list[float] = []

    def record(self, seconds: float) -> None:
        self._vals.append(float(seconds))

    def record_many(self, seconds: float, n: int) -> None:
        self._vals.extend([float(seconds)] * n)

    @property
    def count(self) -> int:
        return len(self._vals)

    def percentile_ms(self, q: float) -> float:
        if not self._vals:
            return 0.0
        return 1e3 * float(np.percentile(np.asarray(self._vals), q))

    def total_s(self) -> float:
        return float(sum(self._vals))

    def summary_ms(self) -> dict:
        if not self._vals:
            return {"count": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
        arr = np.asarray(self._vals)
        return {
            "count": int(arr.size),
            "p50_ms": 1e3 * float(np.percentile(arr, 50)),
            "p99_ms": 1e3 * float(np.percentile(arr, 99)),
            "mean_ms": 1e3 * float(arr.mean()),
        }


class ServiceMetrics:
    """Counters + latency reservoirs for one frontier run."""

    def __init__(self):
        self.n_placed = 0
        self.n_rejected_placement = 0   # scheduler said no
        self.n_rejected_admission = 0   # queue was full
        self.n_flushes = 0
        self.n_flushed_items = 0
        self.n_failures = 0
        self.n_joins = 0
        self.n_heals = 0
        self.n_repairs = 0
        self.n_items_lost = 0
        self.mb_lost = 0.0
        self.max_queue_depth = 0
        self._depth_sum = 0
        self._depth_samples = 0
        #: wall clock: per-item share of each window's place_many call
        self.decision_wall = LatencyStats()
        #: virtual clock: arrival -> decision (queue wait + service)
        self.sojourn_virtual = LatencyStats()

    def record_depth(self, depth: int) -> None:
        self.max_queue_depth = max(self.max_queue_depth, depth)
        self._depth_sum += depth
        self._depth_samples += 1

    def record_flush(self, batch: int, wall_s: float) -> None:
        self.n_flushes += 1
        self.n_flushed_items += batch
        self.decision_wall.record_many(wall_s / batch, batch)

    def summary(self, makespan_virtual_s: float) -> dict:
        span = max(makespan_virtual_s, 1e-12)
        offered = (
            self.n_placed + self.n_rejected_placement + self.n_rejected_admission
        )
        return {
            "n_offered": offered,
            "n_placed": self.n_placed,
            "n_rejected_placement": self.n_rejected_placement,
            "n_rejected_admission": self.n_rejected_admission,
            "reject_count": self.n_rejected_placement + self.n_rejected_admission,
            "reject_rate": (
                (self.n_rejected_placement + self.n_rejected_admission) / offered
                if offered
                else 0.0
            ),
            "n_flushes": self.n_flushes,
            "mean_window": (
                self.n_flushed_items / self.n_flushes if self.n_flushes else 0.0
            ),
            "n_failures": self.n_failures,
            "n_joins": self.n_joins,
            "n_heals": self.n_heals,
            "n_repairs": self.n_repairs,
            "n_items_lost": self.n_items_lost,
            "mb_lost": self.mb_lost,
            "max_queue_depth": self.max_queue_depth,
            "mean_queue_depth": (
                self._depth_sum / self._depth_samples if self._depth_samples else 0.0
            ),
            # deterministic (virtual clock):
            "makespan_virtual_s": makespan_virtual_s,
            "goodput_virtual_items_per_s": self.n_placed / span,
            "sojourn_virtual": self.sojourn_virtual.summary_ms(),
            # measured (wall clock):
            "decision_wall": self.decision_wall.summary_ms(),
            "decision_wall_total_s": self.decision_wall.total_s(),
        }
