"""Streaming placement service (the serving plane of the D-Rex stack).

A long-lived, deterministic service over
:class:`~repro.core.engine.PlacementEngine`:

* :mod:`.admission` — bounded FIFO queue; full == explicit per-item
  admission reject (backpressure, never silent drops);
* :mod:`.frontier` — the event loop: coalesces arrivals into
  micro-batched ``place_many`` windows (max-batch / max-wait), applies
  failure/join/heal churn between windows, and repairs affected items
  through ``engine.plan_repair``;
* :mod:`.epochs` — snapshot-epoch reads: consistent, write-protected
  :class:`~repro.core.types.ClusterView` copies published at window
  boundaries so readers never block (or observe half of) a flush;
* :mod:`.metrics` — service telemetry: virtual (deterministic) sojourn
  / goodput / queue depth / rejects, wall-clock p50/p99 decision
  latency.

See :mod:`.frontier` for the determinism contract (virtual clock +
fixed service model ⇒ byte-identical replay), and
benchmarks/serve_load.py for the gated sustained-load lane.
"""

from .admission import AdmissionQueue, QueuedItem
from .epochs import Epoch, EpochJournal
from .frontier import (
    ADMISSION_REJECT,
    PLACED,
    REJECTED,
    FrontierConfig,
    PlacementFrontier,
    ServiceEvent,
    ServiceOutcome,
    ServiceReport,
    arrival_events,
    churn_events,
    placements_digest,
)
from .metrics import LatencyStats, ServiceMetrics

__all__ = [
    "ADMISSION_REJECT",
    "PLACED",
    "REJECTED",
    "AdmissionQueue",
    "Epoch",
    "EpochJournal",
    "FrontierConfig",
    "LatencyStats",
    "PlacementFrontier",
    "QueuedItem",
    "ServiceEvent",
    "ServiceMetrics",
    "ServiceOutcome",
    "ServiceReport",
    "arrival_events",
    "churn_events",
    "placements_digest",
]
