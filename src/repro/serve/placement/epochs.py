"""Snapshot-epoch concurrency for placement-service reads.

Readers of a live placement service must never observe a half-applied
window (some of a flush's commits visible, others not) and must never
block placements.  The frontier therefore publishes an :class:`Epoch` —
a deep, write-protected :class:`~repro.core.types.ClusterView` copy plus
engine counters — only at consistency points: service start, the end of
each window flush, and after each churn event.  Reads return the latest
published epoch in O(1); the live view is never handed out.

Epochs are totally ordered by ``epoch_id`` and stamped with the engine's
``mutation_seq``, so a reader can tell exactly how many engine-side
mutations separate two snapshots without comparing arrays.  A bounded
ring of recent epochs is kept so diagnostics can diff consecutive
consistency points (e.g. the epoch-consistency tests replay window
commits against them).
"""

from __future__ import annotations

import collections
import dataclasses

from repro.core.types import ClusterView

__all__ = ["Epoch", "EpochJournal"]


@dataclasses.dataclass(frozen=True)
class Epoch:
    """One published consistency point.  ``cluster`` arrays are
    write-protected copies — safe to hold indefinitely."""

    epoch_id: int
    virtual_t: float          # virtual seconds at publication
    mutation_seq: int         # engine mutation counter at publication
    cluster: ClusterView
    stats: dict               # engine stats copy (n_placed, mb_committed, ...)

    @property
    def free_mb(self):
        return self.cluster.free_mb

    @property
    def n_live(self) -> int:
        return int(self.cluster.alive.sum())


class EpochJournal:
    """Publisher + bounded history of snapshot epochs."""

    def __init__(self, keep: int = 8):
        if keep < 1:
            raise ValueError("must keep at least the latest epoch")
        self._ring: collections.deque[Epoch] = collections.deque(maxlen=keep)
        self._next_id = 0

    def publish(self, engine, virtual_t: float) -> Epoch:
        """Snapshot ``engine`` at a consistency point and publish it."""
        epoch = Epoch(
            epoch_id=self._next_id,
            virtual_t=float(virtual_t),
            mutation_seq=engine.mutation_seq,
            cluster=engine.view_snapshot(),
            stats=dict(engine.stats),
        )
        self._next_id += 1
        self._ring.append(epoch)
        return epoch

    def latest(self) -> Epoch:
        if not self._ring:
            raise LookupError("no epoch published yet")
        return self._ring[-1]

    def history(self) -> list[Epoch]:
        """Retained epochs, oldest first (bounded by ``keep``)."""
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)
