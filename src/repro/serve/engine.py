"""Batched *token*-serving engine: prefill a request batch, then step
the decode loop with greedy or temperature sampling.

``serve_step`` (one token for the whole batch against the KV/recurrent
state) is the function the dry-run lowers for the decode_32k / long_500k
shapes; the engine wraps it with the request plumbing the examples use.

Namespace note: this module serves model *tokens*; the storage
*placement* service (admission queue + micro-batched ``place_many``
windows over a :class:`~repro.core.engine.PlacementEngine`) lives in
:mod:`repro.serve.placement` — the two share nothing but the package.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_serve_state, prefill
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0
    eos_id: Optional[int] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig | None = None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg or ServeConfig()
        self._prefill = jax.jit(lambda p, t, f: prefill(p, t, cfg, f))
        self._step = jax.jit(
            lambda p, tok, pos, st: decode_step(p, tok, pos, st, cfg)
        )
        self.metrics = {"prefill_s": 0.0, "decode_s": 0.0, "tokens_out": 0}

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        scaled = logits / self.scfg.temperature
        return jax.random.categorical(key, scaled, axis=-1)[:, None].astype(jnp.int32)

    def generate(self, prompts: np.ndarray, frames=None) -> np.ndarray:
        """prompts: (B, T) int32 -> (B, T + max_new) generated ids."""
        cfg, scfg = self.cfg, self.scfg
        b, t = prompts.shape
        key = jax.random.PRNGKey(scfg.seed)

        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, jnp.asarray(prompts), frames)
        # Decode continues against a fresh cache sized for the full output;
        # attention families re-prefill into it (cache_len = t + new).
        cache_len = t + scfg.max_new_tokens
        if not cfg.sub_quadratic:
            full_state = init_serve_state(cfg, b, cache_len)
            if cfg.is_encdec:
                full_state["cross_kv"] = state["cross_kv"]
            replay, state = state, full_state
            # replay cached K/V into the wider cache
            for name in ("layers",):
                src = replay[name]
                dst = state[name]
                state[name] = jax.tree.map(
                    lambda d, s: jax.lax.dynamic_update_slice(
                        d, s.astype(d.dtype), (0,) * d.ndim
                    ),
                    dst,
                    src,
                )
        self.metrics["prefill_s"] += time.perf_counter() - t0

        out = [jnp.asarray(prompts)]
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        out.append(tok)
        done = jnp.zeros((b,), bool)
        if scfg.eos_id is not None:
            done = done | (tok[:, 0] == scfg.eos_id)
        n_tok = b  # every row emits the first token (eos itself counts)
        t0 = time.perf_counter()
        for i in range(1, scfg.max_new_tokens):
            if bool(done.all()):
                break
            logits, state = self._step(self.params, tok, jnp.int32(t + i - 1), state)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
            # Rows past their eos emit uncounted padding; a row's own eos
            # token is real output and counts.
            n_tok += int(b - int(done.sum()))
            if scfg.eos_id is not None:
                done = done | (tok[:, 0] == scfg.eos_id)
            out.append(tok)
        self.metrics["decode_s"] += time.perf_counter() - t0
        self.metrics["tokens_out"] += n_tok
        return np.asarray(jnp.concatenate(out, axis=1))

    @property
    def decode_tokens_per_s(self) -> float:
        d = self.metrics["decode_s"]
        return self.metrics["tokens_out"] / d if d > 0 else 0.0
