"""Serving layer — two unrelated planes, namespaced apart:

* :mod:`repro.serve.engine` — the batched **token**-serving engine
  (prefill + decode loop over the model zoo);
* :mod:`repro.serve.placement` — the streaming **placement** service
  (admission queue, micro-batched ``place_many`` windows,
  snapshot-epoch reads over a
  :class:`~repro.core.engine.PlacementEngine`).

``TokenServingEngine`` is the unambiguous name for the former;
``ServingEngine`` remains as the original alias.
"""

from .engine import ServeConfig, ServingEngine

#: explicit name so call sites never conflate the token-serving engine
#: with the storage placement service in :mod:`repro.serve.placement`.
TokenServingEngine = ServingEngine

__all__ = ["ServeConfig", "ServingEngine", "TokenServingEngine"]
