"""Event-driven storage simulator (paper §5, simulator originally in C).

The run loop is a discrete-event core over a heap of typed events:

* **item arrivals** — the scheduler places each store request through the
  :class:`~repro.core.engine.PlacementEngine` (Problem 1 constraints,
  per-item overhead telemetry);
* **fail-stop failures** — a node dies, its chunks are lost, and every
  affected item is routed through ``PlacementEngine.plan_repair`` (§5.7:
  replacement nodes freest-first, parity growth gated on the scheduler's
  declared capability).  Failures come in three granularities: single
  nodes (``failure_schedule``), whole racks and whole zones
  (``rack_failure_schedule`` / ``zone_failure_schedule`` against the
  :class:`ClusterView`'s rack/zone topology) — a correlated event kills
  every live node in the domain *atomically* (one void-then-replan pass
  over the batch), so repairs never target a node that dies in the same
  event.  Within an event, items replan most-degraded-first
  (``SimConfig.repair_priority="health"``: surviving-chunks-minus-K
  margin, item-id tie-break, re-derived at every event) so finite repair
  bandwidth is spent where data loss is nearest; ``"fifo"`` keeps the
  legacy insertion-order scan;
* **repair completions** — with a *finite* per-node repair bandwidth
  (``SimConfig.repair_bw_mbps``), a repair charges traffic on both sides
  of the reconstruction: each replacement node ingests its
  ``chunk_mb / repair_bw_mbps`` write, and each of the K survivors
  feeding the decode streams one chunk out through its own lane
  (``RepairPlan.read_mb`` — at 10k nodes the read side is what a shared
  repair fabric actually saturates).  Each node runs one repair transfer
  at a time, so repairs queue; an optional *cluster-wide* budget
  (``cluster_repair_bw_mbps``) additionally serializes the total
  read+write traffic of all repairs through one shared lane.  An item
  whose surviving chunks (or replacement targets) are hit by another
  failure while its repair is still in flight loses the repair — and is
  dropped outright if fewer than K chunks remain.  This is the
  repair-rate sensitivity that repair-bandwidth lower bounds (Luby et
  al., arXiv:2002.07904) show governs data survival; the legacy
  instantaneous-repair model is exactly the ``repair_bw_mbps=inf``
  (and ``cluster_repair_bw_mbps=inf``) special case and reproduces the
  pre-refactor results bit-for-bit (except D-Rex SC, whose saturation
  anchor changed intentionally with the ``smin_mb`` seeding fix — see
  ``TestLegacyEquivalence``).
* **node joins / heals** — late-arriving nodes
  (``SimConfig.node_join_schedule``) grow the cluster view mid-run and
  immediately become placement/repair candidates; healed nodes
  (``SimConfig.node_heal_schedule``) return alive and empty.

Metrics are unchanged: W — bytes successfully stored — and T — average
I/O throughput over encode+decode+write+read (Eq. in §3.2); the Fig. 12
retained-fraction metric now responds to repair bandwidth.

Transfer model per the paper: all chunk transfers are parallel, no shared
links, so the slowest node in the mapping bottlenecks both the write and
the read; encode/decode times come from the calibrated linear model
(:class:`repro.core.types.ECTimeModel`).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Optional, Sequence

import numpy as np

from repro.core.algorithms import Scheduler
from repro.core.engine import BatchContext, PlacementEngine
from repro.core.repair import RepairPlan
from repro.core.types import (
    ClusterView,
    DataItem,
    ECTimeModel,
    Placement,
    PlacementConstraints,
    StorageNode,
)

__all__ = ["SimConfig", "SimResult", "StoredItem", "Simulator", "run_simulation"]

SECONDS_PER_DAY = 86400.0

# Event priorities: ties at the same instant resolve in this order.
# Joins/heals first (capacity becomes available), then completions of
# in-flight repairs, then failures, then arrivals — failures preceding
# same-day arrivals matches the legacy loop's ``day <= arrival`` rule.
_P_JOIN, _P_HEAL, _P_REPAIR, _P_FAIL, _P_ARRIVAL = range(5)


@dataclasses.dataclass
class SimConfig:
    time_model: ECTimeModel = dataclasses.field(default_factory=ECTimeModel)
    #: (day, node_id) forced fail-stop events; node_id -1 = weighted random.
    failure_schedule: tuple[tuple[float, int], ...] = ()
    #: (day, rack_id) correlated fail-stop: every live node in the rack
    #: dies atomically (ToR switch / PDU loss).  Rack ids come from the
    #: cluster's ``ClusterView.rack`` topology.
    rack_failure_schedule: tuple[tuple[float, int], ...] = ()
    #: (day, zone_id) correlated fail-stop of a whole zone.
    zone_failure_schedule: tuple[tuple[float, int], ...] = ()
    #: dynamic schedulers may add parity chunks when repairing (§5.7).
    allow_parity_growth: bool = True
    seed: int = 0
    #: measure per-item scheduling latency (Table 2).
    measure_overhead: bool = False
    #: per-node repair bandwidth (MB/s); each node runs one repair
    #: transfer at a time — replacement targets ingest their chunk write,
    #: the K decode-source survivors stream their chunk read — so repairs
    #: queue.  ``inf`` reproduces the legacy instantaneous-repair model
    #: exactly (together with ``cluster_repair_bw_mbps=inf``).
    repair_bw_mbps: float = math.inf
    #: shared cluster-wide repair budget (MB/s): the *total* read+write
    #: traffic of every repair additionally serializes through one
    #: cluster lane (an oversubscribed core/aggregation fabric).  ``inf``
    #: (default) disables the shared budget.
    cluster_repair_bw_mbps: float = math.inf
    #: (day, StorageNode) nodes joining the cluster mid-run.
    node_join_schedule: tuple[tuple[float, StorageNode], ...] = ()
    #: (day, node_id) failed nodes returning alive and empty.
    node_heal_schedule: tuple[tuple[float, int], ...] = ()
    #: replanning order when one failure event touches several items:
    #: ``"health"`` (default) repairs the most-degraded first, keyed by
    #: surviving-chunks-minus-K margin with a deterministic item-id
    #: tie-break, and re-derives the priorities at every failure event;
    #: ``"fifo"`` keeps the legacy insertion-order scan.  Under finite
    #: repair bandwidth, health ordering spends the budget where data
    #: loss is nearest — an item one failure from death books lanes
    #: before one that can still lose P more chunks.
    repair_priority: str = "health"
    #: failure-domain constraints applied to every placement and repair
    #: the simulator's engine makes (rack/zone caps + spread width).
    constraints: Optional[PlacementConstraints] = None

    def __post_init__(self) -> None:
        if self.repair_priority not in ("health", "fifo"):
            raise ValueError(
                f"repair_priority must be 'health' or 'fifo', "
                f"got {self.repair_priority!r}"
            )


@dataclasses.dataclass
class StoredItem:
    item: DataItem
    placement: Placement
    chunk_mb: float
    t_encode: float
    t_decode: float
    t_write: float
    t_read: float

    @property
    def io_time(self) -> float:
        return self.t_encode + self.t_decode + self.t_write + self.t_read


@dataclasses.dataclass
class _PendingRepair:
    """An in-flight repair: the plan is committed (capacity reserved) but
    the replacement chunks have not landed yet."""

    repair_id: int
    plan: RepairPlan
    finish_day: float
    #: per-node transfer window (start_day, end_day) booked on that
    #: node's repair lane — replacement-chunk writes on the new nodes,
    #: decode-source reads on the first K survivors (disjoint key sets by
    #: construction) — released if the repair is voided.
    transfers: dict[int, tuple[float, float]]
    #: (start_day, end_day) booked on the shared cluster repair lane
    #: (``SimConfig.cluster_repair_bw_mbps``); None when the budget is
    #: infinite.
    cluster_window: Optional[tuple[float, float]] = None


@dataclasses.dataclass
class SimResult:
    stored_mb: float
    total_mb: float
    n_stored: int
    n_failed_writes: int
    #: bytes lost/dropped due to node failures (subset of stored_mb).
    dropped_mb: float
    #: Eq. §3.2: W / sum of IO times over successfully stored items.
    throughput_mbps: float
    time_breakdown: dict
    per_node_used_mb: np.ndarray
    stored_items: list[StoredItem]
    failed_item_ids: list[int]
    sched_overhead_s: list[float]
    n_node_failures: int = 0
    #: occupancy each node held at the moment it failed (latest failure
    #: per node) — ``per_node_used_mb`` shows failed nodes as 0, so this
    #: is what lets Fig. 7-style utilization plots distinguish a dead
    #: node from an idle one.
    used_mb_at_failure: dict[int, float] = dataclasses.field(default_factory=dict)
    n_repairs_planned: int = 0
    n_repairs_completed: int = 0
    #: repairs voided mid-flight (a source or target died before the
    #: replacement chunks landed); each is re-planned or dropped.
    n_repairs_aborted: int = 0
    #: replacement bytes actually landed by completed repairs.
    repaired_mb: float = 0.0
    #: decode-source bytes streamed off the K survivors by completed
    #: repairs (the read side of ``RepairPlan.total_traffic_mb``).
    repair_read_mb: float = 0.0

    @property
    def stored_fraction(self) -> float:
        return self.stored_mb / self.total_mb if self.total_mb else 0.0

    @property
    def retained_fraction(self) -> float:
        """Fraction of successfully-stored bytes still retained at the end
        (Fig. 12 metric)."""
        if self.stored_mb <= 0:
            return 0.0
        return max(0.0, (self.stored_mb - self.dropped_mb)) / self.stored_mb


class Simulator:
    def __init__(
        self,
        nodes: Sequence[StorageNode],
        scheduler: Scheduler | str,
        config: SimConfig | None = None,
    ):
        self.nodes = list(nodes)
        self.config = config or SimConfig()
        # The engine owns the view, commits placements and repair
        # reservations, and measures per-decision overhead; the sim
        # shares one BatchContext across the whole run (AFRs never change
        # mid-simulation) so the reliability DP amortizes over the trace.
        self.engine = PlacementEngine(
            ClusterView.from_nodes(self.nodes),
            scheduler,
            constraints=self.config.constraints,
        )
        self.scheduler = self.engine.scheduler
        self.cluster = self.engine.cluster
        self.ctx = BatchContext()
        self.rng = np.random.default_rng(self.config.seed)
        self.live_items: dict[int, StoredItem] = {}
        self.dropped_mb = 0.0
        self.n_node_failures = 0
        self.used_mb_at_failure: dict[int, float] = {}
        # Event heap + in-flight repair state.
        self._events: list[tuple[float, int, int, tuple]] = []
        self._seq = itertools.count()
        self._pending: dict[int, _PendingRepair] = {}
        self._repair_ids = itertools.count()
        #: day each node's repair lane frees up (finite-bandwidth mode).
        self._repair_free_at: dict[int, float] = {}
        #: day the shared cluster repair lane frees up
        #: (finite ``cluster_repair_bw_mbps`` mode).
        self._cluster_lane_free_at = 0.0
        #: simulation clock: the timestamp of the event being processed.
        self._now = 0.0
        self.n_repairs_planned = 0
        self.n_repairs_completed = 0
        self.n_repairs_aborted = 0
        self.repaired_mb = 0.0
        self.repair_read_mb = 0.0
        #: deterministic replan trace: one ``(day, item_id, margin)`` row
        #: per repair-or-drop decision, in the exact order the decisions
        #: were made — the same-seed replay digest hashes this.
        self.repair_log: list[tuple[float, int, int]] = []

    # -- store path ---------------------------------------------------------

    def _io_times(self, item: DataItem, pl: Placement) -> tuple[float, float, float, float]:
        tm = self.config.time_model
        ids = list(pl.node_ids)
        chunk = pl.chunk_size_mb(item.size_mb)
        t_write = chunk / float(self.cluster.write_bw[ids].min())
        t_read = chunk / float(self.cluster.read_bw[ids].min())
        return (
            tm.t_encode(pl.n, pl.k, item.size_mb),
            tm.t_decode(pl.k, item.size_mb),
            t_write,
            t_read,
        )

    def store(self, item: DataItem) -> tuple[Optional[StoredItem], float]:
        # The engine re-checks Problem 1's write-success constraints and
        # commits; record.overhead_s is the per-item latency of Table 2.
        record = self.engine.place(item, ctx=self.ctx)
        if record.placement is None:
            return None, record.overhead_s
        pl = record.placement
        te, td, tw, tr = self._io_times(item, pl)
        si = StoredItem(item, pl, record.chunk_mb, te, td, tw, tr)
        self.live_items[item.item_id] = si
        return si, record.overhead_s

    # -- cluster membership ---------------------------------------------------

    def add_node(self, node: StorageNode) -> int:
        """Elastic join: the node becomes a placement/repair candidate for
        every subsequent decision."""
        nid = self.cluster.add_node(node)
        self.engine.observe_churn("join", [nid])
        self.nodes.append(node)
        return nid

    def heal_node(self, node_id: int) -> None:
        """Fail-stop recovery: the node returns alive and empty."""
        if self.cluster.alive[node_id]:
            return
        self.cluster.heal_node(node_id)
        self.engine.observe_churn("heal", [node_id])
        self._repair_free_at[node_id] = 0.0

    # -- failure path (§5.7) --------------------------------------------------

    def fail_node(self, node_id: int, day: float = 0.0) -> None:
        """Fail-stop ``node_id`` at time ``day`` (see :meth:`fail_nodes`)."""
        self.fail_nodes([node_id], day=day)

    def fail_nodes(self, node_ids: Sequence[int], day: float = 0.0) -> None:
        """Atomically fail-stop every node in ``node_ids`` at time
        ``day``; plan repair (or drop) for every affected item, including
        items whose in-flight repairs the failures void.  ``day`` is
        clamped to the simulation clock, so direct mid-run callers can
        never book repair transfers in the past.

        All deaths land *before* any replanning (this is what the
        correlated rack/zone events rely on): a repair planned for one
        victim can never choose another same-event victim as a
        replacement target or decode source.  Replanning order follows
        ``SimConfig.repair_priority``: most-degraded-first by
        surviving-chunks-minus-K margin (``"health"``, the default,
        item-id tie-break), or the legacy insertion-order scan
        (``"fifo"`` — with which a single-node event is exactly the old
        ``fail_node``, same decisions bit-for-bit).  Every decision is
        appended to :attr:`repair_log` in replan order."""
        dead: list[int] = []
        for nid in node_ids:
            nid = int(nid)
            if (
                nid >= self.cluster.n_nodes
                or not self.cluster.alive[nid]
                or nid in dead
            ):
                continue
            dead.append(nid)
        if not dead:
            return
        day = max(float(day), self._now)
        for nid in dead:
            self.used_mb_at_failure[nid] = float(self.cluster.used_mb[nid])
            self.cluster.fail_stop(nid)
            self.n_node_failures += 1
        self.engine.observe_churn("fail", dead)
        dead_set = set(dead)
        # Two passes: first void every in-flight repair these failures
        # touch (a reconstruction source or replacement target died),
        # returning capacity reservations and unused lane time — only
        # then re-plan.  Interleaving the two would let a re-plan book a
        # lane window that a later void still occupies, leaving one lane
        # with overlapping transfers.
        affected: list[tuple[int, int, StoredItem, Optional[list[int]]]] = []
        for iid in list(self.live_items):
            si = self.live_items[iid]
            pend = self._pending.get(iid)
            if pend is not None:
                if dead_set.isdisjoint(pend.plan.survivors) and dead_set.isdisjoint(
                    pend.plan.new_nodes
                ):
                    continue
                self.engine.abort_repair(pend.plan)
                self._release_lanes(pend, day)
                del self._pending[iid]
                self.n_repairs_aborted += 1
                survivors = [
                    n for n in pend.plan.survivors if self.cluster.alive[n]
                ]
                margin = len(survivors) - si.placement.k
                affected.append((margin, iid, si, survivors))
            elif not dead_set.isdisjoint(si.placement.node_ids):
                n_live = sum(
                    1 for n in si.placement.node_ids if self.cluster.alive[n]
                )
                affected.append((n_live - si.placement.k, iid, si, None))
        if self.config.repair_priority == "health":
            # Health-prioritized repair: most-degraded items (smallest
            # surviving-chunks-minus-K margin) replan first, so finite
            # repair bandwidth is booked where data loss is nearest;
            # deterministic item-id tie-break.  Margins are re-derived at
            # every failure event, so a second event re-prioritizes the
            # items it voids.  "fifo" preserves the legacy
            # insertion-order scan.
            affected.sort(key=lambda entry: (entry[0], entry[1]))
        for margin, iid, si, survivors in affected:
            self.repair_log.append((day, iid, margin))
            self._repair_or_drop(si, day, survivors=survivors)

    def _repair_or_drop(
        self,
        si: StoredItem,
        day: float,
        survivors: Optional[list[int]] = None,
    ) -> None:
        plan = self.engine.plan_repair(
            si.item,
            si.placement,
            chunk_mb=si.chunk_mb,
            survivors=survivors,
            allow_parity_growth=self.config.allow_parity_growth,
            commit=True,
            ctx=self.ctx,
        )
        if not plan.ok:
            self._drop(si, holding=plan.survivors)
            return
        self.n_repairs_planned += 1
        if not plan.new_nodes:
            si.placement = plan.placement
            return
        bw = self.config.repair_bw_mbps
        cbw = self.config.cluster_repair_bw_mbps
        if math.isinf(bw) and math.isinf(cbw):
            # Legacy instantaneous-repair model: chunks land now.
            si.placement = plan.placement
            self.n_repairs_completed += 1
            self.repaired_mb += plan.repair_mb
            self.repair_read_mb += plan.read_mb
            return
        # Finite repair budget: both sides of the reconstruction book
        # transfer windows, one at a time per node lane — each replacement
        # node ingests its chunk write, each of the K decode-source
        # survivors streams its chunk read (survivors and new nodes are
        # disjoint, so every lane sees at most one window per repair) —
        # and the repair completes when the slowest transfer lands.
        # Until then the item has only its surviving chunks.
        finish = day
        transfers: dict[int, tuple[float, float]] = {}
        if not math.isinf(bw):
            transfer_days = (si.chunk_mb / bw) / SECONDS_PER_DAY
            for n in plan.new_nodes:
                start = max(day, self._repair_free_at.get(n, 0.0))
                end = start + transfer_days
                self._repair_free_at[n] = end
                transfers[n] = (start, end)
                finish = max(finish, end)
            for n in plan.survivors[: plan.placement.k]:
                start = max(day, self._repair_free_at.get(n, 0.0))
                end = start + transfer_days
                self._repair_free_at[n] = end
                transfers[n] = (start, end)
                finish = max(finish, end)
        cluster_window: Optional[tuple[float, float]] = None
        if not math.isinf(cbw):
            # Shared fabric: the repair's *total* read+write traffic
            # serializes through the cluster lane on top of the per-node
            # windows.
            gstart = max(day, self._cluster_lane_free_at)
            gend = gstart + (plan.total_traffic_mb / cbw) / SECONDS_PER_DAY
            self._cluster_lane_free_at = gend
            cluster_window = (gstart, gend)
            finish = max(finish, gend)
        rid = next(self._repair_ids)
        self._pending[si.item.item_id] = _PendingRepair(
            rid, plan, finish, transfers, cluster_window
        )
        self._push(finish, _P_REPAIR, ("repair", si.item.item_id, rid))

    def _release_lanes(self, pend: _PendingRepair, day: float) -> None:
        """Return the un-run remainder of a voided repair's lane bookings
        so later repairs don't queue behind phantom transfers.

        Approximation: repairs already queued *behind* the voided
        transfers keep their original (now conservative) completion
        events — only reservations made after this point see the freed
        lane time.  Dead nodes are skipped; their lanes reset on heal."""
        for n, (start, end) in pend.transfers.items():
            if not self.cluster.alive[n]:
                continue
            remaining = max(0.0, end - max(start, day))
            if remaining > 0.0:
                self._repair_free_at[n] = (
                    self._repair_free_at.get(n, 0.0) - remaining
                )
        if pend.cluster_window is not None:
            start, end = pend.cluster_window
            remaining = max(0.0, end - max(start, day))
            if remaining > 0.0:
                self._cluster_lane_free_at -= remaining

    def _drop(self, si: StoredItem, holding: Sequence[int] | None = None) -> None:
        """Permanently lose an item; ``holding`` names the nodes that
        still carry its chunks (defaults to the full placement)."""
        nodes = si.placement.node_ids if holding is None else holding
        alive_holding = [n for n in nodes if self.cluster.alive[n]]
        if alive_holding:
            # release == per-entry subtract + clamp-at-zero, bitwise what
            # the old per-node max(0, used - chunk) loop computed
            self.cluster.release(alive_holding, si.chunk_mb)
            self.engine.observe_external_release(alive_holding, si.chunk_mb)
        self.dropped_mb += si.item.size_mb
        pend = self._pending.pop(si.item.item_id, None)
        if pend is not None:
            # Defensive: today every caller voids an item's in-flight
            # repair before dropping it, but a dropped item must never
            # keep engine reservations or phantom lane bookings alive.
            self.engine.abort_repair(pend.plan)
            self._release_lanes(pend, self._now)
            self.n_repairs_aborted += 1
        del self.live_items[si.item.item_id]

    # -- event loop ------------------------------------------------------------

    def _push(self, day: float, prio: int, payload: tuple) -> None:
        heapq.heappush(self._events, (day, prio, next(self._seq), payload))

    def run(self, items: Sequence[DataItem]) -> SimResult:
        for day, nid in sorted(self.config.failure_schedule):
            self._push(day, _P_FAIL, ("fail", nid))
        for day, rid in sorted(self.config.rack_failure_schedule):
            self._push(day, _P_FAIL, ("rack_fail", int(rid)))
        for day, zid in sorted(self.config.zone_failure_schedule):
            self._push(day, _P_FAIL, ("zone_fail", int(zid)))
        for day, node in sorted(
            self.config.node_join_schedule, key=lambda e: e[0]
        ):
            self._push(day, _P_JOIN, ("join", node))
        for day, nid in sorted(self.config.node_heal_schedule):
            self._push(day, _P_HEAL, ("heal", nid))
        for item in items:
            self._push(
                item.arrival_time / SECONDS_PER_DAY, _P_ARRIVAL, ("arrival", item)
            )

        stored: list[StoredItem] = []
        failed_ids: list[int] = []
        overheads: list[float] = []
        total_mb = 0.0
        while self._events:
            day, _prio, _seq, payload = heapq.heappop(self._events)
            self._now = max(self._now, day)
            kind = payload[0]
            if kind == "arrival":
                item = payload[1]
                total_mb += item.size_mb
                si, ovh = self.store(item)
                if self.config.measure_overhead:
                    overheads.append(ovh)
                if si is None:
                    failed_ids.append(item.item_id)
                else:
                    stored.append(si)
            elif kind == "fail":
                nid = payload[1]
                if nid < 0:
                    nid = self._draw_failing_node()
                if nid is not None:
                    self.fail_node(int(nid), day=day)
            elif kind in ("rack_fail", "zone_fail"):
                domain = (
                    self.cluster.rack if kind == "rack_fail" else self.cluster.zone
                )
                victims = np.nonzero(
                    (domain == payload[1]) & self.cluster.alive
                )[0]
                self.fail_nodes([int(n) for n in victims], day=day)
            elif kind == "repair":
                self._complete_repair(payload[1], payload[2])
            elif kind == "join":
                self.add_node(payload[1])
            elif kind == "heal":
                self.heal_node(int(payload[1]))

        stored_mb = float(sum(s.item.size_mb for s in stored))
        tsum = {
            "encode": float(sum(s.t_encode for s in stored)),
            "decode": float(sum(s.t_decode for s in stored)),
            "write": float(sum(s.t_write for s in stored)),
            "read": float(sum(s.t_read for s in stored)),
        }
        io_total = sum(tsum.values())
        return SimResult(
            stored_mb=stored_mb,
            total_mb=total_mb,
            n_stored=len(stored),
            n_failed_writes=len(failed_ids),
            dropped_mb=self.dropped_mb,
            throughput_mbps=stored_mb / io_total if io_total > 0 else 0.0,
            time_breakdown=tsum,
            per_node_used_mb=self.cluster.used_mb.copy(),
            stored_items=stored,
            failed_item_ids=failed_ids,
            sched_overhead_s=overheads,
            n_node_failures=self.n_node_failures,
            used_mb_at_failure=dict(self.used_mb_at_failure),
            n_repairs_planned=self.n_repairs_planned,
            n_repairs_completed=self.n_repairs_completed,
            n_repairs_aborted=self.n_repairs_aborted,
            repaired_mb=self.repaired_mb,
            repair_read_mb=self.repair_read_mb,
        )

    def _complete_repair(self, item_id: int, repair_id: int) -> None:
        pend = self._pending.get(item_id)
        if pend is None or pend.repair_id != repair_id:
            return  # stale event: the repair was aborted or the item dropped
        si = self.live_items[item_id]
        si.placement = pend.plan.placement
        del self._pending[item_id]
        self.n_repairs_completed += 1
        self.repaired_mb += pend.plan.repair_mb
        self.repair_read_mb += pend.plan.read_mb

    def _draw_failing_node(self) -> Optional[int]:
        live = self.cluster.live_ids()
        if live.size == 0:
            return None
        daily = -np.expm1(-self.cluster.afr[live] / 365.25)
        w = daily / daily.sum()
        return int(self.rng.choice(live, p=w))


def run_simulation(
    nodes: Sequence[StorageNode],
    scheduler: Scheduler | str,
    items: Sequence[DataItem],
    config: SimConfig | None = None,
) -> SimResult:
    return Simulator(nodes, scheduler, config).run(items)
