"""Discrete-event storage simulator (paper §5, simulator originally in C).

Processes store requests in arrival order through a scheduler, tracks
per-node occupancy, computes the paper's two quality metrics (W — bytes
successfully stored — and T — average I/O throughput over
encode+decode+write+read, Eq. in §3.2), and injects fail-stop node
failures with chunk rescheduling (§5.7).

Transfer model per the paper: all chunk transfers are parallel, no shared
links, so the slowest node in the mapping bottlenecks both the write and
the read; encode/decode times come from the calibrated linear model
(:class:`repro.core.types.ECTimeModel`).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.algorithms import Scheduler
from repro.core.engine import BatchContext, PlacementEngine
from repro.core.registry import scheduler_capabilities
from repro.core.types import ClusterView, DataItem, ECTimeModel, Placement, StorageNode

__all__ = ["SimConfig", "SimResult", "StoredItem", "Simulator", "run_simulation"]


@dataclasses.dataclass
class SimConfig:
    time_model: ECTimeModel = dataclasses.field(default_factory=ECTimeModel)
    #: (day, node_id) forced fail-stop events; node_id -1 = weighted random.
    failure_schedule: tuple[tuple[float, int], ...] = ()
    #: dynamic schedulers may add parity chunks when rescheduling (§5.7).
    allow_parity_growth: bool = True
    seed: int = 0
    #: measure per-item scheduling latency (Table 2).
    measure_overhead: bool = False


@dataclasses.dataclass
class StoredItem:
    item: DataItem
    placement: Placement
    chunk_mb: float
    t_encode: float
    t_decode: float
    t_write: float
    t_read: float

    @property
    def io_time(self) -> float:
        return self.t_encode + self.t_decode + self.t_write + self.t_read


@dataclasses.dataclass
class SimResult:
    stored_mb: float
    total_mb: float
    n_stored: int
    n_failed_writes: int
    #: bytes lost/dropped due to node failures (subset of stored_mb).
    dropped_mb: float
    #: Eq. §3.2: W / sum of IO times over successfully stored items.
    throughput_mbps: float
    time_breakdown: dict
    per_node_used_mb: np.ndarray
    stored_items: list[StoredItem]
    failed_item_ids: list[int]
    sched_overhead_s: list[float]
    n_node_failures: int = 0

    @property
    def stored_fraction(self) -> float:
        return self.stored_mb / self.total_mb if self.total_mb else 0.0

    @property
    def retained_fraction(self) -> float:
        """Fraction of successfully-stored bytes still retained at the end
        (Fig. 12 metric)."""
        if self.stored_mb <= 0:
            return 0.0
        return max(0.0, (self.stored_mb - self.dropped_mb)) / self.stored_mb


class Simulator:
    def __init__(
        self,
        nodes: Sequence[StorageNode],
        scheduler: Scheduler | str,
        config: SimConfig | None = None,
    ):
        self.nodes = list(nodes)
        self.config = config or SimConfig()
        # The engine owns the view, commits placements, and measures
        # per-decision overhead; the sim shares one BatchContext across
        # the whole run (AFRs never change mid-simulation) so the
        # reliability DP amortizes over the trace.
        self.engine = PlacementEngine(ClusterView.from_nodes(self.nodes), scheduler)
        self.scheduler = self.engine.scheduler
        self.cluster = self.engine.cluster
        self.ctx = BatchContext()
        self.rng = np.random.default_rng(self.config.seed)
        self.live_items: dict[int, StoredItem] = {}
        self.dropped_mb = 0.0
        self.n_node_failures = 0

    # -- store path ---------------------------------------------------------

    def _io_times(self, item: DataItem, pl: Placement) -> tuple[float, float, float, float]:
        tm = self.config.time_model
        ids = list(pl.node_ids)
        chunk = pl.chunk_size_mb(item.size_mb)
        t_write = chunk / float(self.cluster.write_bw[ids].min())
        t_read = chunk / float(self.cluster.read_bw[ids].min())
        return (
            tm.t_encode(pl.n, pl.k, item.size_mb),
            tm.t_decode(pl.k, item.size_mb),
            t_write,
            t_read,
        )

    def store(self, item: DataItem) -> tuple[Optional[StoredItem], float]:
        # The engine re-checks Problem 1's write-success constraints and
        # commits; record.overhead_s is the per-item latency of Table 2.
        record = self.engine.place(item, ctx=self.ctx)
        if record.placement is None:
            return None, record.overhead_s
        pl = record.placement
        te, td, tw, tr = self._io_times(item, pl)
        si = StoredItem(item, pl, record.chunk_mb, te, td, tw, tr)
        self.live_items[item.item_id] = si
        return si, record.overhead_s

    # -- failure path (§5.7) --------------------------------------------------

    def fail_node(self, node_id: int) -> None:
        """Fail-stop ``node_id``; reschedule lost chunks of affected items."""
        if not self.cluster.alive[node_id]:
            return
        self.cluster.alive[node_id] = False
        self.cluster.used_mb[node_id] = 0.0
        self.n_node_failures += 1
        for iid in list(self.live_items):
            si = self.live_items[iid]
            if node_id in si.placement.node_ids:
                self._reschedule(si, node_id)

    def _reschedule(self, si: StoredItem, failed_node: int) -> None:
        pl = si.placement
        survivors = [i for i in pl.node_ids if self.cluster.alive[i]]
        lost = pl.n - len(survivors)
        item = si.item
        if pl.n - lost < pl.k:
            # Fewer than K chunks survive: item is unrecoverable.
            self._drop(si)
            return
        # Re-place the lost chunks; dynamic schedulers may also add parity.
        chunk = si.chunk_mb
        candidates = [
            int(i)
            for i in self.cluster.live_ids()
            if i not in survivors and self.cluster.free_mb[i] >= chunk
        ]
        # Prefer the freest nodes (the dynamic algorithms' house style).
        candidates.sort(key=lambda i: -self.cluster.free_mb[i])
        new_map = list(survivors)
        need = lost
        for c in candidates:
            if need == 0:
                break
            new_map.append(c)
            need -= 1
        if need > 0:
            self._drop(si)
            return
        added_parity = 0
        remaining = [c for c in candidates if c not in new_map]
        while True:
            fail = self.ctx.fail_probs(self.cluster, item.delta_t_days)[new_map]
            mp = self.ctx.min_parity(fail, item.reliability_target)
            if 0 <= mp <= pl.p + added_parity:
                break
            if not (self.config.allow_parity_growth and self._dynamic()) or not remaining:
                self._drop(si)
                return
            new_map.append(remaining.pop(0))
            added_parity += 1
        # Commit replacement chunks.
        new_nodes = [n for n in new_map if n not in survivors]
        for n in new_nodes:
            self.cluster.used_mb[n] += chunk
        si.placement = Placement(
            k=pl.k, p=pl.p + added_parity, node_ids=tuple(new_map)
        )

    def _dynamic(self) -> bool:
        """Declared capability, not name matching (§5.7: only adaptive
        D-Rex-style schedulers may buy extra parity when rescheduling)."""
        return scheduler_capabilities(self.scheduler).supports_parity_growth

    def _drop(self, si: StoredItem) -> None:
        for n in si.placement.node_ids:
            if self.cluster.alive[n]:
                self.cluster.used_mb[n] = max(
                    0.0, self.cluster.used_mb[n] - si.chunk_mb
                )
        self.dropped_mb += si.item.size_mb
        del self.live_items[si.item.item_id]

    # -- main loop ------------------------------------------------------------

    def run(self, items: Sequence[DataItem]) -> SimResult:
        schedule = sorted(self.config.failure_schedule)
        sched_idx = 0
        stored: list[StoredItem] = []
        failed_ids: list[int] = []
        overheads: list[float] = []
        total_mb = 0.0
        for item in items:
            day = item.arrival_time / 86400.0
            while sched_idx < len(schedule) and schedule[sched_idx][0] <= day:
                _, nid = schedule[sched_idx]
                if nid < 0:
                    nid = self._draw_failing_node()
                if nid is not None:
                    self.fail_node(int(nid))
                sched_idx += 1
            total_mb += item.size_mb
            si, ovh = self.store(item)
            if self.config.measure_overhead:
                overheads.append(ovh)
            if si is None:
                failed_ids.append(item.item_id)
            else:
                stored.append(si)
        # Any failures scheduled after the last arrival still happen.
        while sched_idx < len(schedule):
            _, nid = schedule[sched_idx]
            if nid < 0:
                nid = self._draw_failing_node()
            if nid is not None:
                self.fail_node(int(nid))
            sched_idx += 1

        stored_mb = float(sum(s.item.size_mb for s in stored))
        tsum = {
            "encode": float(sum(s.t_encode for s in stored)),
            "decode": float(sum(s.t_decode for s in stored)),
            "write": float(sum(s.t_write for s in stored)),
            "read": float(sum(s.t_read for s in stored)),
        }
        io_total = sum(tsum.values())
        return SimResult(
            stored_mb=stored_mb,
            total_mb=total_mb,
            n_stored=len(stored),
            n_failed_writes=len(failed_ids),
            dropped_mb=self.dropped_mb,
            throughput_mbps=stored_mb / io_total if io_total > 0 else 0.0,
            time_breakdown=tsum,
            per_node_used_mb=self.cluster.used_mb.copy(),
            stored_items=stored,
            failed_item_ids=failed_ids,
            sched_overhead_s=overheads,
            n_node_failures=self.n_node_failures,
        )

    def _draw_failing_node(self) -> Optional[int]:
        live = self.cluster.live_ids()
        if live.size == 0:
            return None
        daily = -np.expm1(-self.cluster.afr[live] / 365.25)
        w = daily / daily.sum()
        return int(self.rng.choice(live, p=w))


def run_simulation(
    nodes: Sequence[StorageNode],
    scheduler: Scheduler | str,
    items: Sequence[DataItem],
    config: SimConfig | None = None,
) -> SimResult:
    return Simulator(nodes, scheduler, config).run(items)
