"""Heterogeneous storage-node sets (paper §5.3, Fig. 4; §6 Table 5).

The paper draws ten-node sets from the Backblaze drive-stats corpus. The
raw corpus is not redistributable here, so each set below encodes the
published characteristics: capacities 5-20 TB, write bandwidths
100-250 MB/s, read bandwidths 100-400 MB/s, and annual failure rates with
the spread shown in Fig. 4 (sub-1% for *Most Reliable*, ~0.6-2.2% for
*Most Used*, up to ~13% for *Most Unreliable*). Read/write bandwidths are
correlated (Pearson ~0.9, Table 4) while AFR is uncorrelated with both.

Values are deterministic constants, not draws, so every benchmark run is
reproducible bit-for-bit.
"""

from __future__ import annotations

from typing import Sequence

from repro.core.types import StorageNode

TB = 1_000_000.0  # MB per TB (decimal, as drive vendors report)


def _mk(rows: Sequence[tuple[str, float, float, float, float]]) -> list[StorageNode]:
    return [
        StorageNode(
            node_id=i,
            name=name,
            capacity_mb=cap_tb * TB,
            write_bw=w_bw,
            read_bw=r_bw,
            annual_failure_rate=afr,
        )
        for i, (name, cap_tb, w_bw, r_bw, afr) in enumerate(rows)
    ]


# (model, capacity TB, write MB/s, read MB/s, annual failure rate)
_MOST_USED = [
    ("TOSHIBA_MG07ACA14TA", 14.0, 216.0, 260.0, 0.0094),
    ("HGST_HUH721212ALE604", 12.0, 196.0, 243.0, 0.0063),
    ("WDC_WUH721414ALE6L4", 14.0, 212.0, 255.0, 0.0043),
    ("ST16000NM001G", 16.0, 230.0, 270.0, 0.0065),
    ("ST12000NM001G", 12.0, 195.0, 249.0, 0.0088),
    ("HGST_HUH721212ALN604", 12.0, 186.0, 235.0, 0.0180),
    ("ST8000NM0055", 8.0, 176.0, 220.0, 0.0122),
    ("ST8000DM002", 8.0, 164.0, 205.0, 0.0102),
    ("ST14000NM001G", 14.0, 211.0, 262.0, 0.0110),
    ("WDC_WUH721816ALE6L4", 16.0, 237.0, 284.0, 0.0035),
]

_MOST_UNRELIABLE = [
    ("ST12000NM0117", 12.0, 193.0, 240.0, 0.1316),
    ("WDC_WUH722222ALE6L4", 20.0, 245.0, 305.0, 0.1052),
    ("ST10000NM001G", 10.0, 184.0, 233.0, 0.0876),
    ("HGST_HUH728080ALE604", 8.0, 163.0, 208.0, 0.0587),
    ("ST8000DM005", 8.0, 162.0, 201.0, 0.0494),
    ("TOSHIBA_MQ01ABF050", 5.0, 104.0, 131.0, 0.0441),
    ("ST500LM030", 5.0, 100.0, 126.0, 0.0391),
    ("ST6000DX000", 6.0, 141.0, 178.0, 0.0322),
    ("WDC_WD5000LPCX", 5.0, 102.0, 128.0, 0.0305),
    ("TOSHIBA_MD04ABA500V", 5.0, 118.0, 149.0, 0.0286),
]

_MOST_RELIABLE = [
    ("HGST_HUH721212ALE600", 12.0, 198.0, 248.0, 0.0009),
    ("WDC_WUH721816ALE6L0", 16.0, 235.0, 282.0, 0.0011),
    ("ST16000NM002J", 16.0, 228.0, 276.0, 0.0013),
    ("HGST_HMS5C4040ALE640", 4.0, 130.0, 165.0, 0.0014),
    ("ST12000NM0008", 12.0, 194.0, 246.0, 0.0016),
    ("TOSHIBA_MG08ACA16TE", 16.0, 233.0, 281.0, 0.0017),
    ("WDC_WUH721414ALE604", 14.0, 214.0, 259.0, 0.0019),
    ("ST10000NM0086", 10.0, 182.0, 230.0, 0.0020),
    ("HGST_HUH721010ALE600", 10.0, 185.0, 236.0, 0.0022),
    ("ST14000NM0138", 14.0, 209.0, 256.0, 0.0024),
]

# Ten copies of the most-used Backblaze model (TOSHIBA MG07ACA14TA).
_HOMOGENEOUS = [("TOSHIBA_MG07ACA14TA", 14.0, 216.0, 260.0, 0.0094)] * 10

NODE_SETS = {
    "most_used": _MOST_USED,
    "most_unreliable": _MOST_UNRELIABLE,
    "most_reliable": _MOST_RELIABLE,
    "homogeneous": _HOMOGENEOUS,
}


def make_node_set(name: str, capacity_scale: float = 1.0) -> list[StorageNode]:
    """Instantiate one of the paper's four node sets.

    ``capacity_scale`` rescales capacities; the paper standardizes the
    workload at 122 TB against ~120 TB of raw capacity, and scaled-down
    benchmark presets shrink nodes and workload together to keep the same
    saturation regime at CI-friendly sizes.
    """
    try:
        rows = NODE_SETS[name]
    except KeyError:
        raise ValueError(f"unknown node set {name!r}; known: {sorted(NODE_SETS)}")
    nodes = _mk(rows)
    for n in nodes:
        n.capacity_mb *= capacity_scale
    return nodes


def chameleon_nodes(capacity_scale: float = 1.0) -> list[StorageNode]:
    """The ten Chameleon Cloud nodes of §6 Table 5 (capacities in GB);
    bandwidths estimated per drive class (SSD/NVMe vs HDD), AFRs per the
    SSD~HDD equivalence the paper cites [31]."""
    rows = [
        ("TACC_INTEL_SSDSC1BG40-0", 0.370, 450.0, 500.0, 0.0090),
        ("TACC_INTEL_SSDSC1BG40-1", 0.370, 450.0, 500.0, 0.0090),
        ("TACC_Seagate_ST2000NX0273", 2.000, 136.0, 160.0, 0.0110),
        ("TACC_Micron_MTFDDAK480TDS", 0.450, 420.0, 480.0, 0.0080),
        ("NRP_Seagate_ST9250610NS-0", 0.200, 115.0, 125.0, 0.0130),
        ("NRP_Seagate_ST9250610NS-1", 0.200, 115.0, 125.0, 0.0130),
        ("UC_Dell_ExpressFlash_CD5", 0.960, 1000.0, 1500.0, 0.0060),
        ("UC_INTEL_SSDPF2KX076TZ-0", 7.600, 1800.0, 2400.0, 0.0050),
        ("UC_Dell_MZ7KM240HMHQ0D3", 0.240, 320.0, 380.0, 0.0100),
        ("UC_INTEL_SSDPF2KX076TZ-1", 0.865, 1800.0, 2400.0, 0.0050),
    ]
    nodes = _mk(rows)
    for n in nodes:
        n.capacity_mb *= capacity_scale
    return nodes
