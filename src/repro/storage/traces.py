"""Workload-trace generators matching the paper's four datasets (Table 3).

The original archives (MEVA video clips, Sentinel-2 imagery, SWIM
MapReduce traces, the IBM COS object trace) total hundreds of TB and are
not redistributable; the schedulers only ever observe the tuple
``(size, arrival_time, RT, delta_t)`` per item, so we generate synthetic
traces whose per-item size statistics match Table 3 (count, mean, min,
max, std — lognormal body clipped to the published min/max) with
deterministic seeds. The benchmark presets standardize total request
volume the way the paper does (trim long traces / repeat MEVA).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.types import DataItem

__all__ = ["TraceSpec", "DATASET_NAMES", "make_trace", "random_reliability_targets"]


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Size model for one dataset; stats in MB, per Table 3."""

    name: str
    n_items: int
    mean_mb: float
    std_mb: float
    min_mb: float
    max_mb: float
    duration_days: float = 70.0  # §5.7 uses 70 days of MEVA input

    @property
    def lognormal_params(self) -> tuple[float, float]:
        """(mu, sigma) of the lognormal matching mean/std before clipping."""
        cv2 = (self.std_mb / self.mean_mb) ** 2
        sigma2 = math.log1p(cv2)
        mu = math.log(self.mean_mb) - sigma2 / 2.0
        return mu, math.sqrt(sigma2)


GB = 1024.0

_SPECS = {
    "meva": TraceSpec("meva", 4157, 117.1, 68.1, 1.4, 856.1),
    "sentinel2": TraceSpec("sentinel2", 256_351, 475.9, 256.5, 2.7, 969.9),
    "swim": TraceSpec("swim", 5214, 23.4 * GB, 177.0 * GB, 1e-6, 5329.5 * GB),
    "ibm_cos": TraceSpec("ibm_cos", 47_529, 2.6 * GB, 18.9 * GB, 0.2, 1345.8 * GB),
}

DATASET_NAMES = sorted(_SPECS)


def random_reliability_targets(m: int, rng: np.random.Generator) -> np.ndarray:
    """Per-item random 'number of nines' targets (paper §5.5).

    x ~ U{-1,...,5}; f(-1)=90, f(x)=100-10^-x for 0<=x<5, f(5)=99.99999;
    RT ~ U[f(x), f(x+1)] (as a probability in (0,1)), or f(5) when x=5.
    """

    def f(x: int) -> float:
        if x == -1:
            return 90.0
        if x >= 5:
            return 99.99999
        return 100.0 - 10.0 ** (-x)

    xs = rng.integers(-1, 6, size=m)
    lo = np.array([f(int(x)) for x in xs])
    hi = np.array([f(int(x) + 1) for x in xs])
    vals = np.where(xs == 5, 99.99999, rng.uniform(lo, hi))
    return vals / 100.0


def make_trace(
    name: str,
    *,
    seed: int = 0,
    total_mb: float | None = None,
    n_items: int | None = None,
    reliability: float | str = "random_nines",
    delta_t_days: float = 365.0,
    duration_days: float | None = None,
    size_scale: float = 1.0,
) -> list[DataItem]:
    """Generate a workload trace.

    ``total_mb``: if set, trim/repeat the trace until the cumulative item
    size reaches this volume (the paper standardizes at 122 TB).
    ``n_items``: alternatively cap the item count (benchmark subsets).
    ``reliability``: a fixed target in (0,1) or ``"random_nines"`` (§5.5).
    ``size_scale``: multiply item sizes (scaled-down CI presets).
    """
    spec = _SPECS.get(name)
    if spec is None:
        raise ValueError(f"unknown dataset {name!r}; known: {DATASET_NAMES}")
    rng = np.random.default_rng(seed)
    duration = spec.duration_days if duration_days is None else duration_days

    mu, sigma = spec.lognormal_params
    want = n_items if n_items is not None else spec.n_items

    sizes_parts: list[np.ndarray] = []
    total = 0.0
    count = 0
    while True:
        batch = np.clip(
            rng.lognormal(mu, sigma, size=max(1024, want)), spec.min_mb, spec.max_mb
        ) * size_scale
        if total_mb is not None:
            csum = total + np.cumsum(batch)
            cut = int(np.searchsorted(csum, total_mb, side="left")) + 1
            sizes_parts.append(batch[:cut])
            total = float(csum[min(cut, len(csum)) - 1])
            count += cut
            if total >= total_mb:
                break
        else:
            need = want - count
            sizes_parts.append(batch[:need])
            count += min(need, len(batch))
            if count >= want:
                break
    sizes = np.concatenate(sizes_parts)
    m = len(sizes)

    arrivals_days = np.sort(rng.uniform(0.0, duration, size=m))
    if isinstance(reliability, str):
        if reliability != "random_nines":
            raise ValueError(f"unknown reliability mode {reliability!r}")
        rts = random_reliability_targets(m, rng)
    else:
        rts = np.full(m, float(reliability))

    return [
        DataItem(
            item_id=i,
            size_mb=float(sizes[i]),
            arrival_time=float(arrivals_days[i] * 86400.0),
            delta_t_days=delta_t_days,
            reliability_target=float(rts[i]),
        )
        for i in range(m)
    ]
