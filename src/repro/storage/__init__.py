"""Storage substrate: heterogeneous node sets, workload traces, simulator."""

from .nodesets import NODE_SETS, chameleon_nodes, make_node_set
from .traces import DATASET_NAMES, make_trace, TraceSpec
from .simulator import SimConfig, SimResult, Simulator, run_simulation

__all__ = [
    "NODE_SETS",
    "make_node_set",
    "chameleon_nodes",
    "DATASET_NAMES",
    "make_trace",
    "TraceSpec",
    "Simulator",
    "SimConfig",
    "SimResult",
    "run_simulation",
]
