"""Training launcher: ``python -m repro.launch.train --arch yi-6b --smoke``.

Wires together the full production stack — config registry, sharded
train step, data pipeline, AdamW, and D-Rex EC-protected checkpointing
over a heterogeneous storage fabric — at whatever scale the host
supports (``--smoke`` reduced configs on CPU; full configs on real
slices).
"""

from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointPolicy, DRexCheckpointer, StorageFabric
from repro.configs import get_config
from repro.data import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.storage import make_node_set
from repro.train import Trainer, TrainerConfig, init_train_state


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-scheduler", default="drex_sc")
    ap.add_argument("--compression", action="store_true", help="EF-int8 grads")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"[launch] arch={cfg.name} params~{cfg.n_params()/1e6:.1f}M "
          f"devices={jax.device_count()}")
    mesh = make_local_mesh(1, 1) if jax.device_count() == 1 else None

    checkpointer = None
    if args.ckpt_every:
        fabric = StorageFabric(make_node_set("most_used", capacity_scale=1e-4))
        ck = DRexCheckpointer(fabric, args.ckpt_scheduler, CheckpointPolicy(item_mb=4.0))
        like = init_train_state(cfg, jax.random.PRNGKey(args.seed), args.compression)

        class Adapter:
            def save(self, st, step):
                ck.save(st, step)

            def save_async(self, st, step):
                return ck.save_async(st, step)

            def restore_latest(self, _):
                return ck.restore_latest(like)

        checkpointer = Adapter()

    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20)),
        TrainerConfig(
            steps=args.steps,
            log_every=args.log_every,
            ckpt_every=args.ckpt_every,
            seed=args.seed,
            compression=args.compression,
        ),
        data_cfg=DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq,
            global_batch=args.batch,
            seed=args.seed,
        ),
        mesh=mesh,
        checkpointer=checkpointer,
    )
    trainer.run()
    if trainer.history:
        first, last = trainer.history[0], trainer.history[-1]
        print(f"[launch] loss {first['loss']:.4f} -> {last['loss']:.4f} "
              f"over {args.steps} steps")


if __name__ == "__main__":
    main()
