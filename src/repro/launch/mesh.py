"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls it.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices the host actually has (CPU
    tests / the runnable examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )
