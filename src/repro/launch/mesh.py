"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls it.

``AxisType`` (explicit-sharding axis annotations) only exists on newer
jax; on jax <= 0.4.x meshes carry no axis types and ``jax.make_mesh``
does not accept the kwarg, so we fall back to plain meshes.
"""

from __future__ import annotations

import jax
import jax.sharding
from jax.sharding import Mesh

#: None on jax versions without explicit-sharding axis types (<= 0.4.x).
AxisType = getattr(jax.sharding, "AxisType", None)


def make_mesh_compat(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices the host actually has (CPU
    tests / the runnable examples)."""
    return make_mesh_compat((data, model), ("data", "model"))
