import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks at first init). The
# production mesh needs 512 placeholder devices; smoke tests/benches run
# in separate processes and see the host's real single device.

"""Multi-pod dry-run driver (deliverable (e)).

For every valid (architecture x input-shape) cell, lowers + compiles the
step function on the single-pod 16x16 mesh and the 2x16x16 multi-pod
mesh, prints ``memory_analysis()`` / ``cost_analysis()``, and records the
roofline terms (jaxpr FLOPs, per-device HLO bytes, collective bytes by
type) to JSON for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ARCH_IDS, cell_supported, get_config, input_specs, normalize
from repro.launch.mesh import make_production_mesh
from repro.models import decode_step, init_params, param_axes, prefill
from repro.models import init_serve_state, serve_state_axes
from repro.models.config import ModelConfig
from repro.models.sharding import activate_mesh, logical_to_spec, rules_for
from repro.optim import AdamWConfig
from repro.roofline import RooflineTerms, analyze_hlo, count_fn_flops, model_flops_for
from repro.train import init_train_state, make_train_step, train_state_shardings, batch_shardings


def _tree_shardings_from_axes(axes_tree, shapes_tree, mesh):
    rules = rules_for(mesh)
    return jax.tree.map(
        lambda ax, shp: NamedSharding(mesh, logical_to_spec(ax, shp.shape, mesh, rules)),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def _params_shardings(cfg: ModelConfig, mesh):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return _tree_shardings_from_axes(param_axes(cfg), shapes, mesh)


def _apply_overrides(cfg, overrides):
    if not overrides:
        return cfg
    kw = {}
    for ov in overrides:
        k, v = ov.split("=", 1)
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            v = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            v = int(v)
        elif isinstance(cur, float):
            v = float(v)
        kw[k] = v
    return cfg.with_(**kw)


def lower_cell(arch: str, shape: str, mesh, mesh_name: str, overrides=()):
    """Lower+compile one cell; returns (lowered, compiled, fn_flops, specs)."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    cfg = cfg.with_(max_cache_len=spec.seq_len)
    cfg = _apply_overrides(cfg, overrides)
    specs = input_specs(cfg, shape)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    if spec.kind == "train":
        step = make_train_step(cfg, AdamWConfig(), mesh)
        state_shapes = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0))
        )
        with activate_mesh(mesh), mesh:
            lowered = step.fn.lower(state_shapes, specs["batch"])
        # jaxpr flops: trace the un-jitted step (same math, no shardings)
        flops = count_fn_flops(_raw_train_step(cfg), state_shapes, specs["batch"])
    elif spec.kind == "prefill":
        p_sh = _params_shardings(cfg, mesh)
        tok_sh = NamedSharding(mesh, P(dp, None))
        in_sh = {"tokens": tok_sh}
        args = {"tokens": specs["tokens"]}
        if cfg.is_encdec:
            in_sh["frames"] = NamedSharding(mesh, P(dp, None, None))
            args["frames"] = specs["frames"]
        fn = lambda params, tokens, frames=None: prefill(params, tokens, cfg, frames)
        jf = jax.jit(fn, in_shardings=(p_sh, tok_sh) if not cfg.is_encdec else (p_sh, tok_sh, in_sh["frames"]))
        pshapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        with activate_mesh(mesh), mesh:
            if cfg.is_encdec:
                lowered = jf.lower(pshapes, args["tokens"], args["frames"])
            else:
                lowered = jf.lower(pshapes, args["tokens"])
        flops = count_fn_flops(
            (lambda p, t, f: prefill(p, t, cfg, f)) if cfg.is_encdec else (lambda p, t: prefill(p, t, cfg)),
            pshapes, *( [args["tokens"], args["frames"]] if cfg.is_encdec else [args["tokens"]] ),
        )
    else:  # decode
        p_sh = _params_shardings(cfg, mesh)
        state_shapes = specs["state"]
        st_axes = serve_state_axes(cfg, state_shapes)
        st_sh = _tree_shardings_from_axes(st_axes, state_shapes, mesh)
        # divisibility-aware: long_500k's global_batch=1 cannot shard over
        # the data axes and falls back to replication.
        tok_sh = NamedSharding(
            mesh,
            logical_to_spec(("batch", None), specs["token"].shape, mesh),
        )
        pos_sh = NamedSharding(mesh, P())
        fn = lambda params, token, pos, state: decode_step(params, token, pos, state, cfg)
        jf = jax.jit(fn, in_shardings=(p_sh, tok_sh, pos_sh, st_sh))
        pshapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        with activate_mesh(mesh), mesh:
            lowered = jf.lower(pshapes, specs["token"], specs["pos"], state_shapes)
        flops = count_fn_flops(fn, pshapes, specs["token"], specs["pos"], state_shapes)

    compiled = lowered.compile()
    return cfg, lowered, compiled, flops


def _raw_train_step(cfg: ModelConfig):
    from repro.models import loss_fn
    from repro.optim import adamw_update
    from repro.train.step import TrainState

    opt_cfg = AdamWConfig()

    def raw(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True
        )(state.params)
        new_params, new_opt, om = adamw_update(opt_cfg, grads, state.opt, state.params)
        return TrainState(new_params, new_opt, state.comp), loss

    return raw


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: pathlib.Path, overrides=(), suffix: str = "") -> dict:
    arch = normalize(arch)
    cfg0 = get_config(arch)
    ok, why = cell_supported(cfg0, shape)
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "overrides": list(overrides), "variant": suffix or "baseline"}
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    t0 = time.time()
    try:
        cfg, lowered, compiled, flops = lower_cell(arch, shape, mesh, mesh_name, overrides)
    except Exception as e:
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        return rec
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    mem_d = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_d[attr] = int(v)
    print(f"[dryrun] {arch} x {shape} x {mesh_name}: memory_analysis={mem_d}")
    print(f"[dryrun] cost_analysis flops={cost.get('flops')} "
          f"bytes={cost.get('bytes accessed')} (while bodies counted once — "
          f"see roofline JSON for trip-count-corrected terms)")

    hlo_text = compiled.as_text()
    try:  # persist for offline re-analysis (zstd-compressed)
        import zstandard

        hdir = out_dir.parent / "hlo"
        hdir.mkdir(parents=True, exist_ok=True)
        (hdir / f"{arch}__{shape}__{mesh_name}{suffix}.hlo.zst").write_bytes(
            zstandard.ZstdCompressor(level=3).compress(hlo_text.encode())
        )
    except Exception:
        pass
    hlo = analyze_hlo(hlo_text)
    spec = SHAPES[shape]
    terms = RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        global_flops=flops.total,
        per_device_hbm_bytes=hlo.memory_bytes_ideal,
        per_device_collective_bytes=hlo.total_collective_bytes,
        per_device_hbm_bytes_raw=hlo.memory_bytes,
        collective_breakdown={k: v for k, v in hlo.collective_bytes.items() if v},
        model_flops=model_flops_for(cfg, spec.kind, spec.seq_len, spec.global_batch),
        hlo_dot_flops_per_device=hlo.dot_flops,
    )
    rec.update(
        {
            "status": "ok",
            "compile_s": t_compile,
            "chips": chips,
            "memory_analysis": mem_d,
            "cost_analysis": {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
            "jaxpr_flops": {"dot": flops.dot_flops, "elementwise": flops.elementwise_flops},
            "roofline": terms.to_dict(),
            "n_collective_ops": hlo.n_collectives,
        }
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape}__{mesh_name}{suffix}.json").write_text(json.dumps(rec, indent=2))
    print(
        f"[dryrun] OK {arch} x {shape} x {mesh_name}: compile={t_compile:.1f}s "
        f"compute={terms.compute_s*1e3:.2f}ms memory={terms.memory_s*1e3:.2f}ms "
        f"collective={terms.collective_s*1e3:.2f}ms bottleneck={terms.bottleneck} "
        f"roofline_frac={terms.roofline_fraction:.3f}",
        flush=True,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="config override key=value (repeatable)")
    ap.add_argument("--suffix", default="", help="output filename suffix for variants")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((normalize(args.arch), args.shape))

    summary = []
    for arch, shape in cells:
        for mesh_name in meshes:
            if args.skip_existing and (
                out_dir / f"{normalize(arch)}__{shape}__{mesh_name}.json"
            ).exists():
                print(f"[dryrun] skip existing {arch} x {shape} x {mesh_name}")
                continue
            rec = run_cell(arch, shape, mesh_name, out_dir, tuple(args.overrides), args.suffix)
            summary.append(
                (arch, shape, mesh_name, rec.get("status"), rec.get("reason") or rec.get("error", ""))
            )
    print("\n=== dry-run summary ===")
    for row in summary:
        print(" ", " | ".join(str(x) for x in row))
    bad = [r for r in summary if r[3] == "error"]
    if bad:
        raise SystemExit(f"{len(bad)} cells failed")


if __name__ == "__main__":
    main()
