"""Training substrate: sharded train step, trainer loop, elastic rescale."""

from .step import TrainState, make_train_step, init_train_state, train_state_shardings, batch_shardings
from .trainer import Trainer, TrainerConfig

__all__ = [
    "TrainState",
    "make_train_step",
    "init_train_state",
    "train_state_shardings",
    "batch_shardings",
    "Trainer",
    "TrainerConfig",
]
