"""The sharded training step (pjit) and train-state plumbing.

FSDP x TP x (pod-DP): parameters and optimizer moments are sharded over
the data axes (logical "embed" rule) and the tensor axes over "model";
activations shard batch over ("pod","data"). XLA SPMD inserts the
per-layer all-gathers (FSDP) and the gradient reduce-scatters.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import init_params, loss_fn, param_axes
from repro.models.config import ModelConfig
from repro.models.sharding import activate_mesh, logical_to_spec, rules_for
from repro.optim import (
    AdamWConfig,
    CompressionState,
    OptState,
    adamw_init,
    adamw_update,
    compress_decompress,
    compression_init,
)


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    comp: Optional[CompressionState]


def init_train_state(cfg: ModelConfig, key, compression: bool = False) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(
        params=params,
        opt=adamw_init(params),
        comp=compression_init(params) if compression else None,
    )


def _axes_tree_to_shardings(axes_tree, shapes_tree, mesh: Mesh):
    rules = rules_for(mesh)

    def one(ax, shp):
        return NamedSharding(mesh, logical_to_spec(ax, shp.shape, mesh, rules))

    return jax.tree.map(
        one,
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, compression: bool = False):
    """NamedShardings for the full TrainState (params + moments + master)."""
    axes = param_axes(cfg)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = _axes_tree_to_shardings(axes, shapes, mesh)
    scalar = NamedSharding(mesh, P())
    opt_sh = OptState(step=scalar, mu=p_sh, nu=p_sh, master=p_sh)
    comp_sh = CompressionState(error=p_sh) if compression else None
    return TrainState(params=p_sh, opt=opt_sh, comp=comp_sh)


def batch_shardings(cfg: ModelConfig, mesh: Mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tok = NamedSharding(mesh, P(dp, None))
    out = {"tokens": tok, "labels": tok}
    if cfg.is_encdec:
        out["frames"] = NamedSharding(mesh, P(dp, None, None))
    return out


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh: Optional[Mesh] = None,
    compression: bool = False,
):
    """Build the (optionally pjit-wrapped) train step.

    Returns ``step(state, batch) -> (state, metrics)``; when ``mesh`` is
    given the function is jitted with full in/out shardings and donated
    state."""

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True
        )(state.params)
        comp = state.comp
        if compression:
            grads, comp = compress_decompress(grads, comp)
        new_params, new_opt, om = adamw_update(opt_cfg, grads, state.opt, state.params)
        out_metrics = {
            "loss": loss.astype(jnp.float32),
            "nll": metrics["nll"].astype(jnp.float32),
            "grad_norm": om["grad_norm"],
            "lr": om["lr"],
        }
        return TrainState(new_params, new_opt, comp), out_metrics

    if mesh is None:
        return jax.jit(train_step, donate_argnums=0)

    st_sh = train_state_shardings(cfg, mesh, compression)
    b_sh = batch_shardings(cfg, mesh)
    scalar = NamedSharding(mesh, P())
    metric_sh = {k: scalar for k in ("loss", "nll", "grad_norm", "lr")}

    jitted = jax.jit(
        train_step,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, metric_sh),
        donate_argnums=0,
    )

    class _Wrapped:
        """Trace under activate_mesh so logical constraints resolve."""

        def __init__(self):
            self.fn = jitted

        def __call__(self, state, batch):
            with activate_mesh(mesh):
                return self.fn(state, batch)

        def lower(self, *a, **kw):
            with activate_mesh(mesh), mesh:
                return self.fn.lower(*a, **kw)

    return _Wrapped()


def reshard_state(state: TrainState, cfg: ModelConfig, new_mesh: Mesh,
                  compression: bool = False) -> TrainState:
    """Elastic rescale: move a TrainState onto a different mesh (e.g. after
    losing a pod). Shardings are recomputed from the logical axes, so any
    mesh whose axes divide the dims works."""
    sh = train_state_shardings(cfg, new_mesh, compression)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s),
        state,
        sh,
        is_leaf=lambda x: x is None,
    )
