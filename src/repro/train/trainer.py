"""Trainer loop: metrics, periodic (async, EC-protected) checkpointing,
restart-on-failure, straggler accounting.

The loop is deliberately unexciting — the interesting machinery lives in
the substrate it drives: the sharded step (step.py), the D-Rex checkpoint
manager (repro/checkpoint) and the data pipeline's straggler plan.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.data import DataConfig, LMDataPipeline
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig

from .step import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = disabled
    seed: int = 0
    compression: bool = False
    async_ckpt: bool = True


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        tcfg: TrainerConfig,
        data_cfg: Optional[DataConfig] = None,
        mesh=None,
        checkpointer=None,
        log_fn: Callable[[int, dict], None] | None = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.checkpointer = checkpointer
        self.log_fn = log_fn or self._default_log
        self.data = LMDataPipeline(
            data_cfg
            or DataConfig(vocab_size=cfg.vocab_size, seq_len=256, global_batch=8, seed=tcfg.seed)
        )
        self.step_fn = make_train_step(cfg, opt_cfg, mesh, tcfg.compression)
        self.history: list[dict] = []
        self._pending_ckpt = None

    @staticmethod
    def _default_log(step: int, metrics: dict) -> None:
        ms = " ".join(f"{k}={float(v):.4f}" for k, v in metrics.items())
        print(f"[train] step {step:5d} {ms}", flush=True)

    def init_or_restore(self) -> TrainState:
        if self.checkpointer is not None:
            restored = self.checkpointer.restore_latest(self.cfg)
            if restored is not None:
                state, step = restored
                self.start_step = step
                print(f"[train] restored checkpoint at step {step}", flush=True)
                return state
        self.start_step = 0
        return init_train_state(
            self.cfg, jax.random.PRNGKey(self.tcfg.seed), self.tcfg.compression
        )

    def run(self, state: Optional[TrainState] = None) -> TrainState:
        if state is None:
            state = self.init_or_restore()
        start = getattr(self, "start_step", 0)
        t_last = time.perf_counter()
        for step in range(start, self.tcfg.steps):
            batch = self.data.next_batch()
            state, metrics = self.step_fn(state, batch)
            if (step + 1) % self.tcfg.log_every == 0 or step == start:
                metrics = {k: float(v) for k, v in metrics.items()}
                now = time.perf_counter()
                metrics["steps_per_s"] = self.tcfg.log_every / max(now - t_last, 1e-9)
                t_last = now
                self.history.append({"step": step + 1, **metrics})
                self.log_fn(step + 1, metrics)
            if (
                self.checkpointer is not None
                and self.tcfg.ckpt_every
                and (step + 1) % self.tcfg.ckpt_every == 0
            ):
                self._checkpoint(state, step + 1)
        self._drain_ckpt()
        return state

    # -- checkpoint plumbing --------------------------------------------------

    def _checkpoint(self, state: TrainState, step: int) -> None:
        if self.tcfg.async_ckpt:
            self._drain_ckpt()
            self._pending_ckpt = self.checkpointer.save_async(state, step)
        else:
            self.checkpointer.save(state, step)

    def _drain_ckpt(self) -> None:
        if self._pending_ckpt is not None:
            self._pending_ckpt.result()
            self._pending_ckpt = None
