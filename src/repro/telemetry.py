"""One facade over the library's introspection surfaces.

Five subsystems keep counters that benchmarks and the gated lanes stamp
into JSON: the top-M pre-filter (:func:`repro.core.prefilter.stats`),
the EC coefficient-matrix caches
(:func:`repro.kernels.ops.matrix_cache_stats`), the shape-bucketer
compile census (:func:`repro.core.shapes.compile_cache_stats`), the
per-engine :class:`~repro.core.engine.PlacementEngine` decision counters
(``engine.stats``), and the opt-in persistent XLA compilation cache
(:func:`repro.core.jitcache.status`).  Importing each module ad hoc couples every
benchmark to four internal layouts; this facade freezes one stable
schema (:class:`TelemetrySnapshot`) behind :func:`snapshot` /
:func:`reset`.

The leaf dictionaries are byte-compatible with what the underlying
surfaces emit (the facade copies, it does not reshape), so benchmark
JSON stamped through ``snapshot()`` is identical to what the ad-hoc
imports produced — no baseline churn.

The first three surfaces are process-wide; engine counters live on each
:class:`PlacementEngine` instance, so ``snapshot(engine=...)`` takes the
instance to read (``engine=None`` in the snapshot otherwise), and
:func:`reset` only touches the process-wide state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

__all__ = ["TelemetrySnapshot", "snapshot", "reset"]


@dataclasses.dataclass(frozen=True)
class TelemetrySnapshot:
    """Point-in-time copy of every introspection surface (safe to
    mutate; the live counters are not aliased)."""

    #: per-scheduler pre-filter events (engaged / accepted / fallback /
    #: bypassed / promoted) — ``repro.core.prefilter.stats()``.
    prefilter: dict[str, dict[str, int]]
    #: EC coefficient-matrix builds and LRU hit rates —
    #: ``repro.kernels.ops.matrix_cache_stats()``.
    matrix_cache: dict[str, Any]
    #: jit compile census per kernel family —
    #: ``repro.core.shapes.compile_cache_stats()``.
    compile_cache: dict[str, Any]
    #: decision counters of the engine passed to :func:`snapshot`
    #: (placements, rejections, constraint swaps, repair gauges), or
    #: ``None`` when no engine was given.
    engine: Optional[dict[str, Any]] = None
    #: persistent XLA compilation-cache state —
    #: ``repro.core.jitcache.status()`` (opt-in via REPRO_JIT_CACHE=1).
    jit_cache: Optional[dict[str, Any]] = None

    def as_dict(self) -> dict[str, Any]:
        """Plain-dict view for JSON stamping."""
        return dataclasses.asdict(self)


def snapshot(engine=None) -> TelemetrySnapshot:
    """Copy every introspection surface; pass a
    :class:`~repro.core.engine.PlacementEngine` to include its
    per-instance decision counters."""
    from repro.core import jitcache, prefilter, shapes
    from repro.kernels import ops as kops

    return TelemetrySnapshot(
        prefilter=prefilter.stats(),
        matrix_cache=kops.matrix_cache_stats(),
        compile_cache=shapes.compile_cache_stats(),
        engine=dict(engine.stats) if engine is not None else None,
        jit_cache=jitcache.status(),
    )


def reset(
    *,
    prefilter_counters: bool = True,
    matrix_caches: bool = True,
    compile_census: bool = True,
) -> None:
    """Zero the process-wide counters (benchmark lane isolation).

    Engine counters are per-instance and unaffected — construct a fresh
    engine instead.  Resetting the compile census clears the bucketer's
    issued-shape census, not the jit caches themselves.
    """
    from repro.core import prefilter, shapes
    from repro.kernels import ops as kops

    if prefilter_counters:
        prefilter.reset_stats()
    if matrix_caches:
        kops.reset_matrix_caches()
    if compile_census:
        shapes.reset()
