"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8. [hf:Qwen/Qwen3-30B-A3B]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,               # per-expert ffn dim
    vocab_size=151_936,
    activation="silu",
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_experts=128, experts_per_token=8, expert_d_ff=768, norm_topk=True
    ),
    # explicit shard_map dispatch: one combine-psum per layer instead of
    # GSPMD dispatch-buffer all-reduces (§Perf: collective -89%)
    moe_dispatch="shard_map",
)

SMOKE = CONFIG.with_(
    name="qwen3-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    moe=MoEConfig(n_experts=8, experts_per_token=2, expert_d_ff=96),
)
