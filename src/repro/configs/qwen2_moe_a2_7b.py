"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,              # per-expert ffn dim (shared expert = 4x this)
    vocab_size=151_936,
    activation="silu",
    moe=MoEConfig(
        n_experts=60,
        experts_per_token=4,
        expert_d_ff=1408,
        n_shared_experts=4,
        norm_topk=False,
        # 60 does not divide the 16-way model axis; pad to 64 so expert
        # parallelism shards evenly (beyond-paper §Perf optimization).
        pad_experts_to=64,
    ),
    # explicit shard_map dispatch (§Perf: collective -95%, memory -92%)
    moe_dispatch="shard_map",
)

SMOKE = CONFIG.with_(
    name="qwen2-moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=256,
    moe=MoEConfig(
        n_experts=8, experts_per_token=2, expert_d_ff=96, n_shared_experts=2,
        norm_topk=False,
    ),
)
