"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention, pattern (R,R,A).
[arXiv:2402.19427]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,           # 12 x (R,R,A) groups + 2 trailing recurrent
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    activation="gelu",
    block_pattern="griffin",
    attn_window=2048,
    conv1d_width=4,
)

SMOKE = CONFIG.with_(
    name="recurrentgemma-smoke",
    n_layers=5,            # 1 group + 2 tail
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    attn_window=8,
)
