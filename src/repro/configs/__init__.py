"""Architecture registry + input specs for the assigned (arch x shape) grid.

Every architecture module exposes ``CONFIG`` (the exact published
configuration) and ``SMOKE`` (a reduced same-family configuration used by
the CPU smoke tests). The full configs are only ever lowered against
``ShapeDtypeStruct``s (no allocation) via the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCH_IDS = [
    "whisper_tiny",
    "qwen3_8b",
    "yi_6b",
    "nemotron_4_15b",
    "nemotron_4_340b",
    "qwen2_moe_a2_7b",
    "qwen3_moe_30b_a3b",
    "rwkv6_1_6b",
    "chameleon_34b",
    "recurrentgemma_9b",
]

# canonical external ids (--arch flag) -> module names
_ALIASES = {
    "whisper-tiny": "whisper_tiny",
    "qwen3-8b": "qwen3_8b",
    "yi-6b": "yi_6b",
    "nemotron-4-15b": "nemotron_4_15b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "chameleon-34b": "chameleon_34b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def normalize(arch: str) -> str:
    a = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if a not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; known: {sorted(_ALIASES)}")
    return a


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Is (arch x shape) a valid dry-run cell? (DESIGN.md §5 skip rules)."""
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is full-attention (skip per DESIGN.md §5)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    from repro.models import model as M

    spec = SHAPES[shape]
    b, t = spec.global_batch, spec.seq_len
    i32 = jnp.int32

    def sd(shape_, dtype):
        return jax.ShapeDtypeStruct(shape_, dtype)

    if spec.kind == "train":
        batch = {
            "tokens": sd((b, t), i32),
            "labels": sd((b, t), i32),
        }
        if cfg.is_encdec:
            batch["frames"] = sd((b, cfg.encoder.n_frames, cfg.d_model), cfg.dt)
        return {"batch": batch}
    if spec.kind == "prefill":
        out = {"tokens": sd((b, t), i32)}
        if cfg.is_encdec:
            out["frames"] = sd((b, cfg.encoder.n_frames, cfg.d_model), cfg.dt)
        return out
    # decode: one new token against a seq_len-deep state
    cache_len = t if not cfg.sub_quadratic else (cfg.attn_window or 2048)
    state = jax.eval_shape(
        lambda: M.init_serve_state(cfg, b, cache_len)
    )
    return {
        "token": sd((b, 1), i32),
        "pos": sd((), i32),
        "state": state,
    }


def all_cells() -> list[tuple[str, str, bool, str]]:
    """(arch, shape, supported, skip_reason) for the full 40-cell grid."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_supported(cfg, shape)
            out.append((arch, shape, ok, why))
    return out
