"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU MLP. [arXiv:2402.16819]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73_728,
    vocab_size=256_000,
    activation="squared_relu",
)

SMOKE = CONFIG.with_(
    name="nemotron-4-340b-smoke",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=384,
    vocab_size=256,
)
