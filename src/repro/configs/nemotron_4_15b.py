"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU MLP. [arXiv:2402.16819]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=256_000,
    activation="squared_relu",
)

SMOKE = CONFIG.with_(
    name="nemotron-4-15b-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=256,
)
