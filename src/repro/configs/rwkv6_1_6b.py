"""rwkv6-1.6b [ssm] Finch: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — data-dependent decay. [arXiv:2404.05892]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # = d_model / rwkv_head_size
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65_536,
    block_pattern="rwkv6",
    rwkv_head_size=64,
    # chunked WKV recurrence (bit-exact vs per-step scan; §Perf hillclimb
    # winner: memory term -69% on train_4k)
    rwkv_chunk=16,
)

SMOKE = CONFIG.with_(
    name="rwkv6-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    rwkv_head_size=16,
)
