"""whisper-tiny [audio]: 4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865.

Encoder-decoder; conv/mel frontend is a STUB — inputs are precomputed
frame embeddings (B, 1500, 384) per the assignment. [arXiv:2212.04356]
"""

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    activation="gelu",
    block_pattern="attn",
    encoder=EncoderConfig(n_layers=4, n_frames=1500),
)

SMOKE = CONFIG.with_(
    name="whisper-tiny-smoke",
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    encoder=EncoderConfig(n_layers=2, n_frames=16),
)
