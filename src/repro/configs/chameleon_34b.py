"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early fusion; VQ image-token frontend is a STUB (image
patches arrive as token ids in the unified vocab). [arXiv:2405.09818]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    activation="silu",
    use_qk_norm=True,      # chameleon's qk-norm is load-bearing at 34B
)

SMOKE = CONFIG.with_(
    name="chameleon-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
)
