"""Streaming placement service (repro.serve.placement) test suite.

Four pillars, mirroring the frontier's contract:

* **determinism / goldens** — same arrival trace + seed ⇒ byte-identical
  outcomes; absolute digests for the pinned scenario are hardcoded like
  the simulator's legacy goldens, so a placement-bit drift anywhere in
  the engine/scheduler stack fails here with a named constant to update.
* **oracle equivalence** — every registry scheduler declaring the
  ``batch_scoring`` capability runs behind the frontier and must produce
  exactly the placements of a naive per-item ``place`` loop (windows are
  a performance construct, never a behavior change).
* **backpressure** — the bounded admission queue rejects explicitly:
  per-item ADMISSION_REJECT outcomes, conservation of offered items,
  depth never exceeding capacity.
* **epoch consistency** — snapshot reads are immutable, monotonically
  versioned, decoupled from the live view, and bracket churn (an epoch
  before a failure still shows the node alive).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    ClusterView,
    DataItem,
    PlacementEngine,
    SCHEDULER_NAMES,
    StorageNode,
    get_spec,
    scheduler_names,
)
from repro.serve.placement import (
    ADMISSION_REJECT,
    PLACED,
    REJECTED,
    FrontierConfig,
    PlacementFrontier,
    ServiceEvent,
    arrival_events,
    churn_events,
)
from repro.storage.traces import make_trace

# Every scheduler advertising batched scoring — new registrations join
# the sweep automatically (same materialization as tests/test_invariants).
BATCHED = [
    n
    for n in sorted(set(scheduler_names()) | set(SCHEDULER_NAMES))
    if get_spec(n).capabilities.batch_scoring
]


def _cluster(n: int = 12, seed: int = 7) -> ClusterView:
    rng = np.random.default_rng(seed)
    nodes = [
        StorageNode(
            node_id=i,
            capacity_mb=float(rng.uniform(5e5, 2e6)),
            write_bw=float(rng.uniform(100, 250)),
            read_bw=float(rng.uniform(100, 400)),
            annual_failure_rate=float(rng.uniform(0.003, 0.05)),
        )
        for i in range(n)
    ]
    return ClusterView.from_nodes(nodes)


def _trace(n_items: int = 40, rate: float = 200.0, seed: int = 3):
    base = make_trace("meva", seed=seed, n_items=n_items)
    rng = np.random.default_rng(seed)
    at = np.cumsum(rng.exponential(1.0 / rate, size=n_items))
    return [
        dataclasses.replace(it, arrival_time=float(at[i]))
        for i, it in enumerate(base)
    ]


_CFG = FrontierConfig(max_batch=8, max_wait_s=0.02)


def _run(algo: str, events=None, cfg: FrontierConfig = _CFG, n: int = 12):
    frontier = PlacementFrontier(PlacementEngine(_cluster(n), algo), cfg)
    report = frontier.run(
        events if events is not None else arrival_events(_trace())
    )
    return frontier, report


def _churn():
    """The pinned churn scenario: two failures, a join, a heal, all
    interleaved with the arrival stream."""
    return arrival_events(_trace()) + churn_events(
        failure_schedule=((0.05, 3), (0.12, 7)),
        node_join_schedule=(
            (
                0.15,
                StorageNode(
                    node_id=12,
                    capacity_mb=1.5e6,
                    write_bw=200.0,
                    read_bw=300.0,
                    annual_failure_rate=0.01,
                ),
            ),
        ),
        node_heal_schedule=((0.18, 3),),
        unit="seconds",
    )


class TestGoldenTraces:
    """Absolute digests for the pinned scenario (seeded trace + cluster).

    These play the role of the simulator's legacy goldens for the serving
    plane: the frontier's replay contract says the digest is a pure
    function of (trace, cluster seed, config), so any engine/scheduler
    change that moves a placement bit fails here.  Update the constants
    only for an intentional behavior change, alongside the serve_load
    smoke baseline.
    """

    # drex_lb and greedy_least_used coincide on this small scenario
    # (both chase the most-free nodes and the feasible fronts agree) —
    # two independent pins of the same bits, not a copy-paste error.
    GOLDEN = {
        "drex_sc": 40223875852926,
        "drex_lb": 242294610488822,
        "greedy_least_used": 242294610488822,
        "greedy_min_storage": 163243786829188,
    }
    GOLDEN_CHURN_SC = 246991119138540

    @pytest.mark.parametrize("algo", sorted(GOLDEN))
    def test_pinned_digest(self, algo):
        _, report = _run(algo)
        assert report.digest() == self.GOLDEN[algo]

    def test_pinned_churn_digest(self):
        _, report = _run("drex_sc", events=_churn())
        assert report.digest() == self.GOLDEN_CHURN_SC


class TestDeterminism:
    @pytest.mark.parametrize("algo", ["drex_sc", "greedy_least_used"])
    def test_replay_byte_identical(self, algo):
        _, a = _run(algo)
        _, b = _run(algo)
        assert a.outcomes == b.outcomes  # full tuples, not just digests
        assert a.digest() == b.digest()
        assert a.makespan_virtual_s == b.makespan_virtual_s
        # virtual metrics are part of the replay contract too
        for key in (
            "goodput_virtual_items_per_s",
            "n_flushes",
            "max_queue_depth",
            "reject_count",
        ):
            assert a.summary[key] == b.summary[key], key

    def test_churn_replay_byte_identical(self):
        _, a = _run("drex_sc", events=_churn())
        _, b = _run("drex_sc", events=_churn())
        assert a.outcomes == b.outcomes
        assert a.summary["n_repairs"] == b.summary["n_repairs"]
        assert a.summary["n_failures"] == 2

    def test_past_event_rejected(self):
        frontier, _ = _run("greedy_least_used")
        with pytest.raises(ValueError, match="past"):
            frontier.run([ServiceEvent(0.0, "fail", 0)])


class TestOracleEquivalence:
    """Windows are a batching construct: the frontier must emit exactly
    the placements of a per-item ``place`` loop in arrival order."""

    @pytest.mark.parametrize("name", BATCHED)
    def test_frontier_matches_sequential(self, name):
        caps = get_spec(name).capabilities
        if caps.randomized:
            pytest.skip("randomized scheduler: no sequential oracle")
        items = _trace()
        _, report = _run(name)
        assert report.summary["n_rejected_admission"] == 0  # queue ample
        engine = PlacementEngine(_cluster(), name)
        seq = {}
        for it in items:
            r = engine.place(it)
            seq[r.item_id] = (PLACED if r.ok else REJECTED, r.placement)
        for o in report.outcomes:
            assert (o.status, o.placement) == seq[o.item_id], o.item_id

    @pytest.mark.parametrize("name", BATCHED)
    def test_window_partitioning_invariance(self, name):
        """Different max_batch ⇒ different windows ⇒ same placements."""
        caps = get_spec(name).capabilities
        if caps.randomized:
            pytest.skip("randomized scheduler: no sequential oracle")
        _, small = _run(name, cfg=FrontierConfig(max_batch=3, max_wait_s=0.02))
        _, large = _run(name, cfg=FrontierConfig(max_batch=32, max_wait_s=0.2))
        by_id = lambda rep: {
            o.item_id: (o.status, o.placement) for o in rep.outcomes
        }
        assert by_id(small) == by_id(large)


class TestBackpressure:
    CFG = FrontierConfig(max_batch=4, max_wait_s=0.01, queue_capacity=4)

    def _overload(self):
        return _run(
            "greedy_least_used",
            events=arrival_events(_trace(n_items=60, rate=5000.0)),
            cfg=self.CFG,
        )

    def test_no_silent_drops(self):
        _, report = self._overload()
        s = report.summary
        assert s["n_offered"] == 60
        assert len(report.outcomes) == 60
        assert (
            s["n_offered"]
            == s["n_placed"] + s["n_rejected_placement"] + s["n_rejected_admission"]
        )
        assert {o.item_id for o in report.outcomes} == set(range(60))

    def test_rejects_are_explicit(self):
        _, report = self._overload()
        rejected = [o for o in report.outcomes if o.status == ADMISSION_REJECT]
        assert rejected and len(rejected) == report.summary["n_rejected_admission"]
        for o in rejected:
            assert o.placement is None
            assert "queue full" in o.reason
            assert o.decide_t == o.submit_t  # bounced at the door

    def test_depth_bounded_and_deterministic(self):
        _, a = self._overload()
        _, b = self._overload()
        assert a.summary["max_queue_depth"] <= self.CFG.queue_capacity
        assert a.summary["max_queue_depth"] == b.summary["max_queue_depth"]
        assert a.summary["n_rejected_admission"] == b.summary["n_rejected_admission"]
        assert a.digest() == b.digest()

    def test_no_rejects_when_capacity_suffices(self):
        _, report = _run("greedy_least_used")
        assert report.summary["n_rejected_admission"] == 0
        assert report.summary["reject_count"] == 0


class TestEpochConsistency:
    def test_epochs_monotonic_and_immutable(self):
        frontier, report = _run("drex_sc", events=_churn())
        history = frontier.epochs.history()
        assert len(history) >= 2
        ids = [e.epoch_id for e in history]
        seqs = [e.mutation_seq for e in history]
        assert ids == sorted(ids) and len(set(ids)) == len(ids)
        assert seqs == sorted(seqs)
        # NB: virtual_t is *not* monotonic across epochs — a window's
        # epoch is stamped at its completion time, so churn arriving
        # while that window is in flight publishes an earlier timestamp.
        # Ordering guarantees live in epoch_id / mutation_seq.
        for e in history:
            with pytest.raises(ValueError):
                e.cluster.used_mb[0] = 123.0
            with pytest.raises(ValueError):
                e.cluster.alive[0] = False

    def test_latest_epoch_matches_live_view(self):
        frontier, _ = _run("drex_sc")
        epoch = frontier.read()
        live = frontier.engine.cluster
        assert np.array_equal(epoch.cluster.used_mb, live.used_mb)
        assert np.array_equal(epoch.cluster.alive, live.alive)
        assert epoch.mutation_seq == frontier.engine.mutation_seq

    def test_snapshots_decoupled_from_live_mutations(self):
        frontier, _ = _run("greedy_least_used")
        epoch = frontier.read()
        before = epoch.cluster.used_mb.copy()
        live = frontier.engine.cluster
        # Published epochs share buffers copy-on-write: a direct
        # out-of-band write to the live arrays must fault loudly ...
        with pytest.raises(ValueError):
            live.used_mb[0] += 999.0
        # ... while API-routed mutation copies first, leaving every
        # previously published epoch untouched.
        live.writable("used_mb")[0] += 999.0
        assert np.array_equal(epoch.cluster.used_mb, before)
        assert live.used_mb[0] == before[0] + 999.0

    def test_epochs_bracket_failures(self):
        """Reads never see a half-applied failure: some published epoch
        still shows node 7 alive, and every epoch after the failure
        (never healed) shows it dead with zero usage."""
        frontier, _ = _run("drex_sc", events=_churn())
        history = frontier.epochs.history()
        dead = [e for e in history if not e.cluster.alive[7]]
        assert dead, "failure epoch was not published"
        for e in dead:
            assert e.cluster.used_mb[7] == 0.0
        assert not frontier.engine.cluster.alive[7]

    def test_epoch_ring_bounded(self):
        cfg = dataclasses.replace(_CFG, epoch_history=4)
        frontier, _ = _run("greedy_least_used", cfg=cfg)
        assert len(frontier.epochs.history()) <= 4


class TestChurnRepairPlane:
    def test_failed_node_evacuated(self):
        """After a failure with no heal, no stored item still maps to the
        dead node — every affected item was repaired or counted lost."""
        events = arrival_events(_trace()) + churn_events(
            failure_schedule=((0.1, 7),), unit="seconds"
        )
        frontier, report = _run("drex_sc", events=events)
        for si in frontier.stored.values():
            assert 7 not in si.placement.node_ids
        s = report.summary
        assert s["n_failures"] == 1
        assert s["n_repairs"] + s["n_items_lost"] >= 0
        assert s["n_placed"] == len(frontier.stored) + s["n_items_lost"]

    def test_join_expands_cluster(self):
        frontier, report = _run("greedy_least_used", events=_churn())
        assert frontier.engine.cluster.n_nodes == 13
        assert report.summary["n_joins"] == 1
        assert report.summary["n_heals"] == 1


class TestInteractiveApi:
    """submit/advance/drain piecemeal — the non-run() driving mode."""

    def test_manual_drive(self):
        engine = PlacementEngine(_cluster(), "greedy_least_used")
        frontier = PlacementFrontier(engine, _CFG)
        epoch0 = frontier.read()
        for i, it in enumerate(_trace(n_items=6, rate=1000.0)):
            frontier.submit(it, float(it.arrival_time))
        assert frontier.queue.depth == 6
        assert frontier.read().epoch_id == epoch0.epoch_id  # no flush yet
        frontier.drain()
        assert frontier.queue.depth == 0
        assert len(frontier.outcomes) == 6
        assert frontier.read().epoch_id > epoch0.epoch_id

    def test_requires_auto_commit(self):
        engine = PlacementEngine(_cluster(), "greedy_least_used", auto_commit=False)
        with pytest.raises(ValueError, match="auto_commit"):
            PlacementFrontier(engine, _CFG)
