"""Behavioural tests for the four D-Rex schedulers and SOTA baselines (§4, §5.2)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dep (requirements-dev.txt); keep invariants running
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import (
    ClusterView,
    DataItem,
    ECTimeModel,
    SCHEDULER_NAMES,
    StorageNode,
    create_scheduler,
)
from repro.core.reliability import pr_avail
from repro.storage import make_node_set


def mk_item(size_mb=100.0, rt=0.9, dt=365.0, iid=0):
    return DataItem(
        item_id=iid,
        size_mb=size_mb,
        arrival_time=0.0,
        delta_t_days=dt,
        reliability_target=rt,
    )


def mk_cluster(caps, bw_w=None, bw_r=None, afr=None):
    n = len(caps)
    nodes = [
        StorageNode(
            node_id=i,
            capacity_mb=caps[i],
            write_bw=(bw_w or [200.0] * n)[i],
            read_bw=(bw_r or [250.0] * n)[i],
            annual_failure_rate=(afr or [0.01] * n)[i],
        )
        for i in range(n)
    ]
    return ClusterView.from_nodes(nodes)


ALL_SCHEDULERS = [n for n in SCHEDULER_NAMES if n != "random_spread"]


class TestInvariants:
    """Problem-1 write-success constraints hold for every scheduler."""

    @pytest.mark.parametrize("name", ALL_SCHEDULERS)
    def test_placement_satisfies_problem1(self, name):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        item = mk_item(size_mb=500.0, rt=0.95)
        d = create_scheduler(name).place(item, cluster)
        assert d.placement is not None, d.reason
        pl = d.placement
        ids = list(pl.node_ids)
        chunk = pl.chunk_size_mb(item.size_mb)
        # distinct nodes, capacity, reliability (Eq. 3)
        assert len(set(ids)) == pl.n
        assert np.all(cluster.free_mb[ids] >= chunk - 1e-9)
        fp = cluster.fail_probs(item.delta_t_days)[ids]
        assert pr_avail(fp, pl.p) >= item.reliability_target

    @pytest.mark.parametrize("name", ALL_SCHEDULERS)
    def test_no_mutation_of_cluster(self, name):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        before = cluster.used_mb.copy()
        create_scheduler(name).place(mk_item(), cluster)
        np.testing.assert_array_equal(before, cluster.used_mb)

    @pytest.mark.parametrize("name", ALL_SCHEDULERS)
    def test_impossible_target_fails_gracefully(self, name):
        # Nodes that essentially always fail within the window.
        cluster = mk_cluster([1e6] * 5, afr=[500.0] * 5)
        d = create_scheduler(name).place(mk_item(rt=0.999999), cluster)
        assert d.placement is None

    @pytest.mark.parametrize("name", ALL_SCHEDULERS)
    def test_capacity_exhaustion_fails_gracefully(self, name):
        cluster = mk_cluster([10.0] * 10)  # 10 MB nodes
        d = create_scheduler(name).place(mk_item(size_mb=1e6), cluster)
        assert d.placement is None

    @pytest.mark.parametrize("name", ALL_SCHEDULERS)
    def test_dead_nodes_never_used(self, name):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        for dead in (0, 3, 9):
            cluster.fail_node(dead)
        d = create_scheduler(name).place(mk_item(), cluster)
        if d.placement is not None:
            assert not ({0, 3, 9} & set(d.placement.node_ids))


class TestGreedyMinStorage:
    def test_prefers_large_k(self):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        d = create_scheduler("greedy_min_storage").place(mk_item(rt=0.9), cluster)
        # With reliable nodes the min-overhead solution uses many chunks.
        assert d.placement.k >= 7

    def test_slides_to_slower_nodes_when_fast_ones_full(self):
        # Fast nodes have no room: mapping must use the slow ones.
        caps = [100.0, 100.0, 1e6, 1e6, 1e6, 1e6, 1e6]
        bw = [1000.0, 900.0, 100.0, 100.0, 100.0, 100.0, 100.0]
        cluster = mk_cluster(caps, bw_w=bw, bw_r=bw)
        d = create_scheduler("greedy_min_storage").place(mk_item(size_mb=5000.0), cluster)
        assert d.placement is not None
        assert not ({0, 1} & set(d.placement.node_ids))


class TestGreedyLeastUsed:
    def test_minimizes_n(self):
        cluster = ClusterView.from_nodes(make_node_set("most_reliable", 0.001))
        d = create_scheduler("greedy_least_used").place(mk_item(rt=0.9), cluster)
        assert d.placement.n == 3  # smallest N with K>=2, P>=1
        assert d.placement.k == 2

    def test_targets_least_used_nodes(self):
        caps = [1e6] * 6
        cluster = mk_cluster(caps)
        cluster.used_mb[:] = [9e5, 8e5, 7e5, 0.0, 1e5, 2e5]
        d = create_scheduler("greedy_least_used").place(mk_item(), cluster)
        assert set(d.placement.node_ids) == {3, 4, 5}


class TestDRexLB:
    def test_smallest_feasible_parity(self):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        d = create_scheduler("drex_lb").place(mk_item(rt=0.9), cluster)
        assert d.placement.p == 1
        assert d.placement.k >= 2  # Alg. 1 line 6

    def test_balances_toward_empty_nodes(self):
        caps = [1e6] * 5
        cluster = mk_cluster(caps)
        cluster.used_mb[:] = [5e5, 5e5, 0.0, 0.0, 0.0]
        d = create_scheduler("drex_lb").place(mk_item(size_mb=1000.0), cluster)
        # Mapping is a prefix of the free-space ordering: emptiest first.
        assert set(d.placement.node_ids) >= {2, 3, 4}


class TestDRexSC:
    def test_returns_pareto_scored_choice(self):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        d = create_scheduler("drex_sc").place(mk_item(rt=0.9), cluster)
        assert d.placement is not None
        assert 1 <= d.placement.k <= 9
        assert d.candidates_considered > 10

    def test_mapping_cap_respected(self):
        sched = create_scheduler("drex_sc")
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        d = sched.place(mk_item(), cluster)
        assert d.candidates_considered <= sched.MAX_MAPPINGS


class TestStaticAndDAOS:
    def test_static_ec_fixed_parameters(self):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        for k, p in [(3, 2), (4, 2), (6, 3)]:
            d = create_scheduler(f"ec({k},{p})").place(mk_item(), cluster)
            assert (d.placement.k, d.placement.p) == (k, p)

    def test_static_ec_picks_fastest_nodes(self):
        bw = [100.0, 200.0, 300.0, 400.0, 500.0, 600.0, 700.0]
        cluster = mk_cluster([1e6] * 7, bw_w=bw, bw_r=bw)
        d = create_scheduler("ec(3,2)").place(mk_item(), cluster)
        assert set(d.placement.node_ids) == {2, 3, 4, 5, 6}

    def test_static_ec_fails_on_unreachable_target(self):
        cluster = mk_cluster([1e6] * 10, afr=[3.0] * 10)  # very unreliable
        d = create_scheduler("ec(3,2)").place(mk_item(rt=0.9999999, dt=365.0), cluster)
        assert d.placement is None

    def test_daos_lowest_overhead_config_first(self):
        cluster = ClusterView.from_nodes(make_node_set("most_reliable", 0.001))
        d = create_scheduler("daos").place(mk_item(rt=0.9), cluster)
        assert (d.placement.k, d.placement.p) == (8, 1)  # 1.125x overhead

    def test_daos_escalates_to_replication(self):
        # Unreliable nodes + extreme target: only 6x replication survives.
        cluster = mk_cluster([1e6] * 10, afr=[1.5] * 10)
        d = create_scheduler("daos").place(mk_item(rt=0.99999, dt=30.0), cluster)
        if d.placement is not None:
            assert d.placement.k == 1  # replication config


class TestECTimeModel:
    def test_decode_grows_with_k(self):
        tm = ECTimeModel()
        ts = [tm.t_decode(k, 400.0) for k in range(2, 17)]
        assert all(b > a for a, b in zip(ts, ts[1:]))

    def test_encode_grows_with_parity(self):
        tm = ECTimeModel()
        assert tm.t_encode(6, 4, 400.0) > tm.t_encode(5, 4, 400.0)

    def test_replication_free(self):
        tm = ECTimeModel()
        assert tm.t_encode(4, 1, 400.0) == pytest.approx(tm.e0)
        assert tm.t_decode(1, 400.0) == pytest.approx(tm.d0)

    def test_vectorized_variants_match_scalar(self):
        tm = ECTimeModel()
        ns = np.array([2, 5, 8, 9, 3])
        ks = np.array([1, 4, 6, 8, 2])
        enc = tm.t_encode_many(ns, ks, 117.0)
        dec = tm.t_decode_many(ks, 117.0)
        for i in range(len(ns)):
            assert enc[i] == tm.t_encode(int(ns[i]), int(ks[i]), 117.0)
            assert dec[i] == tm.t_decode(int(ks[i]), 117.0)


@given(
    size=st.floats(1.0, 5000.0),
    rt=st.floats(0.5, 0.9999999),
    dt=st.floats(1.0, 3650.0),
    name=st.sampled_from(ALL_SCHEDULERS),
)
@settings(max_examples=80, deadline=None)
def test_property_any_returned_placement_is_valid(size, rt, dt, name):
    """For any item parameters, a returned placement always satisfies the
    reliability constraint and capacity (Problem 1)."""
    cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
    item = DataItem(0, size, 0.0, dt, rt)
    d = create_scheduler(name).place(item, cluster)
    if d.placement is None:
        return
    pl = d.placement
    ids = list(pl.node_ids)
    chunk = pl.chunk_size_mb(size)
    assert np.all(cluster.free_mb[ids] >= chunk - 1e-9)
    fp = cluster.fail_probs(dt)[ids]
    assert pr_avail(fp, pl.p) >= rt - 1e-12
