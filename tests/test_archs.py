"""Per-architecture smoke tests (reduced configs, CPU, deliverable (f)).

Each assigned architecture instantiates a same-family reduced config and
runs one forward/train step asserting output shapes and no NaNs, plus the
decode==forward consistency invariant that guards the serving path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, all_cells, cell_supported, get_config, input_specs
from repro.models import (
    decode_step,
    forward,
    init_params,
    init_serve_state,
    loss_fn,
    param_axes,
    prefill,
)

# Per-architecture forward/train smoke sweeps: full lane only (deselect
# via -m "not slow").
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _batch(cfg, b=2, t=16):
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (b, t), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, t), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["frames"] = (
            jax.random.normal(key, (2, cfg.encoder.n_frames, cfg.d_model)) * 0.1
        ).astype(cfg.dt)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch, rng):
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, rng)
        batch = _batch(cfg)
        logits, aux = forward(params, batch["tokens"], cfg, batch.get("frames"))
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert logits.dtype == jnp.float32
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_decreases_loss(self, arch, rng):
        """One SGD step on a repeated batch must reduce the loss."""
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, rng)
        batch = _batch(cfg)

        @jax.jit
        def step(p):
            (l, m), g = jax.value_and_grad(lambda q: loss_fn(q, batch, cfg), has_aux=True)(p)
            p2 = jax.tree.map(lambda w, gw: w - 0.5 * gw.astype(w.dtype), p, g)
            return l, p2

        l0, params = step(params)
        l1, _ = step(params)
        assert bool(jnp.isfinite(l0)) and bool(jnp.isfinite(l1))
        assert float(l1) < float(l0), (float(l0), float(l1))

    def test_param_axes_structure_matches(self, arch, rng):
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, rng)
        axes = param_axes(cfg)
        pl = jax.tree.structure(params)
        al = jax.tree.structure(
            axes,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
        assert pl == al
        # every leaf's axes tuple length == its rank
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(
            axes,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
        for p, a in zip(flat_p, flat_a):
            assert len(a) == p.ndim, (p.shape, a)

    def test_decode_matches_forward(self, arch, rng):
        cfg = get_config(arch, smoke=True)
        if cfg.moe:  # avoid capacity-drop nondeterminism in the comparison
            cfg = cfg.with_(
                moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
            )
        params = init_params(cfg, rng)
        b, t = 2, 13  # exceeds the smoke local-attention window (ring wrap)
        key = jax.random.PRNGKey(2)
        toks = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
        frames = None
        if cfg.is_encdec:
            frames = (
                jax.random.normal(key, (b, cfg.encoder.n_frames, cfg.d_model)) * 0.1
            ).astype(cfg.dt)
        full, _ = forward(params, toks, cfg, frames)
        state = init_serve_state(cfg, b, t)
        if cfg.is_encdec:
            from repro.models.layers import encode_cross_kv
            from repro.models.model import _encode

            enc = _encode(params, frames, cfg)
            state["cross_kv"] = jax.vmap(
                lambda lp: encode_cross_kv(lp["xattn"], enc, cfg)
            )(params["layers"])
        dec = jax.jit(lambda p, tk, pos, s: decode_step(p, tk, pos, s, cfg))
        err = 0.0
        for i in range(t):
            lg, state = dec(params, toks[:, i : i + 1], jnp.int32(i), state)
            err = max(err, float(jnp.abs(lg - full[:, i, :]).max()))
        assert err < 1e-3, err

    def test_prefill_state_matches_forward_logits(self, arch, rng):
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, rng)
        b, t = 2, 12
        toks = jax.random.randint(jax.random.PRNGKey(3), (b, t), 0, cfg.vocab_size)
        frames = None
        if cfg.is_encdec:
            frames = (
                jax.random.normal(jax.random.PRNGKey(3), (b, cfg.encoder.n_frames, cfg.d_model))
                * 0.1
            ).astype(cfg.dt)
        last, state = prefill(params, toks, cfg, frames)
        full, _ = forward(params, toks, cfg, frames)
        assert float(jnp.abs(last - full[:, -1, :]).max()) < 2e-2

    def test_input_specs_cover_every_supported_shape(self, arch, rng):
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_supported(cfg, shape)
            if not ok:
                assert "long_500k" in shape and not cfg.sub_quadratic
                continue
            specs = input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves, (arch, shape)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_grid_is_40_cells_with_documented_skips():
    cells = all_cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    # exactly the 8 full-attention archs skip long_500k
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    runnable = {(a, s) for a, s, ok, _ in cells if ok}
    assert ("rwkv6_1_6b", "long_500k") in runnable
    assert ("recurrentgemma_9b", "long_500k") in runnable


def test_param_counts_match_published_sizes():
    """Analytic parameter counts are within tolerance of the published
    model sizes (sanity that configs encode the right architectures)."""
    expect = {
        "qwen3_8b": (8.2e9, 0.15),
        "yi_6b": (6.1e9, 0.15),
        "nemotron_4_15b": (15.6e9, 0.20),
        "nemotron_4_340b": (340e9, 0.15),
        "qwen3_moe_30b_a3b": (30.5e9, 0.20),
        "qwen2_moe_a2_7b": (14.3e9, 0.30),
        "rwkv6_1_6b": (1.6e9, 0.30),
        "chameleon_34b": (34e9, 0.15),
        "recurrentgemma_9b": (9e9, 0.35),
    }
    for arch, (want, tol) in expect.items():
        got = get_config(arch).n_params()
        assert abs(got - want) / want < tol, (arch, got, want)


def test_moe_active_params_below_total():
    cfg = get_config("qwen3_moe_30b_a3b")
    assert cfg.n_active_params() < 0.25 * cfg.n_params()
