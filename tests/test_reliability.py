"""Unit + property tests for the reliability model (paper §3.1)."""

import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dep (requirements-dev.txt); keep invariants running
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.reliability import (
    batch_pr_avail_exact,
    meets_target,
    min_parity_for_target,
    ParityFrontier,
    poisson_binomial_cdf,
    pr_avail,
    pr_failure,
)


class TestPrFailure:
    def test_eq1_closed_form(self):
        # lambda=1.0/yr over half a year: 1 - e^-0.5
        assert pr_failure(1.0, 0.5) == pytest.approx(1.0 - math.exp(-0.5))

    def test_zero_rate_never_fails(self):
        assert pr_failure(0.0, 10.0) == 0.0

    def test_zero_window_never_fails(self):
        assert pr_failure(5.0, 0.0) == 0.0

    def test_vectorized(self):
        lam = np.array([0.01, 0.1, 1.0])
        out = pr_failure(lam, 1.0)
        assert out.shape == (3,)
        assert np.all(np.diff(out) > 0)  # monotone in rate

    def test_rejects_negative(self):
        with pytest.raises(ValueError):
            pr_failure(-1.0, 1.0)
        with pytest.raises(ValueError):
            pr_failure(1.0, -1.0)


def _brute_force_cdf(probs, k):
    """Enumerate all 2^n outcomes — ground truth for small n."""
    n = len(probs)
    total = 0.0
    for mask in range(2**n):
        nfail = bin(mask).count("1")
        if nfail > k:
            continue
        pr = 1.0
        for i in range(n):
            pr *= probs[i] if (mask >> i) & 1 else 1.0 - probs[i]
        total += pr
    return total


class TestPoissonBinomial:
    def test_matches_brute_force(self):
        probs = [0.1, 0.25, 0.03, 0.4, 0.07]
        for k in range(-1, 6):
            assert poisson_binomial_cdf(probs, k, "exact") == pytest.approx(
                _brute_force_cdf(probs, k), abs=1e-12
            )

    def test_binomial_special_case(self):
        # iid p -> Binomial CDF
        p, n, k = 0.2, 12, 3
        from math import comb

        want = sum(comb(n, j) * p**j * (1 - p) ** (n - j) for j in range(k + 1))
        assert poisson_binomial_cdf([p] * n, k, "exact") == pytest.approx(want)

    def test_bounds(self):
        probs = [0.5] * 8
        assert poisson_binomial_cdf(probs, -1) == 0.0
        assert poisson_binomial_cdf(probs, 8) == 1.0
        assert poisson_binomial_cdf(probs, 100) == 1.0

    def test_rna_close_to_exact(self):
        rng = np.random.default_rng(0)
        for _ in range(25):
            n = int(rng.integers(10, 120))
            probs = rng.uniform(0.001, 0.3, size=n)
            k = int(rng.integers(0, n))
            exact = poisson_binomial_cdf(probs, k, "exact")
            rna = poisson_binomial_cdf(probs, k, "rna")
            assert rna == pytest.approx(exact, abs=2e-2)

    @given(
        st.lists(st.floats(0.0, 1.0), min_size=1, max_size=10),
        st.integers(-1, 11),
    )
    @settings(max_examples=200, deadline=None)
    def test_property_matches_brute_force(self, probs, k):
        got = poisson_binomial_cdf(probs, k, "exact")
        want = _brute_force_cdf(probs, k)
        assert got == pytest.approx(want, abs=1e-9)

    @given(st.lists(st.floats(0.0, 0.99), min_size=2, max_size=20))
    @settings(max_examples=100, deadline=None)
    def test_property_monotone_in_k(self, probs):
        vals = [poisson_binomial_cdf(probs, k, "exact") for k in range(len(probs) + 1)]
        assert all(b >= a - 1e-12 for a, b in zip(vals, vals[1:]))
        assert vals[-1] == pytest.approx(1.0)

    @given(
        st.lists(st.floats(0.001, 0.5), min_size=2, max_size=12),
        st.integers(0, 5),
        st.floats(0.01, 0.3),
    )
    @settings(max_examples=100, deadline=None)
    def test_property_extra_parity_never_hurts(self, probs, k, bump):
        """Adding parity weakly increases availability; raising any node's
        failure probability weakly decreases it."""
        base = poisson_binomial_cdf(probs, k, "exact")
        assert poisson_binomial_cdf(probs, k + 1, "exact") >= base - 1e-12
        worse = list(probs)
        worse[0] = min(1.0, worse[0] + bump)
        assert poisson_binomial_cdf(worse, k, "exact") <= base + 1e-12


class TestMinParity:
    def test_matches_linear_scan(self):
        rng = np.random.default_rng(1)
        for _ in range(30):
            n = int(rng.integers(2, 20))
            probs = rng.uniform(0.0, 0.5, size=n)
            target = float(rng.uniform(0.5, 0.999999))
            got = min_parity_for_target(probs, target)
            want = None
            for p in range(n):
                if poisson_binomial_cdf(probs, p, "exact") >= target:
                    want = p
                    break
            assert got == want

    def test_impossible_target(self):
        # Nodes that always fail can never deliver any availability at P<N.
        assert min_parity_for_target([1.0, 1.0, 1.0], 0.99) is None

    def test_perfect_nodes(self):
        assert min_parity_for_target([0.0, 0.0, 0.0], 0.999999) == 0


class TestPrAvail:
    def test_figure2_example_semantics(self):
        """Paper Fig. 2: 3 data + 2 parity on 5 nodes survives <= 2 failures."""
        probs = [0.05] * 5
        avail = pr_avail(probs, 2)
        want = _brute_force_cdf(probs, 2)
        assert avail == pytest.approx(want)
        assert meets_target(probs, 2, 0.99)

    def test_replication_is_special_case(self):
        """Replication = K=1 with P copies: item lost iff all P+1 fail."""
        p = 0.1
        for copies in range(1, 5):
            avail = pr_avail([p] * (copies + 1), copies)
            assert avail == pytest.approx(1.0 - p ** (copies + 1))


def _brute_force_min_parity(probs, target):
    """Ground truth by 2^n enumeration: smallest P with Pr(X<=P) >= target,
    -1 if even P = n-1 is insufficient (the frontier's convention)."""
    n = len(probs)
    for p in range(n):
        if _brute_force_cdf(probs, p) >= target:
            return p
    return -1


class TestParityFrontierProperties:
    """Property tests for the frontier DP and its ``upto_many`` batch
    variant against brute-force Poisson-binomial enumeration (n <= 8)."""

    @given(
        probs=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=8),
        target=st.floats(0.5, 0.9999999),
    )
    @settings(max_examples=60, deadline=None)
    def test_upto_matches_brute_force_per_prefix(self, probs, target):
        fr = ParityFrontier(np.array(probs), target).upto(len(probs))
        for m in range(1, len(probs) + 1):
            assert fr[m - 1] == _brute_force_min_parity(probs[:m], target)

    @given(
        probs=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=8),
        target=st.floats(0.5, 0.9999999),
    )
    @settings(max_examples=60, deadline=None)
    def test_upto_many_matches_brute_force_per_window(self, probs, target):
        out = ParityFrontier(np.array(probs), target).upto_many()
        L = len(probs)
        assert out.shape == (L, L)
        for s in range(L):
            for m in range(L):
                window = probs[s : s + m + 1]
                if s + m + 1 > L:
                    assert out[s, m] == -1  # out of range
                else:
                    assert out[s, m] == _brute_force_min_parity(window, target)

    @given(
        probs=st.lists(st.floats(0.0, 1.0), min_size=1, max_size=8),
        target=st.floats(0.5, 0.9999999),
        n_starts=st.integers(1, 8),
        nmax=st.integers(1, 8),
    )
    @settings(max_examples=40, deadline=None)
    def test_upto_many_bounds_agree_with_full_matrix(self, probs, target, n_starts, nmax):
        fr = ParityFrontier(np.array(probs), target)
        full = fr.upto_many()
        part = fr.upto_many(n_starts=n_starts, nmax=nmax)
        s = min(n_starts, len(probs))
        w = min(nmax, len(probs))
        np.testing.assert_array_equal(part, full[:s, :w])

    @pytest.mark.parametrize(
        "probs",
        [
            [0.0, 0.0, 0.0],
            [1.0, 1.0, 1.0],
            [0.0, 1.0, 0.0, 1.0],
            [0.3, 0.3, 0.3, 0.3],  # duplicates
            [1.0],
            [0.0],
        ],
    )
    @pytest.mark.parametrize("target", [0.5, 0.99, 0.999999])
    def test_degenerate_probs_match_brute_force(self, probs, target):
        fr = ParityFrontier(np.array(probs), target)
        out = fr.upto_many()
        L = len(probs)
        for s in range(L):
            for m in range(L - s):
                assert out[s, m] == _brute_force_min_parity(
                    probs[s : s + m + 1], target
                )
        # Row 0 of upto_many is exactly upto's prefix frontier.
        np.testing.assert_array_equal(out[0, :L], fr.upto(L))

    def test_upto_many_row_zero_equals_upto_random(self):
        rng = np.random.default_rng(17)
        for _ in range(10):
            n = int(rng.integers(2, 12))
            probs = rng.uniform(0.0, 1.0, size=n)
            t = float(rng.uniform(0.5, 0.99999))
            fr = ParityFrontier(probs, t)
            np.testing.assert_array_equal(fr.upto_many()[0], fr.upto(n))

    def test_upto_many_empty_frontier(self):
        out = ParityFrontier(np.array([]), 0.9).upto_many()
        assert out.shape == (0, 0)


class TestBatchJax:
    def test_matches_scalar(self):
        rng = np.random.default_rng(2)
        mats = rng.uniform(0.0, 0.4, size=(16, 9))
        out = np.asarray(batch_pr_avail_exact(mats, 2))
        for i in range(16):
            want = poisson_binomial_cdf(mats[i], 2, "exact")
            assert out[i] == pytest.approx(want, abs=1e-5)

    def test_padding_with_zero_prob_is_identity(self):
        base = np.array([[0.1, 0.2, 0.3]])
        padded = np.array([[0.1, 0.2, 0.3, 0.0, 0.0]])
        a = float(batch_pr_avail_exact(base, 1)[0])
        b = float(batch_pr_avail_exact(padded, 1)[0])
        assert a == pytest.approx(b, abs=1e-6)
