"""EC-protected checkpointing tests: save/restore, node failures, repair,
async path, GC, trainer integration, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointPolicy, DRexCheckpointer, StorageFabric
from repro.configs import get_config
from repro.core import create_scheduler
from repro.data import DataConfig
from repro.launch import make_local_mesh
from repro.optim import AdamWConfig
from repro.storage import make_node_set
from repro.train import Trainer, TrainerConfig, init_train_state

# checkpoint save/restore e2e: full lane only (deselect via -m "not slow").
pytestmark = pytest.mark.slow


def small_fabric(scale=1e-5):
    return StorageFabric(make_node_set("most_used", capacity_scale=scale))


def tiny_state(arch="yi_6b"):
    cfg = get_config(arch, smoke=True)
    return cfg, init_train_state(cfg, jax.random.PRNGKey(0))


def states_equal(a, b) -> bool:
    return all(
        (x is None and y is None) or np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


class TestSaveRestore:
    @pytest.mark.parametrize("sched", ["drex_sc", "drex_lb", "greedy_min_storage", "greedy_least_used"])
    def test_roundtrip_all_schedulers(self, sched):
        cfg, state = tiny_state()
        ck = DRexCheckpointer(small_fabric(), sched, CheckpointPolicy(item_mb=0.25))
        ck.save(state, 1)
        restored, step = ck.restore_latest(state)
        assert step == 1
        assert states_equal(state, restored)

    def test_restore_after_p_failures(self):
        cfg, state = tiny_state()
        fabric = small_fabric()
        ck = DRexCheckpointer(fabric, "drex_sc", CheckpointPolicy(item_mb=0.25, reliability_target=0.999))
        ck.save(state, 5)
        fabric.fail_node(1)
        restored, _ = ck.restore_latest(state)
        assert states_equal(state, restored)

    def test_unrecoverable_when_too_many_failures(self):
        cfg, state = tiny_state()
        fabric = small_fabric()
        ck = DRexCheckpointer(fabric, "greedy_least_used", CheckpointPolicy(item_mb=0.25))
        ck.save(state, 5)
        for n in range(9):
            fabric.fail_node(n)
        with pytest.raises(IOError):
            ck.restore(5, state)

    def test_storage_overhead_below_replication(self):
        """EC beats the 3x replication of HDFS-style systems (paper §1)."""
        cfg, state = tiny_state()
        ck = DRexCheckpointer(small_fabric(), "drex_sc", CheckpointPolicy(item_mb=0.25))
        ck.save(state, 1)
        overhead = ck.stats["bytes_stored"] / ck.stats["bytes_raw"]
        assert 1.0 < overhead < 2.0

    def test_async_save(self):
        cfg, state = tiny_state()
        ck = DRexCheckpointer(small_fabric(), "drex_lb", CheckpointPolicy(item_mb=0.25))
        fut = ck.save_async(state, 7)
        man = fut.result(timeout=120)
        assert man["step"] == 7
        restored, step = ck.restore_latest(state)
        assert step == 7 and states_equal(state, restored)

    def test_gc_keeps_last_k(self):
        cfg, state = tiny_state()
        fabric = small_fabric()
        ck = DRexCheckpointer(fabric, "drex_lb", CheckpointPolicy(item_mb=0.25, keep_last=2))
        for s in (1, 2, 3):
            ck.save(state, s)
        assert sorted(ck._manifests) == [2, 3]
        # bytes for step 1 were actually deleted from the fabric
        used = fabric.cluster.used_mb.sum()
        ck.save(state, 4)
        assert fabric.cluster.used_mb.sum() == pytest.approx(used, rel=0.01)


class TestRepair:
    def test_repair_restores_reliability(self):
        cfg, state = tiny_state()
        fabric = small_fabric()
        ck = DRexCheckpointer(fabric, "drex_sc", CheckpointPolicy(item_mb=0.25, reliability_target=0.999))
        ck.save(state, 1)
        fabric.fail_node(0)
        degraded = min(ck.group_reliability())
        n = ck.repair()
        assert n > 0
        assert min(ck.group_reliability()) >= degraded
        restored, _ = ck.restore_latest(state)
        assert states_equal(state, restored)

    def test_repair_noop_when_healthy(self):
        cfg, state = tiny_state()
        ck = DRexCheckpointer(small_fabric(), "drex_sc", CheckpointPolicy(item_mb=0.25))
        ck.save(state, 1)
        assert ck.repair() == 0

    def test_repair_raises_instead_of_silently_under_repairing(self):
        """Regression: with fewer eligible live nodes than missing chunks
        the old ``zip(missing, live)`` truncated silently, leaving groups
        degraded with no error.  A 5-node fabric and EC(3,2) puts every
        group on all 5 nodes; after one failure there are zero candidate
        nodes, so strict repair must raise (and must not partially
        re-map), while strict=False reports 0 chunks rebuilt."""
        cfg, state = tiny_state()
        fabric = StorageFabric(make_node_set("most_used", capacity_scale=1e-5)[:5])
        ck = DRexCheckpointer(
            fabric, "ec(3,2)",
            CheckpointPolicy(item_mb=0.25, reliability_target=0.9),
        )
        ck.save(state, 1)
        node_ids_before = [
            tuple(gd["node_ids"])
            for meta in ck._manifests[1]["leaves"] if meta is not None
            for gd in meta["groups"]
        ]
        fabric.fail_node(0)
        with pytest.raises(IOError, match="degraded"):
            ck.repair()
        assert ck.repair(strict=False) == 0
        # No partial re-mapping happened behind the error.
        node_ids_after = [
            tuple(gd["node_ids"])
            for meta in ck._manifests[1]["leaves"] if meta is not None
            for gd in meta["groups"]
        ]
        assert node_ids_after == node_ids_before
        # The data itself is still within P: restore works regardless.
        restored, _ = ck.restore_latest(state)
        assert states_equal(state, restored)

    def test_repaired_chunks_match_surviving_shape(self):
        """Regression: repair must re-encode the bucket-padded payload —
        otherwise replacement chunks differ in shape from survivors and
        restore fails on groups whose size is not a power of two."""
        cfg, state = tiny_state()
        fabric = small_fabric()
        ck = DRexCheckpointer(fabric, "drex_lb", CheckpointPolicy(item_mb=0.25))
        ck.save(state, 1)
        # Fail every node that holds row 0 of some group, so restore must
        # read at least one repaired chunk alongside surviving ones.
        first_row_nodes = {
            meta["groups"][0]["node_ids"][0]
            for meta in ck._manifests[1]["leaves"]
            if meta is not None
        }
        for n in list(first_row_nodes)[:2]:
            fabric.fail_node(n)
        assert ck.repair() > 0
        restored, _ = ck.restore_latest(state)
        assert states_equal(state, restored)


class TestPipeline:
    """The streaming encode→place→write pipeline must be observationally
    identical to the serial path — same placements, same restored bytes —
    and overlapping async saves must not deadlock or corrupt stats."""

    def _placements(self, ck, step):
        return [
            (gd["key"], gd["k"], gd["p"], tuple(gd["node_ids"]))
            for meta in ck._manifests[step]["leaves"] if meta is not None
            for gd in meta["groups"]
        ]

    @pytest.mark.parametrize("wave", [1, 3, 16])
    def test_pipelined_matches_serial(self, wave):
        cfg, state = tiny_state()
        cks = {}
        for workers in (0, 2):
            ck = DRexCheckpointer(
                small_fabric(), "drex_sc",
                CheckpointPolicy(item_mb=0.25, pipeline_workers=workers,
                                 encode_wave_groups=wave),
            )
            ck.save(state, 1)
            cks[workers] = ck
        assert self._placements(cks[0], 1) == self._placements(cks[2], 1)
        assert cks[0].stats["bytes_stored"] == cks[2].stats["bytes_stored"]
        restored, _ = cks[2].restore_latest(state)
        assert states_equal(state, restored)

    def test_pipelined_respects_link_bandwidth_fabric(self):
        """Puts through a bandwidth-simulating fabric still land intact."""
        cfg, state = tiny_state()
        fabric = StorageFabric(
            make_node_set("most_used", capacity_scale=1e-5), link_mbps=2000.0
        )
        ck = DRexCheckpointer(fabric, "drex_lb", CheckpointPolicy(
            item_mb=0.25, pipeline_workers=2, encode_wave_groups=2))
        ck.save(state, 1)
        restored, _ = ck.restore_latest(state)
        assert states_equal(state, restored)

    def test_overlapping_async_saves(self):
        """Two save_async calls in flight at once: both complete (drivers
        and I/O run on separate pools, so no cross-wait deadlock) and
        both checkpoints restore bit-exact."""
        cfg, state = tiny_state()
        ck = DRexCheckpointer(
            small_fabric(), "drex_lb",
            CheckpointPolicy(item_mb=0.25, keep_last=2, pipeline_workers=2,
                             encode_wave_groups=2),
        )
        futs = [ck.save_async(state, s) for s in (1, 2)]
        for f, step in zip(futs, (1, 2)):
            assert f.result(timeout=120)["step"] == step
        assert sorted(ck._manifests) == [1, 2]
        for step in (1, 2):
            assert states_equal(state, ck.restore(step, state))

    def test_mid_pipeline_put_failure_propagates(self):
        """A fabric error inside a background put wave surfaces as the
        save's exception (no hang, no orphaned futures), and the
        checkpointer stays usable for a later save."""
        cfg, state = tiny_state()
        fabric = StorageFabric(make_node_set("most_used", capacity_scale=1e-9))
        ck = DRexCheckpointer(fabric, "drex_sc", CheckpointPolicy(
            item_mb=0.25, pipeline_workers=2, encode_wave_groups=2))
        with pytest.raises(IOError):
            ck.save(state, 1)
        assert 1 not in ck._manifests
        # pools survive the failure: a save against a healthy fabric works
        ck2 = DRexCheckpointer(small_fabric(), "drex_sc",
                               CheckpointPolicy(item_mb=0.25))
        ck2.save(state, 2)
        restored, _ = ck2.restore_latest(state)
        assert states_equal(state, restored)


class TestKernelVsRefCodecs:
    def test_checkpoint_identical_between_codecs(self):
        cfg, state = tiny_state()
        for use_kernel in (True, False):
            ck = DRexCheckpointer(
                small_fabric(), "drex_lb",
                CheckpointPolicy(item_mb=0.25, use_kernel=use_kernel),
            )
            ck.save(state, 1)
            restored, _ = ck.restore_latest(state)
            assert states_equal(state, restored)


class TestTrainerIntegration:
    def test_checkpoint_restart_continues_training(self):
        """Kill-and-restart: restored run picks up at the saved step."""
        cfg = get_config("yi_6b", smoke=True)
        fabric = small_fabric()
        ck = DRexCheckpointer(fabric, "drex_sc", CheckpointPolicy(item_mb=0.25))
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

        t1 = Trainer(cfg, AdamWConfig(), TrainerConfig(steps=6, log_every=2, ckpt_every=3, async_ckpt=False),
                     data_cfg=dc, checkpointer=None, log_fn=lambda s, m: None)
        state = t1.init_or_restore()
        # wire the checkpointer manually so restore_latest has a like-state
        like = state

        class Adapter:
            def save(self, st, step):
                ck.save(st, step)

            def save_async(self, st, step):
                return ck.save_async(st, step)

            def restore_latest(self, _cfg):
                r = ck.restore_latest(like)
                return r

        t1.checkpointer = Adapter()
        state = t1.run(state)
        assert max(ck._manifests) == 6

        # a "failed" trainer restarts and resumes from step 6
        t2 = Trainer(cfg, AdamWConfig(), TrainerConfig(steps=8, log_every=2),
                     data_cfg=dc, checkpointer=Adapter(), log_fn=lambda s, m: None)
        resumed = t2.init_or_restore()
        assert t2.start_step == 6
        assert states_equal(resumed, state)

    def test_elastic_restore_onto_new_mesh(self):
        cfg = get_config("yi_6b", smoke=True)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        ck = DRexCheckpointer(small_fabric(), "drex_sc", CheckpointPolicy(item_mb=0.25))
        ck.save(state, 1)
        restored, _ = ck.restore_latest(state)
        from repro.train.step import reshard_state

        mesh = make_local_mesh(1, 1)  # "new" cluster shape
        resharded = reshard_state(restored, cfg, mesh)
        assert states_equal(state, resharded)
