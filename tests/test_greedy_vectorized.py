"""Golden-equivalence tests for the jitted greedy-scheduler kernels.

The scalar numpy paths (``GreedyMinStorage.place_scalar`` /
``GreedyLeastUsed.place_scalar``) are the reference oracles; the jax
kernels (``repro.core.greedy_kernel``) and the batched
``PlacementEngine.place_many`` scoring built on them must reproduce
their decisions bit-for-bit.  Styled after tests/test_sc_vectorized.py:
the ``GOLDEN`` placements below were captured from the scalar oracles at
the commit introducing the kernels, so *both* paths are pinned against
drift.  Coverage deliberately spans the kernels' three regimes:

* exact-DP feasibility (mappings of <= ``_AUTO_EXACT_LIMIT`` nodes),
* the RNA approximation regime (larger clusters, host-computed frontier
  rows via :func:`reliability.rna_parity_frontier`),
* the hybrid fallbacks (GreedyMinStorage's capacity-tight ``slow`` rows,
  GreedyLeastUsed's beyond-``SCAN_CAP`` first-feasible N).
"""

import numpy as np
import pytest

from repro.core import (
    BatchContext,
    ClusterView,
    DataItem,
    Placement,
    PlacementEngine,
    StorageNode,
    create_scheduler,
    get_spec,
)
from repro.core import greedy_kernel
from repro.core.reliability import (
    _AUTO_EXACT_LIMIT,
    min_parity_for_target,
    rna_parity_frontier,
)
from repro.storage import make_node_set, make_trace

needs_jax = pytest.mark.skipif(
    not greedy_kernel.kernel_available(), reason="jax unavailable"
)

GREEDY = ("greedy_min_storage", "greedy_least_used")


def forced_kernel_scheduler(name: str):
    """A greedy scheduler that uses the kernel at any cluster size (no
    numpy-dispatch crossover), so small test clusters hit the jit path."""
    sched = create_scheduler(name)
    sched.KERNEL_MIN_NODES = 0
    sched.KERNEL_MIN_NODES_BATCH = 0
    return sched


def scalar_scheduler(name: str):
    sched = create_scheduler(name)
    sched.use_kernel = False
    return sched


def random_cluster(
    seed: int,
    n: int,
    *,
    tight: bool = False,
    afr_hi: float = 0.2,
) -> ClusterView:
    rng = np.random.default_rng(seed)
    cap_lo, cap_hi, used_hi = (
        (50.0, 800.0, 300.0) if tight else (2e3, 1e5, 1e3)
    )
    nodes = [
        StorageNode(
            node_id=i,
            capacity_mb=float(rng.uniform(cap_lo, cap_hi)),
            write_bw=float(rng.uniform(50, 400)),
            read_bw=float(rng.uniform(50, 450)),
            annual_failure_rate=float(rng.uniform(0.001, afr_hi)),
            used_mb=float(rng.uniform(0.0, used_hi)),
        )
        for i in range(n)
    ]
    return ClusterView.from_nodes(nodes)


def random_items(seed: int, count: int = 6, size_hi: float = 500.0):
    rng = np.random.default_rng(seed + 1)
    targets = [0.9, 0.99, 0.999, 0.99999]
    return [
        DataItem(
            item_id=i,
            size_mb=float(rng.uniform(1.0, size_hi)),
            arrival_time=float(i),
            delta_t_days=float(rng.uniform(30.0, 730.0)),
            reliability_target=targets[int(rng.integers(len(targets)))],
        )
        for i in range(count)
    ]


# scheduler -> (nodeset, trace seed) -> (k, p, node_ids) of the first
# 8 meva items at RT 0.99, committed sequentially.  Captured from the
# scalar oracles; guards oracle and kernel against silent drift.
GOLDEN = {
        "greedy_min_storage": {
            ("most_used", 3): [
                (9, 1, (9, 3, 0, 2, 8, 1, 4, 5, 6, 7)),
            ] * 8,
            ("most_unreliable", 11): [
                (5, 2, (1, 0, 2, 3, 4, 7, 9)),
            ] * 8,
        },
        "greedy_least_used": {
            ("most_used", 3): [
                (2, 1, (3, 9, 0)),
                (2, 1, (3, 9, 2)),
                (2, 1, (3, 9, 8)),
                (2, 1, (3, 9, 2)),
                (2, 1, (3, 9, 2)),
                (2, 1, (3, 9, 8)),
                (2, 1, (3, 9, 2)),
                (2, 1, (3, 9, 2)),
            ],
            ("most_unreliable", 11): [
                (2, 2, (1, 0, 2, 3)),
                (2, 2, (1, 0, 2, 4)),
                (2, 2, (1, 0, 2, 3)),
                (2, 2, (1, 0, 2, 4)),
                (2, 2, (1, 0, 2, 4)),
                (2, 2, (1, 0, 2, 3)),
                (2, 2, (1, 0, 2, 4)),
                (2, 2, (1, 0, 2, 3)),
        ],
    },
}

GOLDEN_KEYS = [(name, key) for name in GREEDY for key in sorted(GOLDEN[name])]


class TestGoldenPlacements:
    """Pinned traces -> pinned placements, for both implementations."""

    def _run(self, nodeset, seed, scheduler):
        items = make_trace("meva", seed=seed, n_items=8, reliability=0.99)
        eng = PlacementEngine(make_node_set(nodeset, 0.001), scheduler)
        return [eng.place(it).placement for it in items]

    @pytest.mark.parametrize("name,key", GOLDEN_KEYS)
    def test_scalar_oracle_matches_golden(self, name, key):
        got = self._run(*key, scalar_scheduler(name))
        want = [Placement(k, p, ids) for k, p, ids in GOLDEN[name][key]]
        assert got == want

    @needs_jax
    @pytest.mark.parametrize("name,key", GOLDEN_KEYS)
    def test_kernel_matches_golden(self, name, key):
        got = self._run(*key, forced_kernel_scheduler(name))
        want = [Placement(k, p, ids) for k, p, ids in GOLDEN[name][key]]
        assert got == want

    @needs_jax
    @pytest.mark.parametrize("name,key", GOLDEN_KEYS)
    def test_batched_place_many_matches_golden(self, name, key):
        nodeset, seed = key
        items = make_trace("meva", seed=seed, n_items=8, reliability=0.99)
        eng = PlacementEngine(
            make_node_set(nodeset, 0.001), forced_kernel_scheduler(name)
        )
        got = [r.placement for r in eng.place_many(items)]
        want = [Placement(k, p, ids) for k, p, ids in GOLDEN[name][key]]
        assert got == want


@needs_jax
@pytest.mark.parametrize("name", GREEDY)
class TestKernelOracleEquivalence:
    """Kernel decisions == scalar oracle decisions, bit for bit."""

    def _assert_sequential_equal(self, name, cluster, items, ctx=None):
        a = create_scheduler(name)
        a.use_kernel = False
        b = forced_kernel_scheduler(name)
        for it in items:
            da = a.place(it, cluster)
            db = b.place(it, cluster, ctx=ctx)
            assert da.placement == db.placement, f"{name}: {it.item_id}"
            assert da.candidates_considered == db.candidates_considered
            assert da.reason == db.reason

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("n", [5, 10, 40])
    def test_exact_dp_regime(self, name, seed, n):
        self._assert_sequential_equal(
            name, random_cluster(seed * 100 + n, n), random_items(seed)
        )

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("n", [65, 80, 120])
    def test_rna_regime(self, name, seed, n):
        # Mappings larger than _AUTO_EXACT_LIMIT take the oracle's RNA
        # branch; the kernel must reproduce it via the host frontier row.
        assert n > _AUTO_EXACT_LIMIT
        self._assert_sequential_equal(
            name, random_cluster(seed * 100 + n, n), random_items(seed)
        )

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_capacity_tight_clusters(self, name, seed):
        # Tight free space engages GreedyMinStorage's capacity filter
        # (the kernel's host-finished ``slow`` rows) and GreedyLeastUsed's
        # capacity skips.
        self._assert_sequential_equal(
            name,
            random_cluster(seed, 40, tight=True),
            random_items(seed, size_hi=900.0),
        )

    def test_batched_place_many_matches_sequential_oracle(self, name):
        items = make_trace("sentinel2", seed=5, n_items=40, reliability=0.95)
        a = PlacementEngine(make_node_set("most_used", 0.001), scalar_scheduler(name))
        pa = [a.place(it).placement for it in items]
        b = PlacementEngine(
            make_node_set("most_used", 0.001), forced_kernel_scheduler(name)
        )
        pb = [r.placement for r in b.place_many(items)]
        assert pa == pb
        np.testing.assert_array_equal(a.cluster.used_mb, b.cluster.used_mb)

    def test_non_committing_batch_matches_oracle(self, name):
        # auto_commit=False: nothing invalidates, the whole queue is
        # scored against one snapshot (the Table-2 decision-cost protocol).
        items = make_trace("meva", seed=9, n_items=30, reliability=0.99)
        a = PlacementEngine(
            make_node_set("most_used", 0.001), scalar_scheduler(name),
            auto_commit=False,
        )
        pa = [a.place(it).placement for it in items]
        b = PlacementEngine(
            make_node_set("most_used", 0.001), forced_kernel_scheduler(name),
            auto_commit=False,
        )
        pb = [r.placement for r in b.place_many(items)]
        assert pa == pb

    def test_matches_oracle_with_dead_nodes(self, name):
        items = make_trace("meva", seed=13, n_items=20, reliability=0.9)
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        cluster.fail_node(0)
        cluster.fail_node(4)
        self._assert_sequential_equal(name, cluster, items)

    def test_rejections_match_oracle(self, name):
        # Nodes that essentially always fail within the window make any
        # meaningful target infeasible; a 1e12 MB item exhausts capacity.
        doomed = ClusterView.from_nodes(
            [StorageNode(i, 1e6, 200.0, 250.0, annual_failure_rate=500.0)
             for i in range(6)]
        )
        a = scalar_scheduler(name)
        b = forced_kernel_scheduler(name)
        for it in (
            DataItem(0, 1e12, 0.0, 365.0, 0.9),
            DataItem(1, 10.0, 0.0, 365.0, 0.999999),
        ):
            da, db = a.place(it, doomed), b.place(it, doomed)
            assert da.placement is None and db.placement is None
            assert da.reason == db.reason
            assert da.candidates_considered == db.candidates_considered

    def test_fewer_than_two_live_nodes(self, name):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001)[:2])
        cluster.fail_node(0)
        rec = forced_kernel_scheduler(name).place(
            DataItem(0, 1.0, 0.0, 365.0, 0.9), cluster
        )
        assert rec.placement is None
        assert "fewer than 2" in rec.reason

    def test_registry_declares_batch_scoring_capability(self, name):
        assert get_spec(name).capabilities.batch_scoring

    def test_place_batch_is_pure(self, name):
        # Scoring a batch must not mutate scheduler state or the cluster.
        sched = forced_kernel_scheduler(name)
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        items = make_trace("meva", seed=1, n_items=10, reliability=0.9)
        used0 = cluster.used_mb.copy()
        smin0 = sched.smin_mb
        sched.place_batch(items, cluster)
        np.testing.assert_array_equal(cluster.used_mb, used0)
        assert sched.smin_mb == smin0


@needs_jax
class TestHybridFallbacks:
    """The kernels' host-side completion paths, exercised explicitly."""

    def test_min_storage_slow_rows_trigger_and_match(self):
        # Tight capacity: the bw-sorted prefix does not fit the chunk, so
        # the kernel must flag rows slow and finish them on the host.
        cluster = random_cluster(7, 40, tight=True)
        items = random_items(7, count=8, size_hi=900.0)
        sched = forced_kernel_scheduler("greedy_min_storage")
        orig = greedy_kernel.min_storage_batch
        slow_rows = 0

        def spy(*args, **kwargs):
            nonlocal slow_rows
            out = orig(*args, **kwargs)
            slow_rows += int(out[1].sum())
            return out

        greedy_kernel.min_storage_batch = spy
        try:
            got = [sched.place(it, cluster).placement for it in items]
        finally:
            greedy_kernel.min_storage_batch = orig
        assert slow_rows > 0, "expected the capacity filter to engage"
        oracle = scalar_scheduler("greedy_min_storage")
        want = [oracle.place(it, cluster).placement for it in items]
        assert got == want

    def test_least_used_scan_cap_fallback(self):
        # Very unreliable nodes + a many-nines target push the first
        # feasible N beyond SCAN_CAP; the kernel falls back to the scalar
        # oracle for those items.
        cluster = random_cluster(0, 90, afr_hi=5.0)
        item = DataItem(0, 5.0, 0.0, 365.0, 0.9999999)
        sched = forced_kernel_scheduler("greedy_least_used")
        got = sched.place(item, cluster)
        want = scalar_scheduler("greedy_least_used").place(item, cluster)
        assert got.placement == want.placement
        assert got.candidates_considered == want.candidates_considered
        assert got.placement is not None
        assert got.placement.n > sched.SCAN_CAP

    def test_rna_frontier_row_matches_min_parity_for_target(self):
        rng = np.random.default_rng(11)
        for trial in range(8):
            L = int(rng.integers(_AUTO_EXACT_LIMIT + 1, 140))
            probs = rng.uniform(0.0, 0.6, size=L)
            if trial == 0:
                probs = np.zeros(L)  # degenerate var == 0 branch
            target = float(rng.choice([0.9, 0.999, 0.9999999]))
            row = greedy_kernel.rna_frontier_row(probs, target, L)
            assert np.all(row[: _AUTO_EXACT_LIMIT + 1] == -1)
            for n in range(_AUTO_EXACT_LIMIT + 1, L + 1):
                want = min_parity_for_target(probs[:n], target)
                assert row[n] == (-1 if want is None else want)

    def test_rna_parity_frontier_range_bounds(self):
        probs = np.full(70, 0.01)
        row = rna_parity_frontier(probs, 0.99, 65, 70)
        assert row.shape == (6,)
        for i, n in enumerate(range(65, 71)):
            want = min_parity_for_target(probs[:n], 0.99)
            assert row[i] == (-1 if want is None else want)


@needs_jax
class TestBatchContextRnaCache:
    def test_rna_rows_are_cached_and_exact(self):
        ctx = BatchContext()
        probs = np.random.default_rng(5).uniform(0.0, 0.3, size=100)
        a = ctx.rna_frontier(probs, 0.999, 100)
        misses0 = ctx.misses
        b = ctx.rna_frontier(probs, 0.999, 100)
        assert ctx.misses == misses0 and ctx.hits >= 1
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(
            a, greedy_kernel.rna_frontier_row(probs, 0.999, 100)
        )
