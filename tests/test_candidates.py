"""Property + unit pins for the incremental candidate order
(repro.core.candidates.CandidateTracker).

The contract: ``tracker.order(cluster)`` is **bit-identical** to a fresh
``Scheduler._live_sorted(cluster, cluster.free_mb)`` — live node ids,
free-space-descending, ascending-id tie-break — after *any* interleaving
of the cluster's mutation vocabulary (commit / release / fail / heal /
join / rollback), whether or not the matching observe hook was called.
Hooks only buy reuse; out-of-band mutations self-heal via the mirror.

The property tests drive random op tapes (hypothesis when installed,
the deterministic stub otherwise) including the adversarial corners:
equal-free-space tie churn and dead-node resurrection.
"""

import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dep (requirements-dev.txt); keep invariants running
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import ClusterView, StorageNode
from repro.core.candidates import CandidateTracker


def _node(i, cap, afr=0.01):
    return StorageNode(
        node_id=i,
        capacity_mb=float(cap),
        write_bw=150.0,
        read_bw=200.0,
        annual_failure_rate=float(afr),
    )


def _cluster(n=10, seed=3, equal_caps=False):
    rng = np.random.default_rng(seed)
    return ClusterView.from_nodes(
        [
            _node(i, 1e5 if equal_caps else rng.uniform(2e4, 2e5))
            for i in range(n)
        ]
    )


def _oracle(cluster):
    """Fresh ``_live_sorted(cluster, cluster.free_mb)``."""
    ids = cluster.live_ids()
    return ids[np.argsort(-cluster.free_mb[ids], kind="stable")]


def _placement(node_ids):
    return dataclasses.make_dataclass("P", ["node_ids"])(list(node_ids))


# Op tape vocabulary for the property tests.  Each opcode picks targets
# from the drawn rng so a single integer list encodes a full scenario.
_OPS = ("commit", "release", "fail", "heal", "join", "rollback", "oob")


def _apply(op, cluster, tracker, rng, snap):
    """Apply one op to the cluster, notifying the tracker through the
    same hook vocabulary the engine uses (or none, for rollback/oob)."""
    n = cluster.n_nodes
    if op == "commit":
        k = int(rng.integers(1, min(4, n) + 1))
        ids = sorted(int(x) for x in rng.choice(n, size=k, replace=False))
        chunk = float(rng.uniform(1.0, 500.0))
        cluster.charge(ids, chunk)
        tracker.observe_commit(ids, chunk, cluster)
    elif op == "release":
        k = int(rng.integers(1, min(4, n) + 1))
        ids = sorted(int(x) for x in rng.choice(n, size=k, replace=False))
        chunk = float(rng.uniform(1.0, 500.0))
        cluster.release(ids, chunk)
        tracker.observe_release(ids, chunk, cluster)
    elif op == "fail":
        live = cluster.live_ids()
        if live.size <= 2:
            return snap
        nid = int(rng.choice(live))
        cluster.fail_stop(nid)
        tracker.observe_churn("fail", [nid], cluster)
    elif op == "heal":
        dead = np.nonzero(~cluster.alive)[0]
        if dead.size == 0:
            return snap
        nid = int(rng.choice(dead))  # dead-node resurrection
        cluster.heal_node(nid)
        tracker.observe_churn("heal", [nid], cluster)
    elif op == "join":
        nid = cluster.add_node(_node(n, float(rng.uniform(2e4, 2e5))))
        tracker.observe_churn("join", [nid], cluster)
    elif op == "rollback":
        # out-of-band restore (engine.rollback's op): no hook exists;
        # the tracker must self-heal via the mirror mismatch
        cluster.restore(*snap) if snap else None
    elif op == "oob":
        # bare array write with no notification at all
        nid = int(rng.integers(0, n))
        cluster.writable("used_mb")[nid] = float(rng.uniform(0.0, 1e4))
    return (cluster.used_mb.copy(), cluster.alive.copy())


class TestOrderProperty:
    @settings(max_examples=25)
    @given(
        tape=st.lists(st.integers(0, len(_OPS) - 1), min_size=4, max_size=30),
        seed=st.integers(0, 10_000),
    )
    def test_random_interleavings_bit_identical(self, tape, seed):
        rng = np.random.default_rng(seed)
        cluster = _cluster(10, seed=seed % 97)
        tracker = CandidateTracker()
        m = 5
        snap = (cluster.used_mb.copy(), cluster.alive.copy())
        assert np.array_equal(tracker.order(cluster), _oracle(cluster))
        for code in tape:
            snap = _apply(_OPS[code], cluster, tracker, rng, snap)
            want = _oracle(cluster)
            assert np.array_equal(tracker.order(cluster), want)
            assert np.array_equal(tracker.topm(cluster, m), want[:m])

    @settings(max_examples=25)
    @given(
        tape=st.lists(st.integers(0, len(_OPS) - 1), min_size=4, max_size=30),
        seed=st.integers(0, 10_000),
    )
    def test_equal_capacity_tie_churn(self, tape, seed):
        """All capacities equal: every delta creates/destroys key ties,
        hammering the ascending-id tie-break on both the fast path's
        adjacency check and the splice's in-tie bisect."""
        rng = np.random.default_rng(seed)
        cluster = _cluster(8, seed=seed % 89, equal_caps=True)
        tracker = CandidateTracker()
        snap = (cluster.used_mb.copy(), cluster.alive.copy())
        for code in tape:
            snap = _apply(_OPS[code], cluster, tracker, rng, snap)
            assert np.array_equal(tracker.order(cluster), _oracle(cluster))

    @settings(max_examples=15)
    @given(seed=st.integers(0, 10_000))
    def test_query_between_every_op_vs_query_once(self, seed):
        """Querying after every op and querying only at the end must
        land on the same final order (splices commute with batching)."""
        rng1 = np.random.default_rng(seed)
        rng2 = np.random.default_rng(seed)
        c1, c2 = _cluster(9, seed=7), _cluster(9, seed=7)
        t1, t2 = CandidateTracker(), CandidateTracker()
        t1.order(c1), t2.order(c2)
        ops = ["commit", "fail", "commit", "heal", "join", "release", "commit"]
        for op in ops:
            _apply(op, c1, t1, rng1, None)
            t1.order(c1)  # query eagerly
            _apply(op, c2, t2, rng2, None)  # query only at the end
        assert np.array_equal(t1.order(c1), t2.order(c2))
        assert np.array_equal(t2.order(c2), _oracle(c2))


class TestTrackerMechanics:
    def test_fast_path_no_splice_no_rebuild(self):
        """A commit that provably cannot reorder (top node, less than its
        margin) must be absorbed in place: no splice, no rebuild."""
        cluster = _cluster(8)
        tr = CandidateTracker()
        first = tr.order(cluster)
        top, runner = int(first[0]), int(first[1])
        margin = float(cluster.free_mb[top] - cluster.free_mb[runner])
        cluster.charge([top], margin / 2)
        tr.observe_commit([top], margin / 2, cluster)
        assert np.array_equal(tr.order(cluster), _oracle(cluster))
        assert tr.rebuilds == 1 and tr.splices == 0 and tr.hits >= 1

    def test_reorder_served_by_splice_not_rebuild(self):
        """Pushing the top node below the runner-up violates adjacency:
        the next query splices — the argsort never reruns."""
        cluster = _cluster(8)
        tr = CandidateTracker()
        first = tr.order(cluster)
        top, runner = int(first[0]), int(first[1])
        delta = float(cluster.free_mb[top] - cluster.free_mb[runner]) + 1.0
        cluster.charge([top], delta)
        tr.observe_commit([top], delta, cluster)
        got = tr.order(cluster)
        assert np.array_equal(got, _oracle(cluster))
        assert int(got[0]) == runner
        assert tr.rebuilds == 1 and tr.splices == 1

    def test_join_grows_order(self):
        cluster = _cluster(6)
        tr = CandidateTracker()
        tr.order(cluster)
        nid = cluster.add_node(_node(6, 9e5))  # most-free newcomer
        tr.observe_churn("join", [nid], cluster)
        got = tr.order(cluster)
        assert np.array_equal(got, _oracle(cluster))
        assert int(got[0]) == nid
        assert tr.rebuilds == 1  # grown via splice, not argsort

    def test_fail_then_heal_round_trip(self):
        cluster = _cluster(6)
        tr = CandidateTracker()
        first = tr.order(cluster)
        victim = int(first[2])
        cluster.fail_stop(victim)
        tr.observe_churn("fail", [victim], cluster)
        assert victim not in tr.order(cluster)
        cluster.heal_node(victim)
        tr.observe_churn("heal", [victim], cluster)
        got = tr.order(cluster)
        assert victim in got
        assert np.array_equal(got, _oracle(cluster))
        assert tr.rebuilds == 1

    def test_out_of_band_write_self_heals(self):
        cluster = _cluster(6)
        tr = CandidateTracker()
        tr.order(cluster)
        cluster.writable("used_mb")[1] += 777.0  # never observed
        assert np.array_equal(tr.order(cluster), _oracle(cluster))
        assert tr.rebuilds == 2

    def test_unknown_churn_kind_invalidates(self):
        cluster = _cluster(6)
        tr = CandidateTracker()
        tr.order(cluster)
        tr.observe_churn("repartition", [0], cluster)
        assert tr._order is None
        assert np.array_equal(tr.order(cluster), _oracle(cluster))

    def test_hit_rate_reported(self):
        cluster = _cluster(6)
        tr = CandidateTracker()
        assert tr.hit_rate() == 0.0
        for _ in range(9):
            tr.order(cluster)
        assert tr.hit_rate() == pytest.approx(8 / 9)


class TestFailProbsCache:
    def _oracle(self, cluster, dt):
        from repro.core.reliability import pr_failure
        from repro.core.types import DAYS_PER_YEAR

        return np.asarray(
            pr_failure(cluster.afr, dt / DAYS_PER_YEAR), dtype=np.float64
        )

    def test_cached_vector_reused_and_exact(self):
        cluster = _cluster(8)
        a = cluster.fail_probs(30.0)
        b = cluster.fail_probs(30.0)
        assert a is b  # same object: no recompute
        assert np.array_equal(a, self._oracle(cluster, 30.0))
        with pytest.raises(ValueError):
            a[0] = 0.5  # published vectors are write-protected

    def test_afr_edit_recomputes_touched_entries_exactly(self):
        cluster = _cluster(8)
        before = cluster.fail_probs(30.0)
        cluster.writable("afr")[3] = 0.25
        after = cluster.fail_probs(30.0)
        assert after is not before
        assert np.array_equal(after, self._oracle(cluster, 30.0))
        # untouched entries keep their exact bits
        mask = np.ones(8, dtype=bool)
        mask[3] = False
        assert np.array_equal(after[mask], before[mask])

    def test_join_extends_cached_vectors(self):
        cluster = _cluster(8)
        before = cluster.fail_probs(30.0)
        cluster.add_node(_node(8, 5e4, afr=0.2))
        after = cluster.fail_probs(30.0)
        assert after.shape == (9,)
        assert np.array_equal(after[:8], before)
        assert np.array_equal(after, self._oracle(cluster, 30.0))

    def test_anchor_bound(self):
        cluster = _cluster(4)
        for k in range(3 * ClusterView._MAX_FP_ANCHORS):
            cluster.fail_probs(float(k + 1))
            assert len(cluster.__dict__["_fp_cache"]) <= ClusterView._MAX_FP_ANCHORS
