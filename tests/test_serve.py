"""Serving engine tests: prefill→decode cache replay continuity, greedy
determinism, throughput accounting."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_params
from repro.serve import ServeConfig, ServingEngine

# serving-engine e2e decode loops: full lane only (deselect via -m "not slow").
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-1.6b", "recurrentgemma-9b", "qwen3-8b"])
def test_generate_shapes_and_determinism(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=6))
    prompts = np.ones((2, 8), np.int32) * 3
    a = engine.generate(prompts)
    b = ServingEngine(cfg, params, ServeConfig(max_new_tokens=6)).generate(prompts)
    assert a.shape == (2, 14)
    np.testing.assert_array_equal(a, b)  # greedy = deterministic
    assert (a[:, :8] == prompts).all()


def test_greedy_continuation_matches_full_forward():
    """The engine's prefill-replay + decode path must produce the same
    greedy tokens as repeatedly running the full forward (the gold, slow
    implementation)."""
    cfg = get_config("yi-6b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(2, 7)).astype(np.int32)

    engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=5))
    fast = engine.generate(prompts)

    # gold: argmax over full forward, token by token
    import jax.numpy as jnp

    toks = jnp.asarray(prompts)
    for _ in range(5):
        logits, _ = forward(params, toks, cfg)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt], axis=1)
    np.testing.assert_array_equal(fast, np.asarray(toks))


def test_eos_early_stop():
    cfg = get_config("yi-6b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.ones((1, 4), np.int32)
    # find the first greedily emitted token, then declare it EOS
    probe = ServingEngine(cfg, params, ServeConfig(max_new_tokens=3)).generate(prompts)
    eos = int(probe[0, 4])
    engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=16, eos_id=eos))
    out = engine.generate(prompts)
    assert out.shape[1] < 4 + 16  # stopped early


def test_throughput_accounting():
    cfg = get_config("rwkv6-1.6b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=4))
    engine.generate(np.ones((3, 5), np.int32))
    assert engine.metrics["tokens_out"] == 3 * 4
    assert engine.decode_tokens_per_s > 0
