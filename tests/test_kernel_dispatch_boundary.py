"""KERNEL_MIN_NODES dispatch-boundary coverage (SC + greedy kernels).

Each vectorized scheduler dispatches single-item ``place`` calls to its
jitted kernel only at/above a crossover cluster size
(``KERNEL_MIN_NODES``); below it the scalar numpy oracle wins on
dispatch overhead.  Whatever the constant's value, decisions must be
identical on both sides of the boundary — these tests pin that at
``N - 1``, ``N`` and ``N + 1`` live nodes for every kernel-backed
scheduler, and assert the dispatch itself flips exactly at ``N``.

``greedy_least_used`` runs with an overridden boundary: its class
default intentionally exceeds any realistic cluster (the scalar
first-feasible-N scan is dispatch-proof), which would make the
parametrized cluster sizes impractical.
"""

import numpy as np
import pytest

from repro.core import ClusterView, DataItem, StorageNode, create_scheduler
from repro.core import greedy_kernel, lb_kernel, sc_kernel

needs_jax = pytest.mark.skipif(
    not (sc_kernel.kernel_available() and greedy_kernel.kernel_available()),
    reason="jax unavailable",
)

#: (scheduler, boundary override or None for the class default,
#:  kernel module, batch entry point the spy wraps)
#: drex_lb also runs overridden: its class default (~the measured 200+
#: node crossover against its vectorized-numpy oracle) would make the
#: parametrized cluster sizes slow for a boundary check.
CASES = [
    ("drex_sc", None, sc_kernel, "score_windows_batch"),
    ("greedy_min_storage", None, greedy_kernel, "min_storage_batch"),
    ("greedy_least_used", 12, greedy_kernel, "least_used_batch"),
    ("drex_lb", 16, lb_kernel, "lb_batch"),
]


def boundary_cluster(n: int, seed: int = 0) -> ClusterView:
    rng = np.random.default_rng(seed)
    return ClusterView.from_nodes(
        [
            StorageNode(
                node_id=i,
                capacity_mb=float(rng.uniform(2e3, 1e5)),
                write_bw=float(rng.uniform(50, 400)),
                read_bw=float(rng.uniform(50, 450)),
                annual_failure_rate=float(rng.uniform(0.001, 0.1)),
                used_mb=float(rng.uniform(0.0, 1e3)),
            )
            for i in range(n)
        ]
    )


def boundary_items(count: int = 4):
    rng = np.random.default_rng(1)
    targets = [0.9, 0.99, 0.999]
    return [
        DataItem(i, float(rng.uniform(1.0, 400.0)), float(i),
                 float(rng.uniform(30.0, 730.0)),
                 targets[int(rng.integers(len(targets)))])
        for i in range(count)
    ]


@needs_jax
@pytest.mark.parametrize("delta", [-1, 0, 1])
@pytest.mark.parametrize("name,override,module,entry", CASES)
class TestDispatchBoundary:
    def _make(self, name, override):
        sched = create_scheduler(name)
        if override is not None:
            sched.KERNEL_MIN_NODES = override
        return sched, sched.KERNEL_MIN_NODES

    def test_scalar_and_kernel_paths_agree_exactly(
        self, name, override, module, entry, delta
    ):
        sched, boundary = self._make(name, override)
        n_nodes = boundary + delta
        items = boundary_items()

        def decide(s):
            cluster = boundary_cluster(n_nodes)
            return [s.place(it, cluster) for it in items]

        scalar = create_scheduler(name)
        scalar.use_kernel = False
        kernel = create_scheduler(name)
        kernel.KERNEL_MIN_NODES = 0
        auto = decide(sched)
        for label, other in (("scalar", decide(scalar)), ("kernel", decide(kernel))):
            for da, db in zip(auto, other):
                assert da.placement == db.placement, (
                    f"{name} auto vs {label} at {n_nodes} nodes"
                )
                assert da.candidates_considered == db.candidates_considered
                assert da.reason == db.reason

    def test_dispatch_flips_exactly_at_the_boundary(
        self, name, override, module, entry, delta, monkeypatch
    ):
        sched, boundary = self._make(name, override)
        n_nodes = boundary + delta
        calls = []
        orig = getattr(module, entry)
        monkeypatch.setattr(
            module, entry, lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
        )
        cluster = boundary_cluster(n_nodes)
        sched.place(boundary_items(1)[0], cluster)  # single item: no batch rule
        used_kernel = bool(calls)
        assert used_kernel == (n_nodes >= boundary), (
            f"{name}: kernel dispatch at {n_nodes} nodes with boundary {boundary}"
        )
