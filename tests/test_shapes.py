"""Shape-bucketing + compile-cache regression tests (core/shapes.py).

The jitted kernels recompile once per static shape signature; the
shared bucketer must (a) keep pads masked-safe (always >= the request),
(b) bound the number of distinct shapes an elastic cluster can generate
(geometric rungs), and (c) absorb join/heal oscillation around a rung
boundary (hysteresis band).  The churn-budget tests are the regression
teeth for the ROADMAP's "per-shape-bucket recompiles on elastic
clusters" item: a simulated join/heal sequence must stay within a fixed
compile budget, asserted through the compile-cache counter the kernels
feed (`shapes.record_compile` / `compile_cache_stats`).
"""

import numpy as np
import pytest

from repro.core import shapes
from repro.core import lb_kernel
from repro.core.shapes import ShapeBucketer, rung
from repro.core import ClusterView, DataItem, StorageNode, create_scheduler


class TestRungLadder:
    def test_covers_and_aligns(self):
        for n in range(1, 700):
            r = rung(n)
            assert r >= n
            assert r % shapes.ALIGN == 0

    def test_exact_multiples_below_geometric_regime(self):
        # Small shapes keep the historical round-up-to-8 ladder.
        for n in range(1, shapes.GEOMETRIC_FROM + 1):
            assert rung(n) == max(8, ((n + 7) // 8) * 8)

    def test_geometric_above(self):
        # Rung count from 64 to 10k grows logarithmically: a cluster
        # scaling 100 -> 10000 one join at a time compiles O(log) times.
        rungs = {rung(n) for n in range(65, 10_000)}
        assert len(rungs) < 25
        ladder = sorted(rungs)
        ratios = [b / a for a, b in zip(ladder, ladder[1:])]
        assert max(ratios) <= shapes.GROWTH * 1.2

    def test_monotone(self):
        vals = [rung(n) for n in range(1, 2000)]
        assert all(b >= a for a, b in zip(vals, vals[1:]))


class TestHysteresisBand:
    def test_oscillation_around_a_rung_boundary_holds_one_shape(self):
        b = ShapeBucketer()
        lo, hi = 100, 110  # straddles the 104/136 rung boundary
        pads = {b.bucket("nodes", n) for _ in range(5) for n in range(lo, hi)}
        pads |= {b.bucket("nodes", n) for _ in range(5) for n in range(hi, lo, -1)}
        assert len(pads) <= 2  # one grow step, then held
        assert b.band_hits > 0

    def test_shrink_beyond_band_releases_the_held_pad(self):
        b = ShapeBucketer()
        big = b.bucket("nodes", 500)
        small = b.bucket("nodes", 24)  # far below big / SHRINK_BAND
        assert small == rung(24) < big

    def test_shrink_within_band_keeps_the_held_pad(self):
        b = ShapeBucketer()
        held = b.bucket("nodes", 130)
        assert b.bucket("nodes", 100) == held  # rung(100)*2 >= held

    def test_kinds_are_independent(self):
        b = ShapeBucketer()
        assert b.bucket("nodes", 500) >= 500
        assert b.bucket("sc_starts", 12) == rung(12)

    def test_pad_always_covers_request(self):
        b = ShapeBucketer()
        rng = np.random.default_rng(0)
        for n in rng.integers(1, 900, size=300):
            assert b.bucket("nodes", int(n)) >= n


class TestCompileCensus:
    def test_record_compile_dedups(self):
        b = ShapeBucketer()
        assert b.record_compile("k", (8, 16))
        assert not b.record_compile("k", (8, 16))
        assert b.record_compile("k", (8, 24))
        stats = b.stats()
        assert stats["kernels"]["k"] == {"compiles": 2, "calls": 3}

    def test_default_stats_shape(self):
        stats = shapes.compile_cache_stats()
        assert set(stats) == {"queries", "band_hits", "kernels"}


needs_jax = pytest.mark.skipif(
    not lb_kernel.kernel_available(), reason="jax unavailable"
)


def churn_cluster(n: int, seed: int = 0) -> ClusterView:
    rng = np.random.default_rng(seed)
    return ClusterView.from_nodes(
        [
            StorageNode(
                node_id=i,
                capacity_mb=float(rng.uniform(2e3, 1e5)),
                write_bw=float(rng.uniform(50, 400)),
                read_bw=float(rng.uniform(50, 450)),
                annual_failure_rate=float(rng.uniform(0.001, 0.05)),
            )
            for i in range(n)
        ]
    )


@needs_jax
class TestRecompileBudgetUnderChurn:
    """A node_join/node_heal churn sequence must stay within the bucket
    budget — the compile census counts every distinct static signature
    the kernel would compile."""

    def test_lb_kernel_join_heal_churn(self):
        # 90 -> 110 -> 95 one node at a time (joins, then fail/heals),
        # crossing the old round-up-to-8 ladder 4 times; the banded
        # buckets must hold this to <= 2 node shapes (one per batch pad
        # actually used).
        sched = create_scheduler("drex_lb")
        sched.KERNEL_MIN_NODES = 0
        sched.KERNEL_MIN_NODES_BATCH = 0
        item = DataItem(0, 50.0, 0.0, 365.0, 0.99)
        before = shapes.issued_shapes("lb_kernel")
        sizes = list(range(90, 111)) + list(range(110, 94, -1))
        for n in sizes:
            sched.place_batch([item], churn_cluster(n), None)
        new = shapes.issued_shapes("lb_kernel") - before
        node_pads = {sig[1] for sig in new}
        assert len(node_pads) <= 2, f"churn issued node pads {node_pads}"

    def test_bucketer_budget_is_logarithmic_under_wide_churn(self):
        # Pure-bucketer variant (no jit cost): a 2x elastic range maps
        # onto at most 4 pads.
        b = ShapeBucketer()
        rng = np.random.default_rng(7)
        pads = {b.bucket("nodes", int(n)) for n in rng.integers(250, 500, 400)}
        assert len(pads) <= 4

    def test_decisions_invariant_to_bucket_history(self):
        # The same cluster placed through a fresh bucketer state and a
        # held-oversized one must decide identically (pads are masked).
        sched = create_scheduler("drex_lb")
        sched.KERNEL_MIN_NODES = 0
        item = DataItem(0, 50.0, 0.0, 365.0, 0.99)
        cluster = churn_cluster(100)
        want = sched.place(item, cluster)
        # Inflate the held node pad far beyond 100, within the band.
        shapes.DEFAULT.bucket("nodes", 130)
        got = sched.place(item, cluster)
        assert got.placement == want.placement
        assert got.candidates_considered == want.candidates_considered
