"""Failure-domain constraint API: ``PlacementConstraints`` validation,
the cap-admitted candidate order, the swap post-pass, the registry
capability query, engine threading, and the telemetry facade."""

import numpy as np
import pytest

from repro import telemetry
from repro.core import (
    ClusterView,
    DataItem,
    PlacementConstraints,
    PlacementEngine,
    StorageNode,
    create_scheduler,
    find,
)
from repro.core import constraints as cmod
from repro.core.types import Placement


def topo_nodes(n, n_racks, cap=1e5, racks_per_zone=2):
    return [
        StorageNode(
            node_id=i,
            capacity_mb=cap,
            write_bw=200.0,
            read_bw=250.0,
            annual_failure_rate=0.01,
            rack=i % n_racks,
            zone=(i % n_racks) // racks_per_zone,
        )
        for i in range(n)
    ]


def mk_item(iid=0, size=50.0, rt=0.9):
    return DataItem(iid, size, 0.0, 365.0, rt)


class TestPlacementConstraints:
    def test_defaults_are_unconstrained(self):
        c = PlacementConstraints()
        assert c.unconstrained

    def test_any_field_clears_unconstrained(self):
        assert not PlacementConstraints(max_per_rack=2).unconstrained
        assert not PlacementConstraints(min_zones=2).unconstrained

    @pytest.mark.parametrize(
        "kw",
        [
            {"max_per_rack": 0},
            {"max_per_zone": -1},
            {"min_racks": 0},
            {"min_zones": -2},
        ],
    )
    def test_invalid_values_rejected(self, kw):
        with pytest.raises(ValueError):
            PlacementConstraints(**kw)

    def test_satisfied_by_checks_caps_and_spread(self):
        rack = np.array([0, 0, 1, 1, 2])
        zone = np.array([0, 0, 0, 1, 1])
        c = PlacementConstraints(max_per_rack=2, min_racks=2, min_zones=2)
        assert c.satisfied_by([0, 2, 3], rack, zone)
        assert not c.satisfied_by([0, 1, 2], rack, zone)  # zone spread
        assert not PlacementConstraints(max_per_rack=1).satisfied_by(
            [0, 1], rack, zone
        )

    def test_spread_clamps_to_mapping_size(self):
        # min_racks=4 on a 2-chunk mapping: need min(4, 2) = 2 racks.
        rack = np.array([0, 1, 2, 3])
        zone = np.zeros(4, dtype=np.int64)
        c = PlacementConstraints(min_racks=4)
        assert c.satisfied_by([0, 1], rack, zone)
        assert not c.satisfied_by([0, 0], np.array([5, 5]), np.zeros(2))


class TestConstrainedOrder:
    RACK = np.array([0, 0, 0, 1, 1, 2])
    ZONE = np.array([0, 0, 0, 0, 1, 1])

    def test_no_caps_returns_same_object(self):
        order = np.array([3, 1, 2])
        out = cmod.constrained_order(
            order, self.RACK, self.ZONE, PlacementConstraints(min_racks=3)
        )
        assert out is order
        assert cmod.constrained_order(order, self.RACK, self.ZONE, None) is order

    def test_rack_cap_admits_in_order(self):
        order = np.array([0, 1, 2, 3, 4, 5])
        out = cmod.constrained_order(
            order, self.RACK, self.ZONE, PlacementConstraints(max_per_rack=2)
        )
        # node 2 (third of rack 0) dropped, everything else kept in order.
        np.testing.assert_array_equal(out, [0, 1, 3, 4, 5])

    def test_dual_caps_rack_reject_frees_no_zone_slot(self):
        # Node 2 is rack-rejected; it must not consume a zone-0 slot,
        # so node 3 (zone 0) is still admitted.
        out = cmod.constrained_order(
            np.arange(6),
            self.RACK,
            self.ZONE,
            PlacementConstraints(max_per_rack=2, max_per_zone=3),
        )
        np.testing.assert_array_equal(out, [0, 1, 3, 4, 5])

    def test_admitted_set_subsets_conform(self):
        import itertools

        rng = np.random.default_rng(0)
        rack = rng.integers(0, 4, size=20)
        zone = rng.integers(0, 3, size=20)
        c = PlacementConstraints(max_per_rack=2, max_per_zone=3)
        out = cmod.constrained_order(np.arange(20), rack, zone, c)
        for r in (2, min(4, len(out))):
            for combo in itertools.islice(itertools.combinations(out, r), 50):
                assert c.satisfied_by(list(combo), rack, zone)


class TestRepairMapping:
    def _cluster(self, n=12, n_racks=4):
        return ClusterView.from_nodes(topo_nodes(n, n_racks))

    def test_conforming_mapping_returned_unchanged(self):
        cl = self._cluster()
        pl = Placement(k=2, p=1, node_ids=(0, 1, 2))  # racks 0,1,2
        c = PlacementConstraints(max_per_rack=1, min_racks=2)
        got = cmod.repair_mapping(pl, cl, c, 10.0)
        assert got is not None and got[0] is pl and got[1] == 0

    def test_over_cap_chunk_swapped_out_of_domain(self):
        cl = self._cluster()
        # Nodes 0, 4, 8 are all rack 0.
        pl = Placement(k=2, p=1, node_ids=(0, 4, 8))
        c = PlacementConstraints(max_per_rack=2)
        got = cmod.repair_mapping(pl, cl, c, 10.0)
        assert got is not None
        new_pl, swaps = got
        assert swaps == 1
        assert c.satisfied_by(new_pl.node_ids, cl.rack, cl.zone)
        assert len(set(new_pl.node_ids)) == 3

    def test_spread_promotion(self):
        cl = self._cluster()
        pl = Placement(k=2, p=1, node_ids=(0, 4, 8))  # one rack
        c = PlacementConstraints(min_racks=3)
        got = cmod.repair_mapping(pl, cl, c, 10.0)
        assert got is not None
        ids = got[0].node_ids
        assert len(set(int(cl.rack[i]) for i in ids)) >= 3

    def test_infeasible_returns_none(self):
        cl = ClusterView.from_nodes(topo_nodes(4, 1))  # one rack only
        pl = Placement(k=2, p=1, node_ids=(0, 1, 2))
        got = cmod.repair_mapping(
            pl, cl, PlacementConstraints(min_racks=2), 10.0
        )
        assert got is None

    def test_reliability_recheck_can_reject_swaps(self):
        cl = self._cluster()
        pl = Placement(k=2, p=1, node_ids=(0, 4, 8))
        c = PlacementConstraints(max_per_rack=1)
        got = cmod.repair_mapping(
            pl, cl, c, 10.0,
            min_parity=lambda fp: pl.p + 1,  # target now unreachable
            fail_probs=cl.fail_probs(365.0),
        )
        assert got is None


class TestRegistryFind:
    def test_flags_filter_and_sort(self):
        topo = find(topology_aware=True)
        names = [s.name for s in topo]
        assert names == sorted(names)
        assert {"drex_sc", "drex_lb", "greedy_least_used",
                "greedy_min_storage"} <= set(names)

    def test_dict_and_kwargs_agree(self):
        assert [s.name for s in find(capabilities={"batch_scoring": True})] == [
            s.name for s in find(batch_scoring=True)
        ]

    def test_unknown_flag_raises(self):
        with pytest.raises(ValueError, match="unknown capability"):
            find(zone_aware=True)

    def test_no_filter_returns_everything(self):
        all_specs = find()
        assert {"daos", "random_spread", "drex_sc"} <= {
            s.name for s in all_specs
        }

    def test_make_scheduler_shim_is_gone(self):
        import repro.core as core

        assert not hasattr(core, "make_scheduler")
        with pytest.raises(ImportError):
            from repro.core.algorithms import make_scheduler  # noqa: F401


class TestEngineConstraintThreading:
    C = PlacementConstraints(max_per_rack=2, min_racks=2)

    def _engine(self, name, **kw):
        return PlacementEngine(
            ClusterView.from_nodes(topo_nodes(12, 4)),
            create_scheduler(name),
            **kw,
        )

    def test_topology_aware_places_with_zero_swaps(self):
        engine = self._engine("drex_sc", constraints=self.C)
        recs = [engine.place(mk_item(i)) for i in range(4)]
        assert all(r.ok for r in recs)
        for r in recs:
            assert self.C.satisfied_by(
                r.placement.node_ids, engine.cluster.rack, engine.cluster.zone
            )
        assert engine.stats["n_constraint_swaps"] == 0  # by construction

    def test_non_declaring_scheduler_fixed_by_post_pass(self):
        # 6 racks x cap 2 = 12 slots: room for random_spread's 9-chunk
        # EC(6,3) mappings after the post-pass reshuffles them.
        engine = PlacementEngine(
            ClusterView.from_nodes(topo_nodes(18, 6)),
            create_scheduler("random_spread"),
            constraints=self.C,
        )
        placed = [r for r in (engine.place(mk_item(i)) for i in range(8)) if r.ok]
        assert placed, "random_spread placed nothing on 18 nodes"
        for r in placed:
            assert self.C.satisfied_by(
                r.placement.node_ids, engine.cluster.rack, engine.cluster.zone
            )

    def test_per_call_constraints_override_engine_default(self):
        engine = self._engine("drex_lb")  # engine-level: unconstrained
        rec = engine.place(mk_item(), constraints=self.C)
        assert rec.ok
        assert self.C.satisfied_by(
            rec.placement.node_ids, engine.cluster.rack, engine.cluster.zone
        )

    def test_unsatisfiable_constraint_rejects_and_counts(self):
        tight = PlacementConstraints(max_per_rack=1, max_per_zone=1)
        # One zone only: any mapping >= 2 chunks violates the zone cap.
        engine = PlacementEngine(
            ClusterView.from_nodes(topo_nodes(12, 3, racks_per_zone=3)),
            create_scheduler("random_spread"),
            constraints=tight,
        )
        recs = [engine.place(mk_item(i)) for i in range(3)]
        assert all(not r.ok for r in recs)
        assert engine.stats["n_constraint_rejects"] == 3
        assert all("failure-domain" in r.reason for r in recs)

    def test_place_many_conforms_batch_and_sequential(self):
        for name in ("drex_lb", "daos"):
            engine = self._engine(name, constraints=self.C)
            recs = engine.place_many([mk_item(i) for i in range(5)])
            for r in recs:
                if r.ok:
                    assert self.C.satisfied_by(
                        r.placement.node_ids,
                        engine.cluster.rack,
                        engine.cluster.zone,
                    )

    def test_post_pass_swaps_are_counted(self):
        # Single-rack-heavy mapping forces the swap post-pass: daos packs
        # the fastest nodes, which here all share rack 0.
        # 8 nodes crowd rack 0; racks 1-4 hold two each (10 cap-2 slots,
        # enough for random_spread's 9-chunk mappings after swapping).
        nodes = topo_nodes(16, 1)
        for n in nodes:
            n.rack = 0 if n.node_id < 8 else 1 + (n.node_id % 4)
            n.zone = 0
        engine = PlacementEngine(
            ClusterView.from_nodes(nodes),
            create_scheduler("random_spread"),
            constraints=PlacementConstraints(max_per_rack=2),
        )
        placed = [r for r in (engine.place(mk_item(i)) for i in range(8)) if r.ok]
        assert placed
        assert engine.stats["n_constraint_swaps"] > 0


class TestTelemetryFacade:
    def test_snapshot_schema_matches_sources(self):
        from repro.core import prefilter, shapes
        from repro.kernels import ops as kops

        from repro.core import jitcache

        snap = telemetry.snapshot()
        assert snap.engine is None
        assert set(snap.matrix_cache) == set(kops.matrix_cache_stats())
        assert set(snap.compile_cache) == set(shapes.compile_cache_stats())
        assert snap.prefilter == prefilter.stats()
        assert set(snap.jit_cache) == set(jitcache.status())
        d = snap.as_dict()
        assert set(d) == {
            "prefilter",
            "matrix_cache",
            "compile_cache",
            "engine",
            "jit_cache",
        }

    def test_snapshot_includes_engine_counters(self):
        engine = PlacementEngine(
            ClusterView.from_nodes(topo_nodes(6, 3)),
            create_scheduler("drex_lb"),
        )
        engine.place(mk_item())
        snap = telemetry.snapshot(engine=engine)
        assert snap.engine["n_placed"] == 1
        # A copy, not an alias.
        snap.engine["n_placed"] = 99
        assert engine.stats["n_placed"] == 1

    def test_reset_zeroes_prefilter_counters(self):
        from repro.core import prefilter

        prefilter.record("drex_sc", "engaged", 3)
        assert telemetry.snapshot().prefilter
        telemetry.reset(matrix_caches=False, compile_census=False)
        assert telemetry.snapshot().prefilter == {}
