"""Registry-driven scheduler invariant suite.

Every scheduler the registry knows about — including family members and
any scheduler a future PR registers — is swept over randomized clusters
and items, and its *accepted* placements are checked against Problem 1's
write-success constraints:

* the mapping uses distinct, live nodes only;
* every mapped node has free capacity for the chunk;
* the reliability target holds per the shared Poisson-binomial DP
  kernel (``min_parity_for_target`` / ``pr_avail``);
* engine rollback restores the ``ClusterView`` byte-for-byte.

Behavioral branches key on **capability flags only** (``adaptive``,
``randomized``, ``batch_scoring``) — never on scheduler names, so the
suite extends automatically to new registrations.
"""

import numpy as np
import pytest

from repro.core import (
    BatchContext,
    ClusterView,
    DataItem,
    PlacementConstraints,
    PlacementEngine,
    SCHEDULER_NAMES,
    StorageNode,
    create_scheduler,
    find,
)
from repro.core.reliability import min_parity_for_target, pr_avail

# Materialized registry sweep: SCHEDULER_NAMES resolves the paper's nine
# (incl. the ec(K,P) family members) into the registry at import time;
# registry.find() then yields every concrete registration.
ALL_REGISTERED = sorted({s.name for s in find()} | set(SCHEDULER_NAMES))

# Capability-keyed sweeps come from the registry query API, never from
# poking class attributes.
BATCH_SCORING = [s.name for s in find(batch_scoring=True)]
TOPOLOGY_AWARE = [s.name for s in find(topology_aware=True)]
NON_ADAPTIVE = [s.name for s in find(adaptive=False)]


def random_cluster(seed: int, n_lo: int = 5, n_hi: int = 14) -> ClusterView:
    rng = np.random.default_rng(seed)
    n = int(rng.integers(n_lo, n_hi + 1))
    nodes = [
        StorageNode(
            node_id=i,
            capacity_mb=float(rng.uniform(2e3, 1e5)),
            write_bw=float(rng.uniform(50, 400)),
            read_bw=float(rng.uniform(50, 450)),
            annual_failure_rate=float(rng.uniform(0.001, 0.2)),
            used_mb=float(rng.uniform(0.0, 1e3)),
        )
        for i in range(n)
    ]
    view = ClusterView.from_nodes(nodes)
    # Kill up to two random nodes so liveness is part of the invariant.
    for dead in rng.choice(n, size=int(rng.integers(0, 3)), replace=False):
        view.fail_node(int(dead))
    return view


def random_items(seed: int, count: int = 8) -> list[DataItem]:
    rng = np.random.default_rng(seed + 10_000)
    targets = [0.9, 0.99, 0.999, 0.99999]
    return [
        DataItem(
            item_id=i,
            size_mb=float(rng.uniform(1.0, 500.0)),
            arrival_time=float(i),
            delta_t_days=float(rng.uniform(30.0, 730.0)),
            reliability_target=targets[int(rng.integers(len(targets)))],
        )
        for i in range(count)
    ]


SEEDS = [0, 1, 2]


@pytest.mark.parametrize("name", ALL_REGISTERED)
@pytest.mark.parametrize("seed", SEEDS)
class TestAcceptedPlacementInvariants:
    """Constraints every accepted placement must satisfy, per scheduler."""

    def _records(self, name, seed):
        engine = PlacementEngine(
            random_cluster(seed), create_scheduler(name), auto_commit=False
        )
        items = random_items(seed)
        # auto_commit=False: the cluster is frozen, so constraints can be
        # checked against exactly the state the scheduler saw.
        return engine, items, [engine.place(it) for it in items]

    def test_mappings_use_distinct_live_nodes_with_capacity(self, name, seed):
        engine, items, records = self._records(name, seed)
        cluster = engine.cluster
        for item, rec in zip(items, records):
            if not rec.ok:
                continue
            pl = rec.placement
            ids = np.asarray(pl.node_ids)
            assert len(set(pl.node_ids)) == pl.n
            assert np.all(cluster.alive[ids]), f"{name} mapped a dead node"
            chunk = pl.chunk_size_mb(item.size_mb)
            assert np.all(cluster.free_mb[ids] >= chunk - 1e-9), (
                f"{name} violated capacity"
            )

    def test_reliability_target_met_per_shared_dp_kernel(self, name, seed):
        engine, items, records = self._records(name, seed)
        cluster = engine.cluster
        for item, rec in zip(items, records):
            if not rec.ok:
                continue
            pl = rec.placement
            fp = cluster.fail_probs(item.delta_t_days)[list(pl.node_ids)]
            mp = min_parity_for_target(fp, item.reliability_target)
            assert mp is not None and mp <= pl.p, (
                f"{name}: P={pl.p} but DP kernel needs {mp}"
            )
            assert (
                pr_avail(fp, pl.p) >= item.reliability_target - 1e-12
            )

    def test_rollback_restores_cluster_byte_for_byte(self, name, seed):
        engine = PlacementEngine(random_cluster(seed), create_scheduler(name))
        snap = engine.snapshot()
        used_bytes = engine.cluster.used_mb.tobytes()
        alive_bytes = engine.cluster.alive.tobytes()
        stats0 = dict(engine.stats)
        engine.place_many(random_items(seed))
        engine.rollback(snap)
        assert engine.cluster.used_mb.tobytes() == used_bytes
        assert engine.cluster.alive.tobytes() == alive_bytes
        assert engine.stats == stats0

    def test_scheduler_never_mutates_the_view(self, name, seed):
        cluster = random_cluster(seed)
        used = cluster.used_mb.tobytes()
        alive = cluster.alive.tobytes()
        sched = create_scheduler(name)
        for item in random_items(seed, count=4):
            sched.place(item, cluster)
        assert cluster.used_mb.tobytes() == used
        assert cluster.alive.tobytes() == alive


@pytest.mark.parametrize("name", ALL_REGISTERED)
class TestCapabilityContracts:
    """Capability flags describe behavior truthfully — checked by flag,
    never by name."""

    def test_randomized_schedulers_are_pure_per_item(self, name):
        # randomized == mapping depends on a seed, but repeated calls for
        # the same (seed, item, cluster) must still agree (pure function).
        randomized = name in {s.name for s in find(randomized=True)}
        cluster = random_cluster(3)
        item = random_items(3, count=1)[0]
        a = create_scheduler(name).place(item, cluster)
        b = create_scheduler(name).place(item, cluster)
        assert a.placement == b.placement, (
            f"{name}: place is not a pure function of (seed, item, cluster)"
            + (" despite randomized flag" if randomized else "")
        )

    def test_non_adaptive_schedulers_use_a_fixed_code(self, name):
        if name not in NON_ADAPTIVE:
            pytest.skip("adaptive schedulers choose (K, P) per item")
        engine = PlacementEngine(
            random_cluster(4, n_lo=10, n_hi=14),
            create_scheduler(name),
            auto_commit=False,
        )
        codes = {
            (r.placement.k, r.placement.p)
            for r in (engine.place(it) for it in random_items(4))
            if r.ok
        }
        assert len(codes) <= 1, f"{name} varied (K,P) without adaptive flag"

    def test_batch_scoring_schedulers_match_sequential_place(self, name):
        if name not in BATCH_SCORING:
            pytest.skip("scheduler does not declare batch scoring")
        sched = create_scheduler(name)
        assert hasattr(sched, "place_batch"), (
            f"{name} declares batch_scoring but has no place_batch"
        )
        items = random_items(5)
        seq = PlacementEngine(random_cluster(5), create_scheduler(name))
        want = [seq.place(it).placement for it in items]
        bat = PlacementEngine(random_cluster(5), create_scheduler(name))
        got = [r.placement for r in bat.place_many(items, ctx=BatchContext())]
        assert got == want
        np.testing.assert_array_equal(seq.cluster.used_mb, bat.cluster.used_mb)


# -- failure-domain invariants (PlacementConstraints) -----------------------

#: rack cap 2, mappings must span >= 2 racks and >= 2 zones.
DOMAIN_CAPS = PlacementConstraints(max_per_rack=2, min_racks=2, min_zones=2)


def topo_cluster(seed: int, n_racks: int = 5, per_rack: int = 3) -> ClusterView:
    """Random cluster with rack ids interleaved over node ids and racks
    nested two-per-zone."""
    rng = np.random.default_rng(seed + 77)
    nodes = [
        StorageNode(
            node_id=i,
            capacity_mb=float(rng.uniform(5e4, 1e5)),
            write_bw=float(rng.uniform(100, 400)),
            read_bw=float(rng.uniform(100, 450)),
            annual_failure_rate=float(rng.uniform(0.001, 0.05)),
            rack=i % n_racks,
            zone=(i % n_racks) // 2,
        )
        for i in range(n_racks * per_rack)
    ]
    return ClusterView.from_nodes(nodes)


def _assert_conforms(placement, cluster, constraints, who):
    assert constraints.satisfied_by(
        placement.node_ids, cluster.rack, cluster.zone
    ), (
        f"{who}: mapping {placement.node_ids} violates {constraints} "
        f"(racks={list(cluster.rack[list(placement.node_ids)])}, "
        f"zones={list(cluster.zone[list(placement.node_ids)])})"
    )


@pytest.mark.parametrize("name", ALL_REGISTERED)
@pytest.mark.parametrize("seed", SEEDS)
class TestFailureDomainInvariants:
    """Registry-wide zone-spread invariant: with constraints active on
    the engine, no accepted mapping exceeds a per-domain cap or narrows
    below the spread width — after place, after repair, and after heal.
    ``topology_aware`` schedulers conform by construction (cap-admitted
    candidate orders); everyone else through the engine's swap
    post-pass.  Checked by capability flag, never by name."""

    def _engine(self, name, seed):
        return PlacementEngine(
            topo_cluster(seed), create_scheduler(name), constraints=DOMAIN_CAPS
        )

    def test_caps_and_spread_hold_after_place(self, name, seed):
        engine = self._engine(name, seed)
        for rec in (engine.place(it) for it in random_items(seed)):
            if rec.ok:
                _assert_conforms(rec.placement, engine.cluster, DOMAIN_CAPS, name)

    def test_caps_and_spread_hold_after_repair(self, name, seed):
        engine = self._engine(name, seed)
        items = random_items(seed)
        records = [engine.place(it) for it in items]
        for item, rec in zip(items, records):
            if not rec.ok:
                continue
            engine.cluster.fail_node(int(rec.placement.node_ids[0]))
            plan = engine.plan_repair(item, rec.placement, chunk_mb=rec.chunk_mb)
            if plan.ok:
                _assert_conforms(plan.placement, engine.cluster, DOMAIN_CAPS, name)
            break  # one repair per seed keeps the sweep fast

    def test_caps_and_spread_hold_after_heal(self, name, seed):
        engine = self._engine(name, seed)
        engine.cluster.fail_node(0)
        engine.cluster.fail_node(1)
        engine.cluster.heal_node(0)
        for rec in (engine.place(it) for it in random_items(seed, count=4)):
            if rec.ok:
                _assert_conforms(rec.placement, engine.cluster, DOMAIN_CAPS, name)

    def test_unconstrained_engine_is_unchanged(self, name, seed):
        # constraints=None must decide exactly as before this API existed.
        base = PlacementEngine(topo_cluster(seed), create_scheduler(name))
        want = [base.place(it).placement for it in random_items(seed, count=4)]
        again = PlacementEngine(topo_cluster(seed), create_scheduler(name))
        got = [again.place(it).placement for it in random_items(seed, count=4)]
        assert got == want


class TestRackEventBlastRadius:
    """Acceptance: with a rack-failure schedule and ``topology_aware``
    placement under a satisfiable spread constraint whose rack cap is at
    most every mapping's parity count, no single rack event can destroy
    more than P chunks of any item."""

    @pytest.mark.parametrize("name", TOPOLOGY_AWARE)
    def test_rack_event_destroys_at_most_p_chunks(self, name):
        from repro.storage import SimConfig, Simulator

        # Cap 1 chunk per rack (<= P for every code the schedulers emit);
        # 15 racks leaves spare racks for the post-event repairs even
        # when a scheduler maps 10 chunks wide.
        c = PlacementConstraints(max_per_rack=1, min_racks=3)
        nodes = [
            StorageNode(
                node_id=i,
                capacity_mb=5e4,
                write_bw=200.0,
                read_bw=250.0,
                annual_failure_rate=0.01,
                rack=i % 15,
                zone=(i % 15) // 3,
            )
            for i in range(30)
        ]
        cfg = SimConfig(rack_failure_schedule=((30.0, 4),), constraints=c)
        sim = Simulator(nodes, create_scheduler(name), cfg)
        items = [DataItem(i, 50.0, 0.0, 365.0, 0.9) for i in range(6)]
        res = sim.run(items)
        assert res.n_stored > 0, f"{name} placed nothing under the constraint"
        rack = sim.cluster.rack
        for si in res.stored_items:
            per_rack = np.bincount(rack[list(si.placement.node_ids)])
            assert per_rack.max() <= si.placement.p, (
                f"{name}: a rack event would destroy {per_rack.max()} chunks "
                f"of item {si.item.item_id} (p={si.placement.p})"
            )
        # Items whose mapping left a spare rack survive the event: the
        # chunk in the dead rack decodes from survivors and repairs
        # instantly into an unused rack.  (A mapping spanning *all* 15
        # racks — drex_lb maximizes width — has nowhere cap-conforming
        # to repair into once its rack dies, and is legitimately
        # dropped: re-protection is impossible, not mis-planned.)
        for si in res.stored_items:
            width = len(set(int(rack[n]) for n in si.placement.node_ids))
            if width < 15:
                assert si.item.item_id in sim.live_items, (
                    f"{name}: item {si.item.item_id} had spare racks but "
                    "was dropped by the rack event"
                )


class TestPrefilterSpreadBoundary:
    """Top-M pre-filter vs spread constraints: the sliced candidate set
    must keep per-domain representatives (``prefilter.domain_slice``)
    so the cap cannot starve a satisfiable spread width."""

    def _slice(self, racks, zones, m, **kw):
        from repro.core import prefilter

        order = np.arange(len(racks))
        return prefilter.domain_slice(
            order,
            np.asarray(racks),
            np.asarray(zones),
            m,
            PlacementConstraints(**kw),
        )

    def test_promotes_first_out_of_prefix_rack(self):
        # Top-4 slice is all rack 0; min_racks=2 needs node 9 promoted.
        out = self._slice([0] * 9 + [1], [0] * 10, 4, min_racks=2)
        assert 9 in out and len(out) == 4
        assert list(out) == sorted(out)  # subsequence: order preserved

    def test_exact_prefix_when_slice_already_spans(self):
        out = self._slice([0, 1, 0, 1, 0, 1], [0] * 6, 4, min_racks=2)
        np.testing.assert_array_equal(out, np.arange(4))

    def test_zone_and_rack_both_represented(self):
        racks = [0, 0, 0, 0, 1, 2]
        zones = [0, 0, 0, 0, 0, 1]
        out = self._slice(racks, zones, 3, min_racks=2, min_zones=2)
        # Needs rack 1 (node 4) and zone 1 (node 5) inside a 3-slot slice.
        assert 4 in out and 5 in out and len(out) == 3

    def test_spread_wider_than_slice_clamps_to_m(self):
        # min_racks=5 but m=2: keep 2 distinct racks, never overflow m.
        out = self._slice([0, 0, 1, 2, 3, 4], [0] * 6, 2, min_racks=5)
        assert len(out) == 2 and len(set(out)) == 2

    def test_greedy_scan_cap_cannot_starve_spread(self):
        # 40 nodes; the 32 freest (greedy's SCAN_CAP) are all rack 0 —
        # the admitted candidate set must still span two racks.
        nodes = [
            StorageNode(
                node_id=i,
                capacity_mb=1e5 if i < 32 else 1e3,
                write_bw=200.0,
                read_bw=250.0,
                annual_failure_rate=0.005,
                rack=0 if i < 32 else 1,
                zone=0,
            )
            for i in range(40)
        ]
        engine = PlacementEngine(
            ClusterView.from_nodes(nodes),
            create_scheduler("greedy_least_used"),
            constraints=PlacementConstraints(min_racks=2),
        )
        rec = engine.place(DataItem(0, 10.0, 0.0, 365.0, 0.9))
        assert rec.ok
        racks = set(int(engine.cluster.rack[n]) for n in rec.placement.node_ids)
        assert len(racks) >= 2
