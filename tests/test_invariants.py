"""Registry-driven scheduler invariant suite.

Every scheduler the registry knows about — including family members and
any scheduler a future PR registers — is swept over randomized clusters
and items, and its *accepted* placements are checked against Problem 1's
write-success constraints:

* the mapping uses distinct, live nodes only;
* every mapped node has free capacity for the chunk;
* the reliability target holds per the shared Poisson-binomial DP
  kernel (``min_parity_for_target`` / ``pr_avail``);
* engine rollback restores the ``ClusterView`` byte-for-byte.

Behavioral branches key on **capability flags only** (``adaptive``,
``randomized``, ``batch_scoring``) — never on scheduler names, so the
suite extends automatically to new registrations.
"""

import numpy as np
import pytest

from repro.core import (
    BatchContext,
    ClusterView,
    DataItem,
    PlacementEngine,
    SCHEDULER_NAMES,
    StorageNode,
    create_scheduler,
    get_spec,
    scheduler_names,
)
from repro.core.reliability import min_parity_for_target, pr_avail

# Materialized registry sweep: SCHEDULER_NAMES resolves the paper's nine
# (incl. the ec(K,P) family members) into the registry at import time;
# scheduler_names() then yields every registration.
ALL_REGISTERED = sorted(set(scheduler_names()) | set(SCHEDULER_NAMES))


def random_cluster(seed: int, n_lo: int = 5, n_hi: int = 14) -> ClusterView:
    rng = np.random.default_rng(seed)
    n = int(rng.integers(n_lo, n_hi + 1))
    nodes = [
        StorageNode(
            node_id=i,
            capacity_mb=float(rng.uniform(2e3, 1e5)),
            write_bw=float(rng.uniform(50, 400)),
            read_bw=float(rng.uniform(50, 450)),
            annual_failure_rate=float(rng.uniform(0.001, 0.2)),
            used_mb=float(rng.uniform(0.0, 1e3)),
        )
        for i in range(n)
    ]
    view = ClusterView.from_nodes(nodes)
    # Kill up to two random nodes so liveness is part of the invariant.
    for dead in rng.choice(n, size=int(rng.integers(0, 3)), replace=False):
        view.fail_node(int(dead))
    return view


def random_items(seed: int, count: int = 8) -> list[DataItem]:
    rng = np.random.default_rng(seed + 10_000)
    targets = [0.9, 0.99, 0.999, 0.99999]
    return [
        DataItem(
            item_id=i,
            size_mb=float(rng.uniform(1.0, 500.0)),
            arrival_time=float(i),
            delta_t_days=float(rng.uniform(30.0, 730.0)),
            reliability_target=targets[int(rng.integers(len(targets)))],
        )
        for i in range(count)
    ]


SEEDS = [0, 1, 2]


@pytest.mark.parametrize("name", ALL_REGISTERED)
@pytest.mark.parametrize("seed", SEEDS)
class TestAcceptedPlacementInvariants:
    """Constraints every accepted placement must satisfy, per scheduler."""

    def _records(self, name, seed):
        engine = PlacementEngine(
            random_cluster(seed), create_scheduler(name), auto_commit=False
        )
        items = random_items(seed)
        # auto_commit=False: the cluster is frozen, so constraints can be
        # checked against exactly the state the scheduler saw.
        return engine, items, [engine.place(it) for it in items]

    def test_mappings_use_distinct_live_nodes_with_capacity(self, name, seed):
        engine, items, records = self._records(name, seed)
        cluster = engine.cluster
        for item, rec in zip(items, records):
            if not rec.ok:
                continue
            pl = rec.placement
            ids = np.asarray(pl.node_ids)
            assert len(set(pl.node_ids)) == pl.n
            assert np.all(cluster.alive[ids]), f"{name} mapped a dead node"
            chunk = pl.chunk_size_mb(item.size_mb)
            assert np.all(cluster.free_mb[ids] >= chunk - 1e-9), (
                f"{name} violated capacity"
            )

    def test_reliability_target_met_per_shared_dp_kernel(self, name, seed):
        engine, items, records = self._records(name, seed)
        cluster = engine.cluster
        for item, rec in zip(items, records):
            if not rec.ok:
                continue
            pl = rec.placement
            fp = cluster.fail_probs(item.delta_t_days)[list(pl.node_ids)]
            mp = min_parity_for_target(fp, item.reliability_target)
            assert mp is not None and mp <= pl.p, (
                f"{name}: P={pl.p} but DP kernel needs {mp}"
            )
            assert (
                pr_avail(fp, pl.p) >= item.reliability_target - 1e-12
            )

    def test_rollback_restores_cluster_byte_for_byte(self, name, seed):
        engine = PlacementEngine(random_cluster(seed), create_scheduler(name))
        snap = engine.snapshot()
        used_bytes = engine.cluster.used_mb.tobytes()
        alive_bytes = engine.cluster.alive.tobytes()
        stats0 = dict(engine.stats)
        engine.place_many(random_items(seed))
        engine.rollback(snap)
        assert engine.cluster.used_mb.tobytes() == used_bytes
        assert engine.cluster.alive.tobytes() == alive_bytes
        assert engine.stats == stats0

    def test_scheduler_never_mutates_the_view(self, name, seed):
        cluster = random_cluster(seed)
        used = cluster.used_mb.tobytes()
        alive = cluster.alive.tobytes()
        sched = create_scheduler(name)
        for item in random_items(seed, count=4):
            sched.place(item, cluster)
        assert cluster.used_mb.tobytes() == used
        assert cluster.alive.tobytes() == alive


@pytest.mark.parametrize("name", ALL_REGISTERED)
class TestCapabilityContracts:
    """Capability flags describe behavior truthfully — checked by flag,
    never by name."""

    def test_randomized_schedulers_are_pure_per_item(self, name):
        # randomized == mapping depends on a seed, but repeated calls for
        # the same (seed, item, cluster) must still agree (pure function).
        caps = get_spec(name).capabilities
        cluster = random_cluster(3)
        item = random_items(3, count=1)[0]
        a = create_scheduler(name).place(item, cluster)
        b = create_scheduler(name).place(item, cluster)
        assert a.placement == b.placement, (
            f"{name}: place is not a pure function of (seed, item, cluster)"
            + (" despite randomized flag" if caps.randomized else "")
        )

    def test_non_adaptive_schedulers_use_a_fixed_code(self, name):
        caps = get_spec(name).capabilities
        if caps.adaptive:
            pytest.skip("adaptive schedulers choose (K, P) per item")
        engine = PlacementEngine(
            random_cluster(4, n_lo=10, n_hi=14),
            create_scheduler(name),
            auto_commit=False,
        )
        codes = {
            (r.placement.k, r.placement.p)
            for r in (engine.place(it) for it in random_items(4))
            if r.ok
        }
        assert len(codes) <= 1, f"{name} varied (K,P) without adaptive flag"

    def test_batch_scoring_schedulers_match_sequential_place(self, name):
        caps = get_spec(name).capabilities
        if not caps.batch_scoring:
            pytest.skip("scheduler does not declare batch scoring")
        sched = create_scheduler(name)
        assert hasattr(sched, "place_batch"), (
            f"{name} declares batch_scoring but has no place_batch"
        )
        items = random_items(5)
        seq = PlacementEngine(random_cluster(5), create_scheduler(name))
        want = [seq.place(it).placement for it in items]
        bat = PlacementEngine(random_cluster(5), create_scheduler(name))
        got = [r.placement for r in bat.place_many(items, ctx=BatchContext())]
        assert got == want
        np.testing.assert_array_equal(seq.cluster.used_mb, bat.cluster.used_mb)
