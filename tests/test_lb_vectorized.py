"""Golden-equivalence tests for the jitted D-Rex LB kernel.

The scalar numpy path (``DRexLB.place_scalar``) is the reference oracle;
the jax kernel (``repro.core.lb_kernel``) and the batched
``PlacementEngine.place_many`` scoring built on it must reproduce its
decisions bit-for-bit.  Styled after tests/test_greedy_vectorized.py:
the ``GOLDEN`` placements below were captured from the scalar oracle at
the commit introducing the kernel, so *both* paths are pinned against
drift.  Coverage spans:

* normal heterogeneous clusters (the balance penalty discriminating
  between many feasible K at P = 1),
* capacity-tight clusters (the per-column capacity range collapsing),
* low-reliability regimes (high parity demand — the host frontier rows
  are exact at every width, so there is no fallback regime to hide in),
* the summation-order policy: penalties accumulate in prefix-sum order
  on both paths and the parity frontier enters the kernel as a host
  input (see the lb_kernel module docstring), so decisions are equal
  bit-for-bit, not approximately.
"""

import numpy as np
import pytest

from repro.core import (
    ClusterView,
    DataItem,
    Placement,
    PlacementEngine,
    StorageNode,
    create_scheduler,
    get_spec,
)
from repro.core import lb_kernel
from repro.storage import make_node_set, make_trace

needs_jax = pytest.mark.skipif(
    not lb_kernel.kernel_available(), reason="jax unavailable"
)


def forced_kernel_scheduler():
    """A DRexLB that uses the kernel at any cluster size (no numpy-
    dispatch crossover), so small test clusters hit the jit path."""
    sched = create_scheduler("drex_lb")
    sched.KERNEL_MIN_NODES = 0
    sched.KERNEL_MIN_NODES_BATCH = 0
    return sched


def scalar_scheduler():
    sched = create_scheduler("drex_lb")
    sched.use_kernel = False
    return sched


def random_cluster(
    seed: int, n: int, *, tight: bool = False, afr_hi: float = 0.2
) -> ClusterView:
    rng = np.random.default_rng(seed)
    cap_lo, cap_hi, used_hi = (
        (50.0, 800.0, 300.0) if tight else (2e3, 1e5, 1e3)
    )
    nodes = [
        StorageNode(
            node_id=i,
            capacity_mb=float(rng.uniform(cap_lo, cap_hi)),
            write_bw=float(rng.uniform(50, 400)),
            read_bw=float(rng.uniform(50, 450)),
            annual_failure_rate=float(rng.uniform(0.001, afr_hi)),
            used_mb=float(rng.uniform(0.0, used_hi)),
        )
        for i in range(n)
    ]
    return ClusterView.from_nodes(nodes)


def random_items(seed: int, count: int = 6, size_hi: float = 500.0):
    rng = np.random.default_rng(seed + 1)
    targets = [0.9, 0.99, 0.999, 0.99999]
    return [
        DataItem(
            item_id=i,
            size_mb=float(rng.uniform(1.0, size_hi)),
            arrival_time=float(i),
            delta_t_days=float(rng.uniform(30.0, 730.0)),
            reliability_target=targets[int(rng.integers(len(targets)))],
        )
        for i in range(count)
    ]


# (nodeset, trace seed) -> (k, p, node_ids) of the first 8 meva items at
# RT 0.99, committed sequentially.  Captured from the scalar oracle;
# guards oracle and kernel against silent drift.  The homogeneous set is
# the discriminating one: every node has identical free space, so the
# balance penalty (not first-feasibility) picks the wide K=9 mapping.
GOLDEN = {
    ("most_used", 3): [
        (2, 1, (3, 9, 0)),
        (2, 1, (3, 9, 2)),
        (2, 1, (3, 9, 8)),
        (2, 1, (3, 9, 2)),
        (2, 1, (3, 9, 2)),
        (2, 1, (3, 9, 8)),
        (2, 1, (3, 9, 2)),
        (2, 1, (3, 9, 2)),
    ],
    ("most_unreliable", 11): [
        (2, 2, (1, 0, 2, 3)),
        (2, 2, (1, 0, 2, 4)),
        (2, 2, (1, 0, 2, 3)),
        (2, 2, (1, 0, 2, 4)),
        (2, 2, (1, 0, 2, 4)),
        (2, 2, (1, 0, 2, 3)),
        (2, 2, (1, 0, 2, 4)),
        (2, 2, (1, 0, 2, 3)),
    ],
    ("homogeneous", 5): [
        (9, 1, (0, 1, 2, 3, 4, 5, 6, 7, 8, 9)),
    ] * 8,
}

GOLDEN_KEYS = sorted(GOLDEN)


class TestGoldenPlacements:
    """Pinned traces -> pinned placements, for both implementations."""

    def _run(self, nodeset, seed, scheduler):
        items = make_trace("meva", seed=seed, n_items=8, reliability=0.99)
        eng = PlacementEngine(make_node_set(nodeset, 0.001), scheduler)
        return [eng.place(it).placement for it in items]

    @pytest.mark.parametrize("key", GOLDEN_KEYS)
    def test_scalar_oracle_matches_golden(self, key):
        got = self._run(*key, scalar_scheduler())
        want = [Placement(k, p, ids) for k, p, ids in GOLDEN[key]]
        assert got == want

    @needs_jax
    @pytest.mark.parametrize("key", GOLDEN_KEYS)
    def test_kernel_matches_golden(self, key):
        got = self._run(*key, forced_kernel_scheduler())
        want = [Placement(k, p, ids) for k, p, ids in GOLDEN[key]]
        assert got == want

    @needs_jax
    @pytest.mark.parametrize("key", GOLDEN_KEYS)
    def test_batched_place_many_matches_golden(self, key):
        nodeset, seed = key
        items = make_trace("meva", seed=seed, n_items=8, reliability=0.99)
        eng = PlacementEngine(
            make_node_set(nodeset, 0.001), forced_kernel_scheduler()
        )
        got = [r.placement for r in eng.place_many(items)]
        want = [Placement(k, p, ids) for k, p, ids in GOLDEN[key]]
        assert got == want


@needs_jax
class TestKernelOracleEquivalence:
    """Kernel decisions == scalar oracle decisions, bit for bit."""

    def _assert_sequential_equal(self, cluster, items, ctx=None):
        a = scalar_scheduler()
        b = forced_kernel_scheduler()
        for it in items:
            da = a.place(it, cluster)
            db = b.place(it, cluster, ctx=ctx)
            assert da.placement == db.placement, f"item {it.item_id}"
            assert da.candidates_considered == db.candidates_considered
            assert da.reason == db.reason

    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("n", [5, 10, 40, 65, 120])
    def test_random_clusters(self, seed, n):
        self._assert_sequential_equal(
            random_cluster(seed * 100 + n, n), random_items(seed)
        )

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_capacity_tight_clusters(self, seed):
        # Tight free space engages the per-column capacity range: most
        # columns' largest feasible K no longer fits the chunk.
        self._assert_sequential_equal(
            random_cluster(seed, 40, tight=True),
            random_items(seed, size_hi=900.0),
        )

    @pytest.mark.parametrize("seed", [0, 1])
    def test_low_reliability_regime(self, seed):
        # High AFRs + many-nines targets: large minimum parities, deep
        # feasibility scans, frequent rejections.
        self._assert_sequential_equal(
            random_cluster(seed + 50, 30, afr_hi=3.0),
            [
                DataItem(i, 10.0 + i, float(i), 365.0, rt)
                for i, rt in enumerate([0.9, 0.999, 0.9999999, 0.99])
            ],
        )

    def test_extreme_parity_demand_matches_scalar(self):
        # Atrocious nodes: the smallest feasible parity lands above 100.
        # The host frontier rows are exact at every width, so the kernel
        # resolves even this regime in-grid (no fallback path exists).
        cluster = ClusterView.from_nodes(
            [
                StorageNode(i, 1e6, 200.0, 250.0, annual_failure_rate=3.5)
                for i in range(160)
            ]
        )
        item = DataItem(0, 10.0, 0.0, 365.0, 0.9)
        want = scalar_scheduler().place(item, cluster)
        got = forced_kernel_scheduler().place(item, cluster)
        assert want.placement is not None
        assert want.placement.p > 100  # the regime is real
        assert got.placement == want.placement
        assert got.candidates_considered == want.candidates_considered

    def test_batched_place_many_matches_sequential_oracle(self):
        items = make_trace("sentinel2", seed=5, n_items=40, reliability=0.95)
        a = PlacementEngine(make_node_set("most_used", 0.001), scalar_scheduler())
        pa = [a.place(it).placement for it in items]
        b = PlacementEngine(
            make_node_set("most_used", 0.001), forced_kernel_scheduler()
        )
        pb = [r.placement for r in b.place_many(items)]
        assert pa == pb
        np.testing.assert_array_equal(a.cluster.used_mb, b.cluster.used_mb)

    def test_non_committing_batch_matches_oracle(self):
        # auto_commit=False: nothing invalidates, the whole queue is
        # scored against one snapshot (the Table-2 decision-cost protocol).
        items = make_trace("meva", seed=9, n_items=30, reliability=0.99)
        a = PlacementEngine(
            make_node_set("most_used", 0.001), scalar_scheduler(),
            auto_commit=False,
        )
        pa = [a.place(it).placement for it in items]
        b = PlacementEngine(
            make_node_set("most_used", 0.001), forced_kernel_scheduler(),
            auto_commit=False,
        )
        pb = [r.placement for r in b.place_many(items)]
        assert pa == pb

    def test_matches_oracle_with_dead_nodes(self):
        items = make_trace("meva", seed=13, n_items=20, reliability=0.9)
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        cluster.fail_node(0)
        cluster.fail_node(4)
        self._assert_sequential_equal(cluster, items)

    def test_rejections_match_oracle(self):
        doomed = ClusterView.from_nodes(
            [StorageNode(i, 1e6, 200.0, 250.0, annual_failure_rate=500.0)
             for i in range(6)]
        )
        a = scalar_scheduler()
        b = forced_kernel_scheduler()
        for it in (
            DataItem(0, 1e12, 0.0, 365.0, 0.9),
            DataItem(1, 10.0, 0.0, 365.0, 0.999999),
        ):
            da, db = a.place(it, doomed), b.place(it, doomed)
            assert da.placement is None and db.placement is None
            assert da.reason == db.reason
            assert da.candidates_considered == db.candidates_considered

    def test_fewer_than_three_live_nodes(self):
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001)[:3])
        cluster.fail_node(0)
        rec = forced_kernel_scheduler().place(
            DataItem(0, 1.0, 0.0, 365.0, 0.9), cluster
        )
        assert rec.placement is None
        assert "fewer than 3" in rec.reason

    def test_registry_declares_batch_scoring_capability(self):
        assert get_spec("drex_lb").capabilities.batch_scoring
        # f_avg makes every LB score cluster-global: it must never claim
        # window-local scores (see the capability's docstring).
        assert not get_spec("drex_lb").capabilities.windowed_scoring

    def test_place_batch_is_pure(self):
        # Scoring a batch must not mutate scheduler state or the cluster.
        sched = forced_kernel_scheduler()
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        items = make_trace("meva", seed=1, n_items=10, reliability=0.9)
        used0 = cluster.used_mb.copy()
        smin0 = sched.smin_mb
        sched.place_batch(items, cluster)
        np.testing.assert_array_equal(cluster.used_mb, used0)
        assert sched.smin_mb == smin0


@needs_jax
class TestSummationOrderPolicy:
    """The penalty prefix sums are sequential on both paths: ulp-level
    agreement, not just same-argmin agreement."""

    def test_scalar_penalty_is_plain_cumsum(self):
        # The oracle's documented order: np.cumsum of the chunk-adjusted
        # deviations.  Recompute one decision's penalty by hand.
        cluster = random_cluster(3, 25)
        item = DataItem(0, 80.0, 0.0, 365.0, 0.99)
        rec = scalar_scheduler().place(item, cluster)
        pl = rec.placement
        assert pl is not None
        ids = cluster.live_ids()
        order = ids[np.argsort(-cluster.free_mb[ids], kind="stable")]
        free_sorted = cluster.free_mb[order]
        f_avg = float(free_sorted.mean())
        chunk = item.size_mb / float(pl.k)
        pen = np.cumsum(np.abs(free_sorted - chunk - f_avg))
        dev = np.abs(free_sorted - f_avg)
        suffix = np.concatenate([np.cumsum(dev[::-1])[::-1], [0.0]])
        want_bp = pen[pl.n - 1] + suffix[pl.n]
        # Any competing K at the same P must have a strictly larger
        # penalty (or equal with a larger K) under the same order.
        for k in range(2, len(order) - pl.p + 1):
            if k == pl.k:
                continue
            n = k + pl.p
            ck = item.size_mb / float(k)
            bp = np.cumsum(np.abs(free_sorted - ck - f_avg))[n - 1] + suffix[n]
            feasible = cluster.free_mb[order[:n]].min() >= ck
            if feasible and bp < want_bp:
                raise AssertionError("oracle did not pick the min-penalty K")

    def test_kernel_bitwise_equal_on_wide_mappings(self):
        # Mappings much wider than numpy's pairwise-sum block (8) — the
        # regime where an unfixed summation order would diverge in ulps.
        # Near-homogeneous free space makes the balance penalty favor
        # spreading wide (cf. the homogeneous golden).
        rng = np.random.default_rng(17)
        cluster = ClusterView.from_nodes(
            [
                StorageNode(
                    i, 5e4, float(rng.uniform(50, 400)),
                    float(rng.uniform(50, 450)), 0.02,
                    used_mb=float(rng.uniform(0.0, 10.0)),
                )
                for i in range(80)
            ]
        )
        items = [DataItem(i, 300.0 + i, float(i), 365.0, 0.9) for i in range(6)]
        a, b = scalar_scheduler(), forced_kernel_scheduler()
        for it in items:
            da, db = a.place(it, cluster), b.place(it, cluster)
            assert da.placement == db.placement
            assert da.placement is not None and da.placement.n > 8
