"""ClusterView on the cluster axis: amortized ``add_node`` growth and
the rack/zone failure-domain topology.

``add_node`` used to ``np.append`` every field (O(N) copy per join, so
O(N^2) to grow a cluster); it now doubles backing buffers geometrically
and hands out views.  The semantics must stay bit-for-bit what the
append implementation produced — same values, dtypes and shapes after
any interleaving of joins and mutations — which the reference-mirror
test pins.  The topology tests cover defaults (one rack in one zone),
``from_nodes`` plumbing, domain queries, and copy/snapshot isolation.
"""

import numpy as np
import pytest

from repro.core import ClusterView, DataItem, PlacementEngine, StorageNode

FIELDS = (
    "capacity_mb",
    "used_mb",
    "write_bw",
    "read_bw",
    "afr",
    "alive",
    "rack",
    "zone",
)


def make_node(i: int, rng) -> StorageNode:
    return StorageNode(
        node_id=i,
        capacity_mb=float(rng.uniform(2e3, 1e5)),
        write_bw=float(rng.uniform(50, 400)),
        read_bw=float(rng.uniform(50, 450)),
        annual_failure_rate=float(rng.uniform(0.001, 0.2)),
        used_mb=float(rng.uniform(0.0, 1e3)),
        failed=bool(rng.integers(0, 8) == 0),
        rack=int(i % 3),
        zone=int(i % 2),
    )


def node_values(node: StorageNode) -> dict:
    return {
        "capacity_mb": node.capacity_mb,
        "used_mb": node.used_mb,
        "write_bw": node.write_bw,
        "read_bw": node.read_bw,
        "afr": node.annual_failure_rate,
        "alive": not node.failed,
        "rack": node.rack,
        "zone": node.zone,
    }


class TestAddNodeGrowth:
    def test_matches_the_append_reference_bit_for_bit(self):
        """Grow 3 -> 60 nodes while mirroring every step with the old
        ``np.append`` semantics; every field must match exactly after
        every join, including interleaved occupancy/liveness mutations
        (the buffers hand out *views*, so a mutation must land in the
        backing store and survive subsequent growth)."""
        rng = np.random.default_rng(0)
        view = ClusterView.from_nodes([make_node(i, rng) for i in range(3)])
        ref = {f: getattr(view, f).copy() for f in FIELDS}
        for i in range(3, 60):
            node = make_node(i, rng)
            assert view.add_node(node) == i
            vals = node_values(node)
            for f in FIELDS:
                ref[f] = np.append(
                    ref[f], np.asarray(vals[f], dtype=ref[f].dtype)
                )
                got = getattr(view, f)
                assert got.dtype == ref[f].dtype
                assert got.shape == ref[f].shape == (i + 1,)
                np.testing.assert_array_equal(got, ref[f], err_msg=f)
            if i % 7 == 0:  # interleave mutations with growth
                j = int(rng.integers(0, i + 1))
                delta = float(rng.uniform(1.0, 50.0))
                view.used_mb[j] += delta
                ref["used_mb"][j] += delta
                k = int(rng.integers(0, i + 1))
                view.alive[k] = not view.alive[k]
                ref["alive"][k] = not ref["alive"][k]
        assert view.n_nodes == 60

    def test_single_node_seed_grows(self):
        rng = np.random.default_rng(1)
        view = ClusterView.from_nodes([make_node(0, rng)])
        for i in range(1, 10):
            assert view.add_node(make_node(i, rng)) == i
        assert view.n_nodes == 10

    def test_copy_detaches_from_growth_buffers(self):
        rng = np.random.default_rng(2)
        view = ClusterView.from_nodes([make_node(i, rng) for i in range(4)])
        view.add_node(make_node(4, rng))
        cp = view.copy()
        before = cp.used_mb.copy()
        view.add_node(make_node(5, rng))
        view.used_mb[0] += 100.0
        assert cp.n_nodes == 5
        np.testing.assert_array_equal(cp.used_mb, before)


class TestTopology:
    def test_defaults_to_single_domain(self):
        nodes = [
            StorageNode(
                node_id=i,
                capacity_mb=1e4,
                write_bw=100.0,
                read_bw=100.0,
                annual_failure_rate=0.01,
            )
            for i in range(4)
        ]
        view = ClusterView.from_nodes(nodes)
        assert view.rack.dtype == np.int64 and view.zone.dtype == np.int64
        assert (view.rack == 0).all() and (view.zone == 0).all()
        np.testing.assert_array_equal(view.nodes_in_rack(0), np.arange(4))
        np.testing.assert_array_equal(view.nodes_in_zone(0), np.arange(4))

    def test_from_nodes_plumbs_domains_and_queries(self):
        rng = np.random.default_rng(3)
        nodes = [make_node(i, rng) for i in range(8)]
        for i, n in enumerate(nodes):
            n.rack = i // 2  # racks {0..3}, zones {0, 1}
            n.zone = i // 4
        view = ClusterView.from_nodes(nodes)
        np.testing.assert_array_equal(view.nodes_in_rack(1), [2, 3])
        np.testing.assert_array_equal(view.nodes_in_zone(1), [4, 5, 6, 7])
        assert view.nodes_in_rack(99).size == 0

    def test_copy_is_independent(self):
        rng = np.random.default_rng(4)
        view = ClusterView.from_nodes([make_node(i, rng) for i in range(5)])
        cp = view.copy()
        cp.rack[0] = 99
        cp.zone[1] = 99
        assert view.rack[0] != 99 and view.zone[1] != 99

    def test_view_snapshot_write_protects_topology(self):
        rng = np.random.default_rng(5)
        engine = PlacementEngine(
            ClusterView.from_nodes([make_node(i, rng) for i in range(5)]),
            "ec(3,2)",
        )
        snap = engine.view_snapshot()
        with pytest.raises(ValueError):
            snap.rack[0] = 1
        with pytest.raises(ValueError):
            snap.zone[0] = 1

    def test_join_after_topology_keeps_domains(self):
        rng = np.random.default_rng(6)
        nodes = [make_node(i, rng) for i in range(4)]
        for n in nodes:
            n.rack, n.zone = 7, 3
        view = ClusterView.from_nodes(nodes)
        late = make_node(4, rng)
        late.rack, late.zone = 8, 3
        view.add_node(late)
        np.testing.assert_array_equal(view.nodes_in_rack(8), [4])
        np.testing.assert_array_equal(view.nodes_in_zone(3), np.arange(5))
