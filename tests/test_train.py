"""Training substrate tests: optimizer, sharded step, trainer loop,
data pipeline, gradient compression, elastic resharding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, LMDataPipeline
from repro.launch import make_local_mesh
from repro.models import init_params, loss_fn
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_decompress,
    compression_init,
)
from repro.train import (
    Trainer,
    TrainerConfig,
    init_train_state,
    make_train_step,
)
from repro.train.step import reshard_state

# trainer-loop e2e steps: full lane only (deselect via -m "not slow").
pytestmark = pytest.mark.slow


class TestAdamW:
    def test_descends_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, decay_steps=0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}  # d/dw w^2
            params, state, _ = adamw_update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_master_weights_stay_f32(self):
        cfg = AdamWConfig()
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw_init(params)
        grads = {"w": jnp.ones((4,), jnp.bfloat16)}
        params, state, _ = adamw_update(cfg, grads, state, params)
        assert state.master["w"].dtype == jnp.float32
        assert params["w"].dtype == jnp.bfloat16

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert float(gn) == pytest.approx(20.0)
        norm = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
        assert norm == pytest.approx(1.0, rel=1e-5)

    def test_warmup_schedule(self):
        from repro.optim.adamw import _schedule

        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, decay_steps=0)
        assert float(_schedule(cfg, jnp.int32(0))) == pytest.approx(1e-4)
        assert float(_schedule(cfg, jnp.int32(9))) == pytest.approx(1e-3)


class TestCompression:
    def test_error_feedback_converges(self):
        """EF-int8 compressed descent still converges on a quadratic."""
        w = jnp.array([4.0])
        comp = compression_init({"w": w})
        for _ in range(300):
            g = {"w": 2 * w}
            (gq, comp) = compress_decompress(g, comp)
            w = w - 0.05 * gq["w"]
        assert abs(float(w[0])) < 0.05

    def test_quantization_bounded_error(self):
        rng = np.random.default_rng(0)
        g = {"x": jnp.asarray(rng.normal(size=1000).astype(np.float32))}
        comp = compression_init(g)
        gq, comp2 = compress_decompress(g, comp)
        amax = float(jnp.abs(g["x"]).max())
        err = float(jnp.abs(gq["x"] - g["x"]).max())
        assert err <= amax / 127.0 + 1e-6


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
        a = LMDataPipeline(cfg).next_batch()
        b = LMDataPipeline(cfg).next_batch()
        assert jnp.array_equal(a["tokens"], b["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
        p = LMDataPipeline(cfg)
        b = p.next_batch()
        assert b["tokens"].shape == (4, 32)
        assert b["labels"].shape == (4, 32)

    def test_straggler_plan_thins_and_rebalances(self):
        cfg = DataConfig(vocab_size=10, seq_len=4, global_batch=4)
        p = LMDataPipeline(cfg)
        for _ in range(10):
            p.record_host_latency(0, 0.01)
            p.record_host_latency(1, 0.01)
            p.record_host_latency(2, 0.5)  # straggler
        assert p.straggler_hosts() == [2]
        plan = p.plan_host_batches([0, 1, 2], per_host=8)
        assert plan[2] < 8
        assert sum(plan.values()) == 24  # total preserved

    def test_no_stragglers_on_uniform_latency(self):
        cfg = DataConfig(vocab_size=10, seq_len=4, global_batch=4)
        p = LMDataPipeline(cfg)
        for h in range(4):
            p.record_host_latency(h, 0.1)
        assert p.straggler_hosts() == []


class TestTrainStep:
    def test_loss_decreases_with_pipeline_data(self):
        cfg = get_config("yi_6b", smoke=True)
        mesh = make_local_mesh(1, 1)
        step = make_train_step(cfg, AdamWConfig(lr=1e-2, warmup_steps=5), mesh)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        pipe = LMDataPipeline(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0)
        )
        losses = []
        for _ in range(20):
            state, m = step(state, pipe.next_batch())
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.1, losses[:3] + losses[-3:]

    def test_metrics_finite(self):
        cfg = get_config("qwen3_moe_30b_a3b", smoke=True)
        mesh = make_local_mesh(1, 1)
        step = make_train_step(cfg, AdamWConfig(), mesh)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        pipe = LMDataPipeline(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
        )
        state, m = step(state, pipe.next_batch())
        for k, v in m.items():
            assert bool(jnp.isfinite(v)), k

    def test_compression_variant_runs(self):
        cfg = get_config("yi_6b", smoke=True)
        step = make_train_step(cfg, AdamWConfig(), mesh=None, compression=True)
        state = init_train_state(cfg, jax.random.PRNGKey(0), compression=True)
        pipe = LMDataPipeline(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
        )
        state, m = step(state, pipe.next_batch())
        assert state.comp is not None
        assert bool(jnp.isfinite(m["loss"]))

    def test_reshard_state_roundtrip(self):
        cfg = get_config("yi_6b", smoke=True)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        mesh = make_local_mesh(1, 1)
        state2 = reshard_state(state, cfg, mesh)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


class TestTrainer:
    def test_end_to_end_loop(self):
        cfg = get_config("rwkv6_1_6b", smoke=True)
        trainer = Trainer(
            cfg,
            AdamWConfig(lr=5e-3, warmup_steps=5),
            TrainerConfig(steps=12, log_every=4),
            data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4),
            log_fn=lambda s, m: None,
        )
        state = trainer.run()
        assert len(trainer.history) >= 3
        assert trainer.history[-1]["loss"] < trainer.history[0]["loss"] + 0.5
