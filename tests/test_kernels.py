"""Pallas kernel tests: bit-matmul vs pure-jnp oracle, shape/dtype sweeps,
roundtrip-with-erasures property tests (hypothesis)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dep (requirements-dev.txt); keep invariants running
    from _hypothesis_stub import given, settings, strategies as st

from repro.ec import ECCodec, gf256
from repro.kernels import ops, ref
from repro.kernels.rs_bitmatmul import gf_bitmatmul

# codec roundtrip property sweeps: full lane only (deselect via -m "not slow").
pytestmark = pytest.mark.slow


class TestGF256Host:
    def test_mul_identity_and_zero(self):
        a = np.arange(256, dtype=np.uint8)
        assert np.array_equal(gf256.gf_mul(a, 1), a)
        assert np.all(gf256.gf_mul(a, 0) == 0)

    def test_mul_commutative_associative(self):
        rng = np.random.default_rng(0)
        a, b, c = rng.integers(0, 256, size=(3, 1000), dtype=np.uint8)
        assert np.array_equal(gf256.gf_mul(a, b), gf256.gf_mul(b, a))
        assert np.array_equal(
            gf256.gf_mul(gf256.gf_mul(a, b), c), gf256.gf_mul(a, gf256.gf_mul(b, c))
        )

    def test_inverse(self):
        a = np.arange(1, 256, dtype=np.uint8)
        assert np.all(gf256.gf_mul(a, gf256.gf_inv(a)) == 1)

    def test_distributive_over_xor(self):
        rng = np.random.default_rng(1)
        a, b, c = rng.integers(0, 256, size=(3, 1000), dtype=np.uint8)
        assert np.array_equal(
            gf256.gf_mul(a, b ^ c), gf256.gf_mul(a, b) ^ gf256.gf_mul(a, c)
        )

    def test_matrix_inverse(self):
        rng = np.random.default_rng(2)
        for n in (2, 3, 5, 8):
            # Cauchy matrices are always invertible.
            m = gf256.cauchy_matrix(n, n)
            inv = gf256.gf_mat_inv(m)
            assert np.array_equal(gf256.gf_matmul(m, inv), np.eye(n, dtype=np.uint8))

    def test_singular_matrix_raises(self):
        m = np.array([[1, 2], [1, 2]], dtype=np.uint8)
        with pytest.raises(np.linalg.LinAlgError):
            gf256.gf_mat_inv(m)

    @pytest.mark.parametrize("k,p", [(2, 1), (3, 2), (4, 2), (6, 3), (8, 2), (16, 4)])
    def test_any_k_rows_of_generator_invertible(self, k, p):
        """The MDS property that makes K-of-N recovery work at all."""
        rng = np.random.default_rng(k * 100 + p)
        g = gf256.generator_matrix(k, p)
        for _ in range(10):
            rows = rng.choice(k + p, size=k, replace=False)
            gf256.gf_mat_inv(g[np.sort(rows)])  # must not raise


class TestBitmatrix:
    @pytest.mark.parametrize("r,k", [(1, 2), (2, 3), (2, 4), (3, 6), (4, 8), (4, 16)])
    def test_bitmatrix_equals_gf_matmul(self, r, k):
        rng = np.random.default_rng(r * 10 + k)
        m = rng.integers(0, 256, size=(r, k), dtype=np.uint8)
        data = rng.integers(0, 256, size=(k, 512), dtype=np.uint8)
        bm = gf256.gf_to_bitmatrix(m)
        got = np.asarray(ref.bitmatmul_ref(bm, data))
        want = gf256.gf_matmul(m, data)
        np.testing.assert_array_equal(got, want)

    def test_bitmatrix_shape_and_binary(self):
        m = gf256.cauchy_matrix(3, 5)
        bm = gf256.gf_to_bitmatrix(m)
        assert bm.shape == (24, 40)
        assert set(np.unique(bm)) <= {0, 1}


class TestPallasKernel:
    """``pallas=True`` forces the Pallas kernel (interpret mode on CPU)
    — the correctness gate for the kernel body itself.  Without it the
    kernel path dispatches to the jitted XLA twin off-TPU (see
    repro.kernels.ops), which TestXlaTwin covers."""

    @pytest.mark.parametrize(
        "k,p,nbytes",
        [
            (2, 1, 2048),
            (3, 2, 2048),
            (4, 2, 4096),
            (6, 3, 2048),
            (8, 2, 6144),
            (10, 4, 2048),
            (16, 4, 4096),
        ],
    )
    def test_encode_matches_oracle(self, k, p, nbytes):
        rng = np.random.default_rng(k * 1000 + p)
        data = rng.integers(0, 256, size=(k, nbytes), dtype=np.uint8)
        got = np.asarray(ops.encode_chunks(data, p, use_kernel=True, pallas=True))
        want = np.asarray(ops.encode_chunks(data, p, use_kernel=False))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("nbytes", [1, 7, 100, 2047, 2048, 2049, 10_000])
    def test_unaligned_sizes_padded_correctly(self, nbytes):
        rng = np.random.default_rng(nbytes)
        data = rng.integers(0, 256, size=(4, nbytes), dtype=np.uint8)
        got = np.asarray(ops.encode_chunks(data, 2, use_kernel=True, pallas=True))
        want = np.asarray(ops.encode_chunks(data, 2, use_kernel=False))
        assert got.shape == (2, nbytes)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("block", [256, 1024, 2048])
    def test_block_size_invariance(self, block):
        rng = np.random.default_rng(block)
        data = rng.integers(0, 256, size=(5, 4096), dtype=np.uint8)
        a = np.asarray(ops.encode_chunks(data, 3, block_bytes=block, pallas=True))
        b = np.asarray(ops.encode_chunks(data, 3, block_bytes=2048, pallas=True))
        np.testing.assert_array_equal(a, b)

    def test_decode_kernel_matches_oracle(self):
        rng = np.random.default_rng(5)
        k, p = 5, 3
        g = gf256.generator_matrix(k, p)
        data = rng.integers(0, 256, size=(k, 3000), dtype=np.uint8)
        all_chunks = gf256.gf_matmul(g, data)
        rows = np.array([0, 2, 5, 6, 7])  # mix of data+parity rows
        got = np.asarray(
            ops.decode_chunks(all_chunks[rows], rows, k, p,
                              use_kernel=True, pallas=True)
        )
        want = np.asarray(
            ops.decode_chunks(all_chunks[rows], rows, k, p, use_kernel=False)
        )
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got, data)


class TestXlaTwin:
    """The off-TPU kernel path (jitted, tiled XLA bit-matmul) must match
    the oracle too — it is what CPU CI times in benchmarks/fig1."""

    @pytest.mark.parametrize("k,p,nbytes", [(3, 2, 2048), (6, 3, 70_000)])
    def test_encode_matches_oracle(self, k, p, nbytes):
        rng = np.random.default_rng(k * 7 + nbytes)
        data = rng.integers(0, 256, size=(k, nbytes), dtype=np.uint8)
        got = np.asarray(ops.encode_chunks(data, p, use_kernel=True, pallas=False))
        want = np.asarray(ops.encode_chunks(data, p, use_kernel=False))
        np.testing.assert_array_equal(got, want)

    def test_tiled_width_equals_untiled(self):
        # wide enough that the lax.map tiling path runs (> EC_TILE_BLOCKS
        # blocks); narrow calls take the single-call branch.
        rng = np.random.default_rng(11)
        wide = rng.integers(
            0, 256, size=(4, ops.EC_TILE_BLOCKS * 2048 * 5), dtype=np.uint8
        )
        got = np.asarray(ops.encode_chunks(wide, 2, use_kernel=True, pallas=False))
        want = np.asarray(ops.encode_chunks(wide, 2, use_kernel=False))
        np.testing.assert_array_equal(got, want)

    def test_rejects_bad_shapes(self):
        import jax.numpy as jnp

        with pytest.raises(AssertionError):
            gf_bitmatmul(
                jnp.zeros((15, 16), jnp.float32), jnp.zeros((2, 2048), jnp.uint8)
            )


class TestCodecRoundtrip:
    @given(
        k=st.integers(2, 10),
        p=st.integers(1, 4),
        nbytes=st.integers(1, 40_000),
        seed=st.integers(0, 2**31),
    )
    @settings(max_examples=30, deadline=None)
    def test_property_roundtrip_any_k_surviving(self, k, p, nbytes, seed):
        rng = np.random.default_rng(seed)
        payload = rng.integers(0, 256, size=nbytes, dtype=np.uint8).tobytes()
        codec = ECCodec(k, p)
        chunks = codec.encode(payload)
        assert chunks.shape[0] == k + p
        keep = np.sort(rng.choice(k + p, size=k, replace=False))
        out = codec.decode(chunks[keep], keep, nbytes)
        assert out == payload

    def test_tolerates_exactly_p_failures_not_more(self):
        rng = np.random.default_rng(0)
        payload = rng.integers(0, 256, size=9999, dtype=np.uint8).tobytes()
        codec = ECCodec(4, 2)
        chunks = codec.encode(payload)
        keep = np.array([2, 3, 4, 5])  # lose rows 0,1 (= P failures): fine
        assert codec.decode(chunks[keep], keep, 9999) == payload
        with pytest.raises(ValueError):
            codec.decode(chunks[:3], np.arange(3), 9999)  # K-1 chunks

    def test_systematic_fast_path(self):
        payload = b"hello world" * 1000
        codec = ECCodec(3, 2)
        chunks = codec.encode(payload)
        rows = np.arange(3)
        assert codec.decode(chunks[:3], rows, len(payload)) == payload

    def test_empty_ish_payload(self):
        codec = ECCodec(4, 2)
        chunks = codec.encode(b"x")
        keep = np.array([0, 3, 4, 5])
        assert codec.decode(chunks[keep], keep, 1) == b"x"
