"""Top-M pre-filter exactness at the dispatch boundary (core/prefilter).

The pre-filter slices kernel inputs to the freest-M prefix of the live
nodes (see :mod:`repro.core.prefilter` for the per-scheduler
losslessness arguments).  These tests pin:

* kernel-vs-oracle agreement at ``M - 1`` / ``M`` / ``M + 1`` live nodes
  for each filtered scheduler — the filter engages exactly when the live
  count exceeds the cap, so the boundary is where a slicing bug would
  first change a decision;
* free-space-key *ties* straddling the cut: ``_live_sorted`` is a
  stable sort, so the filtered prefix must be a prefix of the unfiltered
  order even when every node ties;
* the D-Rex LB fallback lane: rows whose sufficiency test fails re-run
  unfiltered and still match the scalar oracle bit-for-bit;
* telemetry accounting (``engaged == accepted + fallback``);
* a registry sweep: every ``batch_scoring`` scheduler's filtered batch
  decisions are bit-identical to its sequential scalar-oracle decisions
  on randomized clusters large enough for the filter to engage.
"""

import numpy as np
import pytest

from repro.core import (
    ClusterView,
    DataItem,
    SCHEDULER_NAMES,
    StorageNode,
    create_scheduler,
    get_spec,
    scheduler_names,
)
from repro.core import greedy_kernel, lb_kernel, prefilter, sc_kernel

needs_jax = pytest.mark.skipif(
    not (
        sc_kernel.kernel_available()
        and greedy_kernel.kernel_available()
        and lb_kernel.kernel_available()
    ),
    reason="jax unavailable",
)

ALL_REGISTERED = sorted(set(scheduler_names()) | set(SCHEDULER_NAMES))


def make_cluster(n: int, seed: int = 0, afr_hi: float = 0.1, ties: bool = False):
    """``ties=True`` gives every node identical free space, so *every*
    prefix boundary is a tie and only the stable sort order breaks it."""
    rng = np.random.default_rng(seed)
    return ClusterView.from_nodes(
        [
            StorageNode(
                node_id=i,
                capacity_mb=5e4 if ties else float(rng.uniform(2e3, 1e5)),
                write_bw=float(rng.uniform(50, 400)),
                read_bw=float(rng.uniform(50, 450)),
                annual_failure_rate=float(rng.uniform(0.001, afr_hi)),
                used_mb=0.0 if ties else float(rng.uniform(0.0, 1e3)),
            )
            for i in range(n)
        ]
    )


def make_items(count: int = 6, seed: int = 1, target: float | None = None):
    rng = np.random.default_rng(seed)
    targets = [0.9, 0.99, 0.999]
    return [
        DataItem(
            i,
            float(rng.uniform(1.0, 400.0)),
            float(i),
            float(rng.uniform(30.0, 730.0)),
            target
            if target is not None
            else targets[int(rng.integers(len(targets)))],
        )
        for i in range(count)
    ]


def _tuned(name: str, **overrides):
    """Scheduler with the kernel forced on and small caps so the filter
    engages on test-sized clusters; identical tuning must be applied to
    the oracle instance (caps like ``MAX_MAPPINGS`` are part of the
    algorithm, not just the filter)."""
    sched = create_scheduler(name)
    for attr, val in overrides.items():
        assert hasattr(type(sched), attr), f"{name} has no {attr}"
        setattr(sched, attr, val)
    for attr in ("KERNEL_MIN_NODES", "KERNEL_MIN_NODES_BATCH"):
        if hasattr(type(sched), attr):
            setattr(sched, attr, 0)
    return sched


def assert_decisions_match(got, want, label):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert a.placement == b.placement, label
        assert a.candidates_considered == b.candidates_considered, label
        assert a.reason == b.reason, label


LB_CAP = 8  # instance override; lb_batch needs m >= 3


@needs_jax
@pytest.mark.parametrize("delta", [-1, 0, 1])
class TestLBBoundary:
    def _pair(self):
        filt = _tuned("drex_lb", PREFILTER_CAP=LB_CAP)
        oracle = _tuned("drex_lb", PREFILTER_CAP=LB_CAP)
        oracle.use_kernel = False
        return filt, oracle

    def test_matches_scalar_oracle_at_the_cut(self, delta):
        filt, oracle = self._pair()
        cluster = make_cluster(LB_CAP + delta)
        items = make_items()
        got = filt.place_batch(items, cluster)
        want = [oracle.place_scalar(it, cluster) for it in items]
        assert_decisions_match(got, want, f"drex_lb at cap{delta:+d}")

    def test_ties_at_the_cut(self, delta):
        filt, oracle = self._pair()
        cluster = make_cluster(LB_CAP + delta, ties=True)
        items = make_items()
        got = filt.place_batch(items, cluster)
        want = [oracle.place_scalar(it, cluster) for it in items]
        assert_decisions_match(got, want, f"drex_lb ties at cap{delta:+d}")

    def test_engagement_flips_exactly_at_the_cap(self, delta):
        filt, _ = self._pair()
        prefilter.reset_stats()
        items = make_items()
        filt.place_batch(items, make_cluster(LB_CAP + delta))
        st = prefilter.stats().get("drex_lb", {})
        if delta > 0:  # filter engages only when L > cap
            assert st["engaged"] == len(items)
            assert st["engaged"] == st["accepted"] + st["fallback"]
        else:
            assert st.get("engaged", 0) == 0


@needs_jax
class TestLBFallback:
    def test_failed_sufficiency_rows_rerun_unfiltered(self):
        # Near-hopeless nodes + a hard target: the filtered grid's found
        # P hits the prefix's own min parity, the sufficiency test
        # fails, and every row must re-run over the full grid.
        filt = _tuned("drex_lb", PREFILTER_CAP=LB_CAP)
        oracle = _tuned("drex_lb", PREFILTER_CAP=LB_CAP)
        oracle.use_kernel = False
        rng = np.random.default_rng(3)
        cluster = ClusterView.from_nodes(
            [
                StorageNode(
                    node_id=i,
                    capacity_mb=5e4,
                    write_bw=float(rng.uniform(50, 400)),
                    read_bw=float(rng.uniform(50, 450)),
                    annual_failure_rate=float(rng.uniform(0.6, 0.95)),
                )
                for i in range(LB_CAP + 6)
            ]
        )
        items = make_items(4, target=0.999999)
        prefilter.reset_stats()
        got = filt.place_batch(items, cluster)
        st = prefilter.stats()["drex_lb"]
        assert st["fallback"] > 0, "setup no longer triggers the fallback lane"
        want = [oracle.place_scalar(it, cluster) for it in items]
        assert_decisions_match(got, want, "drex_lb fallback lane")


SC_BUDGET = 16  # instance override; sc_cap(16) == rung(17) == 24
SC_CAP = prefilter.sc_cap(SC_BUDGET)


@needs_jax
@pytest.mark.parametrize("delta", [-1, 0, 1])
@pytest.mark.parametrize("ties", [False, True])
class TestSCBoundary:
    def test_matches_scalar_oracle_at_the_cut(self, delta, ties):
        filt = _tuned("drex_sc", MAX_MAPPINGS=SC_BUDGET)
        oracle = _tuned("drex_sc", MAX_MAPPINGS=SC_BUDGET)
        oracle.use_kernel = False
        cluster = make_cluster(SC_CAP + delta, ties=ties)
        items = make_items()
        prefilter.reset_stats()
        got = filt.place_batch(items, cluster)
        # Sequential scalar calls see the same running-smin anchors the
        # batch threads through (place_batch's documented semantics).
        want = [oracle.place_scalar(it, cluster) for it in items]
        assert_decisions_match(got, want, f"drex_sc at cap{delta:+d}")
        st = prefilter.stats().get("drex_sc", {})
        if delta > 0:
            # SC's slice is unconditionally exact: no fallback lane.
            assert st["engaged"] == st["accepted"] == len(items)
            assert st.get("fallback", 0) == 0
        else:
            assert st.get("engaged", 0) == 0


LU_CAP = 6  # SCAN_CAP override


@needs_jax
@pytest.mark.parametrize("delta", [-1, 0, 1])
class TestLeastUsedBoundary:
    def test_matches_scalar_oracle_at_the_cut(self, delta):
        filt = _tuned("greedy_least_used", SCAN_CAP=LU_CAP)
        oracle = create_scheduler("greedy_least_used")
        oracle.use_kernel = False
        cluster = make_cluster(LU_CAP + delta)
        items = make_items()
        got = filt.place_batch(items, cluster)
        want = [oracle.place_scalar(it, cluster) for it in items]
        assert_decisions_match(got, want, f"greedy_least_used at cap{delta:+d}")

    def test_capped_scan_that_finds_nothing_falls_back(self, delta):
        # Impossible target: no N is feasible within the cap (nor at
        # all); the capped kernel must recover the oracle's rejection.
        filt = _tuned("greedy_least_used", SCAN_CAP=LU_CAP)
        oracle = create_scheduler("greedy_least_used")
        oracle.use_kernel = False
        cluster = make_cluster(LU_CAP + delta, afr_hi=0.9, seed=5)
        items = make_items(4, target=0.9999999)
        prefilter.reset_stats()
        got = filt.place_batch(items, cluster)
        want = [oracle.place_scalar(it, cluster) for it in items]
        assert_decisions_match(got, want, "greedy_least_used fallback")
        if delta > 0 and any(d.placement is None for d in got):
            assert prefilter.stats()["greedy_least_used"]["fallback"] > 0


@needs_jax
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("name", ALL_REGISTERED)
class TestRegistrySweep:
    """Every batch-scoring scheduler, filtered caps engaged, vs its own
    sequential scalar oracle — decisions bit-identical."""

    #: small caps so the filter engages at sweep cluster sizes; applied
    #: to filtered and oracle instances alike (attribute-gated, so
    #: schedulers without a given knob are untouched).
    TUNING = {"PREFILTER_CAP": 8, "MAX_MAPPINGS": 8, "SCAN_CAP": 8}

    def _tune_if_present(self, sched):
        for attr, val in self.TUNING.items():
            if hasattr(type(sched), attr):
                setattr(sched, attr, val)
        for attr in ("KERNEL_MIN_NODES", "KERNEL_MIN_NODES_BATCH"):
            if hasattr(type(sched), attr):
                setattr(sched, attr, 0)
        return sched

    def test_filtered_batch_matches_scalar_oracle(self, name, seed):
        if not get_spec(name).capabilities.batch_scoring:
            pytest.skip("no batched scoring path")
        if not hasattr(create_scheduler(name), "place_scalar"):
            # e.g. test-helper registrations without a scalar oracle
            pytest.skip("no scalar-oracle API to compare against")
        rng = np.random.default_rng(seed)
        n = int(rng.integers(20, 41))
        cluster = make_cluster(n, seed=seed + 100)
        items = make_items(8, seed=seed + 200)
        filt = self._tune_if_present(create_scheduler(name))
        oracle = self._tune_if_present(create_scheduler(name))
        oracle.use_kernel = False
        prefilter.reset_stats()
        got = filt.place_batch(items, cluster)
        want = [oracle.place_scalar(it, cluster) for it in items]
        assert_decisions_match(got, want, f"{name} sweep seed={seed}")
        st = prefilter.stats().get(filt.name, {})
        if getattr(filt, "use_prefilter", False):
            # The tuned caps are below every sweep cluster size, so the
            # filtered lane must actually have run.
            assert st["engaged"] == len(items)
            assert st["engaged"] == st["accepted"] + st.get("fallback", 0)


class TestStatsAccounting:
    def test_record_validates_events(self):
        with pytest.raises(ValueError):
            prefilter.record("x", "nonsense")

    def test_record_accumulates_and_resets(self):
        prefilter.reset_stats()
        prefilter.record("x", "engaged", 3)
        prefilter.record("x", "engaged", 2)
        prefilter.record("x", "fallback")
        prefilter.record("x", "accepted", 0)  # no-op
        st = prefilter.stats()["x"]
        assert st["engaged"] == 5 and st["fallback"] == 1 and st["accepted"] == 0
        st["engaged"] = 999  # snapshot is a copy
        assert prefilter.stats()["x"]["engaged"] == 5
        prefilter.reset_stats()
        assert prefilter.stats() == {}

    def test_caps_are_shape_rungs(self):
        from repro.core import shapes

        assert prefilter.sc_cap(1024) == shapes.rung(1025)
        assert prefilter.sc_cap(1024) >= 1025
        assert prefilter.lb_cap() == shapes.rung(prefilter.lb_cap())
