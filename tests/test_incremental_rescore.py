"""Exactness + reuse pins for the incremental commit-delta rescoring
trackers (repro.core.incremental).

The contract (module docstring there): decisions with the trackers
enabled are **bit-identical** to the from-scratch path over any mix of
commits, failures, heals, releases and rollbacks — the trackers only
skip recomputation they can prove redundant, and self-heal on any
out-of-band mutation.  Each D-Rex scheduler exposes the from-scratch
path by setting its tracker attributes to ``None``.

Reuse is pinned too (``hits > 0`` after a commit-heavy run): an
exactness-preserving tracker that never hits would be dead code.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import ClusterView, DataItem, PlacementEngine, StorageNode
from repro.core.algorithms import DRexLB, DRexSC, saturation_score
from repro.core.incremental import FreeOrderTracker, SaturationTracker
from repro.storage.traces import make_trace


def _cluster(n: int = 14, seed: int = 5, equal_caps: bool = False) -> ClusterView:
    rng = np.random.default_rng(seed)
    nodes = [
        StorageNode(
            node_id=i,
            capacity_mb=1e6 if equal_caps else float(rng.uniform(4e5, 2e6)),
            write_bw=float(rng.uniform(100, 250)),
            read_bw=float(rng.uniform(100, 400)),
            annual_failure_rate=float(rng.uniform(0.003, 0.05)),
        )
        for i in range(n)
    ]
    return ClusterView.from_nodes(nodes)


def _items(n: int = 36, seed: int = 9):
    return make_trace("meva", seed=seed, n_items=n)


def _fresh(algo_cls, *, tracked: bool):
    sched = algo_cls()
    if not tracked:
        sched._order_tracker = None
        if hasattr(sched, "_sat_tracker"):
            sched._sat_tracker = None
    return sched


def _drive(engine: PlacementEngine):
    """One commit-heavy adversarial sequence: streaming placements with a
    failure, a heal, a release, and a snapshot/rollback pair interleaved
    — every mutation class the trackers must absorb or self-heal from."""
    placements = []
    released = None
    snap = None
    for i, item in enumerate(_items()):
        rec = engine.place(item)
        placements.append((rec.item_id, rec.ok, rec.placement))
        if rec.ok and released is None and i == 8:
            engine.release(rec)
            released = rec.item_id
        if i == 12:
            engine.cluster.fail_node(3)
        if i == 18:
            engine.cluster.heal_node(3)
        if i == 22:
            snap = engine.snapshot()
        if i == 25:
            engine.rollback(snap)
    return placements


class TestBitIdenticalDecisions:
    @pytest.mark.parametrize("algo_cls", [DRexLB, DRexSC], ids=["lb", "sc"])
    def test_adversarial_sequence(self, algo_cls):
        fast = _fresh(algo_cls, tracked=True)
        slow = _fresh(algo_cls, tracked=False)
        got = _drive(PlacementEngine(_cluster(), fast))
        want = _drive(PlacementEngine(_cluster(), slow))
        assert got == want
        # reuse must actually happen, or the tracker is dead code
        assert fast._order_tracker.hits > 0

    def test_sc_saturation_reuse(self):
        fast = _fresh(DRexSC, tracked=True)
        _drive(PlacementEngine(_cluster(), fast))
        assert fast._sat_tracker.hits > 0
        assert len(fast._sat_tracker._scores) <= SaturationTracker.MAX_ANCHORS

    @pytest.mark.parametrize("algo_cls", [DRexLB, DRexSC], ids=["lb", "sc"])
    def test_equal_capacity_ties(self, algo_cls):
        """All-equal capacities: every commit reorders near-ties, forcing
        the adjacency check's invalidation path constantly — decisions
        must still match the from-scratch argsort (ties break by id)."""
        fast = _fresh(algo_cls, tracked=True)
        slow = _fresh(algo_cls, tracked=False)
        eng_f = PlacementEngine(_cluster(equal_caps=True), fast)
        eng_s = PlacementEngine(_cluster(equal_caps=True), slow)
        for item in _items(24):
            rf, rs = eng_f.place(item), eng_s.place(item)
            assert (rf.ok, rf.placement) == (rs.ok, rs.placement)

    def test_batched_path_matches_scalar_with_trackers(self):
        """place_many on the kernel path with trackers live == per-item
        place with trackers disabled (the strongest end-to-end pin)."""
        fast = _fresh(DRexSC, tracked=True)
        slow = _fresh(DRexSC, tracked=False)
        recs = PlacementEngine(_cluster(), fast).place_many(_items(20))
        eng = PlacementEngine(_cluster(), slow)
        seq = [eng.place(it) for it in _items(20)]
        # both engines started from identical clusters; same decisions
        assert [(r.ok, r.placement) for r in recs] == [
            (r.ok, r.placement) for r in seq
        ]


class TestFreeOrderTracker:
    def _order_oracle(self, cluster):
        ids = cluster.live_ids()
        return ids[np.argsort(-cluster.free_mb[ids], kind="stable")]

    def test_valid_commit_keeps_cache(self):
        cluster = _cluster(8)
        tr = FreeOrderTracker()
        first = tr.order(cluster)
        assert np.array_equal(first, self._order_oracle(cluster))
        # tiny commit to the most-free node: order provably unchanged
        top = int(first[0])
        margin = cluster.free_mb[top] - cluster.free_mb[int(first[1])]
        cluster.commit(_placement([top]), float(margin) / 2)
        tr.observe_commit([top], float(margin) / 2, cluster)
        before = tr.rebuilds
        again = tr.order(cluster)
        assert tr.rebuilds == before and tr.hits >= 1
        assert np.array_equal(again, self._order_oracle(cluster))

    def test_order_flip_invalidates_and_rebuilds_correctly(self):
        cluster = _cluster(8)
        tr = FreeOrderTracker()
        first = tr.order(cluster)
        top, second = int(first[0]), int(first[1])
        # push the top node below the runner-up: adjacency violated
        delta = float(cluster.free_mb[top] - cluster.free_mb[second]) + 1.0
        cluster.commit(_placement([top]), delta)
        tr.observe_commit([top], delta, cluster)
        rebuilt = tr.order(cluster)
        assert np.array_equal(rebuilt, self._order_oracle(cluster))
        assert int(rebuilt[0]) == second

    def test_out_of_band_mutation_self_heals(self):
        cluster = _cluster(8)
        tr = FreeOrderTracker()
        tr.order(cluster)
        cluster.fail_node(int(cluster.live_ids()[0]))  # no observe_commit
        healed = tr.order(cluster)  # mirror mismatch -> rebuild
        assert np.array_equal(healed, self._order_oracle(cluster))
        assert tr.rebuilds >= 2


class TestSaturationTracker:
    def _oracle(self, cluster, smin):
        live = cluster.live_ids()
        return float(
            saturation_score(
                cluster.used_mb[live], cluster.capacity_mb[live], smin, len(live)
            ).sum()
        )

    def test_bit_equal_across_commits(self):
        cluster = _cluster(8)
        tr = SaturationTracker()
        smin = 42.0
        assert tr.f_base_sum(cluster, smin) == self._oracle(cluster, smin)
        nodes = [0, 3, 5]
        cluster.commit(_placement(nodes), 500.0)
        tr.observe_commit(nodes, 500.0, cluster)
        assert tr.f_base_sum(cluster, smin) == self._oracle(cluster, smin)
        assert tr.hits >= 1

    def test_out_of_band_mutation_self_heals(self):
        cluster = _cluster(8)
        tr = SaturationTracker()
        smin = 17.0
        tr.f_base_sum(cluster, smin)
        cluster.used_mb[2] += 1234.0  # mutation the tracker never saw
        assert tr.f_base_sum(cluster, smin) == self._oracle(cluster, smin)

    def test_anchor_bound(self):
        cluster = _cluster(8)
        tr = SaturationTracker()
        for k in range(3 * SaturationTracker.MAX_ANCHORS):
            tr.f_base_sum(cluster, float(k + 1))
            assert len(tr._scores) <= SaturationTracker.MAX_ANCHORS


def _placement(node_ids):
    """Minimal stand-in with the ``node_ids`` attribute
    :meth:`ClusterView.commit` consumes."""
    return dataclasses.make_dataclass("P", ["node_ids"])(list(node_ids))
