"""Batched erasure-coding data plane: the multi-item launch paths
(``encode_chunks_many`` / ``decode_chunks_many`` and their codec
wrappers) pinned bit-for-bit against the per-item oracle, plus the
coding-matrix LRU cache and the compile census."""

import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev-only dep (requirements-dev.txt)
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import shapes as core_shapes
from repro.ec import ECCodec, encode_batch, plan_cohorts
from repro.kernels import ops


def _payloads(lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 256, size=n, dtype=np.uint8).tobytes() for n in lengths
    ]


class TestEncodeMany:
    @given(
        k=st.integers(2, 8),
        p=st.integers(1, 4),
        lengths=st.lists(st.integers(0, 9000), min_size=1, max_size=8),
        seed=st.integers(0, 2**31),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_matches_per_item(self, k, p, lengths, seed):
        """One cohort launch is byte-identical to per-item encodes across
        mixed lengths, tail (non-bucket-aligned) widths and empties."""
        payloads = _payloads(lengths, seed)
        codec = ECCodec(k, p)
        got = codec.encode_many(payloads)
        want = [codec.encode(pl) for pl in payloads]
        assert len(got) == len(want)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)

    def test_mixed_kp_batch_matches_per_item(self):
        specs = [(3, 2), (6, 3), (3, 2), (4, 2), (6, 3)]
        payloads = _payloads([5000, 100, 0, 8192, 2048], seed=3)
        got = encode_batch(specs, payloads)
        for (k, p), pl, chunks in zip(specs, payloads, got):
            np.testing.assert_array_equal(chunks, ECCodec(k, p).encode(pl))

    def test_cohort_mixing_k_raises(self):
        with pytest.raises(ValueError, match="plan_cohorts"):
            ops.encode_chunks_many(
                [np.zeros((3, 8), np.uint8), np.zeros((4, 8), np.uint8)], 2
            )

    def test_empty_cohort(self):
        assert ops.encode_chunks_many([], 2) == []

    def test_pallas_interpret_matches(self):
        """The forced-Pallas cohort launch (interpret off-TPU) agrees."""
        datas = [
            np.random.default_rng(i).integers(0, 256, size=(4, 3000), dtype=np.uint8)
            for i in range(3)
        ]
        got = ops.encode_chunks_many(datas, 2, pallas=True)
        want = [np.asarray(ops.encode_chunks(d, 2, use_kernel=False)) for d in datas]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)


class TestDecodeMany:
    @given(
        k=st.integers(2, 6),
        p=st.integers(1, 3),
        lengths=st.lists(st.integers(0, 6000), min_size=1, max_size=6),
        seed=st.integers(0, 2**31),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_roundtrip_mixed_erasures(self, k, p, lengths, seed):
        rng = np.random.default_rng(seed)
        payloads = _payloads(lengths, seed)
        codec = ECCodec(k, p)
        parts = []
        for pl, chunks in zip(payloads, codec.encode_many(payloads)):
            keep = np.sort(rng.choice(k + p, size=k, replace=False))
            parts.append((chunks[keep], keep, len(pl)))
        got = codec.decode_many(parts)
        want = [codec.decode(*part) for part in parts]
        assert got == want == payloads

    def test_systematic_fast_path_no_kernel(self):
        """All-systematic items decode with zero launches or matrix work."""
        codec = ECCodec(3, 2)
        payloads = _payloads([4000, 2000], seed=5)
        chunks = codec.encode_many(payloads)
        rows = np.arange(3)
        ops.reset_matrix_caches()
        before = core_shapes.compile_cache_stats()["kernels"].get(
            ops.CENSUS_KERNEL, {"calls": 0}
        )["calls"]
        got = codec.decode_many(
            [(c[:3], rows, len(pl)) for c, pl in zip(chunks, payloads)]
        )
        after = core_shapes.compile_cache_stats()["kernels"].get(
            ops.CENSUS_KERNEL, {"calls": 0}
        )["calls"]
        assert got == payloads
        assert after == before
        assert ops.matrix_cache_stats()["decode_builds"] == 0

    def test_groups_by_erasure_pattern(self):
        """Items sharing a survivor pattern share one decode launch."""
        codec = ECCodec(4, 2)
        payloads = _payloads([3000, 3000, 3000], seed=9)
        chunks = codec.encode_many(payloads)
        rows_a = np.array([1, 2, 4, 5])  # two items on pattern a
        rows_b = np.array([0, 2, 3, 5])
        parts = [
            (chunks[0][rows_a], rows_a, len(payloads[0])),
            (chunks[1][rows_b], rows_b, len(payloads[1])),
            (chunks[2][rows_a], rows_a, len(payloads[2])),
        ]
        ops.reset_matrix_caches()
        assert codec.decode_many(parts) == payloads
        assert ops.matrix_cache_stats()["decode_builds"] == 2  # a and b


class TestMatrixCache:
    def test_repeated_decode_builds_matrix_once(self):
        """The satellite regression: N decodes of one erasure pattern pay
        the Gauss-Jordan inversion exactly once (the counter hook)."""
        codec = ECCodec(4, 2)
        payload = _payloads([5000], seed=1)[0]
        chunks = codec.encode(payload)
        keep = np.array([1, 3, 4, 5])
        ops.reset_matrix_caches()
        for _ in range(5):
            assert codec.decode(chunks[keep], keep, len(payload)) == payload
        stats = ops.matrix_cache_stats()
        assert stats["decode_builds"] == 1
        assert stats["decode_cache"]["hits"] == 4

    def test_repeated_encode_builds_matrix_once(self):
        codec = ECCodec(5, 3)
        payloads = _payloads([100, 200, 300], seed=2)
        ops.reset_matrix_caches()
        for pl in payloads:
            codec.encode(pl)
        codec.encode_many(payloads)
        assert ops.matrix_cache_stats()["encode_builds"] == 1

    def test_decode_cache_is_lru_bounded(self):
        """More erasure patterns than MATRIX_CACHE_SIZE: the cache must
        evict (bounded memory) and rebuild on re-miss, never grow."""
        k, p = 3, 13  # C(16, 3) = 560 patterns > 256
        patterns = list(itertools.combinations(range(k + p), k))
        assert len(patterns) > ops.MATRIX_CACHE_SIZE
        ops.reset_matrix_caches()
        for rows in patterns:
            ops._decode_matrices(k, p, rows)
        stats = ops.matrix_cache_stats()
        assert stats["decode_builds"] == len(patterns)
        assert stats["decode_cache"]["size"] <= ops.MATRIX_CACHE_SIZE
        # the earliest pattern was evicted: touching it again rebuilds
        ops._decode_matrices(k, p, patterns[0])
        assert ops.matrix_cache_stats()["decode_builds"] == len(patterns) + 1

    def test_cached_matrices_are_readonly(self):
        cauchy, _ = ops._encode_matrices(4, 2)
        with pytest.raises(ValueError):
            cauchy[0, 0] = 1


class TestCompileCensus:
    def test_one_compile_per_bucket_rung(self):
        """Steady-state cohorts that land in one (K, P, bucket) rung
        issue exactly one kernel signature; repeats issue none."""
        k, p = 9, 5  # (K, P) unused elsewhere in the suite
        codec = ECCodec(k, p)
        payloads = _payloads([4000, 4100, 3900], seed=4)
        before = core_shapes.issued_shapes(ops.CENSUS_KERNEL)
        codec.encode_many(payloads)  # first launch: one new signature
        issued = core_shapes.issued_shapes(ops.CENSUS_KERNEL)
        assert len(issued - before) == 1
        # same cohort widths -> same bucket -> zero new signatures
        codec.encode_many(payloads)
        codec.encode_many(list(reversed(payloads)))
        assert core_shapes.issued_shapes(ops.CENSUS_KERNEL) == issued


class TestPlanCohorts:
    def test_partitions_in_first_appearance_order(self):
        specs = [(3, 2), (6, 3), (3, 2), (4, 2), (6, 3), (3, 2)]
        got = plan_cohorts(specs)
        assert got == [
            ((3, 2), [0, 2, 5]),
            ((6, 3), [1, 4]),
            ((4, 2), [3]),
        ]

    def test_empty(self):
        assert plan_cohorts([]) == []


class TestEmptyPayload:
    """Satellite regression: zero-length payloads get a well-defined
    empty manifest everywhere instead of a kernel-shape crash."""

    def test_encode_empty_shape(self):
        codec = ECCodec(4, 2)
        chunks = codec.encode(b"")
        assert chunks.shape == (6, 0)
        assert chunks.dtype == np.uint8

    def test_decode_empty_roundtrip(self):
        codec = ECCodec(4, 2)
        chunks = codec.encode(b"")
        keep = np.array([0, 2, 4, 5])
        assert codec.decode(chunks[keep], keep, 0) == b""

    def test_encode_many_mixed_empty(self):
        codec = ECCodec(3, 1)
        got = codec.encode_many([b"", b"abc", b""])
        assert got[0].shape == (4, 0)
        assert got[2].shape == (4, 0)
        np.testing.assert_array_equal(got[1], codec.encode(b"abc"))

    def test_decode_many_mixed_empty(self):
        codec = ECCodec(3, 1)
        payloads = [b"", b"some payload bytes"]
        chunks = codec.encode_many(payloads)
        keep = np.array([0, 1, 3])
        parts = [(c[keep], keep, len(pl)) for c, pl in zip(chunks, payloads)]
        assert codec.decode_many(parts) == payloads
