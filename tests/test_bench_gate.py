"""Unit tests for the benchmark-regression gate (benchmarks/gate.py).

The gate compares freshly emitted benchmark JSON against committed
baselines and must: fail when a gated decision-cost metric regresses
beyond the budget (the issue's 'demonstrably fails when a committed
metric is artificially inflated >20%' criterion), pass within the
budget, and skip — never fail — when the comparison would not be
like-for-like (schema or smoke-mode mismatch, missing files/metrics).
Synthetic JSON only; no benchmarks are executed.
"""

import json

import pytest

from benchmarks import gate
from benchmarks.common import SCHEMA_VERSION

METRIC = "batched_greedy.greedy_min_storage.decision_cost.speedup_vs_scalar"


def payload(speedup: float, *, smoke=True, schema=SCHEMA_VERSION, sha="abc123"):
    return {
        "batched_sc": {"decision_cost": {"speedup_vs_scalar": 6.0}},
        "batched_greedy": {
            "greedy_min_storage": {
                "decision_cost": {"speedup_vs_scalar": speedup},
                "committed": {"speedup_vs_scalar": 12.0},
            },
            "greedy_least_used": {
                "decision_cost": {"speedup_vs_scalar": 1.1},
            },
        },
        "meta": {"schema_version": schema, "git_sha": sha, "smoke": smoke},
    }


def write(dirpath, name, data):
    dirpath.mkdir(parents=True, exist_ok=True)
    (dirpath / f"{name}.json").write_text(json.dumps(data))


@pytest.fixture
def dirs(tmp_path):
    return tmp_path / "fresh", tmp_path / "baseline"


class TestRegressionDetection:
    def test_inflated_baseline_fails_the_gate(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(50.0))
        # Baseline claims >20% more than the fresh run delivers.
        write(base, "table2", payload(80.0))
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert len(failures) == 1
        assert METRIC in failures[0]
        assert "abc123" in failures[0]  # baseline sha surfaces in the report

    def test_within_budget_passes(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(50.0))
        write(base, "table2", payload(55.0))  # -9%: inside the 20% budget
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert failures == []

    def test_improvement_passes(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(90.0))
        write(base, "table2", payload(50.0))
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert failures == []

    def test_boundary_is_exactly_the_threshold(self, dirs):
        fresh, base = dirs
        write(base, "table2", payload(100.0))
        write(fresh, "table2", payload(80.0))  # exactly -20%: not a failure
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        write(fresh, "table2", payload(79.9))  # just past the budget
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert len(failures) == 1

    def test_custom_threshold(self, dirs):
        fresh, base = dirs
        write(base, "table2", payload(100.0))
        write(fresh, "table2", payload(95.0))
        failures, _ = gate.check_against(fresh, base, ["table2"], threshold=0.01)
        assert len(failures) == 1


class TestLikeForLike:
    """Mismatched comparisons are skipped with a note, never failed."""

    def test_smoke_mode_mismatch_is_skipped(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(10.0, smoke=True))
        write(base, "table2", payload(80.0, smoke=False))  # full-sweep baseline
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("smoke-mode mismatch" in n for n in notes)

    def test_schema_version_mismatch_is_skipped(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(10.0))
        write(base, "table2", payload(80.0, schema=SCHEMA_VERSION + 1))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("schema_version mismatch" in n for n in notes)

    def test_missing_baseline_is_skipped(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(10.0))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("no baseline" in n for n in notes)

    def test_missing_fresh_results_is_skipped(self, dirs):
        fresh, base = dirs
        write(base, "table2", payload(80.0))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("no fresh results" in n for n in notes)

    def test_absent_metric_is_skipped(self, dirs):
        fresh, base = dirs
        slim = payload(50.0)
        del slim["batched_greedy"]["greedy_min_storage"]["committed"]
        write(fresh, "table2", slim)
        write(base, "table2", payload(50.0))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("committed.speedup_vs_scalar" in n and "absent" in n
                   for n in notes)

    def test_ungated_benchmarks_are_ignored(self, dirs):
        fresh, base = dirs
        failures, notes = gate.check_against(fresh, base, ["fig12", "fig6"])
        assert failures == [] and notes == []

    def test_differing_benchmark_parameters_are_skipped(self, dirs):
        # A re-tuned sweep (different node/batch counts) must be skipped
        # until its baselines are regenerated, not gated apples-to-oranges.
        fresh, base = dirs
        retuned = payload(10.0)
        retuned["batched_greedy"]["greedy_min_storage"]["n_nodes"] = 500
        write(fresh, "table2", retuned)
        sized = payload(80.0)
        sized["batched_greedy"]["greedy_min_storage"]["n_nodes"] = 100
        write(base, "table2", sized)
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert all("greedy_min_storage" not in f for f in failures)
        assert any("parameters differ" in n for n in notes)

    def test_damaged_baseline_json_is_skipped_not_fatal(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(50.0))
        base.mkdir(parents=True, exist_ok=True)
        (base / "table2.json").write_text("{truncated")
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("no baseline" in n for n in notes)

    def test_non_dict_payload_is_skipped_not_fatal(self, dirs):
        fresh, base = dirs
        base.mkdir(parents=True, exist_ok=True)
        (base / "table2.json").write_text("[1, 2, 3]")
        fresh.mkdir(parents=True, exist_ok=True)
        (fresh / "table2.json").write_text(json.dumps(payload(50.0)))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("no baseline" in n for n in notes)


class TestGateConfig:
    def test_gated_metrics_exist_in_committed_smoke_baselines(self):
        # The gate config must stay in lockstep with what table2 emits —
        # a renamed metric would silently turn the gate into a no-op.
        import pathlib

        baseline = pathlib.Path("results/benchmarks/smoke/table2.json")
        if not baseline.exists():
            pytest.skip("no committed smoke baselines in this checkout")
        data = json.loads(baseline.read_text())
        assert data.get("meta", {}).get("smoke") is True
        for dotted, direction in gate.GATE_METRICS["table2"]:
            assert direction in ("higher", "lower")
            node = data
            for key in dotted.split("."):
                assert isinstance(node, dict) and key in node, (
                    f"gated metric {dotted!r} missing from the committed "
                    f"smoke baseline"
                )
                node = node[key]
            assert isinstance(node, (int, float))
