"""Unit tests for the benchmark-regression gate (benchmarks/gate.py).

The gate compares freshly emitted benchmark JSON against committed
baselines and must: fail when a gated decision-cost metric regresses
beyond the budget (the issue's 'demonstrably fails when a committed
metric is artificially inflated >20%' criterion), pass within the
budget, and skip — never fail — when the comparison would not be
like-for-like (schema or smoke-mode mismatch, missing files/metrics).
Synthetic JSON only; no benchmarks are executed.
"""

import json

import pytest

from benchmarks import gate
from benchmarks.common import SCHEMA_VERSION

METRIC = "batched_greedy.greedy_min_storage.decision_cost.speedup_vs_scalar"


def payload(speedup: float, *, smoke=True, schema=SCHEMA_VERSION, sha="abc123"):
    return {
        "batched_sc": {"decision_cost": {"speedup_vs_scalar": 6.0}},
        "batched_greedy": {
            "greedy_min_storage": {
                "decision_cost": {"speedup_vs_scalar": speedup},
                "committed": {"speedup_vs_scalar": 12.0},
            },
            "greedy_least_used": {
                "decision_cost": {"speedup_vs_scalar": 1.1},
            },
        },
        "meta": {"schema_version": schema, "git_sha": sha, "smoke": smoke},
    }


def write(dirpath, name, data):
    dirpath.mkdir(parents=True, exist_ok=True)
    (dirpath / f"{name}.json").write_text(json.dumps(data))


@pytest.fixture
def dirs(tmp_path):
    return tmp_path / "fresh", tmp_path / "baseline"


class TestRegressionDetection:
    def test_inflated_baseline_fails_the_gate(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(50.0))
        # Baseline claims >20% more than the fresh run delivers.
        write(base, "table2", payload(80.0))
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert len(failures) == 1
        assert METRIC in failures[0]
        assert "abc123" in failures[0]  # baseline sha surfaces in the report

    def test_within_budget_passes(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(50.0))
        write(base, "table2", payload(55.0))  # -9%: inside the 20% budget
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert failures == []

    def test_improvement_passes(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(90.0))
        write(base, "table2", payload(50.0))
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert failures == []

    def test_boundary_is_exactly_the_threshold(self, dirs):
        fresh, base = dirs
        write(base, "table2", payload(100.0))
        write(fresh, "table2", payload(80.0))  # exactly -20%: not a failure
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        write(fresh, "table2", payload(79.9))  # just past the budget
        failures, _ = gate.check_against(fresh, base, ["table2"])
        assert len(failures) == 1

    def test_custom_threshold(self, dirs):
        fresh, base = dirs
        write(base, "table2", payload(100.0))
        write(fresh, "table2", payload(95.0))
        failures, _ = gate.check_against(fresh, base, ["table2"], threshold=0.01)
        assert len(failures) == 1


class TestLikeForLike:
    """Mismatched comparisons are skipped with a note, never failed."""

    def test_smoke_mode_mismatch_is_skipped(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(10.0, smoke=True))
        write(base, "table2", payload(80.0, smoke=False))  # full-sweep baseline
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("smoke-mode mismatch" in n for n in notes)

    def test_schema_version_mismatch_is_skipped(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(10.0))
        write(base, "table2", payload(80.0, schema=SCHEMA_VERSION + 1))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("schema_version mismatch" in n for n in notes)

    def test_missing_baseline_is_skipped(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(10.0))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("no baseline" in n for n in notes)

    def test_missing_fresh_results_is_skipped(self, dirs):
        fresh, base = dirs
        write(base, "table2", payload(80.0))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("no fresh results" in n for n in notes)

    def test_absent_metric_is_skipped(self, dirs):
        fresh, base = dirs
        slim = payload(50.0)
        del slim["batched_greedy"]["greedy_min_storage"]["committed"]
        write(fresh, "table2", slim)
        write(base, "table2", payload(50.0))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("committed.speedup_vs_scalar" in n and "absent" in n
                   for n in notes)

    def test_ungated_benchmarks_are_ignored(self, dirs):
        fresh, base = dirs
        failures, notes = gate.check_against(fresh, base, ["fig6", "fig8"])
        assert failures == [] and notes == []

    def test_differing_benchmark_parameters_are_skipped(self, dirs):
        # A re-tuned sweep (different node/batch counts) must be skipped
        # until its baselines are regenerated, not gated apples-to-oranges.
        fresh, base = dirs
        retuned = payload(10.0)
        retuned["batched_greedy"]["greedy_min_storage"]["n_nodes"] = 500
        write(fresh, "table2", retuned)
        sized = payload(80.0)
        sized["batched_greedy"]["greedy_min_storage"]["n_nodes"] = 100
        write(base, "table2", sized)
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert all("greedy_min_storage" not in f for f in failures)
        assert any("parameters differ" in n for n in notes)

    def test_damaged_baseline_json_is_skipped_not_fatal(self, dirs):
        fresh, base = dirs
        write(fresh, "table2", payload(50.0))
        base.mkdir(parents=True, exist_ok=True)
        (base / "table2.json").write_text("{truncated")
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("no baseline" in n for n in notes)

    def test_non_dict_payload_is_skipped_not_fatal(self, dirs):
        fresh, base = dirs
        base.mkdir(parents=True, exist_ok=True)
        (base / "table2.json").write_text("[1, 2, 3]")
        fresh.mkdir(parents=True, exist_ok=True)
        (fresh / "table2.json").write_text(json.dumps(payload(50.0)))
        failures, notes = gate.check_against(fresh, base, ["table2"])
        assert failures == []
        assert any("no baseline" in n for n in notes)


def fig12_payload(retained: float, *, smoke=True, schema=SCHEMA_VERSION):
    return {
        "0.9": {
            "drex_sc": {"2": retained, "5": retained},
            "drex_lb": {"2": 1.0, "5": 1.0},
            "ec(3,2)": {"2": 1.0, "5": 0.5},
        },
        "repair_bw_sweep": {
            "drex_sc": {
                "inf": {"retained_fraction": 1.0,
                        "retained_fraction_fifo": 1.0},
                "0.01": {"retained_fraction": 0.25,
                         "retained_fraction_fifo": 0.25},
            },
            "ec(3,2)": {
                "inf": {"retained_fraction": 1.0,
                        "retained_fraction_fifo": 1.0},
                "0.01": {"retained_fraction": 0.5,
                         "retained_fraction_fifo": 0.5},
            },
        },
        "rack_event": {
            "drex_sc": {
                "inf": {"topo_retained": 1.0, "blind_retained": 0.9},
                "0.01": {"topo_retained": 1.0, "blind_retained": 0.9},
            },
            "ec(3,2)": {
                "inf": {"topo_retained": 1.0, "blind_retained": 1.0},
                "0.01": {"topo_retained": 1.0, "blind_retained": 1.0},
            },
            "meets_improvement_floor": 1,
            "improvement_ratio": 1.05,
        },
        "meta": {"schema_version": schema, "git_sha": "abc123", "smoke": smoke},
    }


class TestEqualityGating:
    """fig12's deterministic retained fractions gate on exact equality:
    the numbers are seeded-simulation outputs, so any drift means the
    placement/repair behavior changed — not the machine."""

    def test_identical_values_pass(self, dirs):
        fresh, base = dirs
        write(fresh, "fig12", fig12_payload(0.75))
        write(base, "fig12", fig12_payload(0.75))
        failures, _ = gate.check_against(fresh, base, ["fig12"])
        assert failures == []

    def test_any_drift_fails_regardless_of_threshold(self, dirs):
        fresh, base = dirs
        write(fresh, "fig12", fig12_payload(0.7500001))  # way inside 20%
        write(base, "fig12", fig12_payload(0.75))
        failures, _ = gate.check_against(fresh, base, ["fig12"])
        assert len(failures) == 2  # both drex_sc cells drifted
        assert all("deterministic metric drifted" in f for f in failures)

    def test_drift_in_either_direction_fails(self, dirs):
        fresh, base = dirs
        write(fresh, "fig12", fig12_payload(0.80))  # "improvement" drifts too
        write(base, "fig12", fig12_payload(0.75))
        failures, _ = gate.check_against(fresh, base, ["fig12"])
        assert len(failures) == 2

    def test_dotted_rt_keys_resolve_via_tuple_paths(self, dirs):
        # "0.9" is one JSON key; the tuple-path form must not split it.
        fresh, base = dirs
        write(fresh, "fig12", fig12_payload(0.75))
        write(base, "fig12", fig12_payload(0.75))
        _, notes = gate.check_against(fresh, base, ["fig12"])
        assert not any("absent" in n for n in notes)

    def test_smoke_mismatch_skips_equality_metrics_too(self, dirs):
        fresh, base = dirs
        write(fresh, "fig12", fig12_payload(0.1, smoke=True))
        write(base, "fig12", fig12_payload(0.9, smoke=False))
        failures, notes = gate.check_against(fresh, base, ["fig12"])
        assert failures == []
        assert any("smoke-mode mismatch" in n for n in notes)


class TestGateConfig:
    @pytest.mark.parametrize("name", sorted(gate.GATE_METRICS))
    def test_gated_metrics_exist_in_committed_smoke_baselines(self, name):
        # The gate config must stay in lockstep with what the benchmarks
        # emit — a renamed metric would silently turn the gate into a
        # no-op.
        import pathlib

        baseline = pathlib.Path(f"results/benchmarks/smoke/{name}.json")
        if not baseline.exists():
            pytest.skip("no committed smoke baselines in this checkout")
        data = json.loads(baseline.read_text())
        assert data.get("meta", {}).get("smoke") is True
        for path, direction in gate.GATE_METRICS[name]:
            assert direction in ("higher", "lower", "equal")
            node = data
            for key in gate._path_keys(path):
                assert isinstance(node, dict) and key in node, (
                    f"gated metric {gate._path_str(path)!r} missing from "
                    f"the committed smoke baseline"
                )
                node = node[key]
            assert isinstance(node, (int, float))
