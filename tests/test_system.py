"""End-to-end system behaviour tests: the paper's headline claims on
fast CPU-scaled workloads, and the full train→checkpoint→fail→restore→
serve pipeline through the public API."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointPolicy, DRexCheckpointer, StorageFabric
from repro.configs import get_config
from repro.core import SCHEDULER_NAMES, create_scheduler
from repro.data import DataConfig
from repro.launch import make_local_mesh
from repro.optim import AdamWConfig
from repro.serve import ServeConfig, ServingEngine
from repro.storage import make_node_set, make_trace, run_simulation
from repro.train import Trainer, TrainerConfig, init_train_state

# full-pipeline e2e simulations: full lane only (deselect via -m "not slow").
pytestmark = pytest.mark.slow


SOTA = ["ec(3,2)", "ec(4,2)", "ec(6,3)", "daos"]


@pytest.fixture(scope="module")
def saturating_results():
    nodes = make_node_set("most_used", capacity_scale=0.001)
    cap = sum(n.capacity_mb for n in nodes)
    items = make_trace("meva", seed=0, total_mb=cap * 0.95)
    return {
        name: run_simulation(nodes, create_scheduler(name), items)
        for name in SOTA + ["drex_sc", "drex_lb", "greedy_min_storage", "greedy_least_used"]
    }


class TestPaperHeadlines:
    """§5 claims, structurally reproduced at CPU scale."""

    def test_drex_stores_more_than_sota_average(self, saturating_results):
        r = saturating_results
        avg_sota = sum(r[a].stored_mb for a in SOTA) / len(SOTA)
        assert r["drex_sc"].stored_mb > 1.15 * avg_sota
        assert r["drex_lb"].stored_mb > 1.10 * avg_sota

    def test_greedy_min_storage_stores_most(self, saturating_results):
        r = saturating_results
        best = max(v.stored_mb for v in r.values())
        assert r["greedy_min_storage"].stored_mb == pytest.approx(best, rel=0.02)

    def test_sc_nearly_matches_gms_with_better_throughput(self, saturating_results):
        r = saturating_results
        assert r["drex_sc"].stored_mb > 0.85 * r["greedy_min_storage"].stored_mb
        assert r["drex_sc"].throughput_mbps > r["greedy_min_storage"].throughput_mbps

    def test_static_ec_fails_extreme_reliability(self):
        """Fig. 5 'missing bars': fixed (K,P) can't reach 7 nines."""
        nodes = make_node_set("most_used", capacity_scale=0.001)
        items = make_trace("meva", seed=0, n_items=60, reliability=0.9999999)
        for algo in ("ec(3,2)", "ec(4,2)", "ec(6,3)"):
            res = run_simulation(nodes, create_scheduler(algo), items)
            assert res.n_stored == 0, algo
        res = run_simulation(nodes, create_scheduler("drex_sc"), items)
        assert res.n_stored == len(items)

    def test_dynamic_algorithms_survive_more_failures(self):
        """Fig. 12 pattern at RT 90%, non-saturating: 4 failures drawn by
        failure-rate weight (the paper's protocol). Dynamic reschedules
        retain ~everything; EC(6,3) needs 9 live nodes and collapses."""
        from repro.storage import SimConfig

        nodes = make_node_set("most_unreliable", capacity_scale=0.001)
        cap = sum(n.capacity_mb for n in nodes)
        items = make_trace("meva", seed=1, total_mb=cap * 0.15, reliability=0.9)
        sched = tuple((20.0 + 10 * i, -1) for i in range(4))  # weighted draws
        cfg = SimConfig(failure_schedule=sched, seed=1)
        dyn = run_simulation(nodes, create_scheduler("drex_sc"), items, cfg)
        assert dyn.retained_fraction > 0.95
        static = run_simulation(
            nodes, create_scheduler("ec(6,3)"), items, SimConfig(failure_schedule=sched, seed=1)
        )
        assert static.retained_fraction < 0.5
        assert dyn.retained_fraction > static.retained_fraction + 0.4


class TestFullPipeline:
    def test_train_checkpoint_fail_restore_serve(self):
        """The whole stack, one story: train a smoke model with D-Rex EC
        checkpoints, kill storage nodes, restore bit-exact, serve."""
        cfg = get_config("qwen3-8b", smoke=True)
        fabric = StorageFabric(make_node_set("most_used", capacity_scale=1e-4))
        ck = DRexCheckpointer(
            fabric, "drex_sc",
            CheckpointPolicy(item_mb=0.5, reliability_target=0.99999,
                             retention_days=365.0),
        )
        like = init_train_state(cfg, jax.random.PRNGKey(0))

        class Adapter:
            def save(self, st, step):
                ck.save(st, step)

            def save_async(self, st, step):
                return ck.save_async(st, step)

            def restore_latest(self, _):
                return ck.restore_latest(like)

        trainer = Trainer(
            cfg, AdamWConfig(lr=3e-3),
            TrainerConfig(steps=8, log_every=4, ckpt_every=4, async_ckpt=False),
            data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4),
            mesh=make_local_mesh(1, 1),
            checkpointer=Adapter(),
        )
        state = trainer.run()

        # two storage nodes die; the checkpoint must survive (P >= 2)
        fabric.fail_node(0)
        fabric.fail_node(4)
        restored, step = ck.restore_latest(like)
        assert step == 8
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

        # serve from the restored weights
        engine = ServingEngine(cfg, restored.params, ServeConfig(max_new_tokens=4))
        prompts = np.ones((2, 8), np.int32)
        out = engine.generate(prompts)
        assert out.shape == (2, 12)
        assert out.dtype == np.int32

    def test_checkpoint_overhead_tracks_drex_placement(self):
        """The checkpointer's storage overhead equals N/K of the D-Rex
        placements it received (EC accounting is airtight end to end)."""
        cfg = get_config("yi-6b", smoke=True)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        fabric = StorageFabric(make_node_set("most_used", capacity_scale=1e-4))
        ck = DRexCheckpointer(fabric, "greedy_least_used", CheckpointPolicy(item_mb=0.25))
        man = ck.save(state, 1)
        ratios = []
        for meta in man["leaves"]:
            for g in meta["groups"]:
                ratios.append((g["k"] + g["p"]) / g["k"])
        got = ck.stats["bytes_stored"] / ck.stats["bytes_raw"]
        assert min(ratios) - 0.01 <= got <= max(ratios) + 0.35  # + bucket padding
