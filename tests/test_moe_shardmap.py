"""Equivalence of the shard_map MoE dispatch vs the global-view scatter
path (the §Perf iteration-11 optimization must not change the math)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_mesh_compat
from repro.models import forward, init_params
from repro.models.sharding import activate_mesh

# shard_map dispatch equivalence sweeps: full lane only (deselect via -m "not slow").
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3_moe_30b_a3b", smoke=True)
    # generous capacity so local-vs-global queue semantics coincide
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
                    moe_dispatch="scatter")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    return cfg, params, toks


def _mesh():
    # AxisType-compatible on jax <= 0.4.x (no axis_types kwarg there).
    return make_mesh_compat((1, 1), ("data", "model"))


class TestShardMapDispatch:
    def test_forward_bit_exact(self, setup):
        cfg, params, toks = setup
        ref, _ = forward(params, toks, cfg)
        mesh = _mesh()
        cfg_sm = cfg.with_(moe_dispatch="shard_map")
        with activate_mesh(mesh), mesh:
            got, _ = jax.jit(lambda p, t: forward(p, t, cfg_sm))(params, toks)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    def test_gradients_bit_exact(self, setup):
        cfg, params, toks = setup
        g_ref = jax.grad(lambda p: (forward(p, toks, cfg)[0] ** 2).mean())(params)
        mesh = _mesh()
        cfg_sm = cfg.with_(moe_dispatch="shard_map")
        with activate_mesh(mesh), mesh:
            g_sm = jax.jit(
                jax.grad(lambda p: (forward(p, toks, cfg_sm)[0] ** 2).mean())
            )(params)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sm)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_falls_back_without_mesh(self, setup):
        """No active mesh -> scatter path (CPU tests, eager use)."""
        cfg, params, toks = setup
        cfg_sm = cfg.with_(moe_dispatch="shard_map")
        ref, _ = forward(params, toks, cfg)
        got, _ = forward(params, toks, cfg_sm)  # no activate_mesh
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

    def test_padded_experts_with_shardmap(self):
        """qwen2-moe config: padding + shard_map together."""
        cfg = get_config("qwen2_moe_a2_7b", smoke=True)
        cfg = cfg.with_(
            moe=dataclasses.replace(cfg.moe, pad_experts_to=12, capacity_factor=8.0)
        )
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
        ref, _ = forward(params, toks, cfg.with_(moe_dispatch="scatter"))
        mesh = _mesh()
        with activate_mesh(mesh), mesh:
            got, _ = jax.jit(
                lambda p, t: forward(p, t, cfg.with_(moe_dispatch="shard_map"))
            )(params, toks)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
