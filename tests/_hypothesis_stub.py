"""Deterministic fallback for the optional `hypothesis` dev dependency.

When `hypothesis` is installed (see requirements-dev.txt) the property
tests use it directly; when it is missing, this stub re-implements the
tiny subset of the API the test-suite uses (`given`, `settings`,
`strategies.{floats,integers,lists,sampled_from}`) as a fixed-seed
random sweep, so the invariants still execute instead of the whole
module failing collection.

Differences from real hypothesis, by design:

* examples are drawn from a PRNG seeded by the test's qualified name —
  fully deterministic run-to-run, no shrinking, no example database;
* the number of examples is capped (default 25) to bound runtime;
* boundary values (min/max) are drawn with elevated probability since
  there is no coverage-guided search.
"""

from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

_MAX_EXAMPLES_CAP = 30


class _Strategy:
    def draw(self, rng: np.random.Generator):
        raise NotImplementedError


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def draw(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return float(rng.uniform(self.lo, self.hi))


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def draw(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Lists(_Strategy):
    def __init__(self, elements: _Strategy, min_size: int, max_size: int):
        self.elements = elements
        self.min_size, self.max_size = int(min_size), int(max_size)

    def draw(self, rng):
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.draw(rng) for _ in range(n)]


class _SampledFrom(_Strategy):
    def __init__(self, seq):
        self.seq = list(seq)

    def draw(self, rng):
        return self.seq[int(rng.integers(len(self.seq)))]


class strategies:
    """Namespace mirror of ``hypothesis.strategies``."""

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Floats(min_value, max_value)

    @staticmethod
    def integers(min_value=0, max_value=1, **_):
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elements, min_size=0, max_size=10, **_):
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def sampled_from(seq):
        return _SampledFrom(seq)


def settings(max_examples: int = 25, **_):
    """Records the example budget on the test function (capped)."""

    def deco(fn):
        fn._stub_max_examples = min(int(max_examples), _MAX_EXAMPLES_CAP)
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test once per drawn example (deterministic per test)."""

    def deco(fn):
        sig = inspect.signature(fn)
        param_names = [p for p in sig.parameters if p != "self"]
        pos_names = param_names[: len(arg_strategies)]

        @functools.wraps(fn)
        def wrapper(*args):  # args is () or (self,)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            n = getattr(fn, "_stub_max_examples", 25)
            for _ in range(min(n, _MAX_EXAMPLES_CAP)):
                kwargs = {
                    name: strat.draw(rng)
                    for name, strat in zip(pos_names, arg_strategies)
                }
                kwargs.update(
                    {name: strat.draw(rng) for name, strat in kw_strategies.items()}
                )
                fn(*args, **kwargs)

        # Hide the strategy-filled parameters from pytest (which would
        # otherwise try to resolve them as fixtures via __wrapped__).
        del wrapper.__wrapped__
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in sig.parameters.values() if p.name == "self"]
        )
        return wrapper

    return deco
