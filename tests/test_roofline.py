"""Roofline analyzer tests: jaxpr FLOP walker (scan/remat aware) and
post-SPMD HLO byte/collective analysis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analyze_hlo, count_fn_flops
from repro.roofline.terms import RooflineTerms


class TestJaxprFlops:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        fc = count_fn_flops(lambda x, y: x @ y, a, b)
        assert fc.dot_flops == 2 * 64 * 128 * 32

    def test_scan_multiplies_by_length(self):
        x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)

        def f(x, ws):
            return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

        fc = count_fn_flops(f, x, ws)
        assert fc.dot_flops == 7 * 2 * 16 * 32 * 32

    def test_scanned_equals_unrolled(self):
        x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        ws = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)

        def scanned(x, ws):
            return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0].sum()

        def unrolled(x, ws):
            for i in range(5):
                x = jnp.tanh(x @ ws[i])
            return x.sum()

        a = count_fn_flops(scanned, x, ws)
        b = count_fn_flops(unrolled, x, ws)
        assert a.dot_flops == b.dot_flops

    def test_grad_includes_backward(self):
        x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        w = jax.ShapeDtypeStruct((16, 16), jnp.float32)

        def loss(x, w):
            return (x @ w).sum()

        fwd = count_fn_flops(loss, x, w)
        bwd = count_fn_flops(jax.grad(loss, argnums=1), x, w)
        assert bwd.dot_flops >= fwd.dot_flops  # dgrad/wgrad dots

    def test_remat_recompute_counted(self):
        x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        w = jax.ShapeDtypeStruct((16, 16), jnp.float32)

        def body(x, w):
            return jnp.tanh(x @ w)

        def loss_plain(x, w):
            return body(x, w).sum()

        def loss_remat(x, w):
            return jax.checkpoint(body)(x, w).sum()

        plain = count_fn_flops(jax.grad(loss_plain, argnums=1), x, w)
        remat = count_fn_flops(jax.grad(loss_remat, argnums=1), x, w)
        assert remat.dot_flops >= plain.dot_flops

    def test_batched_dot_general(self):
        a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
        fc = count_fn_flops(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
        assert fc.dot_flops == 4 * 2 * 8 * 16 * 8


def _compile(fn, *args, mesh_axes=None, in_shardings=None):
    if in_shardings is None:
        return jax.jit(fn).lower(*args).compile()
    # AxisType-compatible on jax <= 0.4.x (no axis_types kwarg there).
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((jax.device_count(),), ("x",))
    with mesh:
        return jax.jit(fn, in_shardings=in_shardings).lower(*args).compile()


class TestHloAnalysis:
    def test_dot_flops_and_memory(self):
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
        comp = _compile(lambda x, y: x @ y, a, b)
        st = analyze_hlo(comp.as_text())
        assert st.dot_flops == 2 * 128 * 256 * 64
        want_bytes = (128 * 256 + 256 * 64 + 128 * 64) * 4
        assert st.memory_bytes >= want_bytes * 0.9
        assert st.memory_bytes <= want_bytes * 3

    def test_while_trip_count_scaling(self):
        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((9, 32, 32), jnp.float32)

        def f(x, ws):
            return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

        st = analyze_hlo(_compile(f, x, ws).as_text())
        assert st.dot_flops == pytest.approx(9 * 2 * 32 * 32 * 32, rel=0.01)

    def test_no_collectives_single_device(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        st = analyze_hlo(_compile(lambda x: (x @ x).sum(), a).as_text())
        assert st.total_collective_bytes == 0
        assert st.n_collectives == 0

    def test_scan_sliced_weights_not_overcounted(self):
        """Stacked scan weights read per layer must cost ~the slice, not
        trips x the whole stack."""
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((50, 64, 64), jnp.float32)

        def f(x, ws):
            return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0].sum()

        st = analyze_hlo(_compile(f, x, ws).as_text())
        stack_bytes = 50 * 64 * 64 * 4
        # naive counting would be ~50 trips x full stack = 50x stack_bytes
        assert st.memory_bytes < 10 * stack_bytes


class TestRooflineTerms:
    def _terms(self, **kw):
        base = dict(
            arch="a", shape="s", mesh="single", chips=256,
            global_flops=1e15, per_device_hbm_bytes=1e11,
            per_device_collective_bytes=1e9, collective_breakdown={},
            model_flops=8e14,
        )
        base.update(kw)
        return RooflineTerms(**base)

    def test_terms_math(self):
        t = self._terms()
        assert t.compute_s == pytest.approx(1e15 / (256 * 197e12))
        assert t.memory_s == pytest.approx(1e11 / 819e9)
        assert t.collective_s == pytest.approx(1e9 / 50e9)
        assert t.bottleneck == "memory"

    def test_roofline_fraction_uses_useful_flops(self):
        t = self._terms()
        frac = t.roofline_fraction
        assert 0 < frac < 1
        # achieving the dominant term exactly with model flops:
        assert frac == pytest.approx(
            (8e14 / t.step_time_s) / (256 * 197e12)
        )

    def test_bottleneck_switches(self):
        t = self._terms(per_device_hbm_bytes=1.0, per_device_collective_bytes=1e13)
        assert t.bottleneck == "collective"
