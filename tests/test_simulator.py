"""Tests for the event-driven storage simulator (§5) incl. failure
injection, finite-repair-bandwidth dynamics, and elastic membership
(§5.7)."""

import math

import numpy as np
import pytest

from repro.core import DataItem, StorageNode, create_scheduler
from repro.storage import SimConfig, Simulator, make_node_set, make_trace, run_simulation
from repro.storage.traces import random_reliability_targets


class TestTraces:
    @pytest.mark.parametrize("name", ["meva", "sentinel2", "swim", "ibm_cos"])
    def test_table3_stats(self, name):
        from repro.storage.traces import _SPECS

        spec = _SPECS[name]
        items = make_trace(name, seed=0, n_items=4000)
        sizes = np.array([i.size_mb for i in items])
        assert sizes.min() >= spec.min_mb - 1e-9
        assert sizes.max() <= spec.max_mb + 1e-9
        # Mean within 25% of Table 3 (clipping shifts the lognormal mean).
        assert abs(sizes.mean() - spec.mean_mb) / spec.mean_mb < 0.25

    def test_deterministic(self):
        a = make_trace("meva", seed=7, n_items=100)
        b = make_trace("meva", seed=7, n_items=100)
        assert [i.size_mb for i in a] == [i.size_mb for i in b]
        c = make_trace("meva", seed=8, n_items=100)
        assert [i.size_mb for i in a] != [i.size_mb for i in c]

    def test_total_mb_standardization(self):
        items = make_trace("meva", seed=0, total_mb=50_000.0)
        total = sum(i.size_mb for i in items)
        assert total >= 50_000.0
        assert total - items[-1].size_mb < 50_000.0  # minimal overshoot

    def test_arrivals_sorted(self):
        items = make_trace("meva", seed=0, n_items=500)
        ts = [i.arrival_time for i in items]
        assert ts == sorted(ts)

    def test_random_nines_distribution(self):
        rng = np.random.default_rng(0)
        rts = random_reliability_targets(20_000, rng)
        assert rts.min() >= 0.90
        assert rts.max() <= 0.9999999
        # All seven nine-buckets occupied.
        assert (rts < 0.99).any() and (rts > 0.99999).any()


class TestSimulator:
    def test_conservation_of_bytes(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=300, reliability=0.9)
        res = run_simulation(nodes, create_scheduler("drex_lb"), items)
        # Bytes on nodes == sum over stored items of chunk * N.
        want = sum(s.chunk_mb * s.placement.n for s in res.stored_items)
        assert res.per_node_used_mb.sum() == pytest.approx(want, rel=1e-9)
        assert res.n_stored + res.n_failed_writes == len(items)

    def test_throughput_definition(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=100, reliability=0.9)
        res = run_simulation(nodes, create_scheduler("ec(3,2)"), items)
        io = sum(res.time_breakdown.values())
        assert res.throughput_mbps == pytest.approx(res.stored_mb / io)

    def test_write_read_bottleneck_is_slowest_node(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=50, reliability=0.9)
        sim = Simulator(nodes, create_scheduler("ec(3,2)"))
        for item in items:
            si, _ = sim.store(item)
            if si is None:
                continue
            ids = list(si.placement.node_ids)
            assert si.t_write == pytest.approx(
                si.chunk_mb / sim.cluster.write_bw[ids].min()
            )
            assert si.t_read == pytest.approx(
                si.chunk_mb / sim.cluster.read_bw[ids].min()
            )


class TestFailures:
    def _run(self, name, schedule, rt=0.9):
        nodes = make_node_set("most_unreliable", 0.001)
        items = make_trace("meva", seed=0, n_items=400, reliability=rt)
        cfg = SimConfig(failure_schedule=tuple(schedule))
        return run_simulation(nodes, create_scheduler(name), items, cfg)

    def test_no_failures_retains_everything(self):
        res = self._run("drex_sc", [])
        assert res.retained_fraction == 1.0
        assert res.n_node_failures == 0

    def test_failed_node_is_emptied_and_unused(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=200, reliability=0.9)
        cfg = SimConfig(failure_schedule=((30.0, 2),))
        sim = Simulator(nodes, create_scheduler("drex_lb"), cfg)
        res = sim.run(items)
        assert not sim.cluster.alive[2]
        assert res.per_node_used_mb[2] == 0.0
        for s in res.stored_items:
            if s.item.arrival_time / 86400.0 > 30.0:
                assert 2 not in s.placement.node_ids

    def test_dynamic_reschedules_after_failure(self):
        res = self._run("drex_sc", [(30.0, 0), (40.0, 1)])
        assert res.n_node_failures == 2
        # Early-day failures with plenty of spare nodes: everything survives
        # via rescheduling (paper Fig. 12a, <=4 failures rows at 100%).
        assert res.retained_fraction > 0.95

    def test_items_below_k_survivors_are_dropped(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=150, reliability=0.9)
        # Kill 8 of 10 nodes mid-run: EC(6,3) needs 9 -> mass drop.
        sched = tuple((35.0 + i * 0.1, i) for i in range(8))
        cfg = SimConfig(failure_schedule=sched)
        res = run_simulation(nodes, create_scheduler("ec(6,3)"), items, cfg)
        assert res.retained_fraction < 0.6

    def test_static_cannot_grow_parity(self):
        """Static EC reschedules chunks but never adds parity (§5.7)."""
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=100, reliability=0.9)
        cfg = SimConfig(failure_schedule=((30.0, 0),))
        res = run_simulation(nodes, create_scheduler("ec(3,2)"), items, cfg)
        for s in res.stored_items:
            assert s.placement.p == 2

    def test_reschedule_preserves_reliability_constraint(self):
        from repro.core.reliability import pr_avail

        nodes = make_node_set("most_unreliable", 0.001)
        items = make_trace("meva", seed=0, n_items=200, reliability=0.9)
        cfg = SimConfig(failure_schedule=((20.0, 0), (35.0, 4)))
        sim = Simulator(nodes, create_scheduler("drex_sc"), cfg)
        res = sim.run(items)
        for s in res.stored_items:
            ids = list(s.placement.node_ids)
            if not all(sim.cluster.alive[i] for i in ids):
                continue  # item was inspected pre-final-failure
            fp = sim.cluster.fail_probs(s.item.delta_t_days)[ids]
            assert pr_avail(fp, s.placement.p) >= s.item.reliability_target - 1e-9


class TestSchedulingOverhead:
    def test_overhead_measured(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=20, reliability=0.9)
        cfg = SimConfig(measure_overhead=True)
        res = run_simulation(nodes, create_scheduler("drex_lb"), items, cfg)
        assert len(res.sched_overhead_s) == 20
        assert all(t >= 0 for t in res.sched_overhead_s)


def _fig12_run(algo, rt, n_failures, **cfg_kwargs):
    """The exact Fig. 12 benchmark configuration (benchmarks/fig12)."""
    nodes = make_node_set("most_unreliable", 0.001)
    cap = sum(n.capacity_mb for n in nodes)
    items = make_trace("meva", seed=1, total_mb=cap * 0.15, reliability=rt)
    schedule = tuple(
        (70.0 * (i + 1) / (n_failures + 1), -1) for i in range(n_failures)
    )
    cfg = SimConfig(failure_schedule=schedule, seed=1, **cfg_kwargs)
    return run_simulation(nodes, create_scheduler(algo), items, cfg)


@pytest.mark.slow
class TestLegacyEquivalence:
    """With ``repair_bw_mbps=inf`` the event-driven simulator must
    reproduce the pre-refactor sequential loop's results on the Fig. 12
    configurations, bit-for-bit.  (A 24-simulation sweep: full lane only.)

    Golden values were captured from the pre-refactor simulator at commit
    112a4fb.  ``drex_sc`` values were captured from the same sequential
    loop *with the smin_mb anchoring fix applied* (seeding s_min from the
    first observed item is an intentional behavior change of this PR and
    shifts SC's saturation scoring; the other schedulers never consult
    s_min, so their goldens are the untouched pre-refactor outputs).

    The pre-refactor loop replanned in item insertion order, so this
    suite runs with ``repair_priority="fifo"`` — which doubles as the
    regression lane for the legacy scan now that ``"health"`` is the
    default.
    """

    # (rt, algo, n_failures) -> (retained_fraction, stored_mb)
    GOLDEN = {
        (0.9, "drex_sc", 2): (1.0, 12645.344562929924),
        (0.9, "drex_sc", 4): (0.9572248308865327, 12645.344562929924),
        (0.9, "drex_sc", 7): (0.18775434006262748, 12645.344562929924),
        (0.99999, "drex_sc", 2): (0.6503832923106293, 12645.344562929924),
        (0.99999, "drex_sc", 4): (0.16885372592881925, 12645.344562929924),
        (0.99999, "drex_sc", 7): (0.0, 11653.280215320558),
        (0.9, "drex_lb", 2): (1.0, 12645.344562929924),
        (0.9, "drex_lb", 4): (1.0, 12645.344562929924),
        (0.9, "drex_lb", 7): (0.8475697749663033, 11748.605365034846),
        (0.99999, "drex_lb", 2): (1.0, 12645.344562929924),
        (0.99999, "drex_lb", 4): (0.7650312198473403, 12645.344562929924),
        (0.99999, "drex_lb", 7): (0.0, 8767.760536086198),
        (0.9, "ec(3,2)", 2): (1.0, 12645.344562929924),
        (0.9, "ec(3,2)", 4): (1.0, 12645.344562929924),
        (0.9, "ec(3,2)", 7): (0.0, 9716.334774446805),
        (0.99999, "ec(3,2)", 2): (0.0, 0.0),
        (0.99999, "ec(3,2)", 4): (0.0, 0.0),
        (0.99999, "ec(3,2)", 7): (0.0, 0.0),
        (0.9, "daos", 2): (0.2902351277167644, 12645.344562929924),
        (0.9, "daos", 4): (0.5162998514387691, 12645.344562929924),
        (0.9, "daos", 7): (0.23162751903728818, 12645.344562929924),
        (0.99999, "daos", 2): (0.8959034525980071, 8922.116159329002),
        (0.99999, "daos", 4): (0.6626789731396473, 9014.519559620712),
        (0.99999, "daos", 7): (0.0, 10367.809352129245),
    }

    @pytest.mark.parametrize("key", sorted(GOLDEN, key=str))
    def test_infinite_bandwidth_matches_pre_refactor(self, key):
        rt, algo, nf = key
        want_retained, want_stored = self.GOLDEN[key]
        res = _fig12_run(algo, rt, nf, repair_priority="fifo")
        assert res.retained_fraction == pytest.approx(want_retained, abs=1e-9)
        assert res.stored_mb == pytest.approx(want_stored, abs=1e-6)

    def test_instant_repairs_never_linger(self):
        res = _fig12_run("drex_lb", 0.9, 4)
        assert res.n_repairs_planned == res.n_repairs_completed
        assert res.n_repairs_aborted == 0


class TestRepairPriority:
    """Health-prioritized replanning (``SimConfig.repair_priority``):
    within a failure event, the most-degraded items — smallest
    surviving-chunks-minus-K margin — replan first, with a deterministic
    item-id tie-break; ``"fifo"`` preserves the legacy insertion-order
    scan.  ``Simulator.repair_log`` records every decision in replan
    order and is pinned by a same-seed replay digest."""

    #: sha256 over the (day, item_id, margin) replay log of
    #: ``_replay_run`` — pins the deterministic replan order under the
    #: health priority (same seed => same digest, every run).
    REPLAY_DIGEST = (
        "238bc3c73c486a6cc01153f6d614aa6900a7a54da77ed26e9a5482d0ab88a26b"
    )

    def _flat_nodes(self, n):
        return [
            StorageNode(
                node_id=i,
                capacity_mb=1000.0,
                write_bw=200.0,
                read_bw=250.0,
                annual_failure_rate=0.001,
            )
            for i in range(n)
        ]

    def _two_item_sim(self, **cfg_kwargs):
        # greedy_least_used on identical nodes: item 0 lands on the first
        # three, item 1 on the next three — disjoint placements with
        # n=3, k=2, p=1 each.
        cfg = SimConfig(**cfg_kwargs)
        sim = Simulator(self._flat_nodes(8), create_scheduler("greedy_least_used"), cfg)
        for i in range(2):
            si, _ = sim.store(DataItem(i, 5.0, 0.0, 365.0, 0.9))
            assert si is not None
        pl0 = sim.live_items[0].placement.node_ids
        pl1 = sim.live_items[1].placement.node_ids
        assert set(pl0).isdisjoint(pl1)
        return sim, pl0, pl1

    def test_most_degraded_replans_first(self):
        sim, pl0, pl1 = self._two_item_sim()
        # One event: item 1 loses two chunks (margin -1, unrepairable),
        # item 0 one (margin 0) — health order puts item 1 first even
        # though item 0 was inserted first.
        sim.fail_nodes([pl1[0], pl1[1], pl0[0]], day=10.0)
        assert sim.repair_log == [(10.0, 1, -1), (10.0, 0, 0)]

    def test_equal_margins_tie_break_on_item_id(self):
        sim, pl0, pl1 = self._two_item_sim()
        sim.fail_nodes([pl0[0], pl1[0]], day=10.0)
        assert sim.repair_log == [(10.0, 0, 0), (10.0, 1, 0)]

    def test_fifo_replans_in_insertion_order(self):
        sim, pl0, pl1 = self._two_item_sim(repair_priority="fifo")
        sim.fail_nodes([pl1[0], pl1[1], pl0[0]], day=10.0)
        assert [iid for _, iid, _ in sim.repair_log] == [0, 1]

    def test_margins_rederived_when_pending_repairs_void(self):
        sim, pl0, pl1 = self._two_item_sim(repair_bw_mbps=0.001)
        sim.fail_nodes([pl1[0]], day=10.0)  # margin 0, repair in flight
        # A survivor dies before the repair lands: the void re-derives
        # the margin from the pending plan's live survivors.
        sim.fail_nodes([pl1[1]], day=10.001)
        assert sim.repair_log == [(10.0, 1, 0), (10.001, 1, -1)]

    def test_invalid_priority_rejected(self):
        with pytest.raises(ValueError, match="repair_priority"):
            SimConfig(repair_priority="lifo")

    def _replay_run(self):
        nodes = make_node_set("most_unreliable", 0.001)
        cap = sum(n.capacity_mb for n in nodes)
        items = make_trace("meva", seed=1, total_mb=cap * 0.1, reliability=0.9)
        schedule = tuple((20.0 + 7.0 * i, -1) for i in range(5))
        cfg = SimConfig(failure_schedule=schedule, seed=3, repair_bw_mbps=0.05)
        sim = Simulator(nodes, create_scheduler("drex_lb"), cfg)
        sim.run(items)
        return sim

    def test_same_seed_replay_digest(self):
        import hashlib

        digests = []
        for _ in range(2):
            sim = self._replay_run()
            payload = repr(
                [(round(d, 9), i, m) for d, i, m in sim.repair_log]
            ).encode()
            digests.append(hashlib.sha256(payload).hexdigest())
        assert digests[0] == digests[1]  # same seed => same replan order
        assert digests[0] == self.REPLAY_DIGEST


class TestRepairBandwidth:
    """Finite per-node repair bandwidth: repairs take time, queue per
    node, and are voided (item possibly dropped) when another failure
    hits them in flight."""

    BURST = tuple((30.0 + i * 0.05, -1) for i in range(5))

    def _burst_run(self, bw, algo="drex_sc"):
        nodes = make_node_set("most_unreliable", 0.001)
        cap = sum(n.capacity_mb for n in nodes)
        items = make_trace("meva", seed=1, total_mb=cap * 0.15, reliability=0.9)
        cfg = SimConfig(failure_schedule=self.BURST, seed=1, repair_bw_mbps=bw)
        return run_simulation(nodes, create_scheduler(algo), items, cfg)

    def test_retained_fraction_degrades_as_bandwidth_shrinks(self):
        retained = [
            self._burst_run(bw).retained_fraction
            for bw in (math.inf, 1.0, 0.1, 0.01)
        ]
        # Monotone non-increasing, and the slow end strictly loses data.
        assert all(a >= b for a, b in zip(retained, retained[1:]))
        assert retained[0] == 1.0
        assert retained[-1] < retained[0]

    def test_items_hit_mid_repair_are_dropped(self):
        res = self._burst_run(0.01)
        assert res.n_repairs_aborted > 0
        assert res.dropped_mb > 0.0
        # Conservation: every planned repair either completed, was
        # aborted, or is impossible to leave pending after the heap drains.
        assert (
            res.n_repairs_planned
            == res.n_repairs_completed + res.n_repairs_aborted
        )

    def test_fast_finite_bandwidth_matches_instant_outcome(self):
        # Plenty of bandwidth between failures: same retention as inf,
        # but completions now happen via scheduled repair events.
        fast = self._burst_run(1.0)
        inf = self._burst_run(math.inf)
        assert fast.retained_fraction == pytest.approx(inf.retained_fraction)
        assert fast.n_repairs_completed > 0

    def test_repaired_mb_tracks_completed_transfers(self):
        res = self._burst_run(0.1)
        if res.n_repairs_completed:
            assert res.repaired_mb > 0.0

    def _one_spare_setup(self):
        # ec(3,2) on 6 nodes maps every item onto the same 5-node prefix
        # (by write bandwidth), leaving exactly one spare: all repairs
        # queue on that node's lane.
        nodes = make_node_set("most_used", 0.001)[:6]
        cfg = SimConfig(repair_bw_mbps=0.001)
        sim = Simulator(nodes, create_scheduler("ec(3,2)"), cfg)
        for i in range(3):
            si, _ = sim.store(DataItem(i, 5.0, 0.0, 365.0, 0.9))
            assert si is not None
        mapped = sim.live_items[0].placement.node_ids
        (spare,) = set(range(6)) - set(mapped)
        sim.fail_node(mapped[0], day=10.0)
        assert len(sim._pending) == 3
        return sim, mapped, spare

    def test_voided_repairs_release_lane_time(self):
        """Regression: aborted repairs must return their un-run lane
        bookings — otherwise later repairs queue behind phantom
        transfers that were canceled."""
        sim, mapped, spare = self._one_spare_setup()
        booked = sim._repair_free_at[spare]
        transfer_days = (sim.live_items[0].chunk_mb / 0.001) / 86400.0
        assert booked == pytest.approx(10.0 + 3 * transfer_days)  # serialized
        # A second failure on a shared survivor voids all three repairs
        # (re-plans find no candidates and drop the items).
        sim.fail_node(mapped[1], day=10.001)
        assert sim.n_repairs_aborted == 3 and not sim._pending
        assert sim._repair_free_at[spare] == pytest.approx(10.001, abs=1e-9)

    def test_replanned_repairs_serialize_on_lanes(self):
        """Regression: voiding and re-planning must not interleave —
        otherwise a re-plan books a lane window that a later void still
        occupies, producing overlapping transfers on one repair lane."""
        nodes = make_node_set("most_used", 0.001)[:7]
        cfg = SimConfig(repair_bw_mbps=0.001)
        sim = Simulator(nodes, create_scheduler("ec(3,2)"), cfg)
        for i in range(3):
            si, _ = sim.store(DataItem(i, 5.0, 0.0, 365.0, 0.9))
            assert si is not None
        mapped = sim.live_items[0].placement.node_ids
        sim.fail_node(mapped[0], day=10.0)
        sim.fail_node(mapped[1], day=10.001)  # voids all 3, re-plans all 3
        assert sim.n_repairs_aborted == 3 and len(sim._pending) == 3
        by_lane: dict[int, list] = {}
        for pend in sim._pending.values():
            for n, window in pend.transfers.items():
                by_lane.setdefault(n, []).append(window)
        for wins in by_lane.values():
            wins.sort()
            for (_, e0), (s1, _) in zip(wins, wins[1:]):
                assert s1 >= e0 - 1e-12  # one transfer at a time per lane

    def test_direct_fail_node_clamps_to_simulation_clock(self):
        # Public fail_node without a day argument must not book repair
        # transfers in the past once simulated time has advanced.
        nodes = make_node_set("most_used", 0.001)[:6]
        cfg = SimConfig(repair_bw_mbps=0.001)
        sim = Simulator(nodes, create_scheduler("ec(3,2)"), cfg)
        sim.run([DataItem(0, 5.0, 20.0 * 86400.0, 365.0, 0.9)])
        mapped = sim.live_items[0].placement.node_ids
        sim.fail_node(mapped[0])  # no day passed: clock says day 20
        pend = next(iter(sim._pending.values()))
        assert pend.finish_day >= 20.0

    def test_aborted_repair_gauge_handles_dead_targets(self):
        """Regression: when the replacement *target* dies, the engine's
        repair_mb_committed gauge must still drop by the full
        reservation (no bytes remain reserved anywhere)."""
        sim, mapped, spare = self._one_spare_setup()
        assert sim.engine.stats["repair_mb_committed"] > 0.0
        sim.fail_node(spare, day=10.001)
        assert sim.n_repairs_aborted == 3 and not sim._pending
        assert sim.engine.stats["repair_mb_committed"] == pytest.approx(0.0)


class TestElasticMembership:
    def _mini_items(self, start_day, n, size=5.0, rt=0.9):
        return [
            DataItem(1000 + start_day * 100 + i, size,
                     (start_day + i) * 86400.0, 365.0, rt)
            for i in range(n)
        ]

    def test_schedulers_place_onto_late_joining_nodes(self):
        # Two live nodes: drex_lb needs >= 3, so early items are rejected;
        # after the join event, placement succeeds on the larger cluster.
        all_nodes = make_node_set("most_used", 0.001)
        cfg = SimConfig(
            node_join_schedule=((10.0, all_nodes[2]), (10.0, all_nodes[3])),
        )
        sim = Simulator(all_nodes[:2], create_scheduler("drex_lb"), cfg)
        items = self._mini_items(1, 3) + self._mini_items(20, 3)
        res = sim.run(items)
        assert sim.cluster.n_nodes == 4
        early = {i.item_id for i in items[:3]}
        assert early <= set(res.failed_item_ids)
        late = [s for s in res.stored_items if s.item.item_id not in early]
        assert len(late) == 3
        # The joined nodes (ids 2 and 3) actually receive chunks.
        assert any(
            n >= 2 for s in late for n in s.placement.node_ids
        )

    def test_healed_node_returns_empty_and_placeable(self):
        # ec(3,2) needs all 5 of a 5-node cluster; after one node fails,
        # writes reject until the node heals (alive and empty).
        nodes = make_node_set("most_used", 0.001)[:5]
        cfg = SimConfig(
            failure_schedule=((4.0, 1),),
            node_heal_schedule=((10.0, 1),),
        )
        sim = Simulator(nodes, create_scheduler("ec(3,2)"), cfg)
        items = self._mini_items(1, 2) + self._mini_items(5, 2) + self._mini_items(12, 2)
        res = sim.run(items)
        mid = {i.item_id for i in items[2:4]}
        late = {i.item_id for i in items[4:]}
        assert mid <= set(res.failed_item_ids)
        stored_late = [s for s in res.stored_items if s.item.item_id in late]
        assert len(stored_late) == 2
        assert all(1 in s.placement.node_ids for s in stored_late)
        assert sim.cluster.alive[1]

    def test_heal_of_live_node_is_noop(self):
        nodes = make_node_set("most_used", 0.001)[:5]
        sim = Simulator(nodes, create_scheduler("ec(3,2)"))
        res = sim.run(self._mini_items(1, 2))
        used_before = sim.cluster.used_mb.copy()
        sim.heal_node(0)  # alive: must not wipe its occupancy
        np.testing.assert_array_equal(sim.cluster.used_mb, used_before)
        assert res.n_stored == 2


class TestFailureTelemetry:
    def test_occupancy_at_failure_distinguishes_dead_from_idle(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=200, reliability=0.9)
        cfg = SimConfig(failure_schedule=((30.0, 2),))
        res = run_simulation(nodes, create_scheduler("drex_lb"), items, cfg)
        # The live view shows the dead node as 0 (its bytes are gone)...
        assert res.per_node_used_mb[2] == 0.0
        # ...but the failure snapshot preserves what it held when it died.
        assert res.used_mb_at_failure[2] > 0.0
        assert set(res.used_mb_at_failure) == {2}

    def test_no_failures_no_snapshot(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=50, reliability=0.9)
        res = run_simulation(nodes, create_scheduler("drex_lb"), items)
        assert res.used_mb_at_failure == {}


def _spare_sim(n_nodes=6, n_items=3, cfg=None):
    """ec(3,2) on ``n_nodes`` most_used nodes: every item maps onto the
    same 5-node prefix (by write bandwidth), leaving ``n_nodes - 5``
    spares.  Returns (sim, mapped, spares)."""
    nodes = make_node_set("most_used", 0.001)[:n_nodes]
    sim = Simulator(nodes, create_scheduler("ec(3,2)"), cfg)
    for i in range(n_items):
        si, _ = sim.store(DataItem(i, 5.0, 0.0, 365.0, 0.9))
        assert si is not None
    mapped = sim.live_items[0].placement.node_ids
    spares = sorted(set(range(n_nodes)) - set(mapped))
    return sim, mapped, spares


class TestCorrelatedFailures:
    """Rack/zone fail-stop: every live node in the domain dies
    *atomically* — one void-then-replan pass over the whole batch, so a
    repair planned for one victim can never lean on another."""

    def _zoned_nodes(self, n=6):
        nodes = make_node_set("most_used", 0.001)[:n]
        for i, node in enumerate(nodes):
            node.rack = i // 2
            node.zone = i // 3
        return nodes

    def test_zone_event_kills_every_live_node_in_zone(self):
        cfg = SimConfig(zone_failure_schedule=((30.0, 0),))
        sim = Simulator(self._zoned_nodes(), create_scheduler("ec(3,2)"), cfg)
        items = [DataItem(i, 5.0, 0.0, 365.0, 0.9) for i in range(3)]
        res = sim.run(items)
        assert res.n_node_failures == 3
        assert set(res.used_mb_at_failure) == {0, 1, 2}  # zone 0
        assert not sim.cluster.alive[:3].any()
        assert sim.cluster.alive[3:].all()

    def test_rack_event_scopes_to_the_rack(self):
        cfg = SimConfig(rack_failure_schedule=((30.0, 1),))
        sim = Simulator(self._zoned_nodes(), create_scheduler("ec(3,2)"), cfg)
        res = sim.run([DataItem(0, 5.0, 0.0, 365.0, 0.9)])
        assert res.n_node_failures == 2
        assert set(res.used_mb_at_failure) == {2, 3}  # rack 1
        assert sim.cluster.alive[[0, 1, 4, 5]].all()

    def test_event_on_empty_or_unknown_domain_is_a_noop(self):
        cfg = SimConfig(rack_failure_schedule=((30.0, 99),))
        sim = Simulator(self._zoned_nodes(), create_scheduler("ec(3,2)"), cfg)
        res = sim.run([DataItem(0, 5.0, 0.0, 365.0, 0.9)])
        assert res.n_node_failures == 0 and res.dropped_mb == 0.0

    def test_batch_deaths_land_before_any_replanning(self):
        """Two mapped nodes dying together yield ONE repair straight
        onto the spares; sequential failures void the first repair
        mid-flight (abort + replan) — the atomic batch must not."""

        def build():
            return _spare_sim(
                n_nodes=7, n_items=1, cfg=SimConfig(repair_bw_mbps=0.001)
            )

        batch, mapped, spares = build()
        batch.fail_nodes([mapped[0], mapped[1]], day=10.0)
        assert batch.n_repairs_planned == 1
        assert batch.n_repairs_aborted == 0
        (pend,) = batch._pending.values()
        assert set(pend.plan.new_nodes) == set(spares)
        assert set(pend.plan.new_nodes).isdisjoint({mapped[0], mapped[1]})

        seq, mapped, _ = build()
        seq.fail_node(mapped[0], day=10.0)
        seq.fail_node(mapped[1], day=10.001)
        assert seq.n_repairs_aborted == 1  # first repair voided in flight
        assert seq.n_repairs_planned == 2

    def test_fail_nodes_dedupes_and_skips_dead(self):
        nodes = make_node_set("most_used", 0.001)[:6]
        sim = Simulator(nodes, create_scheduler("ec(3,2)"))
        sim.fail_nodes([1, 1, 2], day=5.0)
        assert sim.n_node_failures == 2
        sim.fail_nodes([2, 97], day=6.0)  # dead + out of range: no-op
        assert sim.n_node_failures == 2

    def test_correlated_event_lanes_never_overlap(self):
        # A whole zone (two mapped nodes) dies; the surviving repairs'
        # read+write bookings must keep the one-transfer-per-lane
        # invariant and never touch a same-event victim.
        sim, mapped, _ = _spare_sim(
            n_nodes=7, n_items=2, cfg=SimConfig(repair_bw_mbps=0.001)
        )
        for nid in (mapped[0], mapped[1]):
            sim.cluster.zone[nid] = 1
        victims = np.nonzero((sim.cluster.zone == 1) & sim.cluster.alive)[0]
        sim.fail_nodes([int(n) for n in victims], day=10.0)
        assert sim.n_node_failures == 2 and sim.n_repairs_aborted == 0
        assert len(sim._pending) == 2
        by_lane: dict[int, list] = {}
        for pend in sim._pending.values():
            assert set(pend.transfers).isdisjoint({mapped[0], mapped[1]})
            for n, window in pend.transfers.items():
                by_lane.setdefault(n, []).append(window)
        for wins in by_lane.values():
            wins.sort()
            for (_, e0), (s1, _) in zip(wins, wins[1:]):
                assert s1 >= e0 - 1e-12


class TestSurvivorReadCharging:
    """Repair economics: reconstruction charges decode-source reads on
    the K survivors' lanes, and (optionally) the repair's total traffic
    against a shared cluster-wide budget."""

    def test_decode_reads_book_survivor_lanes(self):
        sim, mapped, (spare,) = _spare_sim(cfg=SimConfig(repair_bw_mbps=0.001))
        sim.fail_node(mapped[0], day=10.0)
        T = (sim.live_items[0].chunk_mb / 0.001) / 86400.0
        # Each repair books k=3 decode reads on the first three
        # survivors (placement order) plus one write on the spare, and
        # finishes when its slowest transfer lands.
        for pend in sim._pending.values():
            assert set(pend.transfers) == {spare, *mapped[1:4]}
            assert pend.finish_day == pytest.approx(
                max(end for _, end in pend.transfers.values())
            )
        for n in mapped[1:4]:  # three serialized reads per survivor lane
            assert sim._repair_free_at[n] == pytest.approx(10.0 + 3 * T)
        # The 4th survivor feeds no decode: its lane stays free.
        assert sim._repair_free_at.get(mapped[4], 0.0) == 0.0

    def test_repair_read_mb_accrues_on_completion(self):
        sim, mapped, _ = _spare_sim(cfg=SimConfig(repair_bw_mbps=0.001))
        sim.fail_node(mapped[0], day=10.0)
        chunk = sim.live_items[0].chunk_mb
        res = sim.run([])  # drain the scheduled repair completions
        assert res.n_repairs_completed == 3
        assert res.repaired_mb == pytest.approx(3 * chunk)  # 1 write each
        assert res.repair_read_mb == pytest.approx(3 * chunk * 3)  # k=3 reads

    def test_instant_path_accrues_reads_too(self):
        sim, mapped, _ = _spare_sim()  # both budgets infinite
        sim.fail_node(mapped[0], day=10.0)
        assert sim.n_repairs_completed == 3 and not sim._pending
        chunk = sim.live_items[0].chunk_mb
        assert sim.repair_read_mb == pytest.approx(3 * chunk * 3)
        assert sim.repaired_mb == pytest.approx(3 * chunk)

    def test_cluster_budget_serializes_repairs(self):
        # Per-node bandwidth infinite, shared fabric finite: the only
        # queue is the cluster lane, which serializes each repair's
        # total (k reads + 1 write) traffic.
        sim, mapped, _ = _spare_sim(
            n_items=2, cfg=SimConfig(cluster_repair_bw_mbps=0.001)
        )
        chunk = sim.live_items[0].chunk_mb
        sim.fail_node(mapped[0], day=10.0)
        assert len(sim._pending) == 2
        T = (4 * chunk / 0.001) / 86400.0
        wins = sorted(p.cluster_window for p in sim._pending.values())
        assert wins[0][0] == pytest.approx(10.0)
        assert wins[0][1] == pytest.approx(10.0 + T)
        assert wins[1][0] == pytest.approx(wins[0][1])  # serialized
        for pend in sim._pending.values():
            assert pend.transfers == {}  # no per-node queueing
            assert pend.finish_day == pytest.approx(pend.cluster_window[1])
        assert sim._cluster_lane_free_at == pytest.approx(10.0 + 2 * T)

    def test_voided_repairs_release_the_cluster_lane(self):
        sim, mapped, _ = _spare_sim(
            n_items=2, cfg=SimConfig(cluster_repair_bw_mbps=0.001)
        )
        sim.fail_node(mapped[0], day=10.0)
        # A second failure on a shared survivor voids both repairs (the
        # re-plans find no candidates and drop the items): the cluster
        # lane's un-run reservations must be returned.
        sim.fail_node(mapped[1], day=10.001)
        assert sim.n_repairs_aborted == 2 and not sim._pending
        assert sim._cluster_lane_free_at == pytest.approx(10.001, abs=1e-9)

    def test_finite_cluster_budget_disables_instant_path(self):
        sim, mapped, _ = _spare_sim(cfg=SimConfig(cluster_repair_bw_mbps=1e9))
        sim.fail_node(mapped[0], day=10.0)
        # Even a huge finite budget must go through the event loop, not
        # the legacy instantaneous branch.
        assert sim.n_repairs_completed == 0 and len(sim._pending) == 3


class TestHealMidRepair:
    """Regression (heal-mid-repair schedule): a healed node's repair
    lane resets, and repairs voided because their replacement target
    died leave no phantom bookings behind."""

    def test_heal_resets_the_repair_lane(self):
        sim, mapped, (spare,) = _spare_sim(cfg=SimConfig(repair_bw_mbps=0.001))
        sim.fail_node(mapped[0], day=10.0)
        assert sim._repair_free_at[spare] > 10.0
        sim.fail_node(spare, day=10.001)  # the target dies: all voided
        assert sim.n_repairs_aborted == 3 and not sim._pending
        # Dead nodes keep their stale bookings (releases skip them)...
        assert sim._repair_free_at[spare] > 10.0
        sim.heal_node(spare)
        # ...and the lane resets the moment the node returns.
        assert sim._repair_free_at[spare] == 0.0

    def test_repairs_after_heal_book_from_now_not_phantom_lane(self):
        sim, mapped, (spare,) = _spare_sim(cfg=SimConfig(repair_bw_mbps=0.001))
        sim.fail_node(mapped[0], day=10.0)
        stale = sim._repair_free_at[spare]  # 10 + 3 serialized writes
        sim.fail_node(spare, day=10.001)  # voids all 3; items drop
        assert not sim._pending and sim.dropped_mb == pytest.approx(15.0)
        sim.heal_node(spare)
        sim.heal_node(mapped[0])
        for i in range(10, 13):
            si, _ = sim.store(DataItem(i, 5.0, 0.0, 365.0, 0.9))
            assert si is not None
        mapped2 = sim.live_items[10].placement.node_ids
        assert spare not in mapped2
        day = 10.01
        assert day < stale  # the phantom bookings would still cover it
        sim.fail_node(mapped2[1], day=day)
        assert len(sim._pending) == 3
        wins = sorted(pend.transfers[spare] for pend in sim._pending.values())
        # Without the heal-time reset, the first write would queue
        # behind the dead round's bookings (start == stale, not day).
        assert wins[0][0] == pytest.approx(day)
        for (_, e0), (s1, _) in zip(wins, wins[1:]):
            assert s1 == pytest.approx(e0)  # serialized on the fresh lane
        T = (sim.live_items[10].chunk_mb / 0.001) / 86400.0
        assert sim._repair_free_at[spare] == pytest.approx(day + 3 * T)

    def test_drop_with_live_pending_releases_everything(self):
        """Defensive `_drop` path: dropping an item whose repair is
        still in flight must abort the engine reservation and return
        every lane booking."""
        sim, mapped, (spare,) = _spare_sim(cfg=SimConfig(repair_bw_mbps=0.001))
        sim.fail_node(mapped[0], day=10.0)
        assert len(sim._pending) == 3
        sim._now = 10.0
        for si in list(sim.live_items.values()):
            sim._drop(
                si,
                holding=[
                    n for n in si.placement.node_ids if sim.cluster.alive[n]
                ],
            )
        assert not sim._pending and sim.n_repairs_aborted == 3
        assert sim.engine.stats["repair_mb_committed"] == pytest.approx(0.0)
        assert sim._repair_free_at[spare] == pytest.approx(10.0, abs=1e-9)
        for n in mapped[1:4]:
            assert sim._repair_free_at[n] == pytest.approx(10.0, abs=1e-9)
