"""Tests for the storage simulator (§5) incl. failure injection (§5.7)."""

import numpy as np
import pytest

from repro.core import make_scheduler
from repro.storage import SimConfig, Simulator, make_node_set, make_trace, run_simulation
from repro.storage.traces import random_reliability_targets


class TestTraces:
    @pytest.mark.parametrize("name", ["meva", "sentinel2", "swim", "ibm_cos"])
    def test_table3_stats(self, name):
        from repro.storage.traces import _SPECS

        spec = _SPECS[name]
        items = make_trace(name, seed=0, n_items=4000)
        sizes = np.array([i.size_mb for i in items])
        assert sizes.min() >= spec.min_mb - 1e-9
        assert sizes.max() <= spec.max_mb + 1e-9
        # Mean within 25% of Table 3 (clipping shifts the lognormal mean).
        assert abs(sizes.mean() - spec.mean_mb) / spec.mean_mb < 0.25

    def test_deterministic(self):
        a = make_trace("meva", seed=7, n_items=100)
        b = make_trace("meva", seed=7, n_items=100)
        assert [i.size_mb for i in a] == [i.size_mb for i in b]
        c = make_trace("meva", seed=8, n_items=100)
        assert [i.size_mb for i in a] != [i.size_mb for i in c]

    def test_total_mb_standardization(self):
        items = make_trace("meva", seed=0, total_mb=50_000.0)
        total = sum(i.size_mb for i in items)
        assert total >= 50_000.0
        assert total - items[-1].size_mb < 50_000.0  # minimal overshoot

    def test_arrivals_sorted(self):
        items = make_trace("meva", seed=0, n_items=500)
        ts = [i.arrival_time for i in items]
        assert ts == sorted(ts)

    def test_random_nines_distribution(self):
        rng = np.random.default_rng(0)
        rts = random_reliability_targets(20_000, rng)
        assert rts.min() >= 0.90
        assert rts.max() <= 0.9999999
        # All seven nine-buckets occupied.
        assert (rts < 0.99).any() and (rts > 0.99999).any()


class TestSimulator:
    def test_conservation_of_bytes(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=300, reliability=0.9)
        res = run_simulation(nodes, make_scheduler("drex_lb"), items)
        # Bytes on nodes == sum over stored items of chunk * N.
        want = sum(s.chunk_mb * s.placement.n for s in res.stored_items)
        assert res.per_node_used_mb.sum() == pytest.approx(want, rel=1e-9)
        assert res.n_stored + res.n_failed_writes == len(items)

    def test_throughput_definition(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=100, reliability=0.9)
        res = run_simulation(nodes, make_scheduler("ec(3,2)"), items)
        io = sum(res.time_breakdown.values())
        assert res.throughput_mbps == pytest.approx(res.stored_mb / io)

    def test_write_read_bottleneck_is_slowest_node(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=50, reliability=0.9)
        sim = Simulator(nodes, make_scheduler("ec(3,2)"))
        for item in items:
            si, _ = sim.store(item)
            if si is None:
                continue
            ids = list(si.placement.node_ids)
            assert si.t_write == pytest.approx(
                si.chunk_mb / sim.cluster.write_bw[ids].min()
            )
            assert si.t_read == pytest.approx(
                si.chunk_mb / sim.cluster.read_bw[ids].min()
            )


class TestFailures:
    def _run(self, name, schedule, rt=0.9):
        nodes = make_node_set("most_unreliable", 0.001)
        items = make_trace("meva", seed=0, n_items=400, reliability=rt)
        cfg = SimConfig(failure_schedule=tuple(schedule))
        return run_simulation(nodes, make_scheduler(name), items, cfg)

    def test_no_failures_retains_everything(self):
        res = self._run("drex_sc", [])
        assert res.retained_fraction == 1.0
        assert res.n_node_failures == 0

    def test_failed_node_is_emptied_and_unused(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=200, reliability=0.9)
        cfg = SimConfig(failure_schedule=((30.0, 2),))
        sim = Simulator(nodes, make_scheduler("drex_lb"), cfg)
        res = sim.run(items)
        assert not sim.cluster.alive[2]
        assert res.per_node_used_mb[2] == 0.0
        for s in res.stored_items:
            if s.item.arrival_time / 86400.0 > 30.0:
                assert 2 not in s.placement.node_ids

    def test_dynamic_reschedules_after_failure(self):
        res = self._run("drex_sc", [(30.0, 0), (40.0, 1)])
        assert res.n_node_failures == 2
        # Early-day failures with plenty of spare nodes: everything survives
        # via rescheduling (paper Fig. 12a, <=4 failures rows at 100%).
        assert res.retained_fraction > 0.95

    def test_items_below_k_survivors_are_dropped(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=150, reliability=0.9)
        # Kill 8 of 10 nodes mid-run: EC(6,3) needs 9 -> mass drop.
        sched = tuple((35.0 + i * 0.1, i) for i in range(8))
        cfg = SimConfig(failure_schedule=sched)
        res = run_simulation(nodes, make_scheduler("ec(6,3)"), items, cfg)
        assert res.retained_fraction < 0.6

    def test_static_cannot_grow_parity(self):
        """Static EC reschedules chunks but never adds parity (§5.7)."""
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=100, reliability=0.9)
        cfg = SimConfig(failure_schedule=((30.0, 0),))
        res = run_simulation(nodes, make_scheduler("ec(3,2)"), items, cfg)
        for s in res.stored_items:
            assert s.placement.p == 2

    def test_reschedule_preserves_reliability_constraint(self):
        from repro.core.reliability import pr_avail

        nodes = make_node_set("most_unreliable", 0.001)
        items = make_trace("meva", seed=0, n_items=200, reliability=0.9)
        cfg = SimConfig(failure_schedule=((20.0, 0), (35.0, 4)))
        sim = Simulator(nodes, make_scheduler("drex_sc"), cfg)
        res = sim.run(items)
        for s in res.stored_items:
            ids = list(s.placement.node_ids)
            if not all(sim.cluster.alive[i] for i in ids):
                continue  # item was inspected pre-final-failure
            fp = sim.cluster.fail_probs(s.item.delta_t_days)[ids]
            assert pr_avail(fp, s.placement.p) >= s.item.reliability_target - 1e-9


class TestSchedulingOverhead:
    def test_overhead_measured(self):
        nodes = make_node_set("most_used", 0.001)
        items = make_trace("meva", seed=0, n_items=20, reliability=0.9)
        cfg = SimConfig(measure_overhead=True)
        res = run_simulation(nodes, make_scheduler("drex_lb"), items, cfg)
        assert len(res.sched_overhead_s) == 20
        assert all(t >= 0 for t in res.sched_overhead_s)
