"""Golden-equivalence tests for the jitted D-Rex SC kernel.

The scalar numpy path (``DRexSC.place_scalar``) is the reference oracle;
the jax kernel (``repro.core.sc_kernel``) and the batched
``PlacementEngine.place_many`` scoring built on it must reproduce its
decisions bit-for-bit.  Styled after ``TestLegacyEquivalence``: the
``GOLDEN`` placements below were captured from the scalar oracle at the
commit introducing the kernel, so *both* paths are pinned against drift.
"""

import numpy as np
import pytest

from repro.core import (
    ClusterView,
    DataItem,
    DRexSC,
    Placement,
    PlacementEngine,
    create_scheduler,
    get_spec,
)
from repro.core import sc_kernel
from repro.storage import make_node_set, make_trace

needs_jax = pytest.mark.skipif(
    not sc_kernel.kernel_available(), reason="jax unavailable"
)


def forced_kernel_scheduler() -> DRexSC:
    """A DRexSC that uses the kernel at any cluster size (no numpy-
    dispatch crossover), so small test clusters exercise the jit path."""
    sched = create_scheduler("drex_sc")
    sched.KERNEL_MIN_NODES = 0
    return sched


def scalar_scheduler() -> DRexSC:
    sched = create_scheduler("drex_sc")
    sched.use_kernel = False
    return sched


class TestGoldenPlacements:
    """Pinned traces -> pinned placements, for both implementations."""

    # (nodeset, trace seed) -> (k, p, node_ids) of the first 8 meva items
    # at RT 0.99, committed sequentially.  Captured from the scalar
    # oracle; guards oracle and kernel against silent drift.
    GOLDEN = {
        ("most_used", 3): [
            (3, 1, (3, 9, 0, 2)),
            (3, 1, (1, 4, 5, 6)),
            (4, 1, (8, 0, 2, 1, 4)),
            (4, 1, (5, 1, 4, 7, 6)),
            (4, 1, (3, 9, 8, 0, 2)),
            (4, 1, (3, 9, 8, 0, 2)),
            (4, 1, (3, 9, 8, 0, 2)),
            (4, 1, (3, 9, 8, 0, 2)),
        ],
        ("most_unreliable", 11): [
            (3, 2, (1, 0, 2, 3, 4)),
            (3, 2, (1, 0, 2, 3, 4)),
            (3, 1, (7, 5, 6, 8)),
            (3, 1, (3, 4, 7, 9)),
            (3, 1, (3, 4, 7, 9)),
            (3, 1, (3, 4, 7, 9)),
            (3, 2, (1, 0, 2, 3, 4)),
            (3, 2, (1, 0, 2, 3, 4)),
        ],
    }

    def _run(self, nodeset, seed, scheduler):
        items = make_trace("meva", seed=seed, n_items=8, reliability=0.99)
        eng = PlacementEngine(make_node_set(nodeset, 0.001), scheduler)
        return [eng.place(it).placement for it in items]

    @pytest.mark.parametrize("key", sorted(GOLDEN))
    def test_scalar_oracle_matches_golden(self, key):
        got = self._run(*key, scalar_scheduler())
        want = [Placement(k, p, ids) for k, p, ids in self.GOLDEN[key]]
        assert got == want

    @needs_jax
    @pytest.mark.parametrize("key", sorted(GOLDEN))
    def test_kernel_matches_golden(self, key):
        got = self._run(*key, forced_kernel_scheduler())
        want = [Placement(k, p, ids) for k, p, ids in self.GOLDEN[key]]
        assert got == want

    @needs_jax
    @pytest.mark.parametrize("key", sorted(GOLDEN))
    def test_batched_place_many_matches_golden(self, key):
        nodeset, seed = key
        items = make_trace("meva", seed=seed, n_items=8, reliability=0.99)
        eng = PlacementEngine(make_node_set(nodeset, 0.001), forced_kernel_scheduler())
        got = [r.placement for r in eng.place_many(items)]
        want = [Placement(k, p, ids) for k, p, ids in self.GOLDEN[key]]
        assert got == want


@needs_jax
class TestKernelOracleEquivalence:
    """Kernel decisions == scalar oracle decisions, bit for bit."""

    @pytest.mark.parametrize("nodeset", ["most_used", "most_unreliable", "most_reliable"])
    @pytest.mark.parametrize("rt", [0.9, 0.99999, "random_nines"])
    def test_sequential_place_matches_oracle(self, nodeset, rt):
        items = make_trace("meva", seed=7, n_items=40, reliability=rt)
        a = PlacementEngine(make_node_set(nodeset, 0.001), scalar_scheduler())
        b = PlacementEngine(make_node_set(nodeset, 0.001), forced_kernel_scheduler())
        for it in items:
            ra, rb = a.place(it), b.place(it)
            assert ra.placement == rb.placement
            assert ra.candidates_considered == rb.candidates_considered
            assert ra.reason == rb.reason
        np.testing.assert_array_equal(a.cluster.used_mb, b.cluster.used_mb)

    def test_batched_place_many_matches_sequential_oracle(self):
        items = make_trace("sentinel2", seed=5, n_items=60, reliability=0.95)
        a = PlacementEngine(make_node_set("most_used", 0.001), scalar_scheduler())
        pa = [a.place(it).placement for it in items]
        b = PlacementEngine(make_node_set("most_used", 0.001), forced_kernel_scheduler())
        pb = [r.placement for r in b.place_many(items)]
        assert pa == pb
        np.testing.assert_array_equal(a.cluster.used_mb, b.cluster.used_mb)
        assert a.scheduler.smin_mb == b.scheduler.smin_mb

    def test_non_committing_batch_single_call_matches_oracle(self):
        # auto_commit=False: nothing invalidates, the whole queue is
        # scored against one snapshot (the Table-2 decision-cost protocol).
        items = make_trace("meva", seed=9, n_items=50, reliability=0.99)
        a = PlacementEngine(
            make_node_set("most_used", 0.001), scalar_scheduler(), auto_commit=False
        )
        pa = [a.place(it).placement for it in items]
        b = PlacementEngine(
            make_node_set("most_used", 0.001),
            forced_kernel_scheduler(),
            auto_commit=False,
        )
        pb = [r.placement for r in b.place_many(items)]
        assert pa == pb

    def test_matches_oracle_with_dead_nodes(self):
        items = make_trace("meva", seed=13, n_items=30, reliability=0.9)
        a = PlacementEngine(make_node_set("most_used", 0.001), scalar_scheduler())
        b = PlacementEngine(make_node_set("most_used", 0.001), forced_kernel_scheduler())
        for eng in (a, b):
            eng.cluster.fail_node(0)
            eng.cluster.fail_node(4)
        pa = [a.place(it).placement for it in items]
        pb = [b.place(it).placement for it in items]
        assert pa == pb

    def test_matches_oracle_on_larger_cluster(self):
        # Exercises the budget cap (L*(L-1)/2 > MAX_MAPPINGS) and the
        # start-major enumeration order at a non-trivial scale.
        rng = np.random.default_rng(2)
        from repro.core import StorageNode

        nodes = [
            StorageNode(
                node_id=i,
                capacity_mb=float(rng.uniform(5e4, 2e5)),
                write_bw=float(rng.uniform(100, 250)),
                read_bw=float(rng.uniform(100, 400)),
                annual_failure_rate=float(rng.uniform(0.003, 0.08)),
            )
            for i in range(60)
        ]
        items = [
            DataItem(i, float(rng.uniform(10, 500)), float(i), 365.0, 0.999)
            for i in range(20)
        ]
        a = PlacementEngine(ClusterView.from_nodes(nodes), scalar_scheduler())
        b = PlacementEngine(ClusterView.from_nodes(nodes), forced_kernel_scheduler())
        pa = [a.place(it).placement for it in items]
        pb = [r.placement for r in b.place_many(items)]
        assert pa == pb

    def test_rejections_match_oracle(self):
        from repro.core import StorageNode

        # Nodes that essentially always fail within the window make any
        # meaningful target infeasible; a 1e12 MB item exhausts capacity.
        doomed = [
            StorageNode(i, 1e6, 200.0, 250.0, annual_failure_rate=500.0)
            for i in range(6)
        ]
        eng_a = PlacementEngine(ClusterView.from_nodes(doomed), scalar_scheduler())
        eng_b = PlacementEngine(
            ClusterView.from_nodes(doomed), forced_kernel_scheduler()
        )
        huge = DataItem(0, 1e12, 0.0, 365.0, 0.9)
        impossible = DataItem(1, 10.0, 0.0, 365.0, 0.999999)
        for it in (huge, impossible):
            ra, rb = eng_a.place(it), eng_b.place(it)
            assert ra.placement is None and rb.placement is None
            assert ra.reason == rb.reason

    def test_fewer_than_two_live_nodes(self):
        nodes = make_node_set("most_used", 0.001)[:2]
        eng = PlacementEngine(ClusterView.from_nodes(nodes), forced_kernel_scheduler())
        eng.cluster.fail_node(0)
        rec = eng.place(DataItem(0, 1.0, 0.0, 365.0, 0.9))
        assert rec.placement is None
        assert "fewer than 2" in rec.reason

    def test_registry_declares_batch_scoring_capability(self):
        assert get_spec("drex_sc").capabilities.batch_scoring
        # every hot-path adaptive scheduler is on the batched kernel
        # path as of the LB kernel (tests/test_lb_vectorized.py)
        assert get_spec("drex_lb").capabilities.batch_scoring

    def test_place_batch_is_pure(self):
        # Scoring a batch must not mutate scheduler state or the cluster.
        sched = forced_kernel_scheduler()
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        items = make_trace("meva", seed=1, n_items=10, reliability=0.9)
        used0 = cluster.used_mb.copy()
        smin0 = sched.smin_mb
        sched.place_batch(items, cluster)
        np.testing.assert_array_equal(cluster.used_mb, used0)
        assert sched.smin_mb == smin0

    def test_place_batch_running_smin_matches_sequential_observation(self):
        # Item j in a batch must be scored with the smallest size among
        # items 0..j (plus history), exactly as sequential place observes.
        sched_batch = forced_kernel_scheduler()
        sched_seq = scalar_scheduler()
        cluster = ClusterView.from_nodes(make_node_set("most_used", 0.001))
        # A shrinking size sequence moves the smin anchor mid-batch.
        items = [
            DataItem(i, size, float(i), 365.0, 0.95)
            for i, size in enumerate([500.0, 300.0, 80.0, 2.0, 60.0, 400.0])
        ]
        got = [d.placement for d in sched_batch.place_batch(items, cluster)]
        want = [sched_seq.place(it, cluster).placement for it in items]
        assert got == want
