"""Placement-engine + registry tests: registry round-trips, batched
place_many == sequential place (bit-for-bit), commit/rollback exactness,
capability-driven behavior, telemetry."""

import numpy as np
import pytest

from repro.core import (
    BatchContext,
    ClusterView,
    DataItem,
    Decision,
    Placement,
    PlacementEngine,
    register_scheduler,
    SCHEDULER_NAMES,
    Scheduler,
    batch_stats,
    create_scheduler,
    get_spec,
    parity_frontier,
    ParityFrontier,
    poisson_binomial_cdf,
    scheduler_capabilities,
    scheduler_names,
    StorageNode,
)
from repro.storage import make_node_set, make_trace


def mk_items(n=40, size=60.0, rt=0.99, dt=365.0):
    return [DataItem(i, size + 3.0 * i, float(i), dt, rt) for i in range(n)]


def mk_engine(name, **kw):
    return PlacementEngine(make_node_set("most_used", 0.001), name, **kw)


class TestRegistry:
    @pytest.mark.parametrize("name", SCHEDULER_NAMES)
    def test_round_trips_all_nine(self, name):
        sched = create_scheduler(name)
        assert sched.name == name
        spec = get_spec(name)
        assert spec.name == name
        assert scheduler_capabilities(sched) == spec.capabilities

    def test_all_nine_listed(self):
        assert set(SCHEDULER_NAMES) <= set(scheduler_names())

    def test_family_resolves_unregistered_configs(self):
        sched = create_scheduler("ec(10,4)")
        assert (sched.k, sched.p) == (10, 4)
        assert "ec(10,4)" in scheduler_names()

    def test_names_tolerate_case_and_whitespace(self):
        # The old make_scheduler accepted "ec(6, 3)"; keep that tolerance,
        # normalized to one canonical registry entry.
        sched = create_scheduler("EC(6, 3)")
        assert (sched.k, sched.p) == (6, 3)
        assert "ec(6, 3)" not in scheduler_names()

    def test_atomic_rollback_restores_scheduler_smin(self):
        eng = mk_engine("drex_sc")
        smin0 = eng.scheduler.smin_mb
        tiny = DataItem(0, 0.5, 0.0, 365.0, 0.9)
        huge = DataItem(1, 1e9, 0.0, 365.0, 0.9)
        eng.place_many([tiny, huge], atomic=True)
        assert eng.scheduler.smin_mb == smin0

    def test_batch_context_caches_stay_bounded(self):
        ctx = BatchContext(max_entries=8)
        eng = mk_engine("drex_sc")
        eng.place_many(mk_items(30), ctx=ctx)
        assert len(ctx._frontiers) <= 8

    def test_unknown_name_raises_with_known_list(self):
        with pytest.raises(ValueError, match="drex_sc"):
            create_scheduler("definitely_not_a_scheduler")

    def test_capability_flags_match_paper_semantics(self):
        # §5.7: only the four adaptive D-Rex/greedy algorithms grow parity.
        growers = {
            n for n in SCHEDULER_NAMES
            if get_spec(n).capabilities.supports_parity_growth
        }
        assert growers == {
            "drex_sc", "drex_lb", "greedy_min_storage", "greedy_least_used"
        }
        assert get_spec("daos").capabilities.adaptive
        assert not get_spec("ec(3,2)").capabilities.adaptive
        assert get_spec("random_spread").capabilities.randomized

    def test_default_capabilities_for_unregistered_scheduler(self):
        class Custom(Scheduler):
            name = "custom"

        caps = scheduler_capabilities(Custom())
        assert not caps.supports_parity_growth and not caps.adaptive


class TestPlaceManyEquivalence:
    @pytest.mark.parametrize("name", SCHEDULER_NAMES)
    def test_matches_sequential_place_bit_for_bit(self, name):
        items = mk_items()
        seq = mk_engine(name)
        seq_records = [seq.place(it) for it in items]
        bat = mk_engine(name)
        bat_records = bat.place_many(items)
        assert [r.placement for r in seq_records] == [
            r.placement for r in bat_records
        ]
        np.testing.assert_array_equal(seq.cluster.used_mb, bat.cluster.used_mb)

    @pytest.mark.parametrize("name", ["drex_sc", "drex_lb", "greedy_least_used"])
    def test_matches_on_real_trace(self, name):
        items = make_trace("meva", seed=3, n_items=60, reliability=0.95)
        seq = mk_engine(name)
        seq_pl = [seq.place(it).placement for it in items]
        bat = mk_engine(name)
        bat_pl = [r.placement for r in bat.place_many(items)]
        assert seq_pl == bat_pl

    def test_context_actually_reused(self):
        ctx = BatchContext()
        mk_engine("drex_sc").place_many(mk_items(), ctx=ctx)
        assert ctx.hits > 0

    def test_random_spread_accepts_negative_item_id(self):
        eng = mk_engine("random_spread", auto_commit=False)
        rec = eng.place(DataItem(-1, 10.0, 0.0, 365.0, 0.9))
        assert rec.placement is not None

    def test_reregistration_is_idempotent(self):
        import importlib

        import repro.core.algorithms as algos

        importlib.reload(algos)  # decorators re-run; must not raise
        assert create_scheduler("drex_sc").name == "drex_sc"

    def test_random_spread_repeatable_per_seed(self):
        # Same (seed, item) -> same mapping, regardless of call history.
        item = mk_items(1)[0]
        a = mk_engine("random_spread", auto_commit=False, seed=7)
        b = mk_engine("random_spread", auto_commit=False, seed=7)
        b.place(mk_items(2)[1])  # different call history
        assert a.place(item).placement == b.place(item).placement
        c = mk_engine("random_spread", auto_commit=False, seed=8)
        assert a.place(item).placement != c.place(item).placement


class TestCommitRollback:
    def test_place_commits(self):
        eng = mk_engine("drex_lb")
        before = eng.cluster.used_mb.copy()
        rec = eng.place(mk_items(1)[0])
        assert rec.ok and rec.committed
        ids = list(rec.placement.node_ids)
        assert np.all(eng.cluster.used_mb[ids] > before[ids])

    def test_rollback_restores_cluster_exactly(self):
        eng = mk_engine("drex_sc")
        snap = eng.snapshot()
        used0 = eng.cluster.used_mb.copy()
        alive0 = eng.cluster.alive.copy()
        eng.place_many(mk_items(25))
        assert eng.cluster.used_mb.sum() > used0.sum()
        eng.rollback(snap)
        np.testing.assert_array_equal(eng.cluster.used_mb, used0)
        np.testing.assert_array_equal(eng.cluster.alive, alive0)

    def test_atomic_batch_rolls_back_on_any_reject(self):
        eng = mk_engine("ec(6,3)")
        used0 = eng.cluster.used_mb.copy()
        items = mk_items(3) + [DataItem(99, 1e9, 0.0, 365.0, 0.9)]  # too big
        records = eng.place_many(items, atomic=True)
        assert not records[-1].ok
        assert not any(r.committed for r in records)
        np.testing.assert_array_equal(eng.cluster.used_mb, used0)

    def test_release_returns_bytes(self):
        eng = mk_engine("greedy_least_used")
        total0 = eng.cluster.used_mb.sum()
        rec = eng.place(mk_items(1)[0])
        eng.release(rec)
        assert eng.cluster.used_mb.sum() == pytest.approx(total0)

    def test_auto_commit_false_leaves_cluster_untouched(self):
        eng = mk_engine("drex_lb", auto_commit=False)
        used0 = eng.cluster.used_mb.copy()
        rec = eng.place(mk_items(1)[0])
        assert rec.ok and not rec.committed
        np.testing.assert_array_equal(eng.cluster.used_mb, used0)


class TestTelemetry:
    def test_records_carry_overhead_and_reason(self):
        eng = mk_engine("drex_lb")
        ok = eng.place(mk_items(1)[0])
        assert ok.overhead_s >= 0.0 and ok.reason == ""
        bad = eng.place(DataItem(1, 1e9, 0.0, 365.0, 0.9))
        assert not bad.ok and bad.reason != "" and bad.chunk_mb == 0.0

    def test_batch_stats_aggregates(self):
        eng = mk_engine("greedy_least_used")
        items = mk_items(10) + [DataItem(50, 1e9, 0.0, 365.0, 0.9)]
        stats = batch_stats(eng.place_many(items))
        assert stats["n_items"] == 11
        assert stats["n_placed"] == 10 and stats["n_rejected"] == 1
        assert stats["overhead_per_item_ms"] > 0.0
        assert sum(stats["reject_reasons"].values()) == 1

    def test_engine_stats_accumulate(self):
        eng = mk_engine("drex_lb")
        eng.place_many(mk_items(5))
        assert eng.stats["n_placed"] == 5
        assert eng.stats["mb_committed"] > 0.0

    def test_rolled_back_batch_leaves_no_stats_trace(self):
        eng = mk_engine("ec(6,3)")
        stats0 = dict(eng.stats)
        items = mk_items(3) + [DataItem(99, 1e9, 0.0, 365.0, 0.9)]
        eng.place_many(items, atomic=True)
        assert eng.stats == stats0

    def test_batch_stats_mb_committed_honors_flag(self):
        eng = mk_engine("drex_lb", auto_commit=False)
        stats = batch_stats(eng.place_many(mk_items(4)))
        assert stats["mb_placed"] > 0.0
        assert stats["mb_committed"] == 0.0

    def test_release_adjusts_committed_bytes(self):
        eng = mk_engine("drex_lb")
        rec = eng.place(mk_items(1)[0])
        eng.release(rec)
        assert eng.stats["mb_committed"] == pytest.approx(0.0)

    def test_context_safe_across_different_clusters(self):
        # A (mis)shared context must never leak one cluster's failure
        # probabilities into another's decisions.
        ctx = BatchContext()
        item = mk_items(1)[0]
        a = PlacementEngine(make_node_set("most_used", 0.001), "drex_lb")
        b = PlacementEngine(make_node_set("most_unreliable", 0.001), "drex_lb")
        pa = a.place(item, ctx=ctx).placement
        pb = b.place(item, ctx=ctx).placement
        assert pa == PlacementEngine(
            make_node_set("most_used", 0.001), "drex_lb"
        ).place(item).placement
        assert pb == PlacementEngine(
            make_node_set("most_unreliable", 0.001), "drex_lb"
        ).place(item).placement

    def test_legacy_two_arg_scheduler_still_works(self):
        class Legacy(Scheduler):
            name = "legacy"

            def place(self, item, cluster):  # old signature, no ctx
                return create_scheduler("ec(3,2)").place(item, cluster)

        eng = PlacementEngine(make_node_set("most_used", 0.001), Legacy())
        records = eng.place_many(mk_items(3))
        assert all(r.ok for r in records)


class TestRepairPlanning:
    """PlacementEngine.plan_repair — the one repair policy (§5.7)."""

    def _degrade(self, eng, item):
        rec = eng.place(item)
        assert rec.ok
        dead = rec.placement.node_ids[0]
        eng.cluster.used_mb[dead] = 0.0  # fail-stop loses the bytes
        eng.cluster.alive[dead] = False
        return rec, dead

    def test_plan_replaces_lost_chunks_and_reserves_bytes(self):
        eng = mk_engine("drex_lb")
        item = mk_items(1)[0]
        rec, dead = self._degrade(eng, item)
        before = eng.cluster.used_mb.copy()
        plan = eng.plan_repair(item, rec.placement, chunk_mb=rec.chunk_mb)
        assert plan.ok and plan.committed
        assert dead not in plan.placement.node_ids
        assert len(plan.new_nodes) >= 1
        assert set(plan.survivors) < set(plan.placement.node_ids)
        for n in plan.new_nodes:
            assert eng.cluster.used_mb[n] == pytest.approx(
                before[n] + plan.chunk_mb
            )
        assert eng.stats["n_repairs_planned"] == 1
        assert eng.stats["repair_mb_committed"] == pytest.approx(plan.repair_mb)

    def test_noop_when_nothing_lost(self):
        eng = mk_engine("drex_lb")
        item = mk_items(1)[0]
        rec = eng.place(item)
        plan = eng.plan_repair(item, rec.placement, chunk_mb=rec.chunk_mb)
        assert plan.ok and plan.new_nodes == ()
        assert plan.placement == rec.placement

    def test_unrecoverable_below_k_survivors(self):
        eng = mk_engine("ec(3,2)")
        item = mk_items(1)[0]
        rec = eng.place(item)
        for n in rec.placement.node_ids[:3]:  # K=3: only 2 survive
            eng.cluster.alive[n] = False
            eng.cluster.used_mb[n] = 0.0
        plan = eng.plan_repair(item, rec.placement, chunk_mb=rec.chunk_mb)
        assert not plan.ok and not plan.committed
        assert "unrecoverable" in plan.reason
        assert eng.stats["n_repairs_failed"] == 1

    def test_capability_gates_parity_growth(self):
        # High-AFR nodes + a seven-nines target: the degraded 3-node
        # mapping cannot meet RT with P=1, so repair must buy parity —
        # which only schedulers declaring supports_parity_growth may do.
        from repro.core import DataItem, Placement

        item = DataItem(0, 10.0, 0.0, 365.0, 0.99999)
        pl = Placement(k=2, p=1, node_ids=(0, 1, 2))

        ec = PlacementEngine(make_node_set("most_unreliable", 0.001), "ec(3,2)")
        ec.cluster.alive[0] = False
        static_plan = ec.plan_repair(item, pl, chunk_mb=5.0, commit=False)
        assert not static_plan.ok
        assert "reliability" in static_plan.reason

        lb = PlacementEngine(make_node_set("most_unreliable", 0.001), "drex_lb")
        lb.cluster.alive[0] = False
        grown = lb.plan_repair(item, pl, chunk_mb=5.0, commit=False)
        assert grown.ok and grown.added_parity >= 1
        assert grown.placement.p == pl.p + grown.added_parity
        assert not grown.committed
        # The caller's flag gates too (SimConfig.allow_parity_growth=False).
        denied = lb.plan_repair(
            item, pl, chunk_mb=5.0, commit=False, allow_parity_growth=False
        )
        assert not denied.ok

    def test_require_target_false_keeps_kp_best_effort(self):
        from repro.core import DataItem, Placement

        item = DataItem(0, 10.0, 0.0, 365.0, 0.99999)
        pl = Placement(k=2, p=1, node_ids=(0, 1, 2))
        eng = PlacementEngine(make_node_set("most_unreliable", 0.001), "ec(3,2)")
        eng.cluster.alive[0] = False
        plan = eng.plan_repair(
            item, pl, chunk_mb=5.0, commit=False, require_target=False
        )
        assert plan.ok and plan.added_parity == 0
        assert plan.placement.p == pl.p

    def test_not_enough_capacity_reports(self):
        eng = mk_engine("drex_lb")
        item = mk_items(1)[0]
        rec, _ = self._degrade(eng, item)
        eng.cluster.used_mb[:] = eng.cluster.capacity_mb  # no room anywhere
        plan = eng.plan_repair(item, rec.placement, chunk_mb=rec.chunk_mb)
        assert not plan.ok
        assert "not enough replacement capacity" in plan.reason

    def test_abort_repair_returns_reservation(self):
        eng = mk_engine("drex_lb")
        item = mk_items(1)[0]
        rec, _ = self._degrade(eng, item)
        before = eng.cluster.used_mb.copy()
        plan = eng.plan_repair(item, rec.placement, chunk_mb=rec.chunk_mb)
        assert plan.committed
        eng.abort_repair(plan)
        np.testing.assert_allclose(eng.cluster.used_mb, before)
        assert eng.stats["repair_mb_committed"] == pytest.approx(0.0)

    def test_batch_context_amortizes_across_repairs(self):
        eng = mk_engine("drex_lb")
        items = mk_items(6)
        recs = [eng.place(it) for it in items]
        dead = recs[0].placement.node_ids[0]
        eng.cluster.used_mb[dead] = 0.0
        eng.cluster.alive[dead] = False
        ctx = BatchContext()
        for it, rec in zip(items, recs):
            if dead in rec.placement.node_ids:
                eng.plan_repair(it, rec.placement, chunk_mb=rec.chunk_mb, ctx=ctx)
        assert ctx.hits > 0


class TestBatchStaleness:
    """``place_many`` memoization/scoring must key on *post-commit*
    cluster state: the Nth item of a batch can never reuse a frontier or
    window score computed against pre-commit free space (see the
    BatchContext docstring)."""

    def _filling_setup(self):
        # One node towers over the rest in free space, so every scheduler
        # that sorts by free space targets it first; the batch's items
        # are sized to fill it mid-batch, flipping the sort order (and
        # with it the frontier cache keys) between commits.
        nodes = [
            StorageNode(0, 4_000.0, 200.0, 250.0, 0.02),
            StorageNode(1, 2_500.0, 180.0, 240.0, 0.03),
            StorageNode(2, 2_400.0, 190.0, 230.0, 0.01),
            StorageNode(3, 2_300.0, 170.0, 220.0, 0.04),
            StorageNode(4, 2_200.0, 160.0, 210.0, 0.02),
            StorageNode(5, 2_100.0, 150.0, 200.0, 0.03),
        ]
        items = [DataItem(i, 900.0, float(i), 365.0, 0.9) for i in range(12)]
        return nodes, items

    @pytest.mark.parametrize(
        "name",
        ["drex_sc", "drex_lb", "greedy_least_used", "greedy_min_storage"],
    )
    def test_batch_that_fills_a_node_matches_sequential(self, name):
        nodes, items = self._filling_setup()
        seq = PlacementEngine(ClusterView.from_nodes(nodes), name)
        want = [seq.place(it).placement for it in items]
        bat = PlacementEngine(ClusterView.from_nodes(nodes), name)
        ctx = BatchContext()
        got = [r.placement for r in bat.place_many(items, ctx=ctx)]
        assert got == want
        np.testing.assert_array_equal(seq.cluster.used_mb, bat.cluster.used_mb)
        # The free-space ordering changed mid-batch, so placements cannot
        # all target the same node set — i.e. later items really did see
        # post-commit state rather than the batch-start snapshot.
        mapped = {pl.node_ids for pl in got if pl is not None}
        assert len(mapped) > 1

    def test_no_node_exceeds_capacity_under_batching(self):
        # If the Nth item reused a pre-commit frontier/score, the freest
        # node would be oversubscribed; the engine's validator would
        # raise and this loop would not complete.
        nodes, items = self._filling_setup()
        eng = PlacementEngine(ClusterView.from_nodes(nodes), "drex_sc")
        eng.place_many(items)
        assert np.all(eng.cluster.used_mb <= eng.cluster.capacity_mb + 1e-9)

    def test_mixed_rejects_and_commits_match_sequential(self):
        # Exercises the batched path's adaptive regrouping: rejected
        # items do not invalidate scores, committed ones do.
        nodes, items = self._filling_setup()
        too_big = DataItem(99, 1e9, 0.0, 365.0, 0.9)
        mixed = [too_big, items[0], too_big, items[1], items[2], too_big]
        seq = PlacementEngine(ClusterView.from_nodes(nodes), "drex_sc")
        want = [seq.place(it).placement for it in mixed]
        bat = PlacementEngine(ClusterView.from_nodes(nodes), "drex_sc")
        got = [r.placement for r in bat.place_many(mixed)]
        assert got == want
        np.testing.assert_array_equal(seq.cluster.used_mb, bat.cluster.used_mb)

    def test_noncommitting_engine_scores_whole_batch_against_snapshot(self):
        # auto_commit=False never mutates the view, so nothing is stale
        # and batch == sequential trivially; pin that too.
        nodes, items = self._filling_setup()
        seq = PlacementEngine(ClusterView.from_nodes(nodes), "drex_sc", auto_commit=False)
        want = [seq.place(it).placement for it in items]
        bat = PlacementEngine(ClusterView.from_nodes(nodes), "drex_sc", auto_commit=False)
        got = [r.placement for r in bat.place_many(items)]
        assert got == want

    def test_short_place_batch_return_raises_instead_of_spinning(self):
        # A batch-scoring scheduler violating the one-decision-per-item
        # contract must fail loudly, not hang the regrouping loop.
        nodes, items = self._filling_setup()
        eng = PlacementEngine(ClusterView.from_nodes(nodes), "drex_sc")
        eng.scheduler.place_batch = lambda its, cluster, ctx=None: []
        with pytest.raises(RuntimeError, match="place_batch returned"):
            eng.place_many(items)

    def test_batched_overhead_gauge_covers_discarded_scores(self):
        # Scores discarded by mid-group commits still cost wall time;
        # the aggregate gauge must not under-count relative to the
        # per-record amortized shares.
        nodes, items = self._filling_setup()
        eng = PlacementEngine(ClusterView.from_nodes(nodes), "drex_sc")
        records = eng.place_many(items)
        assert eng.stats["overhead_s"] >= sum(r.overhead_s for r in records) - 1e-9


@register_scheduler(
    "test_pair_windowed", batch_scoring=True, windowed_scoring=True
)
class _PairWindowedScheduler(Scheduler):
    """Window-local test scheduler: item i maps replica-style (K=1, P=1)
    onto the fixed node pair ``(2i, 2i+1) mod n`` — the decision is a
    pure function of that pair's free space (plus its static failure
    probabilities), so ``window`` is exactly the pair and reuse across
    disjoint commits is provably exact.  Registered for real so the
    registry-driven invariant suite sweeps it like any scheduler."""

    name = "test_pair_windowed"

    def _decide(self, item, cluster, ctx=None) -> Decision:
        n = cluster.n_nodes
        a, b = (2 * item.item_id) % n, (2 * item.item_id + 1) % n
        if a == b or not (cluster.alive[a] and cluster.alive[b]):
            return Decision(None, 1, "pair unavailable")
        chunk = item.size_mb  # K = 1
        if cluster.free_mb[a] < chunk or cluster.free_mb[b] < chunk:
            return Decision(None, 1, "pair full")
        fp = self._fail_probs(cluster, item, ctx)[[a, b]]
        mp = self._min_parity(fp, item.reliability_target, ctx)
        if mp < 0 or mp > 1:
            return Decision(None, 1, "pair cannot meet reliability target")
        ids = (int(a), int(b))
        return Decision(
            Placement(k=1, p=1, node_ids=ids), 1, "", window=ids
        )

    def place(self, item, cluster, ctx=None) -> Decision:
        self.observe_item(item)
        return self._decide(item, cluster, ctx)

    def place_batch(self, items, cluster, ctx=None):
        return [self._decide(it, cluster, ctx) for it in items]


class TestDependencyAwareRescoring:
    """Windowed-scoring schedulers keep batched scores across commits
    that are provably independent of them — and *only* those: a score
    whose window intersects a committed mapping, or that was computed
    before the free-desc order changed, is always re-scored."""

    def _spy(self, eng):
        calls = []
        orig = eng.scheduler.place_batch

        def spy(items, cluster, ctx=None):
            calls.append(len(items))
            return orig(items, cluster, ctx=ctx)

        eng.scheduler.place_batch = spy
        return calls

    def _nodes(self, n=12, cap=25_000.0, step=1_000.0):
        # Huge free-space gaps: small commits cannot reorder the
        # free-desc sort, so the order-unchanged condition holds.
        return [
            StorageNode(i, cap - step * i, 100.0, 100.0, 0.01)
            for i in range(n)
        ]

    def test_disjoint_windows_survive_commits_in_one_scoring_call(self):
        items = [DataItem(i, 10.0, float(i), 365.0, 0.9) for i in range(6)]
        seq = PlacementEngine(
            ClusterView.from_nodes(self._nodes()), "test_pair_windowed"
        )
        want = [seq.place(it).placement for it in items]
        bat = PlacementEngine(
            ClusterView.from_nodes(self._nodes()), "test_pair_windowed"
        )
        calls = self._spy(bat)
        got = [r.placement for r in bat.place_many(items)]
        assert got == want and all(pl is not None for pl in got)
        # every window disjoint + order stable -> one vectorized call
        # scored the whole batch despite 6 commits.
        assert calls == [6]
        np.testing.assert_array_equal(seq.cluster.used_mb, bat.cluster.used_mb)

    def test_intersecting_window_is_never_reused(self):
        # Items 0 and 6 share the pair (0, 1) on a 12-node cluster; the
        # cluster only has room for one of them there, so reusing item
        # 6's pre-commit score would commit onto full nodes (the
        # engine's validator would raise).
        nodes = self._nodes()
        nodes[0] = StorageNode(0, 10_000.0, 100.0, 100.0, 0.01, used_mb=9_989.0)
        nodes[1] = StorageNode(1, 9_000.0, 100.0, 100.0, 0.01, used_mb=8_989.0)
        items = [
            DataItem(0, 10.0, 0.0, 365.0, 0.9),
            DataItem(3, 10.0, 1.0, 365.0, 0.9),   # disjoint pair (6, 7)
            DataItem(6, 10.0, 2.0, 365.0, 0.9),   # pair (0, 1) again
        ]
        seq = PlacementEngine(ClusterView.from_nodes(nodes), "test_pair_windowed")
        want = [seq.place(it) for it in items]
        assert want[0].ok and not want[2].ok  # the conflict is real
        bat = PlacementEngine(ClusterView.from_nodes(nodes), "test_pair_windowed")
        calls = self._spy(bat)
        got = bat.place_many(items)
        assert [r.placement for r in got] == [r.placement for r in want]
        assert len(calls) >= 2  # item 6 was re-scored post-commit
        np.testing.assert_array_equal(seq.cluster.used_mb, bat.cluster.used_mb)

    def test_order_change_invalidates_disjoint_windows(self):
        # Items large enough to flip the free-desc order: even disjoint
        # windows must be re-scored (windowed scores are defined
        # relative to the sort order).
        items = [DataItem(i, 2_500.0, float(i), 365.0, 0.9) for i in range(4)]
        nodes = self._nodes(cap=20_000.0, step=100.0)
        seq = PlacementEngine(ClusterView.from_nodes(nodes), "test_pair_windowed")
        want = [seq.place(it).placement for it in items]
        bat = PlacementEngine(ClusterView.from_nodes(nodes), "test_pair_windowed")
        calls = self._spy(bat)
        got = [r.placement for r in bat.place_many(items)]
        assert got == want
        assert len(calls) >= 2  # the first commit reordered free space

    def test_windowless_decisions_always_rescore(self):
        # A windowed-capability scheduler may still emit window=None
        # decisions (e.g. rejections); a commit must invalidate those.
        eng = PlacementEngine(
            ClusterView.from_nodes(self._nodes()), "test_pair_windowed"
        )
        orig = eng.scheduler.place_batch
        eng.scheduler.place_batch = lambda its, cluster, ctx=None: [
            dataclasses_replace_no_window(d) for d in orig(its, cluster, ctx=ctx)
        ]
        calls = []
        inner = eng.scheduler.place_batch

        def spy(items, cluster, ctx=None):
            calls.append(len(items))
            return inner(items, cluster, ctx=ctx)

        eng.scheduler.place_batch = spy
        items = [DataItem(i, 10.0, float(i), 365.0, 0.9) for i in range(4)]
        records = eng.place_many(items)
        assert all(r.ok for r in records)
        assert len(calls) >= 4  # every commit forced a fresh scoring call

    def test_conservative_schedulers_unchanged_by_the_machinery(self):
        # drex_lb declares batch_scoring but NOT windowed_scoring (f_avg
        # is cluster-global): its batched path must still rescore after
        # every commit and stay bit-identical to sequential place.
        assert not get_spec("drex_lb").capabilities.windowed_scoring
        items = [DataItem(i, 700.0, float(i), 365.0, 0.9) for i in range(8)]
        nodes = self._nodes(n=8, cap=4_000.0, step=300.0)
        seq = PlacementEngine(ClusterView.from_nodes(nodes), "drex_lb")
        want = [seq.place(it).placement for it in items]
        bat = PlacementEngine(ClusterView.from_nodes(nodes), "drex_lb")
        got = [r.placement for r in bat.place_many(items)]
        assert got == want
        np.testing.assert_array_equal(seq.cluster.used_mb, bat.cluster.used_mb)

    def test_least_used_declares_windowed_scoring(self):
        # The one built-in whose decisions are provably window-local
        # (the scanned prefix IS the mapping; see the class docstring).
        assert get_spec("greedy_least_used").capabilities.windowed_scoring
        cluster = ClusterView.from_nodes(self._nodes())
        rec = create_scheduler("greedy_least_used").place_batch(
            [DataItem(0, 10.0, 0.0, 365.0, 0.9)], cluster
        )[0]
        assert rec.placement is not None
        assert rec.window == rec.placement.node_ids


def dataclasses_replace_no_window(d: Decision) -> Decision:
    import dataclasses

    return dataclasses.replace(d, window=None)


class TestParityFrontierKernel:
    def test_matches_per_prefix_cdf_scan(self):
        rng = np.random.default_rng(5)
        for _ in range(25):
            n = int(rng.integers(2, 16))
            probs = rng.uniform(0.0, 0.6, size=n)
            t = float(rng.uniform(0.5, 0.99999))
            fr = parity_frontier(probs, t)
            for m in range(1, n + 1):
                want = -1
                for p in range(m):
                    if poisson_binomial_cdf(probs[:m], p, "exact") >= t:
                        want = p
                        break
                assert fr[m - 1] == want

    def test_lazy_extension_matches_eager(self):
        probs = np.array([0.1, 0.3, 0.05, 0.2, 0.4, 0.15])
        eager = parity_frontier(probs, 0.999)
        lazy = ParityFrontier(probs, 0.999)
        assert lazy.min_parity(2) == eager[1]
        assert lazy.min_parity(6) == eager[5]
        assert lazy.min_parity(4) == eager[3]  # backwards query: no re-run

    def test_monotone_in_prefix_length(self):
        rng = np.random.default_rng(9)
        probs = rng.uniform(0.0, 0.5, size=30)
        fr = parity_frontier(probs, 0.9999)
        feas = fr[fr >= 0]
        assert np.all(np.diff(feas) >= 0)

    def test_out_of_range_queries(self):
        fr = ParityFrontier(np.array([0.1, 0.2]), 0.99)
        assert fr.min_parity(0) == -1
        assert fr.min_parity(3) == -1
