"""Thread-safety regressions for the shared telemetry counters.

The serve frontier scores placements from a worker-thread pool, so the
module-level counters it bumps are hit concurrently:

* ``repro.core.prefilter`` per-scheduler event counters — guarded by the
  module ``_lock``;
* ``repro.kernels.ops._MATRIX_BUILDS`` — ``lru_cache`` does NOT hold its
  internal lock while the wrapped builder runs, so two threads missing
  the same key both execute the builder; a bare ``+= 1`` there is a
  read-modify-write race that loses increments.  Builds are counted via
  ``_note_build`` under ``_builds_lock``.

These tests hammer both from many threads and pin the exact totals.
A lost-update race is probabilistic, so they use enough increments per
thread that an unguarded ``+=`` fails in practice (verified by breaking
the lock locally), while staying fast when the code is correct.
"""

import threading

import pytest

from repro.core import prefilter
from repro.kernels import ops

N_THREADS = 8
N_PER_THREAD = 2_000


def _hammer(fn):
    """Run ``fn(thread_index)`` from N_THREADS threads, starting on a
    barrier so the increments genuinely overlap."""
    barrier = threading.Barrier(N_THREADS)
    errors = []

    def runner(t):
        try:
            barrier.wait()
            fn(t)
        except Exception as exc:  # pragma: no cover - failure reporting
            errors.append(exc)

    threads = [threading.Thread(target=runner, args=(t,)) for t in range(N_THREADS)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors, errors


class TestPrefilterCounters:
    def setup_method(self):
        prefilter.reset_stats()

    def teardown_method(self):
        prefilter.reset_stats()

    def test_concurrent_record_exact_totals(self):
        def work(t):
            # every thread mixes schedulers and events, forcing
            # concurrent setdefault + increment on shared dicts
            for i in range(N_PER_THREAD):
                prefilter.record("drex_sc", "engaged")
                prefilter.record("drex_lb", "accepted", 2)
                if i % 4 == 0:
                    prefilter.record("drex_sc", "fallback")

        _hammer(work)
        s = prefilter.stats()
        assert s["drex_sc"]["engaged"] == N_THREADS * N_PER_THREAD
        assert s["drex_sc"]["fallback"] == N_THREADS * (N_PER_THREAD // 4)
        assert s["drex_lb"]["accepted"] == 2 * N_THREADS * N_PER_THREAD

    def test_concurrent_stats_reads_are_safe_snapshots(self):
        stop = threading.Event()
        seen = []

        def reader():
            while not stop.is_set():
                snap = prefilter.stats()
                # a snapshot is a copy: mutating it must not corrupt
                for per in snap.values():
                    per["engaged"] = -1
                seen.append(snap)

        rt = threading.Thread(target=reader)
        rt.start()
        try:
            _hammer(lambda t: [prefilter.record("greedy", "bypassed")
                               for _ in range(N_PER_THREAD)])
        finally:
            stop.set()
            rt.join()
        assert prefilter.stats()["greedy"]["bypassed"] == N_THREADS * N_PER_THREAD


class TestMatrixBuildCounters:
    def setup_method(self):
        ops.reset_matrix_caches()

    def teardown_method(self):
        ops.reset_matrix_caches()

    def test_note_build_exact_under_contention(self):
        """The raw counter hook: N_THREADS * N_PER_THREAD increments
        from overlapping threads must all land (the unguarded ``+=``
        this replaced loses a measurable fraction of them)."""

        def work(t):
            for _ in range(N_PER_THREAD):
                ops._note_build("encode" if t % 2 == 0 else "decode")

        _hammer(work)
        stats = ops.matrix_cache_stats()
        half = (N_THREADS // 2) * N_PER_THREAD
        assert stats["encode_builds"] == half
        assert stats["decode_builds"] == half

    def test_concurrent_builders_and_stats_readers(self):
        """Worker threads racing real cached builders (distinct and
        shared keys) while another thread polls matrix_cache_stats:
        totals stay consistent and every build is counted."""
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                s = ops.matrix_cache_stats()
                assert s["encode_builds"] >= 0 and s["decode_builds"] >= 0

        rt = threading.Thread(target=reader)
        rt.start()
        try:
            def work(t):
                for i in range(40):
                    # shared key (2,1) races the same lru_cache miss;
                    # (2 + t % 3, 2) spreads across a few keys
                    ops._encode_matrices(2, 1)
                    ops._encode_matrices(2 + t % 3, 2)

            _hammer(work)
        finally:
            stop.set()
            rt.join()
        stats = ops.matrix_cache_stats()
        # lru_cache may run a builder more than once on a concurrent
        # miss, never less: counted builds >= distinct keys, and every
        # key is cached exactly once afterwards.
        assert stats["encode_builds"] >= 4
        assert stats["encode_cache"]["size"] == 4
        before = stats["encode_builds"]
        ops._encode_matrices(2, 1)  # warm hit: no new build
        assert ops.matrix_cache_stats()["encode_builds"] == before
