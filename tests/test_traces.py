"""Focused tests for the workload-trace generators (`storage/traces.py`):
volume standardization, item-count caps, and the §5.5 random-nines
reliability-target bounds across seeds."""

import numpy as np
import pytest

from repro.storage.traces import (
    DATASET_NAMES,
    _SPECS,
    make_trace,
    random_reliability_targets,
)


class TestTotalMbTrimming:
    @pytest.mark.parametrize("name", ["meva", "sentinel2"])
    def test_stops_at_target_volume(self, name):
        target = 30_000.0
        items = make_trace(name, seed=3, total_mb=target)
        total = sum(i.size_mb for i in items)
        # Reaches the target...
        assert total >= target
        # ...with minimal overshoot: dropping the last item goes under.
        assert total - items[-1].size_mb < target

    def test_tiny_target_yields_single_item(self):
        items = make_trace("meva", seed=0, total_mb=1e-3)
        assert len(items) == 1

    def test_trimming_is_deterministic(self):
        a = make_trace("meva", seed=11, total_mb=20_000.0)
        b = make_trace("meva", seed=11, total_mb=20_000.0)
        assert [i.size_mb for i in a] == [i.size_mb for i in b]


class TestNItemsCap:
    @pytest.mark.parametrize("name", DATASET_NAMES)
    @pytest.mark.parametrize("n", [1, 100, 1500])
    def test_caps_exactly(self, name, n):
        items = make_trace(name, seed=0, n_items=n)
        assert len(items) == n

    def test_item_ids_are_sequential(self):
        items = make_trace("meva", seed=0, n_items=50)
        assert [i.item_id for i in items] == list(range(50))

    def test_default_count_matches_table3(self):
        items = make_trace("meva", seed=0)
        assert len(items) == _SPECS["meva"].n_items


class TestRandomNinesBounds:
    @pytest.mark.parametrize("seed", [0, 1, 7, 42, 1234])
    def test_within_section_5_5_bounds_across_seeds(self, seed):
        rng = np.random.default_rng(seed)
        rts = random_reliability_targets(5_000, rng)
        # §5.5: f(-1)=90% is the floor; f(5)=99.99999% (seven nines) the
        # ceiling; RT is a probability in (0, 1).
        assert rts.min() >= 0.90
        assert rts.max() <= 0.9999999 + 1e-12
        assert np.all((rts > 0.0) & (rts < 1.0))

    def test_trace_reliability_modes(self):
        fixed = make_trace("meva", seed=0, n_items=20, reliability=0.95)
        assert all(i.reliability_target == 0.95 for i in fixed)
        nines = make_trace("meva", seed=0, n_items=2000)
        rts = np.array([i.reliability_target for i in nines])
        assert rts.min() >= 0.90 and rts.max() <= 0.9999999 + 1e-12
        with pytest.raises(ValueError, match="reliability mode"):
            make_trace("meva", seed=0, n_items=5, reliability="bogus")
