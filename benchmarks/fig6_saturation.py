"""Fig. 6: per-node consumed vs available storage for EC(3,2) @ RT 90% —
the fast-node saturation pathology the dynamic algorithms avoid.

Also records D-Rex SC's scheduling overhead on this exact workload,
scalar numpy oracle vs the jitted/vmapped window-scoring kernel under
batched ``place_many`` (pure decision cost), so the Fig. 6 story carries
its scheduling price tag alongside the utilization curves.
"""

import numpy as np

from repro.core import PlacementEngine, create_scheduler
from .common import csv_row, emit, sc_scalar_vs_vectorized, sim


def _sc_overhead_columns(items) -> dict:
    """Scalar vs vectorized SC decision cost over the Fig. 6 trace."""
    from repro.storage import make_node_set
    from .common import CAP_SCALE

    return sc_scalar_vs_vectorized(
        lambda: PlacementEngine(
            make_node_set("most_used", CAP_SCALE),
            create_scheduler("drex_sc"),
            auto_commit=False,
        ),
        items,
    )


def run() -> list[str]:
    res32, _, _ = sim("most_used", "meva", "ec(3,2)", reliability=0.9)
    ressc, _, items = sim("most_used", "meva", "drex_sc", reliability=0.9)
    from repro.storage import make_node_set
    from .common import CAP_SCALE

    caps = np.array([n.capacity_mb for n in make_node_set("most_used", CAP_SCALE)])
    overhead = _sc_overhead_columns(items)
    emit("fig6", {
        "capacity_mb": caps.tolist(),
        "ec32_used_mb": res32.per_node_used_mb.tolist(),
        "drex_sc_used_mb": ressc.per_node_used_mb.tolist(),
        "sc_scheduling_overhead": overhead,
    })
    ec_util = res32.per_node_used_mb.sum() / caps.sum()
    sc_util = ressc.per_node_used_mb.sum() / caps.sum()
    ec_idle = int((res32.per_node_used_mb / caps < 0.5).sum())
    return [
        csv_row("fig6_utilization", 0.0,
                f"ec32_util={ec_util:.2f};drex_sc_util={sc_util:.2f};ec32_halfempty_nodes={ec_idle}"),
        csv_row(
            "fig6_sc_vectorized_overhead",
            overhead["vectorized_ms_per_item"] * 1e3,
            f"scalar_vs_vectorized={overhead['speedup_vs_scalar']:.2f}x",
        ),
    ]
