"""Fig. 6: per-node consumed vs available storage for EC(3,2) @ RT 90% —
the fast-node saturation pathology the dynamic algorithms avoid."""

import numpy as np

from .common import csv_row, emit, sim


def run() -> list[str]:
    res32, _, _ = sim("most_used", "meva", "ec(3,2)", reliability=0.9)
    ressc, _, _ = sim("most_used", "meva", "drex_sc", reliability=0.9)
    from repro.storage import make_node_set
    from .common import CAP_SCALE

    caps = np.array([n.capacity_mb for n in make_node_set("most_used", CAP_SCALE)])
    emit("fig6", {
        "capacity_mb": caps.tolist(),
        "ec32_used_mb": res32.per_node_used_mb.tolist(),
        "drex_sc_used_mb": ressc.per_node_used_mb.tolist(),
    })
    ec_util = res32.per_node_used_mb.sum() / caps.sum()
    sc_util = ressc.per_node_used_mb.sum() / caps.sum()
    ec_idle = int((res32.per_node_used_mb / caps < 0.5).sum())
    return [csv_row("fig6_utilization", 0.0,
                    f"ec32_util={ec_util:.2f};drex_sc_util={sc_util:.2f};ec32_halfempty_nodes={ec_idle}")]
