"""Fig. 7: proportion stored across the four node sets (random nines)."""

from .common import ALGOS, SOTA, csv_row, emit, sim

SETS = ("most_used", "most_unreliable", "most_reliable", "homogeneous")


def run() -> list[str]:
    out = {}
    for ns in SETS:
        out[ns] = {}
        for algo in ALGOS:
            res, _, _ = sim(ns, "meva", algo)
            out[ns][algo] = res.stored_fraction
    emit("fig7", out)
    lines = []
    for ns in SETS:
        sc = out[ns]["drex_sc"]
        avg_sota = sum(out[ns][a] for a in SOTA) / len(SOTA)
        lines.append(csv_row(f"fig7_{ns}", 0.0,
                             f"drex_sc={sc:.3f};avg_sota={avg_sota:.3f};gain={sc/avg_sota-1:+.1%}"))
    return lines
