"""Fig. 11: matched-volume throughput difference across datasets."""

from .common import ALGOS, DREX, csv_row, emit, matched_throughput, sim

DATASETS = ("sentinel2", "swim", "ibm_cos")


def run() -> list[str]:
    out = {}
    lines = []
    for ds in DATASETS:
        res = {}
        for algo in ALGOS:
            res[algo], _, _ = sim("most_used", ds, algo)
        out[ds] = {}
        for base in DREX:
            out[ds][base] = {
                o: matched_throughput(res, base, o) for o in ALGOS if o != base
            }
        worst = min(out[ds]["drex_sc"].values())
        lines.append(csv_row(f"fig11_{ds}", 0.0, f"drex_sc_worst_delta_mbps={worst:+.2f}"))
    emit("fig11", out)
    return lines
