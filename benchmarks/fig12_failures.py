"""Fig. 12: data retained after 2..7 node failures
(Most Unreliable nodes, MEVA over 70 days), plus a repair-bandwidth
sweep: the event-driven simulator's finite per-node repair budget makes
retained fraction sensitive to how fast lost chunks are rebuilt — items
whose repairs are still in flight when the next failure lands are lost
(Luby-style repair-rate lower bounds; ``repair_bw_mbps=inf`` is the
paper's instantaneous-repair model).

Two lanes (ours) quantify the failure-domain work.  The **rack-event
lane** partitions the node set into racks and kills one whole rack
mid-run: topology-aware placement (``PlacementConstraints`` caps
chunks per rack and requires a spread width) vs topology-blind, swept
across the repair bandwidths.  The **repair-priority comparison** runs
the failure-burst bandwidth sweep twice — health-prioritized
(most-degraded-first) vs the legacy FIFO replan order.  The gate
(benchmarks/gate.py) pins the retained fractions as deterministic
equalities and ``meets_improvement_floor`` — the acceptance floor that
topology-aware placement retains at least as much as topology-blind
and health-prioritized repair at least as much as FIFO, at *every*
swept bandwidth."""

import math

from repro.core import PlacementConstraints

from .common import ALGOS, csv_row, emit, sim

#: per-node repair ingest bandwidths (MB/s) for the sweep; chosen against
#: the CAP_SCALE-shrunk chunk sizes so the slowest settings leave repairs
#: in flight when the next failure hits.
REPAIR_BWS = (math.inf, 1.0, 0.1, 0.01, 0.001)

#: burst of closely-spaced weighted-random failures for the sweep — wide
#: spacing lets even slow repairs drain between failures.
_BURST = tuple((30.0 + i * 0.05, -1) for i in range(5))


def _schedule(n_failures: int):
    # spread failures across the 70-day window; weighted-random node draw
    return tuple((70.0 * (i + 1) / (n_failures + 1), -1) for i in range(n_failures))


#: rack-event lane: the 10-node set split into 6 racks round-robin
#: (racks 0-3 hold two nodes, 4-5 one), and rack 1 — the node pair that
#: co-occurs most in topology-blind mappings — dies whole at day 60,
#: after the late-arriving MEVA items sharing it are already stored.
_N_RACKS = 6
_RACK_EVENTS = ((60.0, 1),)

#: topology constraints for the rack-aware variant: one chunk per rack
#: and every mapping spans >= 3 racks, so the rack event destroys at
#: most one chunk of any conforming item (<= P: always decodable), and
#: the sixth rack leaves even width-5 mappings a conforming repair
#: target after the event.
_RACK_CONSTRAINTS = PlacementConstraints(max_per_rack=1, min_racks=3)


def _rack_run(algo, bw, *, constraints, repair_priority="health"):
    res, _, _ = sim(
        "most_unreliable", "meva", algo, fill=0.15, reliability=0.9,
        seed=1, repair_bw_mbps=bw, n_racks=_N_RACKS,
        rack_failure_schedule=_RACK_EVENTS,
        constraints=constraints, repair_priority=repair_priority,
    )
    return res.retained_fraction if res.stored_mb > 0 else 0.0


def run(
    rts=(0.9, 0.99999),
    failures=(2, 3, 4, 5, 6, 7),
    repair_bws=REPAIR_BWS,
    sweep_algos=("drex_sc", "drex_lb", "ec(3,2)"),
    algos=ALGOS,
    rack_algos=("drex_sc", "ec(3,2)"),
) -> list[str]:
    out = {}
    lines = []
    for rt in rts:
        out[str(rt)] = {}
        for algo in algos:
            out[str(rt)][algo] = {}
            for nf in failures:
                # Non-saturating workload (the paper's failure experiment uses 70
                # days of raw MEVA, well under capacity): rescheduling must
                # have headroom, so survival is governed by reliability math,
                # not by capacity pressure.
                res, _, _ = sim(
                    "most_unreliable", "meva", algo, fill=0.15,
                    reliability=rt, failure_schedule=_schedule(nf), seed=1,
                )
                # retained fraction relative to what was stored (Fig. 12)
                out[str(rt)][algo][nf] = res.retained_fraction if res.stored_mb > 0 else 0.0
        nf_ref = 4 if 4 in failures else failures[-1]
        sc = out[str(rt)].get("drex_sc", {}).get(nf_ref, 0)
        ec = out[str(rt)].get("ec(3,2)", {}).get(nf_ref, 0)
        lines.append(csv_row(
            f"fig12_rt{rt}", 0.0,
            f"drex_sc@{nf_ref}fail={sc:.2f};ec32@{nf_ref}fail={ec:.2f}",
        ))

    # Repair-bandwidth sweep (ours): a failure burst against finite
    # per-node repair bandwidth; retained fraction degrades as the budget
    # shrinks because in-flight repairs are voided by later failures.
    sweep = {}
    for algo in sweep_algos:
        sweep[algo] = {}
        for bw in repair_bws:
            res, _, _ = sim(
                "most_unreliable", "meva", algo, fill=0.15,
                reliability=0.9, failure_schedule=_BURST, seed=1,
                repair_bw_mbps=bw,
            )
            # Same burst with the legacy FIFO replan order: the gated
            # floor requires health-prioritized repair to retain at
            # least as much at every bandwidth.
            res_fifo, _, _ = sim(
                "most_unreliable", "meva", algo, fill=0.15,
                reliability=0.9, failure_schedule=_BURST, seed=1,
                repair_bw_mbps=bw, repair_priority="fifo",
            )
            sweep[algo][str(bw)] = {
                "retained_fraction": res.retained_fraction,
                "retained_fraction_fifo": res_fifo.retained_fraction,
                "n_repairs_planned": res.n_repairs_planned,
                "n_repairs_completed": res.n_repairs_completed,
                "n_repairs_aborted": res.n_repairs_aborted,
                "repaired_mb": res.repaired_mb,
            }
        inf_r = sweep[algo][str(repair_bws[0])]["retained_fraction"]
        slow_r = sweep[algo][str(repair_bws[-1])]["retained_fraction"]
        lines.append(csv_row(
            f"fig12_repair_bw_{algo}", 0.0,
            f"retained@inf={inf_r:.2f};retained@{repair_bws[-1]}={slow_r:.2f}",
        ))
    out["repair_bw_sweep"] = sweep

    # Rack-event lane (ours): a whole rack dies; topology-aware
    # placement (one chunk per rack, spread >= 3) vs topology-blind,
    # across the swept repair bandwidths.
    rack = {"n_racks": _N_RACKS, "events": [list(e) for e in _RACK_EVENTS]}
    floor_ok = True
    for algo in rack_algos:
        rack[algo] = {}
        for bw in repair_bws:
            topo = _rack_run(algo, bw, constraints=_RACK_CONSTRAINTS)
            blind = _rack_run(algo, bw, constraints=None)
            rack[algo][str(bw)] = {
                "topo_retained": topo,
                "blind_retained": blind,
            }
            floor_ok = floor_ok and topo >= blind
        cells = rack[algo]
        lines.append(csv_row(
            f"fig12_rack_event_{algo}", 0.0,
            f"topo@inf={cells[str(repair_bws[0])]['topo_retained']:.2f};"
            f"topo@{repair_bws[-1]}="
            f"{cells[str(repair_bws[-1])]['topo_retained']:.2f};"
            f"blind@{repair_bws[-1]}="
            f"{cells[str(repair_bws[-1])]['blind_retained']:.2f}",
        ))
    # The floor spans both axes of the redesign: topology-aware >=
    # topology-blind in the rack-event lane AND health-prioritized >=
    # FIFO in the repair-bandwidth sweep, at every swept bandwidth.
    for algo in sweep_algos:
        for cell in sweep[algo].values():
            floor_ok = floor_ok and (
                cell["retained_fraction"] >= cell["retained_fraction_fifo"]
            )
    rack["meets_improvement_floor"] = int(floor_ok)
    # Aggregate improvement ratio (deterministic, but gated "higher" so
    # a genuinely better scenario can raise the baseline without churn).
    topo_sum = sum(
        c["topo_retained"] for a in rack_algos for c in rack[a].values()
    ) + sum(
        c["retained_fraction"] for a in sweep_algos for c in sweep[a].values()
    )
    base_sum = sum(
        c["blind_retained"] for a in rack_algos for c in rack[a].values()
    ) + sum(
        c["retained_fraction_fifo"]
        for a in sweep_algos for c in sweep[a].values()
    )
    rack["improvement_ratio"] = (
        topo_sum / base_sum if base_sum > 0 else float("inf")
    )
    out["rack_event"] = rack
    lines.append(csv_row(
        "fig12_rack_event_floor", 0.0,
        f"meets_improvement_floor={rack['meets_improvement_floor']};"
        f"ratio={rack['improvement_ratio']:.3f}",
    ))
    emit("fig12", out)
    return lines
