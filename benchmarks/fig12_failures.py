"""Fig. 12: data retained after 2..7 node failures
(Most Unreliable nodes, MEVA over 70 days), plus a repair-bandwidth
sweep: the event-driven simulator's finite per-node repair budget makes
retained fraction sensitive to how fast lost chunks are rebuilt — items
whose repairs are still in flight when the next failure lands are lost
(Luby-style repair-rate lower bounds; ``repair_bw_mbps=inf`` is the
paper's instantaneous-repair model)."""

import math

from .common import ALGOS, csv_row, emit, sim

#: per-node repair ingest bandwidths (MB/s) for the sweep; chosen against
#: the CAP_SCALE-shrunk chunk sizes so the slowest settings leave repairs
#: in flight when the next failure hits.
REPAIR_BWS = (math.inf, 1.0, 0.1, 0.01, 0.001)

#: burst of closely-spaced weighted-random failures for the sweep — wide
#: spacing lets even slow repairs drain between failures.
_BURST = tuple((30.0 + i * 0.05, -1) for i in range(5))


def _schedule(n_failures: int):
    # spread failures across the 70-day window; weighted-random node draw
    return tuple((70.0 * (i + 1) / (n_failures + 1), -1) for i in range(n_failures))


def run(
    rts=(0.9, 0.99999),
    failures=(2, 3, 4, 5, 6, 7),
    repair_bws=REPAIR_BWS,
    sweep_algos=("drex_sc", "drex_lb", "ec(3,2)"),
    algos=ALGOS,
) -> list[str]:
    out = {}
    lines = []
    for rt in rts:
        out[str(rt)] = {}
        for algo in algos:
            out[str(rt)][algo] = {}
            for nf in failures:
                # Non-saturating workload (the paper's failure experiment uses 70
                # days of raw MEVA, well under capacity): rescheduling must
                # have headroom, so survival is governed by reliability math,
                # not by capacity pressure.
                res, _, _ = sim(
                    "most_unreliable", "meva", algo, fill=0.15,
                    reliability=rt, failure_schedule=_schedule(nf), seed=1,
                )
                # retained fraction relative to what was stored (Fig. 12)
                out[str(rt)][algo][nf] = res.retained_fraction if res.stored_mb > 0 else 0.0
        nf_ref = 4 if 4 in failures else failures[-1]
        sc = out[str(rt)].get("drex_sc", {}).get(nf_ref, 0)
        ec = out[str(rt)].get("ec(3,2)", {}).get(nf_ref, 0)
        lines.append(csv_row(
            f"fig12_rt{rt}", 0.0,
            f"drex_sc@{nf_ref}fail={sc:.2f};ec32@{nf_ref}fail={ec:.2f}",
        ))

    # Repair-bandwidth sweep (ours): a failure burst against finite
    # per-node repair bandwidth; retained fraction degrades as the budget
    # shrinks because in-flight repairs are voided by later failures.
    sweep = {}
    for algo in sweep_algos:
        sweep[algo] = {}
        for bw in repair_bws:
            res, _, _ = sim(
                "most_unreliable", "meva", algo, fill=0.15,
                reliability=0.9, failure_schedule=_BURST, seed=1,
                repair_bw_mbps=bw,
            )
            sweep[algo][str(bw)] = {
                "retained_fraction": res.retained_fraction,
                "n_repairs_planned": res.n_repairs_planned,
                "n_repairs_completed": res.n_repairs_completed,
                "n_repairs_aborted": res.n_repairs_aborted,
                "repaired_mb": res.repaired_mb,
            }
        inf_r = sweep[algo][str(repair_bws[0])]["retained_fraction"]
        slow_r = sweep[algo][str(repair_bws[-1])]["retained_fraction"]
        lines.append(csv_row(
            f"fig12_repair_bw_{algo}", 0.0,
            f"retained@inf={inf_r:.2f};retained@{repair_bws[-1]}={slow_r:.2f}",
        ))
    out["repair_bw_sweep"] = sweep
    emit("fig12", out)
    return lines
