"""Fig. 12: data retained after 2..7 node failures
(Most Unreliable nodes, MEVA over 70 days)."""

from .common import ALGOS, csv_row, emit, sim


def _schedule(n_failures: int):
    # spread failures across the 70-day window; weighted-random node draw
    return tuple((70.0 * (i + 1) / (n_failures + 1), -1) for i in range(n_failures))


def run(rts=(0.9, 0.99999), failures=(2, 3, 4, 5, 6, 7)) -> list[str]:
    out = {}
    lines = []
    for rt in rts:
        out[str(rt)] = {}
        for algo in ALGOS:
            out[str(rt)][algo] = {}
            for nf in failures:
                # Non-saturating workload (the paper's failure experiment uses 70
                # days of raw MEVA, well under capacity): rescheduling must
                # have headroom, so survival is governed by reliability math,
                # not by capacity pressure.
                res, _, _ = sim(
                    "most_unreliable", "meva", algo, fill=0.15,
                    reliability=rt, failure_schedule=_schedule(nf), seed=1,
                )
                # retained fraction relative to what was stored (Fig. 12)
                out[str(rt)][algo][nf] = res.retained_fraction if res.stored_mb > 0 else 0.0
        sc4 = out[str(rt)]["drex_sc"].get(4, 0)
        ec4 = out[str(rt)]["ec(3,2)"].get(4, 0)
        lines.append(csv_row(f"fig12_rt{rt}", 0.0, f"drex_sc@4fail={sc4:.2f};ec32@4fail={ec4:.2f}"))
    emit("fig12", out)
    return lines
