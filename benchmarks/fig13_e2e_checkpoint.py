"""Fig. 13 / §6 analogue: end-to-end checkpoint upload (encode+put) and
download (get+decode) through the REAL codec + fabric on the Chameleon
Cloud node set, D-Rex vs HDFS-style EC(3,2)/EC(6,3)."""

import time

import jax
import numpy as np

from repro.checkpoint import CheckpointPolicy, DRexCheckpointer, StorageFabric
from repro.configs import get_config
from repro.storage.nodesets import chameleon_nodes
from repro.train import init_train_state
from .common import csv_row, emit


def run(n_items: int = 40) -> list[str]:
    cfg = get_config("yi_6b", smoke=True)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    raw_mb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state)) / 1e6
    out = {}
    lines = []
    for algo in ("drex_sc", "drex_lb", "greedy_least_used", "ec(3,2)", "ec(6,3)"):
        fabric = StorageFabric(chameleon_nodes(capacity_scale=0.05))
        # use_kernel=False: time the CPU-native jnp codec (the Pallas kernel
        # targets TPU; interpret mode is a correctness harness, not a timer).
        ck = DRexCheckpointer(fabric, algo, CheckpointPolicy(
            item_mb=1.0, reliability_target=0.99999, use_kernel=False))
        ck.save(state, 1)            # warm-up: jit compiles per (K,P,bucket)
        ck.restore_latest(state)
        t0 = time.perf_counter()
        ck.save(state, 2)            # timed: steady-state upload (encode+put)
        t_up = time.perf_counter() - t0
        t0 = time.perf_counter()
        restored, _ = ck.restore_latest(state)
        t_down = time.perf_counter() - t0
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored))
        )
        assert ok, algo
        out[algo] = {
            "upload_mbps": raw_mb / t_up,
            "download_mbps": raw_mb / t_down,
            "storage_overhead": ck.stats["bytes_stored"] / ck.stats["bytes_raw"],
        }
        lines.append(csv_row(f"fig13_{algo}", t_up * 1e6,
                             f"up={out[algo]['upload_mbps']:.1f}MBps;"
                             f"down={out[algo]['download_mbps']:.1f}MBps;"
                             f"overhead={out[algo]['storage_overhead']:.2f}x"))
    emit("fig13", out)
    return lines
