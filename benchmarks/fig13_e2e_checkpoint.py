"""Fig. 13 / §6 analogue: end-to-end checkpoint upload (encode+put) and
download (get+decode) through the REAL codec + fabric on the Chameleon
Cloud node set, D-Rex vs HDFS-style EC(3,2)/EC(6,3).

The workload is ``n_items`` synthetic leaves of ``item_kb`` apiece
(seeded; one placement group each), so the sweep size is a first-class
knob instead of whatever a model config happens to flatten to.  The
fabric simulates ``link_mbps`` of per-put write bandwidth (the sleep
happens outside the fabric lock, so concurrent puts overlap like real
links) — that is what makes the *pipelined* upload lane measurable:

* ``serial``   — ``pipeline_workers=0``: per-group encode then put, the
  pre-pipeline baseline.
* ``pipelined`` — ``pipeline_workers=2``: cohort waves encoded through
  ``encode_many`` while the previous wave's puts drain on the I/O pool.

``pipeline_speedup = serial / pipelined`` (min-of-reps both sides) is
ratio-gated in benchmarks/gate.py; the placement digest pins that both
modes place every group identically (placement happens before the
pipeline forks, so any drift means the batch placement path changed).
"""

import hashlib
import time

import numpy as np

from repro.checkpoint import CheckpointPolicy, DRexCheckpointer, StorageFabric
from repro.storage.nodesets import chameleon_nodes
from .common import csv_row, emit


def _placements_digest(manifest: dict) -> int:
    """Int digest of every group's (key, k, p, node_ids) in tree order."""
    h = hashlib.sha256()
    for meta in manifest["leaves"]:
        if meta is None:
            continue
        for g in meta["groups"]:
            h.update(
                f"{g['key']}:{g['k']}:{g['p']}:{tuple(g['node_ids'])}".encode()
            )
    return int.from_bytes(h.digest()[:8], "big")


def _make_state(n_items: int, item_kb: int) -> list[np.ndarray]:
    rng = np.random.default_rng(7)
    return [
        rng.integers(0, 256, size=item_kb * 1024, dtype=np.uint8)
        for _ in range(n_items)
    ]


def run(
    n_items: int = 40,
    item_kb: int = 256,
    algos=("drex_sc", "drex_lb", "greedy_least_used", "ec(3,2)", "ec(6,3)"),
    link_mbps: float = 100.0,
    reps: int = 3,
) -> list[str]:
    state = _make_state(n_items, item_kb)
    raw_mb = sum(x.size for x in state) / 1e6
    out = {"n_items": n_items, "item_kb": item_kb, "link_mbps": link_mbps}
    lines = []
    for algo in algos:
        per_mode = {}
        digests = {}
        for mode, workers in (("serial", 0), ("pipelined", 2)):
            fabric = StorageFabric(
                chameleon_nodes(capacity_scale=0.05), link_mbps=link_mbps
            )
            # use_kernel=True: the kernel path (jitted XLA bit-matmul on
            # CPU, Pallas on TPU) is now the timed data plane; waves of 4
            # give the pipelined mode real encode/put overlap.
            ck = DRexCheckpointer(fabric, algo, CheckpointPolicy(
                item_mb=1.0, reliability_target=0.99999, keep_last=1,
                pipeline_workers=workers, encode_wave_groups=4))
            step = 1
            manifest = ck.save(state, step)   # warm-up: jit per (K,P,bucket)
            digests[mode] = _placements_digest(manifest)
            ck.restore_latest(state)
            t_up = float("inf")
            for _ in range(max(1, reps)):     # timed: steady-state upload
                step += 1
                t0 = time.perf_counter()
                ck.save(state, step)
                t_up = min(t_up, time.perf_counter() - t0)
            t0 = time.perf_counter()
            restored, _ = ck.restore_latest(state)
            t_down = time.perf_counter() - t0
            ok = all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(state, restored)
            )
            assert ok, (algo, mode)
            per_mode[mode] = {
                "upload_s": t_up,
                "upload_mbps": raw_mb / t_up,
                "download_mbps": raw_mb / t_down,
                "storage_overhead": ck.stats["bytes_stored"] / ck.stats["bytes_raw"],
                "restore_ok": int(ok),
            }
        assert digests["serial"] == digests["pipelined"], algo
        speedup = per_mode["serial"]["upload_s"] / per_mode["pipelined"]["upload_s"]
        out[algo] = {
            **per_mode["pipelined"],
            "serial_upload_s": per_mode["serial"]["upload_s"],
            "serial_upload_mbps": per_mode["serial"]["upload_mbps"],
            "pipeline_speedup": speedup,
            "placements_digest": digests["pipelined"],
            "placements_match_serial": int(digests["serial"] == digests["pipelined"]),
        }
        lines.append(csv_row(
            f"fig13_{algo}", per_mode["pipelined"]["upload_s"] * 1e6,
            f"up={out[algo]['upload_mbps']:.1f}MBps;"
            f"down={out[algo]['download_mbps']:.1f}MBps;"
            f"pipeline={speedup:.2f}x;"
            f"overhead={out[algo]['storage_overhead']:.2f}x"))
    emit("fig13", out)
    return lines
