"""Fig. 9: time per operation class on a non-saturating subset
(every algorithm stores everything -> fair breakdown comparison)."""

from .common import ALGOS, csv_row, emit, sim


def run() -> list[str]:
    out = {}
    for algo in ALGOS:
        res, _, _ = sim("most_used", "meva", algo, reliability=0.9999, n_items=400)
        assert res.n_failed_writes == 0 or res.stored_fraction > 0.99, algo
        out[algo] = res.time_breakdown
    emit("fig9", out)
    lines = []
    for algo in ("drex_sc", "greedy_min_storage", "ec(3,2)"):
        t = out[algo]
        coding = t["encode"] + t["decode"]
        io = t["read"] + t["write"]
        lines.append(csv_row(f"fig9_{algo}", 0.0,
                             f"coding_s={coding:.1f};io_s={io:.1f};coding_share={coding/(coding+io):.2f}"))
    return lines
