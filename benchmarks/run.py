"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and
writes per-figure JSON (stamped with ``meta``: schema version, git SHA,
smoke flag) into results/benchmarks/ for EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig12] [--smoke]
        [--out DIR] [--check-against BASELINE_DIR]

``--smoke`` shrinks the parameterizable benchmarks to CI-sized sweeps;
used by ``make verify`` / the GitHub Actions workflow.  All RNGs are
seeded explicitly at startup so repeated runs are comparable.

``--check-against`` is the benchmark-regression gate (``make
bench-check`` / the ``bench-gate`` CI job): after the run, the freshly
emitted JSON is compared like-for-like against the committed baselines
in BASELINE_DIR (see benchmarks/gate.py) and the process exits nonzero
if any gated decision-cost metric regressed beyond the budget.
"""

from __future__ import annotations

import argparse
import random
import sys
import time

import numpy as np

from . import (
    fig1_encode_breakdown,
    fig5_reliability_sweep,
    fig6_saturation,
    fig7_nodesets,
    fig8_throughput,
    fig9_op_breakdown,
    fig10_datasets,
    fig11_throughput_datasets,
    fig12_failures,
    fig13_e2e_checkpoint,
    gate,
    scale_cluster,
    serve_load,
    table2_overhead,
)
from . import common

BENCHES = {
    "fig1": fig1_encode_breakdown.run,
    "table2": table2_overhead.run,
    "fig5": fig5_reliability_sweep.run,
    "fig6": fig6_saturation.run,
    "fig7": fig7_nodesets.run,
    "fig8": fig8_throughput.run,
    "fig9": fig9_op_breakdown.run,
    "fig10": fig10_datasets.run,
    "fig11": fig11_throughput_datasets.run,
    "fig12": fig12_failures.run,
    "fig13": fig13_e2e_checkpoint.run,
    "serve_load": serve_load.run,
    "scale": scale_cluster.run,
}


#: reduced parameters per benchmark under --smoke (others run unchanged).
SMOKE_KWARGS = {
    # Batched-EC data plane lane: small per-K sweep, but a cohort big
    # enough that the gated per-item-vs-batched ratio divides dispatch
    # overhead x n_groups, not timer noise.
    "fig1": dict(size_mb=1.0, ks=(2, 4, 6), reps=2, n_groups=32, group_kb=16),
    # Pipelined-vs-serial checkpoint upload on a CI-sized synthetic
    # state; link_mbps stays at the default so the put cost (what the
    # pipeline overlaps) is the same regime as the full run.
    "fig13": dict(n_items=16, item_kb=128, reps=3,
                  algos=("drex_sc", "ec(3,2)")),
    # greedy_batch stays >= 32 so the gated speedup ratios divide two
    # multi-millisecond totals (min-of-reps timed) instead of dispatch
    # jitter; see benchmarks/gate.py.
    "table2": dict(
        sizes=(10, 50), reps=1, batch=100, greedy_nodes=100, greedy_batch=32
    ),
    # CI-sized failure/repair sweep: exercises the event-driven simulator's
    # failure, repair-bandwidth and drop paths on every PR.
    "fig12": dict(
        rts=(0.9,),
        failures=(2, 5),
        repair_bws=(float("inf"), 0.01),
        sweep_algos=("drex_sc", "ec(3,2)"),
        algos=("drex_sc", "drex_lb", "ec(3,2)"),
    ),
    # Sustained-load placement-service lane: one reject-free rate (oracle
    # checked against the sequential baseline) and one overload rate
    # (deterministic backpressure), kept small enough for the PR lane.
    "serve_load": dict(n_items=240, rates=(60.0, 1500.0), reps=2),
    # Cluster-axis scale lane: the node count stays at 10k even under
    # --smoke (the pre-filter's >= 5x acceptance floor is only meaningful
    # at scale); the unfiltered reference path is what costs seconds, so
    # smoke trims reps, not N.
    "scale": dict(reps=2),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweeps")
    ap.add_argument(
        "--out",
        default=None,
        help="directory for emitted JSON (default results/benchmarks)",
    )
    ap.add_argument(
        "--check-against",
        default=None,
        metavar="BASELINE_DIR",
        help="after running, fail (exit 1) if any gated decision-cost "
        "metric regressed beyond the budget vs the baselines in this dir",
    )
    args = ap.parse_args()
    # Explicit global seeding: every benchmark already uses per-call
    # default_rng(seed), but any stray library draw must be repeatable
    # too or the regression gate would not compare like-for-like.
    random.seed(0)
    np.random.seed(0)
    common.set_run_context(smoke=args.smoke, out_dir=args.out)
    names = list(BENCHES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        t0 = time.perf_counter()
        kwargs = SMOKE_KWARGS.get(name, {}) if args.smoke else {}
        try:
            for line in BENCHES[name](**kwargs):
                print(line, flush=True)
        except Exception as e:  # keep the harness running, report at exit
            failures.append((name, repr(e)))
            print(f"{name},0.0,ERROR:{type(e).__name__}", flush=True)
        print(f"{name}_wall,{(time.perf_counter()-t0)*1e6:.0f},", flush=True)
    gate_failed = False
    if args.check_against:
        out_dir = args.out or common.RESULTS
        regressions, notes = gate.check_against(
            out_dir, args.check_against, names
        )
        gate.report(regressions, notes)
        gate_failed = bool(regressions)
    if failures:
        for n, e in failures:
            print(f"[bench] FAILED {n}: {e}", file=sys.stderr)
    if failures or gate_failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
