"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and
writes per-figure JSON into results/benchmarks/ for EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig12] [--smoke]

``--smoke`` shrinks the parameterizable benchmarks (currently table2) to
CI-sized sweeps; used by ``make verify`` / the GitHub Actions workflow.
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (
    fig1_encode_breakdown,
    fig5_reliability_sweep,
    fig6_saturation,
    fig7_nodesets,
    fig8_throughput,
    fig9_op_breakdown,
    fig10_datasets,
    fig11_throughput_datasets,
    fig12_failures,
    fig13_e2e_checkpoint,
    table2_overhead,
)

BENCHES = {
    "fig1": fig1_encode_breakdown.run,
    "table2": table2_overhead.run,
    "fig5": fig5_reliability_sweep.run,
    "fig6": fig6_saturation.run,
    "fig7": fig7_nodesets.run,
    "fig8": fig8_throughput.run,
    "fig9": fig9_op_breakdown.run,
    "fig10": fig10_datasets.run,
    "fig11": fig11_throughput_datasets.run,
    "fig12": fig12_failures.run,
    "fig13": fig13_e2e_checkpoint.run,
}


#: reduced parameters per benchmark under --smoke (others run unchanged).
SMOKE_KWARGS = {
    "table2": dict(sizes=(10, 50), reps=1, batch=100),
    # CI-sized failure/repair sweep: exercises the event-driven simulator's
    # failure, repair-bandwidth and drop paths on every PR.
    "fig12": dict(
        rts=(0.9,),
        failures=(2, 5),
        repair_bws=(float("inf"), 0.01),
        sweep_algos=("drex_sc", "ec(3,2)"),
        algos=("drex_sc", "drex_lb", "ec(3,2)"),
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--smoke", action="store_true", help="CI-sized sweeps")
    args = ap.parse_args()
    names = list(BENCHES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        t0 = time.perf_counter()
        kwargs = SMOKE_KWARGS.get(name, {}) if args.smoke else {}
        try:
            for line in BENCHES[name](**kwargs):
                print(line, flush=True)
        except Exception as e:  # keep the harness running, report at exit
            failures.append((name, repr(e)))
            print(f"{name},0.0,ERROR:{type(e).__name__}", flush=True)
        print(f"{name}_wall,{(time.perf_counter()-t0)*1e6:.0f},", flush=True)
    if failures:
        for n, e in failures:
            print(f"[bench] FAILED {n}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
