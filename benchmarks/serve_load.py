"""Sustained-load lane: the streaming placement frontier under open-loop
Poisson arrivals at swept rates.

For each (algorithm, rate) the benchmark drives
:class:`repro.serve.placement.PlacementFrontier` over a Poisson arrival
trace and reports items/sec goodput, p50/p99 decision latency, queue
depth, window sizes and reject rate.  Two kinds of numbers, gated
differently (see benchmarks/gate.py):

* **deterministic** (virtual-clock) quantities — placements digest,
  reject counts, virtual goodput, frontier-vs-sequential placement
  equality — are byte-stable by the frontier's determinism contract
  (virtual service model; same trace + seed ⇒ byte-identical
  placements) and are equality-gated: any drift is a behavior change.
* **wall-clock** quantities — decision-latency percentiles and the
  speedup of micro-batched windows + shared :class:`BatchContext` +
  incremental rescoring over a naive one-request-at-a-time baseline
  (fresh engine, per-item ``place``, no shared context) — are timed
  min-of-reps and ratio-gated with the standard noise budget.

The sequential baseline doubles as the oracle: at rates with no rejects
the frontier's placements must equal the per-item ``place`` loop's
bit-for-bit (the same oracle-vs-kernel playbook the schedulers use).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import PlacementEngine, StorageNode
from repro.serve.placement import (
    FrontierConfig,
    PlacementFrontier,
    arrival_events,
    churn_events,
)
from repro.storage.traces import make_trace

from .common import csv_row, emit
from .table2_overhead import _cluster

SEED = 11


def _poisson_trace(n_items: int, rate: float, seed: int = SEED):
    """The meva size/RT trace with exponential inter-arrivals at
    ``rate`` items/s (open loop: arrivals ignore service progress)."""
    base = make_trace("meva", seed=seed, n_items=n_items)
    rng = np.random.default_rng((seed, int(rate * 1000)))
    gaps = rng.exponential(1.0 / rate, size=n_items)
    at = np.cumsum(gaps)
    return [
        dataclasses.replace(it, arrival_time=float(at[i]))
        for i, it in enumerate(base)
    ]


def _frontier_once(algo: str, n_nodes: int, cfg: FrontierConfig, events):
    engine = PlacementEngine(_cluster(n_nodes), algo)
    frontier = PlacementFrontier(engine, cfg)
    return frontier.run(list(events))


def _best_frontier(algo, n_nodes, cfg, events, reps):
    """Min-of-reps frontier run: digests must agree across reps (the
    determinism contract); wall metrics come from the fastest rep."""
    _frontier_once(algo, n_nodes, cfg, events)  # warm the jit cache
    best = None
    for _ in range(max(1, reps)):
        rep = _frontier_once(algo, n_nodes, cfg, events)
        if best is not None and rep.digest() != best.digest():
            raise AssertionError(
                f"frontier replay diverged for {algo}: "
                f"{rep.digest()} vs {best.digest()}"
            )
        if best is None or (
            rep.summary["decision_wall_total_s"]
            < best.summary["decision_wall_total_s"]
        ):
            best = rep
    return best


def _sequential_baseline(algo: str, n_nodes: int, items, reps: int):
    """Naive one-request-at-a-time server: fresh engine, per-item
    ``place``, no shared context.  Returns (latency summary, placements)."""
    best_total, best_lat, placements = float("inf"), None, None
    for _ in range(max(1, reps)):
        engine = PlacementEngine(_cluster(n_nodes), algo)
        lat = []
        got = []
        for it in items:
            t0 = time.perf_counter()
            got.append(engine.place(it))
            lat.append(time.perf_counter() - t0)
        total = sum(lat)
        if total < best_total:
            best_total, best_lat, placements = total, lat, got
    arr = np.asarray(best_lat)
    return (
        {
            "reps": max(1, reps),
            "total_s": best_total,
            "p50_ms": 1e3 * float(np.percentile(arr, 50)),
            "p99_ms": 1e3 * float(np.percentile(arr, 99)),
        },
        placements,
    )


def _rate_metrics(report, seq, seq_records, check_oracle: bool) -> dict:
    s = report.summary
    wall = s["decision_wall"]
    out = {
        "goodput_virtual_items_per_s": s["goodput_virtual_items_per_s"],
        "makespan_virtual_s": s["makespan_virtual_s"],
        "placements_digest": report.digest(),
        "reject_count": s["reject_count"],
        "n_rejected_admission": s["n_rejected_admission"],
        "max_queue_depth": s["max_queue_depth"],
        "mean_queue_depth": s["mean_queue_depth"],
        "n_flushes": s["n_flushes"],
        "mean_window": s["mean_window"],
        "sojourn_virtual_p99_ms": s["sojourn_virtual"]["p99_ms"],
        "p50_ms": wall["p50_ms"],
        "p99_ms": wall["p99_ms"],
        "decision_wall_total_s": s["decision_wall_total_s"],
        "speedup_vs_sequential": (
            seq["total_s"] / s["decision_wall_total_s"]
            if s["decision_wall_total_s"] > 0
            else float("inf")
        ),
        "p99_latency_ratio": (
            seq["p99_ms"] / wall["p99_ms"] if wall["p99_ms"] > 0 else float("inf")
        ),
    }
    if check_oracle and s["reject_count"] == 0:
        by_id = {o.item_id: o.placement for o in report.outcomes}
        out["matches_sequential"] = int(
            all(by_id.get(r.item_id) == r.placement for r in seq_records)
        )
    return out


def run(
    n_nodes: int = 100,
    n_items: int = 600,
    rates=(60.0, 250.0, 1500.0),
    algos=("drex_sc", "greedy_least_used"),
    reps: int = 3,
    max_batch: int = 32,
    max_wait_s: float = 0.05,
    queue_capacity: int = 96,
    churn: bool = True,
):
    cfg = FrontierConfig(
        max_batch=max_batch,
        max_wait_s=max_wait_s,
        queue_capacity=queue_capacity,
    )
    # With the default service model the frontier sustains roughly
    # 1 / (per_item + base/max_batch) ~ 940 items/s: the low rates run
    # reject-free (oracle-checked), the top rate overloads the queue and
    # exercises deterministic backpressure.
    payload: dict = {
        "config": {
            "n_nodes": n_nodes,
            "n_items": n_items,
            "max_batch": max_batch,
            "max_wait_s": max_wait_s,
            "queue_capacity": queue_capacity,
            "service_base_s": cfg.service_base_s,
            "service_per_item_s": cfg.service_per_item_s,
            "reps": reps,
        }
    }
    lines: list[str] = []
    for algo in algos:
        section: dict = {"n_nodes": n_nodes, "n_items": n_items}
        seq = seq_records = None
        for rate in rates:
            items = _poisson_trace(n_items, rate)
            if seq is None:
                # item sizes/targets (and hence sequential placements)
                # are rate-independent; one baseline serves every rate.
                seq, seq_records = _sequential_baseline(
                    algo, n_nodes, items, reps
                )
                section["sequential"] = seq
            report = _best_frontier(algo, n_nodes, cfg, arrival_events(items), reps)
            m = _rate_metrics(report, seq, seq_records, check_oracle=True)
            m["rate"] = rate
            section[f"rate_{int(rate)}"] = m
            lines.append(
                csv_row(
                    f"serve_load_{algo}_r{int(rate)}",
                    1e3 * m["p99_ms"],
                    f"goodput={m['goodput_virtual_items_per_s']:.1f}/s "
                    f"rejects={m['reject_count']} "
                    f"speedup={m['speedup_vs_sequential']:.2f}x",
                )
            )
        if churn:
            rate = rates[0]
            items = _poisson_trace(n_items, rate)
            horizon = n_items / rate
            extra = churn_events(
                failure_schedule=((0.30 * horizon, 3), (0.55 * horizon, 7)),
                node_join_schedule=(
                    (
                        0.70 * horizon,
                        StorageNode(
                            node_id=n_nodes,
                            capacity_mb=1.2e7,
                            write_bw=200.0,
                            read_bw=300.0,
                            annual_failure_rate=0.01,
                        ),
                    ),
                ),
                node_heal_schedule=((0.85 * horizon, 3),),
                unit="seconds",
            )
            report = _best_frontier(
                algo, n_nodes, cfg, arrival_events(items) + extra, reps
            )
            s = report.summary
            section["churn"] = {
                "rate": rate,
                "placements_digest": report.digest(),
                "reject_count": s["reject_count"],
                "n_failures": s["n_failures"],
                "n_joins": s["n_joins"],
                "n_heals": s["n_heals"],
                "n_repairs": s["n_repairs"],
                "n_items_lost": s["n_items_lost"],
                "goodput_virtual_items_per_s": s["goodput_virtual_items_per_s"],
                "p99_ms": s["decision_wall"]["p99_ms"],
            }
            lines.append(
                csv_row(
                    f"serve_load_{algo}_churn",
                    1e3 * s["decision_wall"]["p99_ms"],
                    f"repairs={s['n_repairs']} lost={s['n_items_lost']}",
                )
            )
        payload[algo] = section
    emit("serve_load", payload)
    return lines
