"""Benchmark-regression gate: compare fresh results against committed
baselines and fail loudly when a decision-cost metric regresses.

Three PRs of measured speedups accumulated in ``results/benchmarks/``
with nothing stopping a future change from silently eroding them — CI
ran the benchmarks but never compared the numbers.  This module is the
comparison: ``benchmarks.run --check-against <baseline-dir>`` loads the
freshly emitted JSON and the committed baseline for each benchmark,
checks they are like-for-like (same ``meta.schema_version``, same
``meta.smoke`` flag — a full-sweep baseline is never compared against a
smoke run), and fails (nonzero exit) if any gated metric regresses more
than ``DEFAULT_THRESHOLD``.

Gated metrics come in two kinds:

* **ratios** (vectorized-kernel speedup over the scalar oracle on the
  same machine in the same process), which transfer across machine
  speeds far better than absolute milliseconds — a CI runner half as
  fast slows both sides of the ratio.  Both sides are timed min-of-reps
  (``common.scalar_vs_vectorized``) so load spikes cannot fake a
  regression.  Direction ``"higher"``, budget ``DEFAULT_THRESHOLD``.
* **deterministic equalities** (fig12's retained fractions: seeded
  simulation, no timing anywhere), gated with direction ``"equal"`` —
  any change at all fails, because a drifted retained fraction means
  placement or repair *behavior* changed, not the machine.  Regenerate
  the baselines when the change is intentional.

Committed smoke baselines live in ``results/benchmarks/smoke/``;
regenerate them with ``make bench-baseline`` (see benchmarks/README.md
for the full workflow)::

    python -m benchmarks.run --only table2,fig12 --smoke \
        --out results/benchmarks/smoke

Ratios still carry a *systematic* machine-class component (a 4-vCPU
runner gives XLA less parallel headroom than a many-core dev box), so
baselines should be captured on — or recalibrated to — the machine
class that runs the gate: the CI ``smoke-benchmarks`` artifact from any
green run IS a valid baseline (same schema, ``smoke`` flag and
parameters); download it and commit it under
``results/benchmarks/smoke/`` to rebase the gate on runner hardware.
"""

from __future__ import annotations

import json
import pathlib

from .common import SCHEMA_VERSION

__all__ = ["DEFAULT_THRESHOLD", "GATE_METRICS", "check_against"]

#: relative regression tolerance: fail when a higher-is-better metric
#: drops below (1 - threshold) x baseline.
DEFAULT_THRESHOLD = 0.20

#: benchmark name -> ((metric path, direction), ...).  A metric path is
#: dotted, or a tuple of keys when a key itself contains a dot (fig12's
#: reliability-target keys like "0.9").  "higher" means higher is
#: better (ratio metrics, DEFAULT_THRESHOLD budget); "equal" means the
#: value is deterministic and any drift fails (see module docstring).
#: GreedyLeastUsed's speedup is intentionally not gated: its scalar
#: path is already dispatch-proof, so the ratio hovers near 1 and would
#: gate on noise.  LB's committed column likewise (its cluster-global
#: penalty forces per-item rescoring, so the ratio hovers near 1).
GATE_METRICS: dict[str, tuple[tuple, ...]] = {
    # Batched erasure-coding data plane (benchmarks/fig1, batched lane).
    # The cohort-vs-per-item speedup is min-of-reps timed and ratio-
    # gated; the chunk digest and oracle match are deterministic (seeded
    # payloads, bit-exact codec) and equality-gated; steady-state compile
    # signatures must stay at zero — one compile per (K, P, bucket).
    "fig1": (
        ("batched.speedup_vs_per_item", "higher"),
        ("batched.chunks_digest", "equal"),
        ("batched.matches_per_item", "equal"),
        ("batched.steady_state_new_signatures", "equal"),
    ),
    # Pipelined checkpoint upload (benchmarks/fig13): serial/pipelined
    # ratio is min-of-reps timed on the simulated-bandwidth fabric;
    # the placement digest pins that the batched place_many path makes
    # identical decisions in both modes (and across PRs).
    "fig13": (
        ("drex_sc.pipeline_speedup", "higher"),
        ("drex_sc.placements_digest", "equal"),
        ("drex_sc.placements_match_serial", "equal"),
        ("drex_sc.restore_ok", "equal"),
    ),
    "table2": (
        ("batched_sc.decision_cost.speedup_vs_scalar", "higher"),
        ("batched_greedy.greedy_min_storage.decision_cost.speedup_vs_scalar",
         "higher"),
        ("batched_greedy.greedy_min_storage.committed.speedup_vs_scalar",
         "higher"),
        ("batched_lb.standard.decision_cost.speedup_vs_scalar", "higher"),
    ),
    # Deterministic retained fractions: the smoke sweep's (rt, algo,
    # n_failures) cells plus the repair-bandwidth endpoints.  Seeded
    # simulation, pure numpy — equal or the behavior changed.  The
    # rack-event lane and the health-vs-FIFO comparison are pinned the
    # same way, plus the two improvement metrics: the floor boolean
    # (topology-aware >= blind AND health >= FIFO at every swept
    # bandwidth) is equality-gated at 1, and the aggregate ratio is
    # gated "higher" so a better scenario can raise it without churn.
    "fig12": (
        (("0.9", "drex_sc", "2"), "equal"),
        (("0.9", "drex_sc", "5"), "equal"),
        (("0.9", "drex_lb", "2"), "equal"),
        (("0.9", "drex_lb", "5"), "equal"),
        (("0.9", "ec(3,2)", "2"), "equal"),
        (("0.9", "ec(3,2)", "5"), "equal"),
        (("repair_bw_sweep", "drex_sc", "inf", "retained_fraction"), "equal"),
        (("repair_bw_sweep", "drex_sc", "0.01", "retained_fraction"), "equal"),
        (("repair_bw_sweep", "drex_sc", "0.01", "retained_fraction_fifo"),
         "equal"),
        (("repair_bw_sweep", "ec(3,2)", "0.01", "retained_fraction_fifo"),
         "equal"),
        (("rack_event", "drex_sc", "inf", "topo_retained"), "equal"),
        (("rack_event", "drex_sc", "0.01", "topo_retained"), "equal"),
        (("rack_event", "drex_sc", "0.01", "blind_retained"), "equal"),
        (("rack_event", "ec(3,2)", "0.01", "topo_retained"), "equal"),
        (("rack_event", "meets_improvement_floor"), "equal"),
        (("rack_event", "improvement_ratio"), "higher"),
    ),
    # Streaming placement service (benchmarks/serve_load.py).  Virtual
    # quantities — placement digests, goodput on the virtual clock,
    # reject counts, oracle equality — are deterministic by the
    # frontier's replay contract and equality-gated.  The wall-clock
    # speedup/latency ratios over the naive per-item baseline are
    # min-of-reps timed and ratio-gated like table2's.  rate_60 runs
    # reject-free; rate_1500 overloads the bounded queue so its reject
    # count pins the backpressure path.
    # Cluster-axis scale lane (benchmarks/scale_cluster.py): the
    # pre-filtered/unfiltered speedup ratios are min-of-reps timed on
    # 10k nodes (both sides seconds vs milliseconds — far above timer
    # noise) and ratio-gated; decisions_match_unfiltered pins the
    # filtered path bit-exact against the unfiltered kernel reference,
    # and meets_5x_floor pins the acceptance floor deterministically
    # (a silently bypassed pre-filter flips it to 0 even while the raw
    # ratios of the bypassed path might still pass).
    # The rack-event scenario pins the constrained placement path at
    # 10k nodes: blast radius (within_parity/worst_rack_chunks) and the
    # constrained-decisions digest are seeded and deterministic.
    # The 100k XL smoke lane (SCALE_XL=1; scale_cluster's "xl" section)
    # is gated oracle-free: placement digests and the argsort-path
    # replay are deterministic (seeded streams) and equality-gated; the
    # hit-rate floor and the 100k-vs-10k per-decision cost ceiling are
    # booleans computed from machine-cancelling in-process ratios, so
    # they are equality-gated at 1; unfiltered_reference_ran is a
    # constant 0 that pins the lane as oracle-free by construction.
    # The fast lane runs without SCALE_XL, so the section is absent and
    # every xl.* metric is reported as a skipped note, never a failure.
    "scale": (
        ("schedulers.drex_sc.filtered_speedup", "higher"),
        ("schedulers.drex_lb.filtered_speedup", "higher"),
        ("schedulers.greedy_least_used.filtered_speedup", "higher"),
        ("schedulers.drex_sc.decisions_match_unfiltered", "equal"),
        ("schedulers.drex_lb.decisions_match_unfiltered", "equal"),
        ("schedulers.greedy_least_used.decisions_match_unfiltered", "equal"),
        ("meets_5x_floor", "equal"),
        ("rack_event.within_parity", "equal"),
        ("rack_event.worst_rack_chunks", "equal"),
        ("rack_event.placements_digest", "equal"),
        ("xl.drex_sc.placements_digest", "equal"),
        ("xl.drex_sc.matches_argsort_path", "equal"),
        ("xl.drex_sc.meets_hit_rate_floor", "equal"),
        ("xl.drex_sc.cost_within_2x_of_10k", "equal"),
        ("xl.drex_sc.unfiltered_reference_ran", "equal"),
        ("xl.drex_lb.placements_digest", "equal"),
        ("xl.drex_lb.matches_argsort_path", "equal"),
        ("xl.drex_lb.meets_hit_rate_floor", "equal"),
        ("xl.drex_lb.cost_within_2x_of_10k", "equal"),
        ("xl.drex_lb.unfiltered_reference_ran", "equal"),
        ("xl.greedy_least_used.placements_digest", "equal"),
        ("xl.greedy_least_used.matches_argsort_path", "equal"),
        ("xl.greedy_least_used.meets_hit_rate_floor", "equal"),
        ("xl.greedy_least_used.cost_within_2x_of_10k", "equal"),
        ("xl.greedy_least_used.unfiltered_reference_ran", "equal"),
    ),
    "serve_load": (
        ("drex_sc.rate_60.placements_digest", "equal"),
        ("drex_sc.rate_60.goodput_virtual_items_per_s", "equal"),
        ("drex_sc.rate_60.matches_sequential", "equal"),
        ("drex_sc.rate_1500.placements_digest", "equal"),
        ("drex_sc.rate_1500.reject_count", "equal"),
        ("drex_sc.churn.placements_digest", "equal"),
        ("greedy_least_used.rate_60.placements_digest", "equal"),
        ("greedy_least_used.rate_1500.reject_count", "equal"),
        ("drex_sc.rate_60.speedup_vs_sequential", "higher"),
        ("drex_sc.rate_60.p99_latency_ratio", "higher"),
    ),
}


#: keys that parameterize a benchmark section; compared along every
#: gated metric's ancestor path so a SMOKE_KWARGS tweak (different
#: batch/node count) is skipped instead of gated apples-to-oranges.
_PARAM_KEYS = ("n_nodes", "batch", "n_items", "n_groups", "group_kb", "item_kb")


def _path_keys(path) -> tuple:
    """A metric path as a key tuple: dotted string, or already a tuple
    when a key itself contains a dot (e.g. fig12's "0.9")."""
    return tuple(path) if isinstance(path, (tuple, list)) else tuple(path.split("."))


def _path_str(path) -> str:
    return ".".join(_path_keys(path))


def _lookup(payload: dict, path):
    node = payload
    for key in _path_keys(path):
        if not isinstance(node, dict) or key not in node:
            return None
        node = node[key]
    return node


def _params_along(payload: dict, path) -> dict:
    """Benchmark parameters found in the dicts along a metric's path."""
    out = {}
    node = payload
    prefix = []
    for key in _path_keys(path):
        if not isinstance(node, dict):
            break
        for pk in _PARAM_KEYS:
            v = node.get(pk)
            if isinstance(v, (int, float)):
                out[".".join(prefix + [pk])] = v
        node = node.get(key)
        prefix.append(key)
    return out


def _load(path: pathlib.Path):
    """Parsed baseline/result dict, or None when absent or unusable —
    a damaged file must skip its comparisons, never crash the run."""
    try:
        data = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    return data if isinstance(data, dict) else None


def check_against(
    out_dir,
    baseline_dir,
    names,
    threshold: float = DEFAULT_THRESHOLD,
) -> tuple[list[str], list[str]]:
    """Compare freshly emitted benchmark JSON against committed baselines.

    Returns ``(failures, notes)``: ``failures`` are regressions that must
    fail the run; ``notes`` are comparisons that were skipped and why
    (missing baseline, schema or smoke-mode mismatch, metric absent).
    A missing or mismatched baseline is never a failure — the gate only
    compares like-for-like.
    """
    out_dir = pathlib.Path(out_dir)
    baseline_dir = pathlib.Path(baseline_dir)
    failures: list[str] = []
    notes: list[str] = []
    for name in names:
        metrics = GATE_METRICS.get(name)
        if not metrics:
            continue
        new = _load(out_dir / f"{name}.json")
        base = _load(baseline_dir / f"{name}.json")
        if new is None:
            notes.append(f"{name}: no fresh results in {out_dir}; skipped")
            continue
        if base is None:
            notes.append(f"{name}: no baseline in {baseline_dir}; skipped")
            continue
        new_meta = new.get("meta", {})
        base_meta = base.get("meta", {})
        if new_meta.get("schema_version") != base_meta.get("schema_version") or \
                new_meta.get("schema_version") != SCHEMA_VERSION:
            notes.append(
                f"{name}: schema_version mismatch "
                f"(baseline {base_meta.get('schema_version')}, "
                f"fresh {new_meta.get('schema_version')}, "
                f"gate {SCHEMA_VERSION}); skipped"
            )
            continue
        if new_meta.get("smoke") != base_meta.get("smoke"):
            notes.append(
                f"{name}: smoke-mode mismatch "
                f"(baseline smoke={base_meta.get('smoke')}, "
                f"fresh smoke={new_meta.get('smoke')}); skipped"
            )
            continue
        for path, direction in metrics:
            dotted = _path_str(path)
            old_v = _lookup(base, path)
            new_v = _lookup(new, path)
            if not isinstance(old_v, (int, float)) or not isinstance(
                new_v, (int, float)
            ):
                notes.append(f"{name}.{dotted}: metric absent; skipped")
                continue
            old_p = _params_along(base, path)
            new_p = _params_along(new, path)
            if old_p != new_p:
                notes.append(
                    f"{name}.{dotted}: benchmark parameters differ "
                    f"(baseline {old_p}, fresh {new_p}); skipped"
                )
                continue
            if direction == "higher":
                regressed = new_v < old_v * (1.0 - threshold)
                detail = f"worse than the {threshold:.0%} budget"
            elif direction == "equal":
                # Deterministic metric: any drift is a behavior change.
                regressed = new_v != old_v
                detail = "deterministic metric drifted"
            else:
                regressed = new_v > old_v * (1.0 + threshold)
                detail = f"worse than the {threshold:.0%} budget"
            if regressed:
                # Equality drifts can be tiny: print full precision so
                # the report shows the actual change, not two rounded
                # identical-looking numbers.
                if direction == "equal":
                    shown = f"{new_v!r} vs baseline {old_v!r}"
                else:
                    shown = f"{new_v:.3f} vs baseline {old_v:.3f}"
                failures.append(
                    f"{name}.{dotted}: {shown} ({detail}, "
                    f"baseline sha {base_meta.get('git_sha') or 'unknown'})"
                )
    return failures, notes


def report(failures: list[str], notes: list[str]) -> None:
    """Print a gate result to stderr (shared by run.py and the CLI)."""
    import sys

    for note in notes:
        print(f"[bench-gate] note: {note}", file=sys.stderr)
    for reg in failures:
        print(f"[bench-gate] REGRESSION {reg}", file=sys.stderr)
    if not failures:
        print("[bench-gate] all gated metrics within budget", file=sys.stderr)


def main(argv=None) -> None:
    """Standalone gate over already-emitted JSON (no benchmarks re-run):

        python -m benchmarks.gate <results-dir> <baseline-dir> [name ...]

    Used by CI to gate the verify job's smoke output without paying for
    a second benchmark sweep; ``benchmarks.run --check-against`` is the
    one-shot run-and-gate form.
    """
    import argparse

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("results_dir")
    ap.add_argument("baseline_dir")
    ap.add_argument("names", nargs="*", default=None,
                    help="benchmark names (default: all gated)")
    args = ap.parse_args(argv)
    names = args.names or sorted(GATE_METRICS)
    failures, notes = check_against(args.results_dir, args.baseline_dir, names)
    report(failures, notes)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
