"""Benchmark-regression gate: compare fresh results against committed
baselines and fail loudly when a decision-cost metric regresses.

Three PRs of measured speedups accumulated in ``results/benchmarks/``
with nothing stopping a future change from silently eroding them — CI
ran the benchmarks but never compared the numbers.  This module is the
comparison: ``benchmarks.run --check-against <baseline-dir>`` loads the
freshly emitted JSON and the committed baseline for each benchmark,
checks they are like-for-like (same ``meta.schema_version``, same
``meta.smoke`` flag — a full-sweep baseline is never compared against a
smoke run), and fails (nonzero exit) if any gated metric regresses more
than ``DEFAULT_THRESHOLD``.

Gated metrics are *ratios* (vectorized-kernel speedup over the scalar
oracle on the same machine in the same process), so they transfer
across machine speeds far better than absolute milliseconds — a CI
runner half as fast slows both sides of the ratio.  Both sides are
timed min-of-reps (``common.scalar_vs_vectorized``) so load spikes
cannot fake a regression.  Committed smoke baselines live in
``results/benchmarks/smoke/``; regenerate them with::

    python -m benchmarks.run --only table2,fig12 --smoke \
        --out results/benchmarks/smoke

Ratios still carry a *systematic* machine-class component (a 4-vCPU
runner gives XLA less parallel headroom than a many-core dev box), so
baselines should be captured on — or recalibrated to — the machine
class that runs the gate: the CI ``smoke-benchmarks`` artifact from any
green run IS a valid baseline (same schema, ``smoke`` flag and
parameters); download it and commit it under
``results/benchmarks/smoke/`` to rebase the gate on runner hardware.
"""

from __future__ import annotations

import json
import pathlib

from .common import SCHEMA_VERSION

__all__ = ["DEFAULT_THRESHOLD", "GATE_METRICS", "check_against"]

#: relative regression tolerance: fail when a higher-is-better metric
#: drops below (1 - threshold) x baseline.
DEFAULT_THRESHOLD = 0.20

#: benchmark name -> ((dotted metric path, direction), ...).  Only
#: ratio-valued decision-cost metrics belong here (see module docstring);
#: "higher" means higher is better.  GreedyLeastUsed's speedup is
#: intentionally not gated: its scalar path is already dispatch-proof,
#: so the ratio hovers near 1 and would gate on noise.
GATE_METRICS: dict[str, tuple[tuple[str, str], ...]] = {
    "table2": (
        ("batched_sc.decision_cost.speedup_vs_scalar", "higher"),
        ("batched_greedy.greedy_min_storage.decision_cost.speedup_vs_scalar",
         "higher"),
        ("batched_greedy.greedy_min_storage.committed.speedup_vs_scalar",
         "higher"),
    ),
}


#: keys that parameterize a benchmark section; compared along every
#: gated metric's ancestor path so a SMOKE_KWARGS tweak (different
#: batch/node count) is skipped instead of gated apples-to-oranges.
_PARAM_KEYS = ("n_nodes", "batch", "n_items")


def _lookup(payload: dict, dotted: str):
    node = payload
    for key in dotted.split("."):
        if not isinstance(node, dict) or key not in node:
            return None
        node = node[key]
    return node


def _params_along(payload: dict, dotted: str) -> dict:
    """Benchmark parameters found in the dicts along a metric's path."""
    out = {}
    node = payload
    prefix = []
    for key in dotted.split("."):
        if not isinstance(node, dict):
            break
        for pk in _PARAM_KEYS:
            v = node.get(pk)
            if isinstance(v, (int, float)):
                out[".".join(prefix + [pk])] = v
        node = node.get(key)
        prefix.append(key)
    return out


def _load(path: pathlib.Path):
    """Parsed baseline/result dict, or None when absent or unusable —
    a damaged file must skip its comparisons, never crash the run."""
    try:
        data = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    return data if isinstance(data, dict) else None


def check_against(
    out_dir,
    baseline_dir,
    names,
    threshold: float = DEFAULT_THRESHOLD,
) -> tuple[list[str], list[str]]:
    """Compare freshly emitted benchmark JSON against committed baselines.

    Returns ``(failures, notes)``: ``failures`` are regressions that must
    fail the run; ``notes`` are comparisons that were skipped and why
    (missing baseline, schema or smoke-mode mismatch, metric absent).
    A missing or mismatched baseline is never a failure — the gate only
    compares like-for-like.
    """
    out_dir = pathlib.Path(out_dir)
    baseline_dir = pathlib.Path(baseline_dir)
    failures: list[str] = []
    notes: list[str] = []
    for name in names:
        metrics = GATE_METRICS.get(name)
        if not metrics:
            continue
        new = _load(out_dir / f"{name}.json")
        base = _load(baseline_dir / f"{name}.json")
        if new is None:
            notes.append(f"{name}: no fresh results in {out_dir}; skipped")
            continue
        if base is None:
            notes.append(f"{name}: no baseline in {baseline_dir}; skipped")
            continue
        new_meta = new.get("meta", {})
        base_meta = base.get("meta", {})
        if new_meta.get("schema_version") != base_meta.get("schema_version") or \
                new_meta.get("schema_version") != SCHEMA_VERSION:
            notes.append(
                f"{name}: schema_version mismatch "
                f"(baseline {base_meta.get('schema_version')}, "
                f"fresh {new_meta.get('schema_version')}, "
                f"gate {SCHEMA_VERSION}); skipped"
            )
            continue
        if new_meta.get("smoke") != base_meta.get("smoke"):
            notes.append(
                f"{name}: smoke-mode mismatch "
                f"(baseline smoke={base_meta.get('smoke')}, "
                f"fresh smoke={new_meta.get('smoke')}); skipped"
            )
            continue
        for dotted, direction in metrics:
            old_v = _lookup(base, dotted)
            new_v = _lookup(new, dotted)
            if not isinstance(old_v, (int, float)) or not isinstance(
                new_v, (int, float)
            ):
                notes.append(f"{name}.{dotted}: metric absent; skipped")
                continue
            old_p = _params_along(base, dotted)
            new_p = _params_along(new, dotted)
            if old_p != new_p:
                notes.append(
                    f"{name}.{dotted}: benchmark parameters differ "
                    f"(baseline {old_p}, fresh {new_p}); skipped"
                )
                continue
            if direction == "higher":
                regressed = new_v < old_v * (1.0 - threshold)
            else:
                regressed = new_v > old_v * (1.0 + threshold)
            if regressed:
                failures.append(
                    f"{name}.{dotted}: {new_v:.3f} vs baseline {old_v:.3f} "
                    f"(worse than the {threshold:.0%} budget, "
                    f"baseline sha {base_meta.get('git_sha') or 'unknown'})"
                )
    return failures, notes


def report(failures: list[str], notes: list[str]) -> None:
    """Print a gate result to stderr (shared by run.py and the CLI)."""
    import sys

    for note in notes:
        print(f"[bench-gate] note: {note}", file=sys.stderr)
    for reg in failures:
        print(f"[bench-gate] REGRESSION {reg}", file=sys.stderr)
    if not failures:
        print("[bench-gate] all gated metrics within budget", file=sys.stderr)


def main(argv=None) -> None:
    """Standalone gate over already-emitted JSON (no benchmarks re-run):

        python -m benchmarks.gate <results-dir> <baseline-dir> [name ...]

    Used by CI to gate the verify job's smoke output without paying for
    a second benchmark sweep; ``benchmarks.run --check-against`` is the
    one-shot run-and-gate form.
    """
    import argparse

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("results_dir")
    ap.add_argument("baseline_dir")
    ap.add_argument("names", nargs="*", default=None,
                    help="benchmark names (default: all gated)")
    args = ap.parse_args(argv)
    names = args.names or sorted(GATE_METRICS)
    failures, notes = check_against(args.results_dir, args.baseline_dir, names)
    report(failures, notes)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
