"""Fig. 1: encode/decode/transfer time vs K (P=2 fixed), plus the
batched data-plane lane.

Per-K rows measure our GF(2^8) codec two ways on a fixed-size item: the
jnp reference path (the vectorized log/exp-table algorithm the paper's
CPU numbers correspond to) and the kernel path (Pallas on TPU; its
jitted XLA bit-matmul twin off-TPU — same algorithm, honestly timeable
on CPU CI), asserting the two are bit-identical.  Recalibrates
ECTimeModel's linear coefficients and reports the fit error, validating
the paper's 'linear regression closely matches measurements' claim
(§4.4).

The ``batched`` section is the regression lane for the multi-item data
plane (repro.kernels.ops.encode_chunks_many): a cohort of ``n_groups``
payloads is encoded per-item (one kernel launch per payload) and batched
(ONE launch for the cohort), min-of-reps timed.  The gate
(benchmarks/gate.py) pins the speedup ratio, the output digest, the
bit-for-bit match against the per-item oracle, and the compile census —
steady-state batched encode must issue ZERO new kernel signatures, the
one-compile-per-(K, P, bucket) claim.
"""

import hashlib
import time

import numpy as np

from repro.core import shapes as core_shapes
from repro.ec import ECCodec
from repro.kernels import ops as kops
from repro.storage import make_node_set
from repro import telemetry
from .common import csv_row, emit


def _digest(arrays) -> int:
    """Order-sensitive content digest as an int (the gate only compares
    numbers; 8 bytes of sha256 is plenty to pin bit-identical output)."""
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.ascontiguousarray(np.asarray(a, dtype=np.uint8)).tobytes())
    return int.from_bytes(h.digest()[:8], "big")


def _best_of(fn, reps: int):
    """Min-of-reps wall time (load-spike-robust; matches common.py)."""
    best, out = float("inf"), None
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(
    size_mb: float = 8.0,
    p: int = 2,
    ks=(2, 4, 6, 8, 10, 14),
    reps: int = 3,
    n_groups: int = 32,
    group_kb: int = 32,
) -> list[str]:
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, size=int(size_mb * 1e6), dtype=np.uint8).tobytes()
    nodes = make_node_set("most_used")
    rows, lines = [], []
    for k in ks:
        codec = ECCodec(k, p, use_kernel=False)
        t0 = time.perf_counter()
        chunks = codec.encode(payload)
        t_enc = time.perf_counter() - t0
        keep = np.arange(p, k + p)  # worst case: lose the first P data rows
        t0 = time.perf_counter()
        out = codec.decode(chunks[keep], keep, len(payload))
        t_dec = time.perf_counter() - t0
        assert out == payload
        # kernel path (Pallas on TPU / jitted XLA bit-matmul off-TPU):
        # warm the jit cache, min-of-reps time, pin bit-identical to ref.
        kcodec = ECCodec(k, p, use_kernel=True)
        kcodec.encode(payload)
        t_enc_kernel, kchunks = _best_of(lambda: kcodec.encode(payload), reps)
        kernel_ok = np.array_equal(kchunks, chunks)
        assert kernel_ok, f"kernel encode diverged from ref at k={k}"
        chunk_mb = size_mb / k
        t_up = chunk_mb / min(n.write_bw for n in nodes[: k + p])
        rows.append({
            "k": k, "p": p, "encode_s": t_enc, "decode_s": t_dec,
            "kernel_encode_s": t_enc_kernel, "kernel_matches_ref": int(kernel_ok),
            "upload_s": t_up,
        })
        lines.append(csv_row(
            f"fig1_encode_k{k}", t_enc * 1e6,
            f"decode_s={t_dec:.3f};kernel_encode_s={t_enc_kernel:.3f}"
        ))
    # decode grows ~linearly in K (the paper's headline observation)
    ks_arr = np.array([r["k"] for r in rows], float)
    dec = np.array([r["decode_s"] for r in rows])
    slope, intercept = np.polyfit(ks_arr, dec, 1)
    pred = slope * ks_arr + intercept
    rel_err = float(np.abs(pred - dec).mean() / dec.mean())

    batched, bl = _batched_lane(p, reps=reps, n_groups=n_groups, group_kb=group_kb)
    lines.extend(bl)

    emit("fig1", {"size_mb": size_mb, "rows": rows,
                  "decode_linear_fit": {"slope": slope, "intercept": intercept,
                                        "mean_rel_err": rel_err},
                  "batched": batched,
                  "matrix_cache": telemetry.snapshot().matrix_cache})
    lines.append(csv_row("fig1_linear_fit", 0.0, f"decode_fit_rel_err={rel_err:.3f}"))
    return lines


def _batched_lane(p: int, *, reps: int, n_groups: int, group_kb: int,
                  k: int = 6) -> tuple[dict, list[str]]:
    """Per-item kernel launches vs one cohort launch, same payloads."""
    rng = np.random.default_rng(1)
    payloads = [
        rng.integers(0, 256, size=group_kb * 1024, dtype=np.uint8).tobytes()
        for _ in range(n_groups)
    ]
    codec = ECCodec(k, p, use_kernel=True)

    def per_item():
        return [codec.encode(pl) for pl in payloads]

    def batched():
        return codec.encode_many(payloads)

    per_item(); batched()  # warm: jit compiles per (K, P, bucket) rung
    warmed = core_shapes.issued_shapes(kops.CENSUS_KERNEL)
    t_item, want = _best_of(per_item, reps)
    t_batch, got = _best_of(batched, reps)
    # Steady state must reuse the warmed compiles: the one-compile-per-
    # (K, P, bucket) census claim, asserted in-bench (gate.py pins the
    # count too, but a nonzero delta should fail loudly with context).
    steady_new = core_shapes.issued_shapes(kops.CENSUS_KERNEL) - warmed
    assert not steady_new, f"steady-state encode issued new compiles: {steady_new}"
    ok = len(want) == len(got) and all(
        np.array_equal(a, b) for a, b in zip(want, got)
    )
    assert ok, "batched encode diverged from the per-item oracle"
    out = {
        "k": k, "p": p, "n_groups": n_groups, "group_kb": group_kb,
        "reps": max(1, reps),
        "per_item_s": t_item,
        "batched_s": t_batch,
        "speedup_vs_per_item": t_item / t_batch if t_batch > 0 else float("inf"),
        "matches_per_item": int(ok),
        "chunks_digest": _digest(got),
        "steady_state_new_signatures": len(steady_new),
        "warmed_signatures": len(warmed),
    }
    lines = [
        csv_row("fig1_encode_per_item", t_item * 1e6,
                f"n_groups={n_groups};group_kb={group_kb}"),
        csv_row("fig1_encode_batched", t_batch * 1e6,
                f"speedup={out['speedup_vs_per_item']:.2f}x;"
                f"digest={out['chunks_digest']}"),
    ]
    return out, lines
