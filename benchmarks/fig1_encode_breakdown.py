"""Fig. 1: encode/decode/transfer time vs K (P=2 fixed).

Measures our GF(2^8) codec (the jnp reference path — the vectorized
log/exp-table algorithm the paper's CPU numbers correspond to; the
Pallas kernel targets TPU and only interprets on CPU) on a fixed-size
item across K, plus the modeled upload time on the Most Used node set.
Recalibrates ECTimeModel's linear coefficients and reports the R^2-style
fit error, validating the paper's 'linear regression closely matches
measurements' claim (§4.4).
"""

import time

import numpy as np

from repro.ec import ECCodec
from repro.storage import make_node_set
from .common import csv_row, emit


def run(size_mb: float = 8.0, p: int = 2, ks=(2, 4, 6, 8, 10, 14)) -> list[str]:
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, size=int(size_mb * 1e6), dtype=np.uint8).tobytes()
    nodes = make_node_set("most_used")
    rows, lines = [], []
    for k in ks:
        codec = ECCodec(k, p, use_kernel=False)
        t0 = time.perf_counter()
        chunks = codec.encode(payload)
        t_enc = time.perf_counter() - t0
        keep = np.arange(p, k + p)  # worst case: lose the first P data rows
        t0 = time.perf_counter()
        out = codec.decode(chunks[keep], keep, len(payload))
        t_dec = time.perf_counter() - t0
        assert out == payload
        chunk_mb = size_mb / k
        t_up = chunk_mb / min(n.write_bw for n in nodes[: k + p])
        rows.append({"k": k, "p": p, "encode_s": t_enc, "decode_s": t_dec, "upload_s": t_up})
        lines.append(csv_row(f"fig1_encode_k{k}", t_enc * 1e6, f"decode_s={t_dec:.3f}"))
    # decode grows ~linearly in K (the paper's headline observation)
    ks_arr = np.array([r["k"] for r in rows], float)
    dec = np.array([r["decode_s"] for r in rows])
    slope, intercept = np.polyfit(ks_arr, dec, 1)
    pred = slope * ks_arr + intercept
    rel_err = float(np.abs(pred - dec).mean() / dec.mean())
    emit("fig1", {"size_mb": size_mb, "rows": rows,
                  "decode_linear_fit": {"slope": slope, "intercept": intercept,
                                        "mean_rel_err": rel_err}})
    lines.append(csv_row("fig1_linear_fit", 0.0, f"decode_fit_rel_err={rel_err:.3f}"))
    return lines
